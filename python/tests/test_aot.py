"""AOT lowering: HLO-text artifacts + manifest the rust runtime consumes."""

import os

import numpy as np
import pytest

import jax.numpy as jnp

from compile import aot, model


class TestLowering:
    @pytest.mark.parametrize("kind,r,s,extra", aot.QUICK_VARIANTS)
    def test_variant_lowers_to_hlo_text(self, kind, r, s, extra):
        lowered = aot.build_variant(kind, r, s, extra)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")
        assert "entry_computation_layout" in text

    def test_filter_variant_io_signature(self):
        text = aot.to_hlo_text(aot.build_variant("filter", 64, 100,
                                                 model.PATTERN_LEN))
        # (chunk u8[64,100], pattern u8[16], nvalid s32) -> tuple of 3
        assert "u8[64,100]" in text
        assert f"u8[{model.PATTERN_MAX}]" in text
        assert "s32[64]" in text

    def test_wordcount_variant_io_signature(self):
        text = aot.to_hlo_text(aot.build_variant("wordcount", 16, 2048, 8192))
        assert "u8[16,2048]" in text
        assert "s32[8192]" in text

    def test_lowered_executes_like_eager(self):
        """AOT-compiled filter variant == eager model on the same inputs."""
        lowered = aot.build_variant("filter", 64, 100, model.PATTERN_LEN)
        compiled = lowered.compile()
        rng = np.random.default_rng(3)
        chunk = rng.integers(97, 100, size=(64, 100), dtype=np.uint8)
        pat = np.zeros(model.PATTERN_MAX, np.uint8)
        pat[:2] = np.frombuffer(b"ab", np.uint8)
        got = compiled(jnp.asarray(chunk), jnp.asarray(pat), jnp.int32(50))
        want = model.filter_count_chunk(jnp.asarray(chunk), jnp.asarray(pat),
                                        jnp.int32(50))
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


class TestManifest:
    def test_quick_run_writes_manifest(self, tmp_path):
        rc = aot.main(["--out-dir", str(tmp_path), "--quick"])
        assert rc == 0
        manifest = (tmp_path / aot.MANIFEST).read_text().strip().splitlines()
        assert manifest[0].startswith("#")
        rows = [l.split("\t") for l in manifest[1:]]
        assert len(rows) == len(aot.QUICK_VARIANTS)
        for name, kind, r, s, extra, fname in rows:
            assert (tmp_path / fname).exists()
            assert name == f"{kind}_r{r}_s{s}"
            assert (tmp_path / fname).read_text().startswith("HloModule")

    def test_variant_table_consistent(self):
        # every quick variant is a shipped variant (rust runtime relies on it)
        assert set(aot.QUICK_VARIANTS) <= set(aot.VARIANTS)
        names = [f"{k}_r{r}_s{s}" for k, r, s, _ in aot.VARIANTS]
        assert len(names) == len(set(names)), "duplicate variant names"
