"""Layer-1 word-count kernel vs the regex/bytes oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import wordcount_hist_pallas
from compile.kernels.ref import ref_wordcount_hist, fnv1a


def run_kernel(chunk: np.ndarray, buckets: int, block: int) -> np.ndarray:
    return np.asarray(
        wordcount_hist_pallas(jnp.asarray(chunk), buckets=buckets, block_records=block)
    )


def to_chunk(lines: list[bytes], s: int) -> np.ndarray:
    chunk = np.zeros((len(lines), s), np.uint8)
    for i, line in enumerate(lines):
        data = line[:s]
        chunk[i, : len(data)] = np.frombuffer(data, np.uint8)
    return chunk


class TestWordcountBasics:
    def test_empty_chunk(self):
        assert run_kernel(np.zeros((4, 32), np.uint8), 64, 2).sum() == 0

    def test_single_word(self):
        chunk = to_chunk([b"hello"], 32)
        hist = run_kernel(chunk, 64, 1)
        assert hist.sum() == 1
        assert hist[fnv1a(b"hello") % 64] == 1

    def test_case_folding(self):
        hist = run_kernel(to_chunk([b"Word word WORD"], 32), 128, 1)
        assert hist[fnv1a(b"word") % 128] == 3

    def test_digits_are_token_chars(self):
        hist = run_kernel(to_chunk([b"abc123 123"], 32), 256, 1)
        assert hist[fnv1a(b"abc123") % 256] == 1
        assert hist[fnv1a(b"123") % 256] == 1

    def test_punctuation_splits(self):
        hist = run_kernel(to_chunk([b"a-b_c.d,e"], 32), 256, 1)
        assert hist.sum() == 5

    def test_word_at_record_end_flushed(self):
        # token runs into the record boundary: must still be counted
        s = 8
        chunk = to_chunk([b"xx yyyyy"], s)  # 'yyyyy' ends exactly at S
        hist = run_kernel(chunk, 64, 1)
        assert hist.sum() == 2
        assert hist[fnv1a(b"yyyyy") % 64] == 1

    def test_tokens_do_not_span_records(self):
        chunk = to_chunk([b"abc", b"def"], 4)
        hist = run_kernel(chunk, 64, 2)
        assert hist[fnv1a(b"abc") % 64] == 1
        assert hist[fnv1a(b"def") % 64] == 1
        assert hist[fnv1a(b"abcdef") % 64] == 0

    def test_high_bytes_are_separators(self):
        chunk = to_chunk(["héllo wörld".encode("utf-8")], 32)
        np.testing.assert_array_equal(run_kernel(chunk, 128, 1),
                                      ref_wordcount_hist(chunk, 128))

    def test_ragged_grid(self):
        chunk = to_chunk([b"one two"] * 7, 16)  # 7 rows, block 4 -> padded tile
        hist = run_kernel(chunk, 64, 4)
        assert hist.sum() == 14

    def test_shipped_variant_shapes(self):
        # wordcount_r16_s2048 / r64, buckets 8192 (compile/aot.py::VARIANTS)
        rng = np.random.default_rng(7)
        text = (b"the quick brown Fox jumps over the lazy dog 42 " * 50)[:2048]
        chunk = np.tile(np.frombuffer(text, np.uint8), (16, 1))
        chunk[3, :] = rng.integers(0, 256, 2048, np.uint8)  # one noisy row
        np.testing.assert_array_equal(run_kernel(chunk, 8192, 16),
                                      ref_wordcount_hist(chunk, 8192))


TEXTISH = st.binary(min_size=0, max_size=40).map(
    lambda b: bytes(x % 128 for x in b)  # bias toward ASCII
)


@settings(max_examples=30, deadline=None)
@given(
    lines=st.lists(TEXTISH, min_size=1, max_size=8),
    buckets=st.sampled_from([16, 64, 256, 8192]),
    block=st.integers(1, 8),
)
def test_wordcount_matches_oracle_random(lines, buckets, block):
    """Property: kernel histogram == regex-tokenise + FNV oracle."""
    s = max(max((len(l) for l in lines), default=1), 1)
    chunk = to_chunk(lines, s)
    np.testing.assert_array_equal(
        run_kernel(chunk, buckets, block), ref_wordcount_hist(chunk, buckets)
    )


@settings(max_examples=15, deadline=None)
@given(words=st.lists(st.from_regex(rb"[a-z0-9]{1,6}", fullmatch=True),
                      min_size=1, max_size=10))
def test_total_token_count_is_word_count(words):
    """Property: sum(hist) == number of tokens regardless of bucketing."""
    line = b" ".join(words)
    chunk = to_chunk([line], len(line) + 1)
    assert run_kernel(chunk, 32, 1).sum() == len(words)
