"""Layer-1 filter kernel vs the pure-Python oracle.

Hypothesis sweeps shapes, block sizes, pattern lengths and payload content;
the deterministic cases pin the exact configurations the AOT variants ship.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import filter_count_pallas
from compile.kernels.ref import ref_filter


def run_kernel(chunk: np.ndarray, pattern: bytes, block_records: int) -> np.ndarray:
    patbuf = np.zeros(16, np.uint8)
    patbuf[: len(pattern)] = np.frombuffer(pattern, np.uint8)
    out = filter_count_pallas(
        jnp.asarray(chunk),
        jnp.asarray(patbuf),
        pattern_len=len(pattern),
        block_records=block_records,
    )
    return np.asarray(out)


def plant(chunk: np.ndarray, pattern: bytes, rows, col: int) -> None:
    for r in rows:
        chunk[r, col : col + len(pattern)] = np.frombuffer(pattern, np.uint8)


class TestFilterBasics:
    def test_no_match(self):
        chunk = np.zeros((8, 100), np.uint8)
        assert run_kernel(chunk, b"needle", 8).sum() == 0

    def test_all_match(self):
        chunk = np.zeros((8, 100), np.uint8)
        plant(chunk, b"needle", range(8), 3)
        assert run_kernel(chunk, b"needle", 8).sum() == 8

    def test_match_at_start(self):
        chunk = np.zeros((4, 100), np.uint8)
        plant(chunk, b"abc", [1], 0)
        np.testing.assert_array_equal(run_kernel(chunk, b"abc", 4), [0, 1, 0, 0])

    def test_match_at_exact_end(self):
        chunk = np.zeros((4, 100), np.uint8)
        plant(chunk, b"xyz", [2], 97)  # last window position
        np.testing.assert_array_equal(run_kernel(chunk, b"xyz", 4), [0, 0, 1, 0])

    def test_partial_pattern_no_match(self):
        chunk = np.zeros((2, 50), np.uint8)
        chunk[0, 10:15] = np.frombuffer(b"needl", np.uint8)  # truncated needle
        assert run_kernel(chunk, b"needle", 2).sum() == 0

    def test_single_byte_pattern(self):
        chunk = np.zeros((3, 20), np.uint8)
        chunk[1, 19] = ord("q")
        np.testing.assert_array_equal(run_kernel(chunk, b"q", 3), [0, 1, 0])

    def test_pattern_spans_full_record(self):
        s = 12
        chunk = np.zeros((2, s), np.uint8)
        pat = b"x" * s
        chunk[0, :] = ord("x")
        np.testing.assert_array_equal(run_kernel(chunk, pat, 2), [1, 0])

    def test_rejects_oversized_pattern(self):
        chunk = np.zeros((2, 4), np.uint8)
        with pytest.raises(ValueError):
            run_kernel(chunk, b"toolongpattern", 2)

    def test_ragged_grid_tail_rows(self):
        # R=37 with block 8 -> padded grid; padded rows must not leak flags.
        chunk = np.zeros((37, 100), np.uint8)
        plant(chunk, b"tail", [36], 50)
        flags = run_kernel(chunk, b"tail", 8)
        assert flags.shape == (37,)
        assert flags[36] == 1 and flags[:36].sum() == 0

    @pytest.mark.parametrize("r,s,block", [(64, 100, 64), (256, 100, 64),
                                           (1024, 100, 64), (64, 2048, 64)])
    def test_shipped_variant_shapes(self, r, s, block):
        """Exactly the AOT variant shapes from compile/aot.py::VARIANTS."""
        rng = np.random.default_rng(r + s)
        chunk = rng.integers(0, 256, size=(r, s), dtype=np.uint8)
        pattern = b"ZSneed"
        plant(chunk, pattern, range(0, r, 7), s // 3)
        np.testing.assert_array_equal(run_kernel(chunk, pattern, block),
                                      ref_filter(chunk, pattern))


@settings(max_examples=40, deadline=None)
@given(
    r=st.integers(1, 80),
    s=st.integers(8, 160),
    block=st.integers(1, 96),
    plen=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_filter_matches_oracle_random(r, s, block, plen, seed):
    """Property: kernel == bytes-level `in` oracle on random payloads."""
    plen = min(plen, s)
    rng = np.random.default_rng(seed)
    # Low-entropy alphabet so incidental matches actually happen.
    chunk = rng.integers(97, 101, size=(r, s), dtype=np.uint8)
    pattern = bytes(rng.integers(97, 101, size=plen, dtype=np.uint8).tolist())
    np.testing.assert_array_equal(
        run_kernel(chunk, pattern, block), ref_filter(chunk, pattern)
    )


@settings(max_examples=15, deadline=None)
@given(
    s=st.integers(4, 64),
    col=st.integers(0, 60),
    plen=st.integers(1, 6),
)
def test_filter_planted_always_found(s, col, plen):
    """Property: a planted in-bounds needle is always flagged."""
    plen = min(plen, s)
    col = min(col, s - plen)
    chunk = np.zeros((5, s), np.uint8)
    pattern = bytes(range(200, 200 + plen))
    plant(chunk, pattern, [3], col)
    flags = run_kernel(chunk, pattern, 2)
    assert flags[3] == 1
