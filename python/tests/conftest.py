import os
import sys

# Make the build-time `compile` package importable regardless of pytest's cwd.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
