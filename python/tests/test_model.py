"""Layer-2 model graphs: masking, reductions, window aggregation."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels.ref import ref_filter, ref_wordcount_hist


def mk_pattern(pattern: bytes) -> np.ndarray:
    buf = np.zeros(model.PATTERN_MAX, np.uint8)
    buf[: len(pattern)] = np.frombuffer(pattern, np.uint8)
    return buf


class TestFilterCountChunk:
    def test_full_chunk(self):
        chunk = np.zeros((8, 100), np.uint8)
        chunk[2, 5:11] = np.frombuffer(b"needle", np.uint8)
        flags, matches, records = model.filter_count_chunk(
            jnp.asarray(chunk), jnp.asarray(mk_pattern(b"needle")), jnp.int32(8)
        )
        assert int(matches) == 1
        assert int(records) == 8
        assert np.asarray(flags)[2] == 1

    def test_nvalid_masks_tail(self):
        chunk = np.zeros((8, 100), np.uint8)
        for r in (1, 6):  # 6 is past nvalid
            chunk[r, 0:6] = np.frombuffer(b"needle", np.uint8)
        flags, matches, records = model.filter_count_chunk(
            jnp.asarray(chunk), jnp.asarray(mk_pattern(b"needle")), jnp.int32(4)
        )
        assert int(matches) == 1
        assert int(records) == 4
        assert np.asarray(flags)[6] == 0

    def test_nvalid_zero(self):
        chunk = np.full((4, 50), ord("a"), np.uint8)
        _, matches, records = model.filter_count_chunk(
            jnp.asarray(chunk), jnp.asarray(mk_pattern(b"aaa")), jnp.int32(0)
        )
        assert int(matches) == 0 and int(records) == 0

    @settings(max_examples=20, deadline=None)
    @given(nvalid=st.integers(0, 16), seed=st.integers(0, 1000))
    def test_matches_oracle_on_valid_prefix(self, nvalid, seed):
        rng = np.random.default_rng(seed)
        chunk = rng.integers(97, 100, size=(16, 40), dtype=np.uint8)
        pattern = b"ab"
        flags, matches, records = model.filter_count_chunk(
            jnp.asarray(chunk), jnp.asarray(mk_pattern(pattern)),
            jnp.int32(nvalid), pattern_len=len(pattern), block_records=8,
        )
        expect = ref_filter(chunk, pattern)
        expect[nvalid:] = 0
        np.testing.assert_array_equal(np.asarray(flags), expect)
        assert int(matches) == expect.sum()
        assert int(records) == nvalid


class TestWordcountChunk:
    def test_masking_drops_invalid_rows(self):
        chunk = np.zeros((4, 32), np.uint8)
        for i in range(4):
            chunk[i, :5] = np.frombuffer(b"hello", np.uint8)
        hist, total = model.wordcount_chunk(jnp.asarray(chunk), jnp.int32(2),
                                            buckets=64, block_records=2)
        assert int(total) == 2

    def test_full_matches_oracle(self):
        text = b"To be or not to be that is the Question"
        chunk = np.zeros((2, 64), np.uint8)
        chunk[0, : len(text)] = np.frombuffer(text, np.uint8)
        chunk[1, :10] = np.frombuffer(b"question 1", np.uint8)
        hist, total = model.wordcount_chunk(jnp.asarray(chunk), jnp.int32(2),
                                            buckets=256, block_records=2)
        np.testing.assert_array_equal(np.asarray(hist),
                                      ref_wordcount_hist(chunk, 256))
        assert int(total) == 12


class TestWindowSum:
    def test_sums_slides(self):
        hists = np.arange(5 * 16, dtype=np.int32).reshape(5, 16)
        (out,) = model.window_sum(jnp.asarray(hists))
        np.testing.assert_array_equal(np.asarray(out), hists.sum(axis=0))

    @settings(max_examples=10, deadline=None)
    @given(w=st.integers(1, 8), b=st.integers(1, 64), seed=st.integers(0, 99))
    def test_window_sum_property(self, w, b, seed):
        rng = np.random.default_rng(seed)
        hists = rng.integers(0, 100, size=(w, b)).astype(np.int32)
        (out,) = model.window_sum(jnp.asarray(hists))
        np.testing.assert_array_equal(np.asarray(out), hists.sum(axis=0))


class TestMakeFns:
    def test_filter_fn_shapes(self):
        fn, args = model.make_filter_fn(64, 100)
        assert args[0].shape == (64, 100)
        assert args[1].shape == (model.PATTERN_MAX,)

    def test_wordcount_fn_shapes(self):
        fn, args = model.make_wordcount_fn(16, 2048)
        assert args[0].shape == (16, 2048)

    def test_window_fn_shapes(self):
        fn, args = model.make_window_sum_fn(5, 8192)
        assert args[0].shape == (5, 8192)
