"""Layer-1 Pallas kernels for the ZettaStream streaming operators.

The paper's processing hot loops (the per-record work inside the Flink
user functions of Listings 1 & 2) are implemented as Pallas kernels over
chunk tensors:

* :mod:`filter_count` — substring filter + record count over a ``[R, S]``
  u8 chunk (the "iterate, count and filter" synthetic benchmarks,
  Figs. 5-8).
* :mod:`wordcount_hist` — token scan + rolling-FNV hash histogram (the
  Wikipedia word-count benchmarks, Fig. 9).

All kernels are lowered with ``interpret=True`` — real-TPU lowering emits
Mosaic custom-calls the CPU PJRT plugin cannot execute. Correctness is
checked against the pure-jnp oracles in :mod:`ref` by the pytest suite.
"""

from .filter_count import filter_count_pallas, FNV_OFFSET, FNV_PRIME
from .wordcount_hist import wordcount_hist_pallas, DEFAULT_BUCKETS

__all__ = [
    "filter_count_pallas",
    "wordcount_hist_pallas",
    "FNV_OFFSET",
    "FNV_PRIME",
    "DEFAULT_BUCKETS",
]
