"""Word-count kernel: tokenise records and histogram word hashes.

The Wikipedia benchmarks (paper Fig. 9, Listing 2) tokenise 2 KiB text
records and count words, keyed by word (``keyBy(f0).sum(1)``). The keyed
aggregation state lives in the rust worker (the ``KeyBy``/``Sum`` operators);
the per-record hot loop — scanning bytes, finding token boundaries, hashing
tokens — is this kernel. It emits a bucketed histogram of FNV-1a word hashes
per chunk, which the rust side merges into the keyed state (DESIGN.md §2
documents this exact-word → hash-bucket substitution; the throughput metric
the paper plots counts tuples, which is preserved).

Algorithm, vectorised over a ``[TR, S]`` record tile in VMEM:

* march one column (byte position) at a time with ``lax.fori_loop``;
* per row maintain ``(hash, in_word)`` rolling state — FNV-1a over
  lowercased alphanumeric runs;
* when a token ends (alpha→non-alpha edge), scatter-add 1 into
  ``hist[hash % B]``.

The histogram (``B`` buckets, int32) stays VMEM-resident for the whole tile;
only token-end columns touch it. The final column flushes still-open tokens.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .filter_count import FNV_OFFSET, FNV_PRIME

DEFAULT_BUCKETS = 8192


def _is_alnum(ch):
    """Token chars: ASCII letters (case-folded) and digits."""
    lower = ch | 0x20
    is_alpha = (lower >= ord("a")) & (lower <= ord("z"))
    is_digit = (ch >= ord("0")) & (ch <= ord("9"))
    return is_alpha | is_digit


def _fold(ch):
    """Case-fold a token byte the way the oracle/rust sides do."""
    is_upper = (ch >= ord("A")) & (ch <= ord("Z"))
    return jnp.where(is_upper, ch | 0x20, ch)


def _wordcount_kernel(chunk_ref, hist_ref, *, buckets: int):
    # Perf pass (EXPERIMENTS.md §Perf L1): a scan-then-scatter restructure
    # (emit bucket ids per column, one scatter at the end) was tried and
    # measured SLOWER (14.2 vs 13.0 us/record) — XLA already donates the
    # histogram buffer through the While loop, so the carried [B] update is
    # in-place and the scan variant only added a [S, TR] materialisation.
    # Kept: the straightforward rolling-state loop with per-column scatter.
    tile = chunk_ref[...].astype(jnp.uint32)  # [TR, S]
    tr, s = tile.shape

    @pl.when(pl.program_id(0) == 0)
    def _init():
        hist_ref[...] = jnp.zeros((buckets,), jnp.int32)

    def body(c, carry):
        h, in_word, hist = carry
        ch = tile[:, c]
        tok = _is_alnum(ch)
        folded = _fold(ch)
        # FNV-1a step for rows inside a token char.
        h_step = ((h ^ folded) * jnp.uint32(FNV_PRIME)).astype(jnp.uint32)
        h_next = jnp.where(tok, h_step, jnp.uint32(FNV_OFFSET))
        ended = in_word & ~tok
        bucket = (h % jnp.uint32(buckets)).astype(jnp.int32)
        hist = hist.at[bucket].add(ended.astype(jnp.int32))
        return h_next, tok, hist

    h0 = jnp.full((tr,), FNV_OFFSET, jnp.uint32)
    in0 = jnp.zeros((tr,), jnp.bool_)
    h, in_word, hist = jax.lax.fori_loop(0, s, body, (h0, in0, hist_ref[...]))
    # Flush tokens that run into the record end (records are padded with
    # NULs by the producer framing, but a fully-packed record can end
    # mid-word).
    bucket = (h % jnp.uint32(buckets)).astype(jnp.int32)
    hist = hist.at[bucket].add(in_word.astype(jnp.int32))
    hist_ref[...] = hist


@functools.partial(jax.jit, static_argnames=("buckets", "block_records"))
def wordcount_hist_pallas(chunk, *, buckets: int = DEFAULT_BUCKETS, block_records: int = 16):
    """Histogram of FNV-1a word-hash buckets over a chunk.

    Args:
      chunk: ``[R, S]`` uint8 record-framed text chunk.
      buckets: histogram size ``B`` (static).
      block_records: records per VMEM tile (static).

    Returns:
      ``[B]`` int32 — token counts per hash bucket; ``sum(hist)`` is the
      total token count of the chunk.
    """
    r, s = chunk.shape
    tr = min(block_records, r)
    # Pad the record axis to a whole number of tiles; all-NUL rows contain
    # no token chars and contribute nothing to the histogram.
    rpad = pl.cdiv(r, tr) * tr
    if rpad != r:
        chunk = jnp.pad(chunk, ((0, rpad - r), (0, 0)))
    grid = (rpad // tr,)
    return pl.pallas_call(
        functools.partial(_wordcount_kernel, buckets=buckets),
        grid=grid,
        in_specs=[pl.BlockSpec((tr, s), lambda i: (i, 0))],
        # One VMEM-resident histogram accumulated across all grid steps.
        out_specs=pl.BlockSpec((buckets,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((buckets,), jnp.int32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(chunk)
