"""Substring-filter kernel: the hot loop of the paper's filter benchmark.

The synthetic "iterate, count and filter" benchmarks (paper Figs. 5-8) apply
a grep-style predicate to the byte payload of every stream record. A chunk —
the unit a source reader pulls (or the broker pushes) per partition — is a
``[R, S]`` uint8 tensor: ``R`` records of ``S`` bytes. The kernel reports,
per record, whether ``pattern`` occurs anywhere in the record.

Hardware adaptation (DESIGN.md §5): the paper scans records on Epyc cores
out of L2-resident chunks; here a record-block tile of the chunk is staged
into VMEM via the BlockSpec index map and scanned with vectorised
shift-compare-AND reductions — elementwise VPU work, not MXU matmuls, since
the workload is memory-bound. The pattern is broadcast once per tile.

The match test for window offset ``o``::

    match[r, o] = AND_{j<P} chunk[r, o + j] == pattern[j]
    flag[r]     = OR_o match[r, o]

implemented as ``P`` shifted equality slices (``P`` is a static kernel
parameter, kept small) so the inner loop fully vectorises over ``[TR, S]``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# FNV-1a constants, shared with the word-count kernel and the rust-side
# native fallback (rust/src/compute/native.rs must match bit-for-bit).
FNV_OFFSET = 2166136261
FNV_PRIME = 16777619


def _filter_kernel(chunk_ref, pat_ref, flags_ref, *, pattern_len: int):
    """One grid step: flag records of a ``[TR, S]`` tile that contain the pattern.

    Comparisons stay in uint8 (perf pass: the original int32 upcast
    quadrupled the vector traffic for zero benefit — equality on bytes is
    equality on bytes).
    """
    tile = chunk_ref[...]  # [TR, S] uint8
    pat = pat_ref[...]  # [P_MAX] uint8
    tr, s = tile.shape
    nw = s - pattern_len + 1  # window positions
    acc = jnp.ones((tr, nw), dtype=jnp.bool_)
    for j in range(pattern_len):  # static unroll, P is small
        acc = acc & (jax.lax.slice_in_dim(tile, j, j + nw, axis=1) == pat[j])
    flags_ref[...] = jnp.any(acc, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("pattern_len", "block_records"))
def filter_count_pallas(chunk, pattern, *, pattern_len: int, block_records: int = 64):
    """Per-record substring-match flags for a chunk.

    Args:
      chunk: ``[R, S]`` uint8 — record-framed chunk payload.
      pattern: ``[P_MAX]`` uint8 — needle, padded to a static max length.
      pattern_len: number of valid bytes in ``pattern`` (static).
      block_records: records per VMEM tile (static; R % block_records == 0
        is not required — the grid covers ceil(R / block)).

    Returns:
      ``[R]`` int32 — 1 where the record contains the pattern.
    """
    r, s = chunk.shape
    if pattern_len < 1 or pattern_len > s:
        raise ValueError(f"pattern_len {pattern_len} out of range for S={s}")
    tr = min(block_records, r)
    # Pad the record axis to a whole number of tiles; zero rows cannot match
    # a non-empty pattern of non-NUL bytes and are sliced off below.
    rpad = pl.cdiv(r, tr) * tr
    if rpad != r:
        chunk = jnp.pad(chunk, ((0, rpad - r), (0, 0)))
    grid = (rpad // tr,)
    flags = pl.pallas_call(
        functools.partial(_filter_kernel, pattern_len=pattern_len),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tr, s), lambda i: (i, 0)),  # HBM->VMEM record tile
            pl.BlockSpec((pattern.shape[0],), lambda i: (0,)),  # pattern, replicated
        ],
        out_specs=pl.BlockSpec((tr,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rpad,), jnp.int32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(chunk, pattern)
    return flags[:r]
