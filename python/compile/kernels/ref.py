"""Pure-Python/numpy oracles for the Pallas kernels.

These are the CORE correctness signal for Layer 1: deliberately written in
the most obvious way possible (Python bytes / regex / int arithmetic, no jax)
so that a bug in the kernels cannot be mirrored here. The rust-side native
fallback (rust/src/compute/native.rs) implements the same semantics and is
cross-checked by the integration tests through the record framing.

Token semantics shared by kernel, oracle, and rust:
  * a token is a maximal run of ASCII ``[a-zA-Z0-9]`` bytes;
  * tokens are case-folded to lowercase before hashing;
  * hash is FNV-1a (32-bit): ``h = 2166136261; h = (h ^ b) * 16777619 mod 2^32``;
  * a token is terminated by the record boundary (records do not continue
    across framing).
"""

import re

import numpy as np

FNV_OFFSET = 2166136261
FNV_PRIME = 16777619

_TOKEN_RE = re.compile(rb"[a-zA-Z0-9]+")


def fnv1a(token: bytes) -> int:
    """32-bit FNV-1a over an already-case-folded token."""
    h = FNV_OFFSET
    for b in token:
        h = ((h ^ b) * FNV_PRIME) & 0xFFFFFFFF
    return h


def ref_filter(chunk: np.ndarray, pattern: bytes) -> np.ndarray:
    """``[R]`` int32 flags: 1 where `pattern` occurs in the record bytes."""
    assert chunk.dtype == np.uint8 and chunk.ndim == 2
    rows = [1 if pattern in row.tobytes() else 0 for row in chunk]
    return np.asarray(rows, dtype=np.int32)


def ref_tokens(record: bytes) -> list[bytes]:
    """Case-folded tokens of one record."""
    return [t.lower() for t in _TOKEN_RE.findall(record)]


def ref_wordcount_hist(chunk: np.ndarray, buckets: int) -> np.ndarray:
    """``[B]`` int32 histogram of FNV-1a(token) % buckets over all records."""
    assert chunk.dtype == np.uint8 and chunk.ndim == 2
    hist = np.zeros(buckets, dtype=np.int32)
    for row in chunk:
        for tok in ref_tokens(row.tobytes()):
            hist[fnv1a(tok) % buckets] += 1
    return hist


def ref_word_counts(chunk: np.ndarray) -> dict[bytes, int]:
    """Exact per-word counts (used by integration-level word-count checks)."""
    counts: dict[bytes, int] = {}
    for row in chunk:
        for tok in ref_tokens(row.tobytes()):
            counts[tok] = counts.get(tok, 0) + 1
    return counts
