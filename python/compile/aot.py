"""AOT-lower the Layer-2 graphs to HLO text artifacts for the rust runtime.

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/gen_hlo.py).

Each graph is lowered once per ``[R, S]`` chunk-shape *variant*; the rust
compute bridge (rust/src/compute) pads a chunk's record axis up to the
smallest compiled variant that fits. The variant table below is the single
source of truth — ``manifest.tsv`` carries it to the rust side.

Usage::

    python -m compile.aot --out-dir ../artifacts [--quick]
"""

import argparse
import os
import sys

import jax

from . import model

try:  # jax moved the private xla_client around across releases
    from jax._src.lib import xla_client as xc
except ImportError:  # pragma: no cover
    from jaxlib import xla_client as xc

MANIFEST = "manifest.tsv"

# kind, R, S, extra — extra is pattern_len for filter, buckets for wordcount,
# window size for window_sum. Keep this in sync with rust/src/compute/variants.rs
# (the rust side reads manifest.tsv, so only names/shapes must agree).
VARIANTS = [
    # the synthetic benchmarks: RecS=100 B records, chunks 1..128 KiB
    ("filter", 64, 100, model.PATTERN_LEN),
    ("filter", 256, 100, model.PATTERN_LEN),
    ("filter", 1024, 100, model.PATTERN_LEN),
    ("filter", 2048, 100, model.PATTERN_LEN),
    # the Wikipedia benchmarks: 2 KiB text records
    ("filter", 64, 2048, model.PATTERN_LEN),
    ("wordcount", 16, 2048, 8192),
    ("wordcount", 64, 2048, 8192),
    ("window_sum", 5, 8192, 0),
]

QUICK_VARIANTS = [
    ("filter", 64, 100, model.PATTERN_LEN),
    ("wordcount", 16, 2048, 8192),
    ("window_sum", 5, 8192, 0),
]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple for rust unwrap)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_variant(kind: str, r: int, s: int, extra: int):
    if kind == "filter":
        fn, args = model.make_filter_fn(r, s, pattern_len=extra)
    elif kind == "wordcount":
        fn, args = model.make_wordcount_fn(r, s, buckets=extra)
    elif kind == "window_sum":
        fn, args = model.make_window_sum_fn(r, buckets=s)
    else:  # pragma: no cover
        raise ValueError(f"unknown kind {kind}")
    return jax.jit(fn).lower(*args)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="only the variants the tests need (fast CI loop)")
    args = ap.parse_args(argv)

    os.makedirs(args.out_dir, exist_ok=True)
    variants = QUICK_VARIANTS if args.quick else VARIANTS
    rows = []
    for kind, r, s, extra in variants:
        name = f"{kind}_r{r}_s{s}"
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        lowered = build_variant(kind, r, s, extra)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        rows.append((name, kind, r, s, extra, f"{name}.hlo.txt"))
        print(f"  {name}: {len(text)} chars -> {path}", file=sys.stderr)

    with open(os.path.join(args.out_dir, MANIFEST), "w") as f:
        f.write("# name\tkind\tr\ts\textra\tfile\n")
        for row in rows:
            f.write("\t".join(str(x) for x in row) + "\n")
    print(f"wrote {len(rows)} artifacts + {MANIFEST} to {args.out_dir}",
          file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
