"""Layer-2 JAX compute graphs for the streaming operators.

Each function here is a chunk-granularity compute graph that the rust worker
invokes on its hot path (through the AOT artifacts — python never runs at
request time). They wrap the Layer-1 Pallas kernels with the masking and
reductions the operators need:

* :func:`filter_count_chunk` — the "iterate, count and filter" benchmark
  body (paper Listing 1 / Figs. 5-8): per-record match flags for a partial
  chunk + match / record counts.
* :func:`wordcount_chunk` — the word-count benchmark body (paper Listing 2 /
  Fig. 9): masked token-hash histogram of a partial chunk.
* :func:`window_sum` — the sliding-window aggregation of the windowed
  word-count (5 s window, 1 s slide): sums per-second histograms.

Every graph takes ``nvalid`` (records actually present in the chunk — the
tail chunk of a segment is rarely full) so one compiled variant serves any
fill level of its ``[R, S]`` shape.
"""

import jax
import jax.numpy as jnp

from .kernels import filter_count_pallas, wordcount_hist_pallas, DEFAULT_BUCKETS

# Pattern buffer length in the filter artifacts; actual needle length is a
# compile-time constant baked into each variant (PATTERN_LEN).
PATTERN_MAX = 16
PATTERN_LEN = 6  # the benchmarks grep for a fixed 6-byte needle


def filter_count_chunk(chunk, pattern, nvalid, *, pattern_len: int = PATTERN_LEN,
                       block_records: int = 64):
    """Filter + count one (possibly partial) chunk.

    Args:
      chunk: ``[R, S]`` uint8.
      pattern: ``[PATTERN_MAX]`` uint8, needle in the first `pattern_len` bytes.
      nvalid: int32 scalar — records present (``<= R``).

    Returns:
      ``(flags[R] int32, match_count int32, record_count int32)``.
    """
    r = chunk.shape[0]
    flags = filter_count_pallas(chunk, pattern, pattern_len=pattern_len,
                                block_records=block_records)
    valid = (jnp.arange(r, dtype=jnp.int32) < nvalid).astype(jnp.int32)
    flags = flags * valid
    return flags, jnp.sum(flags), jnp.sum(valid)


def wordcount_chunk(chunk, nvalid, *, buckets: int = DEFAULT_BUCKETS,
                    block_records: int = 16):
    """Token-hash histogram of one (possibly partial) chunk.

    Rows at or past ``nvalid`` are zeroed before the kernel — NUL rows hold
    no token characters, so they add nothing to the histogram.

    Returns:
      ``(hist[B] int32, token_count int32)``.
    """
    r = chunk.shape[0]
    valid = (jnp.arange(r, dtype=jnp.int32) < nvalid).astype(chunk.dtype)
    masked = chunk * valid[:, None]
    hist = wordcount_hist_pallas(masked, buckets=buckets, block_records=block_records)
    return hist, jnp.sum(hist)


def window_sum(hists):
    """Aggregate ``[W, B]`` per-slide histograms into one window histogram."""
    return (jnp.sum(hists, axis=0, dtype=jnp.int32),)


def make_filter_fn(r: int, s: int, *, pattern_len: int = PATTERN_LEN,
                   block_records: int = 64):
    """Closed-shape jit-able entry for AOT lowering of the filter graph."""

    def fn(chunk, pattern, nvalid):
        return filter_count_chunk(chunk, pattern, nvalid,
                                  pattern_len=pattern_len,
                                  block_records=block_records)

    args = (
        jax.ShapeDtypeStruct((r, s), jnp.uint8),
        jax.ShapeDtypeStruct((PATTERN_MAX,), jnp.uint8),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
    return fn, args


def make_wordcount_fn(r: int, s: int, *, buckets: int = DEFAULT_BUCKETS,
                      block_records: int | None = None):
    """Closed-shape jit-able entry for AOT lowering of the word-count graph.

    Perf pass: the column loop (`S` iterations of rolling-hash state) runs
    once per grid step, so the tile should cover the whole record axis —
    ``block_records = r`` amortises the loop across every row at once and
    widens the per-column vector ops (EXPERIMENTS.md §Perf L1).
    """
    if block_records is None:
        block_records = min(r, 64)

    def fn(chunk, nvalid):
        return wordcount_chunk(chunk, nvalid, buckets=buckets,
                               block_records=block_records)

    args = (
        jax.ShapeDtypeStruct((r, s), jnp.uint8),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
    return fn, args


def make_window_sum_fn(w: int, buckets: int = DEFAULT_BUCKETS):
    """Closed-shape entry for the window aggregation graph."""
    args = (jax.ShapeDtypeStruct((w, buckets), jnp.int32),)
    return window_sum, args
