//! A tour of the public API: build a custom pipeline with the DataStream
//! builder, run it on both planes, inspect operators afterwards.
//!
//! ```bash
//! cargo run --release --example pipeline_tour
//! ```

use zettastream::cluster::launch;
use zettastream::compute::ComputeEngine;
use zettastream::config::{DataPlane, ExperimentConfig, SourceMode, Workload};
use zettastream::ops::FilterOp;
use zettastream::pipeline::{OpKind, Pipeline};
use zettastream::sim::SECOND;
use zettastream::worker::OperatorTask;

fn main() {
    // 1. The builder mirrors the paper's Listings 1 & 2.
    let listing1 = Pipeline::source(4).flat_map(OpKind::Filter, 8).build();
    println!("Listing 1 pipeline: {listing1:?}");
    println!("  slots used: {} (vs NFs)", listing1.slots_used());
    let listing2 = Pipeline::source(4)
        .flat_map(OpKind::Tokenizer, 8)
        .key_by_windowed_sum(8)
        .build();
    println!("Listing 2 pipeline: {listing2:?}\n");

    // 2. Run the filter benchmark on the sim plane and pull the operator
    //    state back out of the cluster afterwards.
    let config = ExperimentConfig {
        name: "tour-sim".into(),
        np: 2,
        nc: 2,
        ns: 4,
        nmap: 4,
        workload: Workload::Filter,
        mode: SourceMode::Push,
        duration_secs: 10,
        warmup_secs: 2,
        ..Default::default()
    };
    let cluster = launch(&config, None);
    let mut engine = cluster.engine;
    engine.run_until(config.duration_secs * SECOND);
    let mut total_filtered = 0u64;
    for &tid in &cluster.tasks {
        if let Some(task) = engine.actor_as::<OperatorTask>(tid) {
            if let Some(filter) = task.op_as::<FilterOp>(0) {
                total_filtered += filter.total;
            }
        }
    }
    println!("sim plane: filter mappers processed {total_filtered} tuples\n");

    // 3. Same pipeline on the REAL plane (if artifacts are built): the
    //    filter executes the Pallas kernel through PJRT and finds the
    //    planted needles.
    match ComputeEngine::xla_from_default_dir() {
        Ok(compute) => {
            let mut config = ExperimentConfig {
                name: "tour-real".into(),
                data_plane: DataPlane::Real,
                duration_secs: 6,
                warmup_secs: 1,
                producer_chunk: 4 * 1024,
                ..config
            };
            config.np = 1;
            config.nc = 1;
            config.ns = 2;
            config.nmap = 2;
            let summary = launch(&config, Some(compute)).run();
            println!(
                "real plane: planted {} needles, kernel matched {} ({}% plant rate configured)",
                summary.planted,
                summary.matches,
                zettastream::cluster::PLANT_PERMILLE as f64 / 10.0
            );
            // Consumers may lag producers at the horizon: matches must
            // track the *consumed* fraction of plants.
            let consumed_frac = summary.records_consumed as f64 / summary.records_produced as f64;
            let match_frac = summary.matches as f64 / summary.planted as f64;
            assert!(
                (match_frac - consumed_frac).abs() < 0.1,
                "kernel finds the planted needles that were consumed \
                 ({match_frac:.3} vs {consumed_frac:.3})"
            );
        }
        Err(e) => println!("real plane skipped ({e:#}); run `make artifacts`"),
    }
    println!("\ntour done.");
}
