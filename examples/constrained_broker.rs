//! The paper's headline scenario (Fig. 7): a resource-constrained broker.
//!
//! ```bash
//! cargo run --release --example constrained_broker
//! ```
//!
//! Four producers ingest a replicated stream (factor two, backup broker on
//! a separate node) with eight partitions into a broker with only FOUR
//! working cores, while four consumers process it concurrently. The three
//! source strategies are compared across producer chunk sizes, with the
//! consumer chunk equal to the producer chunk — exactly the paper's §V-C
//! "constrained resources" experiment.
//!
//! Expected (and asserted): the native C++-style consumer keeps up with
//! the producers; the push-based Flink source beats the pull-based one by
//! a factor approaching 2x at small chunks.

use zettastream::cluster::launch;
use zettastream::config::{ExperimentConfig, SourceMode, Workload};

fn main() {
    println!("constrained broker (Fig. 7): NBc=4, Replication=2, Np=Nc=4, Ns=8\n");
    let mut best_ratio: f64 = 0.0;
    for cs_kib in [4usize, 8, 16, 32, 64] {
        let mut per_mode = Vec::new();
        for mode in [SourceMode::NativePull, SourceMode::Pull, SourceMode::Push] {
            let config = ExperimentConfig {
                name: format!("fig7-{}-cs{}KiB", mode.name(), cs_kib),
                np: 4,
                nc: 4,
                nmap: 8,
                ns: 8,
                producer_chunk: cs_kib * 1024,
                consumer_chunk: cs_kib * 1024,
                record_size: 100,
                replication: 2,
                broker_cores: 4,
                mode,
                workload: Workload::Filter,
                duration_secs: 20,
                warmup_secs: 3,
                ..Default::default()
            };
            let summary = launch(&config, None).run();
            println!("{}", summary.report.row());
            per_mode.push(summary);
        }
        let native = per_mode[0].report.consumers.p50;
        let pull = per_mode[1].report.consumers.p50;
        let push = per_mode[2].report.consumers.p50;
        let prod = per_mode[0].report.producers.p50;
        let ratio = push / pull;
        best_ratio = best_ratio.max(ratio);
        println!(
            "  cs={cs_kib}KiB: push/pull = {ratio:.2}x; native reaches {:.0}% of producers\n",
            native / prod * 100.0
        );
    }
    println!("max push/pull advantage observed: {best_ratio:.2}x (paper: up to 2x)");
    assert!(best_ratio > 1.5, "the constrained-broker advantage must show");
}
