//! End-to-end driver on the REAL data plane (deliverable (b)/(d) of the
//! repro): the full three-layer stack on a real small workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example colocated_wordcount
//! ```
//!
//! What happens, end to end:
//!   1. producers read the bundled text corpus in 2 KiB records and append
//!      record-framed chunks to the KerA-like broker (real bytes);
//!   2. push-based sources receive the chunks through shared-memory
//!      objects (single subscription RPC + notifications);
//!   3. the tokenizer mappers execute the **Pallas word-hash kernel
//!      through PJRT** (the AOT `wordcount_*` artifacts — Layer 1/2 on the
//!      rust hot path), keyed sums aggregate the bucketed counts;
//!   4. the run is validated against the pure-rust oracle: total tokens
//!      counted by the pipeline must equal the oracle token count of the
//!      exact bytes the producers pushed.
//!
//! The paper's Fig. 9 metric (word-count tuples/s, p50 across seconds) is
//! reported for pull and push sources. Recorded in EXPERIMENTS.md.

use zettastream::cluster::launch;
use zettastream::compute::ComputeEngine;
use zettastream::config::{DataPlane, ExperimentConfig, SourceMode, Workload};
use zettastream::wikipedia::CorpusReader;

fn main() {
    let compute = match ComputeEngine::xla_from_default_dir() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("cannot load AOT artifacts: {e:#}\nrun `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!("loaded XLA artifacts ({} variants) on {}",
             match compute.as_ref() { ComputeEngine::Xla { lib, .. } => lib.count(), _ => 0 },
             match compute.as_ref() { ComputeEngine::Xla { lib, .. } => lib.platform(), _ => String::new() });

    // Per-producer budget: 6k records x 2 KiB = ~12 MiB each, 2 producers.
    let corpus_records = 6_000u64;
    let np = 2;
    let base = ExperimentConfig {
        name: "colocated-wordcount".into(),
        np,
        nc: 2,
        nmap: 4,
        ns: 4,
        producer_chunk: 32 * 1024,
        consumer_chunk: 128 * 1024,
        record_size: 2048,
        replication: 1,
        broker_cores: 8,
        workload: Workload::WordCount,
        data_plane: DataPlane::Real,
        corpus_records,
        // the bounded corpus drains in ~2 virtual seconds; measure the
        // whole (short) run with no warmup exclusion
        duration_secs: 4,
        warmup_secs: 0,
        ..Default::default()
    };

    // Oracle: token count of the exact byte stream each producer pushes.
    let mut oracle_tokens = 0u64;
    for _ in 0..np {
        let mut reader = CorpusReader::new(2048, corpus_records);
        let mut buf = vec![0u8; 2048];
        while reader.remaining() > 0 {
            reader.fill_records(&mut buf);
            oracle_tokens += CorpusReader::count_tokens(&buf);
        }
    }
    println!("oracle: {oracle_tokens} tokens in {} records of corpus text\n", np as u64 * corpus_records);

    for mode in [SourceMode::Pull, SourceMode::Push] {
        let mut config = base.clone();
        config.mode = mode;
        config.name = format!("wordcount-{}", mode.name());
        let compute = ComputeEngine::xla_from_default_dir().expect("artifacts present");
        let summary = launch(&config, Some(compute.clone())).run();
        println!("{}", summary.report.row());
        println!(
            "  word tuples: {:.2} M/s averaged over the drain ({} total)",
            summary.report.consumers.mean / 1e6,
            summary.tuples_logged
        );
        let stats = compute.stats();
        println!(
            "  kernels: {} wordcount calls over {} records, {:.1} ms host compute",
            stats.wordcount_calls,
            stats.records_processed,
            stats.wall_ns as f64 / 1e6
        );
        println!(
            "  consumed {} records ({} produced)",
            summary.records_consumed, summary.records_produced
        );
        assert_eq!(
            summary.records_produced,
            np as u64 * corpus_records,
            "producers must push the whole corpus budget"
        );
        assert_eq!(
            summary.records_consumed, summary.records_produced,
            "sources must drain every record"
        );
        // ConsumerTuples on the word-count pipeline counts tokens at the
        // keyed sums: it must equal the oracle EXACTLY — every byte flowed
        // broker -> source -> Pallas kernel (PJRT) -> keyed state.
        assert_eq!(
            summary.tuples_logged, oracle_tokens,
            "pipeline token count must match the oracle bit-exactly"
        );
        println!(
            "  validation: pipeline counted {} tokens == oracle ✓\n",
            summary.tuples_logged
        );
    }
    println!("done — see EXPERIMENTS.md §Fig.9 for the recorded run.");
}
