//! Mode selection purely through `SourceMode` — user code never names a
//! concrete source type. The launcher resolves the mode against the
//! `SourceRegistry`, and the uniform `SourceStats` in the run summary
//! replaces every per-type getter.
//!
//! ```bash
//! cargo run --release --example hybrid_source
//! ```

use zettastream::cluster::launch;
use zettastream::config::{ExperimentConfig, SourceMode, Workload};
use zettastream::source::{SourceRegistry, StatKey};

fn main() {
    println!(
        "registered source modes: {:?}\n",
        SourceRegistry::builtin().modes().iter().map(|m| m.name()).collect::<Vec<_>>()
    );

    // A write-heavy count run on a constrained broker — the regime where
    // the paper shows pull RPCs starving behind appends (Fig. 7).
    for mode in [SourceMode::Pull, SourceMode::Push, SourceMode::Hybrid] {
        let config = ExperimentConfig {
            name: format!("demo-{}", mode.name()),
            mode,
            np: 8,
            nc: 2,
            ns: 8,
            nmap: 4,
            broker_cores: 4,
            workload: Workload::Count,
            duration_secs: 12,
            warmup_secs: 2,
            // Make the hybrid switch decisive within a short demo run.
            hybrid_window_polls: 8,
            hybrid_latency_us: 50,
            hybrid_cooldown_ms: 100,
            ..Default::default()
        };
        let summary = launch(&config, None).run();
        let s = &summary.sources;
        println!(
            "{:>6}: {:>9} records consumed | {:>6} pull RPCs ({} empty) | \
             {:>4} objects | threads {} | switches {}→push {}→pull",
            mode.name(),
            s.records_consumed,
            s.pulls_issued,
            s.empty_pulls,
            s.extra(StatKey::ObjectsConsumed),
            s.threads,
            s.extra(StatKey::SwitchesToPush),
            s.extra(StatKey::SwitchesToPull),
        );
    }
    println!("\nno concrete source type was named — only SourceMode.");
}
