//! Quickstart: pull vs push on one small colocated cluster (sim plane).
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Builds the same experiment twice — once with the state-of-the-art
//! pull-based source, once with the paper's push-based source — and prints
//! the p50 per-second throughput each strategy achieves plus the source
//! resource footprint. This is the 60-second version of the whole paper.

use zettastream::cluster::launch;
use zettastream::config::{ExperimentConfig, SourceMode, Workload};

fn main() {
    // Table I, small: 4 producers, 4 consumers, 8 partitions, a
    // resource-constrained broker of 4 cores, replicated stream.
    let mut config = ExperimentConfig {
        name: "quickstart".into(),
        np: 4,
        nc: 4,
        nmap: 8,
        ns: 8,
        producer_chunk: 8 * 1024,
        consumer_chunk: 8 * 1024, // the Fig. 7 regime: consumer CS == producer CS
        record_size: 100,
        replication: 2,
        broker_cores: 4,
        workload: Workload::Filter,
        duration_secs: 20,
        warmup_secs: 3,
        ..Default::default()
    };

    println!("zettastream quickstart — pull vs push streaming sources\n");
    let mut rows = Vec::new();
    for mode in [SourceMode::Pull, SourceMode::Push, SourceMode::NativePull] {
        config.mode = mode;
        config.name = format!("quickstart-{}", mode.name());
        let summary = launch(&config, None).run();
        println!("{}", summary.report.row());
        rows.push((mode, summary));
    }

    let pull = rows[0].1.report.consumers.p50;
    let push = rows[1].1.report.consumers.p50;
    let native = rows[2].1.report.consumers.p50;
    println!("\nconsumer throughput: pull {:.2} M/s | push {:.2} M/s | native {:.2} M/s",
             pull / 1e6, push / 1e6, native / 1e6);
    println!("push/pull speedup: {:.2}x (paper: up to 2x when storage is constrained)",
             push / pull);
    println!(
        "source threads: pull {} vs push {} (paper Fig. 4: 'two threads versus eight')",
        rows[0].1.report.gauge("source_threads").unwrap_or(0.0),
        rows[1].1.report.gauge("source_threads").unwrap_or(0.0),
    );
    println!("\nnext: `cargo bench` regenerates every figure; see EXPERIMENTS.md.");
}
