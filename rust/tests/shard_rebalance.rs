//! Multi-broker golden parity: sharding and live rebalancing must be
//! invisible in the totals.
//!
//! Three invariants guard the scale-out subsystem:
//!
//! 1. **Sharding only spreads the log.** On a fixed seed with bounded
//!    generators, every source mode × write mode cell reports the same
//!    closed-form totals (`Np × corpus_records`) at `broker_count = 3`
//!    with per-shard replica sets (`rf = 2`) **and a forced mid-run
//!    rebalance** as the single-broker run on the same seed — zero loss,
//!    zero duplication across the hand-off.
//! 2. **The hand-off actually happens.** The rebalance cells report the
//!    `shard.*` gauges: one rebalance, a positive primary-move count, a
//!    bounded hand-off time.
//! 3. **A laggard reader survives the hand-off.** A pull consumer
//!    throttled far behind the producers still holds a backlog on the old
//!    primary when the freeze→promote→publish sequence runs; its next
//!    pulls are refused with `WrongShard`, it refreshes the table and
//!    drains the full corpus from the new primary.
//!
//! Producers are throttled (`cost.producer_record_ns`) so the corpus is
//! still being written when the rebalance fires at virtual second 1 —
//! without it the sim drains the bounded corpus in virtual milliseconds
//! and the hand-off would freeze an idle partition.

use zettastream::cluster::launch;
use zettastream::config::{DataPlane, ExperimentConfig, SourceMode, Workload, WriteMode};

const NP: u64 = 2;
const CORPUS: u64 = 2_000;

/// One sharded cell: bc=3, rf=2, rebalance mid-production. The producer
/// throttle stretches the 2 000-record corpus over ~2 virtual seconds so
/// the rebalance at t=1 s lands on live traffic.
fn sharded_config(mode: SourceMode, write: WriteMode) -> ExperimentConfig {
    let mut c = ExperimentConfig {
        name: format!("shard-{}-{}", mode.name(), write.name()),
        np: NP as usize,
        nc: 3,
        nmap: 4,
        ns: 6,
        producer_chunk: 4 * 1024,
        consumer_chunk: 16 * 1024,
        record_size: 100,
        broker_cores: 8,
        mode,
        write_mode: write,
        workload: Workload::Count,
        data_plane: DataPlane::Sim,
        corpus_records: CORPUS,
        duration_secs: 12,
        warmup_secs: 1,
        seed: 0xC0FFEE,
        broker_count: 3,
        replication_factor: 2,
        rebalance_at_secs: 1,
        ..Default::default()
    };
    c.cost.producer_record_ns = 1_000_000; // 1 ms/record: ~2 s of production
    c
}

/// The same cell on one broker: same seed, same generators, same totals.
fn single_broker_config(mode: SourceMode, write: WriteMode) -> ExperimentConfig {
    let mut c = sharded_config(mode, write);
    c.name = format!("shard-base-{}-{}", mode.name(), write.name());
    c.broker_count = 1;
    c.replication_factor = 1;
    c.rebalance_at_secs = 0;
    c
}

#[test]
fn golden_totals_survive_sharding_and_a_live_rebalance() {
    let expect = NP * CORPUS;
    for &mode in &SourceMode::ALL {
        for &write in &WriteMode::ALL {
            let sharded = launch(&sharded_config(mode, write), None).run();
            assert_eq!(
                sharded.records_produced,
                expect,
                "{}/{} bc3: bounded corpus fully produced",
                mode.name(),
                write.name()
            );
            assert_eq!(
                sharded.records_consumed,
                expect,
                "{}/{} bc3: consumed == produced across the hand-off \
                 (exactly once, fully drained)",
                mode.name(),
                write.name()
            );
            assert_eq!(
                sharded.tuples_logged,
                expect,
                "{}/{} bc3: every record logged exactly once",
                mode.name(),
                write.name()
            );
            assert_eq!(
                sharded.report.gauge("shard.rebalances"),
                Some(1.0),
                "{}/{}: the forced rebalance ran",
                mode.name(),
                write.name()
            );

            let single = launch(&single_broker_config(mode, write), None).run();
            assert_eq!(
                (single.records_produced, single.records_consumed, single.tuples_logged),
                (sharded.records_produced, sharded.records_consumed, sharded.tuples_logged),
                "{}/{}: bc=1 and bc=3+rebalance must agree on every total",
                mode.name(),
                write.name()
            );
        }
    }
}

#[test]
fn rebalance_reports_the_handoff_gauges() {
    let summary = launch(&sharded_config(SourceMode::Pull, WriteMode::SyncRpc), None).run();
    assert_eq!(summary.report.gauge("shard.brokers"), Some(3.0));
    assert_eq!(summary.report.gauge("shard.rebalances"), Some(1.0));
    assert!(
        summary.report.gauge("shard.partitions_moved").unwrap_or(0.0) > 0.0,
        "the rebalance moved at least one primary"
    );
    assert!(
        summary.report.gauge("shard.handoff_ms").is_some(),
        "hand-off time reported"
    );
    // The single-broker topology exports none of this.
    let single =
        launch(&single_broker_config(SourceMode::Pull, WriteMode::SyncRpc), None).run();
    assert!(single.report.gauge("shard.rebalances").is_none());
}

#[test]
fn laggard_pull_reader_crosses_the_handoff_without_loss() {
    // Fast producers, slow consumers: the whole corpus is on the brokers
    // long before the readers catch up, so the rebalance freezes
    // partitions the laggards still need history from. Their post-publish
    // pulls hit WrongShard on the old primary, refresh, and resume on the
    // promoted backup — the drain must still be exact.
    let mut c = sharded_config(SourceMode::Pull, WriteMode::SyncRpc);
    c.name = "shard-laggard-pull".into();
    c.cost.producer_record_ns = 0; // corpus lands in virtual milliseconds
    c.cost.engine_record_ns = 1_000_000; // 1 ms/record consume: ~1.3 s behind
    let summary = launch(&c, None).run();
    let expect = NP * CORPUS;
    assert_eq!(summary.records_produced, expect, "bounded corpus fully produced");
    assert_eq!(
        summary.records_consumed, expect,
        "the laggard drained the full corpus across the hand-off"
    );
    assert_eq!(summary.tuples_logged, expect);
    assert_eq!(summary.report.gauge("shard.rebalances"), Some(1.0));
    assert!(summary.pull_rpcs > 0, "the reader kept pulling after the move");
}
