//! Tracing-plane integration tests: per-stage histograms across every
//! source mode, the deterministic JSONL replay contract, and the obs
//! gauges the launcher exports into the experiment report.
//!
//! The replay contract is the load-bearing one: the sink buffers events
//! in DES order and every field is virtual time or a logical index, so
//! two runs of the same config and seed must produce byte-identical
//! JSONL. Any nondeterminism that creeps into the spine (hash-order
//! iteration, wall-clock leakage) breaks this before it breaks a figure.

use zettastream::cluster::launch;
use zettastream::config::{ExperimentConfig, FaultKind, SourceMode, Workload, WriteMode};
use zettastream::obs::Stage;

/// Bounded sim-plane config with the tracer sampling every record.
fn traced_config(mode: SourceMode, tag: &str) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("obs-{tag}-{}", mode.name()),
        np: 2,
        nc: 2,
        nmap: 4,
        ns: 4,
        producer_chunk: 4 * 1024,
        consumer_chunk: 16 * 1024,
        record_size: 100,
        broker_cores: 8,
        mode,
        workload: Workload::Count,
        corpus_records: 2_000, // per producer; drains long before the horizon
        duration_secs: 10,
        warmup_secs: 1,
        seed: 0xC0FFEE,
        trace_sample_permille: 1000,
        ..Default::default()
    }
}

fn sink_path(tag: &str) -> std::path::PathBuf {
    // Unique per test process so parallel `cargo test` invocations never
    // collide; the two same-seed runs inside one test use distinct tags.
    std::env::temp_dir().join(format!("zs_trace_{}_{tag}.jsonl", std::process::id()))
}

#[test]
fn stage_histograms_populate_for_every_source_mode() {
    for &mode in &SourceMode::ALL {
        let summary = launch(&traced_config(mode, "stages"), None).run();
        let lat = &summary.latency;
        assert!(
            lat.spans_completed > 0,
            "{}: sampled spans completed end to end",
            mode.name()
        );
        for stage in [Stage::Append, Stage::Deliver, Stage::Consume, Stage::Operate, Stage::EndToEnd]
        {
            let st = lat.stage(stage).unwrap_or_else(|| {
                panic!("{}: stage {} recorded no samples", mode.name(), stage.name())
            });
            assert!(st.count > 0, "{}: {} count", mode.name(), stage.name());
            assert!(
                st.p50_ns <= st.p99_ns && st.p99_ns <= st.p999_ns,
                "{}: {} percentiles ordered",
                mode.name(),
                stage.name()
            );
        }
        // End-to-end contains the append hop, so its tail cannot sit
        // below the append median (loose on purpose: the two stats rank
        // over slightly different sample sets).
        let e2e = lat.stage(Stage::EndToEnd).expect("checked above");
        let append = lat.stage(Stage::Append).expect("checked above");
        assert!(
            e2e.p99_ns >= append.p50_ns,
            "{}: e2e p99 {} >= append p50 {}",
            mode.name(),
            e2e.p99_ns,
            append.p50_ns
        );
    }
}

#[test]
fn jsonl_sink_replays_byte_identical_on_a_fixed_seed() {
    let path_a = sink_path("replay_a");
    let path_b = sink_path("replay_b");
    let mut run = |path: &std::path::Path| {
        let mut config = traced_config(SourceMode::Pull, "replay");
        config.trace_out = path.to_string_lossy().into_owned();
        launch(&config, None).run()
    };
    let a = run(&path_a);
    let b = run(&path_b);
    assert_eq!(a.latency.spans_completed, b.latency.spans_completed);
    let body_a = std::fs::read_to_string(&path_a).expect("sink A written");
    let body_b = std::fs::read_to_string(&path_b).expect("sink B written");
    let _ = std::fs::remove_file(&path_a);
    let _ = std::fs::remove_file(&path_b);
    assert!(!body_a.is_empty(), "the sink captured events");
    assert!(body_a.contains("\"type\":\"span\""), "span lines present");
    assert_eq!(body_a, body_b, "same seed, same config: byte-identical JSONL");
    // Every line is one well-formed-enough object: starts '{', ends '}'.
    for line in body_a.lines() {
        assert!(line.starts_with('{') && line.ends_with('}'), "JSONL shape: {line}");
    }
}

#[test]
fn checkpoint_and_fault_events_land_in_the_sink() {
    let path = sink_path("fault");
    let mut config = traced_config(SourceMode::Pull, "fault");
    config.write_mode = WriteMode::SyncRpc;
    config.checkpoint_interval_ms = 500;
    config.fault_at_secs = 5;
    config.fault_kind = FaultKind::Worker;
    config.trace_out = path.to_string_lossy().into_owned();
    let summary = launch(&config, None).run();
    let body = std::fs::read_to_string(&path).expect("sink written");
    let _ = std::fs::remove_file(&path);
    assert!(body.contains("\"type\":\"epoch\""), "completed epochs recorded");
    assert!(body.contains("\"type\":\"fault\""), "the injected fault recorded");
    assert!(body.contains("\"type\":\"restore\""), "the recovery recorded");
    // Exactly-once survives with tracing on: the bounded corpus still
    // drains to its closed-form total across the rollback.
    assert_eq!(summary.records_consumed, 2 * 2_000, "exactly-once under tracing");
}

#[test]
fn obs_gauges_export_into_the_experiment_report() {
    let summary = launch(&traced_config(SourceMode::Pull, "gauges"), None).run();
    let spans = summary.report.gauge("obs.spans_completed").expect("spans gauge");
    assert!(spans > 0.0, "spans_completed gauge populated");
    assert!(
        summary.report.gauge("obs.end_to_end_p50_us").expect("e2e gauge") > 0.0,
        "end-to-end p50 gauge populated"
    );
    assert!(
        summary.report.gauge("obs.append_latency_us_mean").is_some(),
        "append RTT series exported"
    );
    // Tracing off: no obs gauges at all (the zero-overhead contract's
    // reporting half; the totals half lives in zero_copy_parity).
    let mut config = traced_config(SourceMode::Pull, "gauges-off");
    config.trace_sample_permille = 0;
    let summary = launch(&config, None).run();
    assert!(
        summary.report.gauge("obs.spans_completed").is_none(),
        "tracer off exports nothing"
    );
    assert_eq!(summary.latency.spans_completed, 0);
    assert!(summary.latency.stages.is_empty());
}
