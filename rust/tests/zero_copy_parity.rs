//! Zero-copy & parity regression suite for the data-spine refactor.
//!
//! Two invariants guard the perf work:
//!
//! 1. **Zero payload copies.** Real payload buffers are materialised
//!    exactly once, by the producer's generator (`Chunk::real` is the only
//!    birthplace and counts materialisations per thread). Everything
//!    downstream — broker log append, segment-resident pull replies,
//!    plasma object fills, the push consume hand-off, every operator hop —
//!    shares the `Rc`d buffer. The cluster-level tests pin the counter to
//!    the number of chunks the broker appended; the unit-level tests pin
//!    pointer identity (`Rc::ptr_eq`) across each hand-off.
//!
//! 2. **Golden totals parity.** On a fixed seed with bounded generators,
//!    every source mode × write mode combination reports byte-identical
//!    record totals (and windowed totals, where a pipeline exists) — the
//!    closed-form `Np × corpus_records`. Any refactor that drops, clones
//!    or duplicates a batch breaks this before it breaks a figure.

use std::rc::Rc;

use zettastream::broker::PartitionLog;
use zettastream::cluster::launch;
use zettastream::compute::ComputeEngine;
use zettastream::config::{DataPlane, ExperimentConfig, SourceMode, Workload, WriteMode};
use zettastream::plasma::ObjectStore;
use zettastream::proto::{
    real_payload_allocs, Batch, Chunk, ChunkList, PartitionId, StampedChunk,
};

// ---------------------------------------------------------------------------
// Unit-level pointer identity across every hand-off
// ---------------------------------------------------------------------------

fn real_chunk(records: u32, rec_size: u32) -> Chunk {
    Chunk::real(records, rec_size, Rc::new(vec![7u8; (records * rec_size) as usize]))
}

#[test]
fn log_read_shares_segment_resident_payloads() {
    let mut log = PartitionLog::new(PartitionId(0), 1 << 20);
    let chunk = real_chunk(4, 100);
    let buffer = chunk.payload.buffer().expect("real").clone();
    log.append(chunk);
    let got = log.read_from(0, 1 << 20).unwrap();
    assert_eq!(got.len(), 1);
    let read_buf = got[0].chunk.payload.buffer().expect("real");
    assert!(Rc::ptr_eq(&buffer, read_buf), "pull replies share the resident buffer");
    // Two readers at once: still the same buffer, refcount only.
    let again = log.read_from(0, 1 << 20).unwrap();
    assert!(Rc::ptr_eq(&buffer, again[0].chunk.payload.buffer().unwrap()));
}

#[test]
fn plasma_fill_and_read_share_payloads() {
    let store = ObjectStore::shared();
    let sub = store.borrow_mut().create_subscription(
        zettastream::sim::ActorId(0),
        vec![(PartitionId(0), 0)],
        2,
        1 << 20,
    );
    let chunk = real_chunk(4, 100);
    let buffer = chunk.payload.buffer().expect("real").clone();
    let object = store.borrow_mut().acquire(sub).expect("free pool");
    store
        .borrow_mut()
        .seal(object, vec![StampedChunk { partition: PartitionId(0), offset: 0, chunk }]);
    let store_ref = store.borrow();
    let read = store_ref.read(object);
    assert!(
        Rc::ptr_eq(&buffer, read[0].chunk.payload.buffer().unwrap()),
        "the sealed object shares the producer's buffer"
    );
}

#[test]
fn batch_clone_at_an_operator_hop_shares_chunks() {
    let chunk = real_chunk(4, 100);
    let buffer = chunk.payload.buffer().expect("real").clone();
    let batch = Batch {
        from_task: 0,
        tuples: 4,
        chunks: ChunkList::One(chunk),
        hist: None,
        inc: 0,
    };
    // The chained-operator passthrough clone: payload stays shared.
    let clone = batch.clone();
    assert!(Rc::ptr_eq(&buffer, clone.chunks[0].payload.buffer().unwrap()));
    // Multi-chunk batches share one Rc'd slice: cloning bumps a refcount.
    let many: ChunkList = vec![real_chunk(1, 8), real_chunk(1, 8)].into();
    let ChunkList::Shared(rc) = &many else { panic!("two chunks share a slice") };
    let rc = rc.clone();
    let c2 = many.clone();
    let ChunkList::Shared(rc2) = &c2 else { panic!("clone keeps the representation") };
    assert!(Rc::ptr_eq(&rc, rc2));
    assert_eq!(many.len(), 2);
}

// ---------------------------------------------------------------------------
// Cluster-level: the materialisation counter over a real-plane run
// ---------------------------------------------------------------------------

/// A tiny bounded real-data-plane run: Wikipedia word count (the bounded
/// corpus generator), native kernels, `mode` sources.
fn real_config(mode: SourceMode) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("zerocopy-{}", mode.name()),
        np: 2,
        nc: 2,
        nmap: 2,
        ns: 2,
        producer_chunk: 8 * 1024,
        consumer_chunk: 32 * 1024,
        record_size: 2048,
        broker_cores: 4,
        mode,
        workload: Workload::WordCount,
        data_plane: DataPlane::Real,
        corpus_records: 64, // per producer — exhausts long before the horizon
        duration_secs: 10,
        warmup_secs: 1,
        seed: 42,
        ..Default::default()
    }
}

/// Run a real-plane cluster and assert the zero-copy invariant: payload
/// materialisations == chunks appended to the broker logs — the consume
/// side (pull replies / push objects / operator hops) adds ZERO.
fn assert_zero_copy(mode: SourceMode) {
    let config = real_config(mode);
    let before = real_payload_allocs();
    let mut cluster = launch(&config, Some(ComputeEngine::native()));
    cluster.engine.run_until(config.duration_secs * zettastream::sim::SECOND);
    let appended: u64 = {
        let broker = cluster
            .engine
            .actor_as::<zettastream::broker::Broker>(cluster.broker)
            .expect("broker actor");
        (0..config.ns)
            .map(|p| broker.partition(PartitionId(p)).expect("hosted").head())
            .sum()
    };
    let materialised = real_payload_allocs() - before;
    let summary = cluster.finish();
    // The bounded corpus drained completely: every generated chunk landed.
    assert_eq!(
        summary.records_produced,
        config.np as u64 * config.corpus_records,
        "{mode:?}: bounded corpus fully produced"
    );
    assert_eq!(
        summary.records_consumed, summary.records_produced,
        "{mode:?}: fully drained by the horizon"
    );
    assert!(appended > 0);
    assert_eq!(
        materialised, appended,
        "{mode:?}: consume path materialised payloads (allocs {materialised} vs \
         appended chunks {appended}) — a copy crept into the zero-copy spine"
    );
}

#[test]
fn push_consume_handoff_copies_no_payloads() {
    assert_zero_copy(SourceMode::Push);
}

#[test]
fn pull_reply_and_operator_hops_copy_no_payloads() {
    assert_zero_copy(SourceMode::Pull);
}

// ---------------------------------------------------------------------------
// Golden totals parity across the whole source × write design space
// ---------------------------------------------------------------------------

/// Bounded sim-plane config: identical generator budget for every cell.
fn parity_config(mode: SourceMode, write: WriteMode, workload: Workload) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("parity-{}-{}", mode.name(), write.name()),
        np: 2,
        nc: 2,
        nmap: 4,
        ns: 4,
        producer_chunk: 4 * 1024,
        consumer_chunk: 16 * 1024,
        record_size: 100,
        broker_cores: 8,
        mode,
        write_mode: write,
        workload,
        data_plane: DataPlane::Sim,
        corpus_records: 2_000, // per producer; drains long before the horizon
        duration_secs: 10,
        warmup_secs: 1,
        seed: 0xC0FFEE,
        ..Default::default()
    }
}

#[test]
fn record_totals_identical_across_all_source_and_write_modes() {
    let expect = 2 * 2_000u64; // Np × corpus_records
    for &mode in &SourceMode::ALL {
        for &write in &WriteMode::ALL {
            let config = parity_config(mode, write, Workload::Count);
            let summary = launch(&config, None).run();
            assert_eq!(
                summary.records_produced, expect,
                "{}/{}: produced",
                mode.name(),
                write.name()
            );
            assert_eq!(
                summary.records_consumed, expect,
                "{}/{}: consumed == produced (exactly once, fully drained)",
                mode.name(),
                write.name()
            );
            assert_eq!(
                summary.tuples_logged, expect,
                "{}/{}: every record logged exactly once",
                mode.name(),
                write.name()
            );
        }
    }
}

#[test]
fn windowed_totals_identical_across_pipeline_modes_and_writers() {
    // Native has no pipeline (no windowed operator); the three pipeline
    // source modes must agree bit-for-bit on the windowed aggregation.
    let mut golden: Option<(u64, u64)> = None;
    for &mode in &[SourceMode::Pull, SourceMode::Push, SourceMode::Hybrid] {
        for &write in &WriteMode::ALL {
            let config = parity_config(mode, write, Workload::WindowedWordCount);
            let summary = launch(&config, None).run();
            let got = (summary.records_consumed, summary.windowed_tuples);
            assert_eq!(
                summary.records_produced,
                2 * 2_000,
                "{}/{}: produced",
                mode.name(),
                write.name()
            );
            assert!(summary.windowed_tuples > 0, "windowed pipeline aggregated");
            match &golden {
                None => golden = Some(got),
                Some(g) => assert_eq!(
                    *g,
                    got,
                    "{}/{}: windowed totals must match every other cell",
                    mode.name(),
                    write.name()
                ),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Tracing plane: zero overhead off, zero copies and identical totals on
// ---------------------------------------------------------------------------

#[test]
fn tracing_at_full_rate_changes_no_totals_and_copies_no_payloads() {
    // The tracing plane observes the spine, it must never touch it: a run
    // with every record sampled reports byte-identical totals and the
    // exact same payload-materialisation count as the untraced run.
    for &mode in &[SourceMode::Pull, SourceMode::Push] {
        let config_off = real_config(mode);
        let before = real_payload_allocs();
        let summary_off = launch(&config_off, Some(ComputeEngine::native())).run();
        let allocs_off = real_payload_allocs() - before;

        let mut config_on = real_config(mode);
        config_on.trace_sample_permille = 1000;
        let before = real_payload_allocs();
        let summary_on = launch(&config_on, Some(ComputeEngine::native())).run();
        let allocs_on = real_payload_allocs() - before;

        assert_eq!(
            summary_off.records_consumed, summary_on.records_consumed,
            "{mode:?}: tracing changed the consumed total"
        );
        assert_eq!(
            summary_off.tuples_logged, summary_on.tuples_logged,
            "{mode:?}: tracing changed the logged total"
        );
        assert_eq!(
            allocs_off, allocs_on,
            "{mode:?}: tracing materialised payloads ({allocs_on} vs {allocs_off})"
        );
        assert!(
            summary_on.latency.spans_completed > 0,
            "{mode:?}: the traced run completed spans"
        );
        assert!(
            summary_off.latency.spans_completed == 0 && summary_off.latency.stages.is_empty(),
            "{mode:?}: the untraced run recorded nothing"
        );
    }
}

#[test]
fn traced_golden_totals_identical_across_all_source_and_write_modes() {
    // The permille=1000 rerun of the golden-totals sweep: the marker FIFOs
    // and span bookkeeping must not drop, clone or reorder a single batch
    // in any (source × write) cell.
    let expect = 2 * 2_000u64; // Np × corpus_records
    for &mode in &SourceMode::ALL {
        for &write in &WriteMode::ALL {
            let mut config = parity_config(mode, write, Workload::Count);
            config.name = format!("parity-traced-{}-{}", mode.name(), write.name());
            config.trace_sample_permille = 1000;
            let summary = launch(&config, None).run();
            assert_eq!(
                summary.records_produced, expect,
                "{}/{} traced: produced",
                mode.name(),
                write.name()
            );
            assert_eq!(
                summary.records_consumed, expect,
                "{}/{} traced: consumed == produced",
                mode.name(),
                write.name()
            );
            assert_eq!(
                summary.tuples_logged, expect,
                "{}/{} traced: every record logged exactly once",
                mode.name(),
                write.name()
            );
            assert!(
                summary.latency.spans_completed > 0,
                "{}/{} traced: spans completed",
                mode.name(),
                write.name()
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Plant-ratio parity (real plane, synthetic generator)
// ---------------------------------------------------------------------------

#[test]
fn plant_ratio_tracks_the_permille_for_every_write_mode() {
    // The synthetic generator plants the filter needle at PLANT_PERMILLE.
    // Identical seed → identical per-record plant decisions for every
    // writer (producer/tests pins the stream-level identity); here the
    // cluster-level ratio must track the permille for each write mode —
    // the volumes differ (pipelined outruns sync), the ratio must not.
    let mut ratios = Vec::new();
    for &write in &WriteMode::ALL {
        let config = ExperimentConfig {
            name: format!("plant-{}", write.name()),
            np: 2,
            nc: 2,
            ns: 2,
            nmap: 2,
            producer_chunk: 2 * 1024,
            consumer_chunk: 8 * 1024,
            record_size: 100,
            broker_cores: 4,
            mode: SourceMode::Pull,
            write_mode: write,
            workload: Workload::Count,
            data_plane: DataPlane::Real,
            duration_secs: 2,
            warmup_secs: 0,
            seed: 7,
            ..Default::default()
        };
        let summary = launch(&config, Some(ComputeEngine::native())).run();
        assert!(summary.records_produced > 1_000, "{}: enough volume", write.name());
        let ratio = summary.planted as f64 / summary.records_produced as f64;
        let expect = zettastream::cluster::PLANT_PERMILLE as f64 / 1000.0;
        assert!(
            (ratio - expect).abs() < expect * 0.5,
            "{}: plant ratio {ratio:.4} tracks the permille {expect:.4}",
            write.name()
        );
        ratios.push(ratio);
    }
    // The write modes sample the same plant distribution: their ratios
    // agree with each other far more tightly than with chance.
    for r in &ratios {
        assert!(
            (r - ratios[0]).abs() < 0.02,
            "plant ratios consistent across write modes: {ratios:?}"
        );
    }
}
