//! Spawned-binary contract harness for `zettastream broker --listen`.
//!
//! Spawns the real binary, drives it over a raw `TcpStream` with frames
//! built by the library's own codec (`encode_frame` + `encode_msg`), and
//! asserts on both the wire responses and the server's structured JSONL
//! output. This is the closest thing to a foreign client the repo has: if
//! a codec or dispatch change breaks the wire contract, it breaks here —
//! in a different process from the broker.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::process::{Child, Command, Stdio};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use zettastream::proto::{
    Chunk, ObjectId, PartitionId, PushSourceSpec, RpcKind, RpcReply, WriteProducerSpec,
};
use zettastream::sim::ActorId;
use zettastream::transport::{
    frame::encode_frame,
    wire::{decode_msg, encode_msg},
    FrameDecoder, WireMsg, WIRE_VERSION,
};

/// Kill the child on panic/early return so a failed assertion never leaks
/// a listening broker process into the test runner.
struct KillGuard(Option<Child>);

impl KillGuard {
    fn child(&mut self) -> &mut Child {
        self.0.as_mut().expect("child still owned")
    }
    /// Hand the child back for a clean `wait` at the end of the test.
    fn disarm(&mut self) -> Child {
        self.0.take().expect("child still owned")
    }
}

impl Drop for KillGuard {
    fn drop(&mut self) {
        if let Some(child) = &mut self.0 {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}

fn send(stream: &mut TcpStream, msg: &WireMsg) {
    let frame = encode_frame(&encode_msg(msg));
    stream.write_all(&frame).expect("write frame");
}

/// Receive the next message, polling the socket until `deadline`.
fn recv(stream: &mut TcpStream, decoder: &mut FrameDecoder, deadline: Instant) -> WireMsg {
    loop {
        if let Some(body) = decoder.next_frame().expect("well-formed frame") {
            return decode_msg(&body).expect("decodable message");
        }
        assert!(Instant::now() < deadline, "timed out waiting for a frame");
        let mut buf = [0u8; 4096];
        match stream.read(&mut buf) {
            Ok(0) => panic!("broker closed the connection mid-conversation"),
            Ok(n) => decoder.push(&buf[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(e) => panic!("socket read: {e}"),
        }
    }
}

fn expect_rep(
    stream: &mut TcpStream,
    decoder: &mut FrameDecoder,
    deadline: Instant,
) -> (u64, RpcReply) {
    match recv(stream, decoder, deadline) {
        WireMsg::Rep { wire_id, reply } => (wire_id, reply),
        other => panic!("expected a Rep frame, got {other:?}"),
    }
}

#[test]
fn broker_binary_serves_the_full_rpc_surface_over_tcp() {
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut guard = KillGuard(Some(
        Command::new(env!("CARGO_BIN_EXE_zettastream"))
            .args(["broker", "--listen", "127.0.0.1:0", "ns=4"])
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .expect("spawn zettastream broker"),
    ));

    // Collect the server's stdout lines on a thread (the ready line first,
    // JSONL afterwards).
    let lines: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
    let reader = {
        let stdout = guard.child().stdout.take().expect("piped stdout");
        let lines = lines.clone();
        std::thread::spawn(move || {
            for line in BufReader::new(stdout).lines().map_while(Result::ok) {
                lines.lock().unwrap().push(line);
            }
        })
    };

    // Wait for the flushed ready line and scan out the ephemeral address.
    let addr = loop {
        assert!(Instant::now() < deadline, "broker never printed its ready line");
        let found = lines.lock().unwrap().iter().find_map(|l| {
            l.strip_prefix("ZETTASTREAM-BROKER ready addr=").map(str::to_string)
        });
        match found {
            Some(a) => break a,
            None => std::thread::sleep(Duration::from_millis(10)),
        }
    };

    let mut stream = TcpStream::connect(&addr).expect("connect to broker");
    stream.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut decoder = FrameDecoder::new();
    let mut reps_received = 0u64;

    send(&mut stream, &WireMsg::Hello { version: WIRE_VERSION, node: 9, cookie: 0 });

    // 1: Append 10 records x 100 B to p0.
    send(
        &mut stream,
        &WireMsg::Req {
            wire_id: 1,
            from_node: 9,
            kind: RpcKind::Append {
                chunks: vec![(PartitionId(0), Chunk::sim(10, 100))],
                produced_at: None,
            },
        },
    );
    let (id, reply) = expect_rep(&mut stream, &mut decoder, deadline);
    reps_received += 1;
    assert_eq!(id, 1);
    assert!(
        matches!(reply, RpcReply::AppendAck { records: 10, .. }),
        "append ack for 10 records, got {reply:?}"
    );

    // 2: Pull p0 from offset 0 — the appended chunk comes back.
    send(
        &mut stream,
        &WireMsg::Req {
            wire_id: 2,
            from_node: 9,
            kind: RpcKind::Pull { assignments: vec![(PartitionId(0), 0)], max_bytes: 1 << 20 },
        },
    );
    let (id, reply) = expect_rep(&mut stream, &mut decoder, deadline);
    reps_received += 1;
    assert_eq!(id, 2);
    match reply {
        RpcReply::PullData { chunks, trims } => {
            assert_eq!(chunks.len(), 1, "one appended chunk to pull");
            assert_eq!(chunks[0].chunk.records, 10);
            assert!(trims.is_empty());
        }
        other => panic!("expected PullData, got {other:?}"),
    }

    // 3: WriteSubscribe — the spec's actor id is garbage on purpose; the
    // server must rewrite it to the connection link, never dereference it.
    send(
        &mut stream,
        &WireMsg::Req {
            wire_id: 3,
            from_node: 9,
            kind: RpcKind::WriteSubscribe {
                producer: WriteProducerSpec {
                    producer_actor: ActorId(999),
                    partitions: vec![PartitionId(0)],
                    objects: 2,
                    object_bytes: 1 << 20,
                },
            },
        },
    );
    let (id, reply) = expect_rep(&mut stream, &mut decoder, deadline);
    reps_received += 1;
    assert_eq!(id, 3);
    let write_sub = match reply {
        RpcReply::WriteSubscribeAck { sub } => sub,
        other => panic!("expected WriteSubscribeAck, got {other:?}"),
    };

    // 4: Seal an object nobody filled — a protocol error must come back as
    // an Error reply on this connection, not a broker panic.
    send(
        &mut stream,
        &WireMsg::Req {
            wire_id: 4,
            from_node: 9,
            kind: RpcKind::SealObject {
                id: ObjectId { sub: write_sub, slot: 0 },
                produced_at: None,
            },
        },
    );
    let (id, reply) = expect_rep(&mut stream, &mut decoder, deadline);
    reps_received += 1;
    assert_eq!(id, 4);
    assert!(
        matches!(&reply, RpcReply::Error { reason } if reason.contains("not sealed")),
        "sealing an unfilled object must fail cleanly, got {reply:?}"
    );

    // 5: PushSubscribe on p1 (again with a garbage actor id to rewrite).
    send(
        &mut stream,
        &WireMsg::Req {
            wire_id: 5,
            from_node: 9,
            kind: RpcKind::PushSubscribe {
                sources: vec![PushSourceSpec {
                    source_actor: ActorId(7),
                    assignments: vec![(PartitionId(1), 0)],
                    objects: 2,
                    object_bytes: 1 << 20,
                }],
            },
        },
    );
    let (id, reply) = expect_rep(&mut stream, &mut decoder, deadline);
    reps_received += 1;
    assert_eq!(id, 5);
    let push_sub = match reply {
        RpcReply::SubscribeAck { sub } => sub,
        other => panic!("expected SubscribeAck, got {other:?}"),
    };

    // 6: Append to p1 — the push thread gathers it into an object and the
    // ObjectReady notification must travel back to us as an Evt frame.
    send(
        &mut stream,
        &WireMsg::Req {
            wire_id: 6,
            from_node: 9,
            kind: RpcKind::Append {
                chunks: vec![(PartitionId(1), Chunk::sim(10, 100))],
                produced_at: None,
            },
        },
    );
    let (id, reply) = expect_rep(&mut stream, &mut decoder, deadline);
    reps_received += 1;
    assert_eq!(id, 6);
    assert!(matches!(reply, RpcReply::AppendAck { records: 10, .. }));
    match recv(&mut stream, &mut decoder, deadline) {
        WireMsg::Evt { event } => {
            let zettastream::transport::WireEvent::ObjectReady { sub, .. } = event;
            assert_eq!(sub, push_sub.0 as u64, "notification for our subscription");
        }
        other => panic!("expected an ObjectReady Evt frame, got {other:?}"),
    }

    // 7: PushUnsubscribe tears the subscription down.
    send(
        &mut stream,
        &WireMsg::Req {
            wire_id: 7,
            from_node: 9,
            kind: RpcKind::PushUnsubscribe { sub: push_sub },
        },
    );
    let (id, reply) = expect_rep(&mut stream, &mut decoder, deadline);
    reps_received += 1;
    assert_eq!(id, 7);
    assert!(
        matches!(reply, RpcReply::UnsubscribeAck { sub, .. } if sub == push_sub),
        "expected UnsubscribeAck for {push_sub:?}, got {reply:?}"
    );

    // Graceful shutdown: the server drains, says Bye with its reply count
    // (the no-lost-acks cross-check), and closes at a frame boundary.
    send(&mut stream, &WireMsg::Shutdown);
    match recv(&mut stream, &mut decoder, deadline) {
        WireMsg::Bye { replies_sent } => {
            assert_eq!(
                replies_sent, reps_received,
                "server reply count disagrees with what the client observed"
            );
        }
        other => panic!("expected Bye, got {other:?}"),
    }
    // EOF at a frame boundary follows the Bye.
    let mut tail = Vec::new();
    loop {
        assert!(Instant::now() < deadline, "timed out waiting for EOF");
        let mut buf = [0u8; 1024];
        match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => tail.extend_from_slice(&buf[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(_) => break, // reset after close is also an end
        }
    }
    decoder.push(&tail);
    while let Some(body) = decoder.next_frame().expect("tail frames well-formed") {
        decode_msg(&body).expect("tail frames decodable");
    }
    decoder.finish().expect("connection ended at a frame boundary");

    // The process exits cleanly and its JSONL log tells the same story.
    let mut child = guard.disarm();
    let status = child.wait().expect("broker exit status");
    assert!(status.success(), "broker exited with {status:?}");
    reader.join().expect("stdout reader");

    let lines = lines.lock().unwrap();
    let has = |needle: &str| lines.iter().any(|l| l.contains(needle));
    assert!(has("\"event\":\"accepted\""), "missing accepted event:\n{lines:#?}");
    assert!(
        has("\"kind\":\"append\"") && has("\"kind\":\"pull\"") && has("\"kind\":\"push_subscribe\""),
        "missing dispatched-request events:\n{lines:#?}"
    );
    assert!(has("\"event\":\"shutdown_requested\""), "missing shutdown_requested:\n{lines:#?}");
    let shutdown = lines
        .iter()
        .find(|l| l.contains("\"event\":\"shutdown\""))
        .unwrap_or_else(|| panic!("missing final shutdown record:\n{lines:#?}"));
    let spawned = scan_u64(shutdown, "\"threads_spawned\":");
    let joined = scan_u64(shutdown, "\"threads_joined\":");
    assert!(spawned > 0, "transport spawned no threads? {shutdown}");
    assert_eq!(spawned, joined, "broker leaked transport threads: {shutdown}");
}

/// Scan `"key": <u64>` out of a JSONL line (no JSON parser in the vendor
/// set; the server writes these fields on one line).
fn scan_u64(line: &str, key: &str) -> u64 {
    let at = line.find(key).unwrap_or_else(|| panic!("`{key}` not in {line}")) + key.len();
    let rest = &line[at..];
    let end = rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len());
    rest[..end].parse().unwrap_or_else(|_| panic!("bad number after `{key}` in {line}"))
}
