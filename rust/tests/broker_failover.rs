//! Broker fail-over golden parity: a broker death at `rf >= 2` must be
//! invisible in the totals.
//!
//! Four invariants guard the fail-over subsystem:
//!
//! 1. **A dead broker loses nothing committed.** On a fixed seed with
//!    bounded generators, every source mode × write mode cell reports the
//!    same closed-form totals (`Np × corpus_records`) at
//!    `broker_count = 3`, `rf = 2` **with a broker killed mid-run** as the
//!    same-seed fault-free run — zero loss, zero duplication across the
//!    promotion.
//! 2. **The detector actually fires.** The faulted cells report the
//!    `shard.*` fail-over gauges: one fail-over, a positive promotion
//!    count, a detection latency bounded by the lease.
//! 3. **A laggard reader survives the corpse.** A pull consumer throttled
//!    far behind the producers still holds a backlog on the dead primary
//!    when the emergency epoch publishes; its deadline-expired pulls
//!    consult the down-mask, re-route to the promoted replica and drain
//!    the full corpus.
//! 4. **An in-flight quorum append crosses the fail-over.** Pipelined
//!    writers keep a window of unacknowledged appends; the kill lands
//!    while that window spans the victim, and the retransmits must land
//!    exactly once under the promoted primary's dedup table.
//!
//! Producers are throttled (`cost.producer_record_ns`) so the corpus is
//! still being written when the broker dies at virtual second 1 — without
//! it the sim drains the bounded corpus in virtual milliseconds and the
//! kill would hit an idle broker.

use zettastream::cluster::launch;
use zettastream::config::{
    DataPlane, ExperimentConfig, FaultKind, SourceMode, Workload, WriteMode,
};

const NP: u64 = 2;
const CORPUS: u64 = 2_000;

/// One faulted cell: bc=3, rf=2, the last broker killed mid-production.
/// The topology mirrors `tests/shard_rebalance.rs` so the rebalance and
/// fail-over suites exercise the same shard layout.
fn faulted_config(mode: SourceMode, write: WriteMode) -> ExperimentConfig {
    let mut c = ExperimentConfig {
        name: format!("failover-{}-{}", mode.name(), write.name()),
        np: NP as usize,
        nc: 3,
        nmap: 4,
        ns: 6,
        producer_chunk: 4 * 1024,
        consumer_chunk: 16 * 1024,
        record_size: 100,
        broker_cores: 8,
        mode,
        write_mode: write,
        workload: Workload::Count,
        data_plane: DataPlane::Sim,
        corpus_records: CORPUS,
        duration_secs: 12,
        warmup_secs: 1,
        seed: 0xC0FFEE,
        broker_count: 3,
        replication_factor: 2,
        fault_at_secs: 1,
        fault_kind: FaultKind::Broker,
        ..Default::default()
    };
    c.cost.producer_record_ns = 1_000_000; // 1 ms/record: ~2 s of production
    c
}

/// The same cell with the kill disarmed: same seed, same topology, same
/// generators, same totals.
fn fault_free_config(mode: SourceMode, write: WriteMode) -> ExperimentConfig {
    let mut c = faulted_config(mode, write);
    c.name = format!("failover-base-{}-{}", mode.name(), write.name());
    c.fault_at_secs = 0;
    c
}

#[test]
fn golden_totals_survive_a_broker_death() {
    let expect = NP * CORPUS;
    for &mode in &SourceMode::ALL {
        for &write in &WriteMode::ALL {
            let faulted = launch(&faulted_config(mode, write), None).run();
            assert_eq!(
                faulted.records_produced,
                expect,
                "{}/{} broker-kill: bounded corpus fully produced",
                mode.name(),
                write.name()
            );
            assert_eq!(
                faulted.records_consumed,
                expect,
                "{}/{} broker-kill: consumed == produced across the promotion \
                 (exactly once, fully drained)",
                mode.name(),
                write.name()
            );
            assert_eq!(
                faulted.tuples_logged,
                expect,
                "{}/{} broker-kill: every record logged exactly once",
                mode.name(),
                write.name()
            );
            assert_eq!(
                faulted.report.gauge("shard.failovers"),
                Some(1.0),
                "{}/{}: the kill triggered exactly one fail-over",
                mode.name(),
                write.name()
            );

            let golden = launch(&fault_free_config(mode, write), None).run();
            assert_eq!(
                (golden.records_produced, golden.records_consumed, golden.tuples_logged),
                (faulted.records_produced, faulted.records_consumed, faulted.tuples_logged),
                "{}/{}: faulted and fault-free runs must agree on every total",
                mode.name(),
                write.name()
            );
        }
    }
}

#[test]
fn failover_reports_the_detection_gauges() {
    let summary = launch(&faulted_config(SourceMode::Pull, WriteMode::SyncRpc), None).run();
    assert_eq!(summary.report.gauge("shard.brokers"), Some(3.0));
    assert_eq!(summary.report.gauge("shard.failovers"), Some(1.0));
    assert!(
        summary.report.gauge("shard.promotions").unwrap_or(0.0) > 0.0,
        "the fail-over promoted at least one replica"
    );
    let detect = summary
        .report
        .gauge("shard.detection_ms")
        .expect("detection latency reported");
    // Kill → declaration is bounded by the lease plus one heartbeat of
    // probe skew (defaults: 500 ms lease, 100 ms heartbeat).
    assert!(
        detect > 0.0 && detect <= 1_000.0,
        "detection latency {detect} ms outside (0, lease + slack]"
    );
    assert!(
        summary.report.gauge("write_broker_down_retries").is_some(),
        "write-path broker-down retry gauge exported"
    );
    assert!(
        summary.report.gauge("source_broker_down_retries").is_some(),
        "read-path broker-down retry gauge exported"
    );
    // The fault-free topology reports no fail-over.
    let golden = launch(&fault_free_config(SourceMode::Pull, WriteMode::SyncRpc), None).run();
    assert_eq!(golden.report.gauge("shard.failovers"), Some(0.0));
}

#[test]
fn laggard_pull_reader_crosses_the_failover_without_loss() {
    // Fast producers, slow consumers: the whole corpus is quorum-durable
    // before the kill, but the laggard readers still need history from
    // the dead primary. Their deadline-expired pulls consult the
    // down-mask, reissue against the promoted replica (which holds the
    // full log) and the drain must still be exact.
    let mut c = faulted_config(SourceMode::Pull, WriteMode::SyncRpc);
    c.name = "failover-laggard-pull".into();
    c.cost.producer_record_ns = 0; // corpus lands in virtual milliseconds
    c.cost.engine_record_ns = 1_000_000; // 1 ms/record consume: ~1.3 s behind
    let summary = launch(&c, None).run();
    let expect = NP * CORPUS;
    assert_eq!(summary.records_produced, expect, "bounded corpus fully produced");
    assert_eq!(
        summary.records_consumed, expect,
        "the laggard drained the full corpus across the promotion"
    );
    assert_eq!(summary.tuples_logged, expect);
    assert_eq!(summary.report.gauge("shard.failovers"), Some(1.0));
    assert!(summary.pull_rpcs > 0, "the reader kept pulling after the death");
}

#[test]
fn in_flight_quorum_append_crosses_the_failover() {
    // The pipelined writer keeps a bounded window of unacked appends; at
    // 1 ms/record the kill at t=1 s lands with that window spanning the
    // victim's partitions. The deadline plane retransmits to the promoted
    // primary, whose append-idempotence table absorbs any duplicate — the
    // totals must not move.
    let summary =
        launch(&faulted_config(SourceMode::Pull, WriteMode::Pipelined), None).run();
    let expect = NP * CORPUS;
    assert_eq!(summary.records_produced, expect);
    assert_eq!(summary.records_consumed, expect);
    assert_eq!(summary.tuples_logged, expect, "no loss and no double-count from retransmits");
    assert_eq!(summary.report.gauge("shard.failovers"), Some(1.0));
    assert!(
        summary.report.gauge("write_broker_down_retries").unwrap_or(0.0) > 0.0,
        "the kill forced at least one write-path deadline retry"
    );
}
