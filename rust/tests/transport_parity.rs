//! Sim/real parity at the transport seam.
//!
//! One scripted conversation — a client Hello + a burst of requests, a
//! server reply burst + an event + a Bye — runs over both [`Transport`]
//! implementations. The ordering contract on the trait (per-connection
//! FIFO both directions, `Accepted` before any frame) means each receiver
//! must observe the *identical* message sequence on both planes; this test
//! holds that line so a transport change that reorders, drops or
//! duplicates frames fails loudly against its sibling.

use std::time::{Duration, Instant};

use zettastream::config::ExperimentConfig;
use zettastream::net::Network;
use zettastream::proto::{Chunk, PartitionId, PushSourceSpec, RpcKind, RpcReply, SubId};
use zettastream::sim::ActorId;
use zettastream::transport::{
    wire::msg_label, SimTransport, TcpTransport, Transport, TransportEvent, WireEvent, WireMsg,
    WIRE_VERSION,
};

/// What a receiver logs per observed event — the comparable trace.
#[derive(Debug, Clone, PartialEq, Eq)]
enum Seen {
    Accepted,
    Msg(&'static str),
    Closed { clean: bool },
}

/// The scripted client->server burst. Real payload shapes so the codec
/// path is exercised, not just empty envelopes.
fn client_script() -> Vec<WireMsg> {
    vec![
        WireMsg::Hello { version: WIRE_VERSION, node: 1, cookie: 42 },
        WireMsg::Req {
            wire_id: 1,
            from_node: 1,
            kind: RpcKind::Append {
                chunks: vec![(PartitionId(0), Chunk::sim(5, 64))],
                produced_at: None,
            },
        },
        WireMsg::Req {
            wire_id: 2,
            from_node: 1,
            kind: RpcKind::Pull { assignments: vec![(PartitionId(0), 0)], max_bytes: 1024 },
        },
        WireMsg::Req {
            wire_id: 3,
            from_node: 1,
            kind: RpcKind::PushSubscribe {
                sources: vec![PushSourceSpec {
                    source_actor: ActorId(3),
                    assignments: vec![(PartitionId(1), 7)],
                    objects: 2,
                    object_bytes: 4096,
                }],
            },
        },
        WireMsg::Req { wire_id: 4, from_node: 1, kind: RpcKind::PushUnsubscribe { sub: SubId(1) } },
    ]
}

/// The scripted server->client burst.
fn server_script() -> Vec<WireMsg> {
    vec![
        WireMsg::Rep { wire_id: 1, reply: RpcReply::AppendAck { records: 5, bytes: 320 } },
        WireMsg::Rep { wire_id: 2, reply: RpcReply::PullData { chunks: vec![], trims: vec![] } },
        WireMsg::Rep { wire_id: 3, reply: RpcReply::SubscribeAck { sub: SubId(1) } },
        WireMsg::Evt { event: WireEvent::ObjectReady { sub: 1, slot: 0 } },
        WireMsg::Rep {
            wire_id: 4,
            reply: RpcReply::UnsubscribeAck { sub: SubId(1), cursors: vec![(PartitionId(1), 9)] },
        },
        WireMsg::Bye { replies_sent: 4 },
    ]
}

/// Poll `t` until `n` events are observed (or the deadline passes), and
/// log them. TCP needs the deadline loop; the sim fabric delivers
/// everything on the first poll.
fn collect<T: Transport>(t: &mut T, n: usize, seen: &mut Vec<Seen>) -> Vec<usize> {
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut conns = Vec::new();
    while seen.len() < n {
        assert!(Instant::now() < deadline, "timed out at {} of {n} events: {seen:?}", seen.len());
        for ev in t.poll(20) {
            match ev {
                TransportEvent::Accepted { conn } => {
                    conns.push(conn);
                    seen.push(Seen::Accepted);
                }
                TransportEvent::Frame { msg, .. } => seen.push(Seen::Msg(msg_label(&msg))),
                TransportEvent::Closed { error, .. } => {
                    seen.push(Seen::Closed { clean: error.is_none() });
                }
            }
        }
    }
    conns
}

/// Run the script over one connected pair; returns what each side saw.
fn run_script<S: Transport, C: Transport>(
    server: &mut S,
    client: &mut C,
    client_conn: usize,
) -> (Vec<Seen>, Vec<Seen>) {
    for msg in client_script() {
        client.send(client_conn, &msg).expect("client send");
    }
    let mut server_saw = Vec::new();
    // Accepted + the 5 scripted client messages.
    let conns = collect(server, 1 + client_script().len(), &mut server_saw);
    assert_eq!(conns.len(), 1, "exactly one Accepted");
    assert_eq!(server_saw[0], Seen::Accepted, "Accepted precedes any frame");

    for msg in server_script() {
        server.send(conns[0], &msg).expect("server send");
    }
    let mut client_saw = Vec::new();
    collect(client, server_script().len(), &mut client_saw);

    // The server closes; the client observes a clean close after the last
    // frame (TCP: at a frame boundary; sim: a flagged close).
    server.close_conn(conns[0]);
    collect(client, server_script().len() + 1, &mut client_saw);
    (server_saw, client_saw)
}

#[test]
fn sim_and_tcp_transports_deliver_identical_sequences() {
    // --- sim plane -------------------------------------------------------
    let cost = ExperimentConfig::default().cost;
    let net = Network::shared(cost.network, cost.loopback);
    let (mut sim_server, mut sim_client) = SimTransport::pair(net, 0, 1);
    let conn = sim_client.connect("sim:0").expect("sim connect");
    let (sim_server_saw, sim_client_saw) = run_script(&mut sim_server, &mut sim_client, conn);

    // --- real plane ------------------------------------------------------
    let mut listener = TcpTransport::listen("127.0.0.1:0").expect("listen");
    let addr = listener.local_addr().expect("listener address");
    let mut tcp_client = TcpTransport::client();
    let conn = tcp_client.connect(&addr).expect("tcp connect");
    let (tcp_server_saw, tcp_client_saw) = run_script(&mut listener, &mut tcp_client, conn);

    // --- the parity claim ------------------------------------------------
    assert_eq!(
        sim_server_saw, tcp_server_saw,
        "server-side sequences diverged between planes"
    );
    assert_eq!(
        sim_client_saw, tcp_client_saw,
        "client-side sequences diverged between planes"
    );

    // And the sequences are the script, in script order (FIFO, no loss).
    let expect_server: Vec<Seen> = std::iter::once(Seen::Accepted)
        .chain(client_script().iter().map(|m| Seen::Msg(msg_label(m))))
        .collect();
    assert_eq!(sim_server_saw, expect_server);
    let expect_client: Vec<Seen> = server_script()
        .iter()
        .map(|m| Seen::Msg(msg_label(m)))
        .chain(std::iter::once(Seen::Closed { clean: true }))
        .collect();
    assert_eq!(sim_client_saw, expect_client);

    let report = tcp_client.shutdown();
    assert_eq!(report.spawned, report.joined, "client transport leaked threads");
    let report = listener.shutdown();
    assert_eq!(report.spawned, report.joined, "listener transport leaked threads");
}
