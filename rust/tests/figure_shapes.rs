//! Integration: the paper's qualitative results must hold (DESIGN.md §4).
//!
//! These run the sim data plane at reduced duration — the claims are about
//! *shape* (who wins, by roughly what factor, where crossovers fall), not
//! absolute numbers. Each test names the figure it guards.

use zettastream::cluster::launch;
use zettastream::config::{ExperimentConfig, SourceMode, Workload};

fn run(mutator: impl FnOnce(&mut ExperimentConfig)) -> zettastream::cluster::RunSummary {
    let mut c = ExperimentConfig { duration_secs: 12, warmup_secs: 2, ..Default::default() };
    mutator(&mut c);
    c.validate().expect("valid config");
    launch(&c, None).run()
}

/// Fig. 3: ingestion throughput grows with chunk size and producer count.
#[test]
fn fig3_chunk_size_and_producers_grow_ingest() {
    let t = |np: usize, cs: usize| {
        run(|c| {
            c.np = np;
            c.producer_chunk = cs * 1024;
            c.mode = SourceMode::NativePull;
            c.pull_timeout_us = 1_000_000; // consumers effectively idle
            c.nc = 1;
        })
        .report
        .producers
        .p50
    };
    let small2 = t(2, 1);
    let big2 = t(2, 128);
    let big8 = t(8, 128);
    assert!(big2 > small2 * 2.0, "chunk size grows ingest: {small2} -> {big2}");
    assert!(big8 > big2 * 1.5, "producers grow ingest: {big2} -> {big8}");
}

/// Fig. 3: replication visibly lowers producer throughput.
#[test]
fn fig3_replication_costs_ingest() {
    let t = |repl: usize| {
        run(|c| {
            c.np = 4;
            c.producer_chunk = 4 * 1024;
            c.replication = repl;
            c.mode = SourceMode::NativePull;
            c.nc = 1;
            c.pull_timeout_us = 1_000_000;
        })
        .report
        .producers
        .p50
    };
    let r1 = t(1);
    let r2 = t(2);
    assert!(r2 < r1 * 0.92, "replication must cost ingest: {r1} vs {r2}");
}

/// Fig. 4: push is competitive (>=) at Nc<=4 and does NOT scale to Nc=8,
/// where pull overtakes it; push uses 2 source threads vs 2*Nc.
#[test]
fn fig4_push_competitive_small_nc_pull_wins_at_8() {
    let t = |mode: SourceMode, n: usize| {
        run(|c| {
            c.mode = mode;
            c.np = n;
            c.nc = n;
            c.ns = 8;
            c.broker_cores = 16;
            c.producer_chunk = 16 * 1024;
        })
    };
    let pull4 = t(SourceMode::Pull, 4);
    let push4 = t(SourceMode::Push, 4);
    assert!(
        push4.report.consumers.p50 >= pull4.report.consumers.p50,
        "push >= pull at Nc=4: {} vs {}",
        push4.report.consumers.p50,
        pull4.report.consumers.p50
    );
    assert_eq!(push4.report.gauge("source_threads"), Some(2.0));
    assert_eq!(pull4.report.gauge("source_threads"), Some(8.0));

    let pull8 = t(SourceMode::Pull, 8);
    let push8 = t(SourceMode::Push, 8);
    assert!(
        pull8.report.consumers.p50 > push8.report.consumers.p50,
        "pull wins at Nc=8 (push does not scale): {} vs {}",
        pull8.report.consumers.p50,
        push8.report.consumers.p50
    );
    // and push@8 is not (much) better than push@4 — the non-scaling itself
    assert!(
        push8.report.consumers.p50 < push4.report.consumers.p50 * 1.35,
        "push plateaus: {} vs {}",
        push8.report.consumers.p50,
        push4.report.consumers.p50
    );
}

/// Fig. 4/5: consumers mostly fail to keep up with producers.
#[test]
fn fig4_consumers_lag_producers() {
    let s = run(|c| {
        c.mode = SourceMode::Pull;
        c.np = 8;
        c.nc = 8;
        c.broker_cores = 16;
    });
    assert!(s.report.consumers.p50 < s.report.producers.p50);
}

/// Fig. 5 vs Fig. 4: the filter benchmark is slightly slower than count.
#[test]
fn fig5_filter_not_faster_than_count() {
    let count = run(|c| {
        c.workload = Workload::Count;
        c.mode = SourceMode::Pull;
    });
    let filter = run(|c| {
        c.workload = Workload::Filter;
        c.mode = SourceMode::Pull;
    });
    assert!(filter.report.consumers.p50 <= count.report.consumers.p50 * 1.05);
}

/// Fig. 7: constrained broker (NBc=4, repl=2, consumer CS == producer CS):
/// push approaches 2x pull; native keeps up with producers.
#[test]
fn fig7_constrained_broker_headline() {
    let t = |mode: SourceMode| {
        run(|c| {
            c.mode = mode;
            c.np = 4;
            c.nc = 4;
            c.ns = 8;
            c.broker_cores = 4;
            c.replication = 2;
            c.producer_chunk = 4 * 1024;
            c.consumer_chunk = 4 * 1024;
            c.workload = Workload::Filter;
        })
    };
    let native = t(SourceMode::NativePull);
    let pull = t(SourceMode::Pull);
    let push = t(SourceMode::Push);
    let ratio = push.report.consumers.p50 / pull.report.consumers.p50;
    assert!(
        ratio > 1.5,
        "push must approach 2x pull on the constrained broker: {ratio:.2}"
    );
    assert!(ratio < 3.0, "and not be absurdly larger: {ratio:.2}");
    assert!(
        native.report.consumers.p50 > native.report.producers.p50 * 0.9,
        "native (C++) consumers keep up with producers"
    );
    // producers under push should not be slower than under pull
    assert!(push.report.producers.p50 >= pull.report.producers.p50 * 0.95);
}

/// Fig. 8: at small producer chunks with consumer CS = 8x, push matches or
/// beats pull while issuing zero pull RPCs.
#[test]
fn fig8_small_chunks_favour_push() {
    let t = |mode: SourceMode| {
        run(|c| {
            c.mode = mode;
            c.np = 4;
            c.nc = 4;
            c.ns = 8;
            c.broker_cores = 8;
            c.producer_chunk = 2 * 1024;
            c.consumer_chunk = 16 * 1024;
        })
    };
    let pull = t(SourceMode::Pull);
    let push = t(SourceMode::Push);
    assert!(push.report.consumers.p50 >= pull.report.consumers.p50 * 0.95);
    assert_eq!(push.pull_rpcs, 0);
    assert!(pull.pull_rpcs > 1000, "pull burns RPCs on small chunks: {}", pull.pull_rpcs);
}

/// Fig. 9: word count is CPU-bound in the mappers — pull ≈ push.
#[test]
fn fig9_wordcount_parity() {
    let t = |mode: SourceMode| {
        run(|c| {
            c.mode = mode;
            c.workload = Workload::WordCount;
            c.record_size = 2048;
            c.np = 4;
            c.nc = 4;
            c.ns = 4;
            c.nmap = 8;
            c.producer_chunk = 16 * 1024;
        })
    };
    let pull = t(SourceMode::Pull);
    let push = t(SourceMode::Push);
    let ratio = push.report.consumers.p50 / pull.report.consumers.p50;
    assert!(
        (0.85..1.15).contains(&ratio),
        "CPU-bound word count: pull ≈ push, got {ratio:.2}"
    );
}

/// §VII / ablation: on a commodity network the push advantage does not
/// shrink (producers own the ingest link; consumers are colocated).
#[test]
fn commodity_network_does_not_hurt_push() {
    let t = |mode: SourceMode, net: &str| {
        let mut c = ExperimentConfig { duration_secs: 12, warmup_secs: 2, ..Default::default() };
        c.mode = mode;
        c.np = 4;
        c.nc = 4;
        c.broker_cores = 4;
        c.producer_chunk = 4 * 1024;
        c.consumer_chunk = 4 * 1024;
        c.cost.apply_one("network", net).unwrap();
        launch(&c, None).run()
    };
    let ib = t(SourceMode::Push, "infiniband").report.consumers.p50
        / t(SourceMode::Pull, "infiniband").report.consumers.p50;
    let tg = t(SourceMode::Push, "commodity").report.consumers.p50
        / t(SourceMode::Pull, "commodity").report.consumers.p50;
    assert!(tg >= ib * 0.9, "push advantage holds on commodity: {tg:.2} vs {ib:.2}");
}
