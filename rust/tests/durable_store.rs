//! Durable-store integration suite: the tiered WAL + sorted-segment
//! backend under the whole cluster, plus crash recovery.
//!
//! Three invariants guard the storage tier:
//!
//! 1. **Golden totals parity.** `store_mode=durable` reports the same
//!    closed-form bounded totals (`Np × corpus_records`, produced ==
//!    consumed == logged) as the in-memory backend across every source
//!    mode × write mode cell — the backend must be invisible to the
//!    dataflow.
//! 2. **Crash recovery.** Killing the broker mid-run (dropping the
//!    cluster without a clean finish) and reopening the store directory
//!    recovers the retained log byte-identically from WAL + cold
//!    segments, with compaction enabled — and an injected fault + rollback
//!    on the durable backend still lands on the exactly-once totals of an
//!    uninterrupted in-memory run on the same seed.
//! 3. **Laggard reads.** A reader starting at the retained `start` is
//!    served entirely from compacted cold segment files, and the chunks
//!    it gets re-enter the spine as shared payloads.

use std::path::PathBuf;

use zettastream::broker::{Broker, LogStore, StoreParams, StoreRegistry};
use zettastream::cluster::launch;
use zettastream::config::{
    ExperimentConfig, FaultKind, SourceMode, StoreMode, Workload, WriteMode,
};
use zettastream::proto::{Chunk, ChunkOffset, PartitionId};

/// A fresh per-test directory under the system tempdir (integration tests
/// run in their own process, so the pid + tag is collision-free).
fn test_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("zs-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Bounded sim-plane config on the durable backend: the
/// `zero_copy_parity` parity cell plus `store_*` knobs small enough that
/// a run seals, flushes and compacts cold files instead of living in the
/// WAL tail.
fn durable_config(mode: SourceMode, write: WriteMode) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("durable-{}-{}", mode.name(), write.name()),
        np: 2,
        nc: 2,
        nmap: 4,
        ns: 4,
        producer_chunk: 4 * 1024,
        consumer_chunk: 16 * 1024,
        record_size: 100,
        broker_cores: 8,
        mode,
        write_mode: write,
        workload: Workload::Count,
        corpus_records: 2_000, // per producer; drains long before the horizon
        duration_secs: 10,
        warmup_secs: 1,
        seed: 0xC0FFEE,
        store_mode: StoreMode::Durable,
        store_segment_bytes: 16 * 1024,
        store_wal_bytes: 256 * 1024,
        store_compact_min_segments: 2,
        ..Default::default()
    }
}

// ---------------------------------------------------------------------------
// 1. Golden totals parity across the whole source × write design space
// ---------------------------------------------------------------------------

#[test]
fn durable_totals_identical_across_all_source_and_write_modes() {
    let expect = 2 * 2_000u64; // Np × corpus_records — the memory golden
    for &mode in &SourceMode::ALL {
        for &write in &WriteMode::ALL {
            let config = durable_config(mode, write);
            let summary = launch(&config, None).run();
            let cell = format!("{}/{}", mode.name(), write.name());
            assert_eq!(summary.records_produced, expect, "{cell}: produced");
            assert_eq!(
                summary.records_consumed, expect,
                "{cell}: consumed == produced (exactly once, fully drained)"
            );
            assert_eq!(summary.tuples_logged, expect, "{cell}: every record logged once");
            // The run actually exercised the tiers, not just the tail.
            assert!(
                summary.report.gauge("broker.store_wal_records").unwrap() > 0.0,
                "{cell}: appends hit the WAL"
            );
            assert!(
                summary.report.gauge("broker.store_segments_flushed").unwrap() > 0.0,
                "{cell}: sealed segments reached the cold tier"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// 2a. Fault + rollback on durable == uninterrupted run on memory
// ---------------------------------------------------------------------------

#[test]
fn faulted_durable_run_matches_uninterrupted_memory_run() {
    for &mode in &[SourceMode::Pull, SourceMode::Push] {
        let mk = |store: StoreMode, fault: bool| {
            let mut c = durable_config(mode, WriteMode::SyncRpc);
            c.store_mode = store;
            c.corpus_records = 5_000;
            c.duration_secs = 30; // long horizon: drains even after recovery
            c.checkpoint_interval_ms = 200;
            if fault {
                c.fault_at_secs = 2;
                c.fault_kind = FaultKind::Worker;
            }
            c
        };
        let golden = launch(&mk(StoreMode::Memory, false), None).run();
        let faulted = launch(&mk(StoreMode::Durable, true), None).run();
        let expect = 2 * 5_000u64;
        assert_eq!(golden.records_consumed, expect, "{}: golden drains", mode.name());
        assert_eq!(
            faulted.checkpoints.recoveries, 1,
            "{}: the injected fault recovered",
            mode.name()
        );
        assert_eq!(
            faulted.records_produced, golden.records_produced,
            "{}: produced parity across backends and faults",
            mode.name()
        );
        assert_eq!(
            faulted.records_consumed, golden.records_consumed,
            "{}: exactly-once totals survive rollback on the durable backend",
            mode.name()
        );
        assert_eq!(faulted.tuples_logged, golden.tuples_logged, "{}: logged", mode.name());
    }
}

// ---------------------------------------------------------------------------
// 2b. Broker crash-restart: reopen the directory, recover byte-identically
// ---------------------------------------------------------------------------

/// The shape of one retained chunk — everything a sim-plane chunk is.
type ChunkShape = (ChunkOffset, u32, u32);

fn retained_window(view: &zettastream::broker::LogView<'_>) -> Vec<ChunkShape> {
    if view.head() == view.start() {
        return Vec::new();
    }
    view.read_from(view.start(), u64::MAX)
        .expect("reads at start never trim")
        .into_iter()
        .map(|s| (s.offset, s.chunk.records, s.chunk.record_size))
        .collect()
}

#[test]
fn broker_crash_restart_recovers_the_log_from_wal_and_segments() {
    let dir = test_dir("crash");
    let mut config = durable_config(SourceMode::Pull, WriteMode::SyncRpc);
    config.store_dir = dir.to_string_lossy().into_owned();
    config.corpus_records = 4_000;
    config.checkpoint_interval_ms = 200; // committed epochs floor the trims
    let partitions: Vec<PartitionId> = (0..config.ns).map(PartitionId).collect();

    // Run past several committed epochs, then kill the broker: drop the
    // cluster without a clean finish, exactly like a process crash as far
    // as the store directory is concerned (no shutdown hook writes state).
    let mut snapshot = Vec::new();
    {
        let mut cluster = launch(&config, None);
        cluster.engine.run_until(4 * zettastream::sim::SECOND);
        let broker =
            cluster.engine.actor_as::<Broker>(cluster.broker).expect("broker actor");
        let stats = broker.store_stats();
        assert!(stats.wal.records > 0, "appends hit the WAL before the crash");
        assert!(stats.segments_flushed > 0, "cold files exist before the crash");
        assert!(stats.compactions > 0, "compaction ran before the crash");
        for &p in &partitions {
            let view = broker.partition(p).expect("hosted");
            snapshot.push((
                p,
                view.head(),
                view.start(),
                view.total_appended_bytes(),
                view.total_appended_records(),
                retained_window(&view),
            ));
        }
    } // <- the crash

    // Reopen the directory with the same knobs the cluster derived.
    let registry = StoreRegistry::builtin();
    let params = StoreParams::from_config(&config);
    let mut store = registry
        .expect(StoreMode::Durable)
        .open(&params, &partitions)
        .expect("reopen after crash");
    for (p, head, start, bytes, records, window) in &snapshot {
        assert_eq!(store.head(*p), *head, "{p:?}: head recovered");
        assert_eq!(store.start(*p), *start, "{p:?}: retained start recovered");
        assert_eq!(store.total_appended_bytes(*p), *bytes, "{p:?}: byte totals recovered");
        assert_eq!(store.total_appended_records(*p), *records, "{p:?}: record totals");
        let reopened: Vec<ChunkShape> = if head == start {
            Vec::new()
        } else {
            store
                .read_from(*p, *start, u64::MAX)
                .expect("recovered window readable")
                .into_iter()
                .map(|s| (s.offset, s.chunk.records, s.chunk.record_size))
                .collect()
        };
        assert_eq!(&reopened, window, "{p:?}: retained window byte-identical");
    }

    // The recovered log is live: appends resume exactly at the old head.
    let p = partitions[0];
    let head = store.head(p);
    assert_eq!(store.append(p, Chunk::sim(10, 100)), head);
    assert_eq!(store.head(p), head + 1);
    drop(store);
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// 3. Laggard reader: served entirely from compacted cold segments
// ---------------------------------------------------------------------------

#[test]
fn laggard_reads_come_entirely_from_compacted_cold_segments() {
    let dir = test_dir("laggard");
    let params = StoreParams {
        mode: StoreMode::Durable,
        dir: Some(dir.clone()),
        segment_bytes: 4 * 400, // 4 chunks per segment
        wal_file_bytes: 64 * 1024,
        compact_min_segments: 2,
        // Big enough to hold every decoded segment: the second laggard
        // pass below must find pass 1's buffers still cached.
        cold_cache_segments: 16,
    };
    let p = PartitionId(0);
    let registry = StoreRegistry::builtin();
    let mut store =
        registry.expect(StoreMode::Durable).open(&params, &[p]).expect("open");
    // 64 chunks → 16 segments; flushing keeps one resident in the tail,
    // compaction merges the cold files behind it.
    for i in 0..64u32 {
        let fill = i as u8;
        let data = std::rc::Rc::new(vec![fill; 400]);
        store.append(p, Chunk::real(4, 100, data));
    }
    let stats = store.stats();
    assert!(stats.segments_flushed >= 15, "cold tier holds nearly everything");
    assert!(stats.compactions > 0, "cold files were merged");

    // The laggard starts at offset 0 and walks the whole log. Everything
    // below the resident tail segment must come from cold files.
    let got = store.read_from(p, 0, u64::MAX).expect("nothing trimmed");
    assert_eq!(got.len(), 64, "every chunk served");
    for (i, s) in got.iter().enumerate() {
        assert_eq!(s.offset, i as u64);
        let buf = s.chunk.payload.buffer().expect("cold chunks rematerialise as real");
        assert!(buf.iter().all(|&b| b == i as u8), "chunk {i}: payload intact");
    }
    let stats = store.stats();
    assert!(stats.cold_loads > 0, "the walk decoded cold segment files");
    assert_eq!(stats.bloom_negatives, 0, "every in-range offset was found");

    // A second laggard pass rides the decoded-chunk cache and shares the
    // very same buffers (one materialisation per chunk per load).
    let hits_before = stats.cold_cache_hits;
    let again = store.read_from(p, 0, u64::MAX).expect("still nothing trimmed");
    let cached = (0..again.len()).take_while(|&i| {
        std::rc::Rc::ptr_eq(
            got[i].chunk.payload.buffer().unwrap(),
            again[i].chunk.payload.buffer().unwrap(),
        )
    });
    assert!(cached.count() > 0, "cached cold chunks are Rc-shared, not re-read");
    assert!(store.stats().cold_cache_hits > hits_before, "the cache served the re-read");
    drop(store);
    let _ = std::fs::remove_dir_all(&dir);
}
