//! Property-based tests on coordinator invariants: random configurations
//! and payloads must never violate the conservation/ordering/backpressure
//! laws, regardless of mode or parameters.

use std::rc::Rc;

use zettastream::broker::PartitionLog;
use zettastream::cluster::launch;
use zettastream::compute::{native, ComputeEngine};
use zettastream::config::{ExperimentConfig, SourceMode, Workload};
use zettastream::proto::{Chunk, PartitionId};
use zettastream::sim::proptest::forall;
use zettastream::sim::Rng;

fn random_config(rng: &mut Rng) -> ExperimentConfig {
    let ns_choices = [1usize, 2, 4, 8];
    let ns = ns_choices[rng.next_below(4) as usize];
    // nc must divide ns
    let divisors: Vec<usize> = (1..=ns).filter(|d| ns % d == 0).collect();
    let nc = divisors[rng.next_below(divisors.len() as u64) as usize];
    let mode = match rng.next_below(3) {
        0 => SourceMode::Pull,
        1 => SourceMode::Push,
        _ => SourceMode::NativePull,
    };
    let workload = match rng.next_below(3) {
        0 => Workload::Count,
        1 => Workload::Filter,
        _ => Workload::WordCount,
    };
    let record_size = if workload.is_text() { 2048 } else { 100 };
    let producer_chunk = (1 << rng.range(11, 16)) as usize; // 2KiB..64KiB
    let mut c = ExperimentConfig {
        np: rng.range(1, 4) as usize,
        nc,
        ns,
        nmap: rng.range(1, 4) as usize,
        producer_chunk,
        consumer_chunk: producer_chunk * (1 << rng.next_below(3)) as usize,
        record_size,
        replication: 1 + rng.next_below(2) as usize,
        broker_cores: rng.range(1, 8) as usize,
        mode,
        workload,
        duration_secs: 3,
        warmup_secs: 1,
        queue_cap: rng.range(1, 8) as usize,
        push_objects_per_source: rng.range(1, 6) as usize,
        seed: rng.next_u64(),
        ..Default::default()
    };
    // push mode needs a spare core for the dedicated thread
    if c.mode == SourceMode::Push && c.broker_cores == 1 {
        c.broker_cores = 2;
    }
    c
}

/// Conservation: consumed <= produced; tuples logged are consistent with
/// consumption; push never issues pull RPCs; everything terminates.
#[test]
fn random_clusters_conserve_records() {
    forall(25, |rng| {
        let config = random_config(rng);
        config.validate().unwrap_or_else(|e| panic!("config invalid: {e}\n{config:#?}"));
        let summary = launch(&config, None).run();
        assert!(
            summary.records_consumed <= summary.records_produced,
            "conservation violated: {} > {} ({config:#?})",
            summary.records_consumed,
            summary.records_produced
        );
        match config.mode {
            SourceMode::Push => assert_eq!(summary.pull_rpcs, 0),
            _ => assert!(summary.pull_rpcs > 0),
        }
        if config.workload == Workload::WordCount && config.mode != SourceMode::NativePull {
            // tokens logged track consumed records (sim estimate is exact)
            let expect = summary.records_consumed * config.cost.tokens_per_record;
            assert!(
                summary.tuples_logged <= expect,
                "logged {} > est {}",
                summary.tuples_logged,
                expect
            );
        }
    });
}

/// The partition log is an append-only queue: reads at increasing offsets
/// return exactly the appended sequence, under random chunk sizes, read
/// budgets and trims.
#[test]
fn partition_log_is_a_faithful_queue() {
    forall(50, |rng| {
        let seg_bytes = rng.range(512, 64 * 1024);
        let mut log = PartitionLog::new(PartitionId(0), seg_bytes);
        let n = rng.range(1, 200);
        let mut appended = Vec::new();
        for _ in 0..n {
            let records = rng.range(1, 50) as u32;
            let rec_size = rng.range(10, 200) as u32;
            log.append(Chunk::sim(records, rec_size));
            appended.push((records, rec_size));
        }
        // sequential read-back with random budgets
        let mut offset = 0u64;
        let mut seen = Vec::new();
        while offset < log.head() {
            let budget = rng.range(1, 128 * 1024);
            let chunks = log.read_from(offset, budget).expect("offset retained");
            assert!(!chunks.is_empty(), "must make progress");
            for sc in &chunks {
                assert_eq!(sc.offset, offset);
                seen.push((sc.chunk.records, sc.chunk.record_size));
                offset += 1;
            }
            // random trim below current progress: never affects future reads
            if rng.next_below(4) == 0 {
                log.trim_below(rng.next_below(offset + 1));
            }
        }
        assert_eq!(seen, appended, "read-back == append order");
    });
}

/// Random trims never drop data at or above the watermark.
#[test]
fn trim_respects_watermark() {
    forall(40, |rng| {
        let mut log = PartitionLog::new(PartitionId(0), rng.range(256, 4096));
        let n = rng.range(2, 100);
        for _ in 0..n {
            log.append(Chunk::sim(rng.range(1, 20) as u32, 16));
        }
        let watermark = rng.next_below(log.head());
        log.trim_below(watermark);
        assert!(log.start() <= watermark, "never trim past the watermark");
        // reading from the watermark always works
        let got = log.read_from(watermark, u64::MAX).unwrap();
        assert_eq!(got.len() as u64, log.head() - watermark);
    });
}

/// Kernel-semantics invariants on random payloads: histogram total equals
/// independent token count; filter flags independent of framing split.
#[test]
fn kernel_invariants_on_random_payloads() {
    forall(40, |rng| {
        let records = rng.range(1, 20) as usize;
        let rec_size = rng.range(8, 128) as usize;
        let mut data = vec![0u8; records * rec_size];
        for b in data.iter_mut() {
            // mix of letters, digits, separators, high bytes
            *b = match rng.next_below(5) {
                0 => b'a' + rng.next_below(26) as u8,
                1 => b'A' + rng.next_below(26) as u8,
                2 => b'0' + rng.next_below(10) as u8,
                3 => b' ',
                _ => rng.next_byte(),
            };
        }
        let hist = native::wordcount_hist(&data, records, rec_size, 64);
        let total: i64 = hist.iter().map(|&v| v as i64).sum();
        // independent token count, respecting record boundaries
        let mut expect = 0i64;
        for r in 0..records {
            expect += zettastream::wikipedia::CorpusReader::count_tokens(
                &data[r * rec_size..(r + 1) * rec_size],
            ) as i64;
        }
        assert_eq!(total, expect, "histogram mass == token count");

        // filter: flags match naive substring search per record
        let pat: Vec<u8> = (0..rng.range(1, 4)).map(|_| b'a' + rng.next_below(3) as u8).collect();
        let flags = native::filter_flags(&data, records, rec_size, &pat);
        for (r, &flag) in flags.iter().enumerate() {
            let rec = &data[r * rec_size..(r + 1) * rec_size];
            let naive = rec.windows(pat.len()).any(|w| w == &pat[..]);
            assert_eq!(flag == 1, naive, "record {r}, pattern {pat:?}");
        }
    });
}

/// Sim determinism: identical configs ⇒ identical summaries, across modes.
#[test]
fn random_configs_are_deterministic() {
    forall(8, |rng| {
        let config = random_config(rng);
        let a = launch(&config, None).run();
        let b = launch(&config, None).run();
        assert_eq!(a.records_produced, b.records_produced);
        assert_eq!(a.records_consumed, b.records_consumed);
        assert_eq!(a.tuples_logged, b.tuples_logged);
        assert_eq!(a.pull_rpcs, b.pull_rpcs);
        assert_eq!(a.objects_filled, b.objects_filled);
    });
}

/// Real plane on random synthetic payloads: native compute engine results
/// are framing-stable (splitting a chunk in two never changes totals).
#[test]
fn compute_results_framing_stable() {
    forall(20, |rng| {
        let records = 2 * rng.range(1, 16) as usize;
        let rec_size = rng.range(16, 64) as usize;
        let mut data = vec![0u8; records * rec_size];
        rng.fill_bytes(&mut data);
        let engine = ComputeEngine::native();
        let whole = Chunk::real(records as u32, rec_size as u32, Rc::new(data.clone()));
        let half = records / 2;
        let a = Chunk::real(half as u32, rec_size as u32,
                            Rc::new(data[..half * rec_size].to_vec()));
        let b = Chunk::real((records - half) as u32, rec_size as u32,
                            Rc::new(data[half * rec_size..].to_vec()));
        let pat = b"ab";
        let whole_matches = engine.filter_count(&whole, pat).unwrap();
        let split_matches =
            engine.filter_count(&a, pat).unwrap() + engine.filter_count(&b, pat).unwrap();
        assert_eq!(whole_matches, split_matches);
        let (wh, wt) = engine.wordcount(&whole).unwrap();
        let (ah, at) = engine.wordcount(&a).unwrap();
        let (bh, bt) = engine.wordcount(&b).unwrap();
        assert_eq!(wt, at + bt);
        let sum: Vec<i32> = ah.iter().zip(bh.iter()).map(|(x, y)| x + y).collect();
        assert_eq!(wh, sum);
    });
}
