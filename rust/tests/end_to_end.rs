//! Integration: the full three-layer stack on the REAL data plane.
//!
//! These tests REQUIRE the AOT artifacts (`make artifacts`) — they are the
//! proof that Layer 3 (rust broker/sources/worker), Layer 2 (JAX graphs)
//! and Layer 1 (Pallas kernels) compose: real bytes flow producer →
//! broker log → source → PJRT kernel → keyed state, and every count is
//! validated against an independent oracle. They only exist in `--features
//! xla` builds; the default (sim-plane) build compiles this file empty.

#![cfg(feature = "xla")]

use std::rc::Rc;

use zettastream::cluster::{launch, FILTER_NEEDLE};
use zettastream::compute::{ComputeEngine, SharedCompute};
use zettastream::config::{DataPlane, ExperimentConfig, SourceMode, Workload};
use zettastream::wikipedia::CorpusReader;

fn xla() -> SharedCompute {
    ComputeEngine::xla_from_default_dir()
        .expect("integration tests need the AOT artifacts: run `make artifacts`")
}

fn real_config(mode: SourceMode, workload: Workload) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("e2e-{}-{}", mode.name(), workload.name()),
        np: 1,
        nc: 2,
        nmap: 2,
        ns: 2,
        producer_chunk: 8 * 1024,
        consumer_chunk: 32 * 1024,
        record_size: 100,
        broker_cores: 4,
        mode,
        workload,
        data_plane: DataPlane::Real,
        duration_secs: 8,
        warmup_secs: 1,
        ..Default::default()
    }
}

#[test]
fn filter_pipeline_finds_planted_needles_pull() {
    let summary = launch(&real_config(SourceMode::Pull, Workload::Filter), Some(xla())).run();
    assert!(summary.planted > 100, "enough needles planted: {}", summary.planted);
    // Consumers may lag producers slightly at the horizon; every consumed
    // needle must be matched, and the match count can never exceed plants
    // (the alphabet is a..z, needle can't occur by chance at 6 bytes of
    // 26^6 ~ 3e8 odds over ~1e5 records).
    assert!(summary.matches <= summary.planted);
    let consumed_frac = summary.records_consumed as f64 / summary.records_produced as f64;
    let match_frac = summary.matches as f64 / summary.planted as f64;
    assert!(
        (match_frac - consumed_frac).abs() < 0.1,
        "matches track consumption: {match_frac:.3} vs {consumed_frac:.3}"
    );
}

#[test]
fn filter_pipeline_finds_planted_needles_push() {
    let summary = launch(&real_config(SourceMode::Push, Workload::Filter), Some(xla())).run();
    assert!(summary.planted > 100);
    assert!(summary.matches > 0);
    assert!(summary.matches <= summary.planted);
}

#[test]
fn native_consumer_matches_like_the_engine_path() {
    let summary =
        launch(&real_config(SourceMode::NativePull, Workload::Filter), Some(xla())).run();
    assert!(summary.matches > 0, "native consumers filter in place");
    assert!(summary.matches <= summary.planted);
}

/// The core cross-layer correctness check: XLA (Pallas kernels through
/// PJRT) and the pure-rust native engine must produce byte-identical
/// results on the same cluster run.
#[test]
fn xla_and_native_planes_agree_exactly() {
    let mut results = Vec::new();
    for compute in [xla(), ComputeEngine::native()] {
        let mut config = real_config(SourceMode::Push, Workload::Filter);
        config.name = format!("plane-{}", compute.name());
        let summary = launch(&config, Some(compute)).run();
        results.push((summary.planted, summary.matches, summary.records_consumed));
    }
    assert_eq!(results[0], results[1], "xla vs native must agree bit-for-bit");
}

fn oracle_tokens(np: u64, corpus_records: u64) -> u64 {
    let mut total = 0;
    for _ in 0..np {
        let mut reader = CorpusReader::new(2048, corpus_records);
        let mut buf = vec![0u8; 2048];
        while reader.remaining() > 0 {
            reader.fill_records(&mut buf);
            total += CorpusReader::count_tokens(&buf);
        }
    }
    total
}

fn wordcount_config(mode: SourceMode, corpus_records: u64) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("e2e-wc-{}", mode.name()),
        np: 1,
        nc: 2,
        nmap: 2,
        ns: 2,
        producer_chunk: 16 * 1024,
        consumer_chunk: 64 * 1024,
        record_size: 2048,
        broker_cores: 4,
        mode,
        workload: Workload::WordCount,
        data_plane: DataPlane::Real,
        corpus_records,
        duration_secs: 20,
        warmup_secs: 1,
        ..Default::default()
    }
}

#[test]
fn wordcount_tokens_match_oracle_exactly_pull() {
    let corpus_records = 1_000;
    let summary = launch(&wordcount_config(SourceMode::Pull, corpus_records), Some(xla())).run();
    assert_eq!(summary.records_produced, corpus_records, "bounded corpus fully pushed");
    assert_eq!(summary.records_consumed, corpus_records, "fully drained");
    assert_eq!(
        summary.tuples_logged,
        oracle_tokens(1, corpus_records),
        "keyed sums count exactly the oracle's tokens (via the Pallas kernel)"
    );
}

#[test]
fn wordcount_tokens_match_oracle_exactly_push() {
    let corpus_records = 1_000;
    let summary = launch(&wordcount_config(SourceMode::Push, corpus_records), Some(xla())).run();
    assert_eq!(summary.records_consumed, corpus_records);
    assert_eq!(summary.tuples_logged, oracle_tokens(1, corpus_records));
}

#[test]
fn windowed_wordcount_fires_and_counts() {
    let mut config = wordcount_config(SourceMode::Push, 800);
    config.workload = Workload::WindowedWordCount;
    config.duration_secs = 15;
    let summary = launch(&config, Some(xla())).run();
    assert!(summary.windows_fired > 0, "sliding windows fired");
    assert_eq!(summary.tuples_logged, oracle_tokens(1, 800));
}

/// Pull and push must deliver the same DATA (same tokens) — the transport
/// strategy cannot change the answer.
#[test]
fn pull_and_push_agree_on_the_answer() {
    let a = launch(&wordcount_config(SourceMode::Pull, 600), Some(xla())).run();
    let b = launch(&wordcount_config(SourceMode::Push, 600), Some(xla())).run();
    assert_eq!(a.tuples_logged, b.tuples_logged);
    assert_eq!(a.records_consumed, b.records_consumed);
}

/// Real-plane chunk payloads survive the broker log + object store
/// round-trip even when consumers lag producers (retention respects the
/// slowest reader).
#[test]
fn retention_never_loses_unconsumed_data() {
    let mut config = real_config(SourceMode::Pull, Workload::Count);
    config.producer_chunk = 64 * 1024; // fast producers, 1 consumer
    config.consumer_chunk = 64 * 1024;
    config.nc = 1;
    config.nmap = 1;
    config.duration_secs = 6;
    let summary = launch(&config, Some(xla())).run();
    // no TrimmedError panics + consumers made progress
    assert!(summary.records_consumed > 0);
    assert!(summary.records_consumed <= summary.records_produced);
}
