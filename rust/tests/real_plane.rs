//! Real-plane cluster runs: golden totals vs the sim plane, and graceful
//! shutdown accounting.
//!
//! The tentpole claim of the execution-plane split is that `plane=real` is
//! the *same system* — same actors, same protocol, same construction
//! paths — merely scheduled by the OS instead of the DES clock. The proof
//! is a bounded workload run both ways on the same seed: every
//! timing-independent total (records produced, consumed, tuples logged,
//! needles planted, filter matches) must match byte for byte across all
//! 4 source modes × 3 write modes. Poll-shaped counters (pull RPC counts,
//! empty polls) legitimately differ — wall-clock interleaving decides how
//! often a pull comes back empty — and are deliberately not compared.

use zettastream::cluster::launch;
use zettastream::config::{ExecPlane, ExperimentConfig, SourceMode, StoreMode, Workload, WriteMode};
use zettastream::real;

/// Per-producer bounded corpus; the run target is `np * CORPUS`.
const CORPUS: u64 = 1_500;

/// One bounded cell: small enough to drain quickly on both planes, big
/// enough that every path (append pacing, push object recycling, hybrid
/// switchover) actually cycles.
fn cell_config(source: SourceMode, write: WriteMode) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("real-parity-{}-{}", source.name(), write.name()),
        np: 2,
        nc: 2,
        nmap: 2,
        ns: 4,
        broker_cores: 8,
        mode: source,
        write_mode: write,
        store_mode: StoreMode::Memory,
        workload: Workload::Count,
        corpus_records: CORPUS,
        // The sim side needs a virtual horizon comfortably past the drain
        // point; the real side ignores it and stops at quiescence.
        duration_secs: 30,
        warmup_secs: 1,
        seed: 7,
        ..Default::default()
    }
}

#[test]
fn golden_totals_match_sim_across_all_cells() {
    for &source in &SourceMode::ALL {
        for &write in &WriteMode::ALL {
            let cell = format!("{}x{}", source.name(), write.name());
            let target = 2 * CORPUS;

            let sim = launch(&cell_config(source, write), None).run();
            assert_eq!(
                sim.records_produced, target,
                "{cell}: sim plane must fully drain the bounded corpus"
            );
            assert_eq!(sim.records_consumed, target, "{cell}: sim plane fully consumed");

            let mut config = cell_config(source, write);
            config.plane = ExecPlane::Real;
            let real = real::run_cluster(&config)
                .unwrap_or_else(|e| panic!("{cell}: real-plane run failed: {e}"));

            assert_eq!(
                real.records_produced, sim.records_produced,
                "{cell}: records_produced diverged across planes"
            );
            assert_eq!(
                real.records_consumed, sim.records_consumed,
                "{cell}: records_consumed diverged across planes"
            );
            assert_eq!(
                real.tuples_logged, sim.tuples_logged,
                "{cell}: tuples_logged diverged across planes"
            );
            assert_eq!(real.planted, sim.planted, "{cell}: planted diverged across planes");
            assert_eq!(real.matches, sim.matches, "{cell}: matches diverged across planes");
        }
    }
}

#[test]
fn graceful_shutdown_no_thread_leak_no_lost_acks() {
    let mut config = cell_config(SourceMode::Pull, WriteMode::SyncRpc);
    config.name = "real-shutdown".into();
    config.plane = ExecPlane::Real;
    let summary = real::run_cluster(&config).expect("real-plane run");

    // Every OS thread the run spawned (node threads + every transport
    // reader/writer) was joined before run_cluster returned.
    assert!(summary.threads.spawned > 0, "a real run spawns threads");
    assert_eq!(
        summary.threads.spawned, summary.threads.joined,
        "thread leak: spawned {} joined {}",
        summary.threads.spawned, summary.threads.joined
    );

    // The drain protocol lost no acks: every append the producer node put
    // on the wire came back acked before its transport shut down.
    assert!(summary.writers.appends_issued > 0);
    assert_eq!(
        summary.writers.appends_acked, summary.writers.appends_issued,
        "in-flight appends were dropped by the shutdown drain"
    );
    assert_eq!(summary.records_produced, 2 * CORPUS);
    assert_eq!(summary.records_consumed, 2 * CORPUS);
}
