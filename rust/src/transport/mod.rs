//! The transport seam between the two execution planes.
//!
//! Everything above this layer — brokers, sources, producers, the operator
//! pipeline, the plasma store — is ONE codebase speaking [`WireMsg`]s. A
//! [`Transport`] moves those messages between endpoints; the crate ships
//! two implementations:
//!
//! * [`SimTransport`] — backed by the DES [`crate::net::Network`]
//!   blackboard: sends are charged through the same serialisation-horizon
//!   link model the sim plane's actors use, delivery is in-memory, and the
//!   virtual clock orders everything. This is the existing plane, exposed
//!   through the seam so its ordering contract is testable side by side
//!   with the real one.
//! * [`TcpTransport`] — real `std::net::TcpStream` connections on
//!   localhost with per-connection reader/writer OS threads, length-
//!   prefixed frames ([`frame`]) and the hand-rolled codec ([`wire`]).
//!
//! # Ordering contract
//!
//! Implementations MUST provide, and callers may only assume:
//!
//! 1. **Per-connection FIFO, both directions.** Messages sent on one
//!    connection are delivered to that connection's peer in send order,
//!    without loss or duplication, up to the point of connection failure.
//! 2. **No cross-connection ordering.** Messages on different connections
//!    are delivered in an unspecified interleaving, even between the same
//!    pair of endpoints.
//! 3. **Connection events are ordered with data.** `Accepted` precedes any
//!    `Frame` from that connection; `Closed` follows the last `Frame` and
//!    is delivered exactly once, carrying `Some(error)` iff the connection
//!    died abnormally (a peer vanishing mid-frame is
//!    [`FrameError::EofMidFrame`], never a panic).
//!
//! # Backpressure
//!
//! [`Transport::send`] may block the calling thread when the connection's
//! bounded write queue is full (TCP: `sync_channel` of encoded frames per
//! connection; kernel socket buffers behind it). Receive never blocks
//! beyond the `poll` timeout: inbound frames are buffered unbounded in the
//! process, which is safe because every protocol above this layer is
//! request/reply or credit-windowed — the peer cannot have more frames in
//! flight than its own windows allow.
//!
//! # Error surface
//!
//! All failures are typed [`FrameError`]s: framing violations
//! (`Oversized`, `Truncated`, `UnknownTag`), abnormal stream end
//! (`EofMidFrame`), socket failures (`Io`) and use-after-close
//! (`Closed`). None of them panic; a decode failure on a connection
//! surfaces as a `Closed` event for exactly that connection.

pub mod frame;
pub mod tcp;
pub mod wire;

#[cfg(test)]
mod tests;

use std::cell::RefCell;
use std::collections::VecDeque;
use std::rc::Rc;

use crate::net::{NodeId, SharedNetwork};
use crate::sim::Time;
pub use frame::{FrameDecoder, FrameError, MAX_FRAME_BYTES};
pub use tcp::{TcpTransport, ThreadReport};
pub use wire::{WireEvent, WireMsg, WIRE_VERSION};

/// Endpoint-scoped connection handle. Stable for the life of the
/// transport; never reused after `Closed`.
pub type ConnId = usize;

/// What `poll` yields.
#[derive(Debug)]
pub enum TransportEvent {
    /// A peer connected to this endpoint's listener.
    Accepted { conn: ConnId },
    /// One decoded message from `conn` (per-connection FIFO).
    Frame { conn: ConnId, msg: WireMsg },
    /// `conn` is gone; `error` is `None` on a clean close at a frame
    /// boundary, `Some` otherwise. Delivered exactly once per connection.
    Closed { conn: ConnId, error: Option<FrameError> },
}

/// Message movement between endpoints — the seam the two execution planes
/// share. See the module docs for the ordering/backpressure/error
/// contract; both implementations are tested against it side by side
/// (`tests/transport_parity.rs`).
pub trait Transport {
    /// Open a connection to `addr`. The peer observes `Accepted`.
    fn connect(&mut self, addr: &str) -> Result<ConnId, FrameError>;

    /// Queue one message on `conn`. May block on the connection's bounded
    /// write queue (backpressure); fails fast with [`FrameError::Closed`]
    /// if the connection is gone.
    fn send(&mut self, conn: ConnId, msg: &WireMsg) -> Result<(), FrameError>;

    /// Deliver pending events, waiting up to `max_wait_ms` for the first
    /// one. Returns an empty vec on timeout.
    fn poll(&mut self, max_wait_ms: u64) -> Vec<TransportEvent>;

    /// Close one connection (the peer observes `Closed`).
    fn close_conn(&mut self, conn: ConnId);

    /// The listen address, if this endpoint accepts connections.
    fn local_addr(&self) -> Option<String>;
}

// ---------------------------------------------------------------------------
// SimTransport
// ---------------------------------------------------------------------------

/// The DES plane behind the [`Transport`] seam.
///
/// Both endpoints of a [`SimTransport::pair`] share one fabric: a virtual
/// clock plus per-connection, per-direction FIFO queues. Every send is
/// charged through the shared [`crate::net::Network`] (the same
/// serialisation-horizon model the sim cluster's actors pay), so message
/// order is exactly what the DES plane would deliver; the message itself
/// round-trips through the real codec (`encode` then `decode`) so the sim
/// seam exercises byte-level compatibility, not just semantics.
pub struct SimTransport {
    fabric: Rc<RefCell<SimFabric>>,
    /// 0 = the "listener" endpoint, 1 = the "client" endpoint.
    side: usize,
}

struct SimFabric {
    net: SharedNetwork,
    /// Node index of side 0 / side 1 in the network model.
    nodes: [NodeId; 2],
    clock: Time,
    conns: Vec<SimConn>,
}

struct SimConn {
    /// Inbound queue per side: `inbox[s]` holds what side `s` will read.
    inbox: [VecDeque<WireMsg>; 2],
    /// Accepted event not yet delivered to side 0.
    pending_accept: bool,
    /// Closed-by flags per side (a close by one side surfaces once at the
    /// other).
    closed_by: [bool; 2],
    close_delivered: [bool; 2],
}

impl SimTransport {
    /// A connected pair of endpoints over `net`, between `node_listener`
    /// and `node_client`. Returns `(listener_side, client_side)`.
    pub fn pair(
        net: SharedNetwork,
        node_listener: NodeId,
        node_client: NodeId,
    ) -> (SimTransport, SimTransport) {
        let fabric = Rc::new(RefCell::new(SimFabric {
            net,
            nodes: [node_listener, node_client],
            clock: 0,
            conns: Vec::new(),
        }));
        (SimTransport { fabric: fabric.clone(), side: 0 }, SimTransport { fabric, side: 1 })
    }

    fn peer(side: usize) -> usize {
        1 - side
    }
}

impl Transport for SimTransport {
    fn connect(&mut self, _addr: &str) -> Result<ConnId, FrameError> {
        let mut f = self.fabric.borrow_mut();
        f.conns.push(SimConn {
            inbox: [VecDeque::new(), VecDeque::new()],
            // Only the listener side observes Accepted, mirroring TCP.
            pending_accept: self.side == 1,
            closed_by: [false, false],
            close_delivered: [false, false],
        });
        Ok(f.conns.len() - 1)
    }

    fn send(&mut self, conn: ConnId, msg: &WireMsg) -> Result<(), FrameError> {
        let mut f = self.fabric.borrow_mut();
        let side = self.side;
        let (from, to) = (f.nodes[side], f.nodes[Self::peer(side)]);
        // Round-trip through the codec: the sim seam must reject exactly
        // what the real seam would reject, and deliver an equal message.
        let body = wire::encode_msg(msg);
        if body.len() > MAX_FRAME_BYTES {
            return Err(FrameError::Oversized { len: body.len(), max: MAX_FRAME_BYTES });
        }
        let decoded = wire::decode_msg(&body)?;
        let now = f.clock;
        // Charge the DES link model; its serialisation horizon is what
        // orders concurrent senders on the sim plane.
        let deliver = f.net.borrow_mut().send(now, from, to, 4 + body.len() as u64);
        f.clock = f.clock.max(deliver);
        let c = f.conns.get_mut(conn).ok_or(FrameError::Closed)?;
        if c.closed_by.iter().any(|&b| b) {
            return Err(FrameError::Closed);
        }
        c.inbox[Self::peer(side)].push_back(decoded);
        Ok(())
    }

    fn poll(&mut self, _max_wait_ms: u64) -> Vec<TransportEvent> {
        let mut f = self.fabric.borrow_mut();
        let side = self.side;
        let mut out = Vec::new();
        for (id, c) in f.conns.iter_mut().enumerate() {
            if side == 0 && c.pending_accept {
                c.pending_accept = false;
                out.push(TransportEvent::Accepted { conn: id });
            }
            while let Some(msg) = c.inbox[side].pop_front() {
                out.push(TransportEvent::Frame { conn: id, msg });
            }
            // A close by the peer surfaces after its last queued frame.
            if c.closed_by[Self::peer(side)] && !c.close_delivered[side] {
                c.close_delivered[side] = true;
                out.push(TransportEvent::Closed { conn: id, error: None });
            }
        }
        out
    }

    fn close_conn(&mut self, conn: ConnId) {
        let mut f = self.fabric.borrow_mut();
        if let Some(c) = f.conns.get_mut(conn) {
            c.closed_by[self.side] = true;
        }
    }

    fn local_addr(&self) -> Option<String> {
        (self.side == 0).then(|| "sim:0".to_string())
    }
}
