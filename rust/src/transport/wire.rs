//! Wire messages and their byte-level codec.
//!
//! [`WireMsg`] is the complete vocabulary of a real-plane connection. The
//! RPC payloads are the **same** [`RpcKind`] / [`RpcReply`] types the DES
//! plane delivers in-process — one protocol codebase, two transports. The
//! codec is hand-rolled little-endian (no serde): each message is one
//! frame body, `[u8 tag][fields...]`, framed by [`super::frame`].
//!
//! ## Payload fidelity
//!
//! [`Payload::Sim`] chunks encode as a tag byte and decode back to
//! `Payload::Sim` *without* touching [`Chunk::real`] — accounting-only
//! runs stay accounting-only across the wire, and the zero-copy
//! materialisation counter stays honest. [`Payload::Real`] chunks ship
//! their bytes and are re-materialised through [`Chunk::real`] on the
//! receiving side: that copy **is** the real deserialisation cost of a
//! pull-style RPC, which the shared-memory path avoids by never crossing
//! the wire at all.
//!
//! ## Identity rewriting
//!
//! Actor ids inside specs ([`PushSourceSpec::source_actor`],
//! [`WriteProducerSpec::producer_actor`]) are engine-local. They are
//! carried verbatim and only meaningful on connections whose HELLO proved
//! cluster membership (the cookie); an untrusted peer's spec ids are
//! rewritten by the server to its connection proxy before they reach the
//! broker (see `crate::real`).

use std::rc::Rc;

use crate::proto::{
    Chunk, ObjectId, PartitionId, Payload, PushSourceSpec, RpcKind, RpcReply, StampedChunk, SubId,
    WriteProducerSpec,
};
use crate::sim::Time;
use crate::transport::frame::{
    put_len_bytes, put_u32, put_u64, put_u8, FrameError, FrameReader,
};

/// Protocol version carried in HELLO. Bumped on any codec change.
/// v2 added the shard vocabulary (ShardReplicate/Freeze/Promote,
/// WrongShard/FreezeAck/PromoteAck); v3 the fail-over vocabulary
/// (Heartbeat/ShardFailover, HeartbeatAck/FailoverAck, and the
/// idempotence origin on ShardReplicate).
pub const WIRE_VERSION: u32 = 3;

/// Everything that can travel on a real-plane connection.
#[derive(Debug, Clone)]
pub enum WireMsg {
    /// First frame in each direction. `cookie` proves cluster membership:
    /// a server only trusts engine-local actor ids inside specs when the
    /// cookie matches its own (standalone `zettastream broker` servers
    /// trust nobody).
    Hello { version: u32, node: u32, cookie: u64 },
    /// An RPC request. `wire_id` is connection-scoped (the client proxy
    /// maps it back to the original client-side id when the reply lands).
    Req { wire_id: u64, from_node: u32, kind: RpcKind },
    /// The reply to `Req { wire_id }` on the same connection.
    Rep { wire_id: u64, reply: RpcReply },
    /// Server-initiated notification (no request pairing).
    Evt { event: WireEvent },
    /// Client asks the server to drain in-flight work and close.
    Shutdown,
    /// Server's final frame after a graceful drain: how many replies it
    /// sent on this connection over its lifetime.
    Bye { replies_sent: u64 },
}

/// Server-initiated notifications.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireEvent {
    /// A plasma object filled for one of the peer's push subscriptions.
    /// Carries only the identity — the object's payload lives in shared
    /// memory and is readable only colocated (the paper's asymmetry).
    ObjectReady { sub: u64, slot: u64 },
}

// Message tags.
const TAG_HELLO: u8 = 1;
const TAG_REQ: u8 = 2;
const TAG_REP: u8 = 3;
const TAG_EVT: u8 = 4;
const TAG_SHUTDOWN: u8 = 5;
const TAG_BYE: u8 = 6;

// RpcKind tags.
const K_APPEND: u8 = 0;
const K_PULL: u8 = 1;
const K_PUSH_SUBSCRIBE: u8 = 2;
const K_PUSH_UNSUBSCRIBE: u8 = 3;
const K_WRITE_SUBSCRIBE: u8 = 4;
const K_COMMIT_CHECKPOINT: u8 = 5;
const K_SEAL_OBJECT: u8 = 6;
const K_REPLICATE: u8 = 7;
const K_SHARD_REPLICATE: u8 = 8;
const K_SHARD_FREEZE: u8 = 9;
const K_SHARD_PROMOTE: u8 = 10;
const K_HEARTBEAT: u8 = 11;
const K_SHARD_FAILOVER: u8 = 12;

// RpcReply tags.
const R_APPEND_ACK: u8 = 0;
const R_PULL_DATA: u8 = 1;
const R_SUBSCRIBE_ACK: u8 = 2;
const R_UNSUBSCRIBE_ACK: u8 = 3;
const R_WRITE_SUBSCRIBE_ACK: u8 = 4;
const R_SEAL_ACK: u8 = 5;
const R_REPLICATE_ACK: u8 = 6;
const R_COMMIT_ACK: u8 = 7;
const R_ERROR: u8 = 8;
const R_WRONG_SHARD: u8 = 9;
const R_FREEZE_ACK: u8 = 10;
const R_PROMOTE_ACK: u8 = 11;
const R_HEARTBEAT_ACK: u8 = 12;
const R_FAILOVER_ACK: u8 = 13;

// Payload tags.
const P_SIM: u8 = 0;
const P_REAL: u8 = 1;

/// Encode a message to a frame body (no length prefix — see
/// [`super::frame::encode_frame`]).
pub fn encode_msg(msg: &WireMsg) -> Vec<u8> {
    let mut out = Vec::with_capacity(64);
    match msg {
        WireMsg::Hello { version, node, cookie } => {
            put_u8(&mut out, TAG_HELLO);
            put_u32(&mut out, *version);
            put_u32(&mut out, *node);
            put_u64(&mut out, *cookie);
        }
        WireMsg::Req { wire_id, from_node, kind } => {
            put_u8(&mut out, TAG_REQ);
            put_u64(&mut out, *wire_id);
            put_u32(&mut out, *from_node);
            encode_kind(&mut out, kind);
        }
        WireMsg::Rep { wire_id, reply } => {
            put_u8(&mut out, TAG_REP);
            put_u64(&mut out, *wire_id);
            encode_reply(&mut out, reply);
        }
        WireMsg::Evt { event } => {
            put_u8(&mut out, TAG_EVT);
            match event {
                WireEvent::ObjectReady { sub, slot } => {
                    put_u8(&mut out, 0);
                    put_u64(&mut out, *sub);
                    put_u64(&mut out, *slot);
                }
            }
        }
        WireMsg::Shutdown => put_u8(&mut out, TAG_SHUTDOWN),
        WireMsg::Bye { replies_sent } => {
            put_u8(&mut out, TAG_BYE);
            put_u64(&mut out, *replies_sent);
        }
    }
    out
}

/// Decode one frame body back to a message.
pub fn decode_msg(body: &[u8]) -> Result<WireMsg, FrameError> {
    let mut r = FrameReader::new(body);
    let tag = r.u8("message tag")?;
    match tag {
        TAG_HELLO => Ok(WireMsg::Hello {
            version: r.u32("hello.version")?,
            node: r.u32("hello.node")?,
            cookie: r.u64("hello.cookie")?,
        }),
        TAG_REQ => Ok(WireMsg::Req {
            wire_id: r.u64("req.wire_id")?,
            from_node: r.u32("req.from_node")?,
            kind: decode_kind(&mut r)?,
        }),
        TAG_REP => {
            Ok(WireMsg::Rep { wire_id: r.u64("rep.wire_id")?, reply: decode_reply(&mut r)? })
        }
        TAG_EVT => {
            let etag = r.u8("event tag")?;
            match etag {
                0 => Ok(WireMsg::Evt {
                    event: WireEvent::ObjectReady {
                        sub: r.u64("evt.sub")?,
                        slot: r.u64("evt.slot")?,
                    },
                }),
                t => Err(FrameError::UnknownTag { what: "event", tag: t }),
            }
        }
        TAG_SHUTDOWN => Ok(WireMsg::Shutdown),
        TAG_BYE => Ok(WireMsg::Bye { replies_sent: r.u64("bye.replies_sent")? }),
        t => Err(FrameError::UnknownTag { what: "message", tag: t }),
    }
}

fn encode_chunk(out: &mut Vec<u8>, chunk: &Chunk) {
    put_u32(out, chunk.records);
    put_u32(out, chunk.record_size);
    match &chunk.payload {
        Payload::Sim => put_u8(out, P_SIM),
        Payload::Real(data) => {
            put_u8(out, P_REAL);
            put_len_bytes(out, data);
        }
    }
}

fn decode_chunk(r: &mut FrameReader<'_>) -> Result<Chunk, FrameError> {
    let records = r.u32("chunk.records")?;
    let record_size = r.u32("chunk.record_size")?;
    match r.u8("chunk.payload tag")? {
        P_SIM => Ok(Chunk::sim(records, record_size)),
        P_REAL => {
            let data = r.len_bytes("chunk.payload")?;
            if data.len() as u64 != records as u64 * record_size as u64 {
                return Err(FrameError::Truncated { what: "chunk.payload framing" });
            }
            // The one honest copy of the pull path: deserialising a real
            // payload off the wire is a materialisation and is counted as
            // such (Chunk::real bumps the zero-copy counter).
            Ok(Chunk::real(records, record_size, Rc::new(data.to_vec())))
        }
        t => Err(FrameError::UnknownTag { what: "payload", tag: t }),
    }
}

fn encode_assignments(out: &mut Vec<u8>, assignments: &[(PartitionId, u64)]) {
    put_u32(out, assignments.len() as u32);
    for (p, off) in assignments {
        put_u64(out, p.0 as u64);
        put_u64(out, *off);
    }
}

fn decode_assignments(
    r: &mut FrameReader<'_>,
    what: &'static str,
) -> Result<Vec<(PartitionId, u64)>, FrameError> {
    let n = r.u32(what)? as usize;
    let mut v = Vec::with_capacity(n.min(4096));
    for _ in 0..n {
        let p = r.u64(what)? as usize;
        let off = r.u64(what)?;
        v.push((PartitionId(p), off));
    }
    Ok(v)
}

fn encode_opt_time(out: &mut Vec<u8>, t: &Option<Time>) {
    match t {
        None => put_u8(out, 0),
        Some(v) => {
            put_u8(out, 1);
            put_u64(out, *v);
        }
    }
}

fn decode_opt_time(r: &mut FrameReader<'_>, what: &'static str) -> Result<Option<Time>, FrameError> {
    match r.u8(what)? {
        0 => Ok(None),
        1 => Ok(Some(r.u64(what)?)),
        t => Err(FrameError::UnknownTag { what, tag: t }),
    }
}

fn encode_kind(out: &mut Vec<u8>, kind: &RpcKind) {
    match kind {
        RpcKind::Append { chunks, produced_at } => {
            put_u8(out, K_APPEND);
            put_u32(out, chunks.len() as u32);
            for (p, chunk) in chunks {
                put_u64(out, p.0 as u64);
                encode_chunk(out, chunk);
            }
            encode_opt_time(out, produced_at);
        }
        RpcKind::Pull { assignments, max_bytes } => {
            put_u8(out, K_PULL);
            encode_assignments(out, assignments);
            put_u64(out, *max_bytes);
        }
        RpcKind::PushSubscribe { sources } => {
            put_u8(out, K_PUSH_SUBSCRIBE);
            put_u32(out, sources.len() as u32);
            for s in sources {
                put_u64(out, s.source_actor.0 as u64);
                encode_assignments(out, &s.assignments);
                put_u64(out, s.objects as u64);
                put_u64(out, s.object_bytes);
            }
        }
        RpcKind::PushUnsubscribe { sub } => {
            put_u8(out, K_PUSH_UNSUBSCRIBE);
            put_u64(out, sub.0 as u64);
        }
        RpcKind::WriteSubscribe { producer } => {
            put_u8(out, K_WRITE_SUBSCRIBE);
            put_u64(out, producer.producer_actor.0 as u64);
            put_u32(out, producer.partitions.len() as u32);
            for p in &producer.partitions {
                put_u64(out, p.0 as u64);
            }
            put_u64(out, producer.objects as u64);
            put_u64(out, producer.object_bytes);
        }
        RpcKind::CommitCheckpoint { epoch, cursors } => {
            put_u8(out, K_COMMIT_CHECKPOINT);
            put_u64(out, *epoch);
            encode_assignments(out, cursors);
        }
        RpcKind::SealObject { id, produced_at } => {
            put_u8(out, K_SEAL_OBJECT);
            put_u64(out, id.sub.0 as u64);
            put_u64(out, id.slot as u64);
            encode_opt_time(out, produced_at);
        }
        RpcKind::Replicate { bytes, chunks } => {
            put_u8(out, K_REPLICATE);
            put_u64(out, *bytes);
            put_u32(out, *chunks);
        }
        RpcKind::ShardReplicate { chunks, origin } => {
            put_u8(out, K_SHARD_REPLICATE);
            put_u32(out, chunks.len() as u32);
            for sc in chunks {
                put_u64(out, sc.partition.0 as u64);
                put_u64(out, sc.offset);
                encode_chunk(out, &sc.chunk);
            }
            match origin {
                None => put_u8(out, 0),
                Some((actor, rpc)) => {
                    put_u8(out, 1);
                    put_u64(out, actor.0 as u64);
                    put_u64(out, *rpc);
                }
            }
        }
        RpcKind::ShardFreeze { epoch, partitions } => {
            put_u8(out, K_SHARD_FREEZE);
            put_u64(out, *epoch);
            encode_partitions(out, partitions);
        }
        RpcKind::ShardPromote { epoch, partitions } => {
            put_u8(out, K_SHARD_PROMOTE);
            put_u64(out, *epoch);
            encode_partitions(out, partitions);
        }
        RpcKind::Heartbeat => put_u8(out, K_HEARTBEAT),
        RpcKind::ShardFailover { epoch, dead, table, gained } => {
            put_u8(out, K_SHARD_FAILOVER);
            put_u64(out, *epoch);
            put_u64(out, *dead as u64);
            encode_shard_table(out, table);
            encode_partitions(out, gained);
        }
    }
}

fn encode_shard_table(out: &mut Vec<u8>, table: &crate::shard::ShardTable) {
    put_u64(out, table.epoch);
    put_u64(out, table.brokers() as u64);
    put_u64(out, table.replication() as u64);
    put_u32(out, table.partitions() as u32);
    for p in 0..table.partitions() {
        let set = table.replica_set(PartitionId(p));
        put_u32(out, set.len() as u32);
        for &b in set {
            put_u64(out, b as u64);
        }
    }
}

fn decode_shard_table(r: &mut FrameReader<'_>) -> Result<crate::shard::ShardTable, FrameError> {
    let epoch = r.u64("shard_table.epoch")?;
    let brokers = r.u64("shard_table.brokers")? as usize;
    let replication = r.u64("shard_table.replication")? as usize;
    let n = r.u32("shard_table.partitions")? as usize;
    let mut replicas = Vec::with_capacity(n.min(4096));
    for _ in 0..n {
        let len = r.u32("shard_table.row")? as usize;
        let mut row = Vec::with_capacity(len.min(64));
        for _ in 0..len {
            row.push(r.u64("shard_table.replica")? as usize);
        }
        replicas.push(row);
    }
    Ok(crate::shard::ShardTable::from_parts(epoch, brokers, replication, replicas))
}

fn encode_partitions(out: &mut Vec<u8>, partitions: &[PartitionId]) {
    put_u32(out, partitions.len() as u32);
    for p in partitions {
        put_u64(out, p.0 as u64);
    }
}

fn decode_partitions(
    r: &mut FrameReader<'_>,
    what: &'static str,
) -> Result<Vec<PartitionId>, FrameError> {
    let n = r.u32(what)? as usize;
    let mut v = Vec::with_capacity(n.min(4096));
    for _ in 0..n {
        v.push(PartitionId(r.u64(what)? as usize));
    }
    Ok(v)
}

fn decode_kind(r: &mut FrameReader<'_>) -> Result<RpcKind, FrameError> {
    use crate::sim::ActorId;
    match r.u8("kind tag")? {
        K_APPEND => {
            let n = r.u32("append.chunks")? as usize;
            let mut chunks = Vec::with_capacity(n.min(4096));
            for _ in 0..n {
                let p = r.u64("append.partition")? as usize;
                chunks.push((PartitionId(p), decode_chunk(r)?));
            }
            let produced_at = decode_opt_time(r, "append.produced_at")?;
            Ok(RpcKind::Append { chunks, produced_at })
        }
        K_PULL => Ok(RpcKind::Pull {
            assignments: decode_assignments(r, "pull.assignments")?,
            max_bytes: r.u64("pull.max_bytes")?,
        }),
        K_PUSH_SUBSCRIBE => {
            let n = r.u32("subscribe.sources")? as usize;
            let mut sources = Vec::with_capacity(n.min(4096));
            for _ in 0..n {
                let source_actor = ActorId(r.u64("subscribe.source_actor")? as usize);
                let assignments = decode_assignments(r, "subscribe.assignments")?;
                let objects = r.u64("subscribe.objects")? as usize;
                let object_bytes = r.u64("subscribe.object_bytes")?;
                sources.push(PushSourceSpec { source_actor, assignments, objects, object_bytes });
            }
            Ok(RpcKind::PushSubscribe { sources })
        }
        K_PUSH_UNSUBSCRIBE => {
            Ok(RpcKind::PushUnsubscribe { sub: SubId(r.u64("unsubscribe.sub")? as usize) })
        }
        K_WRITE_SUBSCRIBE => {
            let producer_actor = ActorId(r.u64("write_subscribe.producer_actor")? as usize);
            let n = r.u32("write_subscribe.partitions")? as usize;
            let mut partitions = Vec::with_capacity(n.min(4096));
            for _ in 0..n {
                partitions.push(PartitionId(r.u64("write_subscribe.partition")? as usize));
            }
            let objects = r.u64("write_subscribe.objects")? as usize;
            let object_bytes = r.u64("write_subscribe.object_bytes")?;
            Ok(RpcKind::WriteSubscribe {
                producer: WriteProducerSpec { producer_actor, partitions, objects, object_bytes },
            })
        }
        K_COMMIT_CHECKPOINT => Ok(RpcKind::CommitCheckpoint {
            epoch: r.u64("commit.epoch")?,
            cursors: decode_assignments(r, "commit.cursors")?,
        }),
        K_SEAL_OBJECT => Ok(RpcKind::SealObject {
            id: ObjectId {
                sub: SubId(r.u64("seal.sub")? as usize),
                slot: r.u64("seal.slot")? as usize,
            },
            produced_at: decode_opt_time(r, "seal.produced_at")?,
        }),
        K_REPLICATE => Ok(RpcKind::Replicate {
            bytes: r.u64("replicate.bytes")?,
            chunks: r.u32("replicate.chunks")?,
        }),
        K_SHARD_REPLICATE => {
            let n = r.u32("shard_replicate.chunks")? as usize;
            let mut chunks = Vec::with_capacity(n.min(4096));
            for _ in 0..n {
                let partition = PartitionId(r.u64("shard_replicate.partition")? as usize);
                let offset = r.u64("shard_replicate.offset")?;
                chunks.push(StampedChunk { partition, offset, chunk: decode_chunk(r)? });
            }
            let origin = match r.u8("shard_replicate.origin tag")? {
                0 => None,
                1 => Some((
                    ActorId(r.u64("shard_replicate.origin_actor")? as usize),
                    r.u64("shard_replicate.origin_rpc")?,
                )),
                t => return Err(FrameError::UnknownTag { what: "origin", tag: t }),
            };
            Ok(RpcKind::ShardReplicate { chunks, origin })
        }
        K_SHARD_FREEZE => Ok(RpcKind::ShardFreeze {
            epoch: r.u64("shard_freeze.epoch")?,
            partitions: decode_partitions(r, "shard_freeze.partitions")?,
        }),
        K_SHARD_PROMOTE => Ok(RpcKind::ShardPromote {
            epoch: r.u64("shard_promote.epoch")?,
            partitions: decode_partitions(r, "shard_promote.partitions")?,
        }),
        K_HEARTBEAT => Ok(RpcKind::Heartbeat),
        K_SHARD_FAILOVER => Ok(RpcKind::ShardFailover {
            epoch: r.u64("shard_failover.epoch")?,
            dead: r.u64("shard_failover.dead")? as usize,
            table: decode_shard_table(r)?,
            gained: decode_partitions(r, "shard_failover.gained")?,
        }),
        t => Err(FrameError::UnknownTag { what: "kind", tag: t }),
    }
}

fn encode_reply(out: &mut Vec<u8>, reply: &RpcReply) {
    match reply {
        RpcReply::AppendAck { records, bytes } => {
            put_u8(out, R_APPEND_ACK);
            put_u64(out, *records);
            put_u64(out, *bytes);
        }
        RpcReply::PullData { chunks, trims } => {
            put_u8(out, R_PULL_DATA);
            put_u32(out, chunks.len() as u32);
            for sc in chunks {
                put_u64(out, sc.partition.0 as u64);
                put_u64(out, sc.offset);
                encode_chunk(out, &sc.chunk);
            }
            encode_assignments(out, trims);
        }
        RpcReply::SubscribeAck { sub } => {
            put_u8(out, R_SUBSCRIBE_ACK);
            put_u64(out, sub.0 as u64);
        }
        RpcReply::UnsubscribeAck { sub, cursors } => {
            put_u8(out, R_UNSUBSCRIBE_ACK);
            put_u64(out, sub.0 as u64);
            encode_assignments(out, cursors);
        }
        RpcReply::WriteSubscribeAck { sub } => {
            put_u8(out, R_WRITE_SUBSCRIBE_ACK);
            put_u64(out, sub.0 as u64);
        }
        RpcReply::SealAck { records, bytes } => {
            put_u8(out, R_SEAL_ACK);
            put_u64(out, *records);
            put_u64(out, *bytes);
        }
        RpcReply::ReplicateAck => put_u8(out, R_REPLICATE_ACK),
        RpcReply::CommitAck { epoch } => {
            put_u8(out, R_COMMIT_ACK);
            put_u64(out, *epoch);
        }
        RpcReply::Error { reason } => {
            put_u8(out, R_ERROR);
            put_len_bytes(out, reason.as_bytes());
        }
        RpcReply::WrongShard { epoch } => {
            put_u8(out, R_WRONG_SHARD);
            put_u64(out, *epoch);
        }
        RpcReply::FreezeAck { epoch } => {
            put_u8(out, R_FREEZE_ACK);
            put_u64(out, *epoch);
        }
        RpcReply::PromoteAck { epoch } => {
            put_u8(out, R_PROMOTE_ACK);
            put_u64(out, *epoch);
        }
        RpcReply::HeartbeatAck { epoch } => {
            put_u8(out, R_HEARTBEAT_ACK);
            put_u64(out, *epoch);
        }
        RpcReply::FailoverAck { epoch } => {
            put_u8(out, R_FAILOVER_ACK);
            put_u64(out, *epoch);
        }
    }
}

fn decode_reply(r: &mut FrameReader<'_>) -> Result<RpcReply, FrameError> {
    match r.u8("reply tag")? {
        R_APPEND_ACK => Ok(RpcReply::AppendAck {
            records: r.u64("append_ack.records")?,
            bytes: r.u64("append_ack.bytes")?,
        }),
        R_PULL_DATA => {
            let n = r.u32("pull_data.chunks")? as usize;
            let mut chunks = Vec::with_capacity(n.min(4096));
            for _ in 0..n {
                let partition = PartitionId(r.u64("pull_data.partition")? as usize);
                let offset = r.u64("pull_data.offset")?;
                chunks.push(StampedChunk { partition, offset, chunk: decode_chunk(r)? });
            }
            let trims = decode_assignments(r, "pull_data.trims")?;
            Ok(RpcReply::PullData { chunks, trims })
        }
        R_SUBSCRIBE_ACK => {
            Ok(RpcReply::SubscribeAck { sub: SubId(r.u64("subscribe_ack.sub")? as usize) })
        }
        R_UNSUBSCRIBE_ACK => Ok(RpcReply::UnsubscribeAck {
            sub: SubId(r.u64("unsubscribe_ack.sub")? as usize),
            cursors: decode_assignments(r, "unsubscribe_ack.cursors")?,
        }),
        R_WRITE_SUBSCRIBE_ACK => Ok(RpcReply::WriteSubscribeAck {
            sub: SubId(r.u64("write_subscribe_ack.sub")? as usize),
        }),
        R_SEAL_ACK => Ok(RpcReply::SealAck {
            records: r.u64("seal_ack.records")?,
            bytes: r.u64("seal_ack.bytes")?,
        }),
        R_REPLICATE_ACK => Ok(RpcReply::ReplicateAck),
        R_COMMIT_ACK => Ok(RpcReply::CommitAck { epoch: r.u64("commit_ack.epoch")? }),
        R_ERROR => {
            let reason = String::from_utf8_lossy(r.len_bytes("error.reason")?).into_owned();
            Ok(RpcReply::Error { reason })
        }
        R_WRONG_SHARD => Ok(RpcReply::WrongShard { epoch: r.u64("wrong_shard.epoch")? }),
        R_FREEZE_ACK => Ok(RpcReply::FreezeAck { epoch: r.u64("freeze_ack.epoch")? }),
        R_PROMOTE_ACK => Ok(RpcReply::PromoteAck { epoch: r.u64("promote_ack.epoch")? }),
        R_HEARTBEAT_ACK => Ok(RpcReply::HeartbeatAck { epoch: r.u64("heartbeat_ack.epoch")? }),
        R_FAILOVER_ACK => Ok(RpcReply::FailoverAck { epoch: r.u64("failover_ack.epoch")? }),
        t => Err(FrameError::UnknownTag { what: "reply", tag: t }),
    }
}

/// A human-readable label for event logs (the broker server mode's
/// structured output names each message it handles).
pub fn msg_label(msg: &WireMsg) -> &'static str {
    match msg {
        WireMsg::Hello { .. } => "hello",
        WireMsg::Req { kind, .. } => match kind {
            RpcKind::Append { .. } => "append",
            RpcKind::Pull { .. } => "pull",
            RpcKind::PushSubscribe { .. } => "push_subscribe",
            RpcKind::PushUnsubscribe { .. } => "push_unsubscribe",
            RpcKind::WriteSubscribe { .. } => "write_subscribe",
            RpcKind::CommitCheckpoint { .. } => "commit_checkpoint",
            RpcKind::SealObject { .. } => "seal_object",
            RpcKind::Replicate { .. } => "replicate",
            RpcKind::ShardReplicate { .. } => "shard_replicate",
            RpcKind::ShardFreeze { .. } => "shard_freeze",
            RpcKind::ShardPromote { .. } => "shard_promote",
            RpcKind::Heartbeat => "heartbeat",
            RpcKind::ShardFailover { .. } => "shard_failover",
        },
        WireMsg::Rep { reply, .. } => match reply {
            RpcReply::AppendAck { .. } => "append_ack",
            RpcReply::PullData { .. } => "pull_data",
            RpcReply::SubscribeAck { .. } => "subscribe_ack",
            RpcReply::UnsubscribeAck { .. } => "unsubscribe_ack",
            RpcReply::WriteSubscribeAck { .. } => "write_subscribe_ack",
            RpcReply::SealAck { .. } => "seal_ack",
            RpcReply::ReplicateAck => "replicate_ack",
            RpcReply::CommitAck { .. } => "commit_ack",
            RpcReply::Error { .. } => "error",
            RpcReply::WrongShard { .. } => "wrong_shard",
            RpcReply::FreezeAck { .. } => "freeze_ack",
            RpcReply::PromoteAck { .. } => "promote_ack",
            RpcReply::HeartbeatAck { .. } => "heartbeat_ack",
            RpcReply::FailoverAck { .. } => "failover_ack",
        },
        WireMsg::Evt { .. } => "object_ready",
        WireMsg::Shutdown => "shutdown",
        WireMsg::Bye { .. } => "bye",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::real_payload_allocs;
    use crate::sim::ActorId;

    fn roundtrip(msg: &WireMsg) -> WireMsg {
        decode_msg(&encode_msg(msg)).expect("roundtrip decode")
    }

    #[test]
    fn hello_shutdown_bye_roundtrip() {
        match roundtrip(&WireMsg::Hello { version: WIRE_VERSION, node: 1, cookie: 0xC0FFEE }) {
            WireMsg::Hello { version, node, cookie } => {
                assert_eq!((version, node, cookie), (WIRE_VERSION, 1, 0xC0FFEE));
            }
            other => panic!("{other:?}"),
        }
        assert!(matches!(roundtrip(&WireMsg::Shutdown), WireMsg::Shutdown));
        match roundtrip(&WireMsg::Bye { replies_sent: 42 }) {
            WireMsg::Bye { replies_sent } => assert_eq!(replies_sent, 42),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn evt_roundtrip() {
        match roundtrip(&WireMsg::Evt { event: WireEvent::ObjectReady { sub: 3, slot: 9 } }) {
            WireMsg::Evt { event } => {
                assert_eq!(event, WireEvent::ObjectReady { sub: 3, slot: 9 });
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn append_real_payload_roundtrips_and_counts_one_materialisation() {
        let data: Vec<u8> = (0..200u32).map(|i| (i % 251) as u8).collect();
        let kind = RpcKind::Append {
            chunks: vec![(PartitionId(2), Chunk::real(2, 100, Rc::new(data.clone())))],
            produced_at: Some(12_345),
        };
        let before = real_payload_allocs();
        let msg = roundtrip(&WireMsg::Req { wire_id: 7, from_node: 1, kind });
        assert_eq!(real_payload_allocs(), before + 1, "decode materialises exactly once");
        let WireMsg::Req { wire_id, from_node, kind } = msg else { panic!() };
        assert_eq!((wire_id, from_node), (7, 1));
        let RpcKind::Append { chunks, produced_at } = kind else { panic!() };
        assert_eq!(produced_at, Some(12_345));
        assert_eq!(chunks.len(), 1);
        assert_eq!(chunks[0].0, PartitionId(2));
        assert_eq!(chunks[0].1.records, 2);
        assert_eq!(chunks[0].1.payload.buffer().unwrap().as_slice(), data.as_slice());
    }

    #[test]
    fn append_sim_payload_stays_sim_and_counts_nothing() {
        let kind = RpcKind::Append {
            chunks: vec![(PartitionId(0), Chunk::sim(160, 100))],
            produced_at: None,
        };
        let before = real_payload_allocs();
        let msg = roundtrip(&WireMsg::Req { wire_id: 1, from_node: 1, kind });
        assert_eq!(real_payload_allocs(), before, "sim payloads never materialise");
        let WireMsg::Req { kind: RpcKind::Append { chunks, produced_at }, .. } = msg else {
            panic!()
        };
        assert_eq!(produced_at, None);
        assert!(matches!(chunks[0].1.payload, Payload::Sim));
        assert_eq!(chunks[0].1.records, 160);
    }

    #[test]
    fn pull_and_pull_data_roundtrip() {
        let req = WireMsg::Req {
            wire_id: 9,
            from_node: 0,
            kind: RpcKind::Pull {
                assignments: vec![(PartitionId(0), 5), (PartitionId(3), 0)],
                max_bytes: 1 << 17,
            },
        };
        let WireMsg::Req { kind: RpcKind::Pull { assignments, max_bytes }, .. } = roundtrip(&req)
        else {
            panic!()
        };
        assert_eq!(assignments, vec![(PartitionId(0), 5), (PartitionId(3), 0)]);
        assert_eq!(max_bytes, 1 << 17);

        let rep = WireMsg::Rep {
            wire_id: 9,
            reply: RpcReply::PullData {
                chunks: vec![StampedChunk {
                    partition: PartitionId(3),
                    offset: 11,
                    chunk: Chunk::sim(4, 25),
                }],
                trims: vec![(PartitionId(0), 7)],
            },
        };
        let WireMsg::Rep { wire_id, reply: RpcReply::PullData { chunks, trims } } = roundtrip(&rep)
        else {
            panic!()
        };
        assert_eq!(wire_id, 9);
        assert_eq!(chunks.len(), 1);
        assert_eq!((chunks[0].partition, chunks[0].offset), (PartitionId(3), 11));
        assert_eq!(trims, vec![(PartitionId(0), 7)]);
    }

    #[test]
    fn subscribe_specs_roundtrip() {
        let req = WireMsg::Req {
            wire_id: 2,
            from_node: 0,
            kind: RpcKind::PushSubscribe {
                sources: vec![PushSourceSpec {
                    source_actor: ActorId(12),
                    assignments: vec![(PartitionId(1), 3)],
                    objects: 4,
                    object_bytes: 1 << 16,
                }],
            },
        };
        let WireMsg::Req { kind: RpcKind::PushSubscribe { sources }, .. } = roundtrip(&req) else {
            panic!()
        };
        assert_eq!(sources.len(), 1);
        assert_eq!(sources[0].source_actor, ActorId(12));
        assert_eq!(sources[0].assignments, vec![(PartitionId(1), 3)]);
        assert_eq!((sources[0].objects, sources[0].object_bytes), (4, 1 << 16));

        let req = WireMsg::Req {
            wire_id: 3,
            from_node: 1,
            kind: RpcKind::WriteSubscribe {
                producer: WriteProducerSpec {
                    producer_actor: ActorId(5),
                    partitions: vec![PartitionId(0), PartitionId(1)],
                    objects: 2,
                    object_bytes: 4096,
                },
            },
        };
        let WireMsg::Req { kind: RpcKind::WriteSubscribe { producer }, .. } = roundtrip(&req)
        else {
            panic!()
        };
        assert_eq!(producer.producer_actor, ActorId(5));
        assert_eq!(producer.partitions, vec![PartitionId(0), PartitionId(1)]);
    }

    #[test]
    fn remaining_kinds_and_replies_roundtrip() {
        let kinds = [
            RpcKind::PushUnsubscribe { sub: SubId(4) },
            RpcKind::CommitCheckpoint { epoch: 8, cursors: vec![(PartitionId(2), 20)] },
            RpcKind::SealObject { id: ObjectId { sub: SubId(1), slot: 3 }, produced_at: None },
            RpcKind::Replicate { bytes: 4096, chunks: 4 },
            RpcKind::ShardReplicate {
                chunks: vec![StampedChunk {
                    partition: PartitionId(5),
                    offset: 17,
                    chunk: Chunk::sim(8, 64),
                }],
                origin: Some((ActorId(42), 99)),
            },
            RpcKind::ShardFreeze { epoch: 2, partitions: vec![PartitionId(0), PartitionId(1)] },
            RpcKind::ShardPromote { epoch: 2, partitions: vec![PartitionId(0)] },
            RpcKind::Heartbeat,
            RpcKind::ShardFailover {
                epoch: 3,
                dead: 1,
                table: crate::shard::ShardTable::build(4, 2, 2, 7).failed_over(1),
                gained: vec![PartitionId(2), PartitionId(3)],
            },
        ];
        for kind in kinds {
            let label_before = msg_label(&WireMsg::Req {
                wire_id: 0,
                from_node: 0,
                kind: kind.clone(),
            });
            let WireMsg::Req { kind: back, .. } =
                roundtrip(&WireMsg::Req { wire_id: 0, from_node: 0, kind })
            else {
                panic!()
            };
            let label_after = msg_label(&WireMsg::Req { wire_id: 0, from_node: 0, kind: back });
            assert_eq!(label_before, label_after);
        }
        let replies = [
            RpcReply::AppendAck { records: 10, bytes: 1000 },
            RpcReply::SubscribeAck { sub: SubId(0) },
            RpcReply::UnsubscribeAck { sub: SubId(0), cursors: vec![(PartitionId(0), 1)] },
            RpcReply::WriteSubscribeAck { sub: SubId(2) },
            RpcReply::SealAck { records: 5, bytes: 500 },
            RpcReply::ReplicateAck,
            RpcReply::CommitAck { epoch: 3 },
            RpcReply::Error { reason: "object p0 is not sealed".into() },
            RpcReply::WrongShard { epoch: 4 },
            RpcReply::FreezeAck { epoch: 4 },
            RpcReply::PromoteAck { epoch: 4 },
            RpcReply::HeartbeatAck { epoch: 4 },
            RpcReply::FailoverAck { epoch: 5 },
        ];
        for reply in replies {
            let before = msg_label(&WireMsg::Rep { wire_id: 1, reply: reply.clone() });
            let WireMsg::Rep { reply: back, .. } =
                roundtrip(&WireMsg::Rep { wire_id: 1, reply })
            else {
                panic!()
            };
            assert_eq!(before, msg_label(&WireMsg::Rep { wire_id: 1, reply: back }));
        }
    }

    #[test]
    fn shard_failover_table_survives_the_wire() {
        let table = crate::shard::ShardTable::build(6, 3, 2, 0xBEEF).failed_over(2);
        let req = WireMsg::Req {
            wire_id: 4,
            from_node: 0,
            kind: RpcKind::ShardFailover {
                epoch: table.epoch,
                dead: 2,
                table: table.clone(),
                gained: vec![PartitionId(4)],
            },
        };
        let WireMsg::Req { kind: RpcKind::ShardFailover { epoch, dead, table: back, gained }, .. } =
            roundtrip(&req)
        else {
            panic!()
        };
        assert_eq!((epoch, dead), (table.epoch, 2));
        assert_eq!(back, table, "ragged post-fail-over rows decode identically");
        assert_eq!(gained, vec![PartitionId(4)]);
    }

    #[test]
    fn error_reason_text_survives() {
        let WireMsg::Rep { reply: RpcReply::Error { reason }, .. } = roundtrip(&WireMsg::Rep {
            wire_id: 1,
            reply: RpcReply::Error { reason: "unknown partition p9".into() },
        }) else {
            panic!()
        };
        assert_eq!(reason, "unknown partition p9");
    }

    #[test]
    fn truncated_body_is_typed_not_panic() {
        let full = encode_msg(&WireMsg::Req {
            wire_id: 1,
            from_node: 0,
            kind: RpcKind::Pull { assignments: vec![(PartitionId(0), 0)], max_bytes: 64 },
        });
        // Chop the body at every prefix length: decode must return a typed
        // error (or succeed only on the full body), never panic.
        for cut in 0..full.len() {
            match decode_msg(&full[..cut]) {
                Err(FrameError::Truncated { .. }) | Err(FrameError::UnknownTag { .. }) => {}
                Ok(_) => panic!("decode succeeded on truncated body (cut {cut})"),
                Err(e) => panic!("unexpected error {e:?}"),
            }
        }
        assert!(decode_msg(&full).is_ok());
    }

    #[test]
    fn unknown_message_tag_is_typed() {
        assert!(matches!(
            decode_msg(&[250]),
            Err(FrameError::UnknownTag { what: "message", tag: 250 })
        ));
        assert!(matches!(decode_msg(&[]), Err(FrameError::Truncated { what: "message tag" })));
    }
}
