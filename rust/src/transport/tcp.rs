//! Real transport: localhost TCP with per-connection reader/writer threads.
//!
//! Thread model (per [`TcpTransport`] endpoint):
//!
//! * the **owner thread** calls [`TcpTransport::poll`] / `send` — it is the
//!   only place [`WireMsg`]s exist (they hold `Rc`s and are not `Send`;
//!   only encoded byte buffers cross threads);
//! * one **reader thread** per connection: blocking reads into a
//!   [`FrameDecoder`], complete frame *bodies* (raw `Vec<u8>`) go to the
//!   owner's unbounded inbox. With an idle deadline armed
//!   ([`TcpTransport::set_idle_timeout_ms`]) the reads are poll-based
//!   instead, so a stream that stalls *mid-frame* past the deadline is
//!   closed with a typed [`FrameError::IdleTimeout`] — the wire-level
//!   analogue of the sim plane's broker failure detector. Unbounded
//!   inbox on purpose — the reader never
//!   stalls, so kernel receive buffers always drain and a peer's writer
//!   can never deadlock against ours (the protocols above are
//!   request/reply or credit-windowed, bounding what a peer can have in
//!   flight);
//! * one **writer thread** per connection: drains a **bounded**
//!   `sync_channel` of encoded frames into `write_all` — the bound is the
//!   send-side backpressure the trait contract documents.
//!
//! Shutdown: closing a connection drops its writer channel — the writer
//! finishes its queue, then sends the FIN itself, so queued frames always
//! reach the wire — and shuts the read half down (the blocking reader
//! wakes with EOF). [`TcpTransport::shutdown`] closes everything and joins
//! every thread it ever spawned, returning the accounting a
//! no-thread-leak test can assert on.

use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TryRecvError};
use std::thread::JoinHandle;
use std::time::Duration;

use super::frame::{encode_frame, FrameDecoder, FrameError};
use super::wire::{decode_msg, encode_msg, WireMsg};
use super::{ConnId, Transport, TransportEvent};

/// Encoded frames queued per connection before `send` blocks (the bounded
/// write window).
const WRITE_QUEUE_FRAMES: usize = 64;

/// Thread accounting returned by [`TcpTransport::shutdown`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ThreadReport {
    pub spawned: usize,
    pub joined: usize,
}

/// What reader threads push to the owner (bytes only — never a decoded
/// message, which would not be `Send`).
enum Inbound {
    Frame { conn: ConnId, body: Vec<u8> },
    Closed { conn: ConnId, error: Option<FrameError> },
}

struct TcpConn {
    /// Encoded frames to the writer thread; dropping it closes the writer.
    writer_tx: Option<SyncSender<Vec<u8>>>,
    /// Own handle for `shutdown(2)` (reader/writer hold clones).
    stream: TcpStream,
}

/// The TCP implementation of the transport seam. See the module docs for
/// the thread model and `super` for the ordering contract.
pub struct TcpTransport {
    listener: Option<TcpListener>,
    conns: HashMap<ConnId, TcpConn>,
    next_conn: ConnId,
    inbox_rx: Receiver<Inbound>,
    inbox_tx: Sender<Inbound>,
    threads: Vec<JoinHandle<()>>,
    /// Connections whose `Closed` event has been delivered (guards the
    /// exactly-once contract when a reader error races a local close).
    closed_delivered: HashMap<ConnId, bool>,
    /// Reader idle deadline (ms) applied to connections registered after
    /// it is set; 0 = blocking reads with no deadline. See
    /// [`TcpTransport::set_idle_timeout_ms`].
    idle_timeout_ms: u64,
}

impl TcpTransport {
    /// A connect-only endpoint (no listener).
    pub fn client() -> Self {
        let (inbox_tx, inbox_rx) = channel();
        TcpTransport {
            listener: None,
            conns: HashMap::new(),
            next_conn: 0,
            inbox_rx,
            inbox_tx,
            threads: Vec::new(),
            closed_delivered: HashMap::new(),
            idle_timeout_ms: 0,
        }
    }

    /// Arm an idle deadline on the readers of connections opened from now
    /// on: a stream that stalls *mid-frame* (partial frame buffered, no
    /// new bytes) for longer than `ms` is closed with a typed
    /// [`FrameError::IdleTimeout`] — the reader's analogue of a dead
    /// broker's silence. Silence between frames never trips it: an idle
    /// but healthy peer owes us nothing. `0` restores plain blocking
    /// reads.
    pub fn set_idle_timeout_ms(&mut self, ms: u64) {
        self.idle_timeout_ms = ms;
    }

    /// An accepting endpoint bound to `addr` (use port 0 for ephemeral;
    /// read the outcome back via [`TcpTransport::local_addr`]).
    pub fn listen(addr: &str) -> Result<Self, FrameError> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let mut t = Self::client();
        t.listener = Some(listener);
        Ok(t)
    }

    /// Threads spawned so far (readers + writers).
    pub fn threads_spawned(&self) -> usize {
        self.threads.len()
    }

    fn register(&mut self, stream: TcpStream) -> Result<ConnId, FrameError> {
        stream.set_nodelay(true)?;
        let conn = self.next_conn;
        self.next_conn += 1;

        let read_stream = stream.try_clone()?;
        let write_stream = stream.try_clone()?;
        let inbox = self.inbox_tx.clone();
        let idle_ms = self.idle_timeout_ms;
        let (writer_tx, writer_rx) = sync_channel::<Vec<u8>>(WRITE_QUEUE_FRAMES);

        self.threads.push(
            std::thread::Builder::new()
                .name(format!("zs-read-{conn}"))
                .spawn(move || reader_main(conn, read_stream, inbox, idle_ms))
                .map_err(|e| FrameError::Io(e.to_string()))?,
        );
        self.threads.push(
            std::thread::Builder::new()
                .name(format!("zs-write-{conn}"))
                .spawn(move || writer_main(write_stream, writer_rx))
                .map_err(|e| FrameError::Io(e.to_string()))?,
        );

        self.conns.insert(conn, TcpConn { writer_tx: Some(writer_tx), stream });
        self.closed_delivered.insert(conn, false);
        Ok(conn)
    }

    fn accept_pending(&mut self, out: &mut Vec<TransportEvent>) {
        loop {
            let accepted = match &self.listener {
                Some(l) => match l.accept() {
                    Ok((stream, _peer)) => Some(stream),
                    Err(e) if e.kind() == ErrorKind::WouldBlock => None,
                    Err(_) => None,
                },
                None => None,
            };
            match accepted {
                Some(stream) => match self.register(stream) {
                    Ok(conn) => out.push(TransportEvent::Accepted { conn }),
                    Err(_) => {}
                },
                None => break,
            }
        }
    }

    fn inbound_to_event(&mut self, inb: Inbound) -> Option<TransportEvent> {
        match inb {
            Inbound::Frame { conn, body } => match decode_msg(&body) {
                Ok(msg) => Some(TransportEvent::Frame { conn, msg }),
                // A protocol violation kills exactly that connection,
                // surfacing as its (typed) Closed event.
                Err(e) => {
                    self.close_conn(conn);
                    self.deliver_closed(conn, Some(e))
                }
            },
            Inbound::Closed { conn, error } => self.deliver_closed(conn, error),
        }
    }

    fn deliver_closed(&mut self, conn: ConnId, error: Option<FrameError>) -> Option<TransportEvent> {
        match self.closed_delivered.get_mut(&conn) {
            Some(done) if !*done => {
                *done = true;
                Some(TransportEvent::Closed { conn, error })
            }
            _ => None,
        }
    }

    /// Close every connection, stop listening, and join every thread this
    /// endpoint ever spawned. The report's `spawned == joined` is the
    /// no-thread-leak invariant tests assert.
    pub fn shutdown(mut self) -> ThreadReport {
        self.listener = None;
        let ids: Vec<ConnId> = self.conns.keys().copied().collect();
        for conn in ids {
            self.close_conn(conn);
        }
        let spawned = self.threads.len();
        let mut joined = 0;
        for h in self.threads.drain(..) {
            if h.join().is_ok() {
                joined += 1;
            }
        }
        ThreadReport { spawned, joined }
    }
}

impl Transport for TcpTransport {
    fn connect(&mut self, addr: &str) -> Result<ConnId, FrameError> {
        let stream = TcpStream::connect(addr)?;
        self.register(stream)
    }

    fn send(&mut self, conn: ConnId, msg: &WireMsg) -> Result<(), FrameError> {
        let c = self.conns.get(&conn).ok_or(FrameError::Closed)?;
        let tx = c.writer_tx.as_ref().ok_or(FrameError::Closed)?;
        let framed = encode_frame(&encode_msg(msg));
        // Blocks when WRITE_QUEUE_FRAMES are already queued: this is the
        // documented send-side backpressure.
        tx.send(framed).map_err(|_| FrameError::Closed)
    }

    fn poll(&mut self, max_wait_ms: u64) -> Vec<TransportEvent> {
        let mut out = Vec::new();
        self.accept_pending(&mut out);

        // Wait (in short slices, so new connections keep being accepted)
        // for the first inbound item, then drain without waiting.
        if out.is_empty() && max_wait_ms > 0 {
            let mut waited = 0;
            while waited < max_wait_ms {
                let slice = (max_wait_ms - waited).min(5);
                match self.inbox_rx.recv_timeout(Duration::from_millis(slice)) {
                    Ok(inb) => {
                        if let Some(ev) = self.inbound_to_event(inb) {
                            out.push(ev);
                        }
                        break;
                    }
                    Err(_) => {
                        waited += slice;
                        self.accept_pending(&mut out);
                        if !out.is_empty() {
                            break;
                        }
                    }
                }
            }
        }
        loop {
            match self.inbox_rx.try_recv() {
                Ok(inb) => {
                    if let Some(ev) = self.inbound_to_event(inb) {
                        out.push(ev);
                    }
                }
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        self.accept_pending(&mut out);
        out
    }

    fn close_conn(&mut self, conn: ConnId) {
        if let Some(c) = self.conns.get_mut(&conn) {
            // Writer: channel drop ends it after the queue drains; the
            // writer sends the FIN itself once everything is flushed, so a
            // close can never cut off frames already handed to `send`
            // (e.g. the graceful-shutdown `Bye`).
            c.writer_tx = None;
            // Reader: shutting down only the read half wakes its blocking
            // read with EOF without touching the in-flight write queue.
            let _ = c.stream.shutdown(std::net::Shutdown::Read);
        }
        self.conns.remove(&conn);
    }

    fn local_addr(&self) -> Option<String> {
        self.listener.as_ref().and_then(|l| l.local_addr().ok()).map(|a| a.to_string())
    }
}

fn reader_main(conn: ConnId, mut stream: TcpStream, inbox: Sender<Inbound>, idle_ms: u64) {
    let mut decoder = FrameDecoder::new();
    let mut buf = [0u8; 64 * 1024];
    // With an idle deadline armed, reads wake periodically (poll-based)
    // so a mid-frame stall can be noticed; without one they block forever,
    // exactly as before.
    if idle_ms > 0 {
        let _ = stream.set_read_timeout(Some(Duration::from_millis(idle_ms.clamp(1, 50))));
    }
    // When the stall clock started: set on the first timed-out read with a
    // partial frame buffered, cleared whenever bytes arrive.
    let mut stalled_since: Option<std::time::Instant> = None;
    loop {
        match stream.read(&mut buf) {
            Ok(0) => {
                // Clean EOF — an error only if it lands mid-frame.
                let _ = inbox.send(Inbound::Closed { conn, error: decoder.finish().err() });
                return;
            }
            Ok(n) => {
                stalled_since = None;
                decoder.push(&buf[..n]);
                loop {
                    match decoder.next_frame() {
                        Ok(Some(body)) => {
                            if inbox.send(Inbound::Frame { conn, body }).is_err() {
                                return; // owner gone
                            }
                        }
                        Ok(None) => break,
                        Err(e) => {
                            let _ = inbox.send(Inbound::Closed { conn, error: Some(e) });
                            let _ = stream.shutdown(std::net::Shutdown::Both);
                            return;
                        }
                    }
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e)
                if idle_ms > 0
                    && matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) =>
            {
                // The poll slice expired. Only a *partial frame* left
                // waiting counts as a stall — silence between frames is an
                // idle peer, not a dead one.
                if decoder.buffered() == 0 {
                    stalled_since = None;
                    continue;
                }
                let t0 = *stalled_since.get_or_insert_with(std::time::Instant::now);
                if t0.elapsed() >= Duration::from_millis(idle_ms) {
                    let _ = inbox.send(Inbound::Closed {
                        conn,
                        error: Some(FrameError::IdleTimeout { ms: idle_ms }),
                    });
                    let _ = stream.shutdown(std::net::Shutdown::Both);
                    return;
                }
            }
            Err(e) => {
                // A local close (shutdown(2) racing the blocking read)
                // surfaces as ConnectionReset/NotConnected — report it as
                // a plain close, not a failure.
                let error = match e.kind() {
                    ErrorKind::ConnectionReset
                    | ErrorKind::ConnectionAborted
                    | ErrorKind::NotConnected => None,
                    _ => Some(FrameError::Io(e.to_string())),
                };
                let _ = inbox.send(Inbound::Closed { conn, error });
                return;
            }
        }
    }
}

fn writer_main(mut stream: TcpStream, rx: Receiver<Vec<u8>>) {
    // Drain until the owner drops the sender; any write error ends the
    // thread (the peer's reader reports the broken stream on its side).
    while let Ok(framed) = rx.recv() {
        if stream.write_all(&framed).is_err() {
            // Keep draining so a blocked `send` on the owner side cannot
            // wedge; bytes go nowhere.
            while rx.recv().is_ok() {}
            return;
        }
    }
    let _ = stream.flush();
    // The owner dropped the sender (graceful close): everything queued is
    // on the wire — send the FIN so the peer observes a clean EOF at a
    // frame boundary.
    let _ = stream.shutdown(std::net::Shutdown::Write);
}
