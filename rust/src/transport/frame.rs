//! Length-prefixed frame codec for the real-plane wire protocol.
//!
//! A frame on the wire is `[u32 LE body-length][body]`; the body's first
//! byte is the message tag (see [`super::wire`]). The codec is hand-rolled
//! on purpose — no serde, no derive magic — so every byte on the wire is
//! visible in this file and the decoder can be driven incrementally from
//! whatever read-buffer slicing the socket happens to produce.
//!
//! Error surface: every malformed input is a typed [`FrameError`], never a
//! panic. A torn frame (bytes missing at the current end of the stream) is
//! *not* an error while the connection is open — [`FrameDecoder::next_frame`]
//! returns `Ok(None)` and waits for more bytes; it becomes
//! [`FrameError::EofMidFrame`] only when [`FrameDecoder::finish`] is called
//! at connection end with bytes still buffered.

use std::fmt;

/// Hard cap on a single frame body. An `Append` carries at most a few
/// hundred KiB of chunk payload under any sane config; 64 MiB is far above
/// every legitimate frame and far below "attacker asked us to allocate
/// 4 GiB from a four-byte prefix".
pub const MAX_FRAME_BYTES: usize = 64 << 20;

/// Typed error surface of the transport layer (framing, body decode, and
/// socket-level failures). `PartialEq` so tests can assert exact variants.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameError {
    /// The length prefix exceeds the decoder's frame cap.
    Oversized { len: usize, max: usize },
    /// The body ended before the structure it declared (short body).
    /// `what` names the field that could not be read.
    Truncated { what: &'static str },
    /// An enum tag byte had no defined meaning. `what` names the enum.
    UnknownTag { what: &'static str, tag: u8 },
    /// The byte stream ended (clean EOF) in the middle of a frame —
    /// the peer dropped the connection mid-send.
    EofMidFrame { buffered: usize },
    /// The stream stalled mid-frame for longer than the reader's idle
    /// deadline: a partial frame sat in the decoder with no new bytes for
    /// `ms` milliseconds. A quiet connection *between* frames never
    /// triggers this — silence is only fatal once a frame has started.
    IdleTimeout { ms: u64 },
    /// Socket-level failure (connect/read/write).
    Io(String),
    /// The connection (or its writer thread) is already gone.
    Closed,
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::Oversized { len, max } => {
                write!(f, "frame length {len} exceeds cap {max}")
            }
            FrameError::Truncated { what } => write!(f, "frame body truncated reading {what}"),
            FrameError::UnknownTag { what, tag } => write!(f, "unknown {what} tag {tag}"),
            FrameError::EofMidFrame { buffered } => {
                write!(f, "stream ended mid-frame ({buffered} bytes buffered)")
            }
            FrameError::IdleTimeout { ms } => {
                write!(f, "stream stalled mid-frame past the {ms} ms idle deadline")
            }
            FrameError::Io(e) => write!(f, "transport i/o: {e}"),
            FrameError::Closed => write!(f, "connection closed"),
        }
    }
}

impl std::error::Error for FrameError {}

impl From<std::io::Error> for FrameError {
    fn from(e: std::io::Error) -> Self {
        FrameError::Io(e.to_string())
    }
}

/// Wrap a frame body with its `u32` little-endian length prefix.
pub fn encode_frame(body: &[u8]) -> Vec<u8> {
    debug_assert!(body.len() <= MAX_FRAME_BYTES);
    let mut out = Vec::with_capacity(4 + body.len());
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.extend_from_slice(body);
    out
}

/// Incremental frame reassembler. Feed it arbitrary byte slices as they
/// arrive off the socket; pull complete frame bodies out as they close.
#[derive(Debug, Default)]
pub struct FrameDecoder {
    buf: Vec<u8>,
    /// Consumed prefix of `buf`; compacted lazily so a long-lived
    /// connection does not shift bytes on every frame.
    start: usize,
    max: usize,
}

impl FrameDecoder {
    pub fn new() -> Self {
        Self::with_max(MAX_FRAME_BYTES)
    }

    /// A decoder with a custom frame cap (tests use tiny caps).
    pub fn with_max(max: usize) -> Self {
        FrameDecoder { buf: Vec::new(), start: 0, max }
    }

    /// Append bytes read from the stream.
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Next complete frame body, if one has fully arrived. `Ok(None)`
    /// means "keep reading" — a partial frame is not an error until EOF.
    pub fn next_frame(&mut self) -> Result<Option<Vec<u8>>, FrameError> {
        let avail = self.buf.len() - self.start;
        if avail < 4 {
            return Ok(None);
        }
        let p = self.start;
        let len =
            u32::from_le_bytes([self.buf[p], self.buf[p + 1], self.buf[p + 2], self.buf[p + 3]])
                as usize;
        if len > self.max {
            return Err(FrameError::Oversized { len, max: self.max });
        }
        if avail < 4 + len {
            return Ok(None);
        }
        let body = self.buf[p + 4..p + 4 + len].to_vec();
        self.start += 4 + len;
        // Compact once the dead prefix dominates the buffer.
        if self.start > 4096 && self.start * 2 >= self.buf.len() {
            self.buf.drain(..self.start);
            self.start = 0;
        }
        Ok(Some(body))
    }

    /// Bytes currently buffered but not yet returned as a frame.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.start
    }

    /// Declare end-of-stream. A clean close lands exactly on a frame
    /// boundary; anything still buffered means the peer died mid-frame.
    pub fn finish(&self) -> Result<(), FrameError> {
        match self.buffered() {
            0 => Ok(()),
            n => Err(FrameError::EofMidFrame { buffered: n }),
        }
    }
}

/// Cursor over a frame body for decoding. Every read is bounds-checked and
/// failure is a typed [`FrameError::Truncated`] naming the field.
pub struct FrameReader<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> FrameReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        FrameReader { buf, at: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.at
    }

    pub fn u8(&mut self, what: &'static str) -> Result<u8, FrameError> {
        if self.remaining() < 1 {
            return Err(FrameError::Truncated { what });
        }
        let v = self.buf[self.at];
        self.at += 1;
        Ok(v)
    }

    pub fn u32(&mut self, what: &'static str) -> Result<u32, FrameError> {
        if self.remaining() < 4 {
            return Err(FrameError::Truncated { what });
        }
        let p = self.at;
        self.at += 4;
        Ok(u32::from_le_bytes([self.buf[p], self.buf[p + 1], self.buf[p + 2], self.buf[p + 3]]))
    }

    pub fn u64(&mut self, what: &'static str) -> Result<u64, FrameError> {
        if self.remaining() < 8 {
            return Err(FrameError::Truncated { what });
        }
        let mut b = [0u8; 8];
        b.copy_from_slice(&self.buf[self.at..self.at + 8]);
        self.at += 8;
        Ok(u64::from_le_bytes(b))
    }

    pub fn bytes(&mut self, len: usize, what: &'static str) -> Result<&'a [u8], FrameError> {
        if self.remaining() < len {
            return Err(FrameError::Truncated { what });
        }
        let s = &self.buf[self.at..self.at + len];
        self.at += len;
        Ok(s)
    }

    /// A `u64` length immediately followed by that many bytes.
    pub fn len_bytes(&mut self, what: &'static str) -> Result<&'a [u8], FrameError> {
        let len = self.u64(what)? as usize;
        self.bytes(len, what)
    }
}

/// Body-encoding helpers mirroring [`FrameReader`].
pub fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// A `u64` length prefix followed by the bytes (pairs with
/// [`FrameReader::len_bytes`]).
pub fn put_len_bytes(out: &mut Vec<u8>, bytes: &[u8]) {
    put_u64(out, bytes.len() as u64);
    out.extend_from_slice(bytes);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(body: &[u8]) -> Vec<u8> {
        encode_frame(body)
    }

    #[test]
    fn single_frame_roundtrip() {
        let mut d = FrameDecoder::new();
        d.push(&frame(b"hello"));
        assert_eq!(d.next_frame().unwrap().as_deref(), Some(&b"hello"[..]));
        assert_eq!(d.next_frame().unwrap(), None);
        d.finish().unwrap();
    }

    #[test]
    fn empty_body_frame_is_legal() {
        let mut d = FrameDecoder::new();
        d.push(&frame(b""));
        assert_eq!(d.next_frame().unwrap().as_deref(), Some(&b""[..]));
        d.finish().unwrap();
    }

    /// The satellite's core property: a stream of frames split at EVERY
    /// byte position decodes to the same frame sequence. This covers
    /// partial length prefixes, torn bodies, and boundary-exact splits.
    #[test]
    fn torn_at_every_split_point() {
        let bodies: [&[u8]; 3] = [b"first", b"", b"third-frame-with-some-length"];
        let mut stream = Vec::new();
        for b in &bodies {
            stream.extend_from_slice(&frame(b));
        }
        for split in 0..=stream.len() {
            let mut d = FrameDecoder::new();
            let mut got: Vec<Vec<u8>> = Vec::new();
            d.push(&stream[..split]);
            while let Some(f) = d.next_frame().unwrap() {
                got.push(f);
            }
            d.push(&stream[split..]);
            while let Some(f) = d.next_frame().unwrap() {
                got.push(f);
            }
            let want: Vec<Vec<u8>> = bodies.iter().map(|b| b.to_vec()).collect();
            assert_eq!(got, want, "split at {split}");
            d.finish().unwrap();
        }
    }

    /// Same property with three-way splits across a longer stream, so
    /// multi-fragment reassembly (prefix split across three pushes) is
    /// exercised too.
    #[test]
    fn torn_three_way_splits() {
        let bodies: Vec<Vec<u8>> = (0..5u8).map(|i| vec![i; i as usize * 7]).collect();
        let mut stream = Vec::new();
        for b in &bodies {
            stream.extend_from_slice(&frame(b));
        }
        // Stride the first cut, sweep the second exhaustively.
        for a in (0..=stream.len()).step_by(3) {
            for b in (a..=stream.len()).step_by(5) {
                let mut d = FrameDecoder::new();
                let mut got = Vec::new();
                for part in [&stream[..a], &stream[a..b], &stream[b..]] {
                    d.push(part);
                    while let Some(f) = d.next_frame().unwrap() {
                        got.push(f);
                    }
                }
                assert_eq!(got, bodies, "splits at {a},{b}");
                d.finish().unwrap();
            }
        }
    }

    #[test]
    fn oversized_length_is_rejected_not_allocated() {
        let mut d = FrameDecoder::with_max(1024);
        let mut bytes = (4096u32).to_le_bytes().to_vec();
        bytes.extend_from_slice(b"xx");
        d.push(&bytes);
        assert_eq!(d.next_frame(), Err(FrameError::Oversized { len: 4096, max: 1024 }));
    }

    #[test]
    fn oversized_detected_from_prefix_alone() {
        // The cap triggers as soon as the 4-byte prefix is complete, long
        // before `len` bytes ever arrive.
        let mut d = FrameDecoder::with_max(16);
        d.push(&(u32::MAX).to_le_bytes());
        assert!(matches!(d.next_frame(), Err(FrameError::Oversized { .. })));
    }

    #[test]
    fn eof_mid_frame_is_typed_error() {
        let mut d = FrameDecoder::new();
        let full = frame(b"abcdef");
        d.push(&full[..full.len() - 2]);
        assert_eq!(d.next_frame().unwrap(), None);
        assert_eq!(d.finish(), Err(FrameError::EofMidFrame { buffered: full.len() - 2 }));
    }

    #[test]
    fn eof_mid_prefix_is_typed_error() {
        let mut d = FrameDecoder::new();
        d.push(&[1, 0]);
        assert_eq!(d.next_frame().unwrap(), None);
        assert_eq!(d.finish(), Err(FrameError::EofMidFrame { buffered: 2 }));
    }

    #[test]
    fn clean_eof_on_boundary_is_ok() {
        let mut d = FrameDecoder::new();
        d.push(&frame(b"x"));
        assert!(d.next_frame().unwrap().is_some());
        d.finish().unwrap();
    }

    #[test]
    fn compaction_keeps_decoding_correct() {
        // Push enough frames that the lazy compaction path runs, and
        // verify every body still comes back intact and in order.
        let mut d = FrameDecoder::new();
        let mut want = Vec::new();
        for i in 0..200u32 {
            let body = i.to_le_bytes().repeat(8);
            d.push(&frame(&body));
            want.push(body);
        }
        let mut got = Vec::new();
        while let Some(f) = d.next_frame().unwrap() {
            got.push(f);
        }
        assert_eq!(got, want);
        d.finish().unwrap();
    }

    #[test]
    fn reader_truncation_names_the_field() {
        let mut r = FrameReader::new(&[1, 2]);
        assert_eq!(r.u32("wire_id"), Err(FrameError::Truncated { what: "wire_id" }));
        let mut r = FrameReader::new(&[]);
        assert_eq!(r.u8("tag"), Err(FrameError::Truncated { what: "tag" }));
    }

    #[test]
    fn reader_len_bytes_roundtrip() {
        let mut out = Vec::new();
        put_len_bytes(&mut out, b"payload");
        put_u32(&mut out, 7);
        let mut r = FrameReader::new(&out);
        assert_eq!(r.len_bytes("payload").unwrap(), b"payload");
        assert_eq!(r.u32("tail").unwrap(), 7);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn reader_len_bytes_lying_length_is_truncated() {
        let mut out = Vec::new();
        put_u64(&mut out, 1 << 40); // declares a terabyte, supplies nothing
        let mut r = FrameReader::new(&out);
        assert_eq!(r.len_bytes("payload"), Err(FrameError::Truncated { what: "payload" }));
    }
}
