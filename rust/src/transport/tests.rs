//! Socket-level tests of the TCP transport against the trait contract:
//! connection lifecycle ordering, typed errors for torn streams and
//! protocol garbage, and the thread-accounting invariant behind the
//! graceful-shutdown satellite.

use std::io::Write;
use std::net::TcpStream;

use super::tcp::TcpTransport;
use super::wire::{WireMsg, WIRE_VERSION};
use super::{FrameError, Transport, TransportEvent};

fn hello(node: u32) -> WireMsg {
    WireMsg::Hello { version: WIRE_VERSION, node, cookie: 7 }
}

/// Poll `t` until `pred` picks an event or the deadline passes.
fn poll_for<T>(
    t: &mut TcpTransport,
    mut pred: impl FnMut(TransportEvent) -> Option<T>,
    what: &str,
) -> T {
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    while std::time::Instant::now() < deadline {
        for ev in t.poll(50) {
            if let Some(v) = pred(ev) {
                return v;
            }
        }
    }
    panic!("timed out waiting for {what}");
}

#[test]
fn accept_precedes_frames_and_fifo_holds() {
    let mut server = TcpTransport::listen("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let mut client = TcpTransport::client();
    let conn = client.connect(&addr).unwrap();
    for node in 0..20 {
        client.send(conn, &hello(node)).unwrap();
    }

    let mut accepted = false;
    let mut nodes = Vec::new();
    let sconn = poll_for(
        &mut server,
        |ev| match ev {
            TransportEvent::Accepted { .. } => {
                assert!(nodes.is_empty(), "Accepted must precede any Frame");
                accepted = true;
                None
            }
            TransportEvent::Frame { conn, msg: WireMsg::Hello { node, .. } } => {
                assert!(accepted, "frame before Accepted");
                nodes.push(node);
                (nodes.len() == 20).then_some(conn)
            }
            other => panic!("unexpected {other:?}"),
        },
        "20 hello frames",
    );
    assert_eq!(nodes, (0..20).collect::<Vec<_>>(), "per-connection FIFO");

    // Bidirectional: the server replies on the accepted conn.
    server.send(sconn, &WireMsg::Bye { replies_sent: 20 }).unwrap();
    let n = poll_for(
        &mut client,
        |ev| match ev {
            TransportEvent::Frame { msg: WireMsg::Bye { replies_sent }, .. } => Some(replies_sent),
            _ => None,
        },
        "bye",
    );
    assert_eq!(n, 20);

    let s = server.shutdown();
    assert_eq!(s.spawned, s.joined, "server leaked threads");
    let c = client.shutdown();
    assert_eq!(c.spawned, c.joined, "client leaked threads");
}

#[test]
fn peer_drop_mid_frame_is_typed_eof_not_panic() {
    let mut server = TcpTransport::listen("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();

    // A raw socket writes half a frame (valid prefix, torn body) and drops.
    let mut raw = TcpStream::connect(&addr).unwrap();
    raw.write_all(&(100u32).to_le_bytes()).unwrap();
    raw.write_all(&[1, 2, 3]).unwrap();
    drop(raw);

    let err = poll_for(
        &mut server,
        |ev| match ev {
            TransportEvent::Accepted { .. } => None,
            TransportEvent::Closed { error, .. } => Some(error),
            other => panic!("unexpected {other:?}"),
        },
        "closed event",
    );
    assert_eq!(err, Some(FrameError::EofMidFrame { buffered: 7 }));
    let s = server.shutdown();
    assert_eq!(s.spawned, s.joined);
}

#[test]
fn mid_frame_stall_times_out_with_typed_error() {
    let mut server = TcpTransport::listen("127.0.0.1:0").unwrap();
    server.set_idle_timeout_ms(100);
    let addr = server.local_addr().unwrap();

    // A raw socket starts a frame (valid prefix, torn body) and goes
    // silent WITHOUT dropping — the EOF path never fires; only the idle
    // deadline can reclaim the reader.
    let mut raw = TcpStream::connect(&addr).unwrap();
    raw.write_all(&(100u32).to_le_bytes()).unwrap();
    raw.write_all(&[9, 9, 9]).unwrap();

    let err = poll_for(
        &mut server,
        |ev| match ev {
            TransportEvent::Accepted { .. } => None,
            TransportEvent::Closed { error, .. } => Some(error),
            other => panic!("unexpected {other:?}"),
        },
        "idle-timeout close",
    );
    assert_eq!(err, Some(FrameError::IdleTimeout { ms: 100 }));
    drop(raw);
    let s = server.shutdown();
    assert_eq!(s.spawned, s.joined);
}

#[test]
fn silence_between_frames_never_times_out() {
    let mut server = TcpTransport::listen("127.0.0.1:0").unwrap();
    server.set_idle_timeout_ms(50);
    let addr = server.local_addr().unwrap();
    let mut client = TcpTransport::client();
    let conn = client.connect(&addr).unwrap();
    client.send(conn, &hello(1)).unwrap();
    poll_for(
        &mut server,
        |ev| match ev {
            TransportEvent::Frame { .. } => Some(()),
            _ => None,
        },
        "first frame",
    );
    // Several deadlines of silence with no frame in flight: legal idle.
    std::thread::sleep(std::time::Duration::from_millis(200));
    client.send(conn, &hello(2)).unwrap();
    poll_for(
        &mut server,
        |ev| match ev {
            TransportEvent::Frame { .. } => Some(()),
            TransportEvent::Closed { error, .. } => {
                panic!("connection died during legal between-frame silence: {error:?}")
            }
            _ => None,
        },
        "second frame after idle gap",
    );
    server.shutdown();
    client.shutdown();
}

#[test]
fn clean_peer_close_has_no_error() {
    let mut server = TcpTransport::listen("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let mut client = TcpTransport::client();
    let conn = client.connect(&addr).unwrap();
    client.send(conn, &hello(1)).unwrap();
    // Wait until the frame arrived, then close from the client side.
    poll_for(
        &mut server,
        |ev| match ev {
            TransportEvent::Frame { .. } => Some(()),
            _ => None,
        },
        "hello",
    );
    client.close_conn(conn);
    let err = poll_for(
        &mut server,
        |ev| match ev {
            TransportEvent::Closed { error, .. } => Some(error),
            _ => None,
        },
        "clean close",
    );
    assert_eq!(err, None, "boundary-aligned close is clean");
    server.shutdown();
    client.shutdown();
}

#[test]
fn protocol_garbage_closes_exactly_that_connection_with_typed_error() {
    let mut server = TcpTransport::listen("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();

    // Conn A: a well-formed frame with an unknown message tag.
    let mut bad = TcpStream::connect(&addr).unwrap();
    bad.write_all(&(1u32).to_le_bytes()).unwrap();
    bad.write_all(&[251]).unwrap();
    bad.flush().unwrap();

    // Conn B (healthy) through the transport proper.
    let mut client = TcpTransport::client();
    let conn_b = client.connect(&addr).unwrap();
    client.send(conn_b, &hello(9)).unwrap();

    let mut saw_healthy = false;
    let err = poll_for(
        &mut server,
        |ev| match ev {
            TransportEvent::Closed { error: Some(e), .. } => Some(e),
            TransportEvent::Frame { msg: WireMsg::Hello { node: 9, .. }, .. } => {
                saw_healthy = true;
                None
            }
            _ => None,
        },
        "typed close",
    );
    assert_eq!(err, FrameError::UnknownTag { what: "message", tag: 251 });
    if !saw_healthy {
        poll_for(
            &mut server,
            |ev| match ev {
                TransportEvent::Frame { msg: WireMsg::Hello { node: 9, .. }, .. } => Some(()),
                _ => None,
            },
            "healthy conn still alive",
        );
    }
    drop(bad);
    server.shutdown();
    client.shutdown();
}

#[test]
fn oversized_frame_closes_with_typed_error() {
    let mut server = TcpTransport::listen("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let mut raw = TcpStream::connect(&addr).unwrap();
    // Prefix claims ~4 GiB; the decoder must refuse from the prefix alone.
    raw.write_all(&u32::MAX.to_le_bytes()).unwrap();
    raw.flush().unwrap();
    let err = poll_for(
        &mut server,
        |ev| match ev {
            TransportEvent::Closed { error: Some(e), .. } => Some(e),
            _ => None,
        },
        "oversized close",
    );
    assert!(matches!(err, FrameError::Oversized { .. }), "{err:?}");
    drop(raw);
    let s = server.shutdown();
    assert_eq!(s.spawned, s.joined);
}

#[test]
fn send_after_close_is_typed_closed() {
    let mut server = TcpTransport::listen("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let mut client = TcpTransport::client();
    let conn = client.connect(&addr).unwrap();
    client.close_conn(conn);
    assert_eq!(client.send(conn, &hello(0)), Err(FrameError::Closed));
    client.shutdown();
    server.shutdown();
}

#[test]
fn shutdown_joins_every_thread_across_many_connections() {
    let mut server = TcpTransport::listen("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let mut client = TcpTransport::client();
    let mut conns = Vec::new();
    for i in 0..8 {
        let c = client.connect(&addr).unwrap();
        client.send(c, &hello(i)).unwrap();
        conns.push(c);
    }
    // Reader + writer per connection on the client side.
    assert_eq!(client.threads_spawned(), 16);
    let mut frames = 0;
    poll_for(
        &mut server,
        |ev| {
            if let TransportEvent::Frame { .. } = ev {
                frames += 1;
            }
            (frames == 8).then_some(())
        },
        "all hellos",
    );
    let c = client.shutdown();
    assert_eq!(c, super::ThreadReport { spawned: 16, joined: 16 });
    let s = server.shutdown();
    assert_eq!(s.spawned, s.joined);
    assert_eq!(s.spawned, 16, "server spawned reader+writer per accepted conn");
}
