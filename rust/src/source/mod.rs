//! Streaming source readers behind one trait — the paper's central
//! comparison axis as a pluggable API.
//!
//! Every reader implements [`StreamSource`] (an [`crate::sim::Actor`] plus
//! uniform [`SourceStats`] introspection) and is built by a
//! [`SourceFactory`] resolved from the [`SourceRegistry`] keyed by
//! [`crate::config::SourceMode`] — the launcher never names a concrete
//! source type, and plugging a new ingestion mechanism in means
//! registering a factory, not editing the engine. Modes:
//!
//! **Pull** (`PullSource`, §II-B): the state-of-the-art Flink/Spark design.
//! A serial fetch loop issues synchronous pull RPCs (up to the consumer
//! `CS` per partition), pays a per-RPC client cost and a per-record
//! deserialisation cost, hands batches to the mappers through credited
//! queues, and — when a pull returns nothing — waits `pull_timeout` before
//! polling again. Backpressure: no mapper credits → no further pulls.
//! What the pull reply carries is still shared, not copied: the broker
//! serves segment-resident chunks by `Rc` into a pre-sized reply, and the
//! source forwards each chunk inline in its batch — the pull path's extra
//! cost is the RPC + the modelled deserialisation, never a payload copy
//! in the simulator itself.
//!
//! **Push** (`PushSourceGroup`, §IV-B): the paper's design. All push source
//! tasks of a worker coordinate so *one* subscription RPC is issued (by the
//! leader — "the smallest of the source tasks' identifiers"); the broker's
//! dedicated thread then fills shared-memory objects and notifies. The
//! group's consume loop reads each sealed object **by pointer** — no fetch
//! RPC, no deserialisation copy (`push_consume_record_ns` vs
//! `engine_record_ns`) — and the hand-off into the pipeline keeps that
//! property end to end: each sealed chunk rides a batch *inline* as
//! [`crate::proto::ChunkList::One`], sharing the object's `Rc`d payload,
//! so neither the consume step nor any operator hop ever touches the
//! bytes (the zero-copy tests pin this). The loop routes batches to the
//! mappers, and only then notifies the broker to reuse the buffer
//! (Step 4): object-pool exhaustion *is* the backpressure. Resource
//! footprint: 2 threads total (consume + broker push) versus 2 per pull
//! consumer — the Fig. 4 claim.
//!
//! **Native** (`NativeConsumer`): the Fig. 7 baseline — the same pull loop
//! without the streaming-engine overhead (C++-grade per-record cost),
//! counting tuples in place.
//!
//! **Hybrid** (`HybridSource`): the adaptive fourth mode the paper's
//! "push-based and/or pull-based" architecture implies. Starts pulling,
//! watches its empty-poll rate and pull round-trip latency over a sliding
//! window, switches to the push subscription when pulls are starved by
//! writes, and falls back (with cooldown hysteresis) when the push path
//! starves instead. See [`HybridSource`] for the switch protocol.
//!
//! ## Checkpointing
//!
//! Every source also implements the [`StreamSource::checkpoint`] trait
//! extension: a uniform per-partition cursor snapshot covering exactly the
//! records already handed downstream, plus the exactly-once counters that
//! roll back with it (see [`crate::checkpoint`]). The *protocol* around it
//! is mode-specific — and that asymmetry is precisely the recovery
//! tradeoff the paper never measured:
//!
//! * **Pull/native** take a barrier at the next clean point of the serial
//!   fetch loop and restore by rewinding their own offsets — cursors make
//!   recovery trivial.
//! * **Push** pauses new object consumes until every member quiesces,
//!   snapshots the members' *consumed floors* (the broker-managed
//!   subscription cursors run ahead by the sealed-but-unconsumed
//!   objects), and must recover by tearing down its subscriptions,
//!   sweeping still-sealed objects back to the pool, resubscribing at the
//!   restored cursors and replaying.
//! * **Hybrid** snapshots the same emitted-floor offsets in either phase
//!   and always restores into the pull phase, orphaning any live
//!   subscription. If restored (or fallback) cursors land behind the
//!   broker trim point — torn-down subscriptions stop pinning retention —
//!   the pull reply's `trims` recovery skips to the floor and counts the
//!   gap instead of wedging the partition.
//!
//! When a barrier is taken, single-task sources broadcast
//! `Msg::Barrier { epoch, from_task }` on every output channel; the push
//! group broadcasts one barrier *per member id*, because downstream tasks
//! align over all `Nc` logical source channels.

#[cfg(test)]
mod tests;

pub mod api;
mod hybrid;
mod native;
mod pull;
mod push;

pub use api::{
    apply_trims, SourceActor, SourceFactory, SourceRegistry, SourceStats, SourceWiring,
    StatExtras, StatKey, StreamSource,
};
pub use hybrid::{HybridParams, HybridSource, HybridSourceFactory, HybridTuning};
pub use native::{NativeConsumer, NativeParams, NativeSourceFactory};
pub use pull::{PullParams, PullSource, PullSourceFactory};
pub use push::{PushGroupParams, PushMember, PushSourceFactory, PushSourceGroup};
