//! Streaming source readers behind one trait — the paper's central
//! comparison axis as a pluggable API.
//!
//! Every reader implements [`StreamSource`] (an [`crate::sim::Actor`] plus
//! uniform [`SourceStats`] introspection) and is built by a
//! [`SourceFactory`] resolved from the [`SourceRegistry`] keyed by
//! [`crate::config::SourceMode`] — the launcher never names a concrete
//! source type, and plugging a new ingestion mechanism in means
//! registering a factory, not editing the engine. Modes:
//!
//! **Pull** (`PullSource`, §II-B): the state-of-the-art Flink/Spark design.
//! A serial fetch loop issues synchronous pull RPCs (up to the consumer
//! `CS` per partition), pays a per-RPC client cost and a per-record
//! deserialisation cost, hands batches to the mappers through credited
//! queues, and — when a pull returns nothing — waits `pull_timeout` before
//! polling again. Backpressure: no mapper credits → no further pulls.
//!
//! **Push** (`PushSourceGroup`, §IV-B): the paper's design. All push source
//! tasks of a worker coordinate so *one* subscription RPC is issued (by the
//! leader — "the smallest of the source tasks' identifiers"); the broker's
//! dedicated thread then fills shared-memory objects and notifies. The
//! group's consume loop reads each sealed object **by pointer** — no fetch
//! RPC, no deserialisation copy (`push_consume_record_ns` vs
//! `engine_record_ns`) — routes batches to the mappers, and only then
//! notifies the broker to reuse the buffer (Step 4): object-pool exhaustion
//! *is* the backpressure. Resource footprint: 2 threads total (consume +
//! broker push) versus 2 per pull consumer — the Fig. 4 claim.
//!
//! **Native** (`NativeConsumer`): the Fig. 7 baseline — the same pull loop
//! without the streaming-engine overhead (C++-grade per-record cost),
//! counting tuples in place.
//!
//! **Hybrid** (`HybridSource`): the adaptive fourth mode the paper's
//! "push-based and/or pull-based" architecture implies. Starts pulling,
//! watches its empty-poll rate and pull round-trip latency over a sliding
//! window, switches to the push subscription when pulls are starved by
//! writes, and falls back (with cooldown hysteresis) when the push path
//! starves instead. See [`HybridSource`] for the switch protocol.

#[cfg(test)]
mod tests;

pub mod api;
mod hybrid;
mod native;
mod pull;
mod push;

pub use api::{
    SourceActor, SourceFactory, SourceRegistry, SourceStats, SourceWiring, StatExtras, StatKey,
    StreamSource,
};
pub use hybrid::{HybridParams, HybridSource, HybridSourceFactory, HybridTuning};
pub use native::{NativeConsumer, NativeParams, NativeSourceFactory};
pub use pull::{PullParams, PullSource, PullSourceFactory};
pub use push::{PushGroupParams, PushMember, PushSourceFactory, PushSourceGroup};
