//! The native ("C++") pull consumer — the Fig. 7 baseline.
//!
//! Same pull loop as [`super::PullSource`] but without the streaming
//! engine: no worker tasks downstream, no queue hops, native per-record
//! cost. It iterates, (optionally) filters and counts in place, like the
//! paper's RAMCloud-client-based consumers. Checkpointing degenerates
//! accordingly: no downstream means no barrier broadcast — a barrier is
//! just a cursor + counter snapshot at the next clean point of the loop.

use crate::checkpoint::{SharedCheckpoint, SourceSnapshot};
use crate::compute::SharedCompute;
use crate::config::{CostModel, DataPlane, SourceMode, Workload};
use crate::metrics::{Class, SharedMetrics};
use crate::net::{NodeId, SharedNetwork};
use crate::proto::{
    ChunkOffset, Msg, PartitionId, RpcEnvelope, RpcKind, RpcReply, RpcRequest, StampedChunk,
};
use crate::shard::ShardClient;
use crate::sim::{Actor, ActorId, Ctx, Engine, Time};

use super::api::{SourceActor, SourceFactory, SourceStats, SourceWiring, StatKey, StreamSource};

/// Wiring for one native consumer.
#[derive(Clone)]
pub struct NativeParams {
    /// Metrics entity (consumer index).
    pub entity: usize,
    pub node: NodeId,
    pub broker: ActorId,
    pub broker_node: NodeId,
    pub assignments: Vec<(PartitionId, ChunkOffset)>,
    /// Consumer `CS` per partition per RPC.
    pub max_bytes: u64,
    pub pull_timeout: Time,
    /// Grep needle, when the workload filters.
    pub pattern: Option<Vec<u8>>,
    /// Real-plane kernels (native engine — the C++ consumer runs native
    /// code, not the JVM path).
    pub compute: Option<SharedCompute>,
    /// Checkpoint blackboard (`None` = checkpointing disabled).
    pub checkpoint: Option<SharedCheckpoint>,
    pub cost: CostModel,
    /// The published shard view when `broker_count > 1`.
    pub shard: Option<crate::shard::SharedShard>,
    /// Per-RPC deadline (`rpc_deadline_ms`): a pull unanswered this long
    /// is checked against the coordinator's down mask and reissued once
    /// its broker is declared dead. 0 or unsharded disables it.
    pub rpc_deadline_ns: Time,
}

// Not derived: `ComputeEngine` holds a PJRT client with no Debug impl.
impl std::fmt::Debug for NativeParams {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("NativeParams")
            .field("entity", &self.entity)
            .field("node", &self.node)
            .field("broker", &self.broker)
            .field("broker_node", &self.broker_node)
            .field("assignments", &self.assignments)
            .field("max_bytes", &self.max_bytes)
            .field("pull_timeout", &self.pull_timeout)
            .field("pattern", &self.pattern)
            .field("compute", &self.compute.is_some())
            .field("checkpoint", &self.checkpoint.is_some())
            .field("cost", &self.cost)
            .field("rpc_deadline_ns", &self.rpc_deadline_ns)
            .finish()
    }
}

/// The native consumer actor: pull → count (→ filter) → pull.
pub struct NativeConsumer {
    params: NativeParams,
    offsets: Vec<(PartitionId, ChunkOffset)>,
    processing: Option<Vec<StampedChunk>>,
    next_rpc: u64,
    records_consumed: u64,
    matches: u64,
    pulls_issued: u64,
    empty_pulls: u64,
    /// Barrier waiting for the next clean point of the loop.
    pending_epoch: Option<u64>,
    /// Recovery incarnation; stale-tagged messages are dropped.
    inc: u64,
    /// Dead between an injected fault and the restore.
    failed: bool,
    /// Replies to RPCs issued before the last restore are stale.
    rpc_floor: u64,
    /// The pull currently awaiting its reply (deadline staleness check).
    inflight_pull: Option<u64>,
    /// Transmissions of the current logical pull (backoff escalation).
    pull_attempts: u32,
    /// Pulls reissued after their broker was declared dead.
    broker_down_retries: u64,
    replayed: u64,
    trim_gap_chunks: u64,
    metrics: SharedMetrics,
    net: SharedNetwork,
    /// Cached shard routing when `broker_count > 1`.
    shard: Option<ShardClient>,
}

impl NativeConsumer {
    pub fn new(params: NativeParams, metrics: SharedMetrics, net: SharedNetwork) -> Self {
        let offsets = params.assignments.clone();
        let shard = params.shard.as_ref().map(ShardClient::new);
        Self {
            params,
            offsets,
            processing: None,
            next_rpc: 0,
            records_consumed: 0,
            matches: 0,
            pulls_issued: 0,
            empty_pulls: 0,
            pending_epoch: None,
            inc: 0,
            failed: false,
            rpc_floor: 0,
            inflight_pull: None,
            pull_attempts: 0,
            broker_down_retries: 0,
            replayed: 0,
            trim_gap_chunks: 0,
            metrics,
            net,
            shard,
        }
    }

    /// The broker serving this consumer's span (re-resolved per pull).
    fn home(&self) -> (ActorId, NodeId) {
        match &self.shard {
            Some(client) => client.broker_for(self.offsets[0].0),
            None => (self.params.broker, self.params.broker_node),
        }
    }

    /// Exponential per-RPC deadline: base × 2^(attempts-1), capped.
    fn deadline_for(&self, attempts: u32) -> Time {
        self.params.rpc_deadline_ns.saturating_mul(1 << attempts.saturating_sub(1).min(6))
    }

    fn issue_pull(&mut self, ctx: &mut Ctx<'_, Msg>) {
        self.maybe_checkpoint(ctx);
        let id = self.next_rpc;
        self.next_rpc += 1;
        self.pulls_issued += 1;
        self.inflight_pull = Some(id);
        self.pull_attempts += 1;
        if self.shard.is_some() && self.params.rpc_deadline_ns > 0 {
            let d = self.deadline_for(self.pull_attempts);
            ctx.send_self_in(d, Msg::Timer(id | crate::producer::DEADLINE_TAG));
        }
        self.metrics.borrow_mut().record(Class::PullRpcs, self.params.entity, ctx.now(), 1);
        let (to, to_node) = self.home();
        let deliver = self.net.borrow_mut().send_control(ctx.now(), self.params.node, to_node);
        ctx.send_at(
            deliver,
            to,
            Msg::rpc(RpcRequest {
                id,
                reply_to: ctx.self_id(),
                from_node: self.params.node,
                kind: RpcKind::Pull {
                    assignments: self.offsets.clone(),
                    max_bytes: self.params.max_bytes,
                },
            }),
        );
    }

    /// Take a pending barrier at a clean point (nothing half-processed):
    /// snapshot + ack. The native consumer feeds no pipeline, so there is
    /// no barrier to broadcast.
    fn maybe_checkpoint(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let Some(epoch) = self.pending_epoch else { return };
        debug_assert!(self.processing.is_none(), "clean points have nothing in flight");
        self.pending_epoch = None;
        let cp = self.params.checkpoint.as_ref().expect("barrier implies checkpointing");
        super::api::ack_barrier(cp, epoch, self.checkpoint(), self.params.cost.notify_ns, ctx);
    }

    /// A pull unanswered past its deadline: once the down mask names the
    /// serving broker, refresh and reissue the same cursors against the
    /// promoted primary (reads are idempotent; the rpc floor strands any
    /// straggler reply). Until then, re-arm and keep waiting.
    fn on_deadline(&mut self, rpc: u64, ctx: &mut Ctx<'_, Msg>) {
        if self.inflight_pull != Some(rpc) {
            return; // answered or already reissued: stale timer
        }
        let (home, _) = self.home();
        if self.shard.as_ref().is_some_and(|c| c.actor_down(home)) {
            self.shard.as_mut().expect("down mask implies sharded").refresh();
            self.broker_down_retries += 1;
            self.rpc_floor = self.next_rpc;
            self.issue_pull(ctx);
        } else {
            let d = self.deadline_for(self.pull_attempts);
            ctx.send_self_in(d, Msg::Timer(rpc | crate::producer::DEADLINE_TAG));
        }
    }

    fn on_reply(&mut self, env: RpcEnvelope, ctx: &mut Ctx<'_, Msg>) {
        if env.id < self.rpc_floor {
            return; // reply to a pre-restore pull
        }
        self.inflight_pull = None;
        self.pull_attempts = 0;
        let (chunks, trims) = match env.reply {
            RpcReply::PullData { chunks, trims } => (chunks, trims),
            RpcReply::WrongShard { .. } => {
                // The span moved mid-flight: refresh and re-poll after the
                // timeout; the next pull re-resolves the primary.
                if let Some(client) = self.shard.as_mut() {
                    client.refresh();
                }
                self.maybe_checkpoint(ctx);
                ctx.send_self_in(self.params.pull_timeout, Msg::Timer(self.inc));
                return;
            }
            RpcReply::Error { reason } => panic!("native consumer: {reason}"),
            other => panic!("native consumer: unexpected reply {other:?}"),
        };
        self.trim_gap_chunks += super::api::apply_trims(&mut self.offsets, &trims);
        if chunks.is_empty() {
            self.empty_pulls += 1;
            if self.metrics.borrow().tracer.enabled() {
                self.metrics.borrow_mut().tracer.note_empty_poll(ctx.now());
            }
            self.maybe_checkpoint(ctx);
            ctx.send_self_in(self.params.pull_timeout, Msg::Timer(self.inc));
            return;
        }
        for sc in &chunks {
            for (p, off) in self.offsets.iter_mut() {
                if *p == sc.partition {
                    *off = (*off).max(sc.offset + 1);
                }
            }
        }
        if self.metrics.borrow().tracer.enabled() {
            let mut m = self.metrics.borrow_mut();
            for sc in &chunks {
                m.tracer.on_notify(sc.partition.0, sc.offset, ctx.now());
            }
        }
        let records: u64 = chunks.iter().map(|c| c.chunk.records as u64).sum();
        // Thin native client: small fixed per-RPC cost, native per-record.
        let cost = self.params.cost.rpc_base_ns + records * self.params.cost.native_record_ns;
        self.processing = Some(chunks);
        ctx.send_self_in(cost, Msg::JobDone(self.inc));
    }

    fn on_processed(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let chunks = self.processing.take().expect("JobDone only while processing");
        let mut records = 0u64;
        for sc in &chunks {
            records += sc.chunk.records as u64;
            if let (Some(pattern), Some(compute)) = (&self.params.pattern, &self.params.compute) {
                self.matches += compute
                    .filter_count(&sc.chunk, pattern)
                    .unwrap_or_else(|e| panic!("native filter: {e:#}"));
            }
        }
        self.records_consumed += records;
        let mut m = self.metrics.borrow_mut();
        m.record(Class::ConsumerTuples, self.params.entity, ctx.now(), records);
        if m.tracer.enabled() {
            // No pipeline downstream: spans close here with a zero Operate
            // stage (the native baseline's whole point).
            for sc in &chunks {
                m.tracer.finalize_at_source(
                    sc.partition.0,
                    sc.offset,
                    self.params.entity,
                    ctx.now(),
                );
            }
        }
        drop(m);
        self.issue_pull(ctx);
    }

    fn on_fault(&mut self, ctx: &mut Ctx<'_, Msg>) {
        self.failed = true;
        self.processing = None;
        self.pending_epoch = None;
        let cp = self.params.checkpoint.as_ref().unwrap_or_else(|| {
            panic!("native consumer {} faulted without checkpointing", self.params.entity)
        });
        super::api::report_failure(cp, self.params.cost.notify_ns, ctx);
    }

    fn on_restore(&mut self, inc: u64, ctx: &mut Ctx<'_, Msg>) {
        self.inc = inc;
        self.failed = false;
        self.processing = None;
        self.pending_epoch = None;
        self.rpc_floor = self.next_rpc;
        self.inflight_pull = None;
        self.pull_attempts = 0;
        let cp = self.params.checkpoint.as_ref().expect("restore implies checkpointing");
        let snap = cp.borrow().source_snapshot(ctx.self_id()).unwrap_or(SourceSnapshot {
            cursors: self.params.assignments.clone(),
            ..Default::default()
        });
        debug_assert_eq!(snap.cursors.len(), self.offsets.len());
        self.offsets = snap.cursors;
        self.replayed += self.records_consumed.saturating_sub(snap.records_consumed);
        self.records_consumed = snap.records_consumed;
        self.matches = snap.matches;
        super::api::ack_restore(cp, self.params.cost.notify_ns, ctx);
        self.issue_pull(ctx);
    }

    pub fn records_consumed(&self) -> u64 {
        self.records_consumed
    }

    pub fn matches(&self) -> u64 {
        self.matches
    }

    pub fn pulls_issued(&self) -> u64 {
        self.pulls_issued
    }

    pub fn empty_pulls(&self) -> u64 {
        self.empty_pulls
    }
}

impl Actor<Msg> for NativeConsumer {
    fn on_start(&mut self, ctx: &mut Ctx<'_, Msg>) {
        self.issue_pull(ctx);
    }

    fn on_event(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        if self.failed {
            if let Msg::Restore { inc, .. } = msg {
                self.on_restore(inc, ctx);
            }
            return;
        }
        match msg {
            Msg::Reply(env) => self.on_reply(*env, ctx),
            Msg::JobDone(tag) => {
                if tag == self.inc {
                    self.on_processed(ctx);
                }
            }
            Msg::Timer(tag) if tag & crate::producer::DEADLINE_TAG != 0 => {
                self.on_deadline(tag & !crate::producer::DEADLINE_TAG, ctx)
            }
            Msg::Timer(tag) => {
                if tag == self.inc && self.processing.is_none() {
                    self.issue_pull(ctx);
                }
            }
            Msg::BarrierInject { epoch } => {
                self.pending_epoch = Some(epoch);
                if self.processing.is_none() {
                    self.maybe_checkpoint(ctx);
                }
            }
            Msg::ShardEpoch { .. } => {
                if let Some(client) = self.shard.as_mut() {
                    client.refresh();
                }
            }
            Msg::Fault { .. } => self.on_fault(ctx),
            Msg::Restore { inc, .. } => self.on_restore(inc, ctx),
            other => panic!("native consumer: unexpected {other:?}"),
        }
    }

    fn label(&self) -> String {
        format!("native-consumer#{}", self.params.entity)
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}

impl StreamSource for NativeConsumer {
    fn mode(&self) -> SourceMode {
        SourceMode::NativePull
    }

    fn stats(&self) -> SourceStats {
        let mut extras = super::api::StatExtras::new();
        extras.insert(StatKey::Matches, self.matches);
        if self.replayed > 0 {
            extras.insert(StatKey::RecordsReplayed, self.replayed);
        }
        if self.trim_gap_chunks > 0 {
            extras.insert(StatKey::TrimGapChunks, self.trim_gap_chunks);
        }
        if self.broker_down_retries > 0 {
            extras.insert(StatKey::BrokerDownRetries, self.broker_down_retries);
        }
        SourceStats {
            records_consumed: self.records_consumed,
            pulls_issued: self.pulls_issued,
            empty_pulls: self.empty_pulls,
            threads: 1,
            extras,
        }
    }

    fn checkpoint(&self) -> SourceSnapshot {
        SourceSnapshot {
            cursors: self.offsets.clone(),
            records_consumed: self.records_consumed,
            matches: self.matches,
            ..Default::default()
        }
    }
}

/// Builds one engine-less [`NativeConsumer`] per consumer (no pipeline).
pub struct NativeSourceFactory;

impl SourceFactory for NativeSourceFactory {
    fn mode(&self) -> SourceMode {
        SourceMode::NativePull
    }

    fn uses_pipeline(&self) -> bool {
        false
    }

    fn build(&self, w: &SourceWiring<'_>, engine: &mut Engine<Msg>) -> Vec<ActorId> {
        let c = w.config;
        (0..c.nc)
            .map(|i| {
                let pattern = matches!(c.workload, Workload::Filter)
                    .then(|| crate::cluster::FILTER_NEEDLE.to_vec());
                let src = NativeConsumer::new(
                    NativeParams {
                        entity: i,
                        node: w.node,
                        broker: w.broker,
                        broker_node: w.broker_node,
                        assignments: w.member_assignments(i),
                        max_bytes: c.consumer_chunk as u64,
                        pull_timeout: c.pull_timeout_us * 1_000,
                        pattern,
                        compute: (c.data_plane == DataPlane::Real).then(|| {
                            w.compute.clone().expect("real data plane needs a compute engine")
                        }),
                        checkpoint: w.checkpoint.clone(),
                        cost: c.cost.clone(),
                        shard: w.shard.clone(),
                        rpc_deadline_ns: c.rpc_deadline_ms * crate::sim::MILLIS,
                    },
                    w.metrics.clone(),
                    w.net.clone(),
                );
                engine.add_actor(Box::new(SourceActor::new(Box::new(src))))
            })
            .collect()
    }
}
