//! The push-based source group (the paper's design, §IV-B).
//!
//! Checkpointing is where the push design pays for its shared-memory
//! fast path: the group tracks a *consumed floor* per member (the offsets
//! of the objects it actually materialised), pauses new consumes while a
//! barrier waits, snapshots at the quiesce point and broadcasts the
//! barrier on behalf of every member. Recovery cannot simply rewind a
//! cursor like the pull source: the group tears down its broker-managed
//! subscriptions (`PushUnsubscribe` per member), sweeps still-sealed
//! objects back to the free pool, resubscribes at the restored cursors
//! and replays — the protocol asymmetry the `checkpoint` ablation
//! measures.

use std::collections::{HashMap, VecDeque};

use crate::checkpoint::{SharedCheckpoint, SourceSnapshot};
use crate::config::{CostModel, SourceMode};
use crate::metrics::SharedMetrics;
use crate::net::{NodeId, SharedNetwork};
use crate::proto::{
    Batch, ChunkOffset, Msg, ObjectId, PartitionId, PushSourceSpec, RpcEnvelope, RpcKind,
    RpcReply, RpcRequest, SubId,
};
use crate::sim::{Actor, ActorId, Ctx, Engine};
use crate::worker::{CreditLedger, SharedRegistry};

use super::api::{SourceActor, SourceFactory, SourceStats, SourceWiring, StatKey, StreamSource};

/// Job tags carry the recovery incarnation above this stride; the member
/// index lives below it.
const INC_STRIDE: u64 = 1 << 32;

/// One logical push source task in the group (a consumer of the paper's
/// model: exclusive partitions, its own shared-object pool, its own slot
/// thread for materialising tuples out of shared objects).
#[derive(Debug, Clone)]
pub struct PushMember {
    /// Global task index of this logical source.
    pub task_idx: usize,
    pub assignments: Vec<(PartitionId, ChunkOffset)>,
    /// Object pool size (backpressure window).
    pub objects: usize,
    /// Object capacity — the push-path consumer chunk size.
    pub object_bytes: u64,
}

/// Wiring for the worker-local push source group.
#[derive(Debug, Clone)]
pub struct PushGroupParams {
    /// The leader's global task index (smallest member id in the paper) —
    /// the one task that issues the single subscription RPC and handles
    /// notifications.
    pub leader_task_idx: usize,
    pub node: NodeId,
    pub broker: ActorId,
    pub broker_node: NodeId,
    pub members: Vec<PushMember>,
    /// Mapper tasks fed round-robin (shared by all members).
    pub downstream: Vec<usize>,
    pub queue_cap: usize,
    /// Checkpoint blackboard (`None` = checkpointing disabled).
    pub checkpoint: Option<SharedCheckpoint>,
    pub cost: CostModel,
}

/// Per-member consume state: each member's slot thread materialises tuples
/// from its own sealed objects, concurrently with the other members.
#[derive(Debug, Default)]
struct MemberState {
    ready: VecDeque<ObjectId>,
    /// Object whose consume cost is currently being charged.
    consuming: Option<ObjectId>,
    /// Batches awaiting mapper credits; the object is freed only after
    /// they drain (backpressure propagates to the broker's push thread).
    pending: VecDeque<Batch>,
    /// Mirror of `pending` while tracing: each batch's chunk identity for
    /// the tracer's marker FIFO. Stays empty when tracing is off.
    trace_keys: VecDeque<Option<(usize, u64)>>,
    pending_free: Option<ObjectId>,
    /// Exclusive consumed floor per owned partition: offsets of everything
    /// this member materialised and handed downstream — the member's
    /// checkpoint cursor.
    consumed: Vec<(PartitionId, ChunkOffset)>,
    objects_consumed: u64,
    records_consumed: u64,
}

/// The group actor. One *extra* thread pair versus `2 × Nc` for pull:
/// the leader's subscription/notification thread here plus the broker's
/// dedicated push thread; the members' tuple materialisation runs on the
/// worker slots they already occupy (hence per-member concurrency).
pub struct PushSourceGroup {
    params: PushGroupParams,
    ledger: CreditLedger,
    members: Vec<MemberState>,
    /// SubId -> member index, resolved from the subscribe ack (the broker
    /// assigns consecutive sub ids in spec order).
    sub_to_member: HashMap<SubId, usize>,
    base_sub: Option<SubId>,
    /// Notifications that raced ahead of the subscribe ack.
    early: Vec<ObjectId>,
    subscribed: bool,
    /// Barrier waiting for every member to reach its quiesce point.
    pending_epoch: Option<u64>,
    /// Recovery incarnation; stale-tagged messages are dropped.
    inc: u64,
    /// Dead between an injected fault and the restore.
    failed: bool,
    /// Mid-restore: tearing down / re-establishing the subscriptions.
    recovering: bool,
    /// Unsubscribe acks still outstanding during a restore.
    unsubs_pending: usize,
    /// A restore that arrived before the initial subscribe ack (carries
    /// the incarnation to adopt once the handshake completes).
    deferred_restore: Option<u64>,
    /// Sub ids below this belong to torn-down incarnations: their object
    /// notifications are freed straight back to the broker.
    stale_floor: usize,
    /// During a restore: sub ids at or above this belong to the
    /// resubscribe in flight — their fills must be *queued* (they carry
    /// replay data), everything below is a dead incarnation's and is
    /// freed. `usize::MAX` until the resubscribe goes out.
    resub_floor: usize,
    replayed: u64,
    rr: usize,
    metrics: SharedMetrics,
    net: SharedNetwork,
    store: crate::plasma::SharedStore,
    registry: SharedRegistry,
}

impl PushSourceGroup {
    pub fn new(
        params: PushGroupParams,
        metrics: SharedMetrics,
        net: SharedNetwork,
        store: crate::plasma::SharedStore,
        registry: SharedRegistry,
    ) -> Self {
        assert!(!params.members.is_empty());
        assert!(!params.downstream.is_empty());
        let ledger = CreditLedger::new(&params.downstream, params.queue_cap);
        let members = params
            .members
            .iter()
            .map(|m| MemberState { consumed: m.assignments.clone(), ..Default::default() })
            .collect();
        Self {
            params,
            ledger,
            members,
            sub_to_member: HashMap::new(),
            base_sub: None,
            early: Vec::new(),
            subscribed: false,
            pending_epoch: None,
            inc: 0,
            failed: false,
            recovering: false,
            unsubs_pending: 0,
            deferred_restore: None,
            stale_floor: 0,
            resub_floor: usize::MAX,
            replayed: 0,
            rr: 0,
            metrics,
            net,
            store,
            registry,
        }
    }

    fn rpc(&mut self, kind: RpcKind, ctx: &mut Ctx<'_, Msg>) {
        let deliver =
            self.net
                .borrow_mut()
                .send_control(ctx.now(), self.params.node, self.params.broker_node);
        ctx.send_at(
            deliver,
            self.params.broker,
            Msg::rpc(RpcRequest {
                id: 0,
                reply_to: ctx.self_id(),
                from_node: self.params.node,
                kind,
            }),
        );
    }

    /// Step 1: the single subscription RPC, issued by the leader on behalf
    /// of every member — at the members' current consumed cursors, so the
    /// same call serves both the initial subscribe and the post-restore
    /// resubscribe.
    fn subscribe(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let sources = self
            .params
            .members
            .iter()
            .zip(self.members.iter())
            .map(|(m, state)| PushSourceSpec {
                source_actor: ctx.self_id(),
                assignments: state.consumed.clone(),
                objects: m.objects,
                object_bytes: m.object_bytes,
            })
            .collect();
        self.rpc(RpcKind::PushSubscribe { sources }, ctx);
    }

    fn member_of(&mut self, id: ObjectId) -> usize {
        let base = self.base_sub.expect("subscribed before notifications").0;
        let idx = id.sub.0 - base;
        debug_assert!(idx < self.members.len(), "sub {:?} not ours", id.sub);
        self.sub_to_member.entry(id.sub).or_insert(idx);
        idx
    }

    /// Return an object's buffer to the broker without consuming it (stale
    /// notifications of torn-down subscriptions).
    fn free_object(&mut self, id: ObjectId, ctx: &mut Ctx<'_, Msg>) {
        ctx.send_in(self.params.cost.notify_ns, self.params.broker, Msg::ObjectFreed { id });
    }

    /// Discard a fill a dead/torn-down consumer cannot use. For a still
    /// *active* subscription, freeing the buffer would make the broker
    /// instantly refill and re-notify it (a free→fill ping-pong until the
    /// recovery unsubscribe lands), so the slot is left sealed instead:
    /// pool exhaustion pauses fills and the unsubscribe's `release_sealed`
    /// sweep reclaims it. Objects of already-inactive subscriptions have
    /// no sweep coming, so those are freed now — an inactive subscription
    /// cannot be refilled.
    fn discard_stale(&mut self, id: ObjectId, ctx: &mut Ctx<'_, Msg>) {
        if !self.store.borrow().subscription(id.sub).active {
            self.free_object(id, ctx);
        }
    }

    fn on_ready(&mut self, id: ObjectId, ctx: &mut Ctx<'_, Msg>) {
        if self.recovering {
            // Mid-restore: a fill for the resubscribe in flight carries
            // replay data (the broker-managed cursor has already advanced
            // past it, so freeing it would lose its records) — queue it
            // for the subscribe ack. Anything older belongs to a dead
            // incarnation and is discarded.
            if id.sub.0 >= self.resub_floor {
                self.early.push(id);
            } else {
                self.discard_stale(id, ctx);
            }
            return;
        }
        if id.sub.0 < self.stale_floor {
            // A fill for a torn-down incarnation sealed after the sweep.
            self.discard_stale(id, ctx);
            return;
        }
        if !self.subscribed {
            self.early.push(id);
            return;
        }
        let m = self.member_of(id);
        self.members[m].ready.push_back(id);
        self.try_consume(m, ctx);
    }

    /// Start the member's slot thread on its next sealed object.
    fn try_consume(&mut self, m: usize, ctx: &mut Ctx<'_, Msg>) {
        if self.pending_epoch.is_some() {
            return; // a barrier is waiting for the group to quiesce
        }
        let state = &mut self.members[m];
        if state.consuming.is_some()
            || !state.pending.is_empty()
            || state.pending_free.is_some()
        {
            return;
        }
        let Some(id) = state.ready.pop_front() else { return };
        let (records, _bytes) = self.store.borrow().sealed_counts(id);
        // Pointer access into shared memory: tuples are materialised from
        // the shared object without a fetch RPC or a deser copy.
        let cost = self.params.cost.push_object_handle_ns
            + records * self.params.cost.push_consume_record_ns;
        state.consuming = Some(id);
        ctx.send_self_in(cost, Msg::JobDone(self.inc * INC_STRIDE + m as u64));
    }

    fn on_consumed(&mut self, m: usize, ctx: &mut Ctx<'_, Msg>) {
        let id = {
            let state = &mut self.members[m];
            state.consuming.take().expect("JobDone only while consuming")
        };
        let from_task = self.params.members[m].task_idx;
        let inc = self.inc;
        let tracing = self.metrics.borrow().tracer.enabled();
        {
            let store = self.store.borrow();
            let state = &mut self.members[m];
            for sc in store.read(id) {
                state.records_consumed += sc.chunk.records as u64;
                for (p, off) in state.consumed.iter_mut() {
                    if *p == sc.partition {
                        *off = (*off).max(sc.offset + 1);
                    }
                }
                if tracing {
                    // "Notified" = the source first observes the chunk's
                    // offsets — for push, the object-consume moment.
                    self.metrics.borrow_mut().tracer.on_notify(
                        sc.partition.0,
                        sc.offset,
                        ctx.now(),
                    );
                    state.trace_keys.push_back(Some((sc.partition.0, sc.offset)));
                }
                // The paper's Step 3 hand-off: the sealed object's chunk is
                // shared into the pipeline by pointer (`Rc` bump inline in
                // the batch) — no fetch RPC, no deser copy, no batch-side
                // allocation.
                state.pending.push_back(Batch {
                    from_task,
                    tuples: sc.chunk.records as u64,
                    chunks: crate::proto::ChunkList::One(sc.chunk.clone()),
                    hist: None,
                    inc,
                });
            }
            state.objects_consumed += 1;
        }
        self.members[m].pending_free = Some(id);
        self.flush(m, ctx);
    }

    /// Forward the member's batches under credits; once drained, notify the
    /// broker (Step 4) so the buffer is reused, then serve its next object.
    fn flush(&mut self, m: usize, ctx: &mut Ctx<'_, Msg>) {
        let tracing = self.metrics.borrow().tracer.enabled();
        loop {
            let Some(batch) = ({
                let state = &mut self.members[m];
                state.pending.pop_front()
            }) else {
                break;
            };
            // Round-robin over the mappers, skipping credit-exhausted ones.
            let n = self.params.downstream.len();
            let Some(k) = (0..n)
                .map(|i| (self.rr + i) % n)
                .find(|&k| self.ledger.has(self.params.downstream[k]))
            else {
                self.members[m].pending.push_front(batch);
                if tracing {
                    self.metrics.borrow_mut().tracer.note_credit_stall(ctx.now());
                }
                return; // blocked: object stays held -> broker stalls
            };
            let target = self.params.downstream[k];
            self.rr = k + 1;
            self.ledger.spend(target);
            if tracing {
                let key = self.members[m].trace_keys.pop_front().flatten();
                self.metrics.borrow_mut().tracer.on_handoff(
                    key,
                    batch.from_task,
                    target,
                    ctx.now(),
                );
            }
            let actor = self.registry.borrow().actor_of(target);
            ctx.send_in(self.params.cost.queue_hop_ns, actor, Msg::Data(batch));
        }
        if let Some(id) = self.members[m].pending_free.take() {
            self.free_object(id, ctx);
        }
        self.maybe_checkpoint(ctx);
        self.try_consume(m, ctx);
    }

    // ------------------------------------------------------- checkpoint --

    /// Take a waiting barrier once every member quiesced (nothing being
    /// consumed, nothing pending, nothing held for free): the members'
    /// consumed floors then cover exactly what was handed downstream.
    /// Snapshot, ack, broadcast one barrier per member id, resume.
    fn maybe_checkpoint(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let Some(epoch) = self.pending_epoch else { return };
        if self.recovering {
            return;
        }
        let quiesced = self
            .members
            .iter()
            .all(|s| s.consuming.is_none() && s.pending.is_empty() && s.pending_free.is_none());
        if !quiesced {
            return;
        }
        self.pending_epoch = None;
        let cp = self.params.checkpoint.as_ref().expect("barrier implies checkpointing");
        super::api::ack_barrier(cp, epoch, self.checkpoint(), self.params.cost.notify_ns, ctx);
        // Every downstream task aligns over all member channels: broadcast
        // the barrier on behalf of each member.
        for i in 0..self.params.members.len() {
            let from_task = self.params.members[i].task_idx;
            for &target in &self.params.downstream {
                let actor = self.registry.borrow().actor_of(target);
                ctx.send_in(
                    self.params.cost.queue_hop_ns,
                    actor,
                    Msg::Barrier { epoch, from_task },
                );
            }
        }
        for m in 0..self.members.len() {
            self.try_consume(m, ctx);
        }
    }

    // --------------------------------------------------------- recovery --

    fn on_fault(&mut self, ctx: &mut Ctx<'_, Msg>) {
        self.failed = true;
        self.pending_epoch = None;
        let cp = self.params.checkpoint.as_ref().unwrap_or_else(|| {
            panic!("push group {} faulted without checkpointing", self.params.leader_task_idx)
        });
        super::api::report_failure(cp, self.params.cost.notify_ns, ctx);
    }

    /// Global rollback. The push path cannot just rewind a cursor: tear
    /// down every member's subscription, sweep its objects, then
    /// resubscribe at the snapshot cursors and replay.
    fn begin_restore(&mut self, inc: u64, ctx: &mut Ctx<'_, Msg>) {
        let Some(base) = self.base_sub else {
            // The initial subscribe is still in flight: finish the
            // handshake first (the ack completes it), then restore.
            self.deferred_restore = Some(inc);
            self.failed = false;
            return;
        };
        self.inc = inc;
        self.failed = false;
        self.recovering = true;
        self.pending_epoch = None;
        // Discard every held object: their subscriptions are about to be
        // unsubscribed, whose `release_sealed` sweep reclaims the slots.
        for m in 0..self.members.len() {
            let ids: Vec<ObjectId> = {
                let s = &mut self.members[m];
                s.pending.clear();
                s.trace_keys.clear();
                s.ready
                    .drain(..)
                    .chain(s.consuming.take())
                    .chain(s.pending_free.take())
                    .collect()
            };
            for id in ids {
                self.discard_stale(id, ctx);
            }
        }
        let early: Vec<ObjectId> = std::mem::take(&mut self.early);
        for id in early {
            self.discard_stale(id, ctx);
        }
        self.ledger = CreditLedger::new(&self.params.downstream, self.params.queue_cap);
        self.rr = 0;
        // Roll the consumed floors and counters back to the snapshot.
        let cp = self.params.checkpoint.as_ref().expect("restore implies checkpointing");
        let snap = cp.borrow().source_snapshot(ctx.self_id());
        let consumed_total: u64 = self.members.iter().map(|s| s.records_consumed).sum();
        match snap {
            Some(snap) => {
                let mut at = 0;
                for (i, state) in self.members.iter_mut().enumerate() {
                    let n = state.consumed.len();
                    state.consumed = snap.cursors[at..at + n].to_vec();
                    at += n;
                    state.records_consumed =
                        snap.member_records.get(i).copied().unwrap_or(0);
                }
                debug_assert_eq!(at, snap.cursors.len());
            }
            None => {
                for (m, state) in self.params.members.iter().zip(self.members.iter_mut()) {
                    state.consumed = m.assignments.clone();
                    state.records_consumed = 0;
                }
            }
        }
        let rolled_back: u64 = self.members.iter().map(|s| s.records_consumed).sum();
        self.replayed += consumed_total.saturating_sub(rolled_back);
        // Tear down the old subscriptions; the acks gate the resubscribe.
        self.subscribed = false;
        self.sub_to_member.clear();
        self.unsubs_pending = self.members.len();
        for k in 0..self.members.len() {
            self.rpc(RpcKind::PushUnsubscribe { sub: SubId(base.0 + k) }, ctx);
        }
    }

    fn on_unsubscribed(&mut self, sub: SubId, ctx: &mut Ctx<'_, Msg>) {
        assert!(self.recovering, "push group only unsubscribes during recovery");
        // Sweep: a crashed incarnation lost its ObjectReady notifications,
        // so still-sealed slots would otherwise never return to the pool.
        self.store.borrow_mut().release_sealed(sub);
        self.unsubs_pending -= 1;
        if self.unsubs_pending == 0 {
            // Resubscribe at the restored cursors. Sub ids granted from
            // here on are the new incarnation's: their fills are replay
            // data, never freed.
            self.resub_floor = self.store.borrow().next_sub_id();
            self.subscribe(ctx);
        }
    }

    fn on_subscribe_ack(&mut self, sub: SubId, ctx: &mut Ctx<'_, Msg>) {
        self.base_sub = Some(sub);
        self.subscribed = true;
        self.stale_floor = sub.0;
        let was_recovering = std::mem::take(&mut self.recovering);
        if was_recovering {
            self.resub_floor = usize::MAX;
            let cp = self.params.checkpoint.as_ref().expect("recovering implies checkpointing");
            super::api::ack_restore(cp, self.params.cost.notify_ns, ctx);
        }
        // Deliver fills that raced ahead of this ack (including replay
        // fills queued during the recovery resubscribe).
        let early = std::mem::take(&mut self.early);
        for id in early {
            self.on_ready(id, ctx);
        }
        if let Some(inc) = self.deferred_restore.take() {
            self.begin_restore(inc, ctx);
        }
    }

    // ---------------------------------------------------- introspection --

    pub fn objects_consumed(&self) -> u64 {
        self.members.iter().map(|m| m.objects_consumed).sum()
    }

    pub fn records_consumed(&self) -> u64 {
        self.members.iter().map(|m| m.records_consumed).sum()
    }

    /// Per-member records (partition-skew diagnostics).
    pub fn member_records(&self) -> Vec<u64> {
        self.members.iter().map(|m| m.records_consumed).collect()
    }

    pub fn is_subscribed(&self) -> bool {
        self.subscribed
    }

    pub fn records_replayed(&self) -> u64 {
        self.replayed
    }
}

impl Actor<Msg> for PushSourceGroup {
    fn on_start(&mut self, ctx: &mut Ctx<'_, Msg>) {
        self.subscribe(ctx);
    }

    fn on_event(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        if self.failed {
            match msg {
                Msg::Restore { inc, .. } => self.begin_restore(inc, ctx),
                // A dead subscriber cannot consume fills; discarding them
                // (sealed until the recovery sweep) also pauses the
                // broker's fill pump via pool exhaustion.
                Msg::ObjectReady { id } => self.discard_stale(id, ctx),
                _ => {}
            }
            return;
        }
        match msg {
            Msg::Reply(env) => {
                let RpcEnvelope { reply, .. } = *env;
                match reply {
                    RpcReply::SubscribeAck { sub } => self.on_subscribe_ack(sub, ctx),
                    RpcReply::UnsubscribeAck { sub, .. } => self.on_unsubscribed(sub, ctx),
                    RpcReply::Error { reason } => panic!(
                        "push group {}: subscribe failed: {reason}",
                        self.params.leader_task_idx
                    ),
                    other => panic!("push group: unexpected reply {other:?}"),
                }
            }
            // Step 3: the broker sealed an object for one of our members.
            Msg::ObjectReady { id } => self.on_ready(id, ctx),
            Msg::JobDone(tag) => {
                if tag / INC_STRIDE == self.inc {
                    self.on_consumed((tag % INC_STRIDE) as usize, ctx);
                }
            }
            Msg::Credit { to_upstream_task, inc } => {
                if inc != self.inc {
                    return; // credit for a pre-rollback batch: ledger was reset
                }
                self.ledger.refund(to_upstream_task);
                for m in 0..self.members.len() {
                    self.flush(m, ctx);
                }
            }
            Msg::BarrierInject { epoch } => {
                self.pending_epoch = Some(epoch);
                self.maybe_checkpoint(ctx);
            }
            Msg::Fault { .. } => self.on_fault(ctx),
            Msg::Restore { inc, .. } => self.begin_restore(inc, ctx),
            other => panic!("push group: unexpected {other:?}"),
        }
    }

    fn label(&self) -> String {
        format!("push-group(leader#{})", self.params.leader_task_idx)
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}

impl StreamSource for PushSourceGroup {
    fn mode(&self) -> SourceMode {
        SourceMode::Push
    }

    fn stats(&self) -> SourceStats {
        let mut extras = super::api::StatExtras::new();
        extras.insert(StatKey::ObjectsConsumed, self.objects_consumed());
        extras.insert(StatKey::Subscribed, self.subscribed as u64);
        if self.replayed > 0 {
            extras.insert(StatKey::RecordsReplayed, self.replayed);
        }
        SourceStats {
            records_consumed: self.records_consumed(),
            pulls_issued: 0,
            empty_pulls: 0,
            threads: 2, // group consume thread + broker push thread
            extras,
        }
    }

    fn checkpoint(&self) -> SourceSnapshot {
        SourceSnapshot {
            cursors: self.members.iter().flat_map(|s| s.consumed.iter().copied()).collect(),
            records_consumed: self.records_consumed(),
            matches: 0,
            member_records: self.member_records(),
        }
    }
}

/// Builds the single worker-local [`PushSourceGroup`] covering all `Nc`
/// logical source tasks (2 threads total — the Fig. 4 footprint claim).
pub struct PushSourceFactory;

impl SourceFactory for PushSourceFactory {
    fn mode(&self) -> SourceMode {
        SourceMode::Push
    }

    fn broker_push_threads(&self) -> usize {
        1
    }

    fn build(&self, w: &SourceWiring<'_>, engine: &mut Engine<Msg>) -> Vec<ActorId> {
        let c = w.config;
        let members: Vec<PushMember> = (0..c.nc)
            .map(|i| PushMember {
                task_idx: i,
                assignments: w.member_assignments(i),
                objects: c.push_objects_per_source,
                object_bytes: c.consumer_chunk as u64,
            })
            .collect();
        let group = PushSourceGroup::new(
            PushGroupParams {
                leader_task_idx: 0,
                node: w.node,
                broker: w.broker,
                broker_node: w.broker_node,
                members,
                downstream: w.downstream.clone(),
                queue_cap: c.queue_cap,
                checkpoint: w.checkpoint.clone(),
                cost: c.cost.clone(),
            },
            w.metrics.clone(),
            w.net.clone(),
            w.store.clone(),
            w.registry.clone(),
        );
        let id = engine.add_actor(Box::new(SourceActor::new(Box::new(group))));
        for i in 0..c.nc {
            w.registry.borrow_mut().register(i, id);
        }
        vec![id]
    }
}
