//! The push-based source group (the paper's design, §IV-B).
//!
//! Checkpointing is where the push design pays for its shared-memory
//! fast path: the group tracks a *consumed floor* per member (the offsets
//! of the objects it actually materialised), pauses new consumes while a
//! barrier waits, snapshots at the quiesce point and broadcasts the
//! barrier on behalf of every member. Recovery cannot simply rewind a
//! cursor like the pull source: the group tears down its broker-managed
//! subscriptions (`PushUnsubscribe` per member), sweeps still-sealed
//! objects back to the free pool, resubscribes at the restored cursors
//! and replays — the protocol asymmetry the `checkpoint` ablation
//! measures.

use std::collections::{HashMap, VecDeque};

use crate::checkpoint::{SharedCheckpoint, SourceSnapshot};
use crate::config::{CostModel, SourceMode};
use crate::metrics::SharedMetrics;
use crate::net::{NodeId, SharedNetwork};
use crate::proto::{
    Batch, ChunkOffset, Msg, ObjectId, PartitionId, PushSourceSpec, RpcEnvelope, RpcKind,
    RpcReply, RpcRequest, SubId,
};
use crate::shard::ShardClient;
use crate::sim::{Actor, ActorId, Ctx, Engine};
use crate::worker::{CreditLedger, SharedRegistry};

use super::api::{SourceActor, SourceFactory, SourceStats, SourceWiring, StatKey, StreamSource};

/// Job tags carry the recovery incarnation above this stride; the member
/// index lives below it.
const INC_STRIDE: u64 = 1 << 32;

/// One logical push source task in the group (a consumer of the paper's
/// model: exclusive partitions, its own shared-object pool, its own slot
/// thread for materialising tuples out of shared objects).
#[derive(Debug, Clone)]
pub struct PushMember {
    /// Global task index of this logical source.
    pub task_idx: usize,
    pub assignments: Vec<(PartitionId, ChunkOffset)>,
    /// Object pool size (backpressure window).
    pub objects: usize,
    /// Object capacity — the push-path consumer chunk size.
    pub object_bytes: u64,
}

/// Wiring for the worker-local push source group.
#[derive(Debug, Clone)]
pub struct PushGroupParams {
    /// The leader's global task index (smallest member id in the paper) —
    /// the one task that issues the single subscription RPC and handles
    /// notifications.
    pub leader_task_idx: usize,
    pub node: NodeId,
    pub broker: ActorId,
    pub broker_node: NodeId,
    pub members: Vec<PushMember>,
    /// Mapper tasks fed round-robin (shared by all members).
    pub downstream: Vec<usize>,
    pub queue_cap: usize,
    /// Checkpoint blackboard (`None` = checkpointing disabled).
    pub checkpoint: Option<SharedCheckpoint>,
    pub cost: CostModel,
    /// The published shard view when `broker_count > 1`: members subscribe
    /// at their span's primary, and a rebalance migrates each moved member
    /// (drain → unsubscribe old primary → resubscribe at the consumed
    /// floor on the new one).
    pub shard: Option<crate::shard::SharedShard>,
}

/// Per-member consume state: each member's slot thread materialises tuples
/// from its own sealed objects, concurrently with the other members.
#[derive(Debug, Default)]
struct MemberState {
    ready: VecDeque<ObjectId>,
    /// Object whose consume cost is currently being charged.
    consuming: Option<ObjectId>,
    /// Batches awaiting mapper credits; the object is freed only after
    /// they drain (backpressure propagates to the broker's push thread).
    pending: VecDeque<Batch>,
    /// Mirror of `pending` while tracing: each batch's chunk identity for
    /// the tracer's marker FIFO. Stays empty when tracing is off.
    trace_keys: VecDeque<Option<(usize, u64)>>,
    pending_free: Option<ObjectId>,
    /// Exclusive consumed floor per owned partition: offsets of everything
    /// this member materialised and handed downstream — the member's
    /// checkpoint cursor.
    consumed: Vec<(PartitionId, ChunkOffset)>,
    objects_consumed: u64,
    records_consumed: u64,
}

/// The group actor. One *extra* thread pair versus `2 × Nc` for pull:
/// the leader's subscription/notification thread here plus the broker's
/// dedicated push thread; the members' tuple materialisation runs on the
/// worker slots they already occupy (hence per-member concurrency).
pub struct PushSourceGroup {
    params: PushGroupParams,
    ledger: CreditLedger,
    members: Vec<MemberState>,
    /// SubId -> member index, filled from subscribe acks (the broker
    /// assigns consecutive sub ids in spec order per request).
    sub_to_member: HashMap<SubId, usize>,
    /// Each member's granted subscription and the broker holding it.
    member_sub: Vec<Option<(SubId, ActorId, NodeId)>>,
    /// Outstanding subscribe RPCs: rpc id -> (broker, members covered).
    pending_subs: HashMap<u64, (ActorId, NodeId, Vec<usize>)>,
    /// Members draining towards a hand-off unsubscribe (rebalance).
    migrating: Vec<bool>,
    next_rpc: u64,
    /// Notifications that raced ahead of the subscribe ack.
    early: Vec<ObjectId>,
    /// Barrier waiting for every member to reach its quiesce point.
    pending_epoch: Option<u64>,
    /// Recovery incarnation; stale-tagged messages are dropped.
    inc: u64,
    /// Dead between an injected fault and the restore.
    failed: bool,
    /// Mid-restore: tearing down / re-establishing the subscriptions.
    recovering: bool,
    /// Unsubscribe acks still outstanding during a restore.
    unsubs_pending: usize,
    /// A restore that arrived before the initial subscribe ack (carries
    /// the incarnation to adopt once the handshake completes).
    deferred_restore: Option<u64>,
    /// Sub ids below this belong to torn-down incarnations: their object
    /// notifications are freed straight back to the broker.
    stale_floor: usize,
    /// During a restore: sub ids at or above this belong to the
    /// resubscribe in flight — their fills must be *queued* (they carry
    /// replay data), everything below is a dead incarnation's and is
    /// freed. `usize::MAX` until the resubscribe goes out.
    resub_floor: usize,
    /// Members re-homed (and in-flight subscribes re-issued) after their
    /// broker was declared dead.
    broker_down_retries: u64,
    replayed: u64,
    rr: usize,
    metrics: SharedMetrics,
    net: SharedNetwork,
    store: crate::plasma::SharedStore,
    registry: SharedRegistry,
    /// Cached shard routing when `broker_count > 1`.
    shard: Option<ShardClient>,
}

impl PushSourceGroup {
    pub fn new(
        params: PushGroupParams,
        metrics: SharedMetrics,
        net: SharedNetwork,
        store: crate::plasma::SharedStore,
        registry: SharedRegistry,
    ) -> Self {
        assert!(!params.members.is_empty());
        assert!(!params.downstream.is_empty());
        let ledger = CreditLedger::new(&params.downstream, params.queue_cap);
        let members: Vec<MemberState> = params
            .members
            .iter()
            .map(|m| MemberState { consumed: m.assignments.clone(), ..Default::default() })
            .collect();
        let n = members.len();
        let shard = params.shard.as_ref().map(ShardClient::new);
        Self {
            params,
            ledger,
            members,
            sub_to_member: HashMap::new(),
            member_sub: vec![None; n],
            pending_subs: HashMap::new(),
            migrating: vec![false; n],
            next_rpc: 0,
            early: Vec::new(),
            pending_epoch: None,
            inc: 0,
            failed: false,
            recovering: false,
            unsubs_pending: 0,
            deferred_restore: None,
            stale_floor: 0,
            resub_floor: usize::MAX,
            broker_down_retries: 0,
            replayed: 0,
            rr: 0,
            metrics,
            net,
            store,
            registry,
            shard,
        }
    }

    /// True once every member holds a granted subscription.
    fn all_subscribed(&self) -> bool {
        self.pending_subs.is_empty() && self.member_sub.iter().all(Option::is_some)
    }

    /// The broker serving a member's span (the single `broker` when
    /// unsharded; re-resolved from the cached table when sharded).
    fn member_home(&self, m: usize) -> (ActorId, NodeId) {
        match &self.shard {
            Some(client) => client.broker_for(self.members[m].consumed[0].0),
            None => (self.params.broker, self.params.broker_node),
        }
    }

    fn rpc_to(&mut self, to: ActorId, to_node: NodeId, kind: RpcKind, ctx: &mut Ctx<'_, Msg>) -> u64 {
        let id = self.next_rpc;
        self.next_rpc += 1;
        let deliver = self.net.borrow_mut().send_control(ctx.now(), self.params.node, to_node);
        ctx.send_at(
            deliver,
            to,
            Msg::rpc(RpcRequest { id, reply_to: ctx.self_id(), from_node: self.params.node, kind }),
        );
        id
    }

    /// Step 1: the subscription RPCs, issued by the leader on behalf of
    /// the given members — at their current consumed cursors, so the same
    /// call serves the initial subscribe, the post-restore resubscribe and
    /// the per-member rebalance hand-off. One RPC per destination broker
    /// (a single RPC for the whole group when unsharded).
    fn subscribe_members(&mut self, ms: &[usize], ctx: &mut Ctx<'_, Msg>) {
        // Group by home broker, preserving member order within a group.
        let mut groups: Vec<(ActorId, NodeId, Vec<usize>)> = Vec::new();
        for &m in ms {
            let (home, home_node) = self.member_home(m);
            match groups.iter_mut().find(|(h, _, _)| *h == home) {
                Some((_, _, list)) => list.push(m),
                None => groups.push((home, home_node, vec![m])),
            }
        }
        for (home, home_node, list) in groups {
            let sources: Vec<PushSourceSpec> = list
                .iter()
                .map(|&m| PushSourceSpec {
                    source_actor: ctx.self_id(),
                    assignments: self.members[m].consumed.clone(),
                    objects: self.params.members[m].objects,
                    object_bytes: self.params.members[m].object_bytes,
                })
                .collect();
            let rpc = self.rpc_to(home, home_node, RpcKind::PushSubscribe { sources }, ctx);
            self.pending_subs.insert(rpc, (home, home_node, list));
        }
    }

    /// Return an object's buffer to the broker. Routed to the broker that
    /// granted the subscription — it owns the sub's pool slots and its
    /// fill pump wakes on the free. Dead subs fall back to the wiring
    /// default: the release itself is node-global and nothing refills.
    fn free_object(&mut self, id: ObjectId, ctx: &mut Ctx<'_, Msg>) {
        let to = self
            .sub_to_member
            .get(&id.sub)
            .and_then(|&m| self.member_sub[m])
            .map_or(self.params.broker, |(_, home, _)| home);
        ctx.send_in(self.params.cost.notify_ns, to, Msg::ObjectFreed { id });
    }

    /// Discard a fill a dead/torn-down consumer cannot use. For a still
    /// *active* subscription, freeing the buffer would make the broker
    /// instantly refill and re-notify it (a free→fill ping-pong until the
    /// recovery unsubscribe lands), so the slot is left sealed instead:
    /// pool exhaustion pauses fills and the unsubscribe's `release_sealed`
    /// sweep reclaims it. Objects of already-inactive subscriptions have
    /// no sweep coming, so those are freed now — an inactive subscription
    /// cannot be refilled.
    fn discard_stale(&mut self, id: ObjectId, ctx: &mut Ctx<'_, Msg>) {
        if !self.store.borrow().subscription(id.sub).active {
            self.free_object(id, ctx);
        }
    }

    fn on_ready(&mut self, id: ObjectId, ctx: &mut Ctx<'_, Msg>) {
        if self.recovering {
            // Mid-restore: a fill for the resubscribe in flight carries
            // replay data (the broker-managed cursor has already advanced
            // past it, so freeing it would lose its records) — queue it
            // for the subscribe ack. Anything older belongs to a dead
            // incarnation and is discarded.
            if id.sub.0 >= self.resub_floor {
                self.early.push(id);
            } else {
                self.discard_stale(id, ctx);
            }
            return;
        }
        if id.sub.0 < self.stale_floor {
            // A fill for a torn-down incarnation sealed after the sweep.
            self.discard_stale(id, ctx);
            return;
        }
        let Some(&m) = self.sub_to_member.get(&id.sub) else {
            // Our fill, but the granting ack is still in flight — or a
            // straggler of an already-unsubscribed hand-off sub, whose
            // sweep reclaims the slot.
            if self.store.borrow().subscription(id.sub).active {
                self.early.push(id);
            } else {
                self.discard_stale(id, ctx);
            }
            return;
        };
        if self.migrating[m] {
            // Mid-hand-off: the new primary re-pushes everything past the
            // consumed floor, so this fill stays sealed for the
            // unsubscribe sweep (freeing it would ping-pong a refill).
            return;
        }
        self.members[m].ready.push_back(id);
        self.try_consume(m, ctx);
    }

    /// Start the member's slot thread on its next sealed object.
    fn try_consume(&mut self, m: usize, ctx: &mut Ctx<'_, Msg>) {
        if self.pending_epoch.is_some() {
            return; // a barrier is waiting for the group to quiesce
        }
        if self.migrating[m] {
            return; // draining towards the hand-off unsubscribe
        }
        let state = &mut self.members[m];
        if state.consuming.is_some()
            || !state.pending.is_empty()
            || state.pending_free.is_some()
        {
            return;
        }
        let Some(id) = state.ready.pop_front() else { return };
        let (records, _bytes) = self.store.borrow().sealed_counts(id);
        // Pointer access into shared memory: tuples are materialised from
        // the shared object without a fetch RPC or a deser copy.
        let cost = self.params.cost.push_object_handle_ns
            + records * self.params.cost.push_consume_record_ns;
        state.consuming = Some(id);
        ctx.send_self_in(cost, Msg::JobDone(self.inc * INC_STRIDE + m as u64));
    }

    fn on_consumed(&mut self, m: usize, ctx: &mut Ctx<'_, Msg>) {
        let id = {
            let state = &mut self.members[m];
            state.consuming.take().expect("JobDone only while consuming")
        };
        let from_task = self.params.members[m].task_idx;
        let inc = self.inc;
        let tracing = self.metrics.borrow().tracer.enabled();
        {
            let store = self.store.borrow();
            let state = &mut self.members[m];
            for sc in store.read(id) {
                state.records_consumed += sc.chunk.records as u64;
                for (p, off) in state.consumed.iter_mut() {
                    if *p == sc.partition {
                        *off = (*off).max(sc.offset + 1);
                    }
                }
                if tracing {
                    // "Notified" = the source first observes the chunk's
                    // offsets — for push, the object-consume moment.
                    self.metrics.borrow_mut().tracer.on_notify(
                        sc.partition.0,
                        sc.offset,
                        ctx.now(),
                    );
                    state.trace_keys.push_back(Some((sc.partition.0, sc.offset)));
                }
                // The paper's Step 3 hand-off: the sealed object's chunk is
                // shared into the pipeline by pointer (`Rc` bump inline in
                // the batch) — no fetch RPC, no deser copy, no batch-side
                // allocation.
                state.pending.push_back(Batch {
                    from_task,
                    tuples: sc.chunk.records as u64,
                    chunks: crate::proto::ChunkList::One(sc.chunk.clone()),
                    hist: None,
                    inc,
                });
            }
            state.objects_consumed += 1;
        }
        self.members[m].pending_free = Some(id);
        self.flush(m, ctx);
    }

    /// Forward the member's batches under credits; once drained, notify the
    /// broker (Step 4) so the buffer is reused, then serve its next object.
    fn flush(&mut self, m: usize, ctx: &mut Ctx<'_, Msg>) {
        let tracing = self.metrics.borrow().tracer.enabled();
        loop {
            let Some(batch) = ({
                let state = &mut self.members[m];
                state.pending.pop_front()
            }) else {
                break;
            };
            // Round-robin over the mappers, skipping credit-exhausted ones.
            let n = self.params.downstream.len();
            let Some(k) = (0..n)
                .map(|i| (self.rr + i) % n)
                .find(|&k| self.ledger.has(self.params.downstream[k]))
            else {
                self.members[m].pending.push_front(batch);
                if tracing {
                    self.metrics.borrow_mut().tracer.note_credit_stall(ctx.now());
                }
                return; // blocked: object stays held -> broker stalls
            };
            let target = self.params.downstream[k];
            self.rr = k + 1;
            self.ledger.spend(target);
            if tracing {
                let key = self.members[m].trace_keys.pop_front().flatten();
                self.metrics.borrow_mut().tracer.on_handoff(
                    key,
                    batch.from_task,
                    target,
                    ctx.now(),
                );
            }
            let actor = self.registry.borrow().actor_of(target);
            ctx.send_in(self.params.cost.queue_hop_ns, actor, Msg::Data(batch));
        }
        if let Some(id) = self.members[m].pending_free.take() {
            self.free_object(id, ctx);
        }
        self.maybe_checkpoint(ctx);
        self.maybe_unsubscribe(m, ctx);
        self.try_consume(m, ctx);
    }

    // -------------------------------------------------------- rebalance --

    /// The coordinator published a new assignment table: refresh the
    /// cached view and hand off every member whose primary moved — drain
    /// in-flight work, unsubscribe at the old primary, resubscribe at the
    /// consumed floor on the new one (the new primary re-pushes everything
    /// past it, so nothing is lost and nothing repeats).
    fn on_shard_epoch(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let Some(client) = self.shard.as_mut() else { return };
        client.refresh();
        if self.recovering || self.failed {
            return; // the recovery resubscribe re-resolves homes itself
        }
        // Subscribes still in flight towards a corpse can never be granted
        // (the broker's work queue died with it): re-issue them against
        // the refreshed table — re-grouped by the members' new homes.
        let dead_rpcs: Vec<u64> = self
            .pending_subs
            .iter()
            .filter(|(_, v)| self.shard.as_ref().is_some_and(|c| c.actor_down(v.0)))
            .map(|(&rpc, _)| rpc)
            .collect();
        for rpc in dead_rpcs {
            let (_, _, list) = self.pending_subs.remove(&rpc).expect("swept above");
            self.broker_down_retries += 1;
            self.subscribe_members(&list, ctx);
        }
        for m in 0..self.members.len() {
            let Some((_, home, _)) = self.member_sub[m] else { continue };
            if self.migrating[m] || self.member_home(m).0 == home {
                continue;
            }
            self.migrating[m] = true;
            // Unconsumed fills stay sealed for the unsubscribe sweep; the
            // new subscription re-pushes them from the consumed floor.
            self.members[m].ready.clear();
            self.maybe_unsubscribe(m, ctx);
        }
    }

    /// Issue the hand-off unsubscribe once the migrating member drained
    /// (nothing consuming, nothing pending, nothing held for free).
    fn maybe_unsubscribe(&mut self, m: usize, ctx: &mut Ctx<'_, Msg>) {
        if !self.migrating[m] {
            return;
        }
        let s = &self.members[m];
        if s.consuming.is_some() || !s.pending.is_empty() || s.pending_free.is_some() {
            return;
        }
        let Some((sub, home, home_node)) = self.member_sub[m].take() else { return };
        if self.shard.as_ref().is_some_and(|c| c.actor_down(home)) {
            // The old primary died: a dead broker drops everything, so no
            // unsubscribe ack can ever come. Tear the subscription down
            // *locally* — deactivate it on the node-shared plasma store
            // and sweep its sealed slots — and resubscribe at the member's
            // consumed floor on the promoted primary, which re-pushes
            // everything past it: the dropped unconsumed fills replay, so
            // nothing is lost and nothing repeats.
            self.store.borrow_mut().deactivate(sub);
            self.store.borrow_mut().release_sealed(sub);
            self.sub_to_member.remove(&sub);
            self.broker_down_retries += 1;
            self.subscribe_members(&[m], ctx);
            return;
        }
        self.rpc_to(home, home_node, RpcKind::PushUnsubscribe { sub }, ctx);
    }

    // ------------------------------------------------------- checkpoint --

    /// Take a waiting barrier once every member quiesced (nothing being
    /// consumed, nothing pending, nothing held for free): the members'
    /// consumed floors then cover exactly what was handed downstream.
    /// Snapshot, ack, broadcast one barrier per member id, resume.
    fn maybe_checkpoint(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let Some(epoch) = self.pending_epoch else { return };
        if self.recovering {
            return;
        }
        let quiesced = self
            .members
            .iter()
            .all(|s| s.consuming.is_none() && s.pending.is_empty() && s.pending_free.is_none());
        if !quiesced {
            return;
        }
        self.pending_epoch = None;
        let cp = self.params.checkpoint.as_ref().expect("barrier implies checkpointing");
        super::api::ack_barrier(cp, epoch, self.checkpoint(), self.params.cost.notify_ns, ctx);
        // Every downstream task aligns over all member channels: broadcast
        // the barrier on behalf of each member.
        for i in 0..self.params.members.len() {
            let from_task = self.params.members[i].task_idx;
            for &target in &self.params.downstream {
                let actor = self.registry.borrow().actor_of(target);
                ctx.send_in(
                    self.params.cost.queue_hop_ns,
                    actor,
                    Msg::Barrier { epoch, from_task },
                );
            }
        }
        for m in 0..self.members.len() {
            self.try_consume(m, ctx);
        }
    }

    // --------------------------------------------------------- recovery --

    fn on_fault(&mut self, ctx: &mut Ctx<'_, Msg>) {
        self.failed = true;
        self.pending_epoch = None;
        let cp = self.params.checkpoint.as_ref().unwrap_or_else(|| {
            panic!("push group {} faulted without checkpointing", self.params.leader_task_idx)
        });
        super::api::report_failure(cp, self.params.cost.notify_ns, ctx);
    }

    /// Global rollback. The push path cannot just rewind a cursor: tear
    /// down every member's subscription, sweep its objects, then
    /// resubscribe at the snapshot cursors and replay.
    fn begin_restore(&mut self, inc: u64, ctx: &mut Ctx<'_, Msg>) {
        if !self.all_subscribed() {
            // A subscribe (initial, or a hand-off's) is still in flight:
            // finish the handshake first (the ack completes it), then
            // restore.
            self.deferred_restore = Some(inc);
            self.failed = false;
            return;
        }
        self.inc = inc;
        self.failed = false;
        self.recovering = true;
        self.pending_epoch = None;
        // Discard every held object: their subscriptions are about to be
        // unsubscribed, whose `release_sealed` sweep reclaims the slots.
        for m in 0..self.members.len() {
            let ids: Vec<ObjectId> = {
                let s = &mut self.members[m];
                s.pending.clear();
                s.trace_keys.clear();
                s.ready
                    .drain(..)
                    .chain(s.consuming.take())
                    .chain(s.pending_free.take())
                    .collect()
            };
            for id in ids {
                self.discard_stale(id, ctx);
            }
        }
        let early: Vec<ObjectId> = std::mem::take(&mut self.early);
        for id in early {
            self.discard_stale(id, ctx);
        }
        self.ledger = CreditLedger::new(&self.params.downstream, self.params.queue_cap);
        self.rr = 0;
        // Roll the consumed floors and counters back to the snapshot.
        let cp = self.params.checkpoint.as_ref().expect("restore implies checkpointing");
        let snap = cp.borrow().source_snapshot(ctx.self_id());
        let consumed_total: u64 = self.members.iter().map(|s| s.records_consumed).sum();
        match snap {
            Some(snap) => {
                let mut at = 0;
                for (i, state) in self.members.iter_mut().enumerate() {
                    let n = state.consumed.len();
                    state.consumed = snap.cursors[at..at + n].to_vec();
                    at += n;
                    state.records_consumed =
                        snap.member_records.get(i).copied().unwrap_or(0);
                }
                debug_assert_eq!(at, snap.cursors.len());
            }
            None => {
                for (m, state) in self.params.members.iter().zip(self.members.iter_mut()) {
                    state.consumed = m.assignments.clone();
                    state.records_consumed = 0;
                }
            }
        }
        let rolled_back: u64 = self.members.iter().map(|s| s.records_consumed).sum();
        self.replayed += consumed_total.saturating_sub(rolled_back);
        // Tear down the old subscriptions (each at the broker holding it);
        // the acks gate the resubscribe. In-flight hand-offs fold in: the
        // recovery resubscribe re-resolves every member's home anyway.
        self.sub_to_member.clear();
        self.migrating.iter_mut().for_each(|f| *f = false);
        self.unsubs_pending = self.members.len();
        for m in 0..self.members.len() {
            let (sub, home, home_node) =
                self.member_sub[m].take().expect("restore starts fully subscribed");
            self.rpc_to(home, home_node, RpcKind::PushUnsubscribe { sub }, ctx);
        }
    }

    fn on_unsubscribed(&mut self, sub: SubId, ctx: &mut Ctx<'_, Msg>) {
        // Sweep: slots sealed after the drain (or lost by a crashed
        // incarnation) would otherwise never return to the pool.
        self.store.borrow_mut().release_sealed(sub);
        if self.recovering {
            self.unsubs_pending -= 1;
            if self.unsubs_pending == 0 {
                // Resubscribe at the restored cursors. Sub ids granted from
                // here on are the new incarnation's: their fills are replay
                // data, never freed.
                self.resub_floor = self.store.borrow().next_sub_id();
                let all: Vec<usize> = (0..self.members.len()).collect();
                self.subscribe_members(&all, ctx);
            }
            return;
        }
        // A hand-off unsubscribe: resubscribe the member at its consumed
        // floor on the new primary.
        let m = self.sub_to_member.remove(&sub).expect("hand-off of a mapped member");
        debug_assert!(self.migrating[m], "only migrating members unsubscribe live");
        self.subscribe_members(&[m], ctx);
    }

    fn on_subscribe_ack(&mut self, rpc: u64, sub: SubId, ctx: &mut Ctx<'_, Msg>) {
        let (home, home_node, list) =
            self.pending_subs.remove(&rpc).expect("ack matches a pending subscribe");
        for (k, &m) in list.iter().enumerate() {
            let granted = SubId(sub.0 + k);
            self.sub_to_member.insert(granted, m);
            self.member_sub[m] = Some((granted, home, home_node));
            self.migrating[m] = false;
        }
        if self.all_subscribed() {
            let was_recovering = std::mem::take(&mut self.recovering);
            if was_recovering {
                self.stale_floor = self.resub_floor;
                self.resub_floor = usize::MAX;
                let cp =
                    self.params.checkpoint.as_ref().expect("recovering implies checkpointing");
                super::api::ack_restore(cp, self.params.cost.notify_ns, ctx);
            }
        }
        // Deliver fills that raced ahead of this ack (including replay
        // fills queued during the recovery resubscribe).
        if !self.recovering {
            let early = std::mem::take(&mut self.early);
            for id in early {
                self.on_ready(id, ctx);
            }
        }
        if self.all_subscribed() {
            if let Some(inc) = self.deferred_restore.take() {
                self.begin_restore(inc, ctx);
            }
        }
    }

    // ---------------------------------------------------- introspection --

    pub fn objects_consumed(&self) -> u64 {
        self.members.iter().map(|m| m.objects_consumed).sum()
    }

    pub fn records_consumed(&self) -> u64 {
        self.members.iter().map(|m| m.records_consumed).sum()
    }

    /// Per-member records (partition-skew diagnostics).
    pub fn member_records(&self) -> Vec<u64> {
        self.members.iter().map(|m| m.records_consumed).collect()
    }

    pub fn is_subscribed(&self) -> bool {
        self.all_subscribed()
    }

    pub fn records_replayed(&self) -> u64 {
        self.replayed
    }
}

impl Actor<Msg> for PushSourceGroup {
    fn on_start(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let all: Vec<usize> = (0..self.members.len()).collect();
        self.subscribe_members(&all, ctx);
    }

    fn on_event(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        if self.failed {
            match msg {
                Msg::Restore { inc, .. } => self.begin_restore(inc, ctx),
                // A dead subscriber cannot consume fills; discarding them
                // (sealed until the recovery sweep) also pauses the
                // broker's fill pump via pool exhaustion.
                Msg::ObjectReady { id } => self.discard_stale(id, ctx),
                _ => {}
            }
            return;
        }
        match msg {
            Msg::Reply(env) => {
                let RpcEnvelope { id, reply } = *env;
                match reply {
                    RpcReply::SubscribeAck { sub } => self.on_subscribe_ack(id, sub, ctx),
                    RpcReply::UnsubscribeAck { sub, .. } => self.on_unsubscribed(sub, ctx),
                    RpcReply::WrongShard { .. } => {
                        // The subscribe raced a rebalance: refresh and
                        // re-issue for the members it covered (homes are
                        // re-resolved against the fresh table).
                        if let Some(client) = self.shard.as_mut() {
                            client.refresh();
                        }
                        let (_, _, list) = self
                            .pending_subs
                            .remove(&id)
                            .expect("refusal matches a pending subscribe");
                        self.subscribe_members(&list, ctx);
                    }
                    RpcReply::Error { reason } => panic!(
                        "push group {}: subscribe failed: {reason}",
                        self.params.leader_task_idx
                    ),
                    other => panic!("push group: unexpected reply {other:?}"),
                }
            }
            Msg::ShardEpoch { .. } => self.on_shard_epoch(ctx),
            // Step 3: the broker sealed an object for one of our members.
            Msg::ObjectReady { id } => self.on_ready(id, ctx),
            Msg::JobDone(tag) => {
                if tag / INC_STRIDE == self.inc {
                    self.on_consumed((tag % INC_STRIDE) as usize, ctx);
                }
            }
            Msg::Credit { to_upstream_task, inc } => {
                if inc != self.inc {
                    return; // credit for a pre-rollback batch: ledger was reset
                }
                self.ledger.refund(to_upstream_task);
                for m in 0..self.members.len() {
                    self.flush(m, ctx);
                }
            }
            Msg::BarrierInject { epoch } => {
                self.pending_epoch = Some(epoch);
                self.maybe_checkpoint(ctx);
            }
            Msg::Fault { .. } => self.on_fault(ctx),
            Msg::Restore { inc, .. } => self.begin_restore(inc, ctx),
            other => panic!("push group: unexpected {other:?}"),
        }
    }

    fn label(&self) -> String {
        format!("push-group(leader#{})", self.params.leader_task_idx)
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}

impl StreamSource for PushSourceGroup {
    fn mode(&self) -> SourceMode {
        SourceMode::Push
    }

    fn stats(&self) -> SourceStats {
        let mut extras = super::api::StatExtras::new();
        extras.insert(StatKey::ObjectsConsumed, self.objects_consumed());
        extras.insert(StatKey::Subscribed, self.all_subscribed() as u64);
        if self.replayed > 0 {
            extras.insert(StatKey::RecordsReplayed, self.replayed);
        }
        if self.broker_down_retries > 0 {
            extras.insert(StatKey::BrokerDownRetries, self.broker_down_retries);
        }
        SourceStats {
            records_consumed: self.records_consumed(),
            pulls_issued: 0,
            empty_pulls: 0,
            threads: 2, // group consume thread + broker push thread
            extras,
        }
    }

    fn checkpoint(&self) -> SourceSnapshot {
        SourceSnapshot {
            cursors: self.members.iter().flat_map(|s| s.consumed.iter().copied()).collect(),
            records_consumed: self.records_consumed(),
            matches: 0,
            member_records: self.member_records(),
        }
    }
}

/// Builds the single worker-local [`PushSourceGroup`] covering all `Nc`
/// logical source tasks (2 threads total — the Fig. 4 footprint claim).
pub struct PushSourceFactory;

impl SourceFactory for PushSourceFactory {
    fn mode(&self) -> SourceMode {
        SourceMode::Push
    }

    fn broker_push_threads(&self) -> usize {
        1
    }

    fn build(&self, w: &SourceWiring<'_>, engine: &mut Engine<Msg>) -> Vec<ActorId> {
        let c = w.config;
        let members: Vec<PushMember> = (0..c.nc)
            .map(|i| PushMember {
                task_idx: i,
                assignments: w.member_assignments(i),
                objects: c.push_objects_per_source,
                object_bytes: c.consumer_chunk as u64,
            })
            .collect();
        let group = PushSourceGroup::new(
            PushGroupParams {
                leader_task_idx: 0,
                node: w.node,
                broker: w.broker,
                broker_node: w.broker_node,
                members,
                downstream: w.downstream.clone(),
                queue_cap: c.queue_cap,
                checkpoint: w.checkpoint.clone(),
                cost: c.cost.clone(),
                shard: w.shard.clone(),
            },
            w.metrics.clone(),
            w.net.clone(),
            w.store.clone(),
            w.registry.clone(),
        );
        let id = engine.add_actor(Box::new(SourceActor::new(Box::new(group))));
        for i in 0..c.nc {
            w.registry.borrow_mut().register(i, id);
        }
        vec![id]
    }
}
