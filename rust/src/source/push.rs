//! The push-based source group (the paper's design, §IV-B).

use std::collections::{HashMap, VecDeque};

use crate::config::{CostModel, SourceMode};
use crate::net::{NodeId, SharedNetwork};
use crate::plasma::SharedStore;
use crate::proto::{
    Batch, ChunkOffset, Msg, ObjectId, PartitionId, PushSourceSpec, RpcEnvelope, RpcKind,
    RpcReply, RpcRequest, SubId,
};
use crate::sim::{Actor, ActorId, Ctx, Engine};
use crate::worker::{CreditLedger, SharedRegistry};

use super::api::{SourceActor, SourceFactory, SourceStats, SourceWiring, StatKey, StreamSource};

/// One logical push source task in the group (a consumer of the paper's
/// model: exclusive partitions, its own shared-object pool, its own slot
/// thread for materialising tuples out of shared objects).
#[derive(Debug, Clone)]
pub struct PushMember {
    /// Global task index of this logical source.
    pub task_idx: usize,
    pub assignments: Vec<(PartitionId, ChunkOffset)>,
    /// Object pool size (backpressure window).
    pub objects: usize,
    /// Object capacity — the push-path consumer chunk size.
    pub object_bytes: u64,
}

/// Wiring for the worker-local push source group.
#[derive(Debug, Clone)]
pub struct PushGroupParams {
    /// The leader's global task index (smallest member id in the paper) —
    /// the one task that issues the single subscription RPC and handles
    /// notifications.
    pub leader_task_idx: usize,
    pub node: NodeId,
    pub broker: ActorId,
    pub broker_node: NodeId,
    pub members: Vec<PushMember>,
    /// Mapper tasks fed round-robin (shared by all members).
    pub downstream: Vec<usize>,
    pub queue_cap: usize,
    pub cost: CostModel,
}

/// Per-member consume state: each member's slot thread materialises tuples
/// from its own sealed objects, concurrently with the other members.
#[derive(Debug, Default)]
struct MemberState {
    ready: VecDeque<ObjectId>,
    /// Object whose consume cost is currently being charged.
    consuming: Option<ObjectId>,
    /// Batches awaiting mapper credits; the object is freed only after
    /// they drain (backpressure propagates to the broker's push thread).
    pending: VecDeque<Batch>,
    pending_free: Option<ObjectId>,
    objects_consumed: u64,
    records_consumed: u64,
}

/// The group actor. One *extra* thread pair versus `2 × Nc` for pull:
/// the leader's subscription/notification thread here plus the broker's
/// dedicated push thread; the members' tuple materialisation runs on the
/// worker slots they already occupy (hence per-member concurrency).
pub struct PushSourceGroup {
    params: PushGroupParams,
    ledger: CreditLedger,
    members: Vec<MemberState>,
    /// SubId -> member index, resolved from the subscribe ack (the broker
    /// assigns consecutive sub ids in spec order).
    sub_to_member: HashMap<SubId, usize>,
    base_sub: Option<SubId>,
    /// Notifications that raced ahead of the subscribe ack.
    early: Vec<ObjectId>,
    subscribed: bool,
    rr: usize,
    net: SharedNetwork,
    store: SharedStore,
    registry: SharedRegistry,
}

impl PushSourceGroup {
    pub fn new(
        params: PushGroupParams,
        net: SharedNetwork,
        store: SharedStore,
        registry: SharedRegistry,
    ) -> Self {
        assert!(!params.members.is_empty());
        assert!(!params.downstream.is_empty());
        let ledger = CreditLedger::new(&params.downstream, params.queue_cap);
        let members = params.members.iter().map(|_| MemberState::default()).collect();
        Self {
            params,
            ledger,
            members,
            sub_to_member: HashMap::new(),
            base_sub: None,
            early: Vec::new(),
            subscribed: false,
            rr: 0,
            net,
            store,
            registry,
        }
    }

    /// Step 1: the single subscription RPC, issued by the leader on behalf
    /// of every member.
    fn subscribe(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let sources = self
            .params
            .members
            .iter()
            .map(|m| PushSourceSpec {
                source_actor: ctx.self_id(),
                assignments: m.assignments.clone(),
                objects: m.objects,
                object_bytes: m.object_bytes,
            })
            .collect();
        let deliver =
            self.net
                .borrow_mut()
                .send_control(ctx.now(), self.params.node, self.params.broker_node);
        ctx.send_at(
            deliver,
            self.params.broker,
            Msg::Rpc(RpcRequest {
                id: 0,
                reply_to: ctx.self_id(),
                from_node: self.params.node,
                kind: RpcKind::PushSubscribe { sources },
            }),
        );
    }

    fn member_of(&mut self, id: ObjectId) -> usize {
        let base = self.base_sub.expect("subscribed before notifications").0;
        let idx = id.sub.0 - base;
        debug_assert!(idx < self.members.len(), "sub {:?} not ours", id.sub);
        self.sub_to_member.entry(id.sub).or_insert(idx);
        idx
    }

    fn on_ready(&mut self, id: ObjectId, ctx: &mut Ctx<'_, Msg>) {
        if !self.subscribed {
            self.early.push(id);
            return;
        }
        let m = self.member_of(id);
        self.members[m].ready.push_back(id);
        self.try_consume(m, ctx);
    }

    /// Start the member's slot thread on its next sealed object.
    fn try_consume(&mut self, m: usize, ctx: &mut Ctx<'_, Msg>) {
        let state = &mut self.members[m];
        if state.consuming.is_some()
            || !state.pending.is_empty()
            || state.pending_free.is_some()
        {
            return;
        }
        let Some(id) = state.ready.pop_front() else { return };
        let (records, _bytes) = self.store.borrow().sealed_counts(id);
        // Pointer access into shared memory: tuples are materialised from
        // the shared object without a fetch RPC or a deser copy.
        let cost = self.params.cost.push_object_handle_ns
            + records * self.params.cost.push_consume_record_ns;
        state.consuming = Some(id);
        ctx.send_self_in(cost, Msg::JobDone(m as u64));
    }

    fn on_consumed(&mut self, m: usize, ctx: &mut Ctx<'_, Msg>) {
        let id = {
            let state = &mut self.members[m];
            state.consuming.take().expect("JobDone only while consuming")
        };
        let from_task = self.params.members[m].task_idx;
        {
            let store = self.store.borrow();
            let state = &mut self.members[m];
            for sc in store.read(id) {
                state.records_consumed += sc.chunk.records as u64;
                state.pending.push_back(Batch {
                    from_task,
                    tuples: sc.chunk.records as u64,
                    bytes: sc.chunk.bytes(),
                    chunks: vec![sc.chunk.clone()],
                    hist: None,
                });
            }
            state.objects_consumed += 1;
        }
        self.members[m].pending_free = Some(id);
        self.flush(m, ctx);
    }

    /// Forward the member's batches under credits; once drained, notify the
    /// broker (Step 4) so the buffer is reused, then serve its next object.
    fn flush(&mut self, m: usize, ctx: &mut Ctx<'_, Msg>) {
        loop {
            let Some(batch) = ({
                let state = &mut self.members[m];
                state.pending.pop_front()
            }) else {
                break;
            };
            // Round-robin over the mappers, skipping credit-exhausted ones.
            let n = self.params.downstream.len();
            let Some(k) = (0..n)
                .map(|i| (self.rr + i) % n)
                .find(|&k| self.ledger.has(self.params.downstream[k]))
            else {
                self.members[m].pending.push_front(batch);
                return; // blocked: object stays held -> broker stalls
            };
            let target = self.params.downstream[k];
            self.rr = k + 1;
            self.ledger.spend(target);
            let actor = self.registry.borrow().actor_of(target);
            ctx.send_in(self.params.cost.queue_hop_ns, actor, Msg::Data(batch));
        }
        if let Some(id) = self.members[m].pending_free.take() {
            ctx.send_in(self.params.cost.notify_ns, self.params.broker, Msg::ObjectFreed { id });
        }
        self.try_consume(m, ctx);
    }

    pub fn objects_consumed(&self) -> u64 {
        self.members.iter().map(|m| m.objects_consumed).sum()
    }

    pub fn records_consumed(&self) -> u64 {
        self.members.iter().map(|m| m.records_consumed).sum()
    }

    /// Per-member records (partition-skew diagnostics).
    pub fn member_records(&self) -> Vec<u64> {
        self.members.iter().map(|m| m.records_consumed).collect()
    }

    pub fn is_subscribed(&self) -> bool {
        self.subscribed
    }
}

impl Actor<Msg> for PushSourceGroup {
    fn on_start(&mut self, ctx: &mut Ctx<'_, Msg>) {
        self.subscribe(ctx);
    }

    fn on_event(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        match msg {
            Msg::Reply(env) => {
                let RpcEnvelope { reply, .. } = env;
                match reply {
                    RpcReply::SubscribeAck { sub } => {
                        self.base_sub = Some(sub);
                        self.subscribed = true;
                        let early = std::mem::take(&mut self.early);
                        for id in early {
                            self.on_ready(id, ctx);
                        }
                    }
                    RpcReply::Error { reason } => panic!(
                        "push group {}: subscribe failed: {reason}",
                        self.params.leader_task_idx
                    ),
                    other => panic!("push group: unexpected reply {other:?}"),
                }
            }
            // Step 3: the broker sealed an object for one of our members.
            Msg::ObjectReady { id } => self.on_ready(id, ctx),
            Msg::JobDone(m) => self.on_consumed(m as usize, ctx),
            Msg::Credit { to_upstream_task } => {
                self.ledger.refund(to_upstream_task);
                for m in 0..self.members.len() {
                    self.flush(m, ctx);
                }
            }
            other => panic!("push group: unexpected {other:?}"),
        }
    }

    fn label(&self) -> String {
        format!("push-group(leader#{})", self.params.leader_task_idx)
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}

impl StreamSource for PushSourceGroup {
    fn mode(&self) -> SourceMode {
        SourceMode::Push
    }

    fn stats(&self) -> SourceStats {
        let mut extras = super::api::StatExtras::new();
        extras.insert(StatKey::ObjectsConsumed, self.objects_consumed());
        extras.insert(StatKey::Subscribed, self.subscribed as u64);
        SourceStats {
            records_consumed: self.records_consumed(),
            pulls_issued: 0,
            empty_pulls: 0,
            threads: 2, // group consume thread + broker push thread
            extras,
        }
    }
}

/// Builds the single worker-local [`PushSourceGroup`] covering all `Nc`
/// logical source tasks (2 threads total — the Fig. 4 footprint claim).
pub struct PushSourceFactory;

impl SourceFactory for PushSourceFactory {
    fn mode(&self) -> SourceMode {
        SourceMode::Push
    }

    fn broker_push_threads(&self) -> usize {
        1
    }

    fn build(&self, w: &SourceWiring<'_>, engine: &mut Engine<Msg>) -> Vec<ActorId> {
        let c = w.config;
        let members: Vec<PushMember> = (0..c.nc)
            .map(|i| PushMember {
                task_idx: i,
                assignments: w.member_assignments(i),
                objects: c.push_objects_per_source,
                object_bytes: c.consumer_chunk as u64,
            })
            .collect();
        let group = PushSourceGroup::new(
            PushGroupParams {
                leader_task_idx: 0,
                node: w.node,
                broker: w.broker,
                broker_node: w.broker_node,
                members,
                downstream: w.downstream.clone(),
                queue_cap: c.queue_cap,
                cost: c.cost.clone(),
            },
            w.net.clone(),
            w.store.clone(),
            w.registry.clone(),
        );
        let id = engine.add_actor(Box::new(SourceActor::new(Box::new(group))));
        for i in 0..c.nc {
            w.registry.borrow_mut().register(i, id);
        }
        vec![id]
    }
}
