//! The pull-based source reader (state-of-the-art baseline).
//!
//! Checkpointing (see [`crate::checkpoint`]) is where pulling shines: the
//! source's own `offsets` *are* its restart position. A barrier is taken at
//! the next clean point of the serial fetch loop — everything fetched has
//! been emitted, nothing is half-processed — by snapshotting the offsets,
//! broadcasting the barrier downstream and acking the coordinator. A
//! restore simply rewinds the offsets (and the exactly-once counters) to
//! the latest completed snapshot and re-pulls.

use crate::checkpoint::{SharedCheckpoint, SourceSnapshot};
use crate::config::{CostModel, SourceMode};
use crate::metrics::{Class, SharedMetrics};
use crate::net::{NodeId, SharedNetwork};
use crate::proto::{
    Batch, ChunkOffset, Msg, PartitionId, RpcEnvelope, RpcKind, RpcReply, RpcRequest,
    StampedChunk,
};
use crate::shard::ShardClient;
use crate::sim::{Actor, ActorId, Ctx, Engine, Time};
use std::collections::VecDeque;

use super::api::{
    SourceActor, SourceFactory, SourceStats, SourceWiring, StatKey, StreamSource,
};
use crate::worker::{CreditLedger, SharedRegistry};

/// Wiring for one pull source task.
#[derive(Debug, Clone)]
pub struct PullParams {
    /// Global task index (upstream id for credits) == metrics entity.
    pub task_idx: usize,
    pub node: NodeId,
    pub broker: ActorId,
    pub broker_node: NodeId,
    /// Exclusive partitions with starting offsets.
    pub assignments: Vec<(PartitionId, ChunkOffset)>,
    /// Consumer `CS`: byte budget **per partition** per pull RPC.
    pub max_bytes: u64,
    /// Poll backoff when a pull returns empty.
    pub pull_timeout: Time,
    /// Mapper tasks this source feeds (round-robin).
    pub downstream: Vec<usize>,
    /// Credits per downstream (queue capacity).
    pub queue_cap: usize,
    /// Checkpoint blackboard (`None` = checkpointing disabled).
    pub checkpoint: Option<SharedCheckpoint>,
    pub cost: CostModel,
    /// The published shard view when `broker_count > 1` (a consumer's
    /// contiguous span always lives on one primary, so each pull has a
    /// single destination).
    pub shard: Option<crate::shard::SharedShard>,
    /// Per-RPC deadline (`rpc_deadline_ms`): a pull unanswered this long
    /// is checked against the coordinator's down mask and reissued at the
    /// same cursors once its broker is declared dead. 0 or an unsharded
    /// run disables the deadline plane.
    pub rpc_deadline_ns: Time,
}

enum State {
    /// RPC in flight.
    Fetching,
    /// Deserialising the fetched chunks.
    Processing(Vec<StampedChunk>),
    /// Stalled: batches wait for mapper credits (backpressure).
    Blocked,
    /// Empty poll: waiting out the pull timeout.
    Idle,
}

/// The pull source actor: a serial fetch → deserialise → emit loop.
pub struct PullSource {
    params: PullParams,
    offsets: Vec<(PartitionId, ChunkOffset)>,
    ledger: CreditLedger,
    state: State,
    rr: usize,
    next_rpc: u64,
    pending: VecDeque<Batch>,
    /// Mirror of `pending` while tracing: each batch's chunk identity
    /// `(partition, offset)`, handed to the tracer's marker FIFO at send
    /// time. Stays empty when tracing is off.
    trace_keys: VecDeque<Option<(usize, u64)>>,
    /// Barrier waiting for the next clean point of the fetch loop.
    pending_epoch: Option<u64>,
    /// Recovery incarnation; stale-tagged messages are dropped.
    inc: u64,
    /// Dead between an injected fault and the restore.
    failed: bool,
    /// Replies to RPCs issued before the last restore are stale.
    rpc_floor: u64,
    pulls_issued: u64,
    empty_pulls: u64,
    records_consumed: u64,
    /// The pull currently awaiting its reply (deadline staleness check).
    inflight_pull: Option<u64>,
    /// Transmissions of the current logical pull (backoff escalation).
    pull_attempts: u32,
    /// Pulls reissued after their broker was declared dead.
    broker_down_retries: u64,
    /// Records re-read after rollbacks (exactly-once replay volume).
    replayed: u64,
    /// Chunks lost to retention and skipped (trim-floor recovery).
    trim_gap_chunks: u64,
    metrics: SharedMetrics,
    net: SharedNetwork,
    registry: SharedRegistry,
    /// Cached shard routing when `broker_count > 1`.
    shard: Option<ShardClient>,
}

impl PullSource {
    pub fn new(
        params: PullParams,
        metrics: SharedMetrics,
        net: SharedNetwork,
        registry: SharedRegistry,
    ) -> Self {
        assert!(!params.assignments.is_empty());
        assert!(!params.downstream.is_empty());
        let offsets = params.assignments.clone();
        let ledger = CreditLedger::new(&params.downstream, params.queue_cap);
        let shard = params.shard.as_ref().map(ShardClient::new);
        Self {
            params,
            offsets,
            ledger,
            state: State::Idle,
            rr: 0,
            next_rpc: 0,
            pending: VecDeque::new(),
            trace_keys: VecDeque::new(),
            pending_epoch: None,
            inc: 0,
            failed: false,
            rpc_floor: 0,
            pulls_issued: 0,
            empty_pulls: 0,
            records_consumed: 0,
            inflight_pull: None,
            pull_attempts: 0,
            broker_down_retries: 0,
            replayed: 0,
            trim_gap_chunks: 0,
            metrics,
            net,
            registry,
            shard,
        }
    }

    /// The broker serving this source's span (re-resolved per pull, so a
    /// refreshed table re-routes the next fetch).
    fn home(&self) -> (ActorId, NodeId) {
        match &self.shard {
            Some(client) => client.broker_for(self.offsets[0].0),
            None => (self.params.broker, self.params.broker_node),
        }
    }

    /// Exponential per-RPC deadline: base × 2^(attempts-1), capped.
    fn deadline_for(&self, attempts: u32) -> Time {
        self.params.rpc_deadline_ns.saturating_mul(1 << attempts.saturating_sub(1).min(6))
    }

    fn issue_pull(&mut self, ctx: &mut Ctx<'_, Msg>) {
        self.maybe_checkpoint(ctx);
        let id = self.next_rpc;
        self.next_rpc += 1;
        self.pulls_issued += 1;
        self.inflight_pull = Some(id);
        self.pull_attempts += 1;
        if self.shard.is_some() && self.params.rpc_deadline_ns > 0 {
            let d = self.deadline_for(self.pull_attempts);
            ctx.send_self_in(d, Msg::Timer(id | crate::producer::DEADLINE_TAG));
        }
        self.metrics.borrow_mut().record(Class::PullRpcs, self.params.task_idx, ctx.now(), 1);
        let (to, to_node) = self.home();
        // The request itself is a control message (tiny payload).
        let deliver = self.net.borrow_mut().send_control(ctx.now(), self.params.node, to_node);
        ctx.send_at(
            deliver,
            to,
            Msg::rpc(RpcRequest {
                id,
                reply_to: ctx.self_id(),
                from_node: self.params.node,
                kind: RpcKind::Pull {
                    assignments: self.offsets.clone(),
                    max_bytes: self.params.max_bytes,
                },
            }),
        );
        self.state = State::Fetching;
    }

    /// Take a pending barrier at a clean point: `pending` is empty and no
    /// fetched chunks await processing, so `offsets` cover exactly what was
    /// emitted. Snapshot, ack the coordinator, broadcast the barrier.
    fn maybe_checkpoint(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let Some(epoch) = self.pending_epoch else { return };
        debug_assert!(self.pending.is_empty(), "clean points have an empty emit queue");
        self.pending_epoch = None;
        let cp = self.params.checkpoint.as_ref().expect("barrier implies checkpointing");
        super::api::ack_barrier(cp, epoch, self.checkpoint(), self.params.cost.notify_ns, ctx);
        for &target in &self.params.downstream {
            let actor = self.registry.borrow().actor_of(target);
            ctx.send_in(
                self.params.cost.queue_hop_ns,
                actor,
                Msg::Barrier { epoch, from_task: self.params.task_idx },
            );
        }
    }

    /// A pull unanswered past its deadline. A dead broker drops
    /// everything, so once the coordinator's down mask names the serving
    /// broker the RPC is lost: refresh the cached table and reissue the
    /// same pull — same cursors, new rpc id — against the promoted
    /// primary. Reads are idempotent, so the reissue is exactly-once by
    /// construction; the rpc floor strands any straggler reply from the
    /// corpse. Until the detector declares the broker, re-arm and wait.
    fn on_deadline(&mut self, rpc: u64, ctx: &mut Ctx<'_, Msg>) {
        if self.inflight_pull != Some(rpc) || !matches!(self.state, State::Fetching) {
            return; // answered or already reissued: stale timer
        }
        let (home, _) = self.home();
        if self.shard.as_ref().is_some_and(|c| c.actor_down(home)) {
            self.shard.as_mut().expect("down mask implies sharded").refresh();
            self.broker_down_retries += 1;
            self.rpc_floor = self.next_rpc;
            self.issue_pull(ctx);
        } else {
            let d = self.deadline_for(self.pull_attempts);
            ctx.send_self_in(d, Msg::Timer(rpc | crate::producer::DEADLINE_TAG));
        }
    }

    fn on_reply(&mut self, env: RpcEnvelope, ctx: &mut Ctx<'_, Msg>) {
        if env.id < self.rpc_floor {
            return; // reply to a pre-restore pull: the cursor was rewound
        }
        self.inflight_pull = None;
        self.pull_attempts = 0;
        let (chunks, trims) = match env.reply {
            RpcReply::PullData { chunks, trims } => (chunks, trims),
            RpcReply::WrongShard { .. } => {
                // The span moved mid-flight: refresh the cached table and
                // re-poll after the timeout — the next pull re-resolves the
                // primary. Cursors are untouched, so nothing is lost.
                if let Some(client) = self.shard.as_mut() {
                    client.refresh();
                }
                self.maybe_checkpoint(ctx);
                self.state = State::Idle;
                ctx.send_self_in(self.params.pull_timeout, Msg::Timer(self.inc));
                return;
            }
            RpcReply::Error { reason } => {
                panic!("pull source {}: {reason}", self.params.task_idx)
            }
            other => panic!("pull source {}: unexpected reply {other:?}", self.params.task_idx),
        };
        self.trim_gap_chunks += super::api::apply_trims(&mut self.offsets, &trims);
        if chunks.is_empty() {
            self.empty_pulls += 1;
            if self.metrics.borrow().tracer.enabled() {
                self.metrics.borrow_mut().tracer.note_empty_poll(ctx.now());
            }
            self.maybe_checkpoint(ctx);
            self.state = State::Idle;
            ctx.send_self_in(self.params.pull_timeout, Msg::Timer(self.inc));
            return;
        }
        // Advance offsets past what we received.
        for sc in &chunks {
            for (p, off) in self.offsets.iter_mut() {
                if *p == sc.partition {
                    *off = (*off).max(sc.offset + 1);
                }
            }
        }
        if self.metrics.borrow().tracer.enabled() {
            let mut m = self.metrics.borrow_mut();
            for sc in &chunks {
                m.tracer.on_notify(sc.partition.0, sc.offset, ctx.now());
            }
        }
        let records: u64 = chunks.iter().map(|c| c.chunk.records as u64).sum();
        // Serial consume loop: per-RPC client overhead + per-record
        // deserialisation — the cost the push path eliminates.
        let cost = self.params.cost.pull_rpc_client_ns
            + records * self.params.cost.engine_record_ns;
        self.state = State::Processing(chunks);
        ctx.send_self_in(cost, Msg::JobDone(self.inc));
    }

    fn on_processed(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let State::Processing(chunks) = std::mem::replace(&mut self.state, State::Blocked) else {
            panic!("pull source {}: JobDone outside Processing", self.params.task_idx)
        };
        let tracing = self.metrics.borrow().tracer.enabled();
        for sc in chunks {
            self.records_consumed += sc.chunk.records as u64;
            if tracing {
                self.trace_keys.push_back(Some((sc.partition.0, sc.offset)));
            }
            // One batch per chunk, chunk inline — the fetched payload is
            // shared into the pipeline, never copied (see `ChunkList`).
            self.pending.push_back(Batch {
                from_task: self.params.task_idx,
                tuples: sc.chunk.records as u64,
                chunks: crate::proto::ChunkList::One(sc.chunk),
                hist: None,
                inc: self.inc,
            });
        }
        self.flush(ctx);
    }

    /// Send pending batches while credits allow; when drained, loop back to
    /// the next pull.
    fn flush(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let tracing = self.metrics.borrow().tracer.enabled();
        while !self.pending.is_empty() {
            // Round-robin over the mappers, skipping credit-exhausted ones.
            let n = self.params.downstream.len();
            let Some(k) = (0..n)
                .map(|i| (self.rr + i) % n)
                .find(|&k| self.ledger.has(self.params.downstream[k]))
            else {
                self.state = State::Blocked;
                if tracing {
                    self.metrics.borrow_mut().tracer.note_credit_stall(ctx.now());
                }
                return;
            };
            let target = self.params.downstream[k];
            self.rr = k + 1;
            self.ledger.spend(target);
            let batch = self.pending.pop_front().expect("checked non-empty");
            if tracing {
                let key = self.trace_keys.pop_front().flatten();
                self.metrics.borrow_mut().tracer.on_handoff(
                    key,
                    self.params.task_idx,
                    target,
                    ctx.now(),
                );
            }
            let actor = self.registry.borrow().actor_of(target);
            ctx.send_in(self.params.cost.queue_hop_ns, actor, Msg::Data(batch));
        }
        self.issue_pull(ctx);
    }

    /// An injected fault: volatile state dies; the failure detector alerts
    /// the coordinator; everything but `Restore` is ignored until then.
    fn on_fault(&mut self, ctx: &mut Ctx<'_, Msg>) {
        self.failed = true;
        self.pending.clear();
        self.trace_keys.clear();
        self.pending_epoch = None;
        let cp = self.params.checkpoint.as_ref().unwrap_or_else(|| {
            panic!("pull source {} faulted without checkpointing", self.params.task_idx)
        });
        super::api::report_failure(cp, self.params.cost.notify_ns, ctx);
    }

    /// Global rollback: rewind the cursors and the exactly-once counters
    /// to the latest completed snapshot (or the initial assignments) and
    /// resume pulling under the new incarnation.
    fn on_restore(&mut self, inc: u64, ctx: &mut Ctx<'_, Msg>) {
        self.inc = inc;
        self.failed = false;
        self.pending.clear();
        self.trace_keys.clear();
        self.pending_epoch = None;
        self.ledger = CreditLedger::new(&self.params.downstream, self.params.queue_cap);
        self.rr = 0;
        self.rpc_floor = self.next_rpc;
        self.inflight_pull = None;
        self.pull_attempts = 0;
        let cp = self.params.checkpoint.as_ref().expect("restore implies checkpointing");
        let snap = cp.borrow().source_snapshot(ctx.self_id()).unwrap_or(SourceSnapshot {
            cursors: self.params.assignments.clone(),
            ..Default::default()
        });
        debug_assert_eq!(snap.cursors.len(), self.offsets.len());
        self.offsets = snap.cursors;
        let replay = self.records_consumed.saturating_sub(snap.records_consumed);
        self.replayed += replay;
        self.records_consumed = snap.records_consumed;
        super::api::ack_restore(cp, self.params.cost.notify_ns, ctx);
        self.issue_pull(ctx);
    }

    pub fn pulls_issued(&self) -> u64 {
        self.pulls_issued
    }

    pub fn empty_pulls(&self) -> u64 {
        self.empty_pulls
    }

    pub fn records_consumed(&self) -> u64 {
        self.records_consumed
    }

    pub fn trim_gap_chunks(&self) -> u64 {
        self.trim_gap_chunks
    }

    pub fn records_replayed(&self) -> u64 {
        self.replayed
    }
}

impl Actor<Msg> for PullSource {
    fn on_start(&mut self, ctx: &mut Ctx<'_, Msg>) {
        self.issue_pull(ctx);
    }

    fn on_event(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        if self.failed {
            if let Msg::Restore { inc, .. } = msg {
                self.on_restore(inc, ctx);
            }
            return;
        }
        match msg {
            Msg::Reply(env) => self.on_reply(*env, ctx),
            Msg::JobDone(tag) => {
                if tag == self.inc {
                    self.on_processed(ctx);
                }
            }
            Msg::Timer(tag) if tag & crate::producer::DEADLINE_TAG != 0 => {
                self.on_deadline(tag & !crate::producer::DEADLINE_TAG, ctx)
            }
            Msg::Timer(tag) => {
                if tag == self.inc && matches!(self.state, State::Idle) {
                    self.issue_pull(ctx);
                }
            }
            Msg::Credit { to_upstream_task, inc } => {
                if inc != self.inc {
                    return; // credit for a pre-rollback batch: ledger was reset
                }
                self.ledger.refund(to_upstream_task);
                if matches!(self.state, State::Blocked) {
                    self.flush(ctx);
                }
            }
            Msg::BarrierInject { epoch } => {
                self.pending_epoch = Some(epoch);
                // Fetching/Idle are already clean (nothing staged, nothing
                // pending); otherwise the next issue_pull takes it.
                if matches!(self.state, State::Fetching | State::Idle) {
                    self.maybe_checkpoint(ctx);
                }
            }
            Msg::ShardEpoch { .. } => {
                // Coordinator published a new table: refresh eagerly so the
                // next pull routes to the new primary without a refusal.
                if let Some(client) = self.shard.as_mut() {
                    client.refresh();
                }
            }
            Msg::Fault { .. } => self.on_fault(ctx),
            Msg::Restore { inc, .. } => self.on_restore(inc, ctx),
            other => panic!("pull source {}: unexpected {other:?}", self.params.task_idx),
        }
    }

    fn label(&self) -> String {
        format!("pull-source#{}", self.params.task_idx)
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}

impl StreamSource for PullSource {
    fn mode(&self) -> SourceMode {
        SourceMode::Pull
    }

    fn stats(&self) -> SourceStats {
        let mut extras = super::api::StatExtras::new();
        if self.replayed > 0 {
            extras.insert(StatKey::RecordsReplayed, self.replayed);
        }
        if self.trim_gap_chunks > 0 {
            extras.insert(StatKey::TrimGapChunks, self.trim_gap_chunks);
        }
        if self.broker_down_retries > 0 {
            extras.insert(StatKey::BrokerDownRetries, self.broker_down_retries);
        }
        SourceStats {
            records_consumed: self.records_consumed,
            pulls_issued: self.pulls_issued,
            empty_pulls: self.empty_pulls,
            threads: 2, // fetch + emit threads per pull consumer
            extras,
        }
    }

    fn checkpoint(&self) -> SourceSnapshot {
        SourceSnapshot {
            cursors: self.offsets.clone(),
            records_consumed: self.records_consumed,
            ..Default::default()
        }
    }
}

/// Builds one [`PullSource`] per consumer (`Nc` total, 2 threads each).
pub struct PullSourceFactory;

impl SourceFactory for PullSourceFactory {
    fn mode(&self) -> SourceMode {
        SourceMode::Pull
    }

    fn build(&self, w: &SourceWiring<'_>, engine: &mut Engine<Msg>) -> Vec<ActorId> {
        let c = w.config;
        (0..c.nc)
            .map(|i| {
                let src = PullSource::new(
                    PullParams {
                        task_idx: i,
                        node: w.node,
                        broker: w.broker,
                        broker_node: w.broker_node,
                        assignments: w.member_assignments(i),
                        max_bytes: c.consumer_chunk as u64,
                        pull_timeout: c.pull_timeout_us * 1_000,
                        downstream: w.downstream.clone(),
                        queue_cap: c.queue_cap,
                        checkpoint: w.checkpoint.clone(),
                        cost: c.cost.clone(),
                        shard: w.shard.clone(),
                        rpc_deadline_ns: c.rpc_deadline_ms * crate::sim::MILLIS,
                    },
                    w.metrics.clone(),
                    w.net.clone(),
                    w.registry.clone(),
                );
                let id = engine.add_actor(Box::new(SourceActor::new(Box::new(src))));
                w.registry.borrow_mut().register(i, id);
                id
            })
            .collect()
    }
}
