//! The pull-based source reader (state-of-the-art baseline).

use crate::config::{CostModel, SourceMode};
use crate::metrics::{Class, SharedMetrics};
use crate::net::{NodeId, SharedNetwork};
use crate::proto::{
    Batch, ChunkOffset, Msg, PartitionId, RpcEnvelope, RpcKind, RpcReply, RpcRequest,
    StampedChunk,
};
use crate::sim::{Actor, ActorId, Ctx, Engine, Time};
use std::collections::VecDeque;

use super::api::{SourceActor, SourceFactory, SourceStats, SourceWiring, StreamSource};
use crate::worker::{CreditLedger, SharedRegistry};

/// Wiring for one pull source task.
#[derive(Debug, Clone)]
pub struct PullParams {
    /// Global task index (upstream id for credits) == metrics entity.
    pub task_idx: usize,
    pub node: NodeId,
    pub broker: ActorId,
    pub broker_node: NodeId,
    /// Exclusive partitions with starting offsets.
    pub assignments: Vec<(PartitionId, ChunkOffset)>,
    /// Consumer `CS`: byte budget **per partition** per pull RPC.
    pub max_bytes: u64,
    /// Poll backoff when a pull returns empty.
    pub pull_timeout: Time,
    /// Mapper tasks this source feeds (round-robin).
    pub downstream: Vec<usize>,
    /// Credits per downstream (queue capacity).
    pub queue_cap: usize,
    pub cost: CostModel,
}

enum State {
    /// RPC in flight.
    Fetching,
    /// Deserialising the fetched chunks.
    Processing(Vec<StampedChunk>),
    /// Stalled: batches wait for mapper credits (backpressure).
    Blocked,
    /// Empty poll: waiting out the pull timeout.
    Idle,
}

/// The pull source actor: a serial fetch → deserialise → emit loop.
pub struct PullSource {
    params: PullParams,
    offsets: Vec<(PartitionId, ChunkOffset)>,
    ledger: CreditLedger,
    state: State,
    rr: usize,
    next_rpc: u64,
    pending: VecDeque<Batch>,
    pulls_issued: u64,
    empty_pulls: u64,
    records_consumed: u64,
    metrics: SharedMetrics,
    net: SharedNetwork,
    registry: SharedRegistry,
}

impl PullSource {
    pub fn new(
        params: PullParams,
        metrics: SharedMetrics,
        net: SharedNetwork,
        registry: SharedRegistry,
    ) -> Self {
        assert!(!params.assignments.is_empty());
        assert!(!params.downstream.is_empty());
        let offsets = params.assignments.clone();
        let ledger = CreditLedger::new(&params.downstream, params.queue_cap);
        Self {
            params,
            offsets,
            ledger,
            state: State::Idle,
            rr: 0,
            next_rpc: 0,
            pending: VecDeque::new(),
            pulls_issued: 0,
            empty_pulls: 0,
            records_consumed: 0,
            metrics,
            net,
            registry,
        }
    }

    fn issue_pull(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let id = self.next_rpc;
        self.next_rpc += 1;
        self.pulls_issued += 1;
        self.metrics.borrow_mut().record(Class::PullRpcs, self.params.task_idx, ctx.now(), 1);
        // The request itself is a control message (tiny payload).
        let deliver =
            self.net
                .borrow_mut()
                .send_control(ctx.now(), self.params.node, self.params.broker_node);
        ctx.send_at(
            deliver,
            self.params.broker,
            Msg::Rpc(RpcRequest {
                id,
                reply_to: ctx.self_id(),
                from_node: self.params.node,
                kind: RpcKind::Pull {
                    assignments: self.offsets.clone(),
                    max_bytes: self.params.max_bytes,
                },
            }),
        );
        self.state = State::Fetching;
    }

    fn on_reply(&mut self, env: RpcEnvelope, ctx: &mut Ctx<'_, Msg>) {
        let chunks = match env.reply {
            RpcReply::PullData { chunks } => chunks,
            RpcReply::Error { reason } => {
                panic!("pull source {}: {reason}", self.params.task_idx)
            }
            other => panic!("pull source {}: unexpected reply {other:?}", self.params.task_idx),
        };
        if chunks.is_empty() {
            self.empty_pulls += 1;
            self.state = State::Idle;
            ctx.send_self_in(self.params.pull_timeout, Msg::Timer(0));
            return;
        }
        // Advance offsets past what we received.
        for sc in &chunks {
            for (p, off) in self.offsets.iter_mut() {
                if *p == sc.partition {
                    *off = (*off).max(sc.offset + 1);
                }
            }
        }
        let records: u64 = chunks.iter().map(|c| c.chunk.records as u64).sum();
        // Serial consume loop: per-RPC client overhead + per-record
        // deserialisation — the cost the push path eliminates.
        let cost = self.params.cost.pull_rpc_client_ns
            + records * self.params.cost.engine_record_ns;
        self.state = State::Processing(chunks);
        ctx.send_self_in(cost, Msg::JobDone(0));
    }

    fn on_processed(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let State::Processing(chunks) = std::mem::replace(&mut self.state, State::Blocked) else {
            panic!("pull source {}: JobDone outside Processing", self.params.task_idx)
        };
        for sc in chunks {
            self.records_consumed += sc.chunk.records as u64;
            self.pending.push_back(Batch {
                from_task: self.params.task_idx,
                tuples: sc.chunk.records as u64,
                bytes: sc.chunk.bytes(),
                chunks: vec![sc.chunk],
                hist: None,
            });
        }
        self.flush(ctx);
    }

    /// Send pending batches while credits allow; when drained, loop back to
    /// the next pull.
    fn flush(&mut self, ctx: &mut Ctx<'_, Msg>) {
        while !self.pending.is_empty() {
            // Round-robin over the mappers, skipping credit-exhausted ones.
            let n = self.params.downstream.len();
            let Some(k) = (0..n)
                .map(|i| (self.rr + i) % n)
                .find(|&k| self.ledger.has(self.params.downstream[k]))
            else {
                self.state = State::Blocked;
                return;
            };
            let target = self.params.downstream[k];
            self.rr = k + 1;
            self.ledger.spend(target);
            let batch = self.pending.pop_front().expect("checked non-empty");
            let actor = self.registry.borrow().actor_of(target);
            ctx.send_in(self.params.cost.queue_hop_ns, actor, Msg::Data(batch));
        }
        self.issue_pull(ctx);
    }

    pub fn pulls_issued(&self) -> u64 {
        self.pulls_issued
    }

    pub fn empty_pulls(&self) -> u64 {
        self.empty_pulls
    }

    pub fn records_consumed(&self) -> u64 {
        self.records_consumed
    }
}

impl Actor<Msg> for PullSource {
    fn on_start(&mut self, ctx: &mut Ctx<'_, Msg>) {
        self.issue_pull(ctx);
    }

    fn on_event(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        match msg {
            Msg::Reply(env) => self.on_reply(env, ctx),
            Msg::JobDone(_) => self.on_processed(ctx),
            Msg::Timer(_) => {
                if matches!(self.state, State::Idle) {
                    self.issue_pull(ctx);
                }
            }
            Msg::Credit { to_upstream_task } => {
                self.ledger.refund(to_upstream_task);
                if matches!(self.state, State::Blocked) {
                    self.flush(ctx);
                }
            }
            other => panic!("pull source {}: unexpected {other:?}", self.params.task_idx),
        }
    }

    fn label(&self) -> String {
        format!("pull-source#{}", self.params.task_idx)
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}

impl StreamSource for PullSource {
    fn mode(&self) -> SourceMode {
        SourceMode::Pull
    }

    fn stats(&self) -> SourceStats {
        SourceStats {
            records_consumed: self.records_consumed,
            pulls_issued: self.pulls_issued,
            empty_pulls: self.empty_pulls,
            threads: 2, // fetch + emit threads per pull consumer
            extras: Default::default(),
        }
    }
}

/// Builds one [`PullSource`] per consumer (`Nc` total, 2 threads each).
pub struct PullSourceFactory;

impl SourceFactory for PullSourceFactory {
    fn mode(&self) -> SourceMode {
        SourceMode::Pull
    }

    fn build(&self, w: &SourceWiring<'_>, engine: &mut Engine<Msg>) -> Vec<ActorId> {
        let c = w.config;
        (0..c.nc)
            .map(|i| {
                let src = PullSource::new(
                    PullParams {
                        task_idx: i,
                        node: w.node,
                        broker: w.broker,
                        broker_node: w.broker_node,
                        assignments: w.member_assignments(i),
                        max_bytes: c.consumer_chunk as u64,
                        pull_timeout: c.pull_timeout_us * 1_000,
                        downstream: w.downstream.clone(),
                        queue_cap: c.queue_cap,
                        cost: c.cost.clone(),
                    },
                    w.metrics.clone(),
                    w.net.clone(),
                    w.registry.clone(),
                );
                let id = engine.add_actor(Box::new(SourceActor::new(Box::new(src))));
                w.registry.borrow_mut().register(i, id);
                id
            })
            .collect()
    }
}
