//! The unified source API: one trait, one stats shape, one registry.
//!
//! The engine/storage decoupling the stream-processing literature calls
//! for (Fragkoulis et al., 2020) lands here as three pieces:
//!
//! * [`StreamSource`] — the lifecycle + introspection contract every
//!   source reader implements. A source is wired by its factory, started
//!   by the engine (`Actor::on_start`), and reports uniform
//!   [`SourceStats`] when the run ends.
//! * [`SourceActor`] — the type-erased actor the launcher registers. The
//!   cluster only ever sees `SourceActor`s, so end-of-run stats extraction
//!   is a single downcast with a hard error — no per-concrete-type chain,
//!   no silently dropped stats.
//! * [`SourceFactory`] + [`SourceRegistry`] — the pluggable construction
//!   path, keyed by [`SourceMode`]. `cluster::launch` resolves the
//!   configured mode against the registry and builds sources through one
//!   generic code path; registering a new ingestion mechanism never
//!   touches the engine (the Uber connector-registry pattern).

use std::any::Any;
use std::collections::BTreeMap;

use crate::checkpoint::{SharedCheckpoint, SourceSnapshot};
use crate::compute::SharedCompute;
use crate::config::{ExperimentConfig, SourceMode};
use crate::metrics::SharedMetrics;
use crate::net::{NodeId, SharedNetwork};
use crate::plasma::SharedStore;
use crate::proto::{ChunkOffset, Msg, PartitionId};
use crate::sim::{Actor, ActorId, Ctx, Engine, Time};
use crate::worker::SharedRegistry;

/// Typed keys for the per-mode counters a [`SourceStats`] may carry beyond
/// the uniform core.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum StatKey {
    /// Shared-memory objects consumed (push path).
    ObjectsConsumed,
    /// Grep matches counted in place (native consumers).
    Matches,
    /// 1 while the source is operating on the push subscription.
    Subscribed,
    /// Pull→push transitions taken (hybrid).
    SwitchesToPush,
    /// Push→pull transitions taken (hybrid).
    SwitchesToPull,
    /// Records re-read and re-processed after recovery rollbacks — the
    /// exactly-once replay volume (reported only when non-zero).
    RecordsReplayed,
    /// Chunks lost to retention the source skipped over (trim-floor
    /// recovery on the pull path; reported only when non-zero).
    TrimGapChunks,
    /// RPCs re-routed after their broker was declared dead: reissued
    /// pulls, push re-homes and forced pull fallbacks (reported only when
    /// non-zero). Unbounded like `WrongShard` retries — read cursors make
    /// the reissue idempotent, so counting is the only bookkeeping needed.
    BrokerDownRetries,
}

impl StatKey {
    pub fn name(&self) -> &'static str {
        match self {
            Self::ObjectsConsumed => "objects_consumed",
            Self::Matches => "matches",
            Self::Subscribed => "subscribed",
            Self::SwitchesToPush => "switches_to_push",
            Self::SwitchesToPull => "switches_to_pull",
            Self::RecordsReplayed => "records_replayed",
            Self::TrimGapChunks => "trim_gap_chunks",
            Self::BrokerDownRetries => "broker_down_retries",
        }
    }
}

/// The typed extension map for per-mode extras.
pub type StatExtras = BTreeMap<StatKey, u64>;

/// Skip `offsets` past retention-trimmed chunks reported by a pull reply
/// (`(partition, floor)` pairs, see `RpcReply::PullData::trims`); returns
/// the skipped gap in chunks. The uniform trim-floor recovery every
/// pull-capable source shares: never wedge, never silently lose the
/// partition — count what retention took ([`StatKey::TrimGapChunks`]).
pub fn apply_trims(
    offsets: &mut [(PartitionId, ChunkOffset)],
    trims: &[(PartitionId, ChunkOffset)],
) -> u64 {
    let mut gap = 0;
    for &(p, floor) in trims {
        for (sp, off) in offsets.iter_mut() {
            if *sp == p && floor > *off {
                gap += floor - *off;
                *off = floor;
            }
        }
    }
    gap
}

// The coordinator-handshake tails every source shares (each source keeps
// its own clean-point and barrier-broadcast logic — only the bookkeeping
// against the checkpoint blackboard is uniform).

/// Write `snap` as the source's `epoch` snapshot and ack the coordinator.
pub(crate) fn ack_barrier(
    cp: &SharedCheckpoint,
    epoch: u64,
    snap: SourceSnapshot,
    notify_ns: Time,
    ctx: &mut Ctx<'_, Msg>,
) {
    let coordinator = {
        let mut c = cp.borrow_mut();
        c.put_source(epoch, ctx.self_id(), snap);
        c.coordinator
    };
    if let Some(coordinator) = coordinator {
        ctx.send_in(notify_ns, coordinator, Msg::BarrierAck { epoch, from: ctx.self_id() });
    }
}

/// The failure detector: report an injected fault to the coordinator.
pub(crate) fn report_failure(cp: &SharedCheckpoint, notify_ns: Time, ctx: &mut Ctx<'_, Msg>) {
    let coordinator = cp.borrow().coordinator.expect("coordinator wired before faults");
    ctx.send_in(notify_ns, coordinator, Msg::FailureDetected { from: ctx.self_id() });
}

/// Tell the coordinator this source finished restoring and resumed.
pub(crate) fn ack_restore(cp: &SharedCheckpoint, notify_ns: Time, ctx: &mut Ctx<'_, Msg>) {
    let coordinator = cp.borrow().coordinator.expect("coordinator wired");
    ctx.send_in(notify_ns, coordinator, Msg::RestoreAck { from: ctx.self_id() });
}

/// Uniform end-of-run report every source returns. Core counters cover the
/// paper's resource-accounting axes; anything mode-specific lives in the
/// typed `extras` map.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SourceStats {
    /// Records this source handed to the pipeline (or counted in place).
    pub records_consumed: u64,
    /// Pull RPCs issued (push-phase sources report 0).
    pub pulls_issued: u64,
    /// Pulls that returned nothing (the poll-timeout tax).
    pub empty_pulls: u64,
    /// Threads the source occupies — the Fig. 4 footprint claim.
    pub threads: usize,
    /// Per-mode extras.
    pub extras: StatExtras,
}

impl SourceStats {
    /// An extra counter, defaulting to 0 when the mode doesn't report it.
    pub fn extra(&self, key: StatKey) -> u64 {
        self.extras.get(&key).copied().unwrap_or(0)
    }

    /// Fold another source's stats into this one (cluster aggregation).
    pub fn merge(&mut self, other: &SourceStats) {
        self.records_consumed += other.records_consumed;
        self.pulls_issued += other.pulls_issued;
        self.empty_pulls += other.empty_pulls;
        self.threads += other.threads;
        for (&k, &v) in &other.extras {
            *self.extras.entry(k).or_insert(0) += v;
        }
    }
}

/// The contract every source reader implements on top of being an actor.
/// Wiring happens in the factory's `build`, starting in `Actor::on_start`;
/// this trait adds the uniform introspection surface.
pub trait StreamSource: Actor<Msg> {
    /// The mode this source implements.
    fn mode(&self) -> SourceMode;

    /// Uniform end-of-run statistics.
    fn stats(&self) -> SourceStats;

    /// The source's restart position: exclusive per-partition cursors
    /// covering exactly the records already handed downstream, plus the
    /// exactly-once counters that roll back with them. This is the
    /// uniform cursor-capture surface all four modes share — a source
    /// takes it internally at barrier-clean points (everything fetched is
    /// emitted, nothing half-processed); callers outside the checkpoint
    /// protocol (tests, inspection) should only trust it when the source
    /// is quiescent.
    fn checkpoint(&self) -> SourceSnapshot;
}

/// The type-erased source actor the launcher registers with the engine.
/// Stats extraction downcasts to this single concrete type — a source that
/// was not built through the registry is a hard error, not dropped stats.
pub struct SourceActor {
    inner: Box<dyn StreamSource>,
}

impl SourceActor {
    pub fn new(inner: Box<dyn StreamSource>) -> Self {
        Self { inner }
    }

    pub fn mode(&self) -> SourceMode {
        self.inner.mode()
    }

    pub fn stats(&self) -> SourceStats {
        self.inner.stats()
    }

    pub fn checkpoint(&self) -> SourceSnapshot {
        self.inner.checkpoint()
    }

    /// Borrow the wrapped source as its concrete type (tests, examples).
    pub fn source_as<T: 'static>(&mut self) -> Option<&mut T> {
        self.inner.as_any_mut()?.downcast_mut::<T>()
    }
}

impl Actor<Msg> for SourceActor {
    fn on_start(&mut self, ctx: &mut Ctx<'_, Msg>) {
        self.inner.on_start(ctx);
    }

    fn on_event(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        self.inner.on_event(msg, ctx);
    }

    fn label(&self) -> String {
        self.inner.label()
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn Any> {
        Some(self)
    }
}

/// Everything a factory may need to wire its sources into a cluster. The
/// launcher fills this once; factories take what their mode uses.
pub struct SourceWiring<'a> {
    pub config: &'a ExperimentConfig,
    /// Node the sources run on (the colocated worker node).
    pub node: NodeId,
    pub broker: ActorId,
    pub broker_node: NodeId,
    /// Task indices of the first pipeline stage (empty for engine-less
    /// modes such as the native baseline).
    pub downstream: Vec<usize>,
    pub metrics: SharedMetrics,
    pub net: SharedNetwork,
    pub store: SharedStore,
    pub registry: SharedRegistry,
    pub compute: Option<SharedCompute>,
    /// Checkpoint blackboard (`None` = checkpointing disabled). Factories
    /// hand it to their sources so barrier snapshots and restores work
    /// identically across modes.
    pub checkpoint: Option<SharedCheckpoint>,
    /// The published shard view when `broker_count > 1`: sources route
    /// per-partition through a cached [`crate::shard::ShardClient`]
    /// instead of the single `broker` above, refresh on the coordinator's
    /// `ShardEpoch` notification, and retry `WrongShard` refusals.
    pub shard: Option<crate::shard::SharedShard>,
}

impl SourceWiring<'_> {
    /// Exclusive partition span of consumer `i` (contiguous split of `Ns`
    /// over `Nc`, starting at offset 0).
    pub fn member_assignments(&self, i: usize) -> Vec<(PartitionId, ChunkOffset)> {
        let parts_per = self.config.ns / self.config.nc;
        (i * parts_per..(i + 1) * parts_per)
            .map(|p| (PartitionId(p), 0))
            .collect()
    }
}

/// Builds one mode's sources. Implementations live next to their source
/// type; the registry hands the launcher the right one for the configured
/// [`SourceMode`].
pub trait SourceFactory {
    /// The mode this factory serves.
    fn mode(&self) -> SourceMode;

    /// Dedicated broker push threads the mode needs (0 for pull-only).
    fn broker_push_threads(&self) -> usize {
        0
    }

    /// Whether the mode feeds a streaming-engine pipeline (false for the
    /// native baseline, which counts tuples in place).
    fn uses_pipeline(&self) -> bool {
        true
    }

    /// Build + register the mode's sources; return their actor ids. Every
    /// actor must be a [`SourceActor`] so stats extraction can't miss it.
    fn build(&self, wiring: &SourceWiring<'_>, engine: &mut Engine<Msg>) -> Vec<ActorId>;
}

/// The pluggable factory registry, keyed by [`SourceMode`].
pub struct SourceRegistry {
    factories: Vec<Box<dyn SourceFactory>>,
}

impl SourceRegistry {
    /// An empty registry (plug in your own factories).
    pub fn empty() -> Self {
        Self { factories: Vec::new() }
    }

    /// The four built-in modes: pull, push, native, hybrid.
    pub fn builtin() -> Self {
        let mut r = Self::empty();
        r.register(Box::new(super::pull::PullSourceFactory));
        r.register(Box::new(super::push::PushSourceFactory));
        r.register(Box::new(super::native::NativeSourceFactory));
        r.register(Box::new(super::hybrid::HybridSourceFactory));
        r
    }

    /// Register a factory; replaces any previous factory for the same mode.
    pub fn register(&mut self, factory: Box<dyn SourceFactory>) {
        if let Some(slot) = self.factories.iter_mut().find(|f| f.mode() == factory.mode()) {
            *slot = factory;
        } else {
            self.factories.push(factory);
        }
    }

    pub fn get(&self, mode: SourceMode) -> Option<&dyn SourceFactory> {
        self.factories.iter().find(|f| f.mode() == mode).map(|b| b.as_ref())
    }

    /// Resolve a mode or die loudly — an unregistered mode is a config
    /// error, not a silently sourceless cluster.
    pub fn expect(&self, mode: SourceMode) -> &dyn SourceFactory {
        self.get(mode).unwrap_or_else(|| {
            panic!("no source factory registered for mode `{}`", mode.name())
        })
    }

    /// The modes currently registered (in registration order).
    pub fn modes(&self) -> Vec<SourceMode> {
        self.factories.iter().map(|f| f.mode()).collect()
    }
}

impl Default for SourceRegistry {
    fn default() -> Self {
        Self::builtin()
    }
}
