//! Source-reader tests against a real broker + worker tasks.

use super::*;
use crate::broker::{Broker, BrokerParams};
use crate::config::{CostModel, NetworkProfile};
use crate::metrics::{Class, MetricsHub, SharedMetrics};
use crate::net::Network;
use crate::ops::CountOp;
use crate::plasma::ObjectStore;
use crate::producer::{Producer, ProducerParams, RecordGen};
use crate::proto::{Msg, PartitionId};
use crate::sim::{ActorId, Engine, SECOND};
use crate::worker::{OperatorTask, TaskParams, TaskRegistry};

/// A full mini-cluster: 1 producer, broker, 1 source (mode-dependent),
/// 2 count mappers.
struct Rig {
    engine: Engine<Msg>,
    metrics: SharedMetrics,
    source: ActorId,
}

fn rig(mode: &str, producer_chunk: usize, consumer_chunk: usize) -> Rig {
    let mut engine = Engine::new(11);
    let metrics = MetricsHub::shared();
    let net = Network::shared(NetworkProfile::INFINIBAND, NetworkProfile::LOOPBACK);
    let store = ObjectStore::shared();
    let registry = TaskRegistry::shared();
    let parts: Vec<PartitionId> = (0..2).map(PartitionId).collect();
    let push = mode == "push";
    let broker = engine.add_actor(Box::new(Broker::new(
        BrokerParams {
            node: 0,
            worker_cores: 4,
            push_threads: if push { 1 } else { 0 },
            segment_bytes: 8 << 20,
            partitions: parts.clone(),
            backup: None,
            is_backup: false,
            cost: CostModel::default(),
        },
        net.clone(),
        store.clone(),
        metrics.clone(),
        0,
    )));
    engine.add_actor(Box::new(Producer::new(
        ProducerParams {
            entity: 0,
            node: 1,
            broker,
            broker_node: 0,
            partitions: parts.clone(),
            chunk_bytes: producer_chunk,
            record_size: 100,
            cost: CostModel::default(),
            data_plane: crate::config::DataPlane::Sim,
        },
        RecordGen::Sim,
        metrics.clone(),
        net.clone(),
    )));
    // two count mappers at task idx 1, 2 (source is task 0)
    let downstream = vec![1usize, 2];
    for &idx in &downstream {
        let t = engine.add_actor(Box::new(OperatorTask::new(
            TaskParams {
                task_idx: idx,
                queue_cap: 8,
                downstream: vec![],
                tick_ns: SECOND,
                cost: CostModel::default(),
            },
            vec![Box::new(CountOp::default())],
            registry.clone(),
            metrics.clone(),
        )));
        registry.borrow_mut().register(idx, t);
    }
    let source = match mode {
        "pull" => {
            let s = engine.add_actor(Box::new(PullSource::new(
                PullParams {
                    task_idx: 0,
                    node: 0,
                    broker,
                    broker_node: 0,
                    assignments: parts.iter().map(|&p| (p, 0)).collect(),
                    max_bytes: consumer_chunk as u64,
                    pull_timeout: 100_000,
                    downstream: downstream.clone(),
                    queue_cap: 8,
                    cost: CostModel::default(),
                },
                metrics.clone(),
                net.clone(),
                registry.clone(),
            )));
            registry.borrow_mut().register(0, s);
            s
        }
        "push" => {
            let s = engine.add_actor(Box::new(PushSourceGroup::new(
                PushGroupParams {
                    leader_task_idx: 0,
                    node: 0,
                    broker,
                    broker_node: 0,
                    members: vec![PushMember {
                        task_idx: 0,
                        assignments: parts.iter().map(|&p| (p, 0)).collect(),
                        objects: 4,
                        object_bytes: consumer_chunk as u64,
                    }],
                    downstream: downstream.clone(),
                    queue_cap: 8,
                    cost: CostModel::default(),
                },
                net.clone(),
                store.clone(),
                registry.clone(),
            )));
            registry.borrow_mut().register(0, s);
            s
        }
        "native" => engine.add_actor(Box::new(NativeConsumer::new(
            NativeParams {
                entity: 0,
                node: 0,
                broker,
                broker_node: 0,
                assignments: parts.iter().map(|&p| (p, 0)).collect(),
                max_bytes: consumer_chunk as u64,
                pull_timeout: 100_000,
                pattern: None,
                compute: None,
                cost: CostModel::default(),
            },
            metrics.clone(),
            net.clone(),
        ))),
        other => panic!("unknown mode {other}"),
    };
    Rig { engine, metrics, source }
}

#[test]
fn pull_source_consumes_and_feeds_mappers() {
    let mut r = rig("pull", 4096, 64 * 1024);
    r.engine.run_until(SECOND);
    let s = r.engine.actor_as::<PullSource>(r.source).unwrap();
    assert!(s.records_consumed() > 10_000, "consumed {}", s.records_consumed());
    assert!(s.pulls_issued() > 10);
    let consumed = s.records_consumed();
    // mappers logged every consumed tuple
    let logged = r.metrics.borrow().total(Class::ConsumerTuples);
    assert!(logged > 0 && logged <= consumed);
    assert!(
        logged as f64 > consumed as f64 * 0.9,
        "mappers keep up: {logged} vs {consumed}"
    );
}

#[test]
fn pull_source_records_rpc_metric() {
    let mut r = rig("pull", 4096, 64 * 1024);
    r.engine.run_until(SECOND / 2);
    let rpcs = r.metrics.borrow().total(Class::PullRpcs);
    let s = r.engine.actor_as::<PullSource>(r.source).unwrap();
    assert_eq!(rpcs, s.pulls_issued());
}

#[test]
fn pull_source_backs_off_when_caught_up() {
    // Tiny producer chunks + huge consumer budget: the source catches up
    // and issues empty polls paced by pull_timeout.
    let mut r = rig("pull", 1024, 1 << 20);
    r.engine.run_until(SECOND);
    let s = r.engine.actor_as::<PullSource>(r.source).unwrap();
    assert!(s.empty_pulls() > 0, "must hit empty polls");
}

#[test]
fn push_group_consumes_objects() {
    let mut r = rig("push", 4096, 64 * 1024);
    r.engine.run_until(SECOND);
    let g = r.engine.actor_as::<PushSourceGroup>(r.source).unwrap();
    assert!(g.is_subscribed());
    assert!(g.objects_consumed() > 5, "objects {}", g.objects_consumed());
    assert!(g.records_consumed() > 10_000);
    let consumed = g.records_consumed();
    let logged = r.metrics.borrow().total(Class::ConsumerTuples);
    assert!(logged as f64 > consumed as f64 * 0.9);
    // push issues no pull RPCs
    assert_eq!(r.metrics.borrow().total(Class::PullRpcs), 0);
}

#[test]
fn push_objects_are_filled_and_reused() {
    let mut r = rig("push", 4096, 64 * 1024);
    r.engine.run_until(SECOND);
    let filled = r.metrics.borrow().total(Class::ObjectsFilled);
    let g = r.engine.actor_as::<PushSourceGroup>(r.source).unwrap();
    // every filled object is eventually consumed (within one in flight)
    assert!(filled >= g.objects_consumed());
    assert!(filled <= g.objects_consumed() + 4 + 1, "bounded in-flight");
}

#[test]
fn native_consumer_keeps_up_with_producer() {
    let mut r = rig("native", 4096, 64 * 1024);
    r.engine.run_until(SECOND);
    let n = r.engine.actor_as::<NativeConsumer>(r.source).unwrap();
    let produced = r.metrics.borrow().total(Class::ProducerRecords);
    let consumed = n.records_consumed();
    assert!(
        consumed as f64 > produced as f64 * 0.8,
        "native keeps up (paper Fig. 7): {consumed} vs {produced}"
    );
    // native counts tuples directly
    assert_eq!(r.metrics.borrow().total(Class::ConsumerTuples), consumed);
}

#[test]
fn consumption_never_exceeds_production() {
    for mode in ["pull", "push", "native"] {
        let mut r = rig(mode, 16 * 1024, 64 * 1024);
        r.engine.run_until(SECOND);
        let produced = r.metrics.borrow().total(Class::ProducerRecords);
        let consumed = match mode {
            "pull" => r.engine.actor_as::<PullSource>(r.source).unwrap().records_consumed(),
            "push" => r.engine.actor_as::<PushSourceGroup>(r.source).unwrap().records_consumed(),
            _ => r.engine.actor_as::<NativeConsumer>(r.source).unwrap().records_consumed(),
        };
        assert!(consumed <= produced, "{mode}: {consumed} <= {produced}");
        assert!(consumed > 0, "{mode}: progress");
    }
}
