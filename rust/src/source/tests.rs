//! Source-reader tests against a real broker + worker tasks. Sources are
//! registered the way the launcher registers them — wrapped in
//! [`SourceActor`] — so every test also exercises the trait API.

use super::*;
use crate::broker::{Broker, BrokerParams, StoreParams};
use crate::config::{CostModel, NetworkProfile};
use crate::metrics::{Class, MetricsHub, SharedMetrics};
use crate::net::Network;
use crate::ops::CountOp;
use crate::plasma::ObjectStore;
use crate::producer::{Producer, ProducerParams, RecordGen};
use crate::proto::{Msg, PartitionId};
use crate::sim::{ActorId, Engine, Time, SECOND};
use crate::worker::{OperatorTask, TaskParams, TaskRegistry};

/// A full mini-cluster: 1 producer, broker, 1 source (mode-dependent),
/// 2 count mappers.
struct Rig {
    engine: Engine<Msg>,
    metrics: SharedMetrics,
    source: ActorId,
}

/// The wrapped source, as the launcher sees it (borrows only the engine so
/// tests can keep reading the rig's metrics).
fn actor_of(engine: &mut Engine<Msg>, id: ActorId) -> &mut SourceActor {
    engine.actor_as::<SourceActor>(id).expect("registry-built source")
}

fn rig(mode: &str, producer_chunk: usize, consumer_chunk: usize) -> Rig {
    rig_opts(mode, producer_chunk, consumer_chunk, true, None)
}

fn rig_opts(
    mode: &str,
    producer_chunk: usize,
    consumer_chunk: usize,
    with_producer: bool,
    tuning: Option<HybridTuning>,
) -> Rig {
    let mut engine = Engine::new(11);
    let metrics = MetricsHub::shared();
    let net = Network::shared(NetworkProfile::INFINIBAND, NetworkProfile::LOOPBACK);
    let store = ObjectStore::shared();
    let registry = TaskRegistry::shared();
    let parts: Vec<PartitionId> = (0..2).map(PartitionId).collect();
    let push = mode == "push" || mode == "hybrid";
    let broker = engine.add_actor(Box::new(Broker::new(
        BrokerParams {
            node: 0,
            worker_cores: 4,
            push_threads: if push { 1 } else { 0 },
            store: StoreParams::memory(8 << 20),
            partitions: parts.clone(),
            backup: None,
            is_backup: false,
            cost: CostModel::default(),
        },
        net.clone(),
        store.clone(),
        metrics.clone(),
        0,
    )));
    if with_producer {
        engine.add_actor(Box::new(Producer::new(
            ProducerParams {
                entity: 0,
                node: 1,
                broker,
                broker_node: 0,
                partitions: parts.clone(),
                chunk_bytes: producer_chunk,
                record_size: 100,
                retry: crate::producer::RetryPolicy::default(),
                cost: CostModel::default(),
                data_plane: crate::config::DataPlane::Sim,
                shard: None,
                rpc_deadline_ns: 0,
            },
            RecordGen::Sim,
            metrics.clone(),
            net.clone(),
        )));
    }
    // two count mappers at task idx 1, 2 (source is task 0)
    let downstream = vec![1usize, 2];
    for &idx in &downstream {
        let t = engine.add_actor(Box::new(OperatorTask::new(
            TaskParams {
                task_idx: idx,
                queue_cap: 8,
                downstream: vec![],
                upstream: vec![0],
                tick_ns: SECOND,
                cost: CostModel::default(),
                checkpoint: None,
            },
            vec![Box::new(CountOp::default())],
            registry.clone(),
            metrics.clone(),
        )));
        registry.borrow_mut().register(idx, t);
    }
    let source: Box<dyn StreamSource> = match mode {
        "pull" => Box::new(PullSource::new(
            PullParams {
                task_idx: 0,
                node: 0,
                broker,
                broker_node: 0,
                assignments: parts.iter().map(|&p| (p, 0)).collect(),
                max_bytes: consumer_chunk as u64,
                pull_timeout: 100_000,
                rpc_deadline_ns: 0,
                downstream: downstream.clone(),
                queue_cap: 8,
                checkpoint: None,
                cost: CostModel::default(),
                shard: None,
            },
            metrics.clone(),
            net.clone(),
            registry.clone(),
        )),
        "push" => Box::new(PushSourceGroup::new(
            PushGroupParams {
                leader_task_idx: 0,
                node: 0,
                broker,
                broker_node: 0,
                members: vec![PushMember {
                    task_idx: 0,
                    assignments: parts.iter().map(|&p| (p, 0)).collect(),
                    objects: 4,
                    object_bytes: consumer_chunk as u64,
                }],
                downstream: downstream.clone(),
                queue_cap: 8,
                checkpoint: None,
                cost: CostModel::default(),
                shard: None,
            },
            metrics.clone(),
            net.clone(),
            store.clone(),
            registry.clone(),
        )),
        "native" => Box::new(NativeConsumer::new(
            NativeParams {
                entity: 0,
                node: 0,
                broker,
                broker_node: 0,
                assignments: parts.iter().map(|&p| (p, 0)).collect(),
                max_bytes: consumer_chunk as u64,
                pull_timeout: 100_000,
                rpc_deadline_ns: 0,
                pattern: None,
                compute: None,
                checkpoint: None,
                cost: CostModel::default(),
                shard: None,
            },
            metrics.clone(),
            net.clone(),
        )),
        "hybrid" => Box::new(HybridSource::new(
            HybridParams {
                task_idx: 0,
                node: 0,
                broker,
                broker_node: 0,
                assignments: parts.iter().map(|&p| (p, 0)).collect(),
                max_bytes: consumer_chunk as u64,
                pull_timeout: 100_000,
                rpc_deadline_ns: 0,
                downstream: downstream.clone(),
                queue_cap: 8,
                objects: 4,
                tuning: tuning.clone().unwrap_or(HybridTuning {
                    window_polls: 32,
                    empty_permille: 600,
                    rpc_latency_ns: 200_000,
                    cooldown_ns: SECOND,
                    idle_timeout_ns: 200_000_000,
                }),
                checkpoint: None,
                cost: CostModel::default(),
                shard: None,
            },
            metrics.clone(),
            net.clone(),
            store.clone(),
            registry.clone(),
        )),
        other => panic!("unknown mode {other}"),
    };
    let is_engine_source = mode != "native";
    let source = engine.add_actor(Box::new(SourceActor::new(source)));
    if is_engine_source {
        registry.borrow_mut().register(0, source);
    }
    Rig { engine, metrics, source }
}

#[test]
fn pull_source_consumes_and_feeds_mappers() {
    let mut r = rig("pull", 4096, 64 * 1024);
    r.engine.run_until(SECOND);
    let s = actor_of(&mut r.engine, r.source).source_as::<PullSource>().unwrap();
    assert!(s.records_consumed() > 10_000, "consumed {}", s.records_consumed());
    assert!(s.pulls_issued() > 10);
    let consumed = s.records_consumed();
    // mappers logged every consumed tuple
    let logged = r.metrics.borrow().total(Class::ConsumerTuples);
    assert!(logged > 0 && logged <= consumed);
    assert!(
        logged as f64 > consumed as f64 * 0.9,
        "mappers keep up: {logged} vs {consumed}"
    );
}

#[test]
fn pull_source_records_rpc_metric() {
    let mut r = rig("pull", 4096, 64 * 1024);
    r.engine.run_until(SECOND / 2);
    let rpcs = r.metrics.borrow().total(Class::PullRpcs);
    let s = actor_of(&mut r.engine, r.source).source_as::<PullSource>().unwrap();
    assert_eq!(rpcs, s.pulls_issued());
}

#[test]
fn pull_source_backs_off_when_caught_up() {
    // Tiny producer chunks + huge consumer budget: the source catches up
    // and issues empty polls paced by pull_timeout.
    let mut r = rig("pull", 1024, 1 << 20);
    r.engine.run_until(SECOND);
    let s = actor_of(&mut r.engine, r.source).source_as::<PullSource>().unwrap();
    assert!(s.empty_pulls() > 0, "must hit empty polls");
}

#[test]
fn push_group_consumes_objects() {
    let mut r = rig("push", 4096, 64 * 1024);
    r.engine.run_until(SECOND);
    let g = actor_of(&mut r.engine, r.source).source_as::<PushSourceGroup>().unwrap();
    assert!(g.is_subscribed());
    assert!(g.objects_consumed() > 5, "objects {}", g.objects_consumed());
    assert!(g.records_consumed() > 10_000);
    let consumed = g.records_consumed();
    let logged = r.metrics.borrow().total(Class::ConsumerTuples);
    assert!(logged as f64 > consumed as f64 * 0.9);
    // push issues no pull RPCs
    assert_eq!(r.metrics.borrow().total(Class::PullRpcs), 0);
}

#[test]
fn push_objects_are_filled_and_reused() {
    let mut r = rig("push", 4096, 64 * 1024);
    r.engine.run_until(SECOND);
    let filled = r.metrics.borrow().total(Class::ObjectsFilled);
    let g = actor_of(&mut r.engine, r.source).source_as::<PushSourceGroup>().unwrap();
    // every filled object is eventually consumed (within one in flight)
    assert!(filled >= g.objects_consumed());
    assert!(filled <= g.objects_consumed() + 4 + 1, "bounded in-flight");
}

#[test]
fn native_consumer_keeps_up_with_producer() {
    let mut r = rig("native", 4096, 64 * 1024);
    r.engine.run_until(SECOND);
    let consumed =
        actor_of(&mut r.engine, r.source).source_as::<NativeConsumer>().unwrap().records_consumed();
    let produced = r.metrics.borrow().total(Class::ProducerRecords);
    assert!(
        consumed as f64 > produced as f64 * 0.8,
        "native keeps up (paper Fig. 7): {consumed} vs {produced}"
    );
    // native counts tuples directly
    assert_eq!(r.metrics.borrow().total(Class::ConsumerTuples), consumed);
}

#[test]
fn consumption_never_exceeds_production() {
    // The uniform trait API replaces the old per-type downcast chain.
    for mode in ["pull", "push", "native", "hybrid"] {
        let mut r = rig(mode, 16 * 1024, 64 * 1024);
        r.engine.run_until(SECOND);
        let produced = r.metrics.borrow().total(Class::ProducerRecords);
        let consumed = actor_of(&mut r.engine, r.source).stats().records_consumed;
        assert!(consumed <= produced, "{mode}: {consumed} <= {produced}");
        assert!(consumed > 0, "{mode}: progress");
    }
}

#[test]
fn trait_stats_match_concrete_getters() {
    // `SourceStats` parity with the old per-type getters, through the
    // type-erased `SourceActor` the launcher uses.
    for mode in ["pull", "push", "native", "hybrid"] {
        let mut r = rig(mode, 4096, 64 * 1024);
        r.engine.run_until(SECOND / 2);
        let actor = actor_of(&mut r.engine, r.source);
        let stats = actor.stats();
        match mode {
            "pull" => {
                let s = actor.source_as::<PullSource>().unwrap();
                assert_eq!(stats.records_consumed, s.records_consumed());
                assert_eq!(stats.pulls_issued, s.pulls_issued());
                assert_eq!(stats.empty_pulls, s.empty_pulls());
                assert_eq!(stats.threads, 2);
                assert!(stats.extras.is_empty());
            }
            "push" => {
                let g = actor.source_as::<PushSourceGroup>().unwrap();
                assert_eq!(stats.records_consumed, g.records_consumed());
                assert_eq!(stats.extra(StatKey::ObjectsConsumed), g.objects_consumed());
                assert_eq!(stats.extra(StatKey::Subscribed), g.is_subscribed() as u64);
                assert_eq!(stats.pulls_issued, 0);
                assert_eq!(stats.threads, 2);
            }
            "native" => {
                let n = actor.source_as::<NativeConsumer>().unwrap();
                assert_eq!(stats.records_consumed, n.records_consumed());
                assert_eq!(stats.pulls_issued, n.pulls_issued());
                assert_eq!(stats.empty_pulls, n.empty_pulls());
                assert_eq!(stats.extra(StatKey::Matches), n.matches());
                assert_eq!(stats.threads, 1);
            }
            _ => {
                let h = actor.source_as::<HybridSource>().unwrap();
                assert_eq!(stats.records_consumed, h.records_consumed());
                assert_eq!(stats.pulls_issued, h.pulls_issued());
                assert_eq!(stats.extra(StatKey::SwitchesToPush), h.switches_to_push());
                assert_eq!(stats.extra(StatKey::SwitchesToPull), h.switches_to_pull());
                assert_eq!(stats.threads, 2);
            }
        }
        assert!(stats.records_consumed > 0, "{mode}: progress");
        // Wrong-type downcasts fail loudly rather than silently.
        assert!(actor.source_as::<crate::producer::Producer>().is_none());
    }
}

#[test]
fn hybrid_switches_on_sustained_empty_polls_and_falls_back_after_cooldown() {
    // No producer at all: every pull comes back empty, so the source must
    // switch to push; the push path then starves, so after the cooldown it
    // must fall back — and keep cycling with hysteresis.
    let tuning = HybridTuning {
        window_polls: 4,
        empty_permille: 500,
        rpc_latency_ns: Time::MAX, // only the empty-poll signal fires
        cooldown_ns: 1_000_000,    // 1 ms dwell
        idle_timeout_ns: 10_000_000, // 10 ms without objects = starved
    };
    let mut r = rig_opts("hybrid", 4096, 64 * 1024, false, Some(tuning));
    r.engine.run_until(SECOND);
    let h = actor_of(&mut r.engine, r.source).source_as::<HybridSource>().unwrap();
    assert!(h.empty_pulls() >= 4, "polls stayed empty: {}", h.empty_pulls());
    assert!(h.switches_to_push() >= 1, "sustained empty polls must switch to push");
    assert!(h.switches_to_pull() >= 1, "a starved push phase must fall back after cooldown");
    // Hysteresis: each direction needs a full window + cooldown, so the
    // cycle count stays bounded well below the raw poll count.
    assert!(h.switches_to_push() <= 1 + h.switches_to_pull());
    assert_eq!(h.records_consumed(), 0, "no data existed to consume");
}

// ---------------------------------------------------------------------------
// Trim-floor recovery (satellite): resume cursors behind the trim point
// ---------------------------------------------------------------------------

use crate::plasma::SharedStore;
use crate::proto::{Chunk, RpcEnvelope, RpcKind, RpcReply, RpcRequest, StampedChunk};
use crate::sim::Ctx;

/// A scripted broker stand-in that forces the trim scenario: the first two
/// pulls serve the requested offset, the third reports the requested
/// offset as trimmed (floor 8) *and* serves the floor chunk, and later
/// pulls are empty. Push subscriptions get one sealed object at the
/// subscribed cursor; unsubscribes return the advanced cursor — which the
/// third pull then declares behind retention, exactly the hybrid
/// pull→push→pull fallback hazard (torn-down cursors stop pinning trims).
struct TrimScriptBroker {
    store: SharedStore,
    pulls: u64,
    subscribes: u64,
}

impl TrimScriptBroker {
    const FLOOR: u64 = 8;

    fn chunk_at(offset: u64) -> StampedChunk {
        StampedChunk { partition: PartitionId(0), offset, chunk: Chunk::sim(10, 100) }
    }
}

impl crate::sim::Actor<Msg> for TrimScriptBroker {
    fn on_event(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        match msg {
            Msg::Rpc(req) => {
                let RpcRequest { id, reply_to, kind, .. } = *req;
                let reply = match kind {
                    RpcKind::Pull { assignments, .. } => {
                        self.pulls += 1;
                        let requested = assignments[0].1;
                        match self.pulls {
                            1 | 2 => RpcReply::PullData {
                                chunks: vec![Self::chunk_at(requested)],
                                trims: vec![],
                            },
                            3 => RpcReply::PullData {
                                chunks: vec![Self::chunk_at(Self::FLOOR)],
                                trims: vec![(PartitionId(0), Self::FLOOR)],
                            },
                            _ => RpcReply::PullData { chunks: vec![], trims: vec![] },
                        }
                    }
                    RpcKind::PushSubscribe { sources } => {
                        self.subscribes += 1;
                        let spec = &sources[0];
                        let cursor = spec.assignments[0].1;
                        let sub = self.store.borrow_mut().create_subscription(
                            spec.source_actor,
                            spec.assignments.clone(),
                            spec.objects,
                            spec.object_bytes,
                        );
                        // The first subscription gets one fill at its
                        // cursor, then starves; later ones (the source may
                        // keep cycling on the aggressive latency signal)
                        // starve outright. The broker-managed cursor
                        // advances past the fill (what the unsubscribe ack
                        // later returns).
                        if self.subscribes == 1 {
                            let object = {
                                let mut s = self.store.borrow_mut();
                                let object = s.acquire(sub).expect("fresh pool");
                                s.seal(object, vec![Self::chunk_at(cursor)]);
                                s.subscription_mut(sub).cursors[0].1 = cursor + 1;
                                object
                            };
                            ctx.send_in(1_000, spec.source_actor, Msg::ObjectReady { id: object });
                        }
                        RpcReply::SubscribeAck { sub }
                    }
                    RpcKind::PushUnsubscribe { sub } => {
                        let cursors = self.store.borrow_mut().deactivate(sub);
                        RpcReply::UnsubscribeAck { sub, cursors }
                    }
                    other => panic!("trim script: unexpected rpc {other:?}"),
                };
                ctx.send(reply_to, Msg::reply(RpcEnvelope { id, reply }));
            }
            Msg::ObjectFreed { id } => self.store.borrow_mut().release(id),
            other => panic!("trim script: unexpected {other:?}"),
        }
    }
}

/// Rig a source (pull or hybrid) against the scripted broker.
fn trim_rig(mode: &str, tuning: Option<HybridTuning>) -> Rig {
    let mut engine = Engine::new(5);
    let metrics = MetricsHub::shared();
    let net = Network::shared(NetworkProfile::INFINIBAND, NetworkProfile::LOOPBACK);
    let store = ObjectStore::shared();
    let registry = TaskRegistry::shared();
    let broker = engine.add_actor(Box::new(TrimScriptBroker {
        store: store.clone(),
        pulls: 0,
        subscribes: 0,
    }));
    let downstream = vec![1usize];
    let t = engine.add_actor(Box::new(OperatorTask::new(
        TaskParams {
            task_idx: 1,
            queue_cap: 8,
            downstream: vec![],
            upstream: vec![0],
            tick_ns: SECOND,
            cost: CostModel::default(),
            checkpoint: None,
        },
        vec![Box::new(CountOp::default())],
        registry.clone(),
        metrics.clone(),
    )));
    registry.borrow_mut().register(1, t);
    let source: Box<dyn StreamSource> = match mode {
        "pull" => Box::new(PullSource::new(
            PullParams {
                task_idx: 0,
                node: 0,
                broker,
                broker_node: 0,
                assignments: vec![(PartitionId(0), 0)],
                max_bytes: 1024,
                pull_timeout: 100_000,
                rpc_deadline_ns: 0,
                downstream,
                queue_cap: 8,
                checkpoint: None,
                cost: CostModel::default(),
                shard: None,
            },
            metrics.clone(),
            net.clone(),
            registry.clone(),
        )),
        _ => Box::new(HybridSource::new(
            HybridParams {
                task_idx: 0,
                node: 0,
                broker,
                broker_node: 0,
                assignments: vec![(PartitionId(0), 0)],
                max_bytes: 1024,
                pull_timeout: 100_000,
                rpc_deadline_ns: 0,
                downstream,
                queue_cap: 8,
                objects: 2,
                tuning: tuning.expect("hybrid needs tuning"),
                checkpoint: None,
                cost: CostModel::default(),
                shard: None,
            },
            metrics.clone(),
            net.clone(),
            store.clone(),
            registry.clone(),
        )),
    };
    let source = engine.add_actor(Box::new(SourceActor::new(source)));
    registry.borrow_mut().register(0, source);
    Rig { engine, metrics, source }
}

#[test]
fn pull_source_skips_to_the_trim_floor_with_a_counted_gap() {
    let mut r = trim_rig("pull", None);
    r.engine.run_until(SECOND);
    let stats = actor_of(&mut r.engine, r.source).stats();
    let s = actor_of(&mut r.engine, r.source).source_as::<PullSource>().unwrap();
    // Pulls 1+2 served offsets 0 and 1; pull 3 (requesting 2) hit the trim
    // floor at 8: gap of 6 chunks counted, floor chunk consumed, loop
    // alive (empty polls follow).
    assert_eq!(s.trim_gap_chunks(), TrimScriptBroker::FLOOR - 2);
    assert_eq!(s.records_consumed(), 30, "2 pre-trim chunks + the floor chunk");
    assert!(s.pulls_issued() >= 4, "the partition is not wedged");
    assert!(s.empty_pulls() > 0, "the loop keeps polling past the gap");
    assert_eq!(stats.extra(StatKey::TrimGapChunks), TrimScriptBroker::FLOOR - 2);
}

#[test]
fn hybrid_fallback_cursors_behind_trim_recover_with_a_counted_gap() {
    // pull -> push (latency signal) -> starve -> pull fallback; the resume
    // cursors then land behind the trim floor and must recover by skipping
    // forward — not wedge, not silently lose the partition.
    let tuning = HybridTuning {
        window_polls: 2,
        empty_permille: 1000,      // empty-poll signal off
        rpc_latency_ns: 1,         // any round-trip forces the switch
        cooldown_ns: 0,
        idle_timeout_ns: 10_000_000, // starve 10 ms after the only object
    };
    let mut r = trim_rig("hybrid", Some(tuning));
    r.engine.run_until(SECOND);
    let stats = actor_of(&mut r.engine, r.source).stats();
    let h = actor_of(&mut r.engine, r.source).source_as::<HybridSource>().unwrap();
    // The aggressive 1 ns latency signal keeps cycling after the first
    // fallback (every later subscription starves outright); the invariants
    // below hold across however many cycles fit the run.
    assert!(h.switches_to_push() >= 1, "latency signal switched after the window");
    assert!(h.switches_to_pull() >= 1, "starved push phase fell back");
    assert_eq!(h.objects_consumed(), 1, "only the first push phase carried an object");
    // Pulls 1+2 at offsets 0,1; the object carried offset 2; the fallback
    // resumed at cursor 3, which pull 3 declared trimmed (floor 8): a gap
    // of 5 chunks, then the floor chunk.
    assert_eq!(h.trim_gap_chunks(), TrimScriptBroker::FLOOR - 3);
    assert_eq!(h.records_consumed(), 40, "no chunk lost outside the counted gap");
    assert!(h.empty_pulls() > 0, "the pull loop runs on past the gap");
    assert_eq!(stats.extra(StatKey::TrimGapChunks), TrimScriptBroker::FLOOR - 3);
}

#[test]
fn hybrid_switch_preserves_data_flow() {
    // Force the contention signal (any RPC round-trip beats 1 ns) so the
    // source switches while data is flowing, then verify the push phase
    // carries the stream: objects consumed, conservation holds.
    let tuning = HybridTuning {
        window_polls: 4,
        empty_permille: 1000, // empty-poll signal effectively off
        rpc_latency_ns: 1,
        cooldown_ns: 0,
        idle_timeout_ns: SECOND, // never starved within the run
    };
    let mut r = rig_opts("hybrid", 4096, 64 * 1024, true, Some(tuning));
    r.engine.run_until(SECOND);
    let produced = r.metrics.borrow().total(Class::ProducerRecords);
    let h = actor_of(&mut r.engine, r.source).source_as::<HybridSource>().unwrap();
    assert_eq!(h.switches_to_push(), 1, "exactly one switch, no fallback");
    assert!(h.is_pushing(), "stays on the push path");
    assert!(h.pulls_issued() >= 4, "pulled through the monitoring window first");
    assert!(h.objects_consumed() > 0, "push phase served shared objects");
    assert!(h.records_consumed() > 10_000, "stream kept flowing across the switch");
    assert!(h.records_consumed() <= produced, "no duplication across the hand-off");
}
