//! The adaptive hybrid source: pull until pulling hurts, then push.
//!
//! The paper proposes an architecture leveraging "push-based **and/or**
//! pull-based source implementations" — this is the *and*. A hybrid source
//! starts on the pull path (lowest resource footprint when the broker is
//! unloaded) and monitors its own pull loop over a sliding window of
//! completed RPCs:
//!
//! * **empty-poll rate** — pulls that return nothing burn an RPC and a
//!   timeout (§II-B: the poll timeout is "difficult to tune");
//! * **RPC round-trip latency** — when producers saturate the broker's
//!   worker cores, pull RPCs queue behind appends (the Fig. 7 starvation).
//!
//! When either signal crosses its threshold the source issues the push
//! subscription RPC at its current offsets and consumes shared-memory
//! objects by pointer, exactly like [`super::PushSourceGroup`]. If the
//! push path then starves (no object for `idle_timeout`), it unsubscribes
//! — the broker returns the resume cursors, so the pull loop continues
//! without loss or duplication. A cooldown after every switch provides the
//! hysteresis that stops the source flapping between modes.
//!
//! ## Checkpointing
//!
//! The hybrid source keeps one set of `offsets` that always equals its
//! *emitted floor*: the pull loop advances them on fetch, the push phase
//! advances them as objects are materialised. A barrier is therefore taken
//! at the next clean point of whichever loop is active — snapshotting the
//! same cursors either way, which is what makes the hybrid checkpoint
//! identical to its parents'. A restore always lands in the *pull* phase
//! (a hybrid can always pull): any live/in-flight subscription is orphaned
//! — unsubscribed fire-and-forget, its late notifications freed back to
//! the broker — and the loop re-pulls from the snapshot cursors. If those
//! cursors fell behind the broker trim point (an orphaned subscription's
//! cursors stop pinning retention), the pull reply's `trims` are applied:
//! skip to the floor, count the gap, keep the partition alive.

use std::collections::VecDeque;

use crate::checkpoint::{SharedCheckpoint, SourceSnapshot};
use crate::config::{CostModel, ExperimentConfig, SourceMode};
use crate::metrics::{Class, SharedMetrics};
use crate::net::{NodeId, SharedNetwork};
use crate::plasma::SharedStore;
use crate::proto::{
    Batch, ChunkOffset, Msg, ObjectId, PartitionId, PushSourceSpec, RpcEnvelope, RpcKind,
    RpcReply, RpcRequest, StampedChunk, SubId,
};
use crate::shard::ShardClient;
use crate::sim::{Actor, ActorId, Ctx, Engine, Time};
use crate::worker::{CreditLedger, SharedRegistry};

use super::api::{SourceActor, SourceFactory, SourceStats, SourceWiring, StatKey, StreamSource};

const TAG_POLL: u64 = 0;
/// Idle-check timers carry `TAG_IDLE_BASE + generation` so a stale chain
/// from an earlier push phase dies at its first fire instead of re-arming.
const TAG_IDLE_BASE: u64 = 1;
const JOB_PULL: u64 = 0;
const JOB_PUSH: u64 = 1;
/// Job tags: `inc * JOB_STRIDE + JOB_*` — completions from before a
/// rollback die on the incarnation mismatch.
const JOB_STRIDE: u64 = 2;

/// Table-I-style parameters governing the adaptive switch.
#[derive(Debug, Clone)]
pub struct HybridTuning {
    /// Sliding window length, in completed pull RPCs.
    pub window_polls: usize,
    /// Pull→push when empty polls exceed this permille of the window.
    pub empty_permille: u32,
    /// Pull→push when the window's mean RPC round-trip exceeds this.
    pub rpc_latency_ns: Time,
    /// Minimum dwell after a switch before the next one (hysteresis).
    pub cooldown_ns: Time,
    /// Push→pull when no object arrives for this long.
    pub idle_timeout_ns: Time,
}

impl HybridTuning {
    pub fn from_config(c: &ExperimentConfig) -> Self {
        Self {
            window_polls: c.hybrid_window_polls,
            empty_permille: c.hybrid_empty_permille,
            rpc_latency_ns: c.hybrid_latency_us * 1_000,
            cooldown_ns: c.hybrid_cooldown_ms * 1_000_000,
            idle_timeout_ns: c.hybrid_idle_ms * 1_000_000,
        }
    }
}

/// Wiring for one hybrid source task.
#[derive(Debug, Clone)]
pub struct HybridParams {
    /// Global task index (upstream id for credits) == metrics entity.
    pub task_idx: usize,
    pub node: NodeId,
    pub broker: ActorId,
    pub broker_node: NodeId,
    /// Exclusive partitions with starting offsets.
    pub assignments: Vec<(PartitionId, ChunkOffset)>,
    /// Consumer `CS`: pull byte budget per partition == push object bytes.
    pub max_bytes: u64,
    /// Poll backoff when a pull returns empty.
    pub pull_timeout: Time,
    /// Mapper tasks this source feeds (round-robin).
    pub downstream: Vec<usize>,
    /// Credits per downstream (queue capacity).
    pub queue_cap: usize,
    /// Push-phase object pool size (backpressure window).
    pub objects: usize,
    pub tuning: HybridTuning,
    /// Checkpoint blackboard (`None` = checkpointing disabled).
    pub checkpoint: Option<SharedCheckpoint>,
    pub cost: CostModel,
    /// The published shard view when `broker_count > 1`: the span's home
    /// broker is re-resolved per RPC, `WrongShard` refusals are retried,
    /// and a rebalance that moves the span away from a live subscription
    /// forces the push→pull fallback.
    pub shard: Option<crate::shard::SharedShard>,
    /// Per-RPC deadline (`rpc_deadline_ms`): a pull or subscribe
    /// unanswered this long is checked against the coordinator's down
    /// mask and reissued once its broker is declared dead; a live
    /// subscription whose broker dies is torn down locally and the
    /// source falls back to pulling. 0 or unsharded disables it.
    pub rpc_deadline_ns: Time,
}

/// Where the control loop currently is. The push consumption machinery
/// (ready queue / consuming marker) lives outside the phase so residual
/// sealed objects keep draining across a fallback.
enum Phase {
    /// Pull loop: RPC in flight.
    PullFetching,
    /// Pull loop: deserialising the fetched chunks.
    PullProcessing(Vec<StampedChunk>),
    /// Pull loop: batches wait for mapper credits.
    PullBlocked,
    /// Pull loop: empty poll, waiting out the timeout.
    PullIdle,
    /// Subscription RPC in flight (pull loop quiesced, pending empty).
    Subscribing,
    /// Push phase: consuming shared objects.
    Push { sub: SubId },
    /// Unsubscribe RPC in flight; sealed objects still drain. Carries the
    /// subscription so a broker death mid-teardown can orphan it.
    Unsubscribing { sub: SubId },
}

/// The hybrid source actor.
pub struct HybridSource {
    params: HybridParams,
    offsets: Vec<(PartitionId, ChunkOffset)>,
    ledger: CreditLedger,
    phase: Phase,
    rr: usize,
    next_rpc: u64,
    /// Issue time of the in-flight pull (round-trip measurement).
    inflight_since: Time,
    /// Batches awaiting mapper credits (shared by both paths).
    pending: VecDeque<Batch>,
    /// Mirror of `pending` while tracing: each batch's chunk identity for
    /// the tracer's marker FIFO. Stays empty when tracing is off.
    trace_keys: VecDeque<Option<(usize, u64)>>,
    /// Sliding window of completed pulls: (was_empty, round_trip).
    poll_window: VecDeque<(bool, Time)>,
    /// Sealed objects awaiting the consume thread.
    ready: VecDeque<ObjectId>,
    /// Object whose consume cost is currently being charged.
    consuming: Option<ObjectId>,
    /// Object freed once its batches drain (backpressure to the broker).
    pending_free: Option<ObjectId>,
    last_switch: Time,
    last_delivery: Time,
    /// Bumped on every subscribe and restore: invalidates idle-check timer
    /// chains from earlier push phases.
    idle_gen: u64,
    /// Barrier waiting for the next clean point of the active loop.
    pending_epoch: Option<u64>,
    /// Recovery incarnation; stale-tagged messages are dropped.
    inc: u64,
    /// Dead between an injected fault and the restore.
    failed: bool,
    /// Pull replies to RPCs issued before the last restore are stale.
    rpc_floor: u64,
    /// Subscribe acks to discard: a restore hit while the subscription RPC
    /// was in flight; the granted sub is immediately unsubscribed.
    orphan_subs: u64,
    /// Unsubscribe acks to discard: a restore hit while the unsubscribe
    /// RPC was in flight.
    orphan_unsub_acks: u64,
    /// Subscriptions torn down by restores: their late object
    /// notifications are freed straight back to the broker.
    orphaned: Vec<SubId>,
    /// Subscriptions created before the last restore are dead to this
    /// incarnation (covers the fallback-in-flight case where the sub id
    /// was never learned): their objects are freed, never consumed —
    /// consuming one would jump the cursors past unreplayed data.
    stale_sub_floor: usize,
    /// Cached shard view (`None` = single broker, route to `params`).
    shard: Option<ShardClient>,
    /// The broker the current (or last) push subscription was issued at:
    /// unsubscribes and object frees are pinned here even after a
    /// rebalance re-homes the span — the old primary still owns the
    /// subscription's fill pump and pool slots.
    push_home: (ActorId, NodeId),
    /// The deadline-raced RPC currently awaiting its reply (the in-flight
    /// pull while `PullFetching`, the subscribe while `Subscribing`).
    inflight_rpc: Option<u64>,
    /// Transmissions of the current raced RPC (backoff escalation).
    rpc_attempts: u32,
    /// RPCs re-routed (and forced fallbacks taken) after a broker death.
    broker_down_retries: u64,
    replayed: u64,
    trim_gap_chunks: u64,
    pulls_issued: u64,
    empty_pulls: u64,
    records_consumed: u64,
    objects_consumed: u64,
    switches_to_push: u64,
    switches_to_pull: u64,
    metrics: SharedMetrics,
    net: SharedNetwork,
    store: SharedStore,
    registry: SharedRegistry,
}

impl HybridSource {
    pub fn new(
        params: HybridParams,
        metrics: SharedMetrics,
        net: SharedNetwork,
        store: SharedStore,
        registry: SharedRegistry,
    ) -> Self {
        assert!(!params.assignments.is_empty());
        assert!(!params.downstream.is_empty());
        assert!(params.tuning.window_polls > 0);
        let offsets = params.assignments.clone();
        let ledger = CreditLedger::new(&params.downstream, params.queue_cap);
        let shard = params.shard.as_ref().map(ShardClient::new);
        let push_home = (params.broker, params.broker_node);
        Self {
            params,
            offsets,
            ledger,
            phase: Phase::PullIdle,
            rr: 0,
            next_rpc: 0,
            inflight_since: 0,
            pending: VecDeque::new(),
            trace_keys: VecDeque::new(),
            poll_window: VecDeque::new(),
            ready: VecDeque::new(),
            consuming: None,
            pending_free: None,
            last_switch: 0,
            last_delivery: 0,
            idle_gen: 0,
            pending_epoch: None,
            inc: 0,
            failed: false,
            rpc_floor: 0,
            orphan_subs: 0,
            orphan_unsub_acks: 0,
            orphaned: Vec::new(),
            stale_sub_floor: 0,
            shard,
            push_home,
            inflight_rpc: None,
            rpc_attempts: 0,
            broker_down_retries: 0,
            replayed: 0,
            trim_gap_chunks: 0,
            pulls_issued: 0,
            empty_pulls: 0,
            records_consumed: 0,
            objects_consumed: 0,
            switches_to_push: 0,
            switches_to_pull: 0,
            metrics,
            net,
            store,
            registry,
        }
    }

    fn rpc_to(&mut self, to: ActorId, to_node: NodeId, kind: RpcKind, ctx: &mut Ctx<'_, Msg>) -> u64 {
        let id = self.next_rpc;
        self.next_rpc += 1;
        let deliver = self.net.borrow_mut().send_control(ctx.now(), self.params.node, to_node);
        ctx.send_at(
            deliver,
            to,
            Msg::rpc(RpcRequest {
                id,
                reply_to: ctx.self_id(),
                from_node: self.params.node,
                kind,
            }),
        );
        id
    }

    /// The primary broker for this source's span. A hybrid source's
    /// contiguous span always lives on exactly one primary (see the
    /// divisibility invariants in [`crate::shard`]), so one destination
    /// covers every partition.
    fn home(&self) -> (ActorId, NodeId) {
        match &self.shard {
            Some(client) => client.broker_for(self.offsets[0].0),
            None => (self.params.broker, self.params.broker_node),
        }
    }

    // -------------------------------------------------------------- pull --

    /// Exponential per-RPC deadline: base × 2^(attempts-1), capped.
    fn deadline_for(&self, attempts: u32) -> Time {
        self.params.rpc_deadline_ns.saturating_mul(1 << attempts.saturating_sub(1).min(6))
    }

    /// Arm the deadline race for the raced RPC just issued.
    fn arm_deadline(&mut self, rpc: u64, ctx: &mut Ctx<'_, Msg>) {
        self.inflight_rpc = Some(rpc);
        self.rpc_attempts += 1;
        if self.shard.is_some() && self.params.rpc_deadline_ns > 0 {
            let d = self.deadline_for(self.rpc_attempts);
            ctx.send_self_in(d, Msg::Timer(rpc | crate::producer::DEADLINE_TAG));
        }
    }

    fn issue_pull(&mut self, ctx: &mut Ctx<'_, Msg>) {
        self.maybe_checkpoint(ctx);
        self.pulls_issued += 1;
        self.inflight_since = ctx.now();
        self.metrics.borrow_mut().record(Class::PullRpcs, self.params.task_idx, ctx.now(), 1);
        let kind = RpcKind::Pull {
            assignments: self.offsets.clone(),
            max_bytes: self.params.max_bytes,
        };
        let (to, to_node) = self.home();
        let rpc = self.rpc_to(to, to_node, kind, ctx);
        self.arm_deadline(rpc, ctx);
        self.phase = Phase::PullFetching;
    }

    /// A raced RPC (pull or subscribe) unanswered past its deadline: once
    /// the coordinator's down mask names its broker the request is lost —
    /// refresh the cached table and reissue against the promoted primary.
    /// Both reissues are exactly-once by construction: a pull is an
    /// idempotent read (and the rpc floor strands any straggler reply), a
    /// dead broker never granted the subscribe (its work queue died with
    /// it). Until the detector declares the broker, re-arm and wait.
    fn on_deadline(&mut self, rpc: u64, ctx: &mut Ctx<'_, Msg>) {
        if self.inflight_rpc != Some(rpc) {
            return; // answered or already reissued: stale timer
        }
        match self.phase {
            Phase::PullFetching => {
                let (home, _) = self.home();
                if self.shard.as_ref().is_some_and(|c| c.actor_down(home)) {
                    self.shard.as_mut().expect("down mask implies sharded").refresh();
                    self.broker_down_retries += 1;
                    self.rpc_floor = self.next_rpc;
                    self.issue_pull(ctx);
                } else {
                    let d = self.deadline_for(self.rpc_attempts);
                    ctx.send_self_in(d, Msg::Timer(rpc | crate::producer::DEADLINE_TAG));
                }
            }
            Phase::Subscribing => {
                if self.shard.as_ref().is_some_and(|c| c.actor_down(self.push_home.0)) {
                    self.shard.as_mut().expect("down mask implies sharded").refresh();
                    self.broker_down_retries += 1;
                    self.send_subscribe(ctx); // re-resolves the span's home
                } else {
                    let d = self.deadline_for(self.rpc_attempts);
                    ctx.send_self_in(d, Msg::Timer(rpc | crate::producer::DEADLINE_TAG));
                }
            }
            _ => {} // the raced RPC's phase already resolved
        }
    }

    fn on_pull_data(
        &mut self,
        id: u64,
        chunks: Vec<StampedChunk>,
        trims: Vec<(PartitionId, ChunkOffset)>,
        ctx: &mut Ctx<'_, Msg>,
    ) {
        if id < self.rpc_floor {
            return; // reply to a pre-restore pull: the cursor was rewound
        }
        self.inflight_rpc = None;
        self.rpc_attempts = 0;
        assert!(
            matches!(self.phase, Phase::PullFetching),
            "hybrid source {}: pull data outside PullFetching",
            self.params.task_idx
        );
        // Resume cursors of a torn-down subscription stop pinning
        // retention, so a fallback (or a restore) can land behind the
        // trim point: skip to the floor and count the gap.
        self.trim_gap_chunks += super::api::apply_trims(&mut self.offsets, &trims);
        let latency = ctx.now().saturating_sub(self.inflight_since);
        if self.poll_window.len() >= self.params.tuning.window_polls {
            self.poll_window.pop_front();
        }
        self.poll_window.push_back((chunks.is_empty(), latency));
        if chunks.is_empty() {
            self.empty_pulls += 1;
            if self.metrics.borrow().tracer.enabled() {
                self.metrics.borrow_mut().tracer.note_empty_poll(ctx.now());
            }
            self.maybe_checkpoint(ctx);
            if self.should_switch_to_push(ctx.now()) {
                self.begin_subscribe(ctx);
            } else {
                self.phase = Phase::PullIdle;
                ctx.send_self_in(self.params.pull_timeout, Msg::Timer(TAG_POLL));
            }
            return;
        }
        for sc in &chunks {
            for (p, off) in self.offsets.iter_mut() {
                if *p == sc.partition {
                    *off = (*off).max(sc.offset + 1);
                }
            }
        }
        if self.metrics.borrow().tracer.enabled() {
            let mut m = self.metrics.borrow_mut();
            for sc in &chunks {
                m.tracer.on_notify(sc.partition.0, sc.offset, ctx.now());
            }
        }
        let records: u64 = chunks.iter().map(|c| c.chunk.records as u64).sum();
        // Same serial consume tax as the plain pull source.
        let cost =
            self.params.cost.pull_rpc_client_ns + records * self.params.cost.engine_record_ns;
        self.phase = Phase::PullProcessing(chunks);
        ctx.send_self_in(cost, Msg::JobDone(self.inc * JOB_STRIDE + JOB_PULL));
    }

    fn on_pull_processed(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let Phase::PullProcessing(chunks) =
            std::mem::replace(&mut self.phase, Phase::PullBlocked)
        else {
            panic!("hybrid source {}: JobDone outside PullProcessing", self.params.task_idx)
        };
        self.last_delivery = ctx.now();
        let tracing = self.metrics.borrow().tracer.enabled();
        for sc in chunks {
            self.records_consumed += sc.chunk.records as u64;
            if tracing {
                self.trace_keys.push_back(Some((sc.partition.0, sc.offset)));
            }
            // One chunk per batch, inline — shared, never copied.
            self.pending.push_back(Batch {
                from_task: self.params.task_idx,
                tuples: sc.chunk.records as u64,
                chunks: crate::proto::ChunkList::One(sc.chunk),
                hist: None,
                inc: self.inc,
            });
        }
        self.flush(ctx);
    }

    /// True when the sliding window says pulling is losing to the broker's
    /// write load — and the post-switch cooldown has expired.
    fn should_switch_to_push(&self, now: Time) -> bool {
        let t = &self.params.tuning;
        // Residual push state still draining (flap in progress): a new
        // subscription starts only once the previous one's objects and
        // batches are fully consumed — which also guarantees that in the
        // push phase everything in `ready` belongs to the *current*
        // subscription (the consumed-floor checkpoint relies on that).
        if !self.pending.is_empty()
            || !self.ready.is_empty()
            || self.consuming.is_some()
            || self.pending_free.is_some()
        {
            return false;
        }
        // A restore left a subscription handshake unresolved: no new push
        // phase until its ack lands. This keeps the invariant that while
        // `orphan_subs > 0` no legitimate subscription can exist, which is
        // what lets ObjectReady free dead-handshake fills without relying
        // on cost-model timing.
        if self.orphan_subs > 0 || self.orphan_unsub_acks > 0 {
            return false;
        }
        if self.poll_window.len() < t.window_polls {
            return false;
        }
        if now.saturating_sub(self.last_switch) < t.cooldown_ns {
            return false;
        }
        // Both thresholds are strict ("exceed"): the documented maxima —
        // empty_permille=1000, a huge latency — disable their signal.
        let empties = self.poll_window.iter().filter(|(e, _)| *e).count();
        if (empties * 1000 / self.poll_window.len()) as u32 > t.empty_permille {
            return true;
        }
        let mean_latency: Time = self.poll_window.iter().map(|(_, l)| l).sum::<Time>()
            / self.poll_window.len() as Time;
        mean_latency > t.rpc_latency_ns
    }

    // -------------------------------------------------------------- push --

    /// The subscription RPC itself, aimed at the span's current home
    /// broker (re-resolved here so a `WrongShard` retry lands at the new
    /// primary). `push_home` pins that destination for the rest of the
    /// subscription's life.
    fn send_subscribe(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let spec = PushSourceSpec {
            source_actor: ctx.self_id(),
            assignments: self.offsets.clone(),
            objects: self.params.objects,
            object_bytes: self.params.max_bytes,
        };
        let (to, to_node) = self.home();
        self.push_home = (to, to_node);
        let rpc = self.rpc_to(to, to_node, RpcKind::PushSubscribe { sources: vec![spec] }, ctx);
        self.arm_deadline(rpc, ctx);
    }

    /// The single subscription RPC, issued at the pull loop's current
    /// offsets (pending is empty and no pull is in flight here).
    fn begin_subscribe(&mut self, ctx: &mut Ctx<'_, Msg>) {
        debug_assert!(self.pending.is_empty());
        self.send_subscribe(ctx);
        self.switches_to_push += 1;
        self.metrics.borrow_mut().tracer.note_switch(self.params.task_idx, true, ctx.now());
        self.last_switch = ctx.now();
        self.poll_window.clear();
        self.phase = Phase::Subscribing;
    }

    fn on_subscribed(&mut self, sub: SubId, ctx: &mut Ctx<'_, Msg>) {
        if self.orphan_subs > 0 {
            // A restore hit while this subscribe was in flight: the
            // granted subscription belongs to a dead incarnation. Its
            // unsubscribe ack is recognised through `orphaned`, and the
            // staleness floor moves past it so late fills are freed.
            self.orphan_subs -= 1;
            self.orphaned.push(sub);
            self.stale_sub_floor = self.stale_sub_floor.max(sub.0 + 1);
            let (to, to_node) = self.push_home;
            self.rpc_to(to, to_node, RpcKind::PushUnsubscribe { sub }, ctx);
            return;
        }
        assert!(
            matches!(self.phase, Phase::Subscribing),
            "hybrid source {}: unexpected SubscribeAck",
            self.params.task_idx
        );
        self.inflight_rpc = None;
        self.rpc_attempts = 0;
        self.phase = Phase::Push { sub };
        self.last_delivery = ctx.now(); // the idle clock starts now
        self.idle_gen += 1;
        ctx.send_self_in(
            self.params.tuning.idle_timeout_ns,
            Msg::Timer(TAG_IDLE_BASE + self.idle_gen),
        );
        self.maybe_checkpoint(ctx);
        // The grant may have raced a rebalance (subscribe accepted just
        // before the freeze, epoch published before the ack landed): check
        // the span's home immediately rather than waiting to starve.
        self.maybe_migrate(ctx);
    }

    /// Forced push→pull fallback when a rebalance moved this span away
    /// from the broker holding its live subscription. The old primary
    /// still answers the unsubscribe for its frozen partitions, its
    /// resume cursors cover every sealed fill (residual objects drain
    /// through `ready`/`consuming` as usual), and the next pull
    /// re-resolves to the new primary — the same no-loss/no-duplication
    /// path as a starvation fallback, minus the cooldown (a frozen
    /// primary never delivers again, so waiting it out is pure stall).
    fn maybe_migrate(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let sub = match &self.phase {
            Phase::Push { sub } => *sub,
            _ => return,
        };
        if self.home() == self.push_home {
            return;
        }
        if self.shard.as_ref().is_some_and(|c| c.actor_down(self.push_home.0)) {
            // The old primary is a corpse: no unsubscribe ack will ever
            // come — the forced local fallback handles this span.
            self.maybe_force_pull(ctx);
            return;
        }
        let (to, to_node) = self.push_home;
        self.rpc_to(to, to_node, RpcKind::PushUnsubscribe { sub }, ctx);
        self.switches_to_pull += 1;
        self.metrics.borrow_mut().tracer.note_switch(self.params.task_idx, false, ctx.now());
        self.last_switch = ctx.now();
        self.phase = Phase::Unsubscribing { sub };
    }

    /// Forced push→pull fallback when the broker holding the live (or
    /// tearing-down) subscription has been declared dead. No unsubscribe
    /// ack can ever arrive — a dead broker drops everything — so the
    /// subscription is torn down *locally*: deactivate it on the
    /// node-shared plasma store and sweep its sealed slots back to the
    /// pool. Unconsumed fills are past the consumed floor and are
    /// dropped, not consumed: the promoted primary re-serves everything
    /// past `offsets` through the pull path, so nothing is lost and
    /// nothing repeats. In-flight consumption drains first (its records
    /// advance the floor exactly once); the drain paths call back here.
    fn maybe_force_pull(&mut self, ctx: &mut Ctx<'_, Msg>) {
        if !self.shard.as_ref().is_some_and(|c| c.actor_down(self.push_home.0)) {
            return;
        }
        let (sub, live) = match self.phase {
            Phase::Push { sub } => (sub, true),
            // Forced mid-teardown: the switch was already counted when
            // the unsubscribe went out; its ack died with the broker.
            Phase::Unsubscribing { sub } => (sub, false),
            _ => return,
        };
        self.ready.clear();
        if self.consuming.is_some() || self.pending_free.is_some() || !self.pending.is_empty() {
            return; // drain first; after_drain retries the fallback
        }
        // Late notifications already in flight when the broker died
        // resolve through `orphaned`; the ObjectFreed they trigger lands
        // at the corpse, which is why the sealed-slot sweep happens here,
        // not there.
        self.store.borrow_mut().deactivate(sub);
        self.store.borrow_mut().release_sealed(sub);
        self.orphaned.push(sub);
        if live {
            self.switches_to_pull += 1;
            self.metrics.borrow_mut().tracer.note_switch(self.params.task_idx, false, ctx.now());
        }
        self.broker_down_retries += 1;
        self.last_switch = ctx.now();
        self.phase = Phase::PullIdle;
        ctx.send_self_in(0, Msg::Timer(TAG_POLL));
    }

    /// Start the consume thread on the next sealed object, if free. Runs in
    /// every phase: residual objects of a torn-down subscription must still
    /// drain (their chunks are already reflected in the resume cursors).
    fn try_consume(&mut self, ctx: &mut Ctx<'_, Msg>) {
        if self.pending_epoch.is_some() && matches!(self.phase, Phase::Push { .. }) {
            // Push phase: pause at the consumed floor so the barrier can
            // be taken. Outside it, residual objects must keep draining —
            // the fallback cursors already cover them, so a checkpoint is
            // only consistent once they are consumed (see
            // `clean_for_checkpoint`).
            return;
        }
        if self.consuming.is_some() || self.pending_free.is_some() || !self.pending.is_empty() {
            return;
        }
        let Some(id) = self.ready.pop_front() else { return };
        let (records, _bytes) = self.store.borrow().sealed_counts(id);
        // Pointer access into shared memory — no fetch RPC, no deser copy.
        let cost = self.params.cost.push_object_handle_ns
            + records * self.params.cost.push_consume_record_ns;
        self.consuming = Some(id);
        ctx.send_self_in(cost, Msg::JobDone(self.inc * JOB_STRIDE + JOB_PUSH));
    }

    fn on_object_consumed(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let id = self.consuming.take().expect("JobDone only while consuming");
        self.last_delivery = ctx.now();
        {
            let tracing = self.metrics.borrow().tracer.enabled();
            let store = self.store.borrow();
            for sc in store.read(id) {
                self.records_consumed += sc.chunk.records as u64;
                // The push phase advances the same emitted-floor cursors
                // the pull loop uses — the uniform checkpoint position.
                for (p, off) in self.offsets.iter_mut() {
                    if *p == sc.partition {
                        *off = (*off).max(sc.offset + 1);
                    }
                }
                if tracing {
                    self.metrics.borrow_mut().tracer.on_notify(
                        sc.partition.0,
                        sc.offset,
                        ctx.now(),
                    );
                    self.trace_keys.push_back(Some((sc.partition.0, sc.offset)));
                }
                self.pending.push_back(Batch {
                    from_task: self.params.task_idx,
                    tuples: sc.chunk.records as u64,
                    chunks: crate::proto::ChunkList::One(sc.chunk.clone()),
                    hist: None,
                    inc: self.inc,
                });
            }
        }
        self.objects_consumed += 1;
        self.pending_free = Some(id);
        self.flush(ctx);
    }

    /// Periodic push-phase starvation check: no object for `idle_timeout`
    /// (and past the cooldown) → tear the subscription down. Downstream
    /// credit backpressure is NOT starvation: while objects are queued,
    /// consuming, or draining, the broker is delivering and the pull path
    /// would be equally blocked — tearing down would just churn.
    fn on_idle_check(&mut self, tag: u64, ctx: &mut Ctx<'_, Msg>) {
        if tag != TAG_IDLE_BASE + self.idle_gen {
            return; // stale chain from an earlier push phase
        }
        let Phase::Push { sub } = &self.phase else { return };
        let sub = *sub;
        let t = &self.params.tuning;
        let now = ctx.now();
        let drained = self.ready.is_empty()
            && self.consuming.is_none()
            && self.pending_free.is_none()
            && self.pending.is_empty();
        let starved = drained && now.saturating_sub(self.last_delivery) >= t.idle_timeout_ns;
        if self.shard.as_ref().is_some_and(|c| c.actor_down(self.push_home.0)) {
            // Starvation by broker death, not by an idle stream: no
            // unsubscribe ack can come, so tear down locally (the chain
            // keeps ticking while the fallback waits for the drain).
            self.maybe_force_pull(ctx);
            if matches!(self.phase, Phase::Push { .. }) {
                ctx.send_self_in(t.idle_timeout_ns, Msg::Timer(tag));
            }
        } else if starved && now.saturating_sub(self.last_switch) >= t.cooldown_ns {
            let (to, to_node) = self.push_home;
            self.rpc_to(to, to_node, RpcKind::PushUnsubscribe { sub }, ctx);
            self.switches_to_pull += 1;
            self.metrics.borrow_mut().tracer.note_switch(self.params.task_idx, false, now);
            self.last_switch = now;
            self.phase = Phase::Unsubscribing { sub };
        } else {
            ctx.send_self_in(t.idle_timeout_ns, Msg::Timer(tag));
        }
    }

    fn on_unsubscribed(
        &mut self,
        sub: SubId,
        cursors: Vec<(PartitionId, ChunkOffset)>,
        ctx: &mut Ctx<'_, Msg>,
    ) {
        if self.orphaned.contains(&sub) {
            // The unsubscribe we fired during a restore: sweep any slots
            // whose notifications died with the old incarnation.
            self.store.borrow_mut().release_sealed(sub);
            return;
        }
        if self.orphan_unsub_acks > 0 {
            // A restore hit while this (normal-fallback) unsubscribe was in
            // flight: its cursors are stale — the snapshot already rewound
            // the offsets. Sweep and ignore.
            self.orphan_unsub_acks -= 1;
            self.store.borrow_mut().release_sealed(sub);
            return;
        }
        assert!(
            matches!(self.phase, Phase::Unsubscribing { .. }),
            "hybrid source {}: unexpected UnsubscribeAck",
            self.params.task_idx
        );
        // Resume pulling exactly where the broker's push cursors stopped;
        // in-flight sealed objects still drain through `ready`/`consuming`.
        debug_assert_eq!(cursors.len(), self.offsets.len());
        self.offsets = cursors;
        self.phase = Phase::PullIdle;
        self.maybe_checkpoint(ctx);
        ctx.send_self_in(0, Msg::Timer(TAG_POLL));
    }

    // ------------------------------------------------------- checkpoint --

    /// Clean point: everything fetched/materialised has been emitted, so
    /// `offsets` are exactly the emitted floor. In the push phase, sealed
    /// but unconsumed objects in `ready` are *beyond* the consumed floor
    /// (they all belong to the current subscription — a new one only
    /// starts fully drained) and simply replay after a restore. Outside
    /// it the offsets came from an unsubscribe ack that already covers
    /// the residual objects, so those must drain before the snapshot is
    /// consistent — a snapshot taken earlier would lose their records.
    fn clean_for_checkpoint(&self) -> bool {
        let quiesced = self.pending.is_empty()
            && self.consuming.is_none()
            && self.pending_free.is_none()
            && !matches!(self.phase, Phase::PullProcessing(_));
        quiesced && (matches!(self.phase, Phase::Push { .. }) || self.ready.is_empty())
    }

    fn maybe_checkpoint(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let Some(epoch) = self.pending_epoch else { return };
        if !self.clean_for_checkpoint() {
            return;
        }
        self.pending_epoch = None;
        let cp = self.params.checkpoint.as_ref().expect("barrier implies checkpointing");
        super::api::ack_barrier(cp, epoch, self.checkpoint(), self.params.cost.notify_ns, ctx);
        for &target in &self.params.downstream {
            let actor = self.registry.borrow().actor_of(target);
            ctx.send_in(
                self.params.cost.queue_hop_ns,
                actor,
                Msg::Barrier { epoch, from_task: self.params.task_idx },
            );
        }
        self.try_consume(ctx);
    }

    // --------------------------------------------------------- recovery --

    /// Discard a fill a dead/torn-down consumer cannot use. For a still
    /// *active* subscription, freeing the buffer would make the broker
    /// instantly refill and re-notify it (a free→fill ping-pong until the
    /// orphan unsubscribe lands), so the slot is left sealed: pool
    /// exhaustion pauses fills and the unsubscribe ack's `release_sealed`
    /// sweep reclaims it. Objects of already-inactive subscriptions have
    /// no sweep coming, so those are freed now.
    fn discard_stale(&mut self, id: ObjectId, ctx: &mut Ctx<'_, Msg>) {
        if !self.store.borrow().subscription(id.sub).active {
            // `push_home`, not the wiring default: the broker that granted
            // the subscription owns its pool slots and fill pump.
            ctx.send_in(self.params.cost.notify_ns, self.push_home.0, Msg::ObjectFreed { id });
        }
    }

    fn on_fault(&mut self, ctx: &mut Ctx<'_, Msg>) {
        self.failed = true;
        self.pending_epoch = None;
        let cp = self.params.checkpoint.as_ref().unwrap_or_else(|| {
            panic!("hybrid source {} faulted without checkpointing", self.params.task_idx)
        });
        super::api::report_failure(cp, self.params.cost.notify_ns, ctx);
    }

    /// Global rollback: always land in the pull phase (a hybrid can always
    /// pull). Any live or in-flight subscription is orphaned; held objects
    /// go back to the broker; the cursors and exactly-once counters rewind
    /// to the snapshot.
    fn on_restore(&mut self, inc: u64, ctx: &mut Ctx<'_, Msg>) {
        self.inc = inc;
        self.failed = false;
        match self.phase {
            Phase::Push { sub } => {
                // Orphan the live subscription; its unsubscribe ack and
                // any late object notifications are recognised through
                // `orphaned`.
                self.orphaned.push(sub);
                let (to, to_node) = self.push_home;
                self.rpc_to(to, to_node, RpcKind::PushUnsubscribe { sub }, ctx);
            }
            Phase::Subscribing => self.orphan_subs += 1,
            // A normal-fallback unsubscribe is in flight; its ack is
            // counted rather than matched by sub id.
            Phase::Unsubscribing { .. } => self.orphan_unsub_acks += 1,
            _ => {}
        }
        // Discard held objects (a dead incarnation cannot consume them;
        // their data replays from the cursors). Active-subscription slots
        // stay sealed until the orphan unsubscribe's sweep.
        let held: Vec<ObjectId> = self
            .ready
            .drain(..)
            .chain(self.consuming.take())
            .chain(self.pending_free.take())
            .collect();
        for id in held {
            self.discard_stale(id, ctx);
        }
        self.pending.clear();
        self.trace_keys.clear();
        self.pending_epoch = None;
        self.poll_window.clear();
        self.ledger = CreditLedger::new(&self.params.downstream, self.params.queue_cap);
        self.rr = 0;
        self.idle_gen += 1; // stale idle chains die
        self.rpc_floor = self.next_rpc;
        self.inflight_rpc = None;
        self.rpc_attempts = 0;
        self.stale_sub_floor = self.store.borrow().next_sub_id();
        let cp = self.params.checkpoint.as_ref().expect("restore implies checkpointing");
        let snap = cp.borrow().source_snapshot(ctx.self_id()).unwrap_or(SourceSnapshot {
            cursors: self.params.assignments.clone(),
            ..Default::default()
        });
        debug_assert_eq!(snap.cursors.len(), self.offsets.len());
        self.offsets = snap.cursors;
        self.replayed += self.records_consumed.saturating_sub(snap.records_consumed);
        self.records_consumed = snap.records_consumed;
        super::api::ack_restore(cp, self.params.cost.notify_ns, ctx);
        self.issue_pull(ctx);
    }

    // -------------------------------------------------------------- emit --

    /// Send pending batches while credits allow; once drained, resume the
    /// active loop (free the object / next pull / switch).
    fn flush(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let tracing = self.metrics.borrow().tracer.enabled();
        while !self.pending.is_empty() {
            let n = self.params.downstream.len();
            let Some(k) = (0..n)
                .map(|i| (self.rr + i) % n)
                .find(|&k| self.ledger.has(self.params.downstream[k]))
            else {
                if tracing {
                    self.metrics.borrow_mut().tracer.note_credit_stall(ctx.now());
                }
                return; // blocked (phase stays PullBlocked / object stays held)
            };
            let target = self.params.downstream[k];
            self.rr = k + 1;
            self.ledger.spend(target);
            let batch = self.pending.pop_front().expect("checked non-empty");
            if tracing {
                let key = self.trace_keys.pop_front().flatten();
                self.metrics.borrow_mut().tracer.on_handoff(
                    key,
                    self.params.task_idx,
                    target,
                    ctx.now(),
                );
            }
            let actor = self.registry.borrow().actor_of(target);
            ctx.send_in(self.params.cost.queue_hop_ns, actor, Msg::Data(batch));
        }
        self.after_drain(ctx);
    }

    fn after_drain(&mut self, ctx: &mut Ctx<'_, Msg>) {
        // Step 4: the drained object's buffer returns to the pool of the
        // broker that filled it (its fill pump wakes on the free).
        if let Some(id) = self.pending_free.take() {
            ctx.send_in(self.params.cost.notify_ns, self.push_home.0, Msg::ObjectFreed { id });
        }
        self.maybe_checkpoint(ctx);
        self.maybe_force_pull(ctx); // a deferred dead-home teardown completes here
        self.try_consume(ctx);
        if matches!(self.phase, Phase::PullBlocked) {
            if self.should_switch_to_push(ctx.now()) {
                self.begin_subscribe(ctx);
            } else {
                self.issue_pull(ctx);
            }
        }
    }

    // ------------------------------------------------------ introspection --

    pub fn pulls_issued(&self) -> u64 {
        self.pulls_issued
    }

    pub fn empty_pulls(&self) -> u64 {
        self.empty_pulls
    }

    pub fn records_consumed(&self) -> u64 {
        self.records_consumed
    }

    pub fn objects_consumed(&self) -> u64 {
        self.objects_consumed
    }

    pub fn switches_to_push(&self) -> u64 {
        self.switches_to_push
    }

    pub fn switches_to_pull(&self) -> u64 {
        self.switches_to_pull
    }

    pub fn trim_gap_chunks(&self) -> u64 {
        self.trim_gap_chunks
    }

    pub fn records_replayed(&self) -> u64 {
        self.replayed
    }

    /// True while operating (or transitioning) on the push subscription.
    pub fn is_pushing(&self) -> bool {
        matches!(self.phase, Phase::Subscribing | Phase::Push { .. })
    }
}

impl Actor<Msg> for HybridSource {
    fn on_start(&mut self, ctx: &mut Ctx<'_, Msg>) {
        self.issue_pull(ctx);
    }

    fn on_event(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        if self.failed {
            match msg {
                Msg::Restore { inc, .. } => self.on_restore(inc, ctx),
                // A dead subscriber cannot consume fills; discarding them
                // (sealed until the recovery sweep) also pauses the
                // broker's fill pump via pool exhaustion.
                Msg::ObjectReady { id } => self.discard_stale(id, ctx),
                // Keep the shard view fresh so the restore's first pull
                // goes to the right primary.
                Msg::ShardEpoch { .. } => {
                    if let Some(client) = self.shard.as_mut() {
                        client.refresh();
                    }
                }
                _ => {}
            }
            return;
        }
        match msg {
            Msg::Reply(env) => {
                let RpcEnvelope { id, reply } = *env;
                match reply {
                    RpcReply::PullData { chunks, trims } => {
                        self.on_pull_data(id, chunks, trims, ctx)
                    }
                    RpcReply::SubscribeAck { sub } => self.on_subscribed(sub, ctx),
                    RpcReply::UnsubscribeAck { sub, cursors } => {
                        self.on_unsubscribed(sub, cursors, ctx)
                    }
                    RpcReply::WrongShard { .. } => {
                        if let Some(client) = self.shard.as_mut() {
                            client.refresh();
                        }
                        if id < self.rpc_floor {
                            // A restored-over subscribe refused by a frozen
                            // primary: no subscription was ever granted, so
                            // the orphaned handshake resolves here (a dead
                            // pull's refusal needs nothing at all — at most
                            // one RPC was in flight when the restore hit).
                            self.orphan_subs = self.orphan_subs.saturating_sub(1);
                            return;
                        }
                        self.inflight_rpc = None;
                        self.rpc_attempts = 0;
                        match self.phase {
                            Phase::PullFetching => {
                                // Cursors untouched: retry after the poll
                                // backoff, exactly like an empty poll.
                                self.maybe_checkpoint(ctx);
                                self.phase = Phase::PullIdle;
                                ctx.send_self_in(
                                    self.params.pull_timeout,
                                    Msg::Timer(TAG_POLL),
                                );
                            }
                            // The subscribe raced a rebalance: re-issue at
                            // the span's new home.
                            Phase::Subscribing => self.send_subscribe(ctx),
                            // Unsubscribes are never shard-gated.
                            _ => panic!(
                                "hybrid source {}: WrongShard outside a routed phase",
                                self.params.task_idx
                            ),
                        }
                    }
                    RpcReply::Error { reason } => {
                        panic!("hybrid source {}: {reason}", self.params.task_idx)
                    }
                    other => panic!(
                        "hybrid source {}: unexpected reply {other:?}",
                        self.params.task_idx
                    ),
                }
            }
            Msg::JobDone(tag) => {
                if tag / JOB_STRIDE != self.inc {
                    return; // completion from a rolled-back incarnation
                }
                match tag % JOB_STRIDE {
                    JOB_PULL => self.on_pull_processed(ctx),
                    _ => self.on_object_consumed(ctx),
                }
            }
            Msg::Timer(TAG_POLL) => {
                if matches!(self.phase, Phase::PullIdle) {
                    self.issue_pull(ctx);
                }
            }
            Msg::Timer(tag) if tag & crate::producer::DEADLINE_TAG != 0 => {
                self.on_deadline(tag & !crate::producer::DEADLINE_TAG, ctx)
            }
            Msg::Timer(tag) => self.on_idle_check(tag, ctx),
            Msg::ObjectReady { id } => {
                // Dead-incarnation fills: below the restore floor, from an
                // orphaned subscription, or — while a restored-over
                // subscribe handshake is still unresolved — from the dead
                // subscription whose id we have not learned yet (no
                // legitimate subscription can exist in that window; see
                // should_switch_to_push). Consuming one would jump the
                // cursors past data not yet replayed — free it instead.
                if id.sub.0 < self.stale_sub_floor
                    || self.orphaned.contains(&id.sub)
                    || self.orphan_subs > 0
                {
                    self.discard_stale(id, ctx);
                    return;
                }
                self.ready.push_back(id);
                self.try_consume(ctx);
            }
            Msg::Credit { to_upstream_task, inc } => {
                if inc != self.inc {
                    return; // credit for a pre-rollback batch: ledger was reset
                }
                self.ledger.refund(to_upstream_task);
                if !self.pending.is_empty() {
                    self.flush(ctx);
                }
            }
            Msg::BarrierInject { epoch } => {
                self.pending_epoch = Some(epoch);
                self.maybe_checkpoint(ctx);
            }
            Msg::ShardEpoch { .. } => {
                if let Some(client) = self.shard.as_mut() {
                    client.refresh();
                }
                // A fail-over publish: a dead push home can never answer
                // the teardown RPCs a migration would send.
                self.maybe_force_pull(ctx);
                self.maybe_migrate(ctx);
            }
            Msg::Fault { .. } => self.on_fault(ctx),
            Msg::Restore { inc, .. } => self.on_restore(inc, ctx),
            other => panic!("hybrid source {}: unexpected {other:?}", self.params.task_idx),
        }
    }

    fn label(&self) -> String {
        format!("hybrid-source#{}", self.params.task_idx)
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}

impl StreamSource for HybridSource {
    fn mode(&self) -> SourceMode {
        SourceMode::Hybrid
    }

    fn stats(&self) -> SourceStats {
        let mut extras = super::api::StatExtras::new();
        extras.insert(StatKey::ObjectsConsumed, self.objects_consumed);
        extras.insert(StatKey::SwitchesToPush, self.switches_to_push);
        extras.insert(StatKey::SwitchesToPull, self.switches_to_pull);
        extras.insert(StatKey::Subscribed, matches!(self.phase, Phase::Push { .. }) as u64);
        if self.replayed > 0 {
            extras.insert(StatKey::RecordsReplayed, self.replayed);
        }
        if self.trim_gap_chunks > 0 {
            extras.insert(StatKey::TrimGapChunks, self.trim_gap_chunks);
        }
        if self.broker_down_retries > 0 {
            extras.insert(StatKey::BrokerDownRetries, self.broker_down_retries);
        }
        SourceStats {
            records_consumed: self.records_consumed,
            pulls_issued: self.pulls_issued,
            empty_pulls: self.empty_pulls,
            // Pull phase: fetch + emit, like a plain pull source. Push
            // phase: just this source's consume loop — the one dedicated
            // broker push thread is shared by every subscription and is
            // already reserved out of `NBc` (counting it per source would
            // inflate the aggregate footprint by Nc-1). Note the deliberate
            // convention difference vs `PushSourceGroup`, which folds that
            // broker thread into its single group-wide figure: the hybrid
            // aggregate is Nc, with the broker-side thread visible through
            // `broker.push_util` instead.
            threads: if matches!(self.phase, Phase::Push { .. }) { 1 } else { 2 },
            extras,
        }
    }

    fn checkpoint(&self) -> SourceSnapshot {
        SourceSnapshot {
            cursors: self.offsets.clone(),
            records_consumed: self.records_consumed,
            ..Default::default()
        }
    }
}

/// Builds one [`HybridSource`] per consumer. Reserves a broker push thread
/// so the push phase has somewhere to switch to.
pub struct HybridSourceFactory;

impl SourceFactory for HybridSourceFactory {
    fn mode(&self) -> SourceMode {
        SourceMode::Hybrid
    }

    fn broker_push_threads(&self) -> usize {
        1
    }

    fn build(&self, w: &SourceWiring<'_>, engine: &mut Engine<Msg>) -> Vec<ActorId> {
        let c = w.config;
        (0..c.nc)
            .map(|i| {
                let src = HybridSource::new(
                    HybridParams {
                        task_idx: i,
                        node: w.node,
                        broker: w.broker,
                        broker_node: w.broker_node,
                        assignments: w.member_assignments(i),
                        max_bytes: c.consumer_chunk as u64,
                        pull_timeout: c.pull_timeout_us * 1_000,
                        downstream: w.downstream.clone(),
                        queue_cap: c.queue_cap,
                        objects: c.push_objects_per_source,
                        tuning: HybridTuning::from_config(c),
                        checkpoint: w.checkpoint.clone(),
                        cost: c.cost.clone(),
                        shard: w.shard.clone(),
                        rpc_deadline_ns: c.rpc_deadline_ms * crate::sim::MILLIS,
                    },
                    w.metrics.clone(),
                    w.net.clone(),
                    w.store.clone(),
                    w.registry.clone(),
                );
                let id = engine.add_actor(Box::new(SourceActor::new(Box::new(src))));
                w.registry.borrow_mut().register(i, id);
                id
            })
            .collect()
    }
}
