//! # ZettaStream
//!
//! A unified real-time storage and processing architecture reproducing
//! *"Colocating Real-time Storage and Processing: An Analysis of Pull-based
//! versus Push-based Streaming"* (Marcu & Bouvry, 2022).
//!
//! The crate is a three-layer rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the KerA-like storage broker, the
//!   Plasma-like shared-memory object store, the pull/push/native/hybrid
//!   streaming sources behind the pluggable [`source::StreamSource`] trait
//!   API, a Flink-like processing worker with a DataStream pipeline
//!   API, producers, metrics and the experiment harness, all driven by a
//!   deterministic discrete-event engine ([`sim`]).
//! * **Layer 2/1 (python/, build-time only)** — the operators' compute
//!   hot-spots (substring filter, word-hash histogram) as Pallas kernels
//!   inside JAX graphs, AOT-lowered to HLO text artifacts that
//!   [`runtime`] loads and [`compute`] executes through PJRT on the
//!   request path. Python never runs at request time.
//!
//! Quick tour: [`config::ExperimentConfig`] describes a run in the paper's
//! own Table I vocabulary; [`cluster::launch`] wires brokers, workers,
//! producers and sources into an engine — sources are built through the
//! [`source::SourceRegistry`], so selecting an ingestion mechanism is just
//! `config.mode`: [`config::SourceMode::Pull`], `Push`, `NativePull`, or
//! the adaptive [`config::SourceMode::Hybrid`], which starts pulling and
//! hands off to the push subscription when writes starve its pull RPCs
//! (see [`source::HybridSource`]).
//!
//! The **write path** is the symmetric axis: producers are built through
//! the [`producer::WriterRegistry`] behind the [`producer::WritePath`]
//! trait, keyed by `config.write_mode` —
//! [`config::WriteMode::SyncRpc`] (the paper's §V-A synchronous
//! `generate → Append → ack` baseline), [`config::WriteMode::Pipelined`]
//! (bounded in-flight append window with per-partition ack sequencing) or
//! [`config::WriteMode::SharedMem`] (one `WriteSubscribe` RPC, then the
//! colocated producer fills plasma objects the broker seals into the log —
//! object exhaustion replaces RPC pacing as write backpressure). All
//! writers report uniform [`producer::WriteStats`], retry rejected appends
//! with bounded backoff and surface [`producer::WriteError`] instead of
//! panicking.
//!
//! **Fault tolerance** is the third axis: with `checkpoint_interval_ms`
//! set, a [`checkpoint::CheckpointCoordinator`] periodically injects
//! aligned barriers at every source; barriers flow in-band through the
//! operator exchange channels, multi-input tasks align and snapshot their
//! operator state ([`ops::OpState`]), and every source captures its
//! per-partition cursors uniformly through the
//! [`source::StreamSource::checkpoint`] trait extension — so all four
//! modes checkpoint identically. Completed epochs are committed to the
//! broker (`CommitCheckpoint`), whose cursors become the floor for
//! watermark log trimming: retention can never pass the last restorable
//! point. `fault_at_secs`/`fault_kind` inject a worker-, source- or
//! broker-kill on the sim plane; a worker or source kill recovers by
//! rolling the whole dataflow back to the last completed checkpoint and
//! replaying, while a **broker** kill recovers by *replica promotion*
//! instead (see the fail-over paragraph below) — either way, a faulted
//! run reports identical record/window totals to the fault-free run on
//! the same seed (exactly-once). [`experiments`] regenerates every figure
//! of the paper's evaluation plus the pull/push/hybrid, write-path,
//! checkpoint/recovery and storage-tier ablations.
//!
//! ## The storage tier
//!
//! The broker's partition logs live behind the [`broker::LogStore`] trait,
//! built through the [`broker::StoreRegistry`] and keyed by
//! `config.store_mode` — the storage mirror of the source and writer
//! registries. [`config::StoreMode::Memory`] is today's in-memory
//! segmented log (the sim default, zero behavioural change).
//! [`config::StoreMode::Durable`] is a tiered disk backend
//! ([`broker::DurableStore`], module [`broker::store`]): every append is
//! framed and checksummed into a rotating **write-ahead-log ring** before
//! it lands in the in-memory tail, sealed tail segments are flushed to
//! immutable **sorted segment files** with per-file bloom filters, and
//! **background compaction** merges cold files and drops trimmed
//! prefixes. Checkpoint-committed cursors floor the broker's watermark
//! trimming exactly as in memory mode, so the compaction floor is the
//! last restorable epoch; a broker restart replays the WAL into a
//! consistent tail and resumes byte-identically (crash-recovery tests in
//! `tests/durable_store.rs`). Cold reads decode a segment file once and
//! re-enter the data spine as shared `Rc` payloads, keeping the zero-copy
//! discipline below intact across the disk hop. `TrimmedError` and
//! trim-gap semantics are identical across both backends, so sources and
//! checkpoint recovery never know which one is underneath.
//!
//! ## Multi-broker scale-out
//!
//! The paper's KerA lineage is a *sharded* store — so the broker tier
//! scales out behind the [`shard`] module. `broker_count > 1` builds N
//! broker actors that each host only their assigned slice of the
//! partition space, under a [`shard::ShardCoordinator`] that owns the
//! **versioned assignment table** ([`shard::ShardTable`]): partitions map
//! to per-shard **replica sets** of `replication_factor` brokers, appends
//! replicate primary → backups and ack on a **commit quorum**, and every
//! producer and source routes each RPC through a cached
//! [`shard::ShardClient`] epoch — a request that lands on a broker that no
//! longer serves the partition is refused with `WrongShard`, the client
//! refreshes its table and retries (counted, never panicking).
//! `rebalance_at_secs` exercises the control loop live: the coordinator
//! **freezes** the moving partitions at the old primary (drain in-flight
//! fills, checkpoint replica cursors), **promotes** a backup to primary,
//! then publishes the new epoch to every routing client — push
//! subscriptions migrate by resubscribing at their consumed floor, hybrid
//! sources fall back to pull across the hand-off, and golden-totals
//! parity across all 4 source × 3 write modes with a mid-run rebalance is
//! pinned by `tests/shard_rebalance.rs` (zero loss, zero duplication).
//! `zettastream bench shard` sweeps `broker_count` 1→3 with and without a
//! live rebalance and reports the `shard.*` hand-off gauges.
//!
//! Scale-out's other half is **fail-over**: at `replication_factor >= 2`
//! the coordinator runs a heartbeat failure detector
//! (`shard_heartbeat_ms` probes, a `shard_lease_ms` lease) and a broker
//! silent past its lease is declared dead — no freeze, no drain; an
//! **emergency epoch** promotes each orphaned partition's standing
//! replica (which already holds every quorum-acked byte) and shrinks the
//! survivors' replica sets. Clients escape the corpse by *deadline*, not
//! by reply: every sharded writer and source arms a per-RPC
//! `rpc_deadline_ms` timer with exponential backoff, and on expiry
//! consults the published down-mask — writers retransmit to the promoted
//! primary under the broker's append-idempotence table, pull sources
//! reissue at their cursors, push groups tear down locally and
//! resubscribe at their consumed floor, hybrids force the pull fallback
//! across the outage. `fault_kind=broker` injects the kill,
//! `tests/broker_failover.rs` pins golden-totals parity across all
//! 12 source × write cells, and `zettastream bench chaos` runs the
//! scripted kill schedules and records detection time, promotions and
//! per-path retry counts in `BENCH_chaos.json`.
//!
//! ## Data-plane memory discipline
//!
//! The paper's thesis is that streaming gets faster when storage and
//! processing "handle streaming data through pointers to shared objects"
//! instead of copying bytes per RPC — so the in-memory data path holds
//! itself to an explicit sharing discipline (enforced by the zero-copy
//! regression tests in `tests/zero_copy_parity.rs`):
//!
//! * **Payload bytes are materialised exactly once**, by the producer's
//!   generator ([`proto::Chunk::real`], the only birthplace — it counts
//!   materialisations). Every later hand-off shares the `Rc`d buffer:
//!   broker log append moves the chunk in, pull replies and push-object
//!   fills share segment-resident chunks out ([`broker::PartitionLog`]
//!   serves reads by linear segment walk into an exactly-pre-sized reply,
//!   never a per-chunk search), the plasma store seals pointers, and
//!   sources hand the same chunk into the pipeline.
//! * **A batch hop moves a pointer, not a `Vec`.** [`proto::Batch`]
//!   carries its chunks as a [`proto::ChunkList`]: the dominant one-chunk
//!   batch is stored inline (no allocation at all), multi-chunk batches
//!   share an `Rc<[Chunk]>`, so the chained-operator passthrough clone is
//!   a refcount bump.
//! * **`Msg` stays ≤ 64 bytes** (compile-time assert in [`proto`]): every
//!   event the DES engine queues and sifts is one `Msg` by value, so the
//!   fat RPC envelopes are boxed ([`proto::Msg::rpc`]/[`proto::Msg::reply`]
//!   — paid once per RPC, saved `O(log n)` times per heap sift) while the
//!   hot dataflow variants stay inline within one cache line. The engine
//!   itself serves same-timestamp events (credits, notifications) from an
//!   O(1) FIFO now-queue in front of the heap, and operator tasks reuse
//!   pooled output buffers/scratch ([`ops::OpOutput`]) so the steady-state
//!   hot path allocates nothing per batch. `zettastream bench hotpath`
//!   measures all of this (events/sec, virtual-vs-wall) across every
//!   source × write mode and records the trajectory in
//!   `BENCH_hotpath.json`.
//!
//! ## Observability
//!
//! The paper's latency claim finally has an instrument: the [`obs`]
//! module traces sampled records through produce → append (incl. the
//! durable store's WAL cost) → seal/notify or pull-reply → consume
//! hand-off → operator emit, folding each stage delta into log2-bucketed
//! histograms ([`obs::LatencyHistogram`]) that report per-stage and
//! end-to-end p50/p95/p99/p999, merged exactly across entities. The
//! [`obs::Tracer`] lives inside the [`metrics::MetricsHub`] blackboard
//! every actor already holds; `trace_sample_permille` picks spans
//! deterministically and **0 keeps the zero-copy hot path untouched**
//! (the parity suite pins byte-identical totals and payload-allocation
//! counters). `trace_out` streams spans, checkpoint epochs, hybrid
//! switch-overs and fault/restore events to a JSONL sink that replays
//! byte-identically on a fixed seed, and the tracer's per-second series
//! (empty polls, credit stalls, append latency) plus `obs.*` gauges are
//! the controller inputs the elastic-runtime roadmap item needs.
//! `zettastream bench latency` sweeps all 4 source × 3 write modes and
//! records the per-stage breakdown in `BENCH_latency.json` — the
//! pull-vs-push latency question, answered with numbers.
//!
//! ## Execution planes
//!
//! Everything above runs on either of two execution planes, selected by
//! `config.plane` ([`config::ExecPlane`]):
//!
//! * **`plane=sim`** (default) — one deterministic DES engine drives the
//!   whole cluster on a virtual clock; every figure and test above runs
//!   here.
//! * **`plane=real`** ([`real`]) — the *same actors, same protocol, same
//!   construction paths* run on OS threads with RPCs as length-prefixed
//!   frames over localhost TCP. The seam is the [`transport::Transport`]
//!   trait with two implementations: [`transport::SimTransport`] (the DES
//!   network blackboard) and [`transport::TcpTransport`] (real sockets,
//!   per-connection reader/writer threads, hand-rolled codec in
//!   [`transport::wire`] — no serde). Cluster topology matches the paper's
//!   node split: the broker, pipeline, sources and plasma store share the
//!   colocated node thread (push notifications and shared-memory writes
//!   never touch a socket — that *is* the colocation premise), while
//!   sync/pipelined producers live on a producer node thread and append
//!   over TCP. Bounded runs drain to quiescence and report golden totals
//!   that match the sim plane byte for byte on the same seed
//!   (`tests/real_plane.rs`); `zettastream broker --listen` serves a
//!   standalone broker that external clients drive over the wire
//!   (`tests/broker_contract.rs`), and `zettastream bench hotpath` reports
//!   both planes side by side with a `plane` key per cell.

pub mod config;
pub mod sim;
pub mod broker;
pub mod checkpoint;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod plasma;
pub mod proto;
pub mod compute;
pub mod producer;
pub mod runtime;
pub mod wikipedia;
pub mod cluster;
pub mod ops;
pub mod pipeline;
pub mod real;
pub mod shard;
pub mod source;
pub mod transport;
pub mod worker;
pub mod experiments;
