//! # ZettaStream
//!
//! A unified real-time storage and processing architecture reproducing
//! *"Colocating Real-time Storage and Processing: An Analysis of Pull-based
//! versus Push-based Streaming"* (Marcu & Bouvry, 2022).
//!
//! The crate is a three-layer rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the KerA-like storage broker, the
//!   Plasma-like shared-memory object store, the pull/push/native/hybrid
//!   streaming sources behind the pluggable [`source::StreamSource`] trait
//!   API, a Flink-like processing worker with a DataStream pipeline
//!   API, producers, metrics and the experiment harness, all driven by a
//!   deterministic discrete-event engine ([`sim`]).
//! * **Layer 2/1 (python/, build-time only)** — the operators' compute
//!   hot-spots (substring filter, word-hash histogram) as Pallas kernels
//!   inside JAX graphs, AOT-lowered to HLO text artifacts that
//!   [`runtime`] loads and [`compute`] executes through PJRT on the
//!   request path. Python never runs at request time.
//!
//! Quick tour: [`config::ExperimentConfig`] describes a run in the paper's
//! own Table I vocabulary; [`cluster::launch`] wires brokers, workers,
//! producers and sources into an engine — sources are built through the
//! [`source::SourceRegistry`], so selecting an ingestion mechanism is just
//! `config.mode`: [`config::SourceMode::Pull`], `Push`, `NativePull`, or
//! the adaptive [`config::SourceMode::Hybrid`], which starts pulling and
//! hands off to the push subscription when writes starve its pull RPCs
//! (see [`source::HybridSource`]).
//!
//! The **write path** is the symmetric axis: producers are built through
//! the [`producer::WriterRegistry`] behind the [`producer::WritePath`]
//! trait, keyed by `config.write_mode` —
//! [`config::WriteMode::SyncRpc`] (the paper's §V-A synchronous
//! `generate → Append → ack` baseline), [`config::WriteMode::Pipelined`]
//! (bounded in-flight append window with per-partition ack sequencing) or
//! [`config::WriteMode::SharedMem`] (one `WriteSubscribe` RPC, then the
//! colocated producer fills plasma objects the broker seals into the log —
//! object exhaustion replaces RPC pacing as write backpressure). All
//! writers report uniform [`producer::WriteStats`], retry rejected appends
//! with bounded backoff and surface [`producer::WriteError`] instead of
//! panicking.
//!
//! **Fault tolerance** is the third axis: with `checkpoint_interval_ms`
//! set, a [`checkpoint::CheckpointCoordinator`] periodically injects
//! aligned barriers at every source; barriers flow in-band through the
//! operator exchange channels, multi-input tasks align and snapshot their
//! operator state ([`ops::OpState`]), and every source captures its
//! per-partition cursors uniformly through the
//! [`source::StreamSource::checkpoint`] trait extension — so all four
//! modes checkpoint identically. Completed epochs are committed to the
//! broker (`CommitCheckpoint`), whose cursors become the floor for
//! watermark log trimming: retention can never pass the last restorable
//! point. `fault_at_secs`/`fault_kind` inject a worker- or source-kill on
//! the sim plane; recovery rolls the whole dataflow back to the last
//! completed checkpoint and replays — a faulted run reports identical
//! record/window totals to the fault-free run on the same seed
//! (exactly-once). [`experiments`] regenerates every figure of the paper's
//! evaluation plus the pull/push/hybrid, write-path and
//! checkpoint/recovery ablations.

pub mod config;
pub mod sim;
pub mod broker;
pub mod checkpoint;
pub mod metrics;
pub mod net;
pub mod plasma;
pub mod proto;
pub mod compute;
pub mod producer;
pub mod runtime;
pub mod wikipedia;
pub mod cluster;
pub mod ops;
pub mod pipeline;
pub mod source;
pub mod worker;
pub mod experiments;
