//! The proxy actors that splice a [`crate::transport::Transport`]
//! connection into a node's local engine.
//!
//! On the real plane every node runs its own single-threaded DES engine as
//! a plain event loop; the only things that cross node (thread) boundaries
//! are encoded frames. These two actors are the splice points:
//!
//! * [`ClientLink`] stands in for a *remote broker*: a producer addresses
//!   its `Msg::Rpc` at the link exactly as it would address a local broker
//!   actor, and the link turns it into a [`WireMsg::Req`] staged on the
//!   shared [`Outbox`]. When the reply frame lands, the node driver asks
//!   the link to translate the connection-scoped wire id back into the
//!   original `(RpcId, reply_to)` pair and re-injects a `Msg::Reply`.
//! * [`ServerLink`] stands in for a *remote client*: the broker addresses
//!   replies and `ObjectReady` notifications at the link exactly as it
//!   would address a local producer or source, and the link stages the
//!   corresponding `Rep`/`Evt` frames.
//!
//! Neither link touches a socket — they only stage `(ConnId, WireMsg)`
//! pairs on the outbox; the [`crate::real::NodeDriver`] flushes the outbox
//! through the transport after every engine pump. That keeps the actors
//! single-threaded and panic-free while the transport owns all blocking.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::proto::{Msg, RpcId};
use crate::sim::{Actor, ActorId, Ctx};
use crate::transport::{ConnId, WireEvent, WireMsg};

/// Frames staged by link actors for the node driver to flush. Engine-local
/// (`Rc`), like every other piece of node state on the real plane.
pub type Outbox = Rc<RefCell<Vec<(ConnId, WireMsg)>>>;

/// Local stand-in for a broker that lives on another node.
pub struct ClientLink {
    conn: ConnId,
    outbox: Outbox,
    next_wire: u64,
    /// wire id -> the original request identity to restore on reply.
    pending: HashMap<u64, (RpcId, ActorId)>,
}

impl ClientLink {
    pub fn new(conn: ConnId, outbox: Outbox) -> Self {
        Self { conn, outbox, next_wire: 1, pending: HashMap::new() }
    }

    /// Resolve a reply frame's wire id back to `(client RpcId, reply_to)`.
    /// `None` means the peer replied to something we never sent — the
    /// driver drops the frame (and reports it) instead of corrupting an
    /// unrelated client's state.
    pub fn take_pending(&mut self, wire_id: u64) -> Option<(RpcId, ActorId)> {
        self.pending.remove(&wire_id)
    }

    /// Requests sent but not yet answered (drain / shutdown accounting).
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }
}

impl Actor<Msg> for ClientLink {
    fn on_event(&mut self, msg: Msg, _ctx: &mut Ctx<'_, Msg>) {
        match msg {
            Msg::Rpc(req) => {
                let req = *req;
                let wire_id = self.next_wire;
                self.next_wire += 1;
                self.pending.insert(wire_id, (req.id, req.reply_to));
                self.outbox.borrow_mut().push((
                    self.conn,
                    WireMsg::Req {
                        wire_id,
                        from_node: req.from_node as u32,
                        kind: req.kind,
                    },
                ));
            }
            other => panic!("client link got non-RPC message {other:?}"),
        }
    }

    fn label(&self) -> String {
        format!("client-link(conn#{})", self.conn)
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}

/// Local stand-in for a producer/source that lives on another node.
pub struct ServerLink {
    conn: ConnId,
    outbox: Outbox,
    replies_sent: u64,
}

impl ServerLink {
    pub fn new(conn: ConnId, outbox: Outbox) -> Self {
        Self { conn, outbox, replies_sent: 0 }
    }

    /// Replies staged over this connection's lifetime — reported in the
    /// graceful-shutdown [`WireMsg::Bye`] so clients can cross-check that
    /// no ack was dropped in the drain.
    pub fn replies_sent(&self) -> u64 {
        self.replies_sent
    }
}

impl Actor<Msg> for ServerLink {
    fn on_event(&mut self, msg: Msg, _ctx: &mut Ctx<'_, Msg>) {
        match msg {
            Msg::Reply(env) => {
                self.replies_sent += 1;
                self.outbox
                    .borrow_mut()
                    .push((self.conn, WireMsg::Rep { wire_id: env.id, reply: env.reply }));
            }
            Msg::ObjectReady { id } => {
                self.outbox.borrow_mut().push((
                    self.conn,
                    WireMsg::Evt {
                        event: WireEvent::ObjectReady {
                            sub: id.sub.0 as u64,
                            slot: id.slot as u64,
                        },
                    },
                ));
            }
            other => panic!("server link got unexpected message {other:?}"),
        }
    }

    fn label(&self) -> String {
        format!("server-link(conn#{})", self.conn)
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}
