//! The standalone broker server behind `zettastream broker --listen`.
//!
//! A broker-only node driven over real TCP by external clients — the
//! spawned-binary contract harness (`tests/broker_contract.rs`) exercises
//! the full RPC surface against it and asserts on this module's structured
//! output. Two output contracts:
//!
//! * one flushed plain-text ready line,
//!   `ZETTASTREAM-BROKER ready addr=<host:port>`, so a harness that
//!   listened on port 0 can learn the ephemeral port;
//! * one JSON object per line afterwards (`{"event":...}`): connection
//!   lifecycle, every request dispatched, every frame sent, and a final
//!   `shutdown` record with the transport thread accounting.
//!
//! The server trusts nobody: every subscription spec's actor ids are
//! rewritten to the connection's [`ServerLink`], so `ObjectReady`
//! notifications and acks travel back over the wire as frames (see the
//! driver's trust docs). A [`WireMsg::Shutdown`] frame triggers the
//! graceful drain: pump until quiescent, send each connection a
//! [`WireMsg::Bye`] carrying its reply count, flush, join every thread.

use std::io::Write as _;

use crate::broker::StoreRegistry;
use crate::cluster::build_brokers;
use crate::config::ExperimentConfig;
use crate::metrics::MetricsHub;
use crate::net::Network;
use crate::plasma::ObjectStore;
use crate::proto::PartitionId;
use crate::sim::Engine;
use crate::transport::{TcpTransport, Transport, WireMsg};

use super::driver::{NodeDriver, Notable};
use super::links::ServerLink;

/// Escape a value for embedding inside a JSON string literal.
fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn jline(line: String) {
    println!("{line}");
}

/// Run a broker-only node on `listen` until a client sends `Shutdown`.
pub fn run_broker_server(listen: &str, config: &ExperimentConfig) -> Result<(), String> {
    let listener = TcpTransport::listen(listen)
        .map_err(|e| format!("broker: listening on {listen}: {e}"))?;
    let addr = listener.local_addr().expect("listener has an address");

    let mut engine = Engine::new(config.seed);
    let metrics = MetricsHub::shared();
    let net = Network::shared(config.cost.network, config.cost.loopback);
    let store = ObjectStore::shared();
    let partitions: Vec<PartitionId> = (0..config.ns).map(PartitionId).collect();
    // Always keep one push thread: external clients may PushSubscribe, and
    // fills must complete so ObjectReady events flow back as frames.
    let (broker, _backup) = build_brokers(
        &mut engine,
        config,
        &StoreRegistry::builtin(),
        1,
        &partitions,
        &net,
        &store,
        &metrics,
    );

    let mut driver = NodeDriver::new(engine, listener, 0, false);
    driver.serve(broker);

    println!("ZETTASTREAM-BROKER ready addr={addr}");
    std::io::stdout().flush().map_err(|e| format!("flushing ready line: {e}"))?;

    let mut shutdown_requested = false;
    let mut wait = 0u64;
    loop {
        let r = driver.step(wait);
        wait = if r.is_idle() { 5 } else { 0 };
        for n in &r.notables {
            emit(n);
        }
        if r.notables.iter().any(|n| matches!(n, Notable::ShutdownRequested { .. })) {
            shutdown_requested = true;
        }
        if shutdown_requested && r.is_idle() {
            break;
        }
    }

    // Drain whatever the shutdown race left in flight, then say goodbye on
    // every live connection with its reply count (the no-lost-acks proof).
    for n in driver.settle(3, 2000) {
        emit(&n);
    }
    let links = driver.server_links();
    for &(conn, link) in &links {
        let replies_sent = driver
            .engine
            .actor_as::<ServerLink>(link)
            .map(|l| l.replies_sent())
            .unwrap_or(0);
        driver.stage(conn, WireMsg::Bye { replies_sent });
    }
    let r = driver.step(0);
    for n in &r.notables {
        emit(n);
    }

    let (_engine, transport) = driver.into_parts();
    let report = transport.shutdown();
    jline(format!(
        "{{\"event\":\"shutdown\",\"threads_spawned\":{},\"threads_joined\":{}}}",
        report.spawned, report.joined
    ));
    std::io::stdout().flush().map_err(|e| format!("flushing shutdown line: {e}"))?;
    if report.spawned != report.joined {
        return Err(format!(
            "transport leaked threads: spawned {} joined {}",
            report.spawned, report.joined
        ));
    }
    Ok(())
}

fn emit(n: &Notable) {
    match n {
        Notable::Accepted { conn } => {
            jline(format!("{{\"event\":\"accepted\",\"conn\":{conn}}}"));
        }
        Notable::Req { conn, wire_id, label } => {
            jline(format!(
                "{{\"event\":\"req\",\"conn\":{conn},\"wire_id\":{wire_id},\"kind\":\"{label}\"}}"
            ));
        }
        Notable::Sent { conn, label } => {
            jline(format!("{{\"event\":\"sent\",\"conn\":{conn},\"kind\":\"{label}\"}}"));
        }
        Notable::Event { conn, event } => {
            jline(format!(
                "{{\"event\":\"notify\",\"conn\":{conn},\"detail\":\"{}\"}}",
                json_escape(&format!("{event:?}"))
            ));
        }
        Notable::ShutdownRequested { conn } => {
            jline(format!("{{\"event\":\"shutdown_requested\",\"conn\":{conn}}}"));
        }
        Notable::Bye { conn, replies_sent } => {
            jline(format!(
                "{{\"event\":\"bye\",\"conn\":{conn},\"replies_sent\":{replies_sent}}}"
            ));
        }
        Notable::Closed { conn, error } => match error {
            None => jline(format!("{{\"event\":\"closed\",\"conn\":{conn}}}")),
            Some(e) => jline(format!(
                "{{\"event\":\"closed\",\"conn\":{conn},\"error\":\"{}\"}}",
                json_escape(&format!("{e:?}"))
            )),
        },
        Notable::SendFailed { conn, error } => {
            jline(format!(
                "{{\"event\":\"send_failed\",\"conn\":{conn},\"error\":\"{}\"}}",
                json_escape(&format!("{error:?}"))
            ));
        }
        Notable::BadHello { conn, version } => {
            jline(format!("{{\"event\":\"bad_hello\",\"conn\":{conn},\"version\":{version}}}"));
        }
        Notable::OrphanReply { conn, wire_id } => {
            jline(format!(
                "{{\"event\":\"orphan_reply\",\"conn\":{conn},\"wire_id\":{wire_id}}}"
            ));
        }
    }
}
