//! The per-node event loop of the real plane: one DES engine pumped as a
//! plain event loop, one [`Transport`], and the glue that turns frames
//! into engine messages and staged messages into frames.
//!
//! # The pump
//!
//! Each [`NodeDriver::step`] does three things, in order:
//!
//! 1. `transport.poll(wait)` — collect inbound frames and connection
//!    events, injecting them into the engine queue at the node's *current
//!    virtual time*;
//! 2. `engine.run_until(now + PUMP_SLICE)` — advance the node's virtual
//!    clock by one bounded slice, executing whatever the actors queued;
//! 3. flush the [`Outbox`] — every frame the link actors staged goes out
//!    through the transport.
//!
//! The slice is *bounded* on purpose. A node's actors are allowed to be
//! self-sustaining (a pull source's empty-poll timer loop re-arms itself
//! forever), so "run the engine dry" would never return; a bounded slice
//! interleaves local progress with socket progress no matter what the
//! actors do. Virtual time still means what it means on the sim plane —
//! costs, timeouts and per-second metric buckets all keep their shape —
//! it just advances in 1 ms hops gated on real I/O instead of in one
//! uninterrupted sweep.
//!
//! # Trust and actor-id rewriting
//!
//! Requests carry engine-local actor ids inside their specs
//! ([`crate::proto::PushSourceSpec::source_actor`],
//! [`crate::proto::WriteProducerSpec::producer_actor`]). Those ids are
//! only meaningful inside the *sender's* engine. A driver serving a
//! connection therefore rewrites them to the connection's [`ServerLink`]
//! unless the peer proved same-cluster membership (its
//! [`WireMsg::Hello`] cookie matched and the driver was built with
//! `trust_cookie`); rewritten notifications and acks then route back over
//! the wire instead of into a foreign actor table. Cluster nodes built by
//! [`crate::real::run_cluster`] share a per-run cookie; the standalone
//! `zettastream broker` server trusts nobody.

use std::collections::HashMap;

use crate::proto::{Msg, RpcEnvelope, RpcKind, RpcRequest};
use crate::sim::{ActorId, Engine, Time, MILLIS};
use crate::transport::{
    wire::msg_label, ConnId, FrameError, Transport, TransportEvent, WireEvent, WireMsg,
    WIRE_VERSION,
};

use super::links::{ClientLink, Outbox, ServerLink};

/// Virtual time one pump step advances the node's engine: long enough to
/// complete whole local request/reply cascades (costs are µs-scale), short
/// enough that cross-node round trips gate on sockets, not on virtual
/// sweeps.
pub const PUMP_SLICE: Time = MILLIS;

/// Things a pump step observed that the caller may want to act on or log
/// (the server turns these into its JSONL event stream; the cluster
/// orchestrator watches for `ShutdownRequested` and abnormal closes).
#[derive(Debug)]
pub enum Notable {
    /// A peer connected (a [`ServerLink`] now serves the connection).
    Accepted { conn: ConnId },
    /// A request frame was dispatched to the local broker.
    Req { conn: ConnId, wire_id: u64, label: &'static str },
    /// A staged frame was handed to the transport.
    Sent { conn: ConnId, label: &'static str },
    /// A server-initiated notification arrived (client side).
    Event { conn: ConnId, event: WireEvent },
    /// The peer asked this node to drain and close.
    ShutdownRequested { conn: ConnId },
    /// The peer's final frame of a graceful drain.
    Bye { conn: ConnId, replies_sent: u64 },
    /// A connection ended; `error` is `None` on a clean close.
    Closed { conn: ConnId, error: Option<FrameError> },
    /// A frame could not be handed to the transport.
    SendFailed { conn: ConnId, error: FrameError },
    /// A peer spoke an incompatible protocol version; connection dropped.
    BadHello { conn: ConnId, version: u32 },
    /// A reply arrived for a wire id we never sent; frame dropped.
    OrphanReply { conn: ConnId, wire_id: u64 },
}

/// What one [`NodeDriver::step`] did — the hot/idle pacing signal.
#[derive(Debug)]
pub struct StepReport {
    /// Transport events handled (frames + connection lifecycle).
    pub received: usize,
    /// Engine events executed in this step's slice.
    pub processed: u64,
    /// Frames flushed from the outbox.
    pub flushed: usize,
    /// Observations for the caller (see [`Notable`]).
    pub notables: Vec<Notable>,
}

impl StepReport {
    /// Nothing moved: no inbound, no engine work, nothing to flush.
    pub fn is_idle(&self) -> bool {
        self.received == 0 && self.processed == 0 && self.flushed == 0
    }
}

/// One real-plane node: engine + transport + link bookkeeping.
pub struct NodeDriver<T: Transport> {
    pub engine: Engine<Msg>,
    transport: T,
    outbox: Outbox,
    /// Local broker that serves requests from accepted connections
    /// (`None` on nodes that only originate requests).
    broker: Option<ActorId>,
    cookie: u64,
    /// Whether a matching cookie lets a peer's spec actor ids through
    /// un-rewritten (same-cluster nodes only).
    trust_cookie: bool,
    /// Outbound connections: conn -> the [`ClientLink`] proxying it.
    clients: HashMap<ConnId, ActorId>,
    /// Accepted connections: conn -> ([`ServerLink`], peer trusted?).
    servers: HashMap<ConnId, (ActorId, bool)>,
}

impl<T: Transport> NodeDriver<T> {
    /// `trust_cookie = true` is for nodes of one [`crate::real::run_cluster`]
    /// sharing a per-run secret; standalone servers pass `false` and treat
    /// every peer's actor ids as foreign.
    pub fn new(engine: Engine<Msg>, transport: T, cookie: u64, trust_cookie: bool) -> Self {
        Self {
            engine,
            transport,
            outbox: Outbox::default(),
            broker: None,
            cookie,
            trust_cookie,
            clients: HashMap::new(),
            servers: HashMap::new(),
        }
    }

    /// Serve inbound requests with `broker` (built into this engine).
    pub fn serve(&mut self, broker: ActorId) {
        self.broker = Some(broker);
    }

    /// The outbox link actors stage frames on.
    pub fn outbox(&self) -> Outbox {
        self.outbox.clone()
    }

    /// Dial `addr`, introduce ourselves, and return the connection plus
    /// the [`ClientLink`] actor standing in for the remote broker.
    pub fn connect(&mut self, addr: &str, node: u32) -> Result<(ConnId, ActorId), FrameError> {
        let conn = self.transport.connect(addr)?;
        self.transport.send(
            conn,
            &WireMsg::Hello { version: WIRE_VERSION, node, cookie: self.cookie },
        )?;
        let link = self.engine.add_actor(Box::new(ClientLink::new(conn, self.outbox.clone())));
        self.clients.insert(conn, link);
        Ok((conn, link))
    }

    /// Accepted connections and their [`ServerLink`] actors.
    pub fn server_links(&self) -> Vec<(ConnId, ActorId)> {
        let mut v: Vec<_> = self.servers.iter().map(|(&c, &(l, _))| (c, l)).collect();
        v.sort_unstable();
        v
    }

    /// Unanswered requests across every outbound connection.
    pub fn pending_replies(&mut self) -> usize {
        let links: Vec<ActorId> = self.clients.values().copied().collect();
        links
            .into_iter()
            .filter_map(|l| self.engine.actor_as::<ClientLink>(l).map(|c| c.pending_len()))
            .sum()
    }

    /// Stage one frame directly (driver-originated traffic: `Shutdown`,
    /// `Bye`); it goes out with the next flush.
    pub fn stage(&mut self, conn: ConnId, msg: WireMsg) {
        self.outbox.borrow_mut().push((conn, msg));
    }

    /// One pump step: poll (waiting up to `wait_ms` for the first event),
    /// advance the engine by [`PUMP_SLICE`], flush the outbox.
    pub fn step(&mut self, wait_ms: u64) -> StepReport {
        let mut notables = Vec::new();
        let events = self.transport.poll(wait_ms);
        let received = events.len();
        for ev in events {
            self.handle(ev, &mut notables);
        }
        let horizon = self.engine.now() + PUMP_SLICE;
        let processed = self.engine.run_until(horizon);
        let flushed = self.flush(&mut notables);
        StepReport { received, processed, flushed, notables }
    }

    /// Pump until `idle_rounds` consecutive steps move nothing — the
    /// graceful drain. Only sound on nodes whose actors quiesce (the
    /// broker is purely reactive; pull sources are not). Returns the
    /// notables observed while draining.
    pub fn settle(&mut self, idle_rounds: u32, max_steps: u32) -> Vec<Notable> {
        let mut notables = Vec::new();
        let mut idle = 0;
        for _ in 0..max_steps {
            let mut r = self.step(1);
            notables.append(&mut r.notables);
            idle = if r.is_idle() { idle + 1 } else { 0 };
            if idle >= idle_rounds {
                break;
            }
        }
        notables
    }

    /// Hand back the engine and the transport (end of run: the caller
    /// reads actor stats from the engine and shuts the transport down).
    pub fn into_parts(self) -> (Engine<Msg>, T) {
        (self.engine, self.transport)
    }

    fn flush(&mut self, notables: &mut Vec<Notable>) -> usize {
        let staged: Vec<(ConnId, WireMsg)> =
            self.outbox.borrow_mut().drain(..).collect();
        let flushed = staged.len();
        for (conn, msg) in staged {
            let label = msg_label(&msg);
            match self.transport.send(conn, &msg) {
                Ok(()) => notables.push(Notable::Sent { conn, label }),
                Err(error) => notables.push(Notable::SendFailed { conn, error }),
            }
        }
        flushed
    }

    fn handle(&mut self, ev: TransportEvent, notables: &mut Vec<Notable>) {
        match ev {
            TransportEvent::Accepted { conn } => {
                let link =
                    self.engine.add_actor(Box::new(ServerLink::new(conn, self.outbox.clone())));
                self.servers.insert(conn, (link, false));
                notables.push(Notable::Accepted { conn });
            }
            TransportEvent::Frame { conn, msg } => self.on_frame(conn, msg, notables),
            TransportEvent::Closed { conn, error } => {
                self.clients.remove(&conn);
                self.servers.remove(&conn);
                notables.push(Notable::Closed { conn, error });
            }
        }
    }

    fn on_frame(&mut self, conn: ConnId, msg: WireMsg, notables: &mut Vec<Notable>) {
        match msg {
            WireMsg::Hello { version, node: _, cookie } => {
                if version != WIRE_VERSION {
                    self.transport.close_conn(conn);
                    notables.push(Notable::BadHello { conn, version });
                    return;
                }
                if let Some(entry) = self.servers.get_mut(&conn) {
                    entry.1 = self.trust_cookie && cookie == self.cookie;
                }
            }
            WireMsg::Req { wire_id, from_node, mut kind } => {
                let Some(&(link, trusted)) = self.servers.get(&conn) else {
                    return;
                };
                let Some(broker) = self.broker else {
                    return;
                };
                if !trusted {
                    rewrite_spec_actors(&mut kind, link);
                }
                let label = kind_label(&kind);
                notables.push(Notable::Req { conn, wire_id, label });
                let now = self.engine.now();
                self.engine.schedule(
                    now,
                    broker,
                    Msg::rpc(RpcRequest {
                        id: wire_id,
                        reply_to: link,
                        from_node: from_node as usize,
                        kind,
                    }),
                );
            }
            WireMsg::Rep { wire_id, reply } => {
                let Some(&link) = self.clients.get(&conn) else {
                    return;
                };
                let routed = self
                    .engine
                    .actor_as::<ClientLink>(link)
                    .and_then(|l| l.take_pending(wire_id));
                match routed {
                    Some((id, reply_to)) => {
                        let now = self.engine.now();
                        self.engine.schedule(
                            now,
                            reply_to,
                            Msg::reply(RpcEnvelope { id, reply }),
                        );
                    }
                    None => notables.push(Notable::OrphanReply { conn, wire_id }),
                }
            }
            // Push subscriptions only exist colocated (the paper's shared-
            // memory asymmetry), so cluster nodes never need an `Evt`
            // re-injected into their engine — surfacing it is enough for
            // external clients (the contract harness reads these raw).
            WireMsg::Evt { event } => notables.push(Notable::Event { conn, event }),
            WireMsg::Shutdown => notables.push(Notable::ShutdownRequested { conn }),
            WireMsg::Bye { replies_sent } => {
                notables.push(Notable::Bye { conn, replies_sent });
            }
        }
    }
}

/// Replace engine-local actor ids in subscription specs with the
/// connection's [`ServerLink`], so notifications and acks route back over
/// the wire instead of into this engine's unrelated actors.
fn rewrite_spec_actors(kind: &mut RpcKind, link: ActorId) {
    match kind {
        RpcKind::PushSubscribe { sources } => {
            for s in sources {
                s.source_actor = link;
            }
        }
        RpcKind::WriteSubscribe { producer } => producer.producer_actor = link,
        _ => {}
    }
}

fn kind_label(kind: &RpcKind) -> &'static str {
    match kind {
        RpcKind::Append { .. } => "append",
        RpcKind::Pull { .. } => "pull",
        RpcKind::PushSubscribe { .. } => "push_subscribe",
        RpcKind::PushUnsubscribe { .. } => "push_unsubscribe",
        RpcKind::WriteSubscribe { .. } => "write_subscribe",
        RpcKind::CommitCheckpoint { .. } => "commit_checkpoint",
        RpcKind::SealObject { .. } => "seal_object",
        RpcKind::Replicate { .. } => "replicate",
        RpcKind::ShardReplicate { .. } => "shard_replicate",
        RpcKind::ShardFreeze { .. } => "shard_freeze",
        RpcKind::ShardPromote { .. } => "shard_promote",
        RpcKind::ShardFailover { .. } => "shard_failover",
        RpcKind::Heartbeat => "heartbeat",
    }
}
