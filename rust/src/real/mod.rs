//! The real execution plane: the cluster on OS threads + localhost TCP.
//!
//! Same actors, same protocol, same construction paths as the sim plane —
//! [`crate::cluster::build_brokers`] and
//! [`crate::cluster::build_pipeline_tasks`] are shared verbatim — but the
//! messages that cross node boundaries travel as length-prefixed frames
//! over real sockets ([`crate::transport`]) instead of through one global
//! event queue. What stays in-process is exactly what the paper colocates:
//! the plasma store, the push notification path and the shared-memory
//! write path never touch a socket.
//!
//! # Topology
//!
//! * **Colocated node thread** (`zs-colo`): broker + operator pipeline +
//!   sources, plus the shared-memory writers when
//!   `write_mode = sharedmem` (they must live with the plasma store —
//!   that *is* the colocated premise). Owns the TCP listener.
//! * **Producer node thread** (`zs-prod`): the sync/pipelined writers,
//!   "deployed separately from the streaming architecture". Their appends
//!   are the only RPCs that cross TCP in a cluster run, matching the
//!   paper's node split (producers remote, processing colocated).
//!
//! Each node thread owns a full private engine + blackboards (metrics,
//! network model, object store); nothing engine-local is `Send`, so
//! construction happens inside the thread and only encoded frames and
//! plain counters cross.
//!
//! # Termination
//!
//! A real run has no virtual horizon: it runs a *bounded* workload
//! (`corpus_records > 0`, enforced by config validation) to quiescence.
//! The orchestrator polls per-node counters and declares the run complete
//! when every produced record was acked, consumed, and the logged-tuple
//! total has stopped moving; then it stops the nodes, drains them, and
//! joins every thread (transport reader/writer threads included — the
//! [`ThreadReport`]s in the summary prove it).

pub mod driver;
pub mod links;
pub mod server;

pub use driver::{NodeDriver, Notable, StepReport, PUMP_SLICE};
pub use links::{ClientLink, Outbox, ServerLink};
pub use server::run_broker_server;

use std::sync::mpsc::{self, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::broker::StoreRegistry;
use crate::cluster::{build_brokers, build_pipeline_tasks, NODE_COLOCATED, NODE_PRODUCERS};
use crate::config::{ExperimentConfig, WriteMode};
use crate::metrics::{Class, MetricsHub, SharedMetrics};
use crate::net::Network;
use crate::obs::LatencyReport;
use crate::ops::FilterOp;
use crate::pipeline::Pipeline;
use crate::plasma::ObjectStore;
use crate::producer::{WriteStats, WriterActor, WriterRegistry, WriterWiring};
use crate::proto::{Msg, PartitionId};
use crate::sim::{ActorId, Engine};
use crate::source::{SourceActor, SourceRegistry, SourceStats, SourceWiring, StatKey};
use crate::transport::{TcpTransport, ThreadReport, Transport};
use crate::worker::{OperatorTask, TaskRegistry};

/// Wall-clock cap on one cluster run — a stuck run returns an error with
/// the nodes stopped and joined, never a hung process.
const RUN_TIMEOUT_SECS: u64 = 180;

/// Orchestrator poll period while waiting for quiescence.
const POLL_MS: u64 = 20;

/// Consecutive stable polls of the logged-tuple total (after production
/// and consumption hit their targets) before the run is declared drained.
const STABLE_POLLS: u32 = 5;

/// End-of-run summary of a real-plane cluster run. The golden totals
/// (`records_produced`, `records_consumed`, `tuples_logged`, `planted`,
/// `matches`) are timing-independent for a bounded workload and must match
/// the sim plane byte for byte on the same config — `tests/real_plane.rs`
/// holds that line. Poll-shaped counters (`pull_rpcs`) depend on wall-clock
/// interleaving and are reported, not compared.
#[derive(Debug, Clone)]
pub struct RealRunSummary {
    pub records_produced: u64,
    pub records_consumed: u64,
    pub tuples_logged: u64,
    pub planted: u64,
    pub matches: u64,
    pub pull_rpcs: u64,
    pub objects_filled: u64,
    /// Engine events executed across every node.
    pub events_processed: u64,
    /// Wall-clock run time (spawn to last join), seconds.
    pub wall_secs: f64,
    /// Thread accounting: node threads + every transport reader/writer.
    /// `spawned == joined` or the run leaked.
    pub threads: ThreadReport,
    pub writers: WriteStats,
    pub sources: SourceStats,
    /// Per-stage latency when tracing was on (`trace_sample_permille > 0`)
    /// — wall-clock spans against a process-wide epoch, so producer-node
    /// `produced_at` stamps and colo-node stage closes are comparable.
    /// Empty when tracing was off.
    pub latency: LatencyReport,
}

/// Per-node progress counters the orchestrator polls. Plain data behind a
/// mutex — the only state shared across node threads.
#[derive(Debug, Default, Clone, Copy)]
struct NodeStatus {
    produced: u64,
    consumed: u64,
    logged: u64,
}

/// What a node thread hands back when it stops. `Send` by construction:
/// all engine-local state dies inside the thread.
struct NodeOutcome {
    writers: WriteStats,
    sources: SourceStats,
    op_matches: u64,
    pull_rpcs: u64,
    objects_filled: u64,
    tuples_logged: u64,
    events_processed: u64,
    threads: ThreadReport,
    /// The node's merged latency histograms (spans close on the colo
    /// node, so the producer node's report is empty).
    latency: LatencyReport,
}

/// Arm a node thread's tracer for the real plane: the configured sampling
/// rate with wall-clock timestamps (node-local engine clocks are not
/// comparable across threads).
fn configure_tracer(metrics: &SharedMetrics, config: &ExperimentConfig) {
    if config.trace_sample_permille > 0 {
        let mut m = metrics.borrow_mut();
        m.tracer.configure(config.trace_sample_permille, &config.trace_out);
        m.tracer.set_wall_clock();
    }
}

/// Run `config` on the real plane: spawn the node threads, wait for the
/// bounded workload to drain, stop and join everything, and summarise.
pub fn run_cluster(config: &ExperimentConfig) -> Result<RealRunSummary, String> {
    config.validate()?;
    if config.corpus_records == 0 {
        return Err("real-plane runs need corpus_records > 0".into());
    }
    let listener = TcpTransport::listen("127.0.0.1:0")
        .map_err(|e| format!("real plane: listen failed: {e}"))?;
    let addr = listener.local_addr().expect("listener has an address");
    // Per-run cluster membership secret (see the driver's trust docs).
    let cookie = config.seed ^ 0xC1u64.rotate_left(32) ^ 0x5EED;
    let remote_writers = config.write_mode != WriteMode::SharedMem;
    let target = (config.np as u64) * config.corpus_records;

    let colo_status = Arc::new(Mutex::new(NodeStatus::default()));
    let prod_status = Arc::new(Mutex::new(NodeStatus::default()));
    let (colo_stop_tx, colo_stop_rx) = mpsc::channel::<()>();
    let (prod_stop_tx, prod_stop_rx) = mpsc::channel::<()>();

    let started = Instant::now();
    let mut node_threads = 0usize;

    let colo = {
        let config = config.clone();
        let status = colo_status.clone();
        thread::Builder::new()
            .name("zs-colo".into())
            .spawn(move || colo_node_main(config, listener, cookie, status, colo_stop_rx))
            .map_err(|e| format!("spawning colo node: {e}"))?
    };
    node_threads += 1;
    let prod = if remote_writers {
        let config = config.clone();
        let status = prod_status.clone();
        let handle = thread::Builder::new()
            .name("zs-prod".into())
            .spawn(move || producer_node_main(config, addr, cookie, status, prod_stop_rx))
            .map_err(|e| format!("spawning producer node: {e}"))?;
        node_threads += 1;
        Some(handle)
    } else {
        None
    };

    // ---- wait for quiescence -------------------------------------------
    let deadline = started + Duration::from_secs(RUN_TIMEOUT_SECS);
    let mut stable = 0u32;
    let mut last_logged = u64::MAX;
    let timed_out = loop {
        thread::sleep(Duration::from_millis(POLL_MS));
        if colo.is_finished() || prod.as_ref().is_some_and(|h| h.is_finished()) {
            // A node died early (panic); stop the rest and surface it.
            break false;
        }
        let c = *colo_status.lock().unwrap();
        let produced = if remote_writers {
            prod_status.lock().unwrap().produced
        } else {
            c.produced
        };
        if produced >= target && c.consumed >= target {
            if c.logged == last_logged {
                stable += 1;
            } else {
                stable = 0;
                last_logged = c.logged;
            }
            if stable >= STABLE_POLLS {
                break false;
            }
        } else {
            stable = 0;
            last_logged = u64::MAX;
        }
        if Instant::now() > deadline {
            break true;
        }
    };

    // ---- stop, drain, join ---------------------------------------------
    // Producers first: their transport shutdown closes the append
    // connection at a frame boundary, which the colo node observes as a
    // clean close before its own stop.
    let _ = prod_stop_tx.send(());
    let prod_outcome = match prod {
        Some(h) => Some(h.join().map_err(|_| "producer node panicked".to_string())?),
        None => None,
    };
    let _ = colo_stop_tx.send(());
    let colo_outcome = colo.join().map_err(|_| "colo node panicked".to_string())?;
    let wall_secs = started.elapsed().as_secs_f64();

    if timed_out {
        return Err(format!(
            "real-plane run timed out after {RUN_TIMEOUT_SECS}s \
             (produced target {target}, see node counters)"
        ));
    }

    // ---- merge ----------------------------------------------------------
    let mut writers = colo_outcome.writers.clone();
    let mut sources = colo_outcome.sources.clone();
    let mut threads = ThreadReport {
        spawned: colo_outcome.threads.spawned + node_threads,
        joined: colo_outcome.threads.joined + node_threads,
    };
    let mut events_processed = colo_outcome.events_processed;
    let mut pull_rpcs = colo_outcome.pull_rpcs;
    let mut objects_filled = colo_outcome.objects_filled;
    if let Some(p) = prod_outcome {
        writers.merge(&p.writers);
        sources.merge(&p.sources);
        threads.spawned += p.threads.spawned;
        threads.joined += p.threads.joined;
        events_processed += p.events_processed;
        pull_rpcs += p.pull_rpcs;
        objects_filled += p.objects_filled;
    }
    Ok(RealRunSummary {
        records_produced: writers.records_sent,
        records_consumed: sources.records_consumed,
        tuples_logged: colo_outcome.tuples_logged,
        planted: writers.planted,
        matches: sources.extra(StatKey::Matches) + colo_outcome.op_matches,
        pull_rpcs,
        objects_filled,
        events_processed,
        wall_secs,
        threads,
        writers,
        sources,
        latency: colo_outcome.latency,
    })
}

/// The colocated node: broker + pipeline + sources (+ sharedmem writers),
/// serving the TCP listener.
fn colo_node_main(
    config: ExperimentConfig,
    listener: TcpTransport,
    cookie: u64,
    status: Arc<Mutex<NodeStatus>>,
    stop: mpsc::Receiver<()>,
) -> NodeOutcome {
    let source_registry = SourceRegistry::builtin();
    let writer_registry = WriterRegistry::builtin();
    let factory = source_registry.expect(config.mode);
    let mut engine = Engine::new(config.seed);
    let metrics = MetricsHub::shared();
    configure_tracer(&metrics, &config);
    let net = Network::shared(config.cost.network, config.cost.loopback);
    let store = ObjectStore::shared();
    let registry = TaskRegistry::shared();
    let partitions: Vec<PartitionId> = (0..config.ns).map(PartitionId).collect();

    let (broker, _backup) = build_brokers(
        &mut engine,
        &config,
        &StoreRegistry::builtin(),
        factory.broker_push_threads(),
        &partitions,
        &net,
        &store,
        &metrics,
    );
    // Shared-memory writers are colocated by definition (they fill plasma
    // objects in-process); every other write mode runs on the producer
    // node thread instead.
    let producers = if config.write_mode == WriteMode::SharedMem {
        writer_registry.expect(config.write_mode).build(
            &WriterWiring {
                config: &config,
                producer_node: NODE_PRODUCERS,
                broker,
                broker_node: NODE_COLOCATED,
                partitions: partitions.clone(),
                metrics: metrics.clone(),
                net: net.clone(),
                store: store.clone(),
                shard: None,
            },
            &mut engine,
        )
    } else {
        Vec::new()
    };
    let pipeline = factory
        .uses_pipeline()
        .then(|| Pipeline::for_workload(config.workload, config.nc, config.nmap));
    let (tasks, stage0) =
        build_pipeline_tasks(&mut engine, &config, &pipeline, &registry, &metrics, &None, &None);
    let wiring = SourceWiring {
        config: &config,
        node: NODE_COLOCATED,
        broker,
        broker_node: NODE_COLOCATED,
        downstream: stage0,
        metrics: metrics.clone(),
        net: net.clone(),
        store: store.clone(),
        registry: registry.clone(),
        compute: None,
        checkpoint: None,
        shard: None,
    };
    let sources = factory.build(&wiring, &mut engine);

    let mut driver = NodeDriver::new(engine, listener, cookie, true);
    driver.serve(broker);

    let mut wait = 0u64;
    let mut tick = 0u32;
    loop {
        match stop.try_recv() {
            Err(TryRecvError::Empty) => {}
            Ok(()) | Err(TryRecvError::Disconnected) => break,
        }
        let r = driver.step(wait);
        wait = if r.is_idle() { 2 } else { 0 };
        tick = tick.wrapping_add(1);
        if r.is_idle() || tick % 8 == 0 {
            publish(&status, &mut driver.engine, &producers, &sources, &metrics);
        }
    }
    // Final flush: push out any staged acks so a stopping peer never loses
    // one. Bounded by max_steps, not idleness — pull sources re-arm their
    // poll timers forever, so this node never reads as idle.
    driver.settle(3, 50);
    publish(&status, &mut driver.engine, &producers, &sources, &metrics);

    let (mut engine, transport) = driver.into_parts();
    let writers = collect_writer_stats(&mut engine, &producers);
    let source_stats = collect_source_stats(&mut engine, &sources);
    let mut op_matches = 0;
    for &tid in &tasks {
        if let Some(t) = engine.actor_as::<OperatorTask>(tid) {
            if let Some(f) = t.op_as::<FilterOp>(0) {
                op_matches += f.matches;
            }
        }
    }
    let m = metrics.borrow();
    NodeOutcome {
        writers,
        sources: source_stats,
        op_matches,
        pull_rpcs: m.total(Class::PullRpcs),
        objects_filled: m.total(Class::ObjectsFilled),
        tuples_logged: m.total(Class::ConsumerTuples),
        events_processed: engine.events_processed(),
        threads: transport.shutdown(),
        latency: m.tracer.report(),
    }
}

/// The producer node: sync/pipelined writers appending to the colo node's
/// broker through a [`ClientLink`] over TCP.
fn producer_node_main(
    config: ExperimentConfig,
    addr: String,
    cookie: u64,
    status: Arc<Mutex<NodeStatus>>,
    stop: mpsc::Receiver<()>,
) -> NodeOutcome {
    let writer_registry = WriterRegistry::builtin();
    let engine = Engine::new(config.seed);
    let metrics = MetricsHub::shared();
    configure_tracer(&metrics, &config);
    let net = Network::shared(config.cost.network, config.cost.loopback);
    let store = ObjectStore::shared();
    let partitions: Vec<PartitionId> = (0..config.ns).map(PartitionId).collect();

    let mut driver = NodeDriver::new(engine, TcpTransport::client(), cookie, true);
    let (_conn, link) = driver
        .connect(&addr, NODE_PRODUCERS as u32)
        .unwrap_or_else(|e| panic!("producer node: connecting to {addr}: {e}"));
    // Same factory, same wiring shape as the sim plane — the broker is
    // simply the link actor, so every append the writer issues becomes a
    // `Req` frame instead of a local engine message. The writer code
    // cannot tell the difference.
    let producers = writer_registry.expect(config.write_mode).build(
        &WriterWiring {
            config: &config,
            producer_node: NODE_PRODUCERS,
            broker: link,
            broker_node: NODE_COLOCATED,
            partitions,
            metrics: metrics.clone(),
            net: net.clone(),
            store: store.clone(),
            shard: None,
        },
        &mut driver.engine,
    );

    let mut wait = 0u64;
    let mut tick = 0u32;
    loop {
        match stop.try_recv() {
            Err(TryRecvError::Empty) => {}
            Ok(()) | Err(TryRecvError::Disconnected) => break,
        }
        let r = driver.step(wait);
        wait = if r.is_idle() { 2 } else { 0 };
        tick = tick.wrapping_add(1);
        if r.is_idle() || tick % 8 == 0 {
            publish(&status, &mut driver.engine, &producers, &[], &metrics);
        }
    }
    // Drain: no new requests originate after generation finished, so a few
    // idle rounds mean every in-flight ack has landed.
    driver.settle(3, 500);
    publish(&status, &mut driver.engine, &producers, &[], &metrics);

    let (mut engine, transport) = driver.into_parts();
    let writers = collect_writer_stats(&mut engine, &producers);
    let m = metrics.borrow();
    NodeOutcome {
        writers,
        sources: SourceStats::default(),
        op_matches: 0,
        pull_rpcs: m.total(Class::PullRpcs),
        objects_filled: m.total(Class::ObjectsFilled),
        tuples_logged: 0,
        events_processed: engine.events_processed(),
        threads: transport.shutdown(),
        latency: LatencyReport::default(),
    }
}

fn publish(
    status: &Arc<Mutex<NodeStatus>>,
    engine: &mut Engine<Msg>,
    producers: &[ActorId],
    sources: &[ActorId],
    metrics: &SharedMetrics,
) {
    let produced = collect_writer_stats(engine, producers).records_sent;
    let consumed = collect_source_stats(engine, sources).records_consumed;
    let logged = metrics.borrow().total(Class::ConsumerTuples);
    if let Ok(mut s) = status.lock() {
        *s = NodeStatus { produced, consumed, logged };
    }
}

/// Same extraction contract as `Cluster::finish`: a producer that is not a
/// registry-built [`WriterActor`] is a hard error, not dropped totals.
fn collect_writer_stats(engine: &mut Engine<Msg>, producers: &[ActorId]) -> WriteStats {
    let mut stats = WriteStats::default();
    for &pid in producers {
        let actor = engine.actor_as::<WriterActor>(pid).unwrap_or_else(|| {
            panic!("producer {pid} was not built through the WriterFactory registry")
        });
        stats.merge(&actor.stats());
    }
    stats
}

/// Same extraction contract as `Cluster::finish` for sources.
fn collect_source_stats(engine: &mut Engine<Msg>, sources: &[ActorId]) -> SourceStats {
    let mut stats = SourceStats::default();
    for &sid in sources {
        let actor = engine.actor_as::<SourceActor>(sid).unwrap_or_else(|| {
            panic!("source {sid} was not built through the SourceFactory registry")
        });
        stats.merge(&actor.stats());
    }
    stats
}
