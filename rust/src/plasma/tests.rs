//! Unit tests: object lifecycle, backpressure, reuse.

use std::rc::Rc;

use super::*;
use crate::proto::{Chunk, PartitionId, StampedChunk};
use crate::sim::ActorId;

fn stamped(partition: usize, offset: u64, records: u32, rec_size: u32) -> StampedChunk {
    StampedChunk {
        partition: PartitionId(partition),
        offset,
        chunk: Chunk::sim(records, rec_size),
    }
}

fn store_with_sub(objects: usize, cap: u64) -> (ObjectStore, SubId) {
    let mut store = ObjectStore::new();
    let sub = store.create_subscription(
        ActorId(7),
        vec![(PartitionId(0), 0), (PartitionId(1), 0)],
        objects,
        cap,
    );
    (store, sub)
}

#[test]
fn acquire_fill_read_release_cycle() {
    let (mut store, sub) = store_with_sub(2, 4096);
    let id = store.acquire(sub).expect("free object");
    store.seal(id, vec![stamped(0, 0, 10, 100)]);
    assert_eq!(store.sealed_counts(id), (10, 1000));
    assert_eq!(store.read(id).len(), 1);
    store.release(id);
    assert!(store.has_free(sub));
    assert_eq!(store.objects_filled(), 1);
    assert_eq!(store.bytes_filled(), 1000);
}

#[test]
fn pool_exhaustion_is_backpressure() {
    let (mut store, sub) = store_with_sub(2, 4096);
    let a = store.acquire(sub).unwrap();
    let _b = store.acquire(sub).unwrap();
    assert!(store.acquire(sub).is_none(), "pool of 2 exhausted");
    assert!(!store.has_free(sub));
    store.seal(a, vec![stamped(0, 0, 1, 100)]);
    store.release(a);
    assert!(store.acquire(sub).is_some(), "released buffer is reusable");
}

#[test]
fn buffers_are_reused_in_fifo_order() {
    let (mut store, sub) = store_with_sub(3, 4096);
    let ids: Vec<_> = (0..3).map(|_| store.acquire(sub).unwrap()).collect();
    for &id in &ids {
        store.seal(id, vec![stamped(0, 0, 1, 10)]);
    }
    store.release(ids[1]);
    store.release(ids[0]);
    assert_eq!(store.acquire(sub).unwrap().slot, ids[1].slot);
    assert_eq!(store.acquire(sub).unwrap().slot, ids[0].slot);
    assert_eq!(store.reuses(sub), 0, "second fill not yet done");
}

#[test]
fn reuse_counting() {
    let (mut store, sub) = store_with_sub(1, 4096);
    for round in 0..5 {
        let id = store.acquire(sub).unwrap();
        store.seal(id, vec![stamped(0, round, 2, 50)]);
        store.release(id);
    }
    assert_eq!(store.reuses(sub), 4);
    assert_eq!(store.objects_filled(), 5);
}

#[test]
#[should_panic(expected = "overfilled")]
fn seal_rejects_overflow() {
    let (mut store, sub) = store_with_sub(1, 500);
    let id = store.acquire(sub).unwrap();
    store.seal(id, vec![stamped(0, 0, 10, 100)]); // 1000 > 500
}

#[test]
#[should_panic(expected = "unacquired")]
fn seal_requires_acquire() {
    let (mut store, sub) = store_with_sub(1, 500);
    store.seal(ObjectId { sub, slot: 0 }, vec![stamped(0, 0, 1, 10)]);
}

#[test]
#[should_panic(expected = "unsealed")]
fn read_requires_seal() {
    let (mut store, sub) = store_with_sub(1, 500);
    let id = store.acquire(sub).unwrap();
    store.read(id);
}

#[test]
#[should_panic(expected = "unsealed")]
fn double_release_panics() {
    let (mut store, sub) = store_with_sub(1, 4096);
    let id = store.acquire(sub).unwrap();
    store.seal(id, vec![stamped(0, 0, 1, 10)]);
    store.release(id);
    store.release(id);
}

#[test]
fn real_payload_is_shared_not_copied() {
    let (mut store, sub) = store_with_sub(1, 4096);
    let data = Rc::new(vec![7u8; 300]);
    let chunk = Chunk::real(3, 100, data.clone());
    let id = store.acquire(sub).unwrap();
    store.seal(
        id,
        vec![StampedChunk { partition: PartitionId(0), offset: 0, chunk }],
    );
    // 1 here + 1 in the store: pointer hand-off, no copy
    assert_eq!(Rc::strong_count(&data), 2);
}

#[test]
fn multiple_subscriptions_are_isolated() {
    let mut store = ObjectStore::new();
    let s1 = store.create_subscription(ActorId(1), vec![(PartitionId(0), 0)], 1, 1024);
    let s2 = store.create_subscription(ActorId(2), vec![(PartitionId(1), 0)], 2, 2048);
    assert_ne!(s1, s2);
    let _ = store.acquire(s1).unwrap();
    assert!(store.acquire(s1).is_none());
    assert!(store.acquire(s2).is_some(), "s2 unaffected by s1 exhaustion");
    assert_eq!(store.reserved_bytes(), 1024 + 2 * 2048);
    assert_eq!(store.subscription(s2).source_actor, ActorId(2));
}

#[test]
fn cursors_are_broker_managed_state() {
    let (mut store, sub) = store_with_sub(1, 4096);
    let s = store.subscription_mut(sub);
    s.cursors[0].1 = 42;
    assert_eq!(store.subscription(sub).cursors[0], (PartitionId(0), 42));
}

#[test]
fn deactivate_returns_cursors_and_drains_sealed_objects() {
    let (mut store, sub) = store_with_sub(2, 4096);
    let id = store.acquire(sub).unwrap();
    store.seal(id, vec![stamped(0, 0, 10, 100)]);
    store.subscription_mut(sub).cursors[0].1 = 1;
    let cursors = store.deactivate(sub);
    assert_eq!(cursors, vec![(PartitionId(0), 1), (PartitionId(1), 0)]);
    assert!(!store.subscription(sub).active);
    // The already-sealed object still drains through the normal lifecycle;
    // its capacity stays reserved until it does.
    assert_eq!(store.sealed_counts(id), (10, 1000));
    assert_eq!(store.reserved_bytes(), 2 * 4096);
    // Once the last object drains, the dead pool is reclaimed — a flapping
    // hybrid source must not leak one pool per switch.
    store.release(id);
    assert!(!store.has_free(sub), "reclaimed pool holds no buffers");
    assert_eq!(store.reserved_bytes(), 0);
}

#[test]
fn recovery_sweep_releases_lost_sealed_objects() {
    // A crashed source loses its ObjectReady notifications: after the
    // recovery unsubscribes, release_sealed returns the orphaned sealed
    // slots to the pool so the deactivated pool can be reclaimed.
    let (mut store, sub) = store_with_sub(3, 4096);
    let a = store.acquire(sub).unwrap();
    let b = store.acquire(sub).unwrap();
    store.seal(a, vec![stamped(0, 0, 5, 100)]);
    store.seal(b, vec![stamped(1, 0, 5, 100)]);
    store.deactivate(sub);
    assert_eq!(store.reserved_bytes(), 3 * 4096, "sealed slots block reclamation");
    assert_eq!(store.release_sealed(sub), 2);
    assert_eq!(store.reserved_bytes(), 0, "swept pool is reclaimed");
    // A stale ObjectFreed racing the sweep is a no-op on the dead pool.
    store.release(a);
    store.release(b);
    assert_eq!(store.next_sub_id(), 1);
}

#[test]
fn stale_release_on_inactive_sub_is_a_noop() {
    let (mut store, sub) = store_with_sub(1, 4096);
    let id = store.acquire(sub).unwrap();
    store.seal(id, vec![stamped(0, 0, 1, 10)]);
    store.release(id);
    store.deactivate(sub);
    // Double release would panic on an active sub (see
    // double_release_panics); on a deactivated one it is a no-op.
    store.release(id);
}

#[test]
fn deactivate_with_all_objects_free_reclaims_immediately() {
    let (mut store, sub) = store_with_sub(4, 1024);
    assert_eq!(store.reserved_bytes(), 4 * 1024);
    store.deactivate(sub);
    assert_eq!(store.reserved_bytes(), 0, "idle pool reclaimed at unsubscribe");
    assert!(!store.has_free(sub));
}
