//! Shared-memory object store between broker and processing worker.
//!
//! Models the paper's Arrow-Plasma-based store (§IV-B): a pool of
//! fixed-capacity in-memory *objects* per push subscription. The broker's
//! dedicated push thread fills a free object with the next chunks of a
//! source's partitions (Step 2), seals it and notifies the source (Step 3);
//! the source processes it through a pointer — never a copy — and notifies
//! back (Step 4) so the buffer is *reused*. Backpressure is the finite pool:
//! a slow source stops freeing objects, which stalls the push thread for
//! that source, which leaves partition data parked in the broker log.
//!
//! The paper runs Plasma as a third process with shared pointers; here the
//! store is an in-process blackboard (`Rc<RefCell>`) with the same object
//! lifecycle — substitution 2 in DESIGN.md §2. Chunk payloads are `Rc`ed
//! buffers, so "filling" an object shares pointers exactly like Plasma.
//!
//! The shared-memory **write path** (`WriteMode::SharedMem`) reuses the
//! identical lifecycle with the roles swapped: the colocated *producer*
//! acquires/fills/seals objects and the *broker* reads, appends and
//! releases them (write subscriptions carry no read cursors, so they never
//! pin retention or enter the push rotation).

#[cfg(test)]
mod tests;

use std::cell::RefCell;
use std::collections::VecDeque;
use std::rc::Rc;

use crate::proto::{ChunkOffset, ObjectId, PartitionId, StampedChunk, SubId};
use crate::sim::ActorId;

/// Object lifecycle. Free → Filling → Sealed → Free (reuse).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ObjectState {
    /// Available to the push thread.
    Free,
    /// The push thread is copying chunks in (holds the slot).
    Filling,
    /// Content visible to the source; awaiting release.
    Sealed,
}

#[derive(Debug)]
struct ObjectSlot {
    state: ObjectState,
    capacity: u64,
    content: Vec<StampedChunk>,
    bytes: u64,
    records: u64,
    fills: u64,
}

/// One worker-local push source group member's registration state.
#[derive(Debug)]
pub struct Subscription {
    pub id: SubId,
    /// Source task actor to notify on seal.
    pub source_actor: ActorId,
    /// Broker-managed consumption cursors (paper: "the storage broker can
    /// assign local partitions and build consumer offsets").
    pub cursors: Vec<(PartitionId, ChunkOffset)>,
    slots: Vec<ObjectSlot>,
    free: VecDeque<usize>,
    /// Next partition to serve (round-robin fairness within the source).
    pub rr_next: usize,
    /// False once unsubscribed: the push thread must not fill for it and
    /// its cursors no longer hold back retention. Sealed objects still
    /// drain through the normal read/release lifecycle.
    pub active: bool,
}

/// The store: all subscriptions of one colocated node.
#[derive(Debug, Default)]
pub struct ObjectStore {
    subs: Vec<Subscription>,
    objects_filled: u64,
    bytes_filled: u64,
}

/// Shared handle.
pub type SharedStore = Rc<RefCell<ObjectStore>>;

impl ObjectStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn shared() -> SharedStore {
        Rc::new(RefCell::new(Self::new()))
    }

    /// Register a push source: `objects` slots of `object_bytes` each.
    pub fn create_subscription(
        &mut self,
        source_actor: ActorId,
        cursors: Vec<(PartitionId, ChunkOffset)>,
        objects: usize,
        object_bytes: u64,
    ) -> SubId {
        assert!(objects > 0, "a subscription needs at least one object");
        assert!(object_bytes > 0, "objects need non-zero capacity");
        let id = SubId(self.subs.len());
        let slots = (0..objects)
            .map(|_| ObjectSlot {
                state: ObjectState::Free,
                capacity: object_bytes,
                content: Vec::new(),
                bytes: 0,
                records: 0,
                fills: 0,
            })
            .collect();
        self.subs.push(Subscription {
            id,
            source_actor,
            cursors,
            slots,
            free: (0..objects).collect(),
            rr_next: 0,
            active: true,
        });
        id
    }

    /// The id the next `create_subscription` will assign. Recovery uses it
    /// as a staleness floor: any subscription created before a rollback is
    /// dead to the restored source, and its object notifications must be
    /// freed rather than consumed.
    pub fn next_sub_id(&self) -> usize {
        self.subs.len()
    }

    /// Unsubscribe: stop filling for `sub` and return its resume cursors.
    /// Slots stay allocated only until in-flight fills and already-sealed
    /// objects drain; then the pool is reclaimed (a flapping hybrid source
    /// subscribes afresh on every switch, so dead pools must not pile up).
    pub fn deactivate(&mut self, sub: SubId) -> Vec<(PartitionId, ChunkOffset)> {
        let s = &mut self.subs[sub.0];
        s.active = false;
        let cursors = s.cursors.clone();
        self.try_reclaim(sub);
        cursors
    }

    /// Drop a deactivated subscription's object pool once every slot is
    /// back to `Free` (nothing filling, nothing sealed).
    fn try_reclaim(&mut self, sub: SubId) {
        let s = &mut self.subs[sub.0];
        if !s.active && s.slots.iter().all(|slot| slot.state == ObjectState::Free) {
            s.slots.clear();
            s.free.clear();
        }
    }

    pub fn subscription(&self, sub: SubId) -> &Subscription {
        &self.subs[sub.0]
    }

    pub fn subscription_mut(&mut self, sub: SubId) -> &mut Subscription {
        &mut self.subs[sub.0]
    }

    pub fn subscriptions(&self) -> impl Iterator<Item = &Subscription> {
        self.subs.iter()
    }

    /// Take a free object for filling. `None` == backpressure.
    pub fn acquire(&mut self, sub: SubId) -> Option<ObjectId> {
        let s = &mut self.subs[sub.0];
        let slot = s.free.pop_front()?;
        debug_assert_eq!(s.slots[slot].state, ObjectState::Free);
        s.slots[slot].state = ObjectState::Filling;
        Some(ObjectId { sub, slot })
    }

    /// Whether the subscription has a free object (peek, for scheduling).
    pub fn has_free(&self, sub: SubId) -> bool {
        !self.subs[sub.0].free.is_empty()
    }

    /// Capacity of an object in bytes.
    pub fn capacity(&self, id: ObjectId) -> u64 {
        self.subs[id.sub.0].slots[id.slot].capacity
    }

    /// Fill + seal an acquired object. Content must respect capacity.
    pub fn seal(&mut self, id: ObjectId, content: Vec<StampedChunk>) {
        let slot = &mut self.subs[id.sub.0].slots[id.slot];
        assert_eq!(slot.state, ObjectState::Filling, "seal of unacquired object");
        let bytes: u64 = content.iter().map(|c| c.chunk.bytes()).sum();
        let records: u64 = content.iter().map(|c| c.chunk.records as u64).sum();
        assert!(bytes <= slot.capacity, "object overfilled: {bytes} > {}", slot.capacity);
        assert!(!content.is_empty(), "sealing an empty object");
        slot.content = content;
        slot.bytes = bytes;
        slot.records = records;
        slot.fills += 1;
        slot.state = ObjectState::Sealed;
        self.objects_filled += 1;
        self.bytes_filled += bytes;
    }

    /// Source-side read: the sealed content, by shared pointer.
    pub fn read(&self, id: ObjectId) -> &[StampedChunk] {
        let slot = &self.subs[id.sub.0].slots[id.slot];
        assert_eq!(slot.state, ObjectState::Sealed, "read of unsealed object");
        &slot.content
    }

    /// Records/bytes of a sealed object (cost accounting without borrowing
    /// the content).
    pub fn sealed_counts(&self, id: ObjectId) -> (u64, u64) {
        let slot = &self.subs[id.sub.0].slots[id.slot];
        assert_eq!(slot.state, ObjectState::Sealed);
        (slot.records, slot.bytes)
    }

    /// Chunks inside a sealed object (the broker's per-chunk append
    /// bookkeeping on the shared-memory write path is charged per chunk).
    pub fn sealed_chunks(&self, id: ObjectId) -> u64 {
        let slot = &self.subs[id.sub.0].slots[id.slot];
        assert_eq!(slot.state, ObjectState::Sealed);
        slot.content.len() as u64
    }

    /// `(records, bytes, chunks)` of a sealed object, or `None` when the
    /// id is unknown or the object is not currently sealed. The broker's
    /// `SealObject` validation peeks through this — a duplicate or stale
    /// notification from a (possibly out-of-tree) writer must become an
    /// `Error` reply, never a store panic.
    pub fn sealed_info(&self, id: ObjectId) -> Option<(u64, u64, u64)> {
        let slot = self.subs.get(id.sub.0)?.slots.get(id.slot)?;
        if slot.state != ObjectState::Sealed {
            return None;
        }
        Some((slot.records, slot.bytes, slot.content.len() as u64))
    }

    /// Source is done: buffer returns to the free pool (paper Step 4) —
    /// or, for a deactivated subscription, towards reclamation.
    ///
    /// For an *inactive* subscription a release of an already-free (or
    /// reclaimed) slot is a no-op, not a bug: a recovery sweep
    /// ([`ObjectStore::release_sealed`]) can race the stale `ObjectFreed`
    /// notifications of the source it replaced. Double-release of an
    /// active subscription's slot stays a hard error.
    pub fn release(&mut self, id: ObjectId) {
        let s = &mut self.subs[id.sub.0];
        if !s.active
            && s.slots.get(id.slot).map_or(true, |slot| slot.state != ObjectState::Sealed)
        {
            return;
        }
        let slot = &mut s.slots[id.slot];
        assert_eq!(slot.state, ObjectState::Sealed, "release of unsealed object");
        slot.content.clear();
        slot.bytes = 0;
        slot.records = 0;
        slot.state = ObjectState::Free;
        s.free.push_back(id.slot);
        self.try_reclaim(id.sub);
    }

    /// Recovery sweep: release every still-sealed slot of a *deactivated*
    /// subscription — a crashed source lost its `ObjectReady`
    /// notifications, so nothing else will ever free them (the broker-side
    /// lease GC of a real deployment, modelled instantly). Returns the
    /// number of slots released.
    pub fn release_sealed(&mut self, sub: SubId) -> usize {
        assert!(!self.subs[sub.0].active, "sweeping an active subscription");
        let slots = self.subs[sub.0].slots.len();
        let mut released = 0;
        for slot in 0..slots {
            let sealed = self.subs[sub.0]
                .slots
                .get(slot)
                .map_or(false, |s| s.state == ObjectState::Sealed);
            if sealed {
                self.release(ObjectId { sub, slot });
                released += 1;
            }
        }
        released
    }

    /// Lifetime fill count (== notifications sent to sources).
    pub fn objects_filled(&self) -> u64 {
        self.objects_filled
    }

    pub fn bytes_filled(&self) -> u64 {
        self.bytes_filled
    }

    /// Total reuse across slots of a subscription: fills beyond first use.
    pub fn reuses(&self, sub: SubId) -> u64 {
        self.subs[sub.0]
            .slots
            .iter()
            .map(|s| s.fills.saturating_sub(1))
            .sum()
    }

    /// Memory footprint the store reserves (sum of slot capacities).
    pub fn reserved_bytes(&self) -> u64 {
        self.subs
            .iter()
            .flat_map(|s| s.slots.iter())
            .map(|s| s.capacity)
            .sum()
    }
}
