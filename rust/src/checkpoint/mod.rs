//! Checkpoint & recovery: aligned barriers, state snapshots, exactly-once.
//!
//! The defining evolution of stream processing engines (Fragkoulis et al.,
//! "A Survey on the Evolution of Stream Processing Systems") and a hard
//! production requirement at scale (Uber, 2104.00087) is checkpoint-based
//! fault tolerance — and it is also where the paper's pull/push designs
//! differ most: a pull source resumes from cursors trivially, while a
//! push/shared-memory source must tear down its subscription, resubscribe
//! at the restored cursors and replay. This module makes that measurable:
//!
//! * [`CheckpointCoordinator`] — an actor that periodically
//!   (`checkpoint_interval_ms`) starts an epoch by asking every source to
//!   inject an aligned barrier ([`crate::proto::Msg::BarrierInject`]). The
//!   barrier flows in-band through the operator exchange channels;
//!   multi-input tasks align (buffer post-barrier input per channel until
//!   every upstream's barrier arrived), snapshot their operator state and
//!   forward the barrier — the classic Chandy-Lamport/Flink protocol.
//! * [`CheckpointControl`] — the shared blackboard (`Rc<RefCell>`, like
//!   the plasma store) where participants write their epoch snapshots:
//!   per-partition source cursors ([`SourceSnapshot`], captured uniformly
//!   through the [`crate::source::StreamSource::checkpoint`] trait
//!   extension, so all four source modes checkpoint identically) and
//!   operator state ([`TaskSnapshot`] of [`crate::ops::OpState`]).
//! * **Commit** — a completed epoch is committed to the broker via the
//!   `CommitCheckpoint` RPC; the committed cursors become the floor for
//!   watermark log trimming, so retention can never pass the last
//!   restorable point.
//! * **Recovery** — an injected fault (`fault_at_secs`/`fault_kind`) makes
//!   the victim wipe its volatile state and report
//!   [`crate::proto::Msg::FailureDetected`]; the coordinator then rolls
//!   the *whole* dataflow back (the Flink global-restart model): every
//!   source and task receives [`crate::proto::Msg::Restore`], resets to
//!   the latest completed snapshot under a new incarnation number, and
//!   resumes. Messages stamped with an older incarnation (in-flight
//!   batches, credits, timers, RPC replies) are dropped on receipt; the
//!   records between the checkpoint and the fault are replayed from the
//!   restored cursors and counted exactly once, because every counter they
//!   touch was rolled back with them.
//!
//! The invariant the whole design serves: **a faulted run produces
//! identical record/window totals to the fault-free run on the same
//! seed** — see `cluster::tests::exactly_once_*`.

#[cfg(test)]
mod tests;

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::config::CostModel;
use crate::metrics::SharedMetrics;
use crate::net::{NodeId, SharedNetwork};
use crate::ops::OpState;
use crate::proto::{ChunkOffset, Msg, PartitionId, RpcKind, RpcReply, RpcRequest};
use crate::sim::{Actor, ActorId, Ctx, Time};

/// A source's restart position: exclusive per-partition cursors covering
/// exactly the records already handed downstream before the barrier, plus
/// the exactly-once counters that roll back with them.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SourceSnapshot {
    /// Resume cursors, one per owned partition.
    pub cursors: Vec<(PartitionId, ChunkOffset)>,
    /// Records handed downstream (or counted in place) so far.
    pub records_consumed: u64,
    /// In-place grep matches (native consumers; 0 elsewhere).
    pub matches: u64,
    /// Per-member record counts for grouped sources (the push group); empty
    /// for single-task sources.
    pub member_records: Vec<u64>,
}

/// One operator task's snapshot: the state of its operator chain, in chain
/// order.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskSnapshot {
    pub ops: Vec<OpState>,
}

/// One epoch's gathered snapshots. (Timing lives with the coordinator,
/// which measures trigger→commit spans itself.)
#[derive(Debug, Clone, Default)]
pub struct EpochRecord {
    pub epoch: u64,
    pub sources: HashMap<ActorId, SourceSnapshot>,
    pub tasks: HashMap<ActorId, TaskSnapshot>,
}

impl EpochRecord {
    /// The epoch's committed cursors: the union of every source's restart
    /// positions, taking the minimum where a partition appears twice (the
    /// restorable floor must cover the lowest restart point).
    pub fn committed_cursors(&self) -> Vec<(PartitionId, ChunkOffset)> {
        let mut floor: HashMap<PartitionId, ChunkOffset> = HashMap::new();
        for snap in self.sources.values() {
            for &(p, off) in &snap.cursors {
                let e = floor.entry(p).or_insert(off);
                *e = (*e).min(off);
            }
        }
        let mut out: Vec<_> = floor.into_iter().collect();
        out.sort_unstable();
        out
    }
}

/// The shared checkpoint blackboard: participants write snapshots here and
/// read them back on restore; the coordinator drives the epoch lifecycle.
#[derive(Debug, Default)]
pub struct CheckpointControl {
    /// The coordinator actor — set by the launcher after it is built, so
    /// sources and tasks (built first) can address their acks.
    pub coordinator: Option<ActorId>,
    /// The epoch currently gathering snapshots.
    pending: Option<EpochRecord>,
    /// The latest *completed* epoch — the restore point. Older completed
    /// epochs are dropped (one restorable point bounds memory).
    latest: Option<EpochRecord>,
    /// Worst/total barrier-alignment span across tasks (ns), all epochs.
    pub align_ns_max: u64,
    pub align_ns_total: u64,
    pub align_spans: u64,
}

/// Shared handle actors hold (same idiom as the plasma store).
pub type SharedCheckpoint = Rc<RefCell<CheckpointControl>>;

impl CheckpointControl {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn shared() -> SharedCheckpoint {
        Rc::new(RefCell::new(Self::new()))
    }

    /// Start gathering epoch `epoch`. Any leftover pending epoch was
    /// aborted (a recovery ran) and is discarded.
    pub fn begin(&mut self, epoch: u64) {
        self.pending = Some(EpochRecord { epoch, ..Default::default() });
    }

    /// A source's snapshot for `epoch`. Writes against a stale epoch (the
    /// participant raced an abort) are dropped.
    pub fn put_source(&mut self, epoch: u64, actor: ActorId, snap: SourceSnapshot) {
        if let Some(p) = &mut self.pending {
            if p.epoch == epoch {
                p.sources.insert(actor, snap);
            }
        }
    }

    /// A task's snapshot for `epoch`.
    pub fn put_task(&mut self, epoch: u64, actor: ActorId, snap: TaskSnapshot) {
        if let Some(p) = &mut self.pending {
            if p.epoch == epoch {
                p.tasks.insert(actor, snap);
            }
        }
    }

    /// A task finished aligning after `span` ns (metrics).
    pub fn note_alignment(&mut self, span: Time) {
        self.align_ns_max = self.align_ns_max.max(span);
        self.align_ns_total += span;
        self.align_spans += 1;
    }

    /// Promote the pending epoch to the restore point; returns its
    /// committed cursors for the broker commit.
    pub fn complete(&mut self, epoch: u64) -> Vec<(PartitionId, ChunkOffset)> {
        let p = self.pending.take().expect("completing an epoch that was begun");
        assert_eq!(p.epoch, epoch, "epoch lifecycle out of order");
        let cursors = p.committed_cursors();
        self.latest = Some(p);
        cursors
    }

    /// Drop the pending epoch (recovery aborted it mid-alignment).
    pub fn abort(&mut self) -> bool {
        self.pending.take().is_some()
    }

    /// The restore point's epoch, if any checkpoint completed yet.
    pub fn latest_epoch(&self) -> Option<u64> {
        self.latest.as_ref().map(|e| e.epoch)
    }

    /// A source's snapshot at the restore point (`None` = restart from the
    /// initial assignments — no checkpoint completed yet).
    pub fn source_snapshot(&self, actor: ActorId) -> Option<SourceSnapshot> {
        self.latest.as_ref().and_then(|e| e.sources.get(&actor)).cloned()
    }

    /// A task's snapshot at the restore point.
    pub fn task_snapshot(&self, actor: ActorId) -> Option<TaskSnapshot> {
        self.latest.as_ref().and_then(|e| e.tasks.get(&actor)).cloned()
    }

    /// The epoch currently gathering snapshots (tests/introspection).
    pub fn pending_epoch(&self) -> Option<u64> {
        self.pending.as_ref().map(|e| e.epoch)
    }
}

/// End-of-run checkpoint/recovery accounting, exported as gauges by the
/// launcher and printed by the `checkpoint` ablation.
#[derive(Debug, Clone, Default)]
pub struct CheckpointStats {
    /// Epochs that aligned everywhere and were committed.
    pub epochs_completed: u64,
    /// Epochs aborted by a recovery mid-alignment.
    pub epochs_aborted: u64,
    /// Interval ticks skipped because the previous epoch was still
    /// aligning (sustained alignment pressure).
    pub epochs_skipped: u64,
    /// Sum/max of trigger→commit spans (ns) over completed epochs.
    pub epoch_ns_total: u64,
    pub epoch_ns_max: u64,
    /// Worst single-task barrier alignment span (ns).
    pub align_ns_max: u64,
    /// Mean task alignment span (ns).
    pub align_ns_mean: u64,
    /// Recoveries run (fault injections detected).
    pub recoveries: u64,
    /// Fault detection → every participant restored, for the last
    /// recovery (ns).
    pub last_recovery_ns: u64,
    /// Commit RPCs acked by the broker.
    pub commits_acked: u64,
    /// Records re-read and re-processed after rollbacks (from source
    /// stats; filled by the launcher).
    pub records_replayed: u64,
}

impl CheckpointStats {
    /// Mean trigger→commit span (ns).
    pub fn mean_epoch_ns(&self) -> u64 {
        if self.epochs_completed == 0 {
            0
        } else {
            self.epoch_ns_total / self.epochs_completed
        }
    }
}

/// Static coordinator wiring.
#[derive(Debug, Clone)]
pub struct CoordinatorParams {
    /// Barrier injection period (ns).
    pub interval_ns: Time,
    /// Node the coordinator runs on (the colocated worker node).
    pub node: NodeId,
    /// Every broker hosting a shard of the stream (one entry at
    /// `broker_count=1`). Commits fan out to all of them: each broker
    /// floors retention for every partition it holds a replica of, so the
    /// committed epoch is a per-shard floor that survives a hand-off.
    pub brokers: Vec<(ActorId, NodeId)>,
    /// Source actors (barrier injection targets + snapshot participants).
    pub sources: Vec<ActorId>,
    /// Operator task actors (snapshot participants).
    pub tasks: Vec<ActorId>,
    /// All stream partitions (the genesis commit pins retention at 0 until
    /// the first epoch completes).
    pub partitions: Vec<PartitionId>,
    pub cost: CostModel,
}

/// In-flight epoch state.
#[derive(Debug)]
struct PendingEpoch {
    epoch: u64,
    started: Time,
    acks: Vec<ActorId>,
}

/// In-flight recovery state.
#[derive(Debug)]
struct Recovery {
    started: Time,
    acks: Vec<ActorId>,
}

/// The coordinator actor: epoch lifecycle + failure detection/recovery.
pub struct CheckpointCoordinator {
    params: CoordinatorParams,
    control: SharedCheckpoint,
    net: SharedNetwork,
    /// Hub handle for the tracer's structured event stream (epoch spans,
    /// fault/restore marks) — see [`crate::obs`].
    metrics: SharedMetrics,
    /// Next epoch number (epochs are 1-based; 0 is the genesis commit).
    next_epoch: u64,
    /// Current recovery incarnation (bumped per recovery).
    inc: u64,
    pending: Option<PendingEpoch>,
    recovering: Option<Recovery>,
    next_rpc: u64,
    stats: CheckpointStats,
}

impl CheckpointCoordinator {
    pub fn new(
        params: CoordinatorParams,
        control: SharedCheckpoint,
        net: SharedNetwork,
        metrics: SharedMetrics,
    ) -> Self {
        assert!(params.interval_ns > 0, "coordinator needs a positive interval");
        assert!(!params.sources.is_empty(), "checkpointing needs sources");
        assert!(!params.brokers.is_empty(), "commits need at least one broker");
        Self {
            params,
            control,
            net,
            metrics,
            next_epoch: 1,
            inc: 0,
            pending: None,
            recovering: None,
            next_rpc: 0,
            stats: CheckpointStats::default(),
        }
    }

    /// Uniform end-of-run stats (alignment spans merged in from the
    /// shared control, where tasks record them).
    pub fn stats(&self) -> CheckpointStats {
        let mut s = self.stats.clone();
        let c = self.control.borrow();
        s.align_ns_max = c.align_ns_max;
        s.align_ns_mean =
            if c.align_spans == 0 { 0 } else { c.align_ns_total / c.align_spans };
        s
    }

    fn participants(&self) -> usize {
        self.params.sources.len() + self.params.tasks.len()
    }

    fn commit(&mut self, epoch: u64, cursors: Vec<(PartitionId, ChunkOffset)>, ctx: &mut Ctx<'_, Msg>) {
        // Fire-and-forget on purpose: a broker that died mid-run drops its
        // commit silently (no ack, no error), and that is safe — epoch
        // progression is timer-driven, the survivors (including any
        // promoted replica, which holds the partition's full log) still
        // floor their retention, and the only visible effect is a smaller
        // `commits_acked` count.
        for &(broker, broker_node) in &self.params.brokers.clone() {
            let id = self.next_rpc;
            self.next_rpc += 1;
            let deliver =
                self.net.borrow_mut().send_control(ctx.now(), self.params.node, broker_node);
            ctx.send_at(
                deliver,
                broker,
                Msg::rpc(RpcRequest {
                    id,
                    reply_to: ctx.self_id(),
                    from_node: self.params.node,
                    kind: RpcKind::CommitCheckpoint { epoch, cursors: cursors.clone() },
                }),
            );
        }
    }

    fn trigger_epoch(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let epoch = self.next_epoch;
        self.next_epoch += 1;
        self.control.borrow_mut().begin(epoch);
        self.pending = Some(PendingEpoch { epoch, started: ctx.now(), acks: Vec::new() });
        for &s in &self.params.sources {
            ctx.send_in(self.params.cost.notify_ns, s, Msg::BarrierInject { epoch });
        }
    }

    fn on_tick(&mut self, ctx: &mut Ctx<'_, Msg>) {
        ctx.send_self_in(self.params.interval_ns, Msg::Timer(self.inc));
        if self.recovering.is_some() {
            return; // checkpointing pauses while the pipeline restores
        }
        if self.pending.is_some() {
            // Previous epoch still aligning: skip rather than queue —
            // overlapping barrier waves would confuse alignment.
            self.stats.epochs_skipped += 1;
            return;
        }
        self.trigger_epoch(ctx);
    }

    fn on_barrier_ack(&mut self, epoch: u64, from: ActorId, ctx: &mut Ctx<'_, Msg>) {
        let Some(p) = &mut self.pending else { return };
        if p.epoch != epoch {
            return; // stale ack from an aborted epoch
        }
        if !p.acks.contains(&from) {
            p.acks.push(from);
        }
        if p.acks.len() < self.participants() {
            return;
        }
        let p = self.pending.take().expect("checked above");
        let cursors = self.control.borrow_mut().complete(p.epoch);
        let span = ctx.now() - p.started;
        self.stats.epochs_completed += 1;
        self.stats.epoch_ns_total += span;
        self.stats.epoch_ns_max = self.stats.epoch_ns_max.max(span);
        self.metrics.borrow_mut().tracer.note_epoch(p.epoch, ctx.now(), span);
        self.commit(p.epoch, cursors, ctx);
    }

    fn on_failure(&mut self, ctx: &mut Ctx<'_, Msg>) {
        if self.recovering.is_some() {
            return; // already rolling back; the restore covers this victim
        }
        self.stats.recoveries += 1;
        // Mark the fault in the trace stream and drop in-flight spans: the
        // rollback replays those records under a new incarnation, so their
        // half-open spans would otherwise report bogus latencies.
        self.metrics.borrow_mut().tracer.note_fault("process", ctx.now());
        if self.pending.take().is_some() {
            self.control.borrow_mut().abort();
            self.stats.epochs_aborted += 1;
        }
        self.inc += 1;
        // Everything below next_epoch (completed or aborted) is stale to
        // the restored pipeline; future epochs start at next_epoch.
        let epoch_floor = self.next_epoch - 1;
        self.recovering = Some(Recovery { started: ctx.now(), acks: Vec::new() });
        let restore = Msg::Restore { inc: self.inc, epoch_floor };
        for &a in self.params.sources.iter().chain(self.params.tasks.iter()) {
            ctx.send_in(self.params.cost.notify_ns, a, restore.clone());
        }
    }

    fn on_restore_ack(&mut self, from: ActorId, ctx: &mut Ctx<'_, Msg>) {
        let Some(r) = &mut self.recovering else { return };
        if !r.acks.contains(&from) {
            r.acks.push(from);
        }
        if r.acks.len() < self.participants() {
            return;
        }
        let r = self.recovering.take().expect("checked above");
        self.stats.last_recovery_ns = ctx.now() - r.started;
        self.metrics.borrow_mut().tracer.note_restore(ctx.now(), self.stats.last_recovery_ns);
        // The old timer chain died with the old incarnation tag; resume
        // checkpointing on the new one.
        ctx.send_self_in(self.params.interval_ns, Msg::Timer(self.inc));
    }
}

impl Actor<Msg> for CheckpointCoordinator {
    fn on_start(&mut self, ctx: &mut Ctx<'_, Msg>) {
        // Genesis commit: pin retention at offset 0 for every partition so
        // a recovery before the first completed checkpoint can replay from
        // the beginning of the log.
        let cursors: Vec<_> = self.params.partitions.iter().map(|&p| (p, 0)).collect();
        self.commit(0, cursors, ctx);
        ctx.send_self_in(self.params.interval_ns, Msg::Timer(self.inc));
    }

    fn on_event(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        match msg {
            Msg::Timer(tag) => {
                if tag == self.inc {
                    self.on_tick(ctx);
                }
                // A stale tag is a timer chain from before a recovery: let
                // it die (the recovery completion armed the new chain).
            }
            Msg::BarrierAck { epoch, from } => self.on_barrier_ack(epoch, from, ctx),
            Msg::FailureDetected { .. } => self.on_failure(ctx),
            Msg::RestoreAck { from } => self.on_restore_ack(from, ctx),
            Msg::Reply(env) => match env.reply {
                RpcReply::CommitAck { .. } => self.stats.commits_acked += 1,
                RpcReply::Error { reason } => {
                    panic!("checkpoint commit refused by the broker: {reason}")
                }
                other => panic!("coordinator: unexpected reply {other:?}"),
            },
            other => panic!("coordinator: unexpected {other:?}"),
        }
    }

    fn label(&self) -> String {
        "checkpoint-coordinator".into()
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}
