//! Checkpoint store + coordinator tests with scripted participants.

use std::cell::RefCell;
use std::rc::Rc;

use super::*;
use crate::config::NetworkProfile;
use crate::net::Network;
use crate::proto::{Msg, PartitionId, RpcEnvelope, RpcKind, RpcReply};
use crate::sim::{Actor, ActorId, Ctx, Engine, MILLIS, SECOND};

// ---------------------------------------------------------------------------
// Store mechanics (no engine)
// ---------------------------------------------------------------------------

fn snap(p: usize, off: u64, records: u64) -> SourceSnapshot {
    SourceSnapshot {
        cursors: vec![(PartitionId(p), off)],
        records_consumed: records,
        ..Default::default()
    }
}

#[test]
fn control_epoch_lifecycle() {
    let mut c = CheckpointControl::new();
    assert_eq!(c.latest_epoch(), None);
    c.begin(1);
    assert_eq!(c.pending_epoch(), Some(1));
    c.put_source(1, ActorId(3), snap(0, 7, 700));
    c.put_task(1, ActorId(4), TaskSnapshot { ops: vec![crate::ops::OpState::Count { total: 9 }] });
    let cursors = c.complete(1);
    assert_eq!(cursors, vec![(PartitionId(0), 7)]);
    assert_eq!(c.latest_epoch(), Some(1));
    assert_eq!(c.source_snapshot(ActorId(3)).unwrap().records_consumed, 700);
    assert!(c.task_snapshot(ActorId(4)).is_some());
    assert!(c.source_snapshot(ActorId(99)).is_none(), "unknown participants have no snapshot");
}

#[test]
fn stale_epoch_writes_are_dropped() {
    let mut c = CheckpointControl::new();
    c.begin(2);
    c.put_source(1, ActorId(0), snap(0, 3, 30)); // epoch 1 was aborted
    let cursors = c.complete(2);
    assert!(cursors.is_empty(), "stale write must not leak into epoch 2");
}

#[test]
fn abort_discards_the_pending_epoch() {
    let mut c = CheckpointControl::new();
    c.begin(1);
    c.put_source(1, ActorId(0), snap(0, 3, 30));
    assert!(c.abort());
    assert!(!c.abort(), "nothing left to abort");
    assert_eq!(c.latest_epoch(), None, "an aborted epoch is not a restore point");
}

#[test]
fn committed_cursors_take_the_minimum_per_partition() {
    let mut e = EpochRecord::default();
    e.sources.insert(ActorId(0), snap(0, 9, 0));
    e.sources.insert(
        ActorId(1),
        SourceSnapshot {
            cursors: vec![(PartitionId(0), 4), (PartitionId(1), 6)],
            ..Default::default()
        },
    );
    assert_eq!(
        e.committed_cursors(),
        vec![(PartitionId(0), 4), (PartitionId(1), 6)],
        "the restorable floor covers the lowest restart point"
    );
}

// ---------------------------------------------------------------------------
// Coordinator lifecycle with scripted participants
// ---------------------------------------------------------------------------

type Commits = Rc<RefCell<Vec<(u64, Vec<(PartitionId, u64)>)>>>;

/// Stands in for the broker: records commits, acks them.
struct AckBroker {
    commits: Commits,
}

impl Actor<Msg> for AckBroker {
    fn on_event(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        let Msg::Rpc(req) = msg else { panic!("fake broker got {msg:?}") };
        let RpcKind::CommitCheckpoint { epoch, cursors } = req.kind else {
            panic!("fake broker only serves commits")
        };
        self.commits.borrow_mut().push((epoch, cursors));
        ctx.send(
            req.reply_to,
            Msg::reply(RpcEnvelope { id: req.id, reply: RpcReply::CommitAck { epoch } }),
        );
    }
}

/// A scripted participant: snapshots + acks barriers (when cooperative),
/// forwards them in-band to its downstream (sources do, in the real
/// protocol), acks restores, reports injected faults.
struct Participant {
    control: SharedCheckpoint,
    as_task: bool,
    cooperative: bool,
    downstream: Option<ActorId>,
    restores_seen: Rc<RefCell<Vec<u64>>>,
}

impl Participant {
    fn coordinator(&self) -> ActorId {
        self.control.borrow().coordinator.expect("coordinator wired")
    }
}

impl Actor<Msg> for Participant {
    fn on_event(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        match msg {
            Msg::BarrierInject { epoch } | Msg::Barrier { epoch, .. } => {
                if !self.cooperative {
                    return; // never aligns: the epoch must stall, not wedge others
                }
                {
                    let mut c = self.control.borrow_mut();
                    if self.as_task {
                        c.put_task(epoch, ctx.self_id(), TaskSnapshot { ops: vec![] });
                    } else {
                        c.put_source(epoch, ctx.self_id(), snap(0, epoch, 10 * epoch));
                    }
                }
                let coord = self.coordinator();
                ctx.send(coord, Msg::BarrierAck { epoch, from: ctx.self_id() });
                if let Some(d) = self.downstream {
                    ctx.send(d, Msg::Barrier { epoch, from_task: 0 });
                }
            }
            Msg::Restore { inc, .. } => {
                self.restores_seen.borrow_mut().push(inc);
                let coord = self.coordinator();
                ctx.send(coord, Msg::RestoreAck { from: ctx.self_id() });
            }
            Msg::Fault { .. } => {
                let coord = self.coordinator();
                ctx.send(coord, Msg::FailureDetected { from: ctx.self_id() });
            }
            _ => {}
        }
    }
}

struct Rig {
    engine: Engine<Msg>,
    coordinator: ActorId,
    source: ActorId,
    commits: Commits,
    restores: Rc<RefCell<Vec<u64>>>,
    control: SharedCheckpoint,
}

fn rig(cooperative_task: bool) -> Rig {
    let mut engine = Engine::new(3);
    let control = CheckpointControl::shared();
    let net = Network::shared(NetworkProfile::INFINIBAND, NetworkProfile::LOOPBACK);
    let commits: Commits = Rc::new(RefCell::new(Vec::new()));
    let restores = Rc::new(RefCell::new(Vec::new()));
    let broker = engine.add_actor(Box::new(AckBroker { commits: commits.clone() }));
    let task = engine.add_actor(Box::new(Participant {
        control: control.clone(),
        as_task: true,
        cooperative: cooperative_task,
        downstream: None,
        restores_seen: restores.clone(),
    }));
    let source = engine.add_actor(Box::new(Participant {
        control: control.clone(),
        as_task: false,
        cooperative: true,
        downstream: Some(task),
        restores_seen: restores.clone(),
    }));
    let coordinator = engine.add_actor(Box::new(CheckpointCoordinator::new(
        CoordinatorParams {
            interval_ns: 100 * MILLIS,
            node: 0,
            brokers: vec![(broker, 0)],
            sources: vec![source],
            tasks: vec![task],
            partitions: vec![PartitionId(0), PartitionId(1)],
            cost: Default::default(),
        },
        control.clone(),
        net,
        crate::metrics::MetricsHub::shared(),
    )));
    control.borrow_mut().coordinator = Some(coordinator);
    Rig { engine, coordinator, source, commits, restores, control }
}

fn coordinator_stats(r: &mut Rig) -> CheckpointStats {
    r.engine.actor_as::<CheckpointCoordinator>(r.coordinator).unwrap().stats()
}

#[test]
fn epochs_complete_and_commit_on_the_interval() {
    let mut r = rig(true);
    r.engine.run_until(SECOND);
    let stats = coordinator_stats(&mut r);
    // 100 ms interval over 1 s: the first trigger fires at 100 ms.
    assert!(stats.epochs_completed >= 8, "epochs: {stats:?}");
    assert_eq!(stats.epochs_aborted, 0);
    assert_eq!(stats.recoveries, 0);
    let commits = r.commits.borrow();
    // Genesis (epoch 0, all partitions at 0) + one commit per epoch.
    assert_eq!(commits[0].0, 0);
    assert_eq!(commits[0].1, vec![(PartitionId(0), 0), (PartitionId(1), 0)]);
    assert_eq!(commits.len() as u64, 1 + stats.epochs_completed);
    // Committed cursors advance with the source snapshots (epoch = offset).
    let (last_epoch, last_cursors) = commits.last().unwrap().clone();
    assert_eq!(last_cursors, vec![(PartitionId(0), last_epoch)]);
    assert_eq!(stats.commits_acked, commits.len() as u64);
    assert_eq!(r.control.borrow().latest_epoch(), Some(last_epoch));
}

#[test]
fn a_stalled_participant_stalls_the_epoch_not_the_coordinator() {
    let mut r = rig(false); // the task never acks
    r.engine.run_until(SECOND);
    let stats = coordinator_stats(&mut r);
    assert_eq!(stats.epochs_completed, 0, "no epoch can complete without the task");
    assert!(stats.epochs_skipped >= 7, "ticks keep firing and skipping: {stats:?}");
    assert_eq!(r.commits.borrow().len(), 1, "only the genesis commit went out");
}

#[test]
fn failure_aborts_restores_and_resumes_checkpointing() {
    let mut r = rig(true);
    // Inject the fault into the source participant mid-run.
    r.engine.schedule(450 * MILLIS, r.source, Msg::Fault { kind: crate::config::FaultKind::Source });
    r.engine.run_until(SECOND);
    let stats = coordinator_stats(&mut r);
    assert_eq!(stats.recoveries, 1);
    assert!(stats.last_recovery_ns > 0, "recovery span measured: {stats:?}");
    // Both participants were restored exactly once, at incarnation 1.
    assert_eq!(*r.restores.borrow(), vec![1, 1]);
    // Checkpointing resumed after the recovery: epochs kept completing.
    assert!(stats.epochs_completed >= 6, "post-recovery epochs: {stats:?}");
    let commits = r.commits.borrow();
    assert_eq!(commits.len() as u64, 1 + stats.epochs_completed);
}
