//! Operator unit tests (passive semantics; task-driving is in worker).

use super::*;
use crate::compute::ComputeEngine;
use crate::proto::{Batch, Chunk};
use std::rc::Rc;

fn batch(tuples: u64) -> Batch {
    Batch { from_task: 0, tuples, chunks: ChunkList::Empty, hist: None, inc: 0 }
}

fn cm() -> CostModel {
    CostModel::default()
}

#[test]
fn count_logs_and_accumulates() {
    let mut op = CountOp::default();
    let mut out = OpOutput::default();
    op.apply(batch(100), 0, &mut out).unwrap();
    assert_eq!(out.tuples_logged, 100);
    op.apply(batch(50), 0, &mut out).unwrap();
    assert_eq!(op.total, 150);
    // Operators accumulate into the task's pooled buffer (see OpOutput).
    assert_eq!(out.tuples_logged, 150, "pooled buffers accumulate");
    assert!(out.emits.is_empty(), "RTLogger is terminal");
}

#[test]
fn count_cost_is_per_tuple() {
    let op = CountOp::default();
    assert_eq!(op.cost(&batch(1000), &cm()), 1000 * cm().count_map_ns);
}

#[test]
fn filter_cost_exceeds_count_cost() {
    let f = FilterOp::new(b"needle", None);
    let c = CountOp::default();
    assert!(f.cost(&batch(1000), &cm()) > c.cost(&batch(1000), &cm()));
}

#[test]
fn filter_real_plane_counts_matches() {
    let mut f = FilterOp::new(b"needle", Some(ComputeEngine::native()));
    let mut data = vec![b'x'; 300];
    data[110..116].copy_from_slice(b"needle");
    let mut b = batch(3);
    b.chunks = ChunkList::One(Chunk::real(3, 100, Rc::new(data)));
    let mut out = OpOutput::default();
    f.apply(b, 0, &mut out).unwrap();
    assert_eq!(f.matches, 1);
    assert_eq!(out.tuples_logged, 3, "throughput counts processed tuples");
}

#[test]
fn tokenizer_sim_splits_tokens_across_targets() {
    let mut t = TokenizerOp::new(vec![10, 11, 12], None, 300);
    let mut out = OpOutput::default();
    t.apply(batch(10), 5, &mut out).unwrap();
    assert_eq!(out.emits.len(), 3);
    let total: u64 = out.emits.iter().map(|(_, b)| b.tuples).sum();
    assert_eq!(total, 3000, "10 records x 300 tokens");
    assert_eq!(t.tokens_emitted, 3000);
    for (target, b) in &out.emits {
        assert!((10..=12).contains(target));
        assert_eq!(b.from_task, 5);
        assert_eq!(b.tuples, 1000);
    }
}

#[test]
fn tokenizer_real_plane_routes_by_bucket_range() {
    let mut t = TokenizerOp::new(vec![7, 8], Some(ComputeEngine::native()), 300);
    let text = b"alpha beta gamma delta epsilon zeta eta theta";
    let mut data = vec![0u8; 64];
    data[..text.len()].copy_from_slice(text);
    let mut b = batch(1);
    b.chunks = ChunkList::One(Chunk::real(1, 64, Rc::new(data)));
    let mut out = OpOutput::default();
    t.apply(b, 0, &mut out).unwrap();
    let total: u64 = out.emits.iter().map(|(_, b)| b.tuples).sum();
    assert_eq!(total, 8, "eight words routed");
    for (_, b) in &out.emits {
        let hist = b.hist.as_ref().expect("real plane carries hists");
        let sum: u64 = hist.iter().map(|&v| v as u64).sum();
        assert_eq!(sum, b.tuples);
    }
}

#[test]
fn keyed_sum_merges_hists() {
    let mut k = KeyedSumOp::new();
    let mut out = OpOutput::default();
    let mut b1 = batch(3);
    b1.hist = Some(Rc::new(vec![1, 2, 0]));
    let mut b2 = batch(4);
    b2.hist = Some(Rc::new(vec![0, 1, 3]));
    k.apply(b1, 0, &mut out).unwrap();
    k.apply(b2, 0, &mut out).unwrap();
    assert_eq!(k.counts, vec![1, 3, 3]);
    assert_eq!(k.total_tuples, 7);
}

#[test]
fn windowed_sum_fires_after_w_slides() {
    let mut w = WindowedSumOp::new(3, None);
    assert!(w.wants_ticks());
    let mut out = OpOutput::default();
    for round in 0..5 {
        let mut b = batch(10);
        b.hist = Some(Rc::new(vec![1i32; 4]));
        w.apply(b, 0, &mut out).unwrap();
        w.on_tick(&mut out).unwrap();
        if round < 2 {
            assert_eq!(w.windows_fired, 0, "window needs 3 slides");
        }
    }
    assert_eq!(w.windows_fired, 3, "fires every tick once warm");
    // 3 slides x 4 buckets x 1 each = 12 tuples per window
    assert_eq!(w.last_window_tuples, 12);
    assert_eq!(w.total_tuples, 50);
}

#[test]
fn windowed_sum_evicts_old_slides() {
    let mut w = WindowedSumOp::new(2, None);
    let mut out = OpOutput::default();
    // slide 1: 10 tokens; slide 2: 0; slide 3: 0 -> window at slide 3 = 0
    let mut b = batch(10);
    b.hist = Some(Rc::new(vec![10i32]));
    w.apply(b, 0, &mut out).unwrap();
    w.on_tick(&mut out).unwrap();
    w.on_tick(&mut out).unwrap();
    assert_eq!(w.last_window_tuples, 10, "slide 1 still in window");
    w.on_tick(&mut out).unwrap();
    assert_eq!(w.last_window_tuples, 0, "slide 1 evicted after 2 slides");
}

#[test]
fn op_names_are_stable() {
    assert_eq!(CountOp::default().name(), "count");
    assert_eq!(FilterOp::new(b"x", None).name(), "filter");
    assert_eq!(KeyedSumOp::new().name(), "keyed-sum");
}

// ---------------------------------------------------------------------------
// Checkpoint snapshots
// ---------------------------------------------------------------------------

#[test]
fn count_snapshot_round_trips() {
    let mut op = CountOp::default();
    let mut out = OpOutput::default();
    op.apply(batch(100), 0, &mut out).unwrap();
    let snap = op.snapshot();
    op.apply(batch(50), 0, &mut out).unwrap();
    assert_eq!(op.total, 150);
    op.restore(&snap);
    assert_eq!(op.total, 100, "rolled back to the snapshot");
}

#[test]
fn filter_snapshot_restores_matches() {
    let mut f = FilterOp::new(b"needle", None);
    f.total = 7;
    f.matches = 3;
    let snap = f.snapshot();
    f.total = 100;
    f.matches = 50;
    f.restore(&snap);
    assert_eq!((f.total, f.matches), (7, 3));
}

#[test]
fn keyed_sum_snapshot_restores_counts() {
    let mut k = KeyedSumOp::new();
    let mut out = OpOutput::default();
    let mut b = batch(3);
    b.hist = Some(Rc::new(vec![1, 2, 0]));
    k.apply(b, 0, &mut out).unwrap();
    let snap = k.snapshot();
    let mut b2 = batch(4);
    b2.hist = Some(Rc::new(vec![0, 1, 3]));
    k.apply(b2, 0, &mut out).unwrap();
    k.restore(&snap);
    assert_eq!(k.counts, vec![1, 2, 0]);
    assert_eq!(k.total_tuples, 3);
}

#[test]
fn windowed_sum_snapshot_restores_the_slide_ring() {
    let mut w = WindowedSumOp::new(2, None);
    let mut out = OpOutput::default();
    let mut b = batch(10);
    b.hist = Some(Rc::new(vec![10i32]));
    w.apply(b, 0, &mut out).unwrap();
    w.on_tick(&mut out).unwrap();
    let snap = w.snapshot();
    // Diverge: more data + ticks fire windows.
    let mut b2 = batch(5);
    b2.hist = Some(Rc::new(vec![5i32]));
    w.apply(b2, 0, &mut out).unwrap();
    w.on_tick(&mut out).unwrap();
    assert_eq!(w.windows_fired, 1);
    w.restore(&snap);
    assert_eq!(w.windows_fired, 0);
    assert_eq!(w.total_tuples, 10);
    // The restored ring replays identically: one more empty tick fires the
    // first window over [slide1, empty].
    w.on_tick(&mut out).unwrap();
    assert_eq!(w.windows_fired, 1);
    assert_eq!(w.last_window_tuples, 10);
}

#[test]
fn stateless_default_snapshot() {
    // An out-of-tree operator without checkpoint support keeps the
    // Stateless default and restore is a no-op.
    struct Noop;
    impl Operator for Noop {
        fn name(&self) -> &'static str {
            "noop"
        }
        fn cost(&self, _b: &Batch, _c: &CostModel) -> crate::sim::Time {
            0
        }
        fn apply(&mut self, _b: Batch, _f: usize, _o: &mut OpOutput) -> anyhow::Result<()> {
            Ok(())
        }
        fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
            self
        }
    }
    let mut op = Noop;
    assert_eq!(op.snapshot(), OpState::Stateless);
    op.restore(&OpState::Stateless);
}

#[test]
#[should_panic(expected = "mismatched snapshot")]
fn restore_rejects_a_foreign_snapshot() {
    let mut op = CountOp::default();
    op.restore(&OpState::Tokenizer { tokens_emitted: 9 });
}
