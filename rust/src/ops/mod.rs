//! Streaming operators: the paper's benchmark user functions (Table II).
//!
//! Each operator mirrors a Flink function from Listings 1 & 2:
//!
//! * [`CountOp`] — `RTLogger`, the iterate-and-count flatMap (benchmark 1);
//! * [`FilterOp`] — `RichFilterThroughputLogger`, grep + count
//!   (benchmark 2; Figs. 5-8). On the real data plane it executes the
//!   Layer-1 filter kernel through PJRT;
//! * [`TokenizerOp`] — the word-count tokenizer; real plane runs the
//!   word-hash histogram kernel and routes keyed sub-batches (`keyBy`);
//! * [`KeyedSumOp`] — `sum(1)`, keyed aggregation state;
//! * [`WindowedSumOp`] — `countWindow(size, slide).sum(1)`: per-slide
//!   histograms, window fired on slide ticks via the `window_sum` artifact.
//!
//! Operators are passive; [`crate::worker::OperatorTask`] drives them and
//! charges their virtual cost.

#[cfg(test)]
mod tests;

use std::collections::VecDeque;

use anyhow::Result;

use crate::compute::SharedCompute;
use crate::config::CostModel;
use crate::proto::{Batch, ChunkList};
use crate::sim::Time;

/// What an operator produced from one batch.
///
/// Tasks keep ONE pooled `OpOutput` and hand it to every `apply`/`on_tick`
/// (see `OperatorTask`): operators therefore **accumulate** into it
/// (`tuples_logged +=`, `emits.push`) and never assume a fresh buffer —
/// that is what lets the hot path run allocation-free once the emit
/// vector has grown to its working size.
#[derive(Debug, Default)]
pub struct OpOutput {
    /// Batches routed downstream: `(destination task index, batch)`.
    pub emits: Vec<(usize, Batch)>,
    /// Tuples this operator counted toward the figure's throughput metric
    /// (what RTLogger logs every second).
    pub tuples_logged: u64,
}

/// A checkpointed operator state — what an aligned-barrier snapshot
/// captures and recovery restores. One variant per built-in operator;
/// out-of-tree stateless operators use [`OpState::Stateless`] (the trait
/// default).
#[derive(Debug, Clone, PartialEq)]
pub enum OpState {
    /// The operator carries no state worth checkpointing.
    Stateless,
    Count {
        total: u64,
    },
    Filter {
        total: u64,
        matches: u64,
    },
    Tokenizer {
        tokens_emitted: u64,
    },
    KeyedSum {
        counts: Vec<i64>,
        total_tuples: u64,
    },
    WindowedSum {
        slides: Vec<Vec<i32>>,
        current: Vec<i32>,
        current_tuples: u64,
        total_tuples: u64,
        windows_fired: u64,
        last_window_tuples: u64,
    },
}

/// A streaming operator driven by an [`crate::worker::OperatorTask`].
pub trait Operator {
    fn name(&self) -> &'static str;

    /// Virtual service time to process `batch` on the task's core.
    fn cost(&self, batch: &Batch, cost: &CostModel) -> Time;

    /// Process a batch. `from_task` identifies this task for emits.
    fn apply(&mut self, batch: Batch, from_task: usize, out: &mut OpOutput) -> Result<()>;

    /// Periodic tick for windowed operators (fired every slide).
    fn on_tick(&mut self, _out: &mut OpOutput) -> Result<()> {
        Ok(())
    }

    /// Whether this operator needs slide ticks.
    fn wants_ticks(&self) -> bool {
        false
    }

    /// Checkpoint the operator's state (taken at an aligned barrier, after
    /// every pre-barrier batch was applied). Stateless operators keep the
    /// default.
    fn snapshot(&self) -> OpState {
        OpState::Stateless
    }

    /// Restore state captured by [`Operator::snapshot`] (recovery rollback).
    /// Implementations panic on a mismatched variant — a snapshot can only
    /// legally come from the same operator kind at the same task.
    fn restore(&mut self, _state: &OpState) {}

    /// Downcast hook for end-of-run state inspection.
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any;
}

// ---------------------------------------------------------------------------

/// Iterate + count (`RTLogger`).
#[derive(Debug, Default)]
pub struct CountOp {
    pub total: u64,
}

impl Operator for CountOp {
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn name(&self) -> &'static str {
        "count"
    }

    fn cost(&self, batch: &Batch, cost: &CostModel) -> Time {
        batch.tuples * cost.count_map_ns
    }

    fn apply(&mut self, batch: Batch, _from: usize, out: &mut OpOutput) -> Result<()> {
        self.total += batch.tuples;
        out.tuples_logged += batch.tuples;
        Ok(())
    }

    fn snapshot(&self) -> OpState {
        OpState::Count { total: self.total }
    }

    fn restore(&mut self, state: &OpState) {
        let OpState::Count { total } = state else {
            panic!("count op: mismatched snapshot {state:?}")
        };
        self.total = *total;
    }
}

// ---------------------------------------------------------------------------

/// Grep filter + count.
pub struct FilterOp {
    pub pattern: Vec<u8>,
    /// Real-plane kernel engine (`None` on the sim plane).
    pub compute: Option<SharedCompute>,
    pub total: u64,
    pub matches: u64,
}

impl FilterOp {
    pub fn new(pattern: &[u8], compute: Option<SharedCompute>) -> Self {
        Self { pattern: pattern.to_vec(), compute, total: 0, matches: 0 }
    }
}

impl Operator for FilterOp {
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn name(&self) -> &'static str {
        "filter"
    }

    fn cost(&self, batch: &Batch, cost: &CostModel) -> Time {
        batch.tuples * (cost.count_map_ns + cost.filter_record_ns)
    }

    fn apply(&mut self, batch: Batch, _from: usize, out: &mut OpOutput) -> Result<()> {
        if let Some(compute) = &self.compute {
            for chunk in &batch.chunks {
                self.matches += compute.filter_count(chunk, &self.pattern)?;
            }
        }
        self.total += batch.tuples;
        out.tuples_logged += batch.tuples;
        Ok(())
    }

    fn snapshot(&self) -> OpState {
        OpState::Filter { total: self.total, matches: self.matches }
    }

    fn restore(&mut self, state: &OpState) {
        let OpState::Filter { total, matches } = state else {
            panic!("filter op: mismatched snapshot {state:?}")
        };
        self.total = *total;
        self.matches = *matches;
    }
}

// ---------------------------------------------------------------------------

/// Word-count tokenizer + `keyBy` exchange.
pub struct TokenizerOp {
    /// Downstream keyed tasks (global task indices); bucket space is split
    /// evenly across them.
    pub targets: Vec<usize>,
    pub compute: Option<SharedCompute>,
    /// Sim-plane tokens-per-record estimate (real plane counts exactly).
    pub tokens_per_record: u64,
    pub tokens_emitted: u64,
    /// Pooled histogram accumulator (real plane): zeroed and refilled per
    /// batch instead of reallocated. Scratch only — never checkpointed.
    acc: Vec<i32>,
}

impl TokenizerOp {
    pub fn new(targets: Vec<usize>, compute: Option<SharedCompute>, tokens_per_record: u64) -> Self {
        assert!(!targets.is_empty());
        Self { targets, compute, tokens_per_record, tokens_emitted: 0, acc: Vec::new() }
    }
}

impl Operator for TokenizerOp {
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn name(&self) -> &'static str {
        "tokenizer"
    }

    fn cost(&self, batch: &Batch, cost: &CostModel) -> Time {
        // Charged on the token estimate; the real token count (known only
        // after the kernel runs) tracks it closely for corpus text.
        batch.tuples * self.tokens_per_record * cost.tokenize_token_ns
    }

    fn apply(&mut self, batch: Batch, from: usize, out: &mut OpOutput) -> Result<()> {
        let n = self.targets.len();
        if let Some(compute) = &self.compute {
            // Real plane: kernel histogram accumulated into the pooled
            // scratch (zeroed in place, grown once), split by bucket range.
            self.acc.iter_mut().for_each(|v| *v = 0);
            for chunk in &batch.chunks {
                let (hist, _) = compute.wordcount(chunk)?;
                if self.acc.len() < hist.len() {
                    self.acc.resize(hist.len(), 0);
                }
                for (x, y) in self.acc.iter_mut().zip(hist.iter()) {
                    *x += y;
                }
            }
            let b = self.acc.len();
            for (i, &target) in self.targets.iter().enumerate() {
                let range = &self.acc[i * b / n..(i + 1) * b / n];
                let tuples: u64 = range.iter().map(|&v| v as u64).sum();
                if tuples == 0 {
                    continue;
                }
                self.tokens_emitted += tuples;
                // The per-target range is handed off by value: downstream
                // keyed state owns it (an Rc the receivers share) — this
                // is data transfer, not a hop copy.
                out.emits.push((
                    target,
                    Batch {
                        from_task: from,
                        tuples,
                        chunks: ChunkList::Empty,
                        hist: Some(std::rc::Rc::new(range.to_vec())),
                        inc: 0,
                    },
                ));
            }
        } else {
            // Sim plane: estimated tokens, split evenly.
            let total = batch.tuples * self.tokens_per_record;
            for (i, &target) in self.targets.iter().enumerate() {
                let tuples = total / n as u64
                    + if i < (total % n as u64) as usize { 1 } else { 0 };
                if tuples == 0 {
                    continue;
                }
                self.tokens_emitted += tuples;
                out.emits.push((
                    target,
                    Batch {
                        from_task: from,
                        tuples,
                        chunks: ChunkList::Empty,
                        hist: None,
                        inc: 0,
                    },
                ));
            }
        }
        Ok(())
    }

    fn snapshot(&self) -> OpState {
        OpState::Tokenizer { tokens_emitted: self.tokens_emitted }
    }

    fn restore(&mut self, state: &OpState) {
        let OpState::Tokenizer { tokens_emitted } = state else {
            panic!("tokenizer op: mismatched snapshot {state:?}")
        };
        self.tokens_emitted = *tokens_emitted;
    }
}

// ---------------------------------------------------------------------------

/// Keyed `sum(1)`: per-word (bucketed) counts.
pub struct KeyedSumOp {
    /// Bucketed counts (real plane) — index is bucket offset within this
    /// task's range.
    pub counts: Vec<i64>,
    pub total_tuples: u64,
}

impl KeyedSumOp {
    pub fn new() -> Self {
        Self { counts: Vec::new(), total_tuples: 0 }
    }

    fn merge(&mut self, hist: &[i32]) {
        if self.counts.len() < hist.len() {
            self.counts.resize(hist.len(), 0);
        }
        for (c, v) in self.counts.iter_mut().zip(hist.iter()) {
            *c += *v as i64;
        }
    }
}

impl Default for KeyedSumOp {
    fn default() -> Self {
        Self::new()
    }
}

impl Operator for KeyedSumOp {
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn name(&self) -> &'static str {
        "keyed-sum"
    }

    fn cost(&self, batch: &Batch, cost: &CostModel) -> Time {
        batch.tuples * cost.keyed_tuple_ns
    }

    fn apply(&mut self, batch: Batch, _from: usize, out: &mut OpOutput) -> Result<()> {
        if let Some(hist) = &batch.hist {
            self.merge(hist);
        }
        self.total_tuples += batch.tuples;
        out.tuples_logged += batch.tuples;
        Ok(())
    }

    fn snapshot(&self) -> OpState {
        OpState::KeyedSum { counts: self.counts.clone(), total_tuples: self.total_tuples }
    }

    fn restore(&mut self, state: &OpState) {
        let OpState::KeyedSum { counts, total_tuples } = state else {
            panic!("keyed-sum op: mismatched snapshot {state:?}")
        };
        self.counts = counts.clone();
        self.total_tuples = *total_tuples;
    }
}

// ---------------------------------------------------------------------------

/// `countWindow(size, slide).sum(1)`: sliding window over per-slide
/// histograms; fires every slide tick once `window_slides` are buffered.
pub struct WindowedSumOp {
    pub window_slides: usize,
    pub compute: Option<SharedCompute>,
    /// Ring of completed slides (newest last).
    slides: VecDeque<Vec<i32>>,
    current: Vec<i32>,
    /// The slide vector recycled out of the ring: a slide expires every
    /// tick and a fresh `current` is needed every tick, so one spare keeps
    /// the ring allocation-free at steady state. Scratch — never
    /// checkpointed.
    spare: Vec<i32>,
    current_tuples: u64,
    pub total_tuples: u64,
    pub windows_fired: u64,
    /// Tuple count of the last fired window (inspectable).
    pub last_window_tuples: u64,
}

impl WindowedSumOp {
    pub fn new(window_slides: usize, compute: Option<SharedCompute>) -> Self {
        assert!(window_slides > 0);
        Self {
            window_slides,
            compute,
            slides: VecDeque::new(),
            current: Vec::new(),
            spare: Vec::new(),
            current_tuples: 0,
            total_tuples: 0,
            windows_fired: 0,
            last_window_tuples: 0,
        }
    }
}

impl Operator for WindowedSumOp {
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn name(&self) -> &'static str {
        "windowed-sum"
    }

    fn cost(&self, batch: &Batch, cost: &CostModel) -> Time {
        batch.tuples * cost.keyed_tuple_ns
    }

    fn apply(&mut self, batch: Batch, _from: usize, out: &mut OpOutput) -> Result<()> {
        if let Some(hist) = &batch.hist {
            if self.current.len() < hist.len() {
                self.current.resize(hist.len(), 0);
            }
            for (c, v) in self.current.iter_mut().zip(hist.iter()) {
                *c += v;
            }
        }
        self.current_tuples += batch.tuples;
        self.total_tuples += batch.tuples;
        out.tuples_logged += batch.tuples;
        Ok(())
    }

    fn on_tick(&mut self, _out: &mut OpOutput) -> Result<()> {
        // Close the current slide; the replacement reuses the capacity of
        // the slide that expired last tick (`spare`).
        let next = std::mem::take(&mut self.spare);
        let slide = std::mem::replace(&mut self.current, next);
        self.slides.push_back(slide);
        self.current_tuples = 0;
        while self.slides.len() > self.window_slides {
            let mut expired = self.slides.pop_front().expect("len checked");
            expired.clear();
            self.spare = expired;
        }
        if self.slides.len() == self.window_slides {
            // Fire: aggregate the window through the window_sum artifact
            // (real plane) or element-wise (sim plane histograms are empty).
            let filled: Vec<Vec<i32>> = self
                .slides
                .iter()
                .filter(|s| !s.is_empty())
                .cloned()
                .collect();
            let window = match (&self.compute, filled.is_empty()) {
                (Some(compute), false) => compute.window_sum(&filled)?,
                _ => crate::compute::native::window_sum(&filled),
            };
            self.last_window_tuples = window.iter().map(|&v| v as u64).sum();
            self.windows_fired += 1;
        }
        Ok(())
    }

    fn wants_ticks(&self) -> bool {
        true
    }

    fn snapshot(&self) -> OpState {
        OpState::WindowedSum {
            slides: self.slides.iter().cloned().collect(),
            current: self.current.clone(),
            current_tuples: self.current_tuples,
            total_tuples: self.total_tuples,
            windows_fired: self.windows_fired,
            last_window_tuples: self.last_window_tuples,
        }
    }

    fn restore(&mut self, state: &OpState) {
        let OpState::WindowedSum {
            slides,
            current,
            current_tuples,
            total_tuples,
            windows_fired,
            last_window_tuples,
        } = state
        else {
            panic!("windowed-sum op: mismatched snapshot {state:?}")
        };
        self.slides = slides.iter().cloned().collect();
        self.current = current.clone();
        self.current_tuples = *current_tuples;
        self.total_tuples = *total_tuples;
        self.windows_fired = *windows_fired;
        self.last_window_tuples = *last_window_tuples;
    }
}
