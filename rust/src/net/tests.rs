//! Unit tests for link serialisation and profiles.

use super::*;
use crate::config::NetworkProfile;

fn net() -> Network {
    Network::new(NetworkProfile::INFINIBAND, NetworkProfile::LOOPBACK)
}

#[test]
fn delivery_includes_wire_and_latency() {
    let mut n = net();
    let t = n.send(0, 0, 1, 125_000); // 125 kB at 12.5 GB/s = 10 us
    assert_eq!(t, 10_000 + NetworkProfile::INFINIBAND.latency_ns);
}

#[test]
fn messages_serialise_on_a_link() {
    let mut n = net();
    let t1 = n.send(0, 0, 1, 125_000);
    let t2 = n.send(0, 0, 1, 125_000); // queued behind the first
    assert_eq!(t2 - t1, 10_000);
}

#[test]
fn reverse_direction_is_a_separate_link() {
    let mut n = net();
    let fwd = n.send(0, 0, 1, 1_250_000);
    let rev = n.send(0, 1, 0, 1_250_000);
    assert_eq!(fwd, rev, "full-duplex: directions must not contend");
}

#[test]
fn link_frees_over_time() {
    let mut n = net();
    n.send(0, 0, 1, 125_000);
    // 50 us later the link is idle again: no queueing delay
    let t = n.send(50_000, 0, 1, 0);
    assert_eq!(t, 50_000 + NetworkProfile::INFINIBAND.latency_ns);
}

#[test]
fn loopback_for_same_node() {
    let mut n = net();
    let t = n.send(0, 3, 3, 1024);
    assert!(t < NetworkProfile::INFINIBAND.latency_ns, "loopback must be cheaper: {t}");
}

#[test]
fn control_messages_skip_serialisation() {
    let mut n = net();
    n.send(0, 0, 1, 10_000_000); // big transfer holds the link
    let ctl = n.send_control(0, 0, 1);
    assert_eq!(ctl, NetworkProfile::INFINIBAND.latency_ns);
}

#[test]
fn stats_accumulate() {
    let mut n = net();
    n.send(0, 0, 1, 100);
    n.send(0, 0, 1, 200);
    n.send(0, 2, 2, 999); // loopback
    assert_eq!(n.link_stats(0, 1), (2, 300));
    assert_eq!(n.cross_node_bytes(), 300);
}

#[test]
fn commodity_profile_queues_sooner() {
    let mut fast = net();
    let mut slow = Network::new(NetworkProfile::COMMODITY, NetworkProfile::LOOPBACK);
    let b = 1_250_000;
    assert!(slow.send(0, 0, 1, b) > fast.send(0, 0, 1, b));
}
