//! Network model: per-directed-link bandwidth serialisation + latency.
//!
//! The paper runs on 100 Gb/s Infiniband ("we avoid the networking
//! communication becoming a bottleneck", §V-A) but argues push-based
//! colocation matters *more* on commodity networks (§VII). Both profiles
//! are first-class here so the ablation benches can flip them.
//!
//! Each directed `(from, to)` node pair is a link with a serialisation
//! horizon: a message occupies the link for `bytes / bandwidth`, then
//! propagates for `latency`. Same-node traffic uses the loopback profile —
//! colocated storage and processing is the paper's whole premise, so the
//! distinction is load-bearing.

#[cfg(test)]
mod tests;

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::config::NetworkProfile;
use crate::sim::Time;

/// Node index in the cluster topology.
pub type NodeId = usize;

#[derive(Debug, Default)]
struct Link {
    /// Time the link becomes free to start serialising the next message.
    next_free: Time,
    messages: u64,
    bytes: u64,
}

/// The shared network blackboard.
#[derive(Debug)]
pub struct Network {
    profile: NetworkProfile,
    loopback: NetworkProfile,
    links: HashMap<(NodeId, NodeId), Link>,
}

/// Handle actors hold.
pub type SharedNetwork = Rc<RefCell<Network>>;

impl Network {
    pub fn new(profile: NetworkProfile, loopback: NetworkProfile) -> Self {
        Self { profile, loopback, links: HashMap::new() }
    }

    pub fn shared(profile: NetworkProfile, loopback: NetworkProfile) -> SharedNetwork {
        Rc::new(RefCell::new(Self::new(profile, loopback)))
    }

    /// Schedule a message of `bytes` from `from` to `to` starting at `now`;
    /// returns its delivery time. Mutates the link serialisation horizon —
    /// concurrent senders on one link queue behind each other, which is how
    /// "the network is the bottleneck" scenarios emerge.
    pub fn send(&mut self, now: Time, from: NodeId, to: NodeId, bytes: u64) -> Time {
        let profile = if from == to { self.loopback } else { self.profile };
        let link = self.links.entry((from, to)).or_default();
        let start = link.next_free.max(now);
        let wire = (bytes as f64 / profile.bandwidth_bps * 1e9) as Time;
        link.next_free = start + wire;
        link.messages += 1;
        link.bytes += bytes;
        link.next_free + profile.latency_ns
    }

    /// Delivery time without occupying the link (control messages whose
    /// payload is negligible: acks, notifications, subscribe).
    pub fn send_control(&mut self, now: Time, from: NodeId, to: NodeId) -> Time {
        let profile = if from == to { self.loopback } else { self.profile };
        now + profile.latency_ns
    }

    /// Total messages and bytes carried by `(from, to)`.
    pub fn link_stats(&self, from: NodeId, to: NodeId) -> (u64, u64) {
        self.links
            .get(&(from, to))
            .map(|l| (l.messages, l.bytes))
            .unwrap_or((0, 0))
    }

    /// Bytes carried by all non-loopback links.
    pub fn cross_node_bytes(&self) -> u64 {
        self.links
            .iter()
            .filter(|((f, t), _)| f != t)
            .map(|(_, l)| l.bytes)
            .sum()
    }

    pub fn profile(&self) -> NetworkProfile {
        self.profile
    }
}
