//! DataStream-like pipeline builder, mirroring the paper's Listings 1 & 2.
//!
//! A [`Pipeline`] is the logical dataflow: a source stage (the consumers,
//! `sourceParallelism = Nc`) followed by operator stages with their own
//! parallelism (`mapParallelism = Nmap`). The launcher materialises it into
//! [`crate::worker::OperatorTask`] actors and wires the sources to stage 0.
//!
//! ```
//! use zettastream::pipeline::{Pipeline, OpKind};
//! // Listing 1 (count + filter):
//! let p = Pipeline::source(4).flat_map(OpKind::Filter, 8).build();
//! assert_eq!(p.stages.len(), 1);
//! // Listing 2 (windowed word count):
//! let p = Pipeline::source(4)
//!     .flat_map(OpKind::Tokenizer, 8)
//!     .key_by_windowed_sum(8)
//!     .build();
//! assert_eq!(p.stages.len(), 2);
//! ```

#[cfg(test)]
mod tests;

use crate::config::Workload;

/// Operator kinds the builder can place (Table II's columns).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    /// Iterate + count (`RTLogger`).
    Count,
    /// Grep filter + count.
    Filter,
    /// Word-count tokenizer (emits a keyed exchange).
    Tokenizer,
    /// Keyed `sum(1)`.
    KeyedSum,
    /// Sliding-window keyed sum.
    WindowedSum,
}

/// One operator stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Stage {
    pub op: OpKind,
    pub parallelism: usize,
}

/// The logical dataflow.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Pipeline {
    /// `sourceParallelism` (= `Nc`).
    pub source_parallelism: usize,
    pub stages: Vec<Stage>,
}

impl Pipeline {
    /// Start a builder with `Nc` source tasks.
    pub fn source(parallelism: usize) -> PipelineBuilder {
        assert!(parallelism > 0);
        PipelineBuilder {
            pipeline: Pipeline { source_parallelism: parallelism, stages: Vec::new() },
        }
    }

    /// The pipeline for a paper workload (Listings 1 & 2 verbatim).
    pub fn for_workload(workload: Workload, nc: usize, nmap: usize) -> Pipeline {
        match workload {
            Workload::Count => Pipeline::source(nc).flat_map(OpKind::Count, nmap).build(),
            Workload::Filter => Pipeline::source(nc).flat_map(OpKind::Filter, nmap).build(),
            Workload::WordCount => Pipeline::source(nc)
                .flat_map(OpKind::Tokenizer, nmap)
                .key_by_sum(nmap)
                .build(),
            Workload::WindowedWordCount => Pipeline::source(nc)
                .flat_map(OpKind::Tokenizer, nmap)
                .key_by_windowed_sum(nmap)
                .build(),
        }
    }

    /// Total operator tasks (slots used beyond the sources).
    pub fn task_count(&self) -> usize {
        self.stages.iter().map(|s| s.parallelism).sum()
    }

    /// Slots the deployment occupies (sources + operator tasks), to compare
    /// against `NFs`.
    pub fn slots_used(&self) -> usize {
        self.source_parallelism + self.task_count()
    }

    /// Validate stage composition (exchange stages follow a tokenizer...).
    pub fn validate(&self) -> Result<(), String> {
        if self.stages.is_empty() {
            return Err("a pipeline needs at least one operator stage".into());
        }
        for (i, stage) in self.stages.iter().enumerate() {
            if stage.parallelism == 0 {
                return Err(format!("stage {i} has zero parallelism"));
            }
            match stage.op {
                OpKind::KeyedSum | OpKind::WindowedSum => {
                    let ok = i > 0 && self.stages[i - 1].op == OpKind::Tokenizer;
                    if !ok {
                        return Err(format!(
                            "stage {i}: keyed aggregation requires a tokenizer (keyBy) upstream"
                        ));
                    }
                }
                OpKind::Tokenizer => {
                    let last = i + 1 == self.stages.len();
                    let next_keyed = !last
                        && matches!(self.stages[i + 1].op, OpKind::KeyedSum | OpKind::WindowedSum);
                    if !last && !next_keyed {
                        return Err(format!("stage {i}: tokenizer must feed a keyed stage"));
                    }
                }
                OpKind::Count | OpKind::Filter => {
                    if i + 1 != self.stages.len() {
                        return Err(format!("stage {i}: {:?} is terminal (RTLogger)", stage.op));
                    }
                }
            }
        }
        Ok(())
    }
}

/// Fluent builder.
pub struct PipelineBuilder {
    pipeline: Pipeline,
}

impl PipelineBuilder {
    /// Append a flatMap stage (`.flatMap(op).setParallelism(n)`).
    pub fn flat_map(mut self, op: OpKind, parallelism: usize) -> Self {
        self.pipeline.stages.push(Stage { op, parallelism });
        self
    }

    /// `.keyBy(f0).sum(1)` after a tokenizer.
    pub fn key_by_sum(self, parallelism: usize) -> Self {
        self.flat_map(OpKind::KeyedSum, parallelism)
    }

    /// `.keyBy(f0).countWindow(size, slide).sum(1)` after a tokenizer.
    pub fn key_by_windowed_sum(self, parallelism: usize) -> Self {
        self.flat_map(OpKind::WindowedSum, parallelism)
    }

    pub fn build(self) -> Pipeline {
        self.pipeline.validate().expect("invalid pipeline");
        self.pipeline
    }
}
