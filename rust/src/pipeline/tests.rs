//! Pipeline builder tests.

use super::*;

#[test]
fn count_pipeline_matches_listing1() {
    let p = Pipeline::for_workload(Workload::Count, 4, 8);
    assert_eq!(p.source_parallelism, 4);
    assert_eq!(p.stages, vec![Stage { op: OpKind::Count, parallelism: 8 }]);
    assert_eq!(p.slots_used(), 12);
}

#[test]
fn wordcount_pipeline_matches_listing2() {
    let p = Pipeline::for_workload(Workload::WordCount, 2, 8);
    assert_eq!(
        p.stages,
        vec![
            Stage { op: OpKind::Tokenizer, parallelism: 8 },
            Stage { op: OpKind::KeyedSum, parallelism: 8 },
        ]
    );
    assert_eq!(p.task_count(), 16);
}

#[test]
fn windowed_wordcount_uses_windowed_sum() {
    let p = Pipeline::for_workload(Workload::WindowedWordCount, 1, 8);
    assert_eq!(p.stages[1].op, OpKind::WindowedSum);
}

#[test]
fn builder_is_fluent() {
    let p = Pipeline::source(2).flat_map(OpKind::Filter, 4).build();
    assert_eq!(p.source_parallelism, 2);
    assert_eq!(p.stages.len(), 1);
}

#[test]
fn validate_rejects_keyed_without_tokenizer() {
    let p = Pipeline {
        source_parallelism: 1,
        stages: vec![Stage { op: OpKind::KeyedSum, parallelism: 2 }],
    };
    assert!(p.validate().is_err());
}

#[test]
fn validate_rejects_tokenizer_feeding_count() {
    let p = Pipeline {
        source_parallelism: 1,
        stages: vec![
            Stage { op: OpKind::Tokenizer, parallelism: 2 },
            Stage { op: OpKind::Count, parallelism: 2 },
        ],
    };
    assert!(p.validate().is_err());
}

#[test]
fn validate_rejects_nonterminal_count() {
    let p = Pipeline {
        source_parallelism: 1,
        stages: vec![
            Stage { op: OpKind::Count, parallelism: 2 },
            Stage { op: OpKind::Count, parallelism: 2 },
        ],
    };
    assert!(p.validate().is_err());
}

#[test]
fn validate_rejects_empty_and_zero_parallelism() {
    let p = Pipeline { source_parallelism: 1, stages: vec![] };
    assert!(p.validate().is_err());
    let p = Pipeline {
        source_parallelism: 1,
        stages: vec![Stage { op: OpKind::Count, parallelism: 0 }],
    };
    assert!(p.validate().is_err());
}

#[test]
#[should_panic(expected = "invalid pipeline")]
fn build_panics_on_invalid() {
    Pipeline::source(1).flat_map(OpKind::KeyedSum, 2).build();
}
