//! Shared wire/data types: chunks, records, RPCs, and the engine message.
//!
//! Everything the actors exchange is one [`Msg`] enum — the DES engine is
//! generic, but the cluster instantiates `Engine<Msg>`. The data unit is the
//! [`Chunk`]: the record-framed byte block a producer seals and appends, a
//! pull RPC returns, and the push thread copies into a shared object.

use std::rc::Rc;

use crate::config::FaultKind;
use crate::sim::ActorId;

/// Global partition index within the (single) stream topic.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PartitionId(pub usize);

impl std::fmt::Display for PartitionId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "p{}", self.0)
    }
}

/// Offset within a partition log, in **chunks** (the broker's append unit —
/// the paper's record offsets are chunk-aligned on both the pull and push
/// paths, so chunk granularity loses nothing).
pub type ChunkOffset = u64;

/// Identifier of a shared-memory object slot (plasma store).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ObjectId {
    /// Owning subscription.
    pub sub: SubId,
    /// Slot index within the subscription's object pool.
    pub slot: usize,
}

/// Push subscription id (one per worker-local source group).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SubId(pub usize);

/// Chunk payload: real bytes or byte/record accounting (DESIGN.md §2.5).
#[derive(Debug, Clone)]
pub enum Payload {
    /// Record-framed bytes: `records × record_size`, records back to back.
    /// `Rc` — cloning a chunk shares the buffer, exactly like the paper's
    /// shared-pointer hand-off (the engine is single-threaded).
    Real(Rc<Vec<u8>>),
    /// Accounting-only payload for the long figure sweeps.
    Sim,
}

impl Payload {
    pub fn is_real(&self) -> bool {
        matches!(self, Payload::Real(_))
    }
}

/// The unit of ingestion and consumption.
#[derive(Debug, Clone)]
pub struct Chunk {
    /// Records in this chunk.
    pub records: u32,
    /// Fixed per-record size (bytes) — the benchmarks use fixed `RecS`.
    pub record_size: u32,
    /// Payload (real framing or accounting).
    pub payload: Payload,
}

impl Chunk {
    /// Payload size in bytes.
    pub fn bytes(&self) -> u64 {
        self.records as u64 * self.record_size as u64
    }

    /// Accounting-only chunk.
    pub fn sim(records: u32, record_size: u32) -> Self {
        Chunk { records, record_size, payload: Payload::Sim }
    }

    /// Real chunk; `data.len()` must equal `records * record_size`.
    pub fn real(records: u32, record_size: u32, data: Rc<Vec<u8>>) -> Self {
        debug_assert_eq!(data.len() as u64, records as u64 * record_size as u64);
        Chunk { records, record_size, payload: Payload::Real(data) }
    }
}

/// A chunk stamped with its partition position (what read paths return).
#[derive(Debug, Clone)]
pub struct StampedChunk {
    pub partition: PartitionId,
    pub offset: ChunkOffset,
    pub chunk: Chunk,
}

// ---------------------------------------------------------------------------
// RPCs
// ---------------------------------------------------------------------------

/// Monotone per-client RPC id (for tracing; uniqueness is per client).
pub type RpcId = u64;

/// Request kinds served by the broker frontend (paper §IV-A).
#[derive(Debug, Clone)]
pub enum RpcKind {
    /// Producer append: one sealed chunk per partition (`ReqS` total).
    Append { chunks: Vec<(PartitionId, Chunk)> },
    /// Pull-based consumer read: per-partition resume offsets, up to
    /// `max_bytes` (the consumer `CS`) returned **per partition**.
    Pull { assignments: Vec<(PartitionId, ChunkOffset)>, max_bytes: u64 },
    /// Push-based source group subscription: the single RPC of the paper's
    /// Step 1. One entry per local source task: its partitions + offsets.
    PushSubscribe { sources: Vec<PushSourceSpec> },
    /// Tear down one push subscription (the hybrid source falling back to
    /// pulling). The ack returns the broker-managed cursors so the client
    /// resumes pulling exactly where the push path left off.
    PushUnsubscribe { sub: SubId },
    /// Shared-memory write-path registration (the push-source idea applied
    /// to ingestion): the single RPC a colocated producer issues before
    /// filling plasma objects directly.
    WriteSubscribe { producer: WriteProducerSpec },
    /// Checkpoint coordinator commits a completed epoch: `cursors` are the
    /// per-partition source restart positions of the epoch's snapshots.
    /// Committed offsets become the floor for watermark log trimming —
    /// retention may never pass the last restorable point.
    CommitCheckpoint { epoch: u64, cursors: Vec<(PartitionId, ChunkOffset)> },
    /// A colocated producer sealed shared object `id`: append its chunks to
    /// the partition logs and release the buffer. The payload never crosses
    /// the dispatcher — only this control notification does.
    SealObject { id: ObjectId },
    /// Primary -> backup replication of one append (Replication = 2).
    Replicate { bytes: u64, chunks: u32 },
}

/// One colocated producer's write-side registration.
#[derive(Debug, Clone)]
pub struct WriteProducerSpec {
    /// Producer actor the broker acks seals to.
    pub producer_actor: ActorId,
    /// Partitions this producer will append to (validated up front).
    pub partitions: Vec<PartitionId>,
    /// Object pool size (the write-side backpressure window).
    pub objects: usize,
    /// Object capacity in bytes (one producer request, `ReqS`).
    pub object_bytes: u64,
}

/// One push source task's registration.
#[derive(Debug, Clone)]
pub struct PushSourceSpec {
    /// Actor to notify when objects fill.
    pub source_actor: ActorId,
    /// Partitions this source consumes exclusively.
    pub assignments: Vec<(PartitionId, ChunkOffset)>,
    /// Object pool size (backpressure window) for this source.
    pub objects: usize,
    /// Object capacity in bytes (the push-path consumer chunk size).
    pub object_bytes: u64,
}

/// Responses the broker sends back.
#[derive(Debug, Clone)]
pub enum RpcReply {
    AppendAck { records: u64, bytes: u64 },
    /// Pull result; `chunks` may be empty (consumer caught up). `trims`
    /// reports every requested partition whose offset fell below the
    /// retention floor as `(partition, floor)` — the consumer recovers by
    /// skipping to the floor and counting the gap, instead of wedging on a
    /// hard error (checkpoint-commit floors make this rare but a torn-down
    /// push subscription's cursors stop pinning retention, so a hybrid
    /// fallback can still land behind the trim point).
    PullData { chunks: Vec<StampedChunk>, trims: Vec<(PartitionId, ChunkOffset)> },
    SubscribeAck { sub: SubId },
    /// Subscription removed; `cursors` are the partitions' resume offsets
    /// (they already account for every object the broker gathered, so the
    /// client must still drain in-flight `ObjectReady` notifications).
    UnsubscribeAck { sub: SubId, cursors: Vec<(PartitionId, ChunkOffset)> },
    /// Write-side registration accepted: the producer's object pool.
    WriteSubscribeAck { sub: SubId },
    /// Sealed object appended (and replicated, if configured); its buffer
    /// is back in the free pool by the time this arrives.
    SealAck { records: u64, bytes: u64 },
    ReplicateAck,
    /// Checkpoint epoch recorded as the new retention floor.
    CommitAck { epoch: u64 },
    /// Request refused (unknown partition, bad offset...). Carried instead
    /// of panicking so fault-injection tests can exercise client handling.
    Error { reason: String },
}

/// Full request envelope delivered to a broker dispatcher.
#[derive(Debug, Clone)]
pub struct RpcRequest {
    pub id: RpcId,
    /// Where the reply goes.
    pub reply_to: ActorId,
    /// Origin node (network path selection).
    pub from_node: usize,
    pub kind: RpcKind,
}

/// Full reply envelope.
#[derive(Debug, Clone)]
pub struct RpcEnvelope {
    pub id: RpcId,
    pub reply: RpcReply,
}

// ---------------------------------------------------------------------------
// Dataflow between worker tasks
// ---------------------------------------------------------------------------

/// A batch of tuples flowing between operator tasks (one source chunk or
/// one shared object's worth, or a keyed sub-batch after an exchange).
#[derive(Debug, Clone)]
pub struct Batch {
    /// Upstream task index (for credit return).
    pub from_task: usize,
    /// Tuple count in the batch.
    pub tuples: u64,
    /// Payload bytes represented (accounting).
    pub bytes: u64,
    /// Real chunks, when the data plane is real.
    pub chunks: Vec<Chunk>,
    /// Keyed-histogram carry (real word-count path): bucket -> count.
    pub hist: Option<Rc<Vec<i32>>>,
    /// Sender's recovery incarnation. Stamped at send time (operators build
    /// batches with 0); a receiver drops batches from an older incarnation —
    /// they were in flight when a fault rolled the pipeline back and their
    /// contents will be replayed from the checkpoint cursors.
    pub inc: u64,
}

// ---------------------------------------------------------------------------
// The engine message
// ---------------------------------------------------------------------------

/// Every event in the simulated cluster.
#[derive(Debug, Clone)]
pub enum Msg {
    /// An RPC request arriving at a broker dispatcher.
    Rpc(RpcRequest),
    /// An RPC reply arriving back at the client.
    Reply(RpcEnvelope),
    /// Core-pool job completion inside an actor (tag = owner-defined).
    JobDone(u64),
    /// Generic timer with owner-defined tag.
    Timer(u64),
    /// Plasma: object `id` was filled and sealed; records/bytes describe
    /// its content (chunks are read from the store by the source).
    ObjectReady { id: ObjectId },
    /// Plasma: source finished with object `id`; broker may reuse it.
    ObjectFreed { id: ObjectId },
    /// Broker-internal: new data appended to a partition some push
    /// subscription watches — wake the push thread.
    DataAvailable,
    /// Dataflow: a batch pushed into a task's input queue.
    Data(Batch),
    /// Dataflow: downstream returns one queue credit to `from_task`.
    /// `inc` is the sender's recovery incarnation: a credit for a batch
    /// that predates a rollback is dropped (ledgers reset on restore).
    Credit { to_upstream_task: usize, inc: u64 },
    /// Producer resumes after generating records (tag = request id).
    GenDone(u64),
    /// Checkpoint: the coordinator asks a source to inject barrier `epoch`
    /// into its output stream at the next clean point.
    BarrierInject { epoch: u64 },
    /// Checkpoint: an aligned barrier flowing in-band between tasks — sent
    /// on a channel after the last pre-barrier batch, never overtaking data
    /// (barriers carry no payload and consume no credits).
    Barrier { epoch: u64, from_task: usize },
    /// Checkpoint: participant `from` wrote its epoch snapshot to the
    /// shared checkpoint store.
    BarrierAck { epoch: u64, from: ActorId },
    /// Fault injection: the receiving actor "crashes" — it wipes its
    /// volatile state, reports the failure and goes silent until restored.
    Fault { kind: FaultKind },
    /// Recovery: the failure detector's notice to the coordinator.
    FailureDetected { from: ActorId },
    /// Recovery: roll back to the latest completed checkpoint. `inc` is the
    /// new incarnation every participant adopts; barriers with
    /// `epoch <= epoch_floor` are stale and must be ignored.
    Restore { inc: u64, epoch_floor: u64 },
    /// Recovery: participant `from` finished restoring and resumed.
    RestoreAck { from: ActorId },
}
