//! Shared wire/data types: chunks, records, RPCs, and the engine message.
//!
//! Everything the actors exchange is one [`Msg`] enum — the DES engine is
//! generic, but the cluster instantiates `Engine<Msg>`. The data unit is the
//! [`Chunk`]: the record-framed byte block a producer seals and appends, a
//! pull RPC returns, and the push thread copies into a shared object.
//!
//! ## Memory discipline
//!
//! `Msg` is the hottest type in the simulator: every event the engine
//! queues, sifts through the heap and delivers is one `Msg` by value. Two
//! rules keep it within a single cache line (≤ 64 bytes, statically
//! asserted below):
//!
//! * the fat RPC envelopes ([`RpcRequest`], [`RpcEnvelope`]) are **boxed**
//!   — an RPC happens once per request, a heap sift happens `O(log n)`
//!   times per event, so the indirection is paid exactly where it is
//!   cheapest. Build them with [`Msg::rpc`] / [`Msg::reply`];
//! * the dataflow [`Batch`] is **inline** (no per-hop box) but carries its
//!   chunks as a [`ChunkList`]: the common one-chunk batch stores the
//!   chunk in place, multi-chunk batches share an `Rc<[Chunk]>` — cloning
//!   a batch at a chained-operator hop bumps a refcount instead of
//!   cloning a `Vec`.
//!
//! Payload bytes themselves are always behind `Rc` ([`Payload::Real`]) and
//! are *materialised* exactly once, by the producer's generator; every
//! later hand-off (broker log append, segment-resident pull replies,
//! plasma object fills, batch hops) shares the pointer. A debug-side
//! counter ([`real_payload_allocs`]) counts materialisations so tests can
//! assert the zero-copy invariant end to end.

use std::cell::Cell;
use std::rc::Rc;

use crate::config::FaultKind;
use crate::sim::{ActorId, Time};

/// Global partition index within the (single) stream topic.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PartitionId(pub usize);

impl std::fmt::Display for PartitionId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "p{}", self.0)
    }
}

/// Offset within a partition log, in **chunks** (the broker's append unit —
/// the paper's record offsets are chunk-aligned on both the pull and push
/// paths, so chunk granularity loses nothing).
pub type ChunkOffset = u64;

/// Identifier of a shared-memory object slot (plasma store).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ObjectId {
    /// Owning subscription.
    pub sub: SubId,
    /// Slot index within the subscription's object pool.
    pub slot: usize,
}

/// Push subscription id (one per worker-local source group).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SubId(pub usize);

thread_local! {
    /// Count of real payload buffers materialised on this thread (every
    /// [`Chunk::real`] call). The zero-copy regression tests compare this
    /// against the number of chunks producers generated: consume paths and
    /// operator hops must never add to it.
    static REAL_PAYLOAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// Real payload buffers materialised on this thread so far (see
/// [`Chunk::real`]). Monotone; tests snapshot it before/after a run.
pub fn real_payload_allocs() -> u64 {
    REAL_PAYLOAD_ALLOCS.with(|c| c.get())
}

/// Chunk payload: real bytes or byte/record accounting (DESIGN.md §2.5).
#[derive(Debug, Clone)]
pub enum Payload {
    /// Record-framed bytes: `records × record_size`, records back to back.
    /// `Rc` — cloning a chunk shares the buffer, exactly like the paper's
    /// shared-pointer hand-off (the engine is single-threaded).
    Real(Rc<Vec<u8>>),
    /// Accounting-only payload for the long figure sweeps.
    Sim,
}

impl Payload {
    pub fn is_real(&self) -> bool {
        matches!(self, Payload::Real(_))
    }

    /// The shared buffer, when real — for pointer-identity assertions
    /// (`Rc::ptr_eq`) in the zero-copy tests.
    pub fn buffer(&self) -> Option<&Rc<Vec<u8>>> {
        match self {
            Payload::Real(data) => Some(data),
            Payload::Sim => None,
        }
    }
}

/// The unit of ingestion and consumption.
#[derive(Debug, Clone)]
pub struct Chunk {
    /// Records in this chunk.
    pub records: u32,
    /// Fixed per-record size (bytes) — the benchmarks use fixed `RecS`.
    pub record_size: u32,
    /// Payload (real framing or accounting).
    pub payload: Payload,
}

impl Chunk {
    /// Payload size in bytes.
    pub fn bytes(&self) -> u64 {
        self.records as u64 * self.record_size as u64
    }

    /// Accounting-only chunk.
    pub fn sim(records: u32, record_size: u32) -> Self {
        Chunk { records, record_size, payload: Payload::Sim }
    }

    /// Real chunk; `data.len()` must equal `records * record_size`.
    ///
    /// This is the **only** place real payloads are born — every consumer
    /// of a real chunk shares the `Rc`d buffer. The materialisation
    /// counter ([`real_payload_allocs`]) backs the zero-copy tests.
    pub fn real(records: u32, record_size: u32, data: Rc<Vec<u8>>) -> Self {
        debug_assert_eq!(data.len() as u64, records as u64 * record_size as u64);
        REAL_PAYLOAD_ALLOCS.with(|c| c.set(c.get() + 1));
        Chunk { records, record_size, payload: Payload::Real(data) }
    }
}

/// A chunk stamped with its partition position (what read paths return).
#[derive(Debug, Clone)]
pub struct StampedChunk {
    pub partition: PartitionId,
    pub offset: ChunkOffset,
    pub chunk: Chunk,
}

// ---------------------------------------------------------------------------
// RPCs
// ---------------------------------------------------------------------------

/// Monotone per-client RPC id (for tracing; uniqueness is per client).
pub type RpcId = u64;

/// Request kinds served by the broker frontend (paper §IV-A).
#[derive(Debug, Clone)]
pub enum RpcKind {
    /// Producer append: one sealed chunk per partition (`ReqS` total).
    /// `produced_at` stamps the request's generation time when the latency
    /// tracer sampled it ([`crate::obs::Tracer::sample_produced`]); `None`
    /// whenever tracing is off — the envelope is boxed, so the field costs
    /// nothing on the `Msg` budget.
    Append { chunks: Vec<(PartitionId, Chunk)>, produced_at: Option<Time> },
    /// Pull-based consumer read: per-partition resume offsets, up to
    /// `max_bytes` (the consumer `CS`) returned **per partition**.
    Pull { assignments: Vec<(PartitionId, ChunkOffset)>, max_bytes: u64 },
    /// Push-based source group subscription: the single RPC of the paper's
    /// Step 1. One entry per local source task: its partitions + offsets.
    PushSubscribe { sources: Vec<PushSourceSpec> },
    /// Tear down one push subscription (the hybrid source falling back to
    /// pulling). The ack returns the broker-managed cursors so the client
    /// resumes pulling exactly where the push path left off.
    PushUnsubscribe { sub: SubId },
    /// Shared-memory write-path registration (the push-source idea applied
    /// to ingestion): the single RPC a colocated producer issues before
    /// filling plasma objects directly.
    WriteSubscribe { producer: WriteProducerSpec },
    /// Checkpoint coordinator commits a completed epoch: `cursors` are the
    /// per-partition source restart positions of the epoch's snapshots.
    /// Committed offsets become the floor for watermark log trimming —
    /// retention may never pass the last restorable point.
    CommitCheckpoint { epoch: u64, cursors: Vec<(PartitionId, ChunkOffset)> },
    /// A colocated producer sealed shared object `id`: append its chunks to
    /// the partition logs and release the buffer. The payload never crosses
    /// the dispatcher — only this control notification does. `produced_at`
    /// is the sampled generation stamp (see [`RpcKind::Append`]).
    SealObject { id: ObjectId, produced_at: Option<Time> },
    /// Primary -> backup replication of one append (Replication = 2).
    Replicate { bytes: u64, chunks: u32 },
    /// Shard primary -> replica replication of one append: the full
    /// stamped chunks with **primary-assigned offsets**, so every replica
    /// log is byte-identical regardless of its own worker-pool completion
    /// order (replicas apply through a per-partition reorder buffer).
    /// `origin` carries the producing client's identity (`reply_to`, rpc
    /// id) so the replica records the append in its idempotence table —
    /// if the primary dies and the producer retransmits the same rpc id
    /// to the promoted replica, it is re-acked, never re-appended.
    ShardReplicate { chunks: Vec<StampedChunk>, origin: Option<(ActorId, RpcId)> },
    /// Coordinator -> broker: stop serving `partitions` as primary under
    /// the table that will carry `epoch`. The broker acks only once every
    /// in-flight replication for those partitions has been acknowledged —
    /// the drain half of the hand-off.
    ShardFreeze { epoch: u64, partitions: Vec<PartitionId> },
    /// Coordinator -> broker: start serving `partitions` as primary at
    /// assignment `epoch` — the resume half of the hand-off. The new
    /// primary's log is already complete (it was a replica).
    ShardPromote { epoch: u64, partitions: Vec<PartitionId> },
    /// Coordinator -> broker: failure-detector liveness probe. A live
    /// broker acks immediately ([`RpcReply::HeartbeatAck`]); a dead one
    /// drops it, and the missed lease is the detection signal.
    Heartbeat,
    /// Coordinator -> surviving broker: broker `dead` was declared dead;
    /// `table` is the rebuilt assignment (epoch bumped once, every replica
    /// set shrunk past the corpse) and `gained` the partitions this broker
    /// now serves as primary (often empty — every survivor still gets the
    /// roster so it purges in-flight replication held on the dead peer and
    /// shrinks its quorum arithmetic). See `crate::shard`'s fail-over docs.
    ShardFailover {
        epoch: u64,
        dead: usize,
        table: crate::shard::ShardTable,
        gained: Vec<PartitionId>,
    },
}

/// One colocated producer's write-side registration.
#[derive(Debug, Clone)]
pub struct WriteProducerSpec {
    /// Producer actor the broker acks seals to.
    pub producer_actor: ActorId,
    /// Partitions this producer will append to (validated up front).
    pub partitions: Vec<PartitionId>,
    /// Object pool size (the write-side backpressure window).
    pub objects: usize,
    /// Object capacity in bytes (one producer request, `ReqS`).
    pub object_bytes: u64,
}

/// One push source task's registration.
#[derive(Debug, Clone)]
pub struct PushSourceSpec {
    /// Actor to notify when objects fill.
    pub source_actor: ActorId,
    /// Partitions this source consumes exclusively.
    pub assignments: Vec<(PartitionId, ChunkOffset)>,
    /// Object pool size (backpressure window) for this source.
    pub objects: usize,
    /// Object capacity in bytes (the push-path consumer chunk size).
    pub object_bytes: u64,
}

/// Responses the broker sends back.
#[derive(Debug, Clone)]
pub enum RpcReply {
    AppendAck { records: u64, bytes: u64 },
    /// Pull result; `chunks` may be empty (consumer caught up). `trims`
    /// reports every requested partition whose offset fell below the
    /// retention floor as `(partition, floor)` — the consumer recovers by
    /// skipping to the floor and counting the gap, instead of wedging on a
    /// hard error (checkpoint-commit floors make this rare but a torn-down
    /// push subscription's cursors stop pinning retention, so a hybrid
    /// fallback can still land behind the trim point).
    PullData { chunks: Vec<StampedChunk>, trims: Vec<(PartitionId, ChunkOffset)> },
    SubscribeAck { sub: SubId },
    /// Subscription removed; `cursors` are the partitions' resume offsets
    /// (they already account for every object the broker gathered, so the
    /// client must still drain in-flight `ObjectReady` notifications).
    UnsubscribeAck { sub: SubId, cursors: Vec<(PartitionId, ChunkOffset)> },
    /// Write-side registration accepted: the producer's object pool.
    WriteSubscribeAck { sub: SubId },
    /// Sealed object appended (and replicated, if configured); its buffer
    /// is back in the free pool by the time this arrives.
    SealAck { records: u64, bytes: u64 },
    ReplicateAck,
    /// Checkpoint epoch recorded as the new retention floor.
    CommitAck { epoch: u64 },
    /// The broker is not (or no longer) the primary for a partition the
    /// request touched: the client's cached assignment table is stale.
    /// `epoch` is the broker's current assignment epoch — the client
    /// refreshes from the coordinator's published table and retries.
    WrongShard { epoch: u64 },
    /// Drain complete: the broker stopped serving the frozen partitions
    /// and every in-flight replication for them is acknowledged.
    FreezeAck { epoch: u64 },
    /// The broker now serves the promoted partitions at `epoch`.
    PromoteAck { epoch: u64 },
    /// Liveness probe answered (the broker's current assignment epoch
    /// rides along for the coordinator's sanity checks).
    HeartbeatAck { epoch: u64 },
    /// Fail-over roster installed: dead peer purged, held quorums
    /// released, gained partitions now served at `epoch`.
    FailoverAck { epoch: u64 },
    /// Request refused (unknown partition, bad offset...). Carried instead
    /// of panicking so fault-injection tests can exercise client handling.
    Error { reason: String },
}

/// Full request envelope delivered to a broker dispatcher. Boxed inside
/// [`Msg::Rpc`] — build with [`Msg::rpc`].
#[derive(Debug, Clone)]
pub struct RpcRequest {
    pub id: RpcId,
    /// Where the reply goes.
    pub reply_to: ActorId,
    /// Origin node (network path selection).
    pub from_node: usize,
    pub kind: RpcKind,
}

/// Full reply envelope. Boxed inside [`Msg::Reply`] — build with
/// [`Msg::reply`].
#[derive(Debug, Clone)]
pub struct RpcEnvelope {
    pub id: RpcId,
    pub reply: RpcReply,
}

// ---------------------------------------------------------------------------
// Dataflow between worker tasks
// ---------------------------------------------------------------------------

/// The chunks a [`Batch`] carries. Batches between operator tasks are the
/// hottest hand-off in the system; this list keeps that hand-off pointer-
/// sized:
///
/// * [`ChunkList::Empty`] — accounting-only batches (keyed exchanges,
///   sim-plane tokenizer output);
/// * [`ChunkList::One`] — the dominant case: one source chunk per batch,
///   stored inline (no heap allocation at all);
/// * [`ChunkList::Shared`] — multi-chunk batches share one `Rc<[Chunk]>`,
///   so cloning the batch is a refcount bump, never a `Vec` clone.
#[derive(Debug, Clone, Default)]
pub enum ChunkList {
    #[default]
    Empty,
    One(Chunk),
    Shared(Rc<[Chunk]>),
}

impl ChunkList {
    /// View as a slice (zero-cost for all three representations).
    pub fn as_slice(&self) -> &[Chunk] {
        match self {
            ChunkList::Empty => &[],
            ChunkList::One(chunk) => std::slice::from_ref(chunk),
            ChunkList::Shared(chunks) => chunks,
        }
    }

    pub fn len(&self) -> usize {
        self.as_slice().len()
    }

    pub fn is_empty(&self) -> bool {
        matches!(self, ChunkList::Empty)
    }

    pub fn iter(&self) -> std::slice::Iter<'_, Chunk> {
        self.as_slice().iter()
    }
}

impl std::ops::Deref for ChunkList {
    type Target = [Chunk];

    fn deref(&self) -> &[Chunk] {
        self.as_slice()
    }
}

impl<'a> IntoIterator for &'a ChunkList {
    type Item = &'a Chunk;
    type IntoIter = std::slice::Iter<'a, Chunk>;

    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

impl From<Vec<Chunk>> for ChunkList {
    /// One chunk stays inline; several share an `Rc<[Chunk]>`.
    fn from(mut chunks: Vec<Chunk>) -> Self {
        match chunks.len() {
            0 => ChunkList::Empty,
            1 => ChunkList::One(chunks.pop().expect("len checked")),
            _ => ChunkList::Shared(chunks.into()),
        }
    }
}

/// A batch of tuples flowing between operator tasks (one source chunk or
/// one shared object's worth, or a keyed sub-batch after an exchange).
///
/// Kept at 56 bytes so [`Msg::Data`] fits the 64-byte `Msg` budget: the
/// chunks ride in a [`ChunkList`] (inline or shared, never a per-hop
/// `Vec`), and there is deliberately no redundant byte count — batch
/// payload bytes are derivable from the chunks ([`Batch::chunk_bytes`])
/// and nothing on the hot path needs them.
#[derive(Debug, Clone)]
pub struct Batch {
    /// Upstream task index (for credit return).
    pub from_task: usize,
    /// Tuple count in the batch.
    pub tuples: u64,
    /// Real chunks, when the data plane is real.
    pub chunks: ChunkList,
    /// Keyed-histogram carry (real word-count path): bucket -> count.
    pub hist: Option<Rc<Vec<i32>>>,
    /// Sender's recovery incarnation. Stamped at send time (operators build
    /// batches with 0); a receiver drops batches from an older incarnation —
    /// they were in flight when a fault rolled the pipeline back and their
    /// contents will be replayed from the checkpoint cursors.
    pub inc: u64,
}

impl Batch {
    /// Payload bytes represented by the carried chunks (accounting only —
    /// not stored, the hot path never reads it).
    pub fn chunk_bytes(&self) -> u64 {
        self.chunks.iter().map(Chunk::bytes).sum()
    }
}

// ---------------------------------------------------------------------------
// The engine message
// ---------------------------------------------------------------------------

/// Every event in the simulated cluster.
///
/// Size-critical: see the module docs. The RPC envelopes are boxed, the
/// dataflow batch is inline; the compile-time assert below is the
/// regression tripwire for both.
#[derive(Debug, Clone)]
pub enum Msg {
    /// An RPC request arriving at a broker dispatcher (see [`Msg::rpc`]).
    Rpc(Box<RpcRequest>),
    /// An RPC reply arriving back at the client (see [`Msg::reply`]).
    Reply(Box<RpcEnvelope>),
    /// Core-pool job completion inside an actor (tag = owner-defined).
    JobDone(u64),
    /// Generic timer with owner-defined tag.
    Timer(u64),
    /// Plasma: object `id` was filled and sealed; records/bytes describe
    /// its content (chunks are read from the store by the source).
    ObjectReady { id: ObjectId },
    /// Plasma: source finished with object `id`; broker may reuse it.
    ObjectFreed { id: ObjectId },
    /// Broker-internal: new data appended to a partition some push
    /// subscription watches — wake the push thread.
    DataAvailable,
    /// Dataflow: a batch pushed into a task's input queue.
    Data(Batch),
    /// Dataflow: downstream returns one queue credit to `from_task`.
    /// `inc` is the sender's recovery incarnation: a credit for a batch
    /// that predates a rollback is dropped (ledgers reset on restore).
    Credit { to_upstream_task: usize, inc: u64 },
    /// Producer resumes after generating records (tag = request id).
    GenDone(u64),
    /// Checkpoint: the coordinator asks a source to inject barrier `epoch`
    /// into its output stream at the next clean point.
    BarrierInject { epoch: u64 },
    /// Checkpoint: an aligned barrier flowing in-band between tasks — sent
    /// on a channel after the last pre-barrier batch, never overtaking data
    /// (barriers carry no payload and consume no credits).
    Barrier { epoch: u64, from_task: usize },
    /// Checkpoint: participant `from` wrote its epoch snapshot to the
    /// shared checkpoint store.
    BarrierAck { epoch: u64, from: ActorId },
    /// Fault injection: the receiving actor "crashes" — it wipes its
    /// volatile state, reports the failure and goes silent until restored.
    Fault { kind: FaultKind },
    /// Recovery: the failure detector's notice to the coordinator.
    FailureDetected { from: ActorId },
    /// Recovery: roll back to the latest completed checkpoint. `inc` is the
    /// new incarnation every participant adopts; barriers with
    /// `epoch <= epoch_floor` are stale and must be ignored.
    Restore { inc: u64, epoch_floor: u64 },
    /// Recovery: participant `from` finished restoring and resumed.
    RestoreAck { from: ActorId },
    /// Sharding: the coordinator published assignment table `epoch` —
    /// cached routing tables are stale; refresh from the shared view
    /// before the next request. Inline (two words), never boxed.
    ShardEpoch { epoch: u64 },
}

impl Msg {
    /// Wrap a request for the engine queue (boxes it — see the module
    /// docs on why the envelope is indirect).
    pub fn rpc(req: RpcRequest) -> Msg {
        Msg::Rpc(Box::new(req))
    }

    /// Wrap a reply for the engine queue.
    pub fn reply(env: RpcEnvelope) -> Msg {
        Msg::Reply(Box::new(env))
    }
}

/// The compile-time regression assert: every event the engine moves is at
/// most one cache line. Growing `Msg` (usually by growing [`Batch`]) slows
/// every heap sift and every dispatch — shrink the new field or box it.
const _: () = assert!(
    std::mem::size_of::<Msg>() <= 64,
    "Msg must stay within one cache line (64 bytes)"
);

#[cfg(test)]
mod tests {
    use super::*;

    /// The named runtime twin of the compile-time assert (CI calls it out
    /// explicitly so a budget regression reads as a test failure, not a
    /// build error buried in a log).
    #[test]
    fn msg_size_fits_one_cache_line() {
        assert!(
            std::mem::size_of::<Msg>() <= 64,
            "Msg is {} bytes — box the growth or shrink Batch",
            std::mem::size_of::<Msg>()
        );
        // The dataflow batch is the inline variant that dominates the
        // budget; RPC envelopes are boxed to a pointer.
        let batch = std::mem::size_of::<Batch>();
        assert!(batch <= 56, "Batch grew: {batch} bytes");
        assert_eq!(std::mem::size_of::<Box<RpcRequest>>(), std::mem::size_of::<usize>());
    }

    #[test]
    fn chunklist_representations() {
        let empty: ChunkList = Vec::new().into();
        assert!(empty.is_empty());
        assert_eq!(empty.len(), 0);

        let one: ChunkList = vec![Chunk::sim(3, 10)].into();
        assert!(matches!(&one, ChunkList::One(_)), "single chunk stays inline");
        assert_eq!(one.len(), 1);
        assert_eq!(one[0].records, 3);

        let many: ChunkList = vec![Chunk::sim(1, 10), Chunk::sim(2, 10)].into();
        assert!(matches!(&many, ChunkList::Shared(_)), "several chunks share a slice");
        let records: u32 = many.iter().map(|c| c.records).sum();
        assert_eq!(records, 3);
        // Cloning the shared form bumps a refcount, not the chunks.
        let ChunkList::Shared(rc) = &many else { unreachable!() };
        assert_eq!(Rc::strong_count(rc), 1);
        let clone = many.clone();
        let ChunkList::Shared(rc2) = &clone else { unreachable!() };
        assert!(Rc::ptr_eq(rc, rc2));
    }

    #[test]
    fn real_payload_materialisations_are_counted() {
        let before = real_payload_allocs();
        let chunk = Chunk::real(2, 4, Rc::new(vec![0u8; 8]));
        assert_eq!(real_payload_allocs(), before + 1);
        // Sharing (what every hand-off does) does not count.
        let _share = chunk.clone();
        let _sim = Chunk::sim(10, 10);
        assert_eq!(real_payload_allocs(), before + 1);
    }

    #[test]
    fn batch_chunk_bytes_derives_from_the_chunks() {
        let b = Batch {
            from_task: 0,
            tuples: 3,
            chunks: vec![Chunk::sim(1, 100), Chunk::sim(2, 100)].into(),
            hist: None,
            inc: 0,
        };
        assert_eq!(b.chunk_bytes(), 300);
    }
}
