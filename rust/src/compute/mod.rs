//! Chunk → tensor bridge: run the operator compute on real chunk payloads.
//!
//! The real data plane executes the Layer-1/2 kernels through PJRT on the
//! request path: a record-framed chunk becomes a `u8[R, S]` literal, the
//! variant whose `r` fits is selected (record axis padded with NUL rows —
//! the kernels treat NUL rows as empty), and the tuple outputs are decoded
//! back. A pure-rust `Native` engine with identical semantics serves as
//! the paper's "C++ consumer" data plane and as the ablation baseline for
//! the XLA path; the integration tests cross-check the two bit-for-bit.

pub mod native;
#[cfg(test)]
mod tests;

use std::cell::RefCell;
use std::rc::Rc;

#[cfg(feature = "xla")]
use anyhow::Context;
use anyhow::{bail, Result};

use crate::proto::{Chunk, Payload};
#[cfg(feature = "xla")]
use crate::runtime::ArtifactLibrary;

/// Histogram buckets baked into the wordcount artifacts (aot.py VARIANTS).
pub const WORDCOUNT_BUCKETS: usize = 8192;
/// Pattern buffer length baked into the filter artifacts.
pub const PATTERN_MAX: usize = 16;

/// Execution statistics (kernel invocations on the hot path).
#[derive(Debug, Default, Clone, Copy)]
pub struct ComputeStats {
    pub filter_calls: u64,
    pub wordcount_calls: u64,
    pub window_calls: u64,
    pub records_processed: u64,
    /// Wall-clock nanoseconds spent in kernel execution (host time, used
    /// by `zettastream calibrate` to fit the cost model).
    pub wall_ns: u64,
}

/// The operator compute engine.
pub enum ComputeEngine {
    /// AOT XLA artifacts through PJRT (the shipped hot path; needs the
    /// `xla` cargo feature — the sim plane never constructs this).
    #[cfg(feature = "xla")]
    Xla { lib: ArtifactLibrary, stats: RefCell<ComputeStats> },
    /// Pure-rust kernels (oracle / "C++ consumer" plane / ablation).
    Native { stats: RefCell<ComputeStats> },
}

/// Shared handle for actors.
pub type SharedCompute = Rc<ComputeEngine>;

impl ComputeEngine {
    #[cfg(feature = "xla")]
    pub fn xla(lib: ArtifactLibrary) -> SharedCompute {
        Rc::new(ComputeEngine::Xla { lib, stats: RefCell::default() })
    }

    #[cfg(feature = "xla")]
    pub fn xla_from_default_dir() -> Result<SharedCompute> {
        Ok(Self::xla(ArtifactLibrary::load(ArtifactLibrary::default_dir())?))
    }

    #[cfg(not(feature = "xla"))]
    pub fn xla_from_default_dir() -> Result<SharedCompute> {
        bail!(
            "built without the `xla` feature: PJRT execution unavailable \
             (rebuild with `cargo build --features xla` and run `make artifacts`)"
        )
    }

    pub fn native() -> SharedCompute {
        Rc::new(ComputeEngine::Native { stats: RefCell::default() })
    }

    pub fn name(&self) -> &'static str {
        match self {
            #[cfg(feature = "xla")]
            ComputeEngine::Xla { .. } => "xla",
            ComputeEngine::Native { .. } => "native",
        }
    }

    fn stats_cell(&self) -> &RefCell<ComputeStats> {
        match self {
            #[cfg(feature = "xla")]
            ComputeEngine::Xla { stats, .. } => stats,
            ComputeEngine::Native { stats } => stats,
        }
    }

    pub fn stats(&self) -> ComputeStats {
        *self.stats_cell().borrow()
    }

    fn stats_mut(&self) -> std::cell::RefMut<'_, ComputeStats> {
        self.stats_cell().borrow_mut()
    }

    /// Filter one real chunk: number of records containing `pattern`.
    pub fn filter_count(&self, chunk: &Chunk, pattern: &[u8]) -> Result<u64> {
        let data = real_payload(chunk)?;
        let records = chunk.records as usize;
        let s = chunk.record_size as usize;
        let t0 = std::time::Instant::now();
        let matches = match self {
            ComputeEngine::Native { .. } => native::filter_count(data, records, s, pattern),
            #[cfg(feature = "xla")]
            ComputeEngine::Xla { lib, .. } => {
                let mut total = 0u64;
                for (part, nvalid) in split_records(lib, "filter", s, records)? {
                    let v = lib.select("filter", s, nvalid).context("filter variant")?;
                    debug_assert!(v.meta.extra == pattern.len(),
                        "artifact pattern_len {} != pattern {}", v.meta.extra, pattern.len());
                    let r = v.meta.r;
                    let mut padded = vec![0u8; r * s];
                    padded[..nvalid * s]
                        .copy_from_slice(&data[part * s..part * s + nvalid * s]);
                    let chunk_lit = xla::Literal::create_from_shape_and_untyped_data(
                        xla::ElementType::U8,
                        &[r, s],
                        &padded,
                    )?;
                    let mut pat = vec![0u8; PATTERN_MAX];
                    pat[..pattern.len()].copy_from_slice(pattern);
                    let pat_lit = xla::Literal::create_from_shape_and_untyped_data(
                        xla::ElementType::U8,
                        &[PATTERN_MAX],
                        &pat,
                    )?;
                    let out = v.execute(&[chunk_lit, pat_lit, xla::Literal::from(nvalid as i32)])?;
                    total += out[1].get_first_element::<i32>()? as u64;
                }
                total
            }
        };
        let mut st = self.stats_mut();
        st.filter_calls += 1;
        st.records_processed += records as u64;
        st.wall_ns += t0.elapsed().as_nanos() as u64;
        Ok(matches)
    }

    /// Word-count one real chunk: `(hist[B], total_tokens)`.
    pub fn wordcount(&self, chunk: &Chunk) -> Result<(Vec<i32>, u64)> {
        let data = real_payload(chunk)?;
        let records = chunk.records as usize;
        let s = chunk.record_size as usize;
        let t0 = std::time::Instant::now();
        let hist = match self {
            ComputeEngine::Native { .. } => {
                native::wordcount_hist(data, records, s, WORDCOUNT_BUCKETS)
            }
            #[cfg(feature = "xla")]
            ComputeEngine::Xla { lib, .. } => {
                let mut hist = vec![0i32; WORDCOUNT_BUCKETS];
                for (part, nvalid) in split_records(lib, "wordcount", s, records)? {
                    let v = lib.select("wordcount", s, nvalid).context("wordcount variant")?;
                    let r = v.meta.r;
                    let mut padded = vec![0u8; r * s];
                    padded[..nvalid * s]
                        .copy_from_slice(&data[part * s..part * s + nvalid * s]);
                    let chunk_lit = xla::Literal::create_from_shape_and_untyped_data(
                        xla::ElementType::U8,
                        &[r, s],
                        &padded,
                    )?;
                    let out = v.execute(&[chunk_lit, xla::Literal::from(nvalid as i32)])?;
                    let part_hist = out[0].to_vec::<i32>()?;
                    for (h, p) in hist.iter_mut().zip(part_hist.iter()) {
                        *h += p;
                    }
                }
                hist
            }
        };
        let total: u64 = hist.iter().map(|&v| v as u64).sum();
        let mut st = self.stats_mut();
        st.wordcount_calls += 1;
        st.records_processed += records as u64;
        st.wall_ns += t0.elapsed().as_nanos() as u64;
        Ok((hist, total))
    }

    /// Sliding-window aggregation of per-slide histograms.
    pub fn window_sum(&self, hists: &[Vec<i32>]) -> Result<Vec<i32>> {
        let t0 = std::time::Instant::now();
        let out = match self {
            ComputeEngine::Native { .. } => native::window_sum(hists),
            #[cfg(feature = "xla")]
            ComputeEngine::Xla { lib, .. } => {
                let Some(v) = lib.select("window_sum", WORDCOUNT_BUCKETS, hists.len()) else {
                    // Window count bigger than the artifact: fall back to
                    // chunked sums through the artifact window.
                    bail!("no window_sum variant for w={}", hists.len());
                };
                let w = v.meta.r;
                // Keyed tasks hold a bucket *range*; zero-pad each slide
                // row up to the artifact's full bucket axis and slice the
                // result back down below.
                let width = hists[0].len().min(WORDCOUNT_BUCKETS);
                let mut flat = vec![0i32; w * WORDCOUNT_BUCKETS];
                for (i, h) in hists.iter().enumerate() {
                    flat[i * WORDCOUNT_BUCKETS..i * WORDCOUNT_BUCKETS + h.len().min(width)]
                        .copy_from_slice(&h[..h.len().min(width)]);
                }
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(flat.as_ptr() as *const u8, flat.len() * 4)
                };
                let lit = xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S32,
                    &[w, WORDCOUNT_BUCKETS],
                    bytes,
                )?;
                let out = v.execute(&[lit])?;
                let mut full = out[0].to_vec::<i32>()?;
                full.truncate(hists[0].len());
                full
            }
        };
        let mut st = self.stats_mut();
        st.window_calls += 1;
        st.wall_ns += t0.elapsed().as_nanos() as u64;
        Ok(out)
    }
}

fn real_payload(chunk: &Chunk) -> Result<&[u8]> {
    match &chunk.payload {
        Payload::Real(data) => Ok(data.as_slice()),
        Payload::Sim => bail!("compute invoked on a sim-plane chunk"),
    }
}

/// Split `records` into `(start_record, count)` parts that each fit the
/// largest compiled variant for `(kind, s)`.
#[cfg(feature = "xla")]
fn split_records(
    lib: &ArtifactLibrary,
    kind: &str,
    s: usize,
    records: usize,
) -> Result<Vec<(usize, usize)>> {
    let max_r = lib
        .max_r(kind, s)
        .with_context(|| format!("no {kind} artifact for record size {s} (see aot.py VARIANTS)"))?;
    let mut parts = Vec::new();
    let mut at = 0;
    while at < records {
        let n = (records - at).min(max_r);
        parts.push((at, n));
        at += n;
    }
    Ok(parts)
}
