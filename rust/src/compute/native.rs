//! Pure-rust reference implementations of the kernels.
//!
//! Bit-for-bit the same semantics as `python/compile/kernels/ref.py` (the
//! Python oracle): substring match per record, FNV-1a word-hash histogram
//! over maximal `[a-zA-Z0-9]` runs, case-folded. Used as (a) the `native`
//! compute engine (the paper's "C++ consumer" data plane and an ablation
//! baseline for the XLA path), and (b) the oracle the integration tests
//! compare the XLA path against.

/// FNV-1a 32-bit constants — must match `kernels/filter_count.py`.
pub const FNV_OFFSET: u32 = 2_166_136_261;
pub const FNV_PRIME: u32 = 16_777_619;

/// Per-record substring flags: `flags[r] = 1` iff `pattern` occurs in
/// record `r` of the `records × record_size` framed `data`.
pub fn filter_flags(data: &[u8], records: usize, record_size: usize, pattern: &[u8]) -> Vec<i32> {
    debug_assert!(data.len() >= records * record_size);
    debug_assert!(!pattern.is_empty());
    let finder = memchr::memmem::Finder::new(pattern);
    (0..records)
        .map(|r| {
            let rec = &data[r * record_size..(r + 1) * record_size];
            finder.find(rec).is_some() as i32
        })
        .collect()
}

/// Count of records containing the pattern.
pub fn filter_count(data: &[u8], records: usize, record_size: usize, pattern: &[u8]) -> u64 {
    filter_flags(data, records, record_size, pattern)
        .iter()
        .map(|&f| f as u64)
        .sum()
}

/// FNV-1a over an already-case-folded token.
pub fn fnv1a(token: &[u8]) -> u32 {
    let mut h = FNV_OFFSET;
    for &b in token {
        h = (h ^ b as u32).wrapping_mul(FNV_PRIME);
    }
    h
}

#[inline]
fn fold(b: u8) -> u8 {
    if b.is_ascii_uppercase() {
        b | 0x20
    } else {
        b
    }
}

/// Word-hash histogram: for each maximal alphanumeric run in each record
/// (tokens do not span records), `hist[fnv1a(folded token) % buckets] += 1`.
pub fn wordcount_hist(
    data: &[u8],
    records: usize,
    record_size: usize,
    buckets: usize,
) -> Vec<i32> {
    debug_assert!(buckets > 0);
    let mut hist = vec![0i32; buckets];
    for r in 0..records {
        let rec = &data[r * record_size..(r + 1) * record_size];
        let mut h = FNV_OFFSET;
        let mut in_word = false;
        for &b in rec {
            if b.is_ascii_alphanumeric() {
                h = (h ^ fold(b) as u32).wrapping_mul(FNV_PRIME);
                in_word = true;
            } else {
                if in_word {
                    hist[(h % buckets as u32) as usize] += 1;
                }
                h = FNV_OFFSET;
                in_word = false;
            }
        }
        if in_word {
            hist[(h % buckets as u32) as usize] += 1;
        }
    }
    hist
}

/// Sum per-slide histograms into a window histogram (the `window_sum`
/// artifact's semantics).
pub fn window_sum(hists: &[Vec<i32>]) -> Vec<i32> {
    let Some(first) = hists.first() else { return Vec::new() };
    let mut out = vec![0i32; first.len()];
    for h in hists {
        debug_assert_eq!(h.len(), out.len());
        for (o, v) in out.iter_mut().zip(h.iter()) {
            *o += v;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(lines: &[&[u8]], record_size: usize) -> Vec<u8> {
        let mut data = vec![0u8; lines.len() * record_size];
        for (i, line) in lines.iter().enumerate() {
            data[i * record_size..i * record_size + line.len()].copy_from_slice(line);
        }
        data
    }

    #[test]
    fn filter_finds_planted_needle() {
        let data = frame(&[b"xxxxneedlexxxx", b"nothing here.."], 20);
        assert_eq!(filter_flags(&data, 2, 20, b"needle"), vec![1, 0]);
        assert_eq!(filter_count(&data, 2, 20, b"needle"), 1);
    }

    #[test]
    fn filter_does_not_cross_record_boundary() {
        // "nee" ends record 0, "dle" starts record 1: no match
        let data = frame(&[b"xxxnee", b"dlexxx"], 6);
        assert_eq!(filter_count(&data, 2, 6, b"needle"), 0);
    }

    #[test]
    fn fnv_matches_python_reference_values() {
        // printed by python: fnv1a(b"hello") etc. (ref.py semantics)
        assert_eq!(fnv1a(b""), FNV_OFFSET);
        assert_eq!(fnv1a(b"a"), 0xE40C292C);
        assert_eq!(fnv1a(b"hello"), 0x4F9F2CAB);
    }

    #[test]
    fn wordcount_counts_folded_tokens() {
        let data = frame(&[b"Word word WORD 42"], 24);
        let hist = wordcount_hist(&data, 1, 24, 64);
        assert_eq!(hist[(fnv1a(b"word") % 64) as usize], 3);
        assert_eq!(hist[(fnv1a(b"42") % 64) as usize], 1);
        assert_eq!(hist.iter().sum::<i32>(), 4);
    }

    #[test]
    fn wordcount_flushes_record_end_token() {
        let data = frame(&[b"endword"], 7); // token runs to the boundary
        let hist = wordcount_hist(&data, 1, 7, 32);
        assert_eq!(hist[(fnv1a(b"endword") % 32) as usize], 1);
    }

    #[test]
    fn nul_padding_is_a_separator() {
        let data = frame(&[b"pad"], 16); // 13 NUL bytes after "pad"
        let hist = wordcount_hist(&data, 1, 16, 32);
        assert_eq!(hist.iter().sum::<i32>(), 1);
    }

    #[test]
    fn window_sum_adds_elementwise() {
        let out = window_sum(&[vec![1, 2], vec![10, 20], vec![100, 200]]);
        assert_eq!(out, vec![111, 222]);
        assert!(window_sum(&[]).is_empty());
    }
}
