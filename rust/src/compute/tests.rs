//! Compute-engine tests: native always; XLA vs native cross-check when
//! artifacts are present (the integration suite requires them).

use std::rc::Rc;

use super::*;
use crate::proto::Chunk;
use crate::wikipedia::CorpusReader;

fn real_chunk(records: usize, record_size: usize, fill: impl Fn(usize, &mut [u8])) -> Chunk {
    let mut data = vec![0u8; records * record_size];
    for r in 0..records {
        fill(r, &mut data[r * record_size..(r + 1) * record_size]);
    }
    Chunk::real(records as u32, record_size as u32, Rc::new(data))
}

#[test]
fn native_filter_counts_planted() {
    let eng = ComputeEngine::native();
    let chunk = real_chunk(50, 100, |r, rec| {
        if r % 5 == 0 {
            rec[20..26].copy_from_slice(b"needle");
        }
    });
    assert_eq!(eng.filter_count(&chunk, b"needle").unwrap(), 10);
    let st = eng.stats();
    assert_eq!(st.filter_calls, 1);
    assert_eq!(st.records_processed, 50);
}

#[test]
fn native_wordcount_totals() {
    let eng = ComputeEngine::native();
    let chunk = real_chunk(4, 32, |_, rec| {
        rec[..11].copy_from_slice(b"hello world");
    });
    let (hist, total) = eng.wordcount(&chunk).unwrap();
    assert_eq!(total, 8);
    assert_eq!(hist.len(), WORDCOUNT_BUCKETS);
    assert_eq!(hist.iter().map(|&v| v as u64).sum::<u64>(), 8);
}

#[test]
fn sim_chunk_is_rejected() {
    let eng = ComputeEngine::native();
    assert!(eng.filter_count(&Chunk::sim(10, 100), b"x").is_err());
}

#[test]
fn native_window_sum() {
    let eng = ComputeEngine::native();
    let out = eng.window_sum(&[vec![1, 2, 3], vec![4, 5, 6]]).unwrap();
    assert_eq!(out, vec![5, 7, 9]);
}

fn try_xla() -> Option<SharedCompute> {
    match ComputeEngine::xla_from_default_dir() {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("skipping XLA compute test ({e:#}); run `make artifacts`");
            None
        }
    }
}

#[test]
fn xla_matches_native_filter() {
    let Some(xla) = try_xla() else { return };
    let native = ComputeEngine::native();
    // 130 records forces a split across the r=64 variant (2 full + 1 pad)
    let chunk = real_chunk(130, 100, |r, rec| {
        for (i, b) in rec.iter_mut().enumerate() {
            *b = b'a' + ((r * 31 + i * 7) % 26) as u8;
        }
        if r % 7 == 3 {
            rec[40..46].copy_from_slice(b"needle");
        }
    });
    let want = native.filter_count(&chunk, b"needle").unwrap();
    let got = xla.filter_count(&chunk, b"needle").unwrap();
    assert_eq!(got, want);
    assert!(want >= 18, "sanity: needles planted");
}

#[test]
fn xla_matches_native_wordcount() {
    let Some(xla) = try_xla() else { return };
    let native = ComputeEngine::native();
    let mut reader = CorpusReader::new(2048, 40);
    let mut data = vec![0u8; 40 * 2048];
    reader.fill_records(&mut data);
    let chunk = Chunk::real(40, 2048, Rc::new(data));
    let (h_native, t_native) = native.wordcount(&chunk).unwrap();
    let (h_xla, t_xla) = xla.wordcount(&chunk).unwrap();
    assert_eq!(t_xla, t_native);
    assert_eq!(h_xla, h_native, "histograms must agree bucket-for-bucket");
    assert!(t_native > 5000, "2 KiB x 40 records of text: {t_native} tokens");
}

#[test]
fn xla_window_sum_matches_native() {
    let Some(xla) = try_xla() else { return };
    let native = ComputeEngine::native();
    let hists: Vec<Vec<i32>> = (0..5)
        .map(|i| (0..WORDCOUNT_BUCKETS as i32).map(|b| (b * (i + 1)) % 17).collect())
        .collect();
    assert_eq!(xla.window_sum(&hists).unwrap(), native.window_sum(&hists).unwrap());
}
