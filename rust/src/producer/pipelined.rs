//! `WriteMode::Pipelined` — asynchronous appends with a bounded in-flight
//! window.
//!
//! Production ingestion layers do not wait one round-trip per request:
//! they pipeline writes with a bounded window and sequence them so acks
//! can complete out of order (Uber's real-time infra, 2104.00087). Here
//! record generation overlaps with up to `write_inflight` outstanding
//! Append RPCs; each chunk carries a per-partition sequence number, and
//! the writer tracks ack completion per partition. The sequencers are
//! *detection*, not enforcement: an ack arriving ahead of an older
//! outstanding append is absorbed and counted
//! ([`WriteStatKey::AcksReordered`]). On the simulator's FIFO network and
//! single-broker topology appends are served in send order, so the
//! counter staying at zero is itself a checked property (see tests); a
//! multi-path transport would use the same sequence numbers broker-side
//! to reject out-of-order appends.
//!
//! Backpressure: a full window parks the generated request and pauses
//! generation; the next ack releases it.

use std::collections::{BTreeSet, HashMap};

use crate::config::WriteMode;
use crate::metrics::{Class, SharedMetrics};
use crate::net::SharedNetwork;
use crate::proto::{Chunk, Msg, PartitionId, RpcEnvelope, RpcKind, RpcReply, RpcRequest};
use crate::shard::ShardClient;
use crate::sim::{Actor, ActorId, Ctx, Engine, Time};

use super::api::{
    WriteAccounting, WriteError, WritePath, WriteStatKey, WriteStats, WriterFactory, WriterWiring,
};
use super::{ProducerParams, RecordGen};

/// Pipelined writer wiring: the shared producer params plus the window.
#[derive(Debug, Clone)]
pub struct PipelinedParams {
    pub base: ProducerParams,
    /// Bounded in-flight append window (`write_inflight`, >= 1).
    pub inflight_window: usize,
}

/// One outstanding append.
#[derive(Debug, Clone)]
struct Inflight {
    chunks: Vec<(PartitionId, Chunk)>,
    /// `(partition, per-partition sequence)` of every chunk in the request.
    seqs: Vec<(PartitionId, u64)>,
    sent_at: Time,
    attempts: u32,
    /// Generation stamp when the latency tracer sampled this request.
    produced_at: Option<Time>,
}

/// Per-partition ack sequencing: acks may arrive out of order; the log
/// order is fixed by send order, and this tracks completion holes.
#[derive(Debug, Default)]
struct PartSeq {
    next_expected: u64,
    acked_ahead: BTreeSet<u64>,
}

impl PartSeq {
    /// Record an ack; returns false when it completed out of order.
    fn ack(&mut self, seq: u64) -> bool {
        if seq == self.next_expected {
            self.next_expected += 1;
            while self.acked_ahead.remove(&self.next_expected) {
                self.next_expected += 1;
            }
            true
        } else {
            self.acked_ahead.insert(seq);
            false
        }
    }
}

/// The pipelined producer actor.
pub struct PipelinedWriter {
    params: PipelinedParams,
    gen: RecordGen,
    next_rpc: u64,
    /// A generated request waiting for a free window slot (at most one —
    /// generation is serial, so this bounds memory).
    ready: Option<(u64, Vec<(PartitionId, Chunk)>, Vec<(PartitionId, u64)>)>,
    /// A GenDone is outstanding.
    generating: bool,
    inflight: HashMap<u64, Inflight>,
    seq: HashMap<PartitionId, PartSeq>,
    next_seq: HashMap<PartitionId, u64>,
    done: bool,
    acct: WriteAccounting,
    reordered: u64,
    inflight_peak: usize,
    metrics: SharedMetrics,
    net: SharedNetwork,
    /// Cached shard routing when `broker_count > 1`.
    shard: Option<ShardClient>,
    /// Which broker group the next request stages (round-robin).
    group_rr: usize,
    /// Appends re-routed after a `WrongShard` refusal.
    shard_retries: u64,
    /// Appends retransmitted after a deadline expiry against a broker the
    /// coordinator declared dead.
    broker_down_retries: u64,
}

impl PipelinedWriter {
    pub fn new(
        params: PipelinedParams,
        gen: RecordGen,
        metrics: SharedMetrics,
        net: SharedNetwork,
    ) -> Self {
        assert!(!params.base.partitions.is_empty());
        assert!(params.base.chunk_bytes >= params.base.record_size);
        assert!(params.inflight_window >= 1, "pipelining needs a window of at least 1");
        let shard = params.base.shard.as_ref().map(ShardClient::new);
        Self {
            params,
            gen,
            next_rpc: 0,
            ready: None,
            generating: false,
            inflight: HashMap::new(),
            seq: HashMap::new(),
            next_seq: HashMap::new(),
            done: false,
            acct: WriteAccounting::default(),
            reordered: 0,
            inflight_peak: 0,
            metrics,
            net,
            shard,
            group_rr: 0,
            shard_retries: 0,
            broker_down_retries: 0,
        }
    }

    /// Exponential per-attempt deadline, capped at 64× the base (see the
    /// sync writer's twin).
    fn deadline_for(&self, attempts: u32) -> Time {
        self.params.base.rpc_deadline_ns.saturating_mul(1 << attempts.saturating_sub(1).min(6))
    }

    /// Generate the next request's chunks; `GenDone` fires after the
    /// per-record generation cost.
    fn start_generation(&mut self, ctx: &mut Ctx<'_, Msg>) {
        debug_assert!(self.ready.is_none(), "one staged request at a time");
        let rpc = self.next_rpc;
        self.next_rpc += 1;
        let staged = match &self.shard {
            None => super::stage_request(&mut self.gen, &self.params.base),
            Some(client) => {
                // Rotate over broker groups, skipping any a fail-over left
                // without primaries (an empty group must not read as "the
                // generator is exhausted"). A request stays within one
                // primary's range so it has a single destination broker.
                let brokers = client.table().brokers();
                let mut parts = Vec::new();
                for _ in 0..brokers {
                    let group = self.group_rr % brokers;
                    self.group_rr = (self.group_rr + 1) % brokers;
                    parts = client.table().primaries_of(group);
                    if !parts.is_empty() {
                        break;
                    }
                }
                super::stage_request_for(&mut self.gen, &self.params.base, &parts)
            }
        };
        let Some((chunks, total_records)) = staged else {
            self.done = true;
            return;
        };
        // Sequence assignment happens at generation: generation order ==
        // send order per partition.
        let seqs = chunks
            .iter()
            .map(|&(p, _)| {
                let s = self.next_seq.entry(p).or_insert(0);
                let assigned = *s;
                *s += 1;
                (p, assigned)
            })
            .collect();
        self.generating = true;
        let cost = total_records * self.params.base.cost.producer_record_ns;
        ctx.send_self_in(cost as Time, Msg::GenDone(rpc));
        self.ready = Some((rpc, chunks, seqs));
    }

    /// Send the parked request if a window slot is free, then keep the
    /// generation thread busy — the pipelining heart.
    fn try_dispatch(&mut self, ctx: &mut Ctx<'_, Msg>) {
        if !self.generating {
            if let Some((rpc, chunks, seqs)) = self.ready.take() {
                if self.inflight.len() < self.params.inflight_window {
                    // None whenever tracing is off (sample_produced self-gates).
                    let produced_at =
                        self.metrics.borrow_mut().tracer.sample_produced(ctx.now());
                    self.inflight.insert(
                        rpc,
                        Inflight { chunks, seqs, sent_at: ctx.now(), attempts: 1, produced_at },
                    );
                    self.inflight_peak = self.inflight_peak.max(self.inflight.len());
                    self.transmit(rpc, ctx);
                } else {
                    self.ready = Some((rpc, chunks, seqs)); // window full: park
                }
            }
        }
        if self.ready.is_none() && !self.generating && !self.done {
            self.start_generation(ctx);
        }
    }

    /// Put one in-flight request on the wire (first send or retry).
    fn transmit(&mut self, rpc: u64, ctx: &mut Ctx<'_, Msg>) {
        let inflight = self.inflight.get_mut(&rpc).expect("transmit of a live append");
        inflight.sent_at = ctx.now();
        let bytes: u64 = inflight.chunks.iter().map(|(_, c)| c.bytes()).sum();
        // Destination from the cached shard table (re-resolved on every
        // transmit, so a WrongShard retry lands at the new primary).
        let (to, to_node) = match &self.shard {
            Some(client) => client.broker_for(inflight.chunks[0].0),
            None => (self.params.base.broker, self.params.base.broker_node),
        };
        self.acct.on_issued();
        let deliver = self.net.borrow_mut().send(ctx.now(), self.params.base.node, to_node, bytes);
        ctx.send_at(
            deliver,
            to,
            Msg::rpc(RpcRequest {
                id: rpc,
                reply_to: ctx.self_id(),
                from_node: self.params.base.node,
                kind: RpcKind::Append {
                    chunks: inflight.chunks.clone(),
                    produced_at: inflight.produced_at,
                },
            }),
        );
        // Sharded runs race every window slot against its own deadline
        // (the broker-death path; see the sync writer's twin).
        if self.shard.is_some() && self.params.base.rpc_deadline_ns > 0 {
            let attempts = self.inflight[&rpc].attempts;
            let d = self.deadline_for(attempts);
            ctx.send_self_in(d, Msg::Timer(rpc | super::DEADLINE_TAG));
        }
    }

    /// A per-RPC deadline fired for one window slot. No-op unless it
    /// genuinely expired the slot's current attempt; on expiry against a
    /// declared-dead broker, refresh the route and retransmit (the
    /// broker-side idempotence table dedups a request that already landed
    /// before the crash), otherwise re-arm.
    fn on_deadline(&mut self, rpc: u64, ctx: &mut Ctx<'_, Msg>) {
        let Some(inflight) = self.inflight.get(&rpc) else { return };
        if ctx.now() < inflight.sent_at + self.deadline_for(inflight.attempts) {
            return;
        }
        let Some(client) = self.shard.as_mut() else { return };
        let (home, _) = client.broker_for(inflight.chunks[0].0);
        if client.actor_down(home) {
            client.refresh();
            self.broker_down_retries += 1;
            self.inflight.get_mut(&rpc).expect("checked above").attempts += 1;
            self.transmit(rpc, ctx);
        } else {
            let d = self.deadline_for(inflight.attempts);
            ctx.send_self_in(d, Msg::Timer(rpc | super::DEADLINE_TAG));
        }
    }

    /// Feed a completed (or abandoned) request through the per-partition
    /// sequencers.
    fn sequence_ack(&mut self, seqs: &[(PartitionId, u64)]) {
        for &(p, s) in seqs {
            if !self.seq.entry(p).or_default().ack(s) {
                self.reordered += 1;
            }
        }
    }

    fn on_ack(&mut self, env: RpcEnvelope, ctx: &mut Ctx<'_, Msg>) {
        match env.reply {
            RpcReply::AppendAck { records, bytes } => {
                let inflight =
                    self.inflight.remove(&env.id).expect("ack matches an in-flight append");
                self.sequence_ack(&inflight.seqs);
                let rtt = ctx.now() - inflight.sent_at;
                self.acct.on_acked(records, bytes, rtt);
                let mut m = self.metrics.borrow_mut();
                m.record(Class::ProducerRecords, self.params.base.entity, ctx.now(), records);
                if m.tracer.enabled() {
                    m.tracer.note_append_latency(ctx.now(), rtt);
                }
            }
            RpcReply::Error { reason } => {
                let attempts = self
                    .inflight
                    .get(&env.id)
                    .expect("error matches an in-flight append")
                    .attempts;
                if self.acct.on_rejected(&self.params.base.retry, attempts, reason) {
                    self.inflight.get_mut(&env.id).expect("just checked").attempts += 1;
                    ctx.send_self_in(self.params.base.retry.backoff_ns, Msg::Timer(env.id));
                    return; // slot stays occupied until the retry resolves
                }
                // Retries exhausted: the typed error is recorded; free the
                // slot and mark the sequences complete so later acks don't
                // count as reordered forever.
                let dropped = self.inflight.remove(&env.id).expect("just checked");
                self.sequence_ack(&dropped.seqs);
            }
            RpcReply::WrongShard { epoch } => match self.shard.as_mut() {
                Some(client) => {
                    // Stale route: refresh the cached table and resend the
                    // same slot after backoff. Unbounded (the coordinator
                    // always publishes the new table), counted separately
                    // from genuine rejections.
                    client.refresh();
                    self.shard_retries += 1;
                    self.inflight
                        .get_mut(&env.id)
                        .expect("refusal matches an in-flight append")
                        .attempts += 1;
                    ctx.send_self_in(self.params.base.retry.backoff_ns, Msg::Timer(env.id));
                    return; // slot stays occupied until the retry resolves
                }
                None => {
                    // No routing view to refresh: surface the typed error
                    // instead of panicking, free the slot.
                    self.acct.errors += 1;
                    self.acct.last_error = Some(WriteError::WrongShard { epoch });
                    let dropped = self.inflight.remove(&env.id).expect("refusal matches a slot");
                    self.sequence_ack(&dropped.seqs);
                }
            },
            other => {
                panic!("pipelined writer {}: unexpected reply {other:?}", self.params.base.entity)
            }
        }
        self.try_dispatch(ctx);
    }

    pub fn records_sent(&self) -> u64 {
        self.acct.records_sent
    }

    pub fn planted(&self) -> u64 {
        self.gen.planted()
    }

    /// Acks that completed out of send order (absorbed by sequencing).
    pub fn acks_reordered(&self) -> u64 {
        self.reordered
    }
}

impl Actor<Msg> for PipelinedWriter {
    fn on_start(&mut self, ctx: &mut Ctx<'_, Msg>) {
        self.start_generation(ctx);
    }

    fn on_event(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        match msg {
            Msg::GenDone(_) => {
                self.generating = false;
                self.try_dispatch(ctx);
            }
            Msg::Reply(env) => self.on_ack(*env, ctx),
            Msg::Timer(tag) if tag & super::DEADLINE_TAG != 0 => {
                self.on_deadline(tag & !super::DEADLINE_TAG, ctx)
            }
            Msg::Timer(rpc) => self.transmit(rpc, ctx),
            other => {
                panic!("pipelined writer {}: unexpected {other:?}", self.params.base.entity)
            }
        }
    }

    fn label(&self) -> String {
        format!("pipelined-writer#{}", self.params.base.entity)
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}

impl WritePath for PipelinedWriter {
    fn mode(&self) -> WriteMode {
        WriteMode::Pipelined
    }

    fn stats(&self) -> WriteStats {
        let mut extras = super::api::WriteStatExtras::new();
        extras.insert(WriteStatKey::AcksReordered, self.reordered);
        extras.insert(WriteStatKey::InflightPeak, self.inflight_peak as u64);
        if self.shard_retries > 0 {
            extras.insert(WriteStatKey::ShardRetries, self.shard_retries);
        }
        if self.broker_down_retries > 0 {
            extras.insert(WriteStatKey::BrokerDownRetries, self.broker_down_retries);
        }
        // Generation thread + async completion thread.
        self.acct.stats(self.gen.planted(), 2, extras)
    }
}

/// Builds the `Np` pipelined producers on the producer node.
pub struct PipelinedWriterFactory;

impl WriterFactory for PipelinedWriterFactory {
    fn mode(&self) -> WriteMode {
        WriteMode::Pipelined
    }

    fn build(&self, w: &WriterWiring<'_>, engine: &mut Engine<Msg>) -> Vec<ActorId> {
        super::api::build_writers(w, engine, w.producer_node, |base, gen| {
            Box::new(PipelinedWriter::new(
                PipelinedParams { base, inflight_window: w.config.write_inflight },
                gen,
                w.metrics.clone(),
                w.net.clone(),
            ))
        })
    }
}
