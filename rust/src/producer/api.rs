//! The unified write-path API: one trait, one stats shape, one registry.
//!
//! The mirror of [`crate::source::api`] on the ingestion side. The paper's
//! central interference effect is producer write RPCs competing with pull
//! reads on the broker's worker cores; studying the symmetric design space
//! ("making room for higher ingestion") needs the write mechanism to be a
//! pluggable framework component (the ingestion-framework argument of
//! Marcu et al., 1812.04197, and Uber's connector registry, 2104.00087):
//!
//! * [`WritePath`] — the lifecycle + introspection contract every producer
//!   backend implements; uniform [`WriteStats`] at end of run.
//! * [`WriterActor`] — the type-erased actor the launcher registers, so
//!   end-of-run stats extraction is one downcast with a hard error.
//! * [`WriterFactory`] + [`WriterRegistry`] — pluggable construction keyed
//!   by [`WriteMode`]; `cluster::launch` resolves the configured mode and
//!   never names a concrete producer type.

use std::any::Any;
use std::collections::BTreeMap;

use crate::config::{ExperimentConfig, WriteMode};
use crate::metrics::SharedMetrics;
use crate::net::{NodeId, SharedNetwork};
use crate::plasma::SharedStore;
use crate::proto::{Msg, PartitionId};
use crate::sim::{Actor, ActorId, Ctx, Engine, Time};

/// Typed keys for the per-mode counters a [`WriteStats`] may carry beyond
/// the uniform core.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum WriteStatKey {
    /// Appends retried after a broker rejection.
    Retries,
    /// Appends abandoned after the bounded retries ran out.
    Errors,
    /// Acks that completed out of send order (pipelined mode; the
    /// per-partition sequencing absorbs them without reordering the log).
    AcksReordered,
    /// Peak appends simultaneously in flight (pipelined mode).
    InflightPeak,
    /// Shared objects sealed and handed to the broker (shared-mem mode).
    ObjectsSealed,
    /// 1 while the writer holds a write subscription (shared-mem mode).
    Subscribed,
    /// Generation stalls on object exhaustion — the shared-memory
    /// backpressure signal (shared-mem mode).
    ObjectStalls,
    /// Appends re-routed after a `WrongShard` refusal (sharded runs).
    /// Unlike `Retries` these are unbounded: the coordinator always
    /// publishes the new table, so the retry loop terminates.
    ShardRetries,
    /// Appends retransmitted after their per-RPC deadline expired against
    /// a broker the coordinator declared dead (sharded runs,
    /// `fault_kind=broker`). Unbounded like `ShardRetries`: the fail-over
    /// always promotes a live primary, so the loop terminates — and the
    /// broker-side idempotence table makes the retransmit exactly-once.
    BrokerDownRetries,
}

impl WriteStatKey {
    pub fn name(&self) -> &'static str {
        match self {
            Self::Retries => "retries",
            Self::Errors => "errors",
            Self::AcksReordered => "acks_reordered",
            Self::InflightPeak => "inflight_peak",
            Self::ObjectsSealed => "objects_sealed",
            Self::Subscribed => "subscribed",
            Self::ObjectStalls => "object_stalls",
            Self::ShardRetries => "shard_retries",
            Self::BrokerDownRetries => "broker_down_retries",
        }
    }
}

/// The typed extension map for per-mode extras.
pub type WriteStatExtras = BTreeMap<WriteStatKey, u64>;

/// A rejected or failed append, surfaced instead of panicking so overload
/// experiments keep running (satellite of the write-path redesign).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WriteError {
    /// The broker refused the append (unknown partition, bad request) and
    /// the bounded retries ran out.
    Rejected { reason: String, attempts: u32 },
    /// The write-subscription handshake failed (shared-mem mode).
    SubscribeFailed { reason: String },
    /// The broker stopped serving the partition (sharded runs) and no
    /// shard client was wired to re-route — surfaced typed instead of
    /// panicking the producer.
    WrongShard { epoch: u64 },
}

impl std::fmt::Display for WriteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Rejected { reason, attempts } => {
                write!(f, "append rejected after {attempts} attempt(s): {reason}")
            }
            Self::SubscribeFailed { reason } => write!(f, "write subscribe failed: {reason}"),
            Self::WrongShard { epoch } => {
                write!(f, "broker no longer serves the partition (assignment epoch {epoch})")
            }
        }
    }
}

/// Bounded retry/backoff for rejected appends, from the `write_retry_*`
/// knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Retries after the first rejection (0 = fail immediately).
    pub max_retries: u32,
    /// Backoff before each retry, in virtual ns.
    pub backoff_ns: Time,
}

impl Default for RetryPolicy {
    /// Derived from the config defaults — `write_retry_*` in
    /// [`ExperimentConfig::default`] is the single source of truth.
    fn default() -> Self {
        Self::from_config(&ExperimentConfig::default())
    }
}

impl RetryPolicy {
    pub fn from_config(config: &ExperimentConfig) -> Self {
        Self {
            max_retries: config.write_retry_max,
            backoff_ns: config.write_retry_backoff_us * crate::sim::MICROS,
        }
    }
}

/// The append accounting every writer backend shares: issue/ack counters,
/// latency sums, and the bounded-retry decision for rejections. Keeping
/// it in one struct keeps the three backends' `WriteStats` assembly from
/// drifting.
#[derive(Debug, Default)]
pub(crate) struct WriteAccounting {
    pub records_sent: u64,
    pub bytes_sent: u64,
    pub appends_issued: u64,
    pub appends_acked: u64,
    pub append_ns_total: u64,
    pub retries: u64,
    pub errors: u64,
    pub last_error: Option<WriteError>,
}

impl WriteAccounting {
    /// One append (or seal notification) went out — first send or retry.
    pub fn on_issued(&mut self) {
        self.appends_issued += 1;
    }

    /// One append was acked after `rtt_ns` of round-trip.
    pub fn on_acked(&mut self, records: u64, bytes: u64, rtt_ns: Time) {
        self.records_sent += records;
        self.bytes_sent += bytes;
        self.appends_acked += 1;
        self.append_ns_total += rtt_ns;
    }

    /// Bounded-retry decision for a rejection at `attempts` tries so far:
    /// `true` = retry (the caller re-transmits after its backoff timer),
    /// `false` = give up, with the typed error recorded.
    pub fn on_rejected(&mut self, retry: &RetryPolicy, attempts: u32, reason: String) -> bool {
        if attempts <= retry.max_retries {
            self.retries += 1;
            true
        } else {
            self.errors += 1;
            self.last_error = Some(WriteError::Rejected { reason, attempts });
            false
        }
    }

    /// Assemble the uniform stats; `Retries`/`Errors` extras come from
    /// here, mode-specific extras from the caller.
    pub fn stats(&self, planted: u64, threads: usize, mut extras: WriteStatExtras) -> WriteStats {
        extras.insert(WriteStatKey::Retries, self.retries);
        extras.insert(WriteStatKey::Errors, self.errors);
        WriteStats {
            records_sent: self.records_sent,
            bytes_sent: self.bytes_sent,
            appends_issued: self.appends_issued,
            appends_acked: self.appends_acked,
            append_ns_total: self.append_ns_total,
            planted,
            threads,
            last_error: self.last_error.clone(),
            extras,
        }
    }
}

/// Uniform end-of-run report every writer returns. Core counters cover the
/// paper's ingestion-accounting axes; mode-specific numbers live in the
/// typed `extras` map.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct WriteStats {
    /// Records acked by the broker (appended, and replicated if configured).
    pub records_sent: u64,
    /// Payload bytes acked.
    pub bytes_sent: u64,
    /// Append requests issued (RPCs or sealed objects, including retries).
    pub appends_issued: u64,
    /// Append requests acked.
    pub appends_acked: u64,
    /// Sum of append round-trip latencies (issue → ack), virtual ns.
    pub append_ns_total: u64,
    /// Needles planted by the synthetic generator (end-to-end checks).
    pub planted: u64,
    /// Threads the writer occupies — the write-side footprint axis.
    pub threads: usize,
    /// Most recent fatal error, if any append was abandoned.
    pub last_error: Option<WriteError>,
    /// Per-mode extras.
    pub extras: WriteStatExtras,
}

impl WriteStats {
    /// An extra counter, defaulting to 0 when the mode doesn't report it.
    pub fn extra(&self, key: WriteStatKey) -> u64 {
        self.extras.get(&key).copied().unwrap_or(0)
    }

    /// Mean append round-trip latency in ns (0 before the first ack).
    pub fn mean_append_ns(&self) -> u64 {
        if self.appends_acked == 0 {
            0
        } else {
            self.append_ns_total / self.appends_acked
        }
    }

    /// Fold another writer's stats into this one (cluster aggregation).
    pub fn merge(&mut self, other: &WriteStats) {
        self.records_sent += other.records_sent;
        self.bytes_sent += other.bytes_sent;
        self.appends_issued += other.appends_issued;
        self.appends_acked += other.appends_acked;
        self.append_ns_total += other.append_ns_total;
        self.planted += other.planted;
        self.threads += other.threads;
        if other.last_error.is_some() {
            self.last_error = other.last_error.clone();
        }
        for (&k, &v) in &other.extras {
            match k {
                // Peaks don't add across writers; take the max.
                WriteStatKey::InflightPeak => {
                    let e = self.extras.entry(k).or_insert(0);
                    *e = (*e).max(v);
                }
                _ => *self.extras.entry(k).or_insert(0) += v,
            }
        }
    }
}

/// The contract every producer backend implements on top of being an
/// actor. Wiring happens in the factory's `build`, the first generation in
/// `Actor::on_start`; this trait adds the uniform introspection surface.
pub trait WritePath: Actor<Msg> {
    /// The mode this writer implements.
    fn mode(&self) -> WriteMode;

    /// Uniform end-of-run statistics.
    fn stats(&self) -> WriteStats;
}

/// The type-erased writer actor the launcher registers with the engine.
/// Stats extraction downcasts to this single concrete type — a producer
/// that was not built through the registry is a hard error, not dropped
/// ingestion totals.
pub struct WriterActor {
    inner: Box<dyn WritePath>,
}

impl WriterActor {
    pub fn new(inner: Box<dyn WritePath>) -> Self {
        Self { inner }
    }

    pub fn mode(&self) -> WriteMode {
        self.inner.mode()
    }

    pub fn stats(&self) -> WriteStats {
        self.inner.stats()
    }

    /// Borrow the wrapped writer as its concrete type (tests, examples).
    pub fn writer_as<T: 'static>(&mut self) -> Option<&mut T> {
        self.inner.as_any_mut()?.downcast_mut::<T>()
    }
}

impl Actor<Msg> for WriterActor {
    fn on_start(&mut self, ctx: &mut Ctx<'_, Msg>) {
        self.inner.on_start(ctx);
    }

    fn on_event(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        self.inner.on_event(msg, ctx);
    }

    fn label(&self) -> String {
        self.inner.label()
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn Any> {
        Some(self)
    }
}

/// Everything a factory may need to wire its writers into a cluster. The
/// launcher fills this once; factories take what their mode uses.
pub struct WriterWiring<'a> {
    pub config: &'a ExperimentConfig,
    /// Node remote producers run on (the paper deploys producers separately
    /// from the streaming architecture).
    pub producer_node: NodeId,
    pub broker: ActorId,
    /// The broker's node — also the *colocated* node a shared-memory
    /// writer must live on to reach the plasma store.
    pub broker_node: NodeId,
    /// Partitions producers append to (all `Ns` of the stream).
    pub partitions: Vec<PartitionId>,
    pub metrics: SharedMetrics,
    pub net: SharedNetwork,
    pub store: SharedStore,
    /// The published shard view when `broker_count > 1`; writers route
    /// per-partition through a [`crate::shard::ShardClient`] instead of
    /// the single `broker` above.
    pub shard: Option<crate::shard::SharedShard>,
}

/// The construction loop shared by the built-in factories: one writer per
/// producer, each with a deterministic generator fork (the seed derivation
/// lives here so every mode draws identical record streams — the
/// cross-mode "identical totals / identical planted needles" checks
/// depend on it), wrapped in a [`WriterActor`].
pub(crate) fn build_writers(
    w: &WriterWiring<'_>,
    engine: &mut Engine<Msg>,
    node: NodeId,
    mut make: impl FnMut(super::ProducerParams, super::RecordGen) -> Box<dyn WritePath>,
) -> Vec<ActorId> {
    let mut seed_rng = crate::sim::Rng::new(w.config.seed ^ 0x9D);
    (0..w.config.np)
        .map(|i| {
            let gen = super::make_gen(w.config, &mut seed_rng);
            let params = super::ProducerParams::from_wiring(w, i, node);
            engine.add_actor(Box::new(WriterActor::new(make(params, gen))))
        })
        .collect()
}

/// Builds one mode's writers. Implementations live next to their writer
/// type; the registry hands the launcher the right one for the configured
/// [`WriteMode`].
pub trait WriterFactory {
    /// The mode this factory serves.
    fn mode(&self) -> WriteMode;

    /// Build + register the mode's `Np` writers; return their actor ids.
    /// Every actor must be a [`WriterActor`] so stats extraction can't
    /// miss it.
    fn build(&self, wiring: &WriterWiring<'_>, engine: &mut Engine<Msg>) -> Vec<ActorId>;
}

/// The pluggable factory registry, keyed by [`WriteMode`].
pub struct WriterRegistry {
    factories: Vec<Box<dyn WriterFactory>>,
}

impl WriterRegistry {
    /// An empty registry (plug in your own factories).
    pub fn empty() -> Self {
        Self { factories: Vec::new() }
    }

    /// The three built-in modes: sync, pipelined, sharedmem.
    pub fn builtin() -> Self {
        let mut r = Self::empty();
        r.register(Box::new(super::sync::SyncRpcWriterFactory));
        r.register(Box::new(super::pipelined::PipelinedWriterFactory));
        r.register(Box::new(super::shmem::SharedMemWriterFactory));
        r
    }

    /// Register a factory; replaces any previous factory for the same mode.
    pub fn register(&mut self, factory: Box<dyn WriterFactory>) {
        if let Some(slot) = self.factories.iter_mut().find(|f| f.mode() == factory.mode()) {
            *slot = factory;
        } else {
            self.factories.push(factory);
        }
    }

    pub fn get(&self, mode: WriteMode) -> Option<&dyn WriterFactory> {
        self.factories.iter().find(|f| f.mode() == mode).map(|b| b.as_ref())
    }

    /// Resolve a mode or die loudly — an unregistered mode is a config
    /// error, not a silently producer-less cluster.
    pub fn expect(&self, mode: WriteMode) -> &dyn WriterFactory {
        self.get(mode).unwrap_or_else(|| {
            panic!("no writer factory registered for mode `{}`", mode.name())
        })
    }

    /// The modes currently registered (in registration order).
    pub fn modes(&self) -> Vec<WriteMode> {
        self.factories.iter().map(|f| f.mode()).collect()
    }
}

impl Default for WriterRegistry {
    fn default() -> Self {
        Self::builtin()
    }
}
