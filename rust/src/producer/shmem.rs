//! `WriteMode::SharedMem` — the paper's push-source idea applied to
//! ingestion.
//!
//! The read-side push path (§IV-B) replaces a stream of pull RPCs with one
//! subscription RPC plus shared-memory objects; this writer mirrors that
//! on the write side. The producer is *colocated* with the broker (the
//! premise of the shared store), issues one `WriteSubscribe` RPC, then
//! loops:
//!
//! ```text
//! acquire free object → generate ReqS records into it → seal →
//! SealObject notification → (broker appends + releases) → SealAck
//! ```
//!
//! The payload never crosses the wire and no per-chunk append RPC occupies
//! the dispatcher; only the per-object control notification does. The
//! broker still charges its worker pool the full append service time, so
//! the paper's write/read interference on the worker cores is preserved —
//! what disappears is the producer-side round-trip pacing and the network
//! transfer. Backpressure is object exhaustion: when all objects are in
//! flight the generation loop stalls ([`WriteStatKey::ObjectStalls`]).
//!
//! Fill offsets inside a sealed object are placeholders (0): log offsets
//! are assigned by the broker at append time, exactly like the Append RPC.

use std::collections::HashMap;

use crate::config::WriteMode;
use crate::metrics::{Class, SharedMetrics};
use crate::net::SharedNetwork;
use crate::plasma::SharedStore;
use crate::proto::{
    Chunk, Msg, ObjectId, PartitionId, RpcEnvelope, RpcKind, RpcReply, RpcRequest, StampedChunk,
    SubId, WriteProducerSpec,
};
use crate::shard::ShardClient;
use crate::sim::{Actor, ActorId, Ctx, Engine, Time};

use super::api::{
    WriteAccounting, WriteError, WritePath, WriteStatKey, WriteStats, WriterFactory, WriterWiring,
};
use super::{ProducerParams, RecordGen};

/// Shared-memory writer wiring: the shared producer params (node = the
/// colocated broker node) plus the object pool.
#[derive(Debug, Clone)]
pub struct SharedMemParams {
    pub base: ProducerParams,
    /// Objects in this producer's pool (`write_objects_per_producer`).
    pub objects: usize,
}

/// One sealed object awaiting the broker's append ack.
#[derive(Debug, Clone, Copy)]
struct SealInflight {
    object: ObjectId,
    /// First chunk's partition — the routing key under sharding (a seal
    /// retry after `WrongShard` re-resolves the primary from it).
    partition: PartitionId,
    sent_at: Time,
    attempts: u32,
    /// Generation stamp when the latency tracer sampled this seal.
    produced_at: Option<Time>,
}

/// The colocated shared-memory producer actor.
pub struct SharedMemWriter {
    params: SharedMemParams,
    gen: RecordGen,
    /// One object pool per broker group (one entry when unsharded) —
    /// each group's primary hosts the registration, but the pools all
    /// live in the node-global plasma store.
    group_subs: Vec<Option<SubId>>,
    /// Outstanding `WriteSubscribe` rpc → broker group it registers.
    sub_rpcs: HashMap<u64, usize>,
    next_rpc: u64,
    /// A generated batch parked until an object frees up (at most one),
    /// tagged with the broker group it was staged for.
    parked: Option<(usize, Vec<(PartitionId, Chunk)>)>,
    generating: bool,
    seals: HashMap<u64, SealInflight>,
    done: bool,
    acct: WriteAccounting,
    objects_sealed: u64,
    object_stalls: u64,
    metrics: SharedMetrics,
    net: SharedNetwork,
    store: SharedStore,
    /// Cached shard routing when `broker_count > 1`.
    shard: Option<ShardClient>,
    /// Which broker group the next batch stages (round-robin).
    group_rr: usize,
    /// Seals re-routed after a `WrongShard` refusal.
    shard_retries: u64,
    /// Notifications retransmitted after a deadline expiry against a
    /// broker the coordinator declared dead.
    broker_down_retries: u64,
}

impl SharedMemWriter {
    pub fn new(
        params: SharedMemParams,
        gen: RecordGen,
        metrics: SharedMetrics,
        net: SharedNetwork,
        store: SharedStore,
    ) -> Self {
        assert!(!params.base.partitions.is_empty());
        assert!(params.base.chunk_bytes >= params.base.record_size);
        assert!(params.objects >= 1, "the write pool needs at least one object");
        let shard = params.base.shard.as_ref().map(ShardClient::new);
        let groups = shard.as_ref().map_or(1, |c| c.table().brokers());
        Self {
            params,
            gen,
            group_subs: vec![None; groups],
            sub_rpcs: HashMap::new(),
            next_rpc: 0,
            parked: None,
            generating: false,
            seals: HashMap::new(),
            done: false,
            acct: WriteAccounting::default(),
            objects_sealed: 0,
            object_stalls: 0,
            metrics,
            net,
            store,
            shard,
            group_rr: 0,
            shard_retries: 0,
            broker_down_retries: 0,
        }
    }

    /// Exponential per-attempt deadline, capped at 64× the base (see the
    /// sync writer's twin).
    fn deadline_for(&self, attempts: u32) -> Time {
        self.params.base.rpc_deadline_ns.saturating_mul(1 << attempts.saturating_sub(1).min(6))
    }

    /// The partition set one broker group's pool covers (all partitions
    /// when unsharded).
    fn group_partitions(&self, group: usize) -> Vec<PartitionId> {
        match &self.shard {
            Some(client) => client.table().primaries_of(group),
            None => self.params.base.partitions.clone(),
        }
    }

    /// True once every broker group's registration has acked. A group a
    /// fail-over emptied of partitions counts vacuously: nothing will
    /// ever stage for it, so its registration can't (and needn't) land.
    fn subscribed(&self) -> bool {
        self.group_subs
            .iter()
            .enumerate()
            .all(|(g, s)| s.is_some() || self.group_partitions(g).is_empty())
    }

    /// Step 1: the registration RPC (control-sized; carries no payload) —
    /// one per broker group, sized for that group's request span.
    fn subscribe_group(&mut self, group: usize, ctx: &mut Ctx<'_, Msg>) {
        let partitions = self.group_partitions(group);
        let object_bytes = (self.params.base.chunk_bytes * partitions.len()) as u64;
        let (to, to_node) = match &self.shard {
            Some(client) => client.broker_for(partitions[0]),
            None => (self.params.base.broker, self.params.base.broker_node),
        };
        let rpc = self.next_rpc;
        self.next_rpc += 1;
        self.sub_rpcs.insert(rpc, group);
        let deliver = self.net.borrow_mut().send_control(ctx.now(), self.params.base.node, to_node);
        ctx.send_at(
            deliver,
            to,
            Msg::rpc(RpcRequest {
                id: rpc,
                reply_to: ctx.self_id(),
                from_node: self.params.base.node,
                kind: RpcKind::WriteSubscribe {
                    producer: WriteProducerSpec {
                        producer_actor: ctx.self_id(),
                        partitions,
                        objects: self.params.objects,
                        object_bytes,
                    },
                },
            }),
        );
        // Race the handshake against a deadline too: a broker dying before
        // its WriteSubscribeAck must not wedge the writer forever.
        if self.shard.is_some() && self.params.base.rpc_deadline_ns > 0 {
            ctx.send_self_in(
                self.params.base.rpc_deadline_ns,
                Msg::Timer(rpc | super::DEADLINE_TAG),
            );
        }
    }

    /// Generate the next batch; `GenDone` fires after the per-record cost.
    fn start_generation(&mut self, ctx: &mut Ctx<'_, Msg>) {
        debug_assert!(self.parked.is_none(), "one parked batch at a time");
        let (group, staged) = match &self.shard {
            None => (0, super::stage_request(&mut self.gen, &self.params.base)),
            Some(client) => {
                // Rotate over broker groups, skipping any a fail-over left
                // without primaries — an empty group must not read as "the
                // generator is exhausted". A batch stays within one
                // primary's range so its seal has a single destination.
                let brokers = client.table().brokers();
                let mut group = self.group_rr % brokers;
                let mut parts = Vec::new();
                for _ in 0..brokers {
                    group = self.group_rr % brokers;
                    self.group_rr = (self.group_rr + 1) % brokers;
                    parts = client.table().primaries_of(group);
                    if !parts.is_empty() {
                        break;
                    }
                }
                (group, super::stage_request_for(&mut self.gen, &self.params.base, &parts))
            }
        };
        let Some((chunks, total_records)) = staged else {
            self.done = true;
            return;
        };
        self.generating = true;
        let cost = total_records * self.params.base.cost.producer_record_ns;
        ctx.send_self_in(cost as Time, Msg::GenDone(0));
        self.parked = Some((group, chunks));
    }

    /// Seal the parked batch into a free object and notify the broker;
    /// stall on object exhaustion (the shared-memory backpressure).
    fn try_seal(&mut self, from_generation: bool, ctx: &mut Ctx<'_, Msg>) {
        if self.generating {
            return; // the batch is still being generated
        }
        if let Some((group, chunks)) = self.parked.take() {
            let sub = self.group_subs[group].expect("subscribed before sealing");
            let Some(object) = self.store.borrow_mut().acquire(sub) else {
                self.parked = Some((group, chunks));
                if from_generation {
                    self.object_stalls += 1;
                }
                return; // pool exhausted: resume on the next SealAck
            };
            let partition = chunks[0].0;
            let content: Vec<StampedChunk> = chunks
                .into_iter()
                .map(|(p, chunk)| StampedChunk { partition: p, offset: 0, chunk })
                .collect();
            self.store.borrow_mut().seal(object, content);
            self.objects_sealed += 1;
            let rpc = self.next_rpc;
            self.next_rpc += 1;
            // None whenever tracing is off (sample_produced self-gates).
            let produced_at = self.metrics.borrow_mut().tracer.sample_produced(ctx.now());
            self.seals.insert(
                rpc,
                SealInflight { object, partition, sent_at: ctx.now(), attempts: 1, produced_at },
            );
            self.notify_seal(rpc, ctx);
        }
        if self.parked.is_none() && !self.generating && !self.done {
            self.start_generation(ctx);
        }
    }

    /// Send the `SealObject` control notification (first send or retry).
    /// The destination is re-resolved from the seal's partition on every
    /// send, so a `WrongShard` retry notifies the new primary.
    fn notify_seal(&mut self, rpc: u64, ctx: &mut Ctx<'_, Msg>) {
        let seal = self.seals.get_mut(&rpc).expect("notify of a live seal");
        seal.sent_at = ctx.now();
        let seal = *seal;
        let (to, to_node) = match &self.shard {
            Some(client) => client.broker_for(seal.partition),
            None => (self.params.base.broker, self.params.base.broker_node),
        };
        self.acct.on_issued();
        let deliver = self.net.borrow_mut().send_control(ctx.now(), self.params.base.node, to_node);
        ctx.send_at(
            deliver,
            to,
            Msg::rpc(RpcRequest {
                id: rpc,
                reply_to: ctx.self_id(),
                from_node: self.params.base.node,
                kind: RpcKind::SealObject { id: seal.object, produced_at: seal.produced_at },
            }),
        );
        // Sharded runs race every notification against a deadline (the
        // broker-death path; see the sync writer's twin).
        if self.shard.is_some() && self.params.base.rpc_deadline_ns > 0 {
            let d = self.deadline_for(seal.attempts);
            ctx.send_self_in(d, Msg::Timer(rpc | super::DEADLINE_TAG));
        }
    }

    /// A per-RPC deadline fired — for a pending registration or an
    /// in-flight seal. No-op unless the request is still outstanding and
    /// genuinely expired; on expiry against a declared-dead broker the
    /// route refreshes and the request retransmits (the broker-side
    /// idempotence table dedups a seal that already landed before the
    /// crash), otherwise it re-arms.
    fn on_deadline(&mut self, rpc: u64, ctx: &mut Ctx<'_, Msg>) {
        if let Some(&group) = self.sub_rpcs.get(&rpc) {
            let parts = self.group_partitions(group);
            if parts.is_empty() {
                // A fail-over emptied the group mid-handshake: nothing
                // will ever stage for it — resolve vacuously.
                self.sub_rpcs.remove(&rpc);
                if self.subscribed() && !self.generating && self.parked.is_none() && !self.done {
                    self.start_generation(ctx);
                }
                return;
            }
            let down = self
                .shard
                .as_ref()
                .is_some_and(|c| c.actor_down(c.broker_for(parts[0]).0));
            if down {
                if let Some(client) = self.shard.as_mut() {
                    client.refresh();
                }
                self.broker_down_retries += 1;
                self.sub_rpcs.remove(&rpc);
                self.subscribe_group(group, ctx);
            } else {
                ctx.send_self_in(
                    self.params.base.rpc_deadline_ns,
                    Msg::Timer(rpc | super::DEADLINE_TAG),
                );
            }
            return;
        }
        let Some(seal) = self.seals.get(&rpc) else { return };
        if ctx.now() < seal.sent_at + self.deadline_for(seal.attempts) {
            return;
        }
        let partition = seal.partition;
        let Some(client) = self.shard.as_mut() else { return };
        let (home, _) = client.broker_for(partition);
        if client.actor_down(home) {
            client.refresh();
            self.broker_down_retries += 1;
            self.seals.get_mut(&rpc).expect("checked above").attempts += 1;
            self.notify_seal(rpc, ctx);
        } else {
            let d = self.deadline_for(self.seals[&rpc].attempts);
            ctx.send_self_in(d, Msg::Timer(rpc | super::DEADLINE_TAG));
        }
    }

    fn on_reply(&mut self, env: RpcEnvelope, ctx: &mut Ctx<'_, Msg>) {
        match env.reply {
            RpcReply::WriteSubscribeAck { sub } => {
                let group =
                    self.sub_rpcs.remove(&env.id).expect("ack matches a pending registration");
                self.group_subs[group] = Some(sub);
                // Generation starts once every group's pool is registered.
                if self.subscribed() {
                    self.start_generation(ctx);
                }
            }
            RpcReply::SealAck { records, bytes } => {
                let seal = self.seals.remove(&env.id).expect("ack matches an in-flight seal");
                let rtt = ctx.now() - seal.sent_at;
                self.acct.on_acked(records, bytes, rtt);
                {
                    let mut m = self.metrics.borrow_mut();
                    m.record(Class::ProducerRecords, self.params.base.entity, ctx.now(), records);
                    if m.tracer.enabled() {
                        m.tracer.note_append_latency(ctx.now(), rtt);
                    }
                }
                // The broker released the object before acking: a parked
                // batch can seal immediately.
                self.try_seal(false, ctx);
            }
            RpcReply::Error { reason } if self.sub_rpcs.contains_key(&env.id) => {
                // The registration itself failed: nothing to retry into.
                self.sub_rpcs.remove(&env.id);
                self.acct.last_error = Some(WriteError::SubscribeFailed { reason });
                self.acct.errors += 1;
                self.done = true;
            }
            RpcReply::Error { reason } => {
                let attempts =
                    self.seals.get(&env.id).expect("error matches an in-flight seal").attempts;
                if self.acct.on_rejected(&self.params.base.retry, attempts, reason) {
                    self.seals.get_mut(&env.id).expect("just checked").attempts += 1;
                    ctx.send_self_in(self.params.base.retry.backoff_ns, Msg::Timer(env.id));
                    return;
                }
                // Retries exhausted: reclaim the object ourselves (we are
                // colocated with the store) and keep producing.
                let dropped = self.seals.remove(&env.id).expect("just checked");
                self.store.borrow_mut().release(dropped.object);
                self.try_seal(false, ctx);
            }
            RpcReply::WrongShard { epoch } => match self.shard.as_mut() {
                Some(client) => {
                    // Stale route: refresh the cached table and re-notify
                    // after backoff — the object stays sealed and the retry
                    // lands at the new primary. Registrations that raced a
                    // rebalance re-register the same way (Timer re-issues
                    // the WriteSubscribe with the refreshed partition set).
                    client.refresh();
                    self.shard_retries += 1;
                    if let Some(seal) = self.seals.get_mut(&env.id) {
                        seal.attempts += 1;
                    } else {
                        assert!(
                            self.sub_rpcs.contains_key(&env.id),
                            "refusal matches a seal or a registration"
                        );
                    }
                    ctx.send_self_in(self.params.base.retry.backoff_ns, Msg::Timer(env.id));
                    return;
                }
                None => {
                    // No routing view to refresh: surface the typed error,
                    // reclaim the object, keep producing.
                    self.acct.errors += 1;
                    self.acct.last_error = Some(WriteError::WrongShard { epoch });
                    let dropped = self.seals.remove(&env.id).expect("refusal matches a seal");
                    self.store.borrow_mut().release(dropped.object);
                    self.try_seal(false, ctx);
                }
            },
            other => {
                panic!("sharedmem writer {}: unexpected reply {other:?}", self.params.base.entity)
            }
        }
    }

    pub fn records_sent(&self) -> u64 {
        self.acct.records_sent
    }

    pub fn planted(&self) -> u64 {
        self.gen.planted()
    }

    pub fn is_subscribed(&self) -> bool {
        self.subscribed()
    }

    /// Generation stalls on object exhaustion so far.
    pub fn object_stalls(&self) -> u64 {
        self.object_stalls
    }
}

impl Actor<Msg> for SharedMemWriter {
    fn on_start(&mut self, ctx: &mut Ctx<'_, Msg>) {
        for group in 0..self.group_subs.len() {
            self.subscribe_group(group, ctx);
        }
    }

    fn on_event(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        match msg {
            Msg::GenDone(_) => {
                self.generating = false;
                self.try_seal(true, ctx);
            }
            Msg::Reply(env) => self.on_reply(*env, ctx),
            Msg::Timer(tag) if tag & super::DEADLINE_TAG != 0 => {
                self.on_deadline(tag & !super::DEADLINE_TAG, ctx)
            }
            Msg::Timer(rpc) => {
                // A backed-off registration retry re-issues the subscribe
                // with the refreshed table; everything else is a seal.
                if let Some(group) = self.sub_rpcs.remove(&rpc) {
                    self.subscribe_group(group, ctx);
                } else {
                    self.notify_seal(rpc, ctx);
                }
            }
            other => {
                panic!("sharedmem writer {}: unexpected {other:?}", self.params.base.entity)
            }
        }
    }

    fn label(&self) -> String {
        format!("sharedmem-writer#{}", self.params.base.entity)
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}

impl WritePath for SharedMemWriter {
    fn mode(&self) -> WriteMode {
        WriteMode::SharedMem
    }

    fn stats(&self) -> WriteStats {
        let mut extras = super::api::WriteStatExtras::new();
        extras.insert(WriteStatKey::ObjectsSealed, self.objects_sealed);
        extras.insert(WriteStatKey::Subscribed, self.subscribed() as u64);
        extras.insert(WriteStatKey::ObjectStalls, self.object_stalls);
        if self.shard_retries > 0 {
            extras.insert(WriteStatKey::ShardRetries, self.shard_retries);
        }
        if self.broker_down_retries > 0 {
            extras.insert(WriteStatKey::BrokerDownRetries, self.broker_down_retries);
        }
        // One fill thread; acks arrive as notifications.
        self.acct.stats(self.gen.planted(), 1, extras)
    }
}

/// Builds the `Np` shared-memory producers — on the *broker's* node: the
/// colocation premise is what makes the plasma store reachable.
pub struct SharedMemWriterFactory;

impl WriterFactory for SharedMemWriterFactory {
    fn mode(&self) -> WriteMode {
        WriteMode::SharedMem
    }

    fn build(&self, w: &WriterWiring<'_>, engine: &mut Engine<Msg>) -> Vec<ActorId> {
        // On the broker's node: colocation is what makes the store reachable.
        super::api::build_writers(w, engine, w.broker_node, |base, gen| {
            Box::new(SharedMemWriter::new(
                SharedMemParams { base, objects: w.config.write_objects_per_producer },
                gen,
                w.metrics.clone(),
                w.net.clone(),
                w.store.clone(),
            ))
        })
    }
}
