//! `WriteMode::SharedMem` — the paper's push-source idea applied to
//! ingestion.
//!
//! The read-side push path (§IV-B) replaces a stream of pull RPCs with one
//! subscription RPC plus shared-memory objects; this writer mirrors that
//! on the write side. The producer is *colocated* with the broker (the
//! premise of the shared store), issues one `WriteSubscribe` RPC, then
//! loops:
//!
//! ```text
//! acquire free object → generate ReqS records into it → seal →
//! SealObject notification → (broker appends + releases) → SealAck
//! ```
//!
//! The payload never crosses the wire and no per-chunk append RPC occupies
//! the dispatcher; only the per-object control notification does. The
//! broker still charges its worker pool the full append service time, so
//! the paper's write/read interference on the worker cores is preserved —
//! what disappears is the producer-side round-trip pacing and the network
//! transfer. Backpressure is object exhaustion: when all objects are in
//! flight the generation loop stalls ([`WriteStatKey::ObjectStalls`]).
//!
//! Fill offsets inside a sealed object are placeholders (0): log offsets
//! are assigned by the broker at append time, exactly like the Append RPC.

use std::collections::HashMap;

use crate::config::WriteMode;
use crate::metrics::{Class, SharedMetrics};
use crate::net::SharedNetwork;
use crate::plasma::SharedStore;
use crate::proto::{
    Chunk, Msg, ObjectId, PartitionId, RpcEnvelope, RpcKind, RpcReply, RpcRequest, StampedChunk,
    SubId, WriteProducerSpec,
};
use crate::sim::{Actor, ActorId, Ctx, Engine, Time};

use super::api::{
    WriteAccounting, WriteError, WritePath, WriteStatKey, WriteStats, WriterFactory, WriterWiring,
};
use super::{ProducerParams, RecordGen};

/// Shared-memory writer wiring: the shared producer params (node = the
/// colocated broker node) plus the object pool.
#[derive(Debug, Clone)]
pub struct SharedMemParams {
    pub base: ProducerParams,
    /// Objects in this producer's pool (`write_objects_per_producer`).
    pub objects: usize,
}

/// One sealed object awaiting the broker's append ack.
#[derive(Debug, Clone, Copy)]
struct SealInflight {
    object: ObjectId,
    sent_at: Time,
    attempts: u32,
    /// Generation stamp when the latency tracer sampled this seal.
    produced_at: Option<Time>,
}

/// The colocated shared-memory producer actor.
pub struct SharedMemWriter {
    params: SharedMemParams,
    gen: RecordGen,
    sub: Option<SubId>,
    next_rpc: u64,
    /// A generated batch parked until an object frees up (at most one).
    parked: Option<Vec<(PartitionId, Chunk)>>,
    generating: bool,
    seals: HashMap<u64, SealInflight>,
    done: bool,
    acct: WriteAccounting,
    objects_sealed: u64,
    object_stalls: u64,
    metrics: SharedMetrics,
    net: SharedNetwork,
    store: SharedStore,
}

impl SharedMemWriter {
    pub fn new(
        params: SharedMemParams,
        gen: RecordGen,
        metrics: SharedMetrics,
        net: SharedNetwork,
        store: SharedStore,
    ) -> Self {
        assert!(!params.base.partitions.is_empty());
        assert!(params.base.chunk_bytes >= params.base.record_size);
        assert!(params.objects >= 1, "the write pool needs at least one object");
        Self {
            params,
            gen,
            sub: None,
            next_rpc: 0,
            parked: None,
            generating: false,
            seals: HashMap::new(),
            done: false,
            acct: WriteAccounting::default(),
            objects_sealed: 0,
            object_stalls: 0,
            metrics,
            net,
            store,
        }
    }

    /// One producer request worth of object capacity (`ReqS`).
    fn object_bytes(&self) -> u64 {
        (self.params.base.chunk_bytes * self.params.base.partitions.len()) as u64
    }

    /// Step 1: the single registration RPC (control-sized; carries no
    /// payload).
    fn subscribe(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let rpc = self.next_rpc;
        self.next_rpc += 1;
        let deliver = self.net.borrow_mut().send_control(
            ctx.now(),
            self.params.base.node,
            self.params.base.broker_node,
        );
        ctx.send_at(
            deliver,
            self.params.base.broker,
            Msg::rpc(RpcRequest {
                id: rpc,
                reply_to: ctx.self_id(),
                from_node: self.params.base.node,
                kind: RpcKind::WriteSubscribe {
                    producer: WriteProducerSpec {
                        producer_actor: ctx.self_id(),
                        partitions: self.params.base.partitions.clone(),
                        objects: self.params.objects,
                        object_bytes: self.object_bytes(),
                    },
                },
            }),
        );
    }

    /// Generate the next batch; `GenDone` fires after the per-record cost.
    fn start_generation(&mut self, ctx: &mut Ctx<'_, Msg>) {
        debug_assert!(self.parked.is_none(), "one parked batch at a time");
        let Some((chunks, total_records)) =
            super::stage_request(&mut self.gen, &self.params.base)
        else {
            self.done = true;
            return;
        };
        self.generating = true;
        let cost = total_records * self.params.base.cost.producer_record_ns;
        ctx.send_self_in(cost as Time, Msg::GenDone(0));
        self.parked = Some(chunks);
    }

    /// Seal the parked batch into a free object and notify the broker;
    /// stall on object exhaustion (the shared-memory backpressure).
    fn try_seal(&mut self, from_generation: bool, ctx: &mut Ctx<'_, Msg>) {
        if self.generating {
            return; // the batch is still being generated
        }
        if let Some(chunks) = self.parked.take() {
            let sub = self.sub.expect("subscribed before sealing");
            let Some(object) = self.store.borrow_mut().acquire(sub) else {
                self.parked = Some(chunks);
                if from_generation {
                    self.object_stalls += 1;
                }
                return; // pool exhausted: resume on the next SealAck
            };
            let content: Vec<StampedChunk> = chunks
                .into_iter()
                .map(|(p, chunk)| StampedChunk { partition: p, offset: 0, chunk })
                .collect();
            self.store.borrow_mut().seal(object, content);
            self.objects_sealed += 1;
            let rpc = self.next_rpc;
            self.next_rpc += 1;
            // None whenever tracing is off (sample_produced self-gates).
            let produced_at = self.metrics.borrow_mut().tracer.sample_produced(ctx.now());
            self.seals.insert(
                rpc,
                SealInflight { object, sent_at: ctx.now(), attempts: 1, produced_at },
            );
            self.notify_seal(rpc, ctx);
        }
        if self.parked.is_none() && !self.generating && !self.done {
            self.start_generation(ctx);
        }
    }

    /// Send the `SealObject` control notification (first send or retry).
    fn notify_seal(&mut self, rpc: u64, ctx: &mut Ctx<'_, Msg>) {
        let seal = self.seals.get_mut(&rpc).expect("notify of a live seal");
        seal.sent_at = ctx.now();
        self.acct.on_issued();
        let deliver = self.net.borrow_mut().send_control(
            ctx.now(),
            self.params.base.node,
            self.params.base.broker_node,
        );
        ctx.send_at(
            deliver,
            self.params.base.broker,
            Msg::rpc(RpcRequest {
                id: rpc,
                reply_to: ctx.self_id(),
                from_node: self.params.base.node,
                kind: RpcKind::SealObject { id: seal.object, produced_at: seal.produced_at },
            }),
        );
    }

    fn on_reply(&mut self, env: RpcEnvelope, ctx: &mut Ctx<'_, Msg>) {
        match env.reply {
            RpcReply::WriteSubscribeAck { sub } => {
                self.sub = Some(sub);
                self.start_generation(ctx);
            }
            RpcReply::SealAck { records, bytes } => {
                let seal = self.seals.remove(&env.id).expect("ack matches an in-flight seal");
                let rtt = ctx.now() - seal.sent_at;
                self.acct.on_acked(records, bytes, rtt);
                {
                    let mut m = self.metrics.borrow_mut();
                    m.record(Class::ProducerRecords, self.params.base.entity, ctx.now(), records);
                    if m.tracer.enabled() {
                        m.tracer.note_append_latency(ctx.now(), rtt);
                    }
                }
                // The broker released the object before acking: a parked
                // batch can seal immediately.
                self.try_seal(false, ctx);
            }
            RpcReply::Error { reason } if self.sub.is_none() => {
                // The registration itself failed: nothing to retry into.
                self.acct.last_error = Some(WriteError::SubscribeFailed { reason });
                self.acct.errors += 1;
                self.done = true;
            }
            RpcReply::Error { reason } => {
                let attempts =
                    self.seals.get(&env.id).expect("error matches an in-flight seal").attempts;
                if self.acct.on_rejected(&self.params.base.retry, attempts, reason) {
                    self.seals.get_mut(&env.id).expect("just checked").attempts += 1;
                    ctx.send_self_in(self.params.base.retry.backoff_ns, Msg::Timer(env.id));
                    return;
                }
                // Retries exhausted: reclaim the object ourselves (we are
                // colocated with the store) and keep producing.
                let dropped = self.seals.remove(&env.id).expect("just checked");
                self.store.borrow_mut().release(dropped.object);
                self.try_seal(false, ctx);
            }
            other => {
                panic!("sharedmem writer {}: unexpected reply {other:?}", self.params.base.entity)
            }
        }
    }

    pub fn records_sent(&self) -> u64 {
        self.acct.records_sent
    }

    pub fn planted(&self) -> u64 {
        self.gen.planted()
    }

    pub fn is_subscribed(&self) -> bool {
        self.sub.is_some()
    }

    /// Generation stalls on object exhaustion so far.
    pub fn object_stalls(&self) -> u64 {
        self.object_stalls
    }
}

impl Actor<Msg> for SharedMemWriter {
    fn on_start(&mut self, ctx: &mut Ctx<'_, Msg>) {
        self.subscribe(ctx);
    }

    fn on_event(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        match msg {
            Msg::GenDone(_) => {
                self.generating = false;
                self.try_seal(true, ctx);
            }
            Msg::Reply(env) => self.on_reply(*env, ctx),
            Msg::Timer(rpc) => self.notify_seal(rpc, ctx),
            other => {
                panic!("sharedmem writer {}: unexpected {other:?}", self.params.base.entity)
            }
        }
    }

    fn label(&self) -> String {
        format!("sharedmem-writer#{}", self.params.base.entity)
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}

impl WritePath for SharedMemWriter {
    fn mode(&self) -> WriteMode {
        WriteMode::SharedMem
    }

    fn stats(&self) -> WriteStats {
        let mut extras = super::api::WriteStatExtras::new();
        extras.insert(WriteStatKey::ObjectsSealed, self.objects_sealed);
        extras.insert(WriteStatKey::Subscribed, self.sub.is_some() as u64);
        extras.insert(WriteStatKey::ObjectStalls, self.object_stalls);
        // One fill thread; acks arrive as notifications.
        self.acct.stats(self.gen.planted(), 1, extras)
    }
}

/// Builds the `Np` shared-memory producers — on the *broker's* node: the
/// colocation premise is what makes the plasma store reachable.
pub struct SharedMemWriterFactory;

impl WriterFactory for SharedMemWriterFactory {
    fn mode(&self) -> WriteMode {
        WriteMode::SharedMem
    }

    fn build(&self, w: &WriterWiring<'_>, engine: &mut Engine<Msg>) -> Vec<ActorId> {
        // On the broker's node: colocation is what makes the store reachable.
        super::api::build_writers(w, engine, w.broker_node, |base, gen| {
            Box::new(SharedMemWriter::new(
                SharedMemParams { base, objects: w.config.write_objects_per_producer },
                gen,
                w.metrics.clone(),
                w.net.clone(),
                w.store.clone(),
            ))
        })
    }
}
