//! Write-path tests against a real broker actor: the sync baseline, the
//! pipelined window, the shared-memory path, and rejected-append handling.

use super::*;
use crate::broker::{Broker, BrokerParams, StoreParams};
use crate::config::{NetworkProfile, WriteMode};
use crate::metrics::{Class, MetricsHub, SharedMetrics};
use crate::net::{Network, SharedNetwork};
use crate::plasma::{ObjectStore, SharedStore};
use crate::proto::{Msg, PartitionId};
use crate::sim::{ActorId, Engine, Rng, MICROS, SECOND};
use crate::wikipedia::CorpusReader;

struct Rig {
    engine: Engine<Msg>,
    producer: ActorId,
    broker: ActorId,
    metrics: SharedMetrics,
    net: SharedNetwork,
    store: SharedStore,
}

/// Engine + broker on node 0 hosting `ns` partitions; the writer slot is
/// filled by the mode-specific constructors below.
fn base_rig(ns: usize) -> Rig {
    let mut engine = Engine::new(3);
    let net = Network::shared(NetworkProfile::INFINIBAND, NetworkProfile::LOOPBACK);
    let store = ObjectStore::shared();
    let metrics = MetricsHub::shared();
    let broker = engine.add_actor(Box::new(Broker::new(
        BrokerParams {
            node: 0,
            worker_cores: 8,
            push_threads: 0,
            store: StoreParams::memory(8 << 20),
            partitions: (0..ns).map(PartitionId).collect(),
            backup: None,
            is_backup: false,
            cost: Default::default(),
        },
        net.clone(),
        store.clone(),
        metrics.clone(),
        0,
    )));
    Rig { engine, producer: ActorId(0), broker, metrics, net, store }
}

/// Writer params against the rig's broker. `partitions` defaults to all
/// hosted partitions; tests targeting unknown partitions override it.
fn params(
    r: &Rig,
    node: usize,
    chunk_bytes: usize,
    record_size: usize,
    ns: usize,
) -> ProducerParams {
    ProducerParams {
        entity: 0,
        node,
        broker: r.broker,
        broker_node: 0,
        partitions: (0..ns).map(PartitionId).collect(),
        chunk_bytes,
        record_size,
        retry: RetryPolicy { max_retries: 3, backoff_ns: 10 * MICROS },
        cost: Default::default(),
        data_plane: crate::config::DataPlane::Sim,
        shard: None,
        rpc_deadline_ns: 0,
    }
}

fn sync_rig(gen: RecordGen, chunk_bytes: usize, record_size: usize, ns: usize) -> Rig {
    let mut r = base_rig(ns);
    let p = params(&r, 1, chunk_bytes, record_size, ns);
    r.producer = r.engine.add_actor(Box::new(Producer::new(
        p,
        gen,
        r.metrics.clone(),
        r.net.clone(),
    )));
    r
}

fn pipelined_rig(gen: RecordGen, chunk_bytes: usize, ns: usize, window: usize) -> Rig {
    let mut r = base_rig(ns);
    let base = params(&r, 1, chunk_bytes, 100, ns);
    r.producer = r.engine.add_actor(Box::new(PipelinedWriter::new(
        PipelinedParams { base, inflight_window: window },
        gen,
        r.metrics.clone(),
        r.net.clone(),
    )));
    r
}

fn shmem_rig(gen: RecordGen, chunk_bytes: usize, ns: usize, objects: usize) -> Rig {
    let mut r = base_rig(ns);
    // Colocated: the shared-memory writer lives on the broker's node.
    let base = params(&r, 0, chunk_bytes, 100, ns);
    r.producer = r.engine.add_actor(Box::new(SharedMemWriter::new(
        SharedMemParams { base, objects },
        gen,
        r.metrics.clone(),
        r.net.clone(),
        r.store.clone(),
    )));
    r
}

// ---------------------------------------------------------------------------
// SyncRpc — the §V-A baseline (unchanged behaviour)
// ---------------------------------------------------------------------------

#[test]
fn producer_appends_continuously() {
    let mut r = sync_rig(RecordGen::Sim, 1024, 100, 4);
    r.engine.run_until(SECOND);
    let total = r.metrics.borrow().total(Class::ProducerRecords);
    assert!(total > 100_000, "1s of appends: {total}");
    let sent = r.engine.actor_as::<Producer>(r.producer).unwrap().records_sent();
    assert_eq!(sent, total);
}

#[test]
fn pacing_is_generation_plus_round_trip() {
    // 10 records per chunk x 4 partitions = 40 records per request at
    // 200 ns each = 8 us generation; RTT adds a few us more. The rate must
    // sit near records/(gen+rtt), well under the pure-generation bound.
    let mut r = sync_rig(RecordGen::Sim, 1024, 100, 4);
    r.engine.run_until(SECOND);
    let total = r.metrics.borrow().total(Class::ProducerRecords);
    let gen_bound = SECOND as u64 / 200; // 5M records/s at 200ns
    assert!(total < gen_bound, "sync RPC must slow the loop: {total}");
    assert!(total > gen_bound / 10, "but not by 10x: {total}");
}

#[test]
fn larger_chunks_raise_throughput() {
    let mut small = sync_rig(RecordGen::Sim, 1024, 100, 8);
    small.engine.run_until(SECOND);
    let t_small = small.metrics.borrow().total(Class::ProducerRecords);
    let mut big = sync_rig(RecordGen::Sim, 128 * 1024, 100, 8);
    big.engine.run_until(SECOND);
    let t_big = big.metrics.borrow().total(Class::ProducerRecords);
    assert!(
        t_big > t_small * 2,
        "paper Fig. 3 shape: chunk size grows throughput ({t_small} -> {t_big})"
    );
}

#[test]
fn synthetic_generator_plants_needles() {
    let gen = RecordGen::Synthetic {
        rng: Rng::new(5),
        needle: b"needle".to_vec(),
        plant_permille: 100, // 10%
        planted: 0,
    };
    let mut r = sync_rig(gen, 4096, 100, 2);
    r.engine.run_until(SECOND / 10);
    let p = r.engine.actor_as::<Producer>(r.producer).unwrap();
    let sent = p.records_sent();
    let planted = p.planted();
    assert!(sent > 1000);
    let ratio = planted as f64 / sent as f64;
    assert!((0.05..0.15).contains(&ratio), "plant ratio {ratio}");
}

#[test]
fn corpus_producer_stops_when_exhausted() {
    let gen = RecordGen::Corpus(CorpusReader::new(2048, 500));
    let mut r = sync_rig(gen, 16 * 1024, 2048, 2);
    r.engine.run_until(10 * SECOND);
    let p = r.engine.actor_as::<Producer>(r.producer).unwrap();
    assert_eq!(p.records_sent(), 500, "bounded volume then stop (paper Fig. 9)");
}

#[test]
fn corpus_partial_final_request_is_sent() {
    // 30 records of budget with 8 records/chunk x 2 partitions = 16/request:
    // the last request is partial and must still be appended.
    let gen = RecordGen::Corpus(CorpusReader::new(2048, 30));
    let mut r = sync_rig(gen, 16 * 1024, 2048, 2);
    r.engine.run_until(10 * SECOND);
    assert_eq!(r.metrics.borrow().total(Class::ProducerRecords), 30);
}

#[test]
fn sync_stats_account_every_ack() {
    let mut r = sync_rig(RecordGen::Sim, 1024, 100, 4);
    r.engine.run_until(SECOND / 10);
    let stats = r.engine.actor_as::<Producer>(r.producer).unwrap().stats();
    assert!(
        stats.appends_issued - stats.appends_acked <= 1,
        "at most one append in flight: {stats:?}"
    );
    assert!(stats.appends_acked > 100);
    assert!(stats.mean_append_ns() > 0, "round-trips measured");
    assert_eq!(stats.records_sent, stats.bytes_sent / 100, "RecS=100");
    assert_eq!(stats.threads, 1);
    assert_eq!(stats.extra(WriteStatKey::Errors), 0);
    assert!(stats.last_error.is_none());
}

// ---------------------------------------------------------------------------
// Rejected appends: typed errors + bounded retry (no panic)
// ---------------------------------------------------------------------------

#[test]
fn rejected_append_retries_then_surfaces_typed_error() {
    // The broker hosts partitions 0..4; the producer appends to p7 only —
    // every append is rejected. The old producer panicked here.
    let mut r = base_rig(4);
    let mut p = params(&r, 1, 1024, 100, 4);
    p.partitions = vec![PartitionId(7)];
    r.producer = r.engine.add_actor(Box::new(Producer::new(
        p,
        RecordGen::Sim,
        r.metrics.clone(),
        r.net.clone(),
    )));
    r.engine.run_until(SECOND / 100);
    let stats = r.engine.actor_as::<Producer>(r.producer).unwrap().stats();
    assert!(stats.extra(WriteStatKey::Errors) >= 1, "gave up at least once: {stats:?}");
    assert!(stats.extra(WriteStatKey::Retries) >= 3, "bounded retries ran: {stats:?}");
    assert_eq!(stats.records_sent, 0);
    match &stats.last_error {
        Some(WriteError::Rejected { reason, attempts }) => {
            assert!(reason.contains("unknown partition"), "{reason}");
            assert_eq!(*attempts, 4, "1 try + 3 retries");
        }
        other => panic!("expected Rejected, got {other:?}"),
    }
}

#[test]
fn pipelined_rejections_free_their_window_slots() {
    let mut r = base_rig(4);
    let mut base = params(&r, 1, 1024, 100, 4);
    base.partitions = vec![PartitionId(9)];
    r.producer = r.engine.add_actor(Box::new(PipelinedWriter::new(
        PipelinedParams { base, inflight_window: 2 },
        RecordGen::Sim,
        r.metrics.clone(),
        r.net.clone(),
    )));
    r.engine.run_until(SECOND / 100);
    let stats = r.engine.actor_as::<PipelinedWriter>(r.producer).unwrap().stats();
    assert!(stats.extra(WriteStatKey::Errors) >= 2, "keeps producing past failures: {stats:?}");
    assert_eq!(stats.records_sent, 0);
    assert!(stats.last_error.is_some());
}

// ---------------------------------------------------------------------------
// Pipelined — bounded in-flight window
// ---------------------------------------------------------------------------

#[test]
fn pipelining_overlaps_generation_with_round_trips() {
    // Same setup as the sync pacing test: generation 8 us per request,
    // RTT a few us. With an 8-deep window the round-trip no longer gates
    // the loop, so throughput must clearly beat sync.
    let mut sync = sync_rig(RecordGen::Sim, 1024, 100, 4);
    sync.engine.run_until(SECOND);
    let t_sync = sync.metrics.borrow().total(Class::ProducerRecords);
    let mut pipe = pipelined_rig(RecordGen::Sim, 1024, 4, 8);
    pipe.engine.run_until(SECOND);
    let t_pipe = pipe.metrics.borrow().total(Class::ProducerRecords);
    assert!(
        t_pipe as f64 > t_sync as f64 * 1.2,
        "pipelining must overlap the ack wait: {t_sync} -> {t_pipe}"
    );
}

#[test]
fn pipelined_window_is_respected() {
    let mut r = pipelined_rig(RecordGen::Sim, 1024, 4, 3);
    r.engine.run_until(SECOND / 10);
    let stats = r.engine.actor_as::<PipelinedWriter>(r.producer).unwrap().stats();
    let peak = stats.extra(WriteStatKey::InflightPeak);
    assert!(peak >= 2, "the window actually pipelines: peak {peak}");
    assert!(peak <= 3, "bounded by write_inflight: peak {peak}");
    assert_eq!(stats.threads, 2);
}

#[test]
fn pipelined_acks_stay_in_partition_order_on_fifo_paths() {
    // Single broker, FIFO network: appends complete in send order, so the
    // per-partition sequencers never observe a reordering — the counter
    // exists for multi-path deployments, not this topology.
    let mut r = pipelined_rig(RecordGen::Sim, 1024, 4, 8);
    r.engine.run_until(SECOND / 10);
    let w = r.engine.actor_as::<PipelinedWriter>(r.producer).unwrap();
    assert!(w.records_sent() > 0);
    assert_eq!(w.acks_reordered(), 0);
}

#[test]
fn pipelined_bounded_generator_sends_exact_budget() {
    let gen = RecordGen::BoundedSim { remaining: 1000 };
    let mut r = pipelined_rig(gen, 1024, 4, 8);
    r.engine.run_until(10 * SECOND);
    let w = r.engine.actor_as::<PipelinedWriter>(r.producer).unwrap();
    assert_eq!(w.records_sent(), 1000, "in-flight tail drains after exhaustion");
}

// ---------------------------------------------------------------------------
// SharedMem — colocated plasma-object ingestion
// ---------------------------------------------------------------------------

#[test]
fn sharedmem_writer_appends_through_objects() {
    let mut r = shmem_rig(RecordGen::Sim, 1024, 4, 4);
    r.engine.run_until(SECOND / 10);
    let stats = r.engine.actor_as::<SharedMemWriter>(r.producer).unwrap().stats();
    assert!(stats.records_sent > 1000, "seals flow: {stats:?}");
    assert_eq!(stats.extra(WriteStatKey::Subscribed), 1);
    assert!(stats.extra(WriteStatKey::ObjectsSealed) >= stats.appends_acked);
    // The broker's logs received exactly the acked records.
    let produced = stats.records_sent;
    let b = r.engine.actor_as::<Broker>(r.broker).unwrap();
    let appended: u64 = (0..4)
        .map(|p| b.partition(PartitionId(p)).unwrap().total_appended_records())
        .sum();
    assert!(appended >= produced, "acked records are in the log: {appended} vs {produced}");
}

#[test]
fn sharedmem_single_object_serialises_the_loop() {
    // One object forces generate → seal → wait-ack serialisation; a few
    // objects pipeline it. With a single small-chunk partition the seal
    // round-trip (fixed RPC costs) outweighs the 2 us generation, so the
    // one-object writer must stall. Throughput must reflect the depth.
    let mut one = shmem_rig(RecordGen::Sim, 1024, 1, 1);
    one.engine.run_until(SECOND / 4);
    let t_one = one.metrics.borrow().total(Class::ProducerRecords);
    let s_one = one.engine.actor_as::<SharedMemWriter>(one.producer).unwrap().stats();
    let mut four = shmem_rig(RecordGen::Sim, 1024, 1, 4);
    four.engine.run_until(SECOND / 4);
    let t_four = four.metrics.borrow().total(Class::ProducerRecords);
    assert!(
        s_one.extra(WriteStatKey::ObjectStalls) > 0,
        "object exhaustion is the backpressure: {s_one:?}"
    );
    assert!(t_four > t_one, "a deeper pool pipelines fills: {t_one} -> {t_four}");
}

#[test]
fn sharedmem_bounded_generator_sends_exact_budget() {
    let gen = RecordGen::BoundedSim { remaining: 777 };
    let mut r = shmem_rig(gen, 1024, 4, 2);
    r.engine.run_until(10 * SECOND);
    let w = r.engine.actor_as::<SharedMemWriter>(r.producer).unwrap();
    assert_eq!(w.records_sent(), 777);
}

// ---------------------------------------------------------------------------
// Cross-mode: identical generation, identical planted needles
// ---------------------------------------------------------------------------

#[test]
fn planted_needles_are_identical_across_write_modes() {
    // The generator sequence is a function of the seed and the records
    // drawn, not of the transport — all three writers plant identically on
    // a bounded budget.
    let mk = || RecordGen::Synthetic {
        rng: Rng::new(42),
        needle: b"needle".to_vec(),
        plant_permille: 100,
        planted: 0,
    };
    let budget = SECOND / 20;
    let mut sync = sync_rig(mk(), 2048, 100, 2);
    sync.engine.run_until(budget);
    let mut pipe = pipelined_rig(mk(), 2048, 2, 4);
    pipe.engine.run_until(budget);
    let mut shm = shmem_rig(mk(), 2048, 2, 4);
    shm.engine.run_until(budget);
    let s = sync.engine.actor_as::<Producer>(sync.producer).unwrap();
    let (s_sent, s_planted) = (s.records_sent(), s.planted());
    let p = pipe.engine.actor_as::<PipelinedWriter>(pipe.producer).unwrap();
    let m = shm.engine.actor_as::<SharedMemWriter>(shm.producer).unwrap();
    assert!(s_sent > 0 && p.records_sent() > 0 && m.records_sent() > 0);
    // Per-record plant probability is identical; spot-check the ratio on
    // each mode rather than absolute counts (they produce different
    // volumes in the same wall-clock).
    for (sent, planted, label) in [
        (s_sent, s_planted, "sync"),
        (p.records_sent(), p.planted(), "pipelined"),
        (m.records_sent(), m.planted(), "sharedmem"),
    ] {
        let ratio = planted as f64 / sent as f64;
        assert!((0.05..0.15).contains(&ratio), "{label}: plant ratio {ratio}");
    }
}

#[test]
fn write_modes_report_their_mode() {
    let mut sync = sync_rig(RecordGen::Sim, 1024, 100, 2);
    assert_eq!(
        sync.engine.actor_as::<Producer>(sync.producer).unwrap().mode(),
        WriteMode::SyncRpc
    );
    let mut pipe = pipelined_rig(RecordGen::Sim, 1024, 2, 2);
    assert_eq!(
        pipe.engine.actor_as::<PipelinedWriter>(pipe.producer).unwrap().mode(),
        WriteMode::Pipelined
    );
    let mut shm = shmem_rig(RecordGen::Sim, 1024, 2, 2);
    assert_eq!(
        shm.engine.actor_as::<SharedMemWriter>(shm.producer).unwrap().mode(),
        WriteMode::SharedMem
    );
}
