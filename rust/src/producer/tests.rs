//! Producer tests against a real broker actor.

use super::*;
use crate::broker::{Broker, BrokerParams};
use crate::config::NetworkProfile;
use crate::metrics::{Class, MetricsHub};
use crate::net::Network;
use crate::plasma::ObjectStore;
use crate::sim::{Engine, Rng, SECOND};

struct Rig {
    engine: Engine<Msg>,
    producer: ActorId,
    metrics: SharedMetrics,
}

fn rig(gen: RecordGen, chunk_bytes: usize, record_size: usize, ns: usize) -> Rig {
    let mut engine = Engine::new(3);
    let net = Network::shared(NetworkProfile::INFINIBAND, NetworkProfile::LOOPBACK);
    let store = ObjectStore::shared();
    let metrics = MetricsHub::shared();
    let broker = engine.add_actor(Box::new(Broker::new(
        BrokerParams {
            node: 0,
            worker_cores: 8,
            push_threads: 0,
            segment_bytes: 8 << 20,
            partitions: (0..ns).map(PartitionId).collect(),
            backup: None,
            is_backup: false,
            cost: Default::default(),
        },
        net.clone(),
        store,
        metrics.clone(),
        0,
    )));
    let producer = engine.add_actor(Box::new(Producer::new(
        ProducerParams {
            entity: 0,
            node: 1,
            broker,
            broker_node: 0,
            partitions: (0..ns).map(PartitionId).collect(),
            chunk_bytes,
            record_size,
            cost: Default::default(),
            data_plane: DataPlane::Sim,
        },
        gen,
        metrics.clone(),
        net,
    )));
    Rig { engine, producer, metrics }
}

#[test]
fn producer_appends_continuously() {
    let mut r = rig(RecordGen::Sim, 1024, 100, 4);
    r.engine.run_until(SECOND);
    let total = r.metrics.borrow().total(Class::ProducerRecords);
    assert!(total > 100_000, "1s of appends: {total}");
    let sent = r.engine.actor_as::<Producer>(r.producer).unwrap().records_sent();
    assert_eq!(sent, total);
}

#[test]
fn pacing_is_generation_plus_round_trip() {
    // 10 records per chunk x 4 partitions = 40 records per request at
    // 200 ns each = 8 us generation; RTT adds a few us more. The rate must
    // sit near records/(gen+rtt), well under the pure-generation bound.
    let mut r = rig(RecordGen::Sim, 1024, 100, 4);
    r.engine.run_until(SECOND);
    let total = r.metrics.borrow().total(Class::ProducerRecords);
    let gen_bound = SECOND as u64 / 200 ; // 5M records/s at 200ns
    assert!(total < gen_bound, "sync RPC must slow the loop: {total}");
    assert!(total > gen_bound / 10, "but not by 10x: {total}");
}

#[test]
fn larger_chunks_raise_throughput() {
    let mut small = rig(RecordGen::Sim, 1024, 100, 8);
    small.engine.run_until(SECOND);
    let t_small = small.metrics.borrow().total(Class::ProducerRecords);
    let mut big = rig(RecordGen::Sim, 128 * 1024, 100, 8);
    big.engine.run_until(SECOND);
    let t_big = big.metrics.borrow().total(Class::ProducerRecords);
    assert!(
        t_big > t_small * 2,
        "paper Fig. 3 shape: chunk size grows throughput ({t_small} -> {t_big})"
    );
}

#[test]
fn synthetic_generator_plants_needles() {
    let gen = RecordGen::Synthetic {
        rng: Rng::new(5),
        needle: b"needle".to_vec(),
        plant_permille: 100, // 10%
        planted: 0,
    };
    let mut r = rig(gen, 4096, 100, 2);
    r.engine.run_until(SECOND / 10);
    let p = r.engine.actor_as::<Producer>(r.producer).unwrap();
    let sent = p.records_sent();
    let planted = p.planted();
    assert!(sent > 1000);
    let ratio = planted as f64 / sent as f64;
    assert!((0.05..0.15).contains(&ratio), "plant ratio {ratio}");
}

#[test]
fn corpus_producer_stops_when_exhausted() {
    let gen = RecordGen::Corpus(CorpusReader::new(2048, 500));
    let mut r = rig(gen, 16 * 1024, 2048, 2);
    r.engine.run_until(10 * SECOND);
    let p = r.engine.actor_as::<Producer>(r.producer).unwrap();
    assert_eq!(p.records_sent(), 500, "bounded volume then stop (paper Fig. 9)");
}

#[test]
fn corpus_partial_final_request_is_sent() {
    // 500 records of budget with 8 records/chunk x 2 partitions = 16/request:
    // the last request is partial and must still be appended.
    let gen = RecordGen::Corpus(CorpusReader::new(2048, 30));
    let mut r = rig(gen, 16 * 1024, 2048, 2);
    r.engine.run_until(10 * SECOND);
    assert_eq!(r.metrics.borrow().total(Class::ProducerRecords), 30);
}
