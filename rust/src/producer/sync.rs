//! `WriteMode::SyncRpc` — the paper's §V-A baseline producer.
//!
//! The serial `generate ReqS records → Append RPC → wait ack` loop,
//! unchanged from the pre-trait producer: the generation cost per record
//! and the synchronous append round-trip pace each producer. Our producers
//! saturate (the benchmarks measure peak ingestion), so chunks always fill
//! before the paper's 1 ms seal timeout.

use crate::config::WriteMode;
use crate::metrics::{Class, SharedMetrics};
use crate::net::SharedNetwork;
use crate::proto::{Chunk, Msg, PartitionId, RpcEnvelope, RpcKind, RpcReply, RpcRequest};
use crate::shard::ShardClient;
use crate::sim::{Actor, ActorId, Ctx, Engine, Time};

use super::api::{
    WriteAccounting, WriteError, WritePath, WriteStatKey, WriteStats, WriterFactory, WriterWiring,
};
use super::{ProducerParams, RecordGen};

/// One append's retry state: what to resend and how often we tried.
#[derive(Debug, Clone)]
struct Inflight {
    rpc: u64,
    chunks: Vec<(PartitionId, Chunk)>,
    sent_at: Time,
    attempts: u32,
    /// Generation stamp when the latency tracer sampled this request.
    produced_at: Option<Time>,
}

/// The synchronous producer actor: a serial generate → append → ack loop.
pub struct Producer {
    params: ProducerParams,
    gen: RecordGen,
    next_rpc: u64,
    /// Chunks staged for the in-flight request (built at GenDone).
    staged: Vec<(PartitionId, Chunk)>,
    /// The one outstanding append (kept for bounded retry + latency).
    inflight: Option<Inflight>,
    /// True once the generator is exhausted (bounded corpus).
    done: bool,
    acct: WriteAccounting,
    metrics: SharedMetrics,
    net: SharedNetwork,
    /// Cached shard routing when `broker_count > 1`.
    shard: Option<ShardClient>,
    /// Which broker group the next request stages (round-robin).
    group_rr: usize,
    /// Appends re-routed after a `WrongShard` refusal.
    shard_retries: u64,
    /// Appends retransmitted after a deadline expiry against a broker the
    /// coordinator declared dead.
    broker_down_retries: u64,
}

impl Producer {
    pub fn new(
        params: ProducerParams,
        gen: RecordGen,
        metrics: SharedMetrics,
        net: SharedNetwork,
    ) -> Self {
        assert!(!params.partitions.is_empty());
        assert!(params.chunk_bytes >= params.record_size);
        let shard = params.shard.as_ref().map(ShardClient::new);
        Self {
            params,
            gen,
            next_rpc: 0,
            staged: Vec::new(),
            inflight: None,
            done: false,
            acct: WriteAccounting::default(),
            metrics,
            net,
            shard,
            group_rr: 0,
            shard_retries: 0,
            broker_down_retries: 0,
        }
    }

    /// The deadline for the in-flight request's `attempts`-th try:
    /// exponential growth from `rpc_deadline_ms`, capped at 64× so the
    /// probe cadence never collapses entirely.
    fn deadline_for(&self, attempts: u32) -> Time {
        self.params.rpc_deadline_ns.saturating_mul(1 << attempts.saturating_sub(1).min(6))
    }

    /// Start generating the next request: busy for `records × gen cost`,
    /// then `GenDone` fires and the RPC goes out.
    fn start_generation(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let rpc = self.next_rpc;
        let staged = match &self.shard {
            None => super::stage_request(&mut self.gen, &self.params),
            Some(client) => {
                // Rotate over broker groups, skipping any a fail-over left
                // without primaries (an empty group must not read as "the
                // generator is exhausted"). A request stays within one
                // primary's range so it has a single destination broker.
                let brokers = client.table().brokers();
                let mut parts = Vec::new();
                for _ in 0..brokers {
                    let group = self.group_rr % brokers;
                    self.group_rr = (self.group_rr + 1) % brokers;
                    parts = client.table().primaries_of(group);
                    if !parts.is_empty() {
                        break;
                    }
                }
                super::stage_request_for(&mut self.gen, &self.params, &parts)
            }
        };
        let Some((chunks, total_records)) = staged else {
            self.done = true;
            return;
        };
        self.staged = chunks;
        let cost = total_records * self.params.cost.producer_record_ns;
        ctx.send_self_in(cost as Time, Msg::GenDone(rpc));
    }

    fn send_append(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let chunks = std::mem::take(&mut self.staged);
        let rpc = self.next_rpc;
        self.next_rpc += 1;
        // None whenever tracing is off (sample_produced self-gates).
        let produced_at = self.metrics.borrow_mut().tracer.sample_produced(ctx.now());
        self.inflight =
            Some(Inflight { rpc, chunks, sent_at: ctx.now(), attempts: 1, produced_at });
        self.transmit(ctx);
    }

    /// Put the in-flight request on the wire (first send or retry).
    fn transmit(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let inflight = self.inflight.as_mut().expect("transmit with an append staged");
        inflight.sent_at = ctx.now();
        let bytes: u64 = inflight.chunks.iter().map(|(_, c)| c.bytes()).sum();
        // Destination from the cached shard table (re-resolved on every
        // transmit, so a WrongShard retry lands at the new primary).
        let (to, to_node) = match &self.shard {
            Some(client) => client.broker_for(inflight.chunks[0].0),
            None => (self.params.broker, self.params.broker_node),
        };
        self.acct.on_issued();
        let deliver = self.net.borrow_mut().send(ctx.now(), self.params.node, to_node, bytes);
        ctx.send_at(
            deliver,
            to,
            Msg::rpc(RpcRequest {
                id: inflight.rpc,
                reply_to: ctx.self_id(),
                from_node: self.params.node,
                kind: RpcKind::Append {
                    chunks: inflight.chunks.clone(),
                    produced_at: inflight.produced_at,
                },
            }),
        );
        // Sharded runs race every transmit against a deadline: if the
        // broker goes silent (broker fault), the expiry checks the down
        // mask and eventually re-routes to the promoted replica.
        if self.shard.is_some() && self.params.rpc_deadline_ns > 0 {
            let inflight = self.inflight.as_ref().expect("just transmitted");
            let d = self.deadline_for(inflight.attempts);
            ctx.send_self_in(d, Msg::Timer(inflight.rpc | super::DEADLINE_TAG));
        }
    }

    /// A per-RPC deadline fired. Ignore it unless it genuinely expired the
    /// *current* attempt of the *current* in-flight request (acks and
    /// retransmits both strand old timers). On a genuine expiry against a
    /// broker the coordinator declared dead, refresh the route and
    /// retransmit — the broker-side idempotence table makes the resend
    /// exactly-once even if the original landed before the crash. Against
    /// a slow-but-live (or not-yet-declared) broker, just re-arm: a
    /// retransmit now could race the original in its queue.
    fn on_deadline(&mut self, rpc: u64, ctx: &mut Ctx<'_, Msg>) {
        let Some(inflight) = self.inflight.as_ref() else { return };
        if inflight.rpc != rpc
            || ctx.now() < inflight.sent_at + self.deadline_for(inflight.attempts)
        {
            return;
        }
        let Some(client) = self.shard.as_mut() else { return };
        let (home, _) = client.broker_for(inflight.chunks[0].0);
        if client.actor_down(home) {
            client.refresh();
            self.broker_down_retries += 1;
            self.inflight.as_mut().expect("checked above").attempts += 1;
            self.transmit(ctx);
        } else {
            let d = self.deadline_for(inflight.attempts);
            ctx.send_self_in(d, Msg::Timer(rpc | super::DEADLINE_TAG));
        }
    }

    fn on_ack(&mut self, env: RpcEnvelope, ctx: &mut Ctx<'_, Msg>) {
        match env.reply {
            RpcReply::AppendAck { records, bytes } => {
                let inflight = self.inflight.take().expect("ack matches the in-flight append");
                debug_assert_eq!(inflight.rpc, env.id);
                let rtt = ctx.now() - inflight.sent_at;
                self.acct.on_acked(records, bytes, rtt);
                let mut m = self.metrics.borrow_mut();
                m.record(Class::ProducerRecords, self.params.entity, ctx.now(), records);
                if m.tracer.enabled() {
                    m.tracer.note_append_latency(ctx.now(), rtt);
                }
            }
            RpcReply::Error { reason } => {
                let attempts =
                    self.inflight.as_ref().expect("error matches in-flight append").attempts;
                if self.acct.on_rejected(&self.params.retry, attempts, reason) {
                    // Bounded retry with backoff: resend the same request.
                    let inflight = self.inflight.as_mut().expect("just checked");
                    inflight.attempts += 1;
                    let rpc = inflight.rpc;
                    ctx.send_self_in(self.params.retry.backoff_ns, Msg::Timer(rpc));
                    return; // next generation starts after the retry acks
                }
                // Retries exhausted: the typed error is recorded; move on —
                // overload experiments must not abort the sim.
                self.inflight = None;
            }
            RpcReply::WrongShard { epoch } => match self.shard.as_mut() {
                Some(client) => {
                    // Stale route: refresh the cached table and resend the
                    // same chunks after backoff. Unbounded (the coordinator
                    // always publishes the new table), counted separately
                    // from genuine rejections.
                    client.refresh();
                    self.shard_retries += 1;
                    let inflight =
                        self.inflight.as_mut().expect("refusal matches the in-flight append");
                    inflight.attempts += 1;
                    ctx.send_self_in(self.params.retry.backoff_ns, Msg::Timer(inflight.rpc));
                    return;
                }
                None => {
                    // No routing view to refresh: surface the typed error
                    // instead of panicking and move on.
                    self.acct.errors += 1;
                    self.acct.last_error = Some(WriteError::WrongShard { epoch });
                    self.inflight = None;
                }
            },
            other => panic!("producer {}: unexpected reply {other:?}", self.params.entity),
        }
        if !self.done {
            self.start_generation(ctx);
        }
    }

    pub fn records_sent(&self) -> u64 {
        self.acct.records_sent
    }

    /// Needle plants so far (synthetic generator; for end-to-end checks).
    pub fn planted(&self) -> u64 {
        self.gen.planted()
    }
}

impl Actor<Msg> for Producer {
    fn on_start(&mut self, ctx: &mut Ctx<'_, Msg>) {
        self.start_generation(ctx);
    }

    fn on_event(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        match msg {
            Msg::GenDone(_) => self.send_append(ctx),
            Msg::Reply(env) => self.on_ack(*env, ctx),
            Msg::Timer(tag) if tag & super::DEADLINE_TAG != 0 => {
                self.on_deadline(tag & !super::DEADLINE_TAG, ctx)
            }
            Msg::Timer(rpc) => {
                debug_assert_eq!(self.inflight.as_ref().map(|i| i.rpc), Some(rpc));
                self.transmit(ctx);
            }
            other => panic!("producer {}: unexpected {other:?}", self.params.entity),
        }
    }

    fn label(&self) -> String {
        format!("producer#{}", self.params.entity)
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}

impl WritePath for Producer {
    fn mode(&self) -> WriteMode {
        WriteMode::SyncRpc
    }

    fn stats(&self) -> WriteStats {
        let mut extras = super::api::WriteStatExtras::new();
        if self.shard_retries > 0 {
            extras.insert(WriteStatKey::ShardRetries, self.shard_retries);
        }
        if self.broker_down_retries > 0 {
            extras.insert(WriteStatKey::BrokerDownRetries, self.broker_down_retries);
        }
        // One client thread generates and waits in turn.
        self.acct.stats(self.gen.planted(), 1, extras)
    }
}

/// Builds the `Np` synchronous baseline producers on the producer node.
pub struct SyncRpcWriterFactory;

impl WriterFactory for SyncRpcWriterFactory {
    fn mode(&self) -> WriteMode {
        WriteMode::SyncRpc
    }

    fn build(&self, w: &WriterWiring<'_>, engine: &mut Engine<Msg>) -> Vec<ActorId> {
        super::api::build_writers(w, engine, w.producer_node, |params, gen| {
            Box::new(Producer::new(params, gen, w.metrics.clone(), w.net.clone()))
        })
    }
}
