//! Producers: the pluggable write path, behind one trait.
//!
//! PR 1 turned the paper's *read*-side comparison (pull vs push vs hybrid
//! sources) into the [`crate::source::StreamSource`] trait API; this module
//! is the symmetric redesign of the *write* side. Every producer backend
//! implements [`WritePath`] (an [`crate::sim::Actor`] plus uniform
//! [`WriteStats`] introspection) and is built by a [`WriterFactory`]
//! resolved from the [`WriterRegistry`] keyed by
//! [`crate::config::WriteMode`] — the launcher never names a concrete
//! producer type. Modes:
//!
//! **SyncRpc** ([`Producer`], §V-A baseline): "Each producer issues one
//! synchronous RPC having one chunk of CS size for each partition of a
//! broker, having in total ReqS size". The serial
//! `generate ReqS records → Append RPC → wait ack` loop; the generation
//! cost per record and the synchronous round-trip pace each producer.
//!
//! **Pipelined** ([`PipelinedWriter`]): production ingestion layers batch
//! and pipeline writes (Uber's real-time infra, 2104.00087). Generation
//! overlaps with up to `write_inflight` outstanding appends; every chunk
//! carries a per-partition sequence number, and the writer's sequencers
//! detect and account acks completing out of send order
//! (`acks_reordered`) — on the simulator's FIFO fabric the log keeps
//! send order, and the counter verifies it.
//!
//! **SharedMem** ([`SharedMemWriter`]): the paper's push-source idea
//! applied to ingestion. One `WriteSubscribe` RPC registers the *colocated*
//! producer, which then fills free plasma objects directly and sends a
//! `SealObject` control notification; the broker appends the object's
//! chunks and releases the buffer. Per-chunk dispatcher+worker RPC
//! occupancy (and the payload's trip over the wire) is replaced by
//! object-exhaustion backpressure.
//!
//! Rejected appends never panic: every backend retries with bounded
//! backoff ([`RetryPolicy`]) and surfaces a typed [`WriteError`] through
//! its [`WriteStats`], so overload experiments keep running.
//!
//! Two record generators cover the paper's workloads: synthetic fixed-size
//! records (optionally planting the filter needle), and the Wikipedia
//! corpus reader (2 KiB text records, bounded volume); [`RecordGen::
//! BoundedSim`] mirrors the corpus budget on the accounting-only plane.

pub mod api;
mod pipelined;
mod shmem;
mod sync;
#[cfg(test)]
mod tests;

pub use api::{
    RetryPolicy, WriteError, WritePath, WriteStatExtras, WriteStatKey, WriteStats, WriterActor,
    WriterFactory, WriterRegistry, WriterWiring,
};
pub use pipelined::{PipelinedParams, PipelinedWriter, PipelinedWriterFactory};
pub use shmem::{SharedMemParams, SharedMemWriter, SharedMemWriterFactory};
pub use sync::{Producer, SyncRpcWriterFactory};

use std::rc::Rc;

use crate::config::{CostModel, DataPlane, ExperimentConfig};
use crate::net::NodeId;
use crate::proto::{Chunk, PartitionId};
use crate::sim::{ActorId, Rng};
use crate::wikipedia::CorpusReader;

/// The grep needle all filter benchmarks use (length must equal the
/// `PATTERN_LEN` baked into the filter artifacts).
pub const FILTER_NEEDLE: &[u8] = b"needle";
/// Fraction of synthetic records carrying the needle, in permille.
pub const PLANT_PERMILLE: u32 = 50;

/// What producers put inside records.
pub enum RecordGen {
    /// Accounting-only payloads (sim data plane).
    Sim,
    /// Accounting-only payloads with a bounded record budget — the sim
    /// plane's mirror of the corpus volume bound, so write modes can be
    /// cross-checked on identical totals.
    BoundedSim { remaining: u64 },
    /// Random lowercase text with the filter needle planted in a fraction
    /// of records (real data plane, synthetic benchmarks).
    Synthetic { rng: Rng, needle: Vec<u8>, plant_permille: u32, planted: u64 },
    /// The Wikipedia corpus (real data plane, word-count benchmarks).
    Corpus(CorpusReader),
}

impl RecordGen {
    /// Produce one chunk of `records` × `record_size`. Returns `None` when
    /// a bounded generator is exhausted (Wikipedia producers stop).
    fn next_chunk(&mut self, records: u32, record_size: u32) -> Option<Chunk> {
        match self {
            RecordGen::Sim => Some(Chunk::sim(records, record_size)),
            RecordGen::BoundedSim { remaining } => {
                if *remaining == 0 {
                    return None;
                }
                let want = (records as u64).min(*remaining) as u32;
                *remaining -= want as u64;
                Some(Chunk::sim(want, record_size))
            }
            RecordGen::Synthetic { rng, needle, plant_permille, planted } => {
                let mut data = vec![0u8; records as usize * record_size as usize];
                for r in 0..records as usize {
                    let rec = &mut data[r * record_size as usize..(r + 1) * record_size as usize];
                    for b in rec.iter_mut() {
                        *b = b'a' + rng.next_below(26) as u8;
                    }
                    if rng.next_below(1000) < *plant_permille as u64
                        && rec.len() >= needle.len()
                    {
                        let at = rng.next_below((rec.len() - needle.len() + 1) as u64) as usize;
                        rec[at..at + needle.len()].copy_from_slice(needle);
                        *planted += 1;
                    }
                }
                Some(Chunk::real(records, record_size, Rc::new(data)))
            }
            RecordGen::Corpus(reader) => {
                if reader.remaining() == 0 {
                    return None;
                }
                let want = (records as u64).min(reader.remaining()) as u32;
                let mut data = vec![0u8; want as usize * record_size as usize];
                let got = reader.fill_records(&mut data);
                debug_assert_eq!(got as u32, want);
                Some(Chunk::real(want, record_size, Rc::new(data)))
            }
        }
    }

    /// Needle plants so far (synthetic generator; for end-to-end checks).
    pub fn planted(&self) -> u64 {
        match self {
            RecordGen::Synthetic { planted, .. } => *planted,
            _ => 0,
        }
    }
}

/// The generator matching a config's data plane + workload (factories call
/// this once per producer; `seed_rng` forks keep producers decorrelated
/// but deterministic).
pub fn make_gen(config: &ExperimentConfig, seed_rng: &mut Rng) -> RecordGen {
    match (config.data_plane, config.workload.is_text()) {
        (DataPlane::Sim, _) if config.corpus_records > 0 => {
            // Bounded sim producers: same budget semantics as the corpus
            // (paper Fig. 9: push ~2 GiB then stop) without materialising
            // payloads — the write-mode cross-checks rely on this.
            RecordGen::BoundedSim { remaining: config.corpus_records }
        }
        (DataPlane::Sim, _) => RecordGen::Sim,
        (DataPlane::Real, false) => RecordGen::Synthetic {
            rng: seed_rng.fork(),
            needle: FILTER_NEEDLE.to_vec(),
            plant_permille: PLANT_PERMILLE,
            planted: 0,
        },
        (DataPlane::Real, true) => {
            let budget = if config.corpus_records > 0 { config.corpus_records } else { u64::MAX };
            RecordGen::Corpus(CorpusReader::new(config.record_size, budget))
        }
    }
}

/// Stage one request: up to one chunk per partition (`ReqS` total),
/// stopping early when a bounded generator runs out mid-request (the
/// partial final request is still sent). Returns the staged chunks and
/// their total records, or `None` once the generator is exhausted — the
/// one staging loop every write mode shares.
pub(crate) fn stage_request(
    gen: &mut RecordGen,
    params: &ProducerParams,
) -> Option<(Vec<(PartitionId, Chunk)>, u64)> {
    let per_chunk = (params.chunk_bytes / params.record_size) as u32;
    let mut total_records = 0u64;
    let mut chunks = Vec::new();
    for &p in &params.partitions {
        match gen.next_chunk(per_chunk, params.record_size as u32) {
            Some(chunk) => {
                total_records += chunk.records as u64;
                chunks.push((p, chunk));
            }
            None => break,
        }
    }
    if chunks.is_empty() {
        None
    } else {
        Some((chunks, total_records))
    }
}

/// Static producer wiring, shared by all write modes.
#[derive(Debug, Clone)]
pub struct ProducerParams {
    /// Metrics entity (producer index).
    pub entity: usize,
    pub node: NodeId,
    pub broker: ActorId,
    pub broker_node: NodeId,
    /// Partitions this producer appends to (all `Ns` of the stream).
    pub partitions: Vec<PartitionId>,
    /// `CS` producer chunk size in bytes.
    pub chunk_bytes: usize,
    /// `RecS`.
    pub record_size: usize,
    /// Bounded retry/backoff for rejected appends.
    pub retry: RetryPolicy,
    pub cost: CostModel,
    pub data_plane: DataPlane,
}

impl ProducerParams {
    /// Fill from a config + registry wiring (the factories' common path).
    pub fn from_wiring(w: &WriterWiring<'_>, entity: usize, node: NodeId) -> Self {
        Self {
            entity,
            node,
            broker: w.broker,
            broker_node: w.broker_node,
            partitions: w.partitions.clone(),
            chunk_bytes: w.config.producer_chunk,
            record_size: w.config.record_size,
            retry: RetryPolicy::from_config(w.config),
            cost: w.config.cost.clone(),
            data_plane: w.config.data_plane,
        }
    }
}
