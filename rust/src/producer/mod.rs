//! Producers: multi-threaded clients appending chunks of records.
//!
//! §V-A: "Each producer issues one synchronous RPC having one chunk of CS
//! size for each partition of a broker, having in total ReqS size" and
//! "Producers wait up to one millisecond before sealing chunks ready to be
//! pushed to the broker (or the chunk gets filled and sealed)". Our
//! producers saturate (the benchmarks measure peak ingestion), so chunks
//! always fill before the seal timeout; the generation cost per record and
//! the synchronous append round-trip pace each producer:
//!
//! ```text
//! loop { generate ReqS records  ->  Append RPC  ->  wait ack }
//! ```
//!
//! Two record generators cover the paper's workloads: synthetic fixed-size
//! records (optionally planting the filter needle), and the Wikipedia
//! corpus reader (2 KiB text records, bounded volume).

#[cfg(test)]
mod tests;

use std::rc::Rc;

use crate::config::{CostModel, DataPlane};
use crate::metrics::{Class, SharedMetrics};
use crate::net::{NodeId, SharedNetwork};
use crate::proto::{Chunk, Msg, PartitionId, RpcEnvelope, RpcKind, RpcReply, RpcRequest};
use crate::sim::{Actor, ActorId, Ctx, Rng, Time};
use crate::wikipedia::CorpusReader;

/// What producers put inside records.
pub enum RecordGen {
    /// Accounting-only payloads (sim data plane).
    Sim,
    /// Random lowercase text with the filter needle planted in a fraction
    /// of records (real data plane, synthetic benchmarks).
    Synthetic { rng: Rng, needle: Vec<u8>, plant_permille: u32, planted: u64 },
    /// The Wikipedia corpus (real data plane, word-count benchmarks).
    Corpus(CorpusReader),
}

impl RecordGen {
    /// Produce one chunk of `records` × `record_size`. Returns `None` when
    /// a bounded generator is exhausted (Wikipedia producers stop).
    fn next_chunk(&mut self, records: u32, record_size: u32) -> Option<Chunk> {
        match self {
            RecordGen::Sim => Some(Chunk::sim(records, record_size)),
            RecordGen::Synthetic { rng, needle, plant_permille, planted } => {
                let mut data = vec![0u8; records as usize * record_size as usize];
                for r in 0..records as usize {
                    let rec = &mut data[r * record_size as usize..(r + 1) * record_size as usize];
                    for b in rec.iter_mut() {
                        *b = b'a' + rng.next_below(26) as u8;
                    }
                    if rng.next_below(1000) < *plant_permille as u64
                        && rec.len() >= needle.len()
                    {
                        let at = rng.next_below((rec.len() - needle.len() + 1) as u64) as usize;
                        rec[at..at + needle.len()].copy_from_slice(needle);
                        *planted += 1;
                    }
                }
                Some(Chunk::real(records, record_size, Rc::new(data)))
            }
            RecordGen::Corpus(reader) => {
                if reader.remaining() == 0 {
                    return None;
                }
                let want = (records as u64).min(reader.remaining()) as u32;
                let mut data = vec![0u8; want as usize * record_size as usize];
                let got = reader.fill_records(&mut data);
                debug_assert_eq!(got as u32, want);
                Some(Chunk::real(want, record_size, Rc::new(data)))
            }
        }
    }
}

/// Static producer wiring.
pub struct ProducerParams {
    /// Metrics entity (producer index).
    pub entity: usize,
    pub node: NodeId,
    pub broker: ActorId,
    pub broker_node: NodeId,
    /// Partitions this producer appends to (all `Ns` of the stream).
    pub partitions: Vec<PartitionId>,
    /// `CS` producer chunk size in bytes.
    pub chunk_bytes: usize,
    /// `RecS`.
    pub record_size: usize,
    pub cost: CostModel,
    pub data_plane: DataPlane,
}

/// The producer actor: a serial generate → append → ack loop.
pub struct Producer {
    params: ProducerParams,
    gen: RecordGen,
    next_rpc: u64,
    /// Chunks staged for the in-flight request (built at GenDone).
    staged: Vec<(PartitionId, Chunk)>,
    /// True once the generator is exhausted (bounded corpus).
    done: bool,
    records_sent: u64,
    metrics: SharedMetrics,
    net: SharedNetwork,
}

impl Producer {
    pub fn new(
        params: ProducerParams,
        gen: RecordGen,
        metrics: SharedMetrics,
        net: SharedNetwork,
    ) -> Self {
        assert!(!params.partitions.is_empty());
        assert!(params.chunk_bytes >= params.record_size);
        Self {
            params,
            gen,
            next_rpc: 0,
            staged: Vec::new(),
            done: false,
            records_sent: 0,
            metrics,
            net,
        }
    }

    fn records_per_chunk(&self) -> u32 {
        (self.params.chunk_bytes / self.params.record_size) as u32
    }

    /// Start generating the next request: busy for `records × gen cost`,
    /// then `GenDone` fires and the RPC goes out.
    fn start_generation(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let rpc = self.next_rpc;
        let per_chunk = self.records_per_chunk();
        let mut total_records: u64 = 0;
        self.staged.clear();
        for &p in &self.params.partitions {
            match self.gen.next_chunk(per_chunk, self.params.record_size as u32) {
                Some(chunk) => {
                    total_records += chunk.records as u64;
                    self.staged.push((p, chunk));
                }
                None => break, // generator exhausted mid-request: send what we have
            }
        }
        if self.staged.is_empty() {
            self.done = true;
            return;
        }
        let cost = total_records * self.params.cost.producer_record_ns;
        ctx.send_self_in(cost as Time, Msg::GenDone(rpc));
    }

    fn send_append(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let chunks = std::mem::take(&mut self.staged);
        let bytes: u64 = chunks.iter().map(|(_, c)| c.bytes()).sum();
        let rpc = self.next_rpc;
        self.next_rpc += 1;
        let deliver =
            self.net
                .borrow_mut()
                .send(ctx.now(), self.params.node, self.params.broker_node, bytes);
        ctx.send_at(
            deliver,
            self.params.broker,
            Msg::Rpc(RpcRequest {
                id: rpc,
                reply_to: ctx.self_id(),
                from_node: self.params.node,
                kind: RpcKind::Append { chunks },
            }),
        );
    }

    fn on_ack(&mut self, env: RpcEnvelope, ctx: &mut Ctx<'_, Msg>) {
        match env.reply {
            RpcReply::AppendAck { records, .. } => {
                self.records_sent += records;
                self.metrics.borrow_mut().record(
                    Class::ProducerRecords,
                    self.params.entity,
                    ctx.now(),
                    records,
                );
            }
            RpcReply::Error { reason } => {
                panic!("producer {}: append rejected: {reason}", self.params.entity)
            }
            other => panic!("producer {}: unexpected reply {other:?}", self.params.entity),
        }
        if !self.done {
            self.start_generation(ctx);
        }
    }

    pub fn records_sent(&self) -> u64 {
        self.records_sent
    }

    /// Needle plants so far (synthetic generator; for end-to-end checks).
    pub fn planted(&self) -> u64 {
        match &self.gen {
            RecordGen::Synthetic { planted, .. } => *planted,
            _ => 0,
        }
    }
}

impl Actor<Msg> for Producer {
    fn on_start(&mut self, ctx: &mut Ctx<'_, Msg>) {
        self.start_generation(ctx);
    }

    fn on_event(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        match msg {
            Msg::GenDone(_) => self.send_append(ctx),
            Msg::Reply(env) => self.on_ack(env, ctx),
            other => panic!("producer {}: unexpected {other:?}", self.params.entity),
        }
    }

    fn label(&self) -> String {
        format!("producer#{}", self.params.entity)
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}
