//! Write-ahead log ring: the durability layer under the hot in-memory tail.
//!
//! Every append the durable store accepts is framed into the active WAL
//! file *before* it lands in the in-memory tail, so a crash between the
//! two loses nothing. The WAL is a **ring of files**: when the active file
//! passes the configured size it is sealed and a fresh one started, and
//! sealed files are pruned from the front as soon as every append they
//! hold has been flushed into a cold segment file — the cold tier, not
//! the WAL, is the long-term home of the data, so the ring stays within a
//! few files of the rotation size regardless of run length.
//!
//! ## Record framing
//!
//! Each record is a little-endian frame `[len: u32][body][fnv64(body)]`.
//! Three body kinds:
//!
//! * `APPEND` — partition, chunk offset, record framing, and the payload
//!   bytes (real plane) or just the accounting (sim plane);
//! * `TRIM` — a retention floor advanced past `floor`; best-effort (a lost
//!   trim replays as conservative over-retention, never data loss);
//! * `TOTALS` — a per-partition snapshot of lifetime appended bytes and
//!   records, written at the head of every file after the first. Replay is
//!   *set-then-add in file order*: the newest snapshot overrides whatever
//!   older (possibly pruned) files contributed, which is what makes the
//!   lifetime counters exact even though the ring drops history.
//!
//! A torn or checksum-failed record ends replay of its file cleanly — the
//! partial tail of a crashed write is expected, counted
//! ([`WalStats::torn_tails`]), and never propagates garbage.

use std::collections::{HashMap, VecDeque};
use std::fs::{self, File};
use std::io::{self, BufWriter, Write};
use std::path::{Path, PathBuf};
use std::rc::Rc;

use crate::proto::{Chunk, ChunkOffset, PartitionId, Payload};

use super::codec::{fnv64, put_u32, put_u64, put_u8, Cursor};

const KIND_APPEND: u8 = 1;
const KIND_TRIM: u8 = 2;
const KIND_TOTALS: u8 = 3;

const PAYLOAD_SIM: u8 = 0;
const PAYLOAD_REAL: u8 = 1;

/// Frame overhead around a record body: length prefix + checksum.
#[cfg(test)]
const FRAME_OVERHEAD: u64 = 4 + 8;

/// WAL ring counters (exported through the broker's store gauges).
#[derive(Debug, Clone, Default)]
pub struct WalStats {
    /// Append records written this incarnation.
    pub records: u64,
    /// Frame bytes written this incarnation (all record kinds).
    pub bytes: u64,
    /// Trim records written this incarnation.
    pub trims: u64,
    /// WAL files created (the first active file counts).
    pub files_created: u64,
    /// Sealed files pruned after their appends reached the cold tier.
    pub files_pruned: u64,
    /// Append records decoded during open-time replay.
    pub replayed_records: u64,
    /// Replayed appends skipped because a cold segment already held them.
    pub replayed_skipped: u64,
    /// Files whose replay ended at a torn or corrupt record.
    pub torn_tails: u64,
}

/// One durable log record.
#[derive(Debug, Clone)]
pub(crate) enum WalRecord {
    /// A chunk appended at `offset` of `partition`.
    Append { partition: PartitionId, offset: ChunkOffset, chunk: Chunk },
    /// Retention advanced: everything below `floor` is trimmable.
    Trim { partition: PartitionId, floor: ChunkOffset },
    /// Lifetime appended totals snapshot (see module docs on replay).
    Totals { partition: PartitionId, bytes: u64, records: u64 },
}

fn encode_body(rec: &WalRecord, out: &mut Vec<u8>) {
    match rec {
        WalRecord::Append { partition, offset, chunk } => {
            put_u8(out, KIND_APPEND);
            put_u32(out, partition.0 as u32);
            put_u64(out, *offset);
            put_u32(out, chunk.records);
            put_u32(out, chunk.record_size);
            match &chunk.payload {
                Payload::Real(data) => {
                    put_u8(out, PAYLOAD_REAL);
                    out.extend_from_slice(data);
                }
                Payload::Sim => put_u8(out, PAYLOAD_SIM),
            }
        }
        WalRecord::Trim { partition, floor } => {
            put_u8(out, KIND_TRIM);
            put_u32(out, partition.0 as u32);
            put_u64(out, *floor);
        }
        WalRecord::Totals { partition, bytes, records } => {
            put_u8(out, KIND_TOTALS);
            put_u32(out, partition.0 as u32);
            put_u64(out, *bytes);
            put_u64(out, *records);
        }
    }
}

fn decode_body(body: &[u8]) -> Option<WalRecord> {
    let mut cur = Cursor::new(body);
    match cur.u8()? {
        KIND_APPEND => {
            let partition = PartitionId(cur.u32()? as usize);
            let offset = cur.u64()?;
            let records = cur.u32()?;
            let record_size = cur.u32()?;
            let chunk = match cur.u8()? {
                PAYLOAD_REAL => {
                    let len = records as usize * record_size as usize;
                    let data = cur.take(len)?.to_vec();
                    // One materialisation per replayed real chunk — the
                    // recovery-path counterpart of the producer's single
                    // `Chunk::real`; everything downstream shares the `Rc`.
                    Chunk::real(records, record_size, Rc::new(data))
                }
                PAYLOAD_SIM => Chunk::sim(records, record_size),
                _ => return None,
            };
            if cur.remaining() != 0 {
                return None;
            }
            Some(WalRecord::Append { partition, offset, chunk })
        }
        KIND_TRIM => {
            let partition = PartitionId(cur.u32()? as usize);
            let floor = cur.u64()?;
            (cur.remaining() == 0).then_some(WalRecord::Trim { partition, floor })
        }
        KIND_TOTALS => {
            let partition = PartitionId(cur.u32()? as usize);
            let bytes = cur.u64()?;
            let records = cur.u64()?;
            (cur.remaining() == 0).then_some(WalRecord::Totals { partition, bytes, records })
        }
        _ => None,
    }
}

/// Encode a full frame: `[len][body][checksum]`.
fn encode_frame(rec: &WalRecord) -> Vec<u8> {
    let mut body = Vec::new();
    encode_body(rec, &mut body);
    let mut frame = Vec::with_capacity(4 + body.len() + 8);
    put_u32(&mut frame, body.len() as u32);
    frame.extend_from_slice(&body);
    put_u64(&mut frame, fnv64(&body));
    frame
}

/// Decode every intact frame in a file image. The bool is `true` when the
/// file ended in a torn or corrupt record (decode stopped early).
fn decode_file(bytes: &[u8]) -> (Vec<WalRecord>, bool) {
    let mut out = Vec::new();
    let mut cur = Cursor::new(bytes);
    while cur.remaining() > 0 {
        let Some(len) = cur.u32() else { return (out, true) };
        let Some(body) = cur.take(len as usize) else { return (out, true) };
        let Some(sum) = cur.u64() else { return (out, true) };
        if fnv64(body) != sum {
            return (out, true);
        }
        let Some(rec) = decode_body(body) else { return (out, true) };
        out.push(rec);
    }
    (out, false)
}

fn file_path(dir: &Path, seq: u64) -> PathBuf {
    dir.join(format!("wal-{seq:08}.log"))
}

/// Parse `wal-<seq>.log` back to its sequence number.
fn parse_seq(name: &str) -> Option<u64> {
    name.strip_prefix("wal-")?.strip_suffix(".log")?.parse().ok()
}

/// A sealed (non-active) file still on disk, with the highest append
/// offset it holds per partition — the prune condition's input.
#[derive(Debug)]
struct SealedWal {
    seq: u64,
    max_off: HashMap<PartitionId, ChunkOffset>,
}

/// The ring of WAL files: one active writer plus sealed predecessors
/// awaiting prune. Writes are flushed to the file per append — the crash
/// model is process death, matching the paper's node-failure experiments
/// (per-record `fsync` group-commit tuning is out of scope for the sim).
#[derive(Debug)]
pub(crate) struct WalRing {
    dir: PathBuf,
    rotate_bytes: u64,
    /// Sequence number of the active file.
    seq: u64,
    writer: BufWriter<File>,
    active_bytes: u64,
    /// Highest append offset per partition in the active file.
    active_max: HashMap<PartitionId, ChunkOffset>,
    sealed: VecDeque<SealedWal>,
    stats: WalStats,
}

impl WalRing {
    /// Open the ring under `dir`, replaying whatever files a previous
    /// incarnation left. Returns the decoded records **in write order**
    /// for the caller to apply (set-then-add for totals, rebuild for
    /// appends), then starts a fresh active file — the caller should write
    /// a post-replay totals snapshot into it next.
    pub fn open(dir: &Path, rotate_bytes: u64) -> io::Result<(Self, Vec<WalRecord>)> {
        assert!(rotate_bytes > 0, "wal rotation size must be positive");
        fs::create_dir_all(dir)?;
        let mut seqs: Vec<u64> = fs::read_dir(dir)?
            .filter_map(|e| e.ok())
            .filter_map(|e| parse_seq(e.file_name().to_str()?))
            .collect();
        seqs.sort_unstable();

        let mut stats = WalStats::default();
        let mut sealed = VecDeque::new();
        let mut replay = Vec::new();
        for &seq in &seqs {
            let bytes = fs::read(file_path(dir, seq))?;
            let (records, torn) = decode_file(&bytes);
            if torn {
                stats.torn_tails += 1;
            }
            let mut max_off = HashMap::new();
            for rec in &records {
                if let WalRecord::Append { partition, offset, .. } = rec {
                    let e = max_off.entry(*partition).or_insert(*offset);
                    *e = (*e).max(*offset);
                    stats.replayed_records += 1;
                }
            }
            sealed.push_back(SealedWal { seq, max_off });
            replay.extend(records);
        }

        let seq = seqs.last().map_or(0, |s| s + 1);
        let writer = BufWriter::new(File::create(file_path(dir, seq))?);
        stats.files_created += 1;
        let ring = WalRing {
            dir: dir.to_path_buf(),
            rotate_bytes,
            seq,
            writer,
            active_bytes: 0,
            active_max: HashMap::new(),
            sealed,
            stats,
        };
        Ok((ring, replay))
    }

    /// Write one record, rotating first when it would push the active file
    /// past the rotation size. On rotation, `snapshot()` supplies the
    /// totals records written at the head of the fresh file **before**
    /// `rec` — the snapshot must therefore describe the state *excluding*
    /// the pending record.
    pub fn append(
        &mut self,
        rec: &WalRecord,
        snapshot: impl FnOnce() -> Vec<WalRecord>,
    ) -> io::Result<()> {
        let frame = encode_frame(rec);
        if self.active_bytes > 0 && self.active_bytes + frame.len() as u64 > self.rotate_bytes {
            self.rotate()?;
            for snap in snapshot() {
                let f = encode_frame(&snap);
                self.write_frame(&f, &snap)?;
            }
        }
        self.write_frame(&frame, rec)?;
        self.writer.flush()
    }

    fn write_frame(&mut self, frame: &[u8], rec: &WalRecord) -> io::Result<()> {
        self.writer.write_all(frame)?;
        self.active_bytes += frame.len() as u64;
        self.stats.bytes += frame.len() as u64;
        match rec {
            WalRecord::Append { partition, offset, .. } => {
                self.stats.records += 1;
                let e = self.active_max.entry(*partition).or_insert(*offset);
                *e = (*e).max(*offset);
            }
            WalRecord::Trim { .. } => self.stats.trims += 1,
            WalRecord::Totals { .. } => {}
        }
        Ok(())
    }

    /// Seal the active file and start the next one.
    fn rotate(&mut self) -> io::Result<()> {
        self.writer.flush()?;
        self.sealed.push_back(SealedWal {
            seq: self.seq,
            max_off: std::mem::take(&mut self.active_max),
        });
        self.seq += 1;
        self.writer = BufWriter::new(File::create(file_path(&self.dir, self.seq))?);
        self.active_bytes = 0;
        self.stats.files_created += 1;
        Ok(())
    }

    /// Drop sealed files from the front of the ring whose every append
    /// now lives in a cold segment. `flushed` maps each partition to its
    /// cold-tier end (first offset *not* yet flushed); a file goes when
    /// all its per-partition maxima sit strictly below those floors.
    /// Returns the number of files removed.
    pub fn prune(&mut self, flushed: &HashMap<PartitionId, ChunkOffset>) -> io::Result<u64> {
        let mut pruned = 0;
        while let Some(front) = self.sealed.front() {
            let covered = front
                .max_off
                .iter()
                .all(|(p, &off)| flushed.get(p).is_some_and(|&floor| off < floor));
            if !covered {
                break;
            }
            let seq = front.seq;
            fs::remove_file(file_path(&self.dir, seq))?;
            self.sealed.pop_front();
            pruned += 1;
        }
        self.stats.files_pruned += pruned;
        Ok(pruned)
    }

    /// Files on disk (sealed + active).
    pub fn files_retained(&self) -> usize {
        self.sealed.len() + 1
    }

    /// An upper bound on a frame for `chunk` (sizing heuristics in tests).
    #[cfg(test)]
    pub fn frame_bytes(chunk: &Chunk) -> u64 {
        let payload = if chunk.payload.is_real() { chunk.bytes() } else { 0 };
        FRAME_OVERHEAD + 1 + 4 + 8 + 4 + 4 + 1 + payload
    }

    pub fn stats(&self) -> WalStats {
        self.stats.clone()
    }

    pub fn stats_mut(&mut self) -> &mut WalStats {
        &mut self.stats
    }
}
