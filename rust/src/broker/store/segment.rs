//! Immutable sorted segment files: the cold tier of the durable log.
//!
//! A segment file holds one contiguous, offset-sorted run of chunks
//! `[base, end)` of a single partition — exactly one sealed in-memory
//! segment at flush time, possibly a merged run after compaction. Files
//! are written once and never modified; compaction replaces files, it
//! never edits them. Each file embeds a [`Bloom`] over its chunk offsets
//! (consulted before a cold load) and ends in an FNV-1a checksum over the
//! whole image, so a torn flush is detected — and discarded — at scan
//! time, while the WAL ring still holds every chunk the file lost.
//!
//! Layout (all little-endian):
//!
//! ```text
//! magic u32 | version u32 | partition u32 | base u64 | end u64 |
//! data_bytes u64 | bloom: (hashes u32, bits u32, nwords u32, words...) |
//! per chunk: records u32 | record_size u32 | payload_kind u8 | payload |
//! fnv64 over everything above
//! ```
//!
//! The chunk count is implicit: `end - base` (offsets are dense).

use std::fs;
use std::io;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use crate::proto::{Chunk, ChunkOffset, PartitionId, Payload};

use super::bloom::Bloom;
use super::codec::{fnv64, put_u32, put_u64, put_u8, Cursor};

const MAGIC: u32 = 0x5A45_5347; // "ZSEG"
const VERSION: u32 = 1;

const PAYLOAD_SIM: u8 = 0;
const PAYLOAD_REAL: u8 = 1;

/// An open cold segment: everything but the chunks themselves, which are
/// loaded (and cached) on demand by the read path.
#[derive(Debug, Clone)]
pub struct SegmentMeta {
    pub partition: PartitionId,
    /// Offset of the first chunk.
    pub base: ChunkOffset,
    /// One past the last chunk.
    pub end: ChunkOffset,
    /// Total payload bytes across the chunks.
    pub data_bytes: u64,
    /// Offset membership filter, checked before any cold load.
    pub bloom: Bloom,
    pub path: PathBuf,
}

impl SegmentMeta {
    pub fn chunks(&self) -> u64 {
        self.end - self.base
    }

    pub fn holds(&self, offset: ChunkOffset) -> bool {
        self.base <= offset && offset < self.end
    }
}

fn file_name(partition: PartitionId, base: ChunkOffset, end: ChunkOffset) -> String {
    format!("seg-p{}-{base:016x}-{end:016x}.seg", partition.0)
}

fn encode_chunk(chunk: &Chunk, out: &mut Vec<u8>) {
    put_u32(out, chunk.records);
    put_u32(out, chunk.record_size);
    match &chunk.payload {
        Payload::Real(data) => {
            put_u8(out, PAYLOAD_REAL);
            out.extend_from_slice(data);
        }
        Payload::Sim => put_u8(out, PAYLOAD_SIM),
    }
}

fn decode_chunk(cur: &mut Cursor<'_>) -> Option<Chunk> {
    let records = cur.u32()?;
    let record_size = cur.u32()?;
    match cur.u8()? {
        PAYLOAD_REAL => {
            let data = cur.take(records as usize * record_size as usize)?.to_vec();
            // The cold tier's single materialisation point: one buffer per
            // chunk per segment load; every reader of the cached segment
            // shares the `Rc` from here on.
            Some(Chunk::real(records, record_size, Rc::new(data)))
        }
        PAYLOAD_SIM => Some(Chunk::sim(records, record_size)),
        _ => None,
    }
}

/// Write `chunks` (the run `[base, base + chunks.len())`) as one segment
/// file under `dir`. Builds the bloom, frames every chunk, checksums the
/// image and writes it in one shot.
pub(crate) fn write_segment(
    dir: &Path,
    partition: PartitionId,
    base: ChunkOffset,
    chunks: &[Chunk],
) -> io::Result<SegmentMeta> {
    assert!(!chunks.is_empty(), "segments are never empty");
    let end = base + chunks.len() as u64;
    let data_bytes: u64 = chunks.iter().map(Chunk::bytes).sum();

    let mut bloom = Bloom::with_capacity(chunks.len() as u64);
    for off in base..end {
        bloom.insert(off);
    }

    let mut image = Vec::new();
    put_u32(&mut image, MAGIC);
    put_u32(&mut image, VERSION);
    put_u32(&mut image, partition.0 as u32);
    put_u64(&mut image, base);
    put_u64(&mut image, end);
    put_u64(&mut image, data_bytes);
    let (bits, hashes, words) = bloom.parts();
    put_u32(&mut image, hashes);
    put_u32(&mut image, bits);
    put_u32(&mut image, words.len() as u32);
    for &w in words {
        put_u64(&mut image, w);
    }
    for chunk in chunks {
        encode_chunk(chunk, &mut image);
    }
    let sum = fnv64(&image);
    put_u64(&mut image, sum);

    let path = dir.join(file_name(partition, base, end));
    fs::write(&path, &image)?;
    Ok(SegmentMeta { partition, base, end, data_bytes, bloom, path })
}

/// Parse a segment image's header + bloom; returns the meta and a cursor
/// positioned at the first chunk. `None` on any structural mismatch.
fn parse_header<'a>(bytes: &'a [u8], path: &Path) -> Option<(SegmentMeta, Cursor<'a>)> {
    if bytes.len() < 8 {
        return None;
    }
    let (image, sum_bytes) = bytes.split_at(bytes.len() - 8);
    let sum = u64::from_le_bytes(sum_bytes.try_into().expect("8 bytes"));
    if fnv64(image) != sum {
        return None;
    }
    let mut cur = Cursor::new(image);
    if cur.u32()? != MAGIC || cur.u32()? != VERSION {
        return None;
    }
    let partition = PartitionId(cur.u32()? as usize);
    let base = cur.u64()?;
    let end = cur.u64()?;
    if end <= base {
        return None;
    }
    let data_bytes = cur.u64()?;
    let hashes = cur.u32()?;
    let bits = cur.u32()?;
    let nwords = cur.u32()? as usize;
    let mut words = Vec::with_capacity(nwords);
    for _ in 0..nwords {
        words.push(cur.u64()?);
    }
    let bloom = Bloom::from_parts(bits, hashes, words)?;
    let meta =
        SegmentMeta { partition, base, end, data_bytes, bloom, path: path.to_path_buf() };
    Some((meta, cur))
}

/// Open one segment file's metadata (header + bloom; checksum verified
/// over the full image). `None` means torn/corrupt.
fn open_segment(path: &Path) -> io::Result<Option<SegmentMeta>> {
    let bytes = fs::read(path)?;
    Ok(parse_header(&bytes, path).map(|(meta, _)| meta))
}

/// Scan `dir` for segment files. Corrupt files (a flush torn by a crash)
/// are deleted — their chunks are still in the un-pruned WAL — and
/// counted in the second return. Metas come back sorted by partition,
/// then base offset.
pub(crate) fn scan_dir(dir: &Path) -> io::Result<(Vec<SegmentMeta>, u64)> {
    let mut metas = Vec::new();
    let mut dropped = 0u64;
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if !name.starts_with("seg-") || !name.ends_with(".seg") {
            continue;
        }
        let path = entry.path();
        match open_segment(&path)? {
            Some(meta) => metas.push(meta),
            None => {
                fs::remove_file(&path)?;
                dropped += 1;
            }
        }
    }
    // Widest file first among equal bases: a merged file shares its base
    // with its first source, and the open-time containment dedup keeps
    // whichever comes first.
    metas.sort_by_key(|m| (m.partition, m.base, std::cmp::Reverse(m.end)));
    Ok((metas, dropped))
}

/// Load a segment's chunks (the cold read path's cache-miss branch).
/// Re-verifies the checksum — the file may have rotted since the scan.
pub(crate) fn load_chunks(meta: &SegmentMeta) -> io::Result<Vec<Chunk>> {
    let bytes = fs::read(&meta.path)?;
    let Some((parsed, mut cur)) = parse_header(&bytes, &meta.path) else {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("segment {} failed checksum on load", meta.path.display()),
        ));
    };
    debug_assert_eq!(parsed.base, meta.base);
    debug_assert_eq!(parsed.end, meta.end);
    let n = parsed.chunks() as usize;
    let mut chunks = Vec::with_capacity(n);
    for _ in 0..n {
        let Some(chunk) = decode_chunk(&mut cur) else {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("segment {} truncated chunk run", meta.path.display()),
            ));
        };
        chunks.push(chunk);
    }
    Ok(chunks)
}
