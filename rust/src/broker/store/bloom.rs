//! A plain bloom filter over chunk offsets, one per cold segment file.
//!
//! Sorted segment stores keep a bloom per file so point lookups can skip
//! files that cannot contain the key. Our keys (chunk offsets) are dense
//! within a segment's `[base, end)` range, so the range check alone is
//! precise — the bloom's job here is the same one the footer checksum does
//! for payload bytes: a cheap, independent consistency witness over the
//! offset index that survives compaction rewrites, and the structural slot
//! where a sparse-key store would do its real filtering. Lookups consult
//! it before touching a file; a negative for an in-range offset means the
//! file does not hold what its name claims.
//!
//! No external deps: double hashing over two FNV-1a style mixes,
//! `k` probes into an `m`-bit array, sized at build time for ~1% false
//! positives (10 bits/key, 7 probes).

/// Bits per inserted key (≈1% false-positive rate with [`HASHES`] probes).
const BITS_PER_KEY: u64 = 10;
/// Probes per lookup (`k` ≈ 0.7 · bits/key).
const HASHES: u32 = 7;

/// A fixed-size bloom filter over `u64` keys.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Bloom {
    /// Bit array, 64 bits per word.
    words: Vec<u64>,
    /// Total bits (`m`); kept explicit so serialization round-trips.
    bits: u32,
    /// Probes per key (`k`).
    hashes: u32,
}

/// 64-bit FNV-1a.
fn fnv1a(seed: u64, key: u64) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64 ^ seed;
    for byte in key.to_le_bytes() {
        h ^= byte as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

impl Bloom {
    /// An empty filter sized for `expected` keys.
    pub fn with_capacity(expected: u64) -> Self {
        let bits = (expected.max(1) * BITS_PER_KEY).min(u32::MAX as u64) as u32;
        let bits = bits.max(64);
        Self { words: vec![0; bits.div_ceil(64) as usize], bits, hashes: HASHES }
    }

    /// Rebuild from serialized parts (segment file footer).
    pub fn from_parts(bits: u32, hashes: u32, words: Vec<u64>) -> Option<Self> {
        if bits == 0 || hashes == 0 || words.len() != bits.div_ceil(64) as usize {
            return None;
        }
        Some(Self { words, bits, hashes })
    }

    /// The serialized parts: `(bits, hashes, words)`.
    pub fn parts(&self) -> (u32, u32, &[u64]) {
        (self.bits, self.hashes, &self.words)
    }

    /// Double-hashed probe positions: `h1 + i·h2 mod m`.
    fn probe(&self, key: u64, i: u32) -> usize {
        let h1 = fnv1a(0, key);
        let h2 = fnv1a(0x9e37_79b9_7f4a_7c15, key) | 1; // odd: full cycle
        (h1.wrapping_add((i as u64).wrapping_mul(h2)) % self.bits as u64) as usize
    }

    pub fn insert(&mut self, key: u64) {
        for i in 0..self.hashes {
            let bit = self.probe(key, i);
            self.words[bit / 64] |= 1u64 << (bit % 64);
        }
    }

    /// `false` means definitely absent; `true` means probably present.
    pub fn might_contain(&self, key: u64) -> bool {
        (0..self.hashes).all(|i| {
            let bit = self.probe(key, i);
            self.words[bit / 64] & (1u64 << (bit % 64)) != 0
        })
    }
}
