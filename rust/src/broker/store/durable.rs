//! The durable tiered backend: WAL ring + in-memory tail + cold segment
//! files, presenting exactly the [`LogStore`] semantics of the in-memory
//! backend.
//!
//! ## Data path
//!
//! * **Append** — the chunk is framed into the WAL first, then appended
//!   to the in-memory tail (a plain `PartitionLog`). Whenever the tail
//!   seals a segment, that segment's chunk run is flushed to an
//!   immutable cold file and dropped from memory, the WAL ring prunes
//!   files the cold tier now covers, and a compaction pass keeps the
//!   cold file count bounded.
//! * **Read** — one budget walk with the same always-make-progress rule
//!   as `PartitionLog::walk_from`, serving the cold range from a small
//!   FIFO cache of decoded segments (`Rc<Vec<Chunk>>` — loaded once,
//!   shared by every reader) and continuing seamlessly into the tail.
//! * **Trim** — logical *units* mirror the segment boundaries the memory
//!   backend would have sealed, so `start` advances at identical points
//!   regardless of how compaction has merged the physical files; cold
//!   files wholly below the floor are deleted.
//!
//! ## Recovery
//!
//! [`DurableStore::open`] on a non-empty directory is broker crash
//! recovery: scan the cold files (dropping torn flushes — the WAL still
//! covers them), replay the WAL in write order (`TOTALS` snapshots set
//! the lifetime counters, appends rebuild the tail and re-add, trims
//! re-raise the floor), and start a fresh WAL file with a post-replay
//! snapshot. Replayed real payloads are materialised once here — the
//! recovery-path equivalent of the producer's single `Chunk::real`.
//!
//! I/O errors outside `open` panic: the simulator treats a failing disk
//! under the store the way it treats OOM — not a modeled fault.

use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};
use std::fs;
use std::io;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::config::StoreMode;
use crate::proto::{Chunk, ChunkOffset, PartitionId, StampedChunk};

use super::super::log::{PartitionLog, TrimmedError};
use super::compaction::{self, CompactionConfig};
use super::segment::{self, SegmentMeta};
use super::wal::{WalRecord, WalRing};
use super::{LogStore, StoreParams, StoreStats};

/// Distinguishes sibling ephemeral stores within one process.
static AUTO_DIR_SEQ: AtomicU64 = AtomicU64::new(0);

fn auto_dir() -> PathBuf {
    std::env::temp_dir().join(format!(
        "zettastream-store-{}-{}",
        std::process::id(),
        AUTO_DIR_SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// One logical flush unit: a sealed tail segment's `[base, end)` span
/// and payload bytes. Trim advances over these — never over physical
/// file boundaries, which compaction is free to merge.
#[derive(Debug, Clone, Copy)]
struct TrimUnit {
    base: ChunkOffset,
    end: ChunkOffset,
    bytes: u64,
}

/// Per-partition durable state.
#[derive(Debug)]
struct DurablePartition {
    /// Hot tail: the resident `PartitionLog` over `[cold_end, head)`.
    tail: PartitionLog,
    /// Untrimmed flush units covering `[start, cold_end)`, oldest first.
    units: VecDeque<TrimUnit>,
    /// Cold files sorted by base offset.
    files: Vec<SegmentMeta>,
    /// Logical retained start (the memory backend's `start` twin).
    start: ChunkOffset,
    /// Lifetime appended totals (restored from WAL snapshots on reopen).
    total_bytes: u64,
    total_records: u64,
}

impl DurablePartition {
    /// First offset not yet flushed to a cold file.
    fn cold_end(&self) -> ChunkOffset {
        self.tail.start()
    }

    /// The memory backend's trim rule over units + tail: whole sealed
    /// spans strictly below `watermark` go, but never the last resident
    /// span. Returns bytes reclaimed (cold for units, memory for tail).
    fn apply_trim(&mut self, watermark: ChunkOffset) -> u64 {
        let mut reclaimed = 0;
        while let Some(u) = self.units.front() {
            if u.end <= watermark && self.units.len() + self.tail.resident_segments() > 1 {
                self.start = u.end;
                reclaimed += u.bytes;
                self.units.pop_front();
            } else {
                break;
            }
        }
        if self.units.is_empty() {
            reclaimed += self.tail.trim_below(watermark);
            self.start = self.start.max(self.tail.start());
        }
        reclaimed
    }
}

/// FIFO cache of decoded cold segments, keyed by `(partition, base)`.
#[derive(Debug)]
struct ColdCache {
    map: HashMap<(PartitionId, ChunkOffset), Rc<Vec<Chunk>>>,
    order: VecDeque<(PartitionId, ChunkOffset)>,
    cap: usize,
}

impl ColdCache {
    fn new(cap: usize) -> Self {
        ColdCache { map: HashMap::new(), order: VecDeque::new(), cap: cap.max(1) }
    }

    fn get(&self, key: (PartitionId, ChunkOffset)) -> Option<Rc<Vec<Chunk>>> {
        self.map.get(&key).cloned()
    }

    fn insert(&mut self, key: (PartitionId, ChunkOffset), chunks: Rc<Vec<Chunk>>) {
        if self.map.insert(key, chunks).is_none() {
            self.order.push_back(key);
        }
        while self.order.len() > self.cap {
            let old = self.order.pop_front().expect("len checked");
            self.map.remove(&old);
        }
    }

    /// Drop every entry of `p` (its file set changed under us).
    fn purge(&mut self, p: PartitionId) {
        self.order.retain(|k| k.0 != p);
        self.map.retain(|k, _| k.0 != p);
    }
}

/// The durable tiered store (see module docs).
#[derive(Debug)]
pub struct DurableStore {
    root: PathBuf,
    seg_dir: PathBuf,
    /// Auto temp dir: remove the tree on drop.
    ephemeral: bool,
    compaction: CompactionConfig,
    wal: WalRing,
    order: Vec<PartitionId>,
    parts: HashMap<PartitionId, DurablePartition>,
    cache: RefCell<ColdCache>,
    stats: RefCell<StoreStats>,
}

/// Current lifetime-totals snapshot records, one per partition.
fn totals_records(
    order: &[PartitionId],
    parts: &HashMap<PartitionId, DurablePartition>,
) -> Vec<WalRecord> {
    order
        .iter()
        .map(|&p| {
            let d = &parts[&p];
            WalRecord::Totals { partition: p, bytes: d.total_bytes, records: d.total_records }
        })
        .collect()
}

impl DurableStore {
    /// Open (or create) the store under `params.dir` hosting
    /// `partitions`. A non-empty directory is replayed — this is the
    /// broker-restart recovery path; see the module docs.
    pub fn open(params: &StoreParams, partitions: &[PartitionId]) -> io::Result<Self> {
        let (root, ephemeral) = match &params.dir {
            Some(dir) => (dir.clone(), false),
            None => (auto_dir(), true),
        };
        let seg_dir = root.join("segments");
        fs::create_dir_all(&seg_dir)?;

        let mut stats = StoreStats::default();
        let (metas, torn) = segment::scan_dir(&seg_dir)?;
        stats.torn_segments = torn;

        let (mut wal, replay) = WalRing::open(&root.join("wal"), params.wal_file_bytes)?;

        let order: Vec<PartitionId> = partitions.to_vec();
        let mut parts = HashMap::with_capacity(order.len());
        for &p in &order {
            // A crash mid-compaction can leave a merged file alongside the
            // sources it subsumes; keep the widest cover, drop contained.
            let mut files: Vec<SegmentMeta> = Vec::new();
            for meta in metas.iter().filter(|m| m.partition == p) {
                match files.last() {
                    Some(prev) if meta.end <= prev.end => {
                        fs::remove_file(&meta.path)?;
                        stats.segments_compacted += 1;
                    }
                    _ => files.push(meta.clone()),
                }
            }
            let cold_end = files.last().map_or(0, |m| m.end);
            let start = files.first().map_or(cold_end, |m| m.base);
            // Reopened units are the physical file boundaries — coarser
            // than the lost in-memory seal points, which only means trim
            // advances in bigger steps until fresh flushes take over.
            let units = files
                .iter()
                .map(|m| TrimUnit { base: m.base, end: m.end, bytes: m.data_bytes })
                .collect();
            parts.insert(
                p,
                DurablePartition {
                    tail: PartitionLog::with_base(p, params.segment_bytes, cold_end),
                    units,
                    files,
                    start,
                    total_bytes: 0,
                    total_records: 0,
                },
            );
        }

        // Replay in write order: snapshots set, appends add + rebuild the
        // tail, trims re-raise the floor. Appends the cold tier already
        // covers still *count* (they postdate the last snapshot) but are
        // skipped for the tail.
        for rec in replay {
            match rec {
                WalRecord::Totals { partition, bytes, records } => {
                    if let Some(d) = parts.get_mut(&partition) {
                        d.total_bytes = bytes;
                        d.total_records = records;
                    }
                }
                WalRecord::Append { partition, offset, chunk } => {
                    let Some(d) = parts.get_mut(&partition) else { continue };
                    d.total_bytes += chunk.bytes();
                    d.total_records += chunk.records as u64;
                    let head = d.tail.head();
                    if offset < head {
                        wal.stats_mut().replayed_skipped += 1;
                    } else if offset == head {
                        d.tail.append(chunk);
                    } else {
                        panic!(
                            "WAL gap replaying {partition}: record at {offset}, tail head {head}"
                        );
                    }
                }
                WalRecord::Trim { partition, floor } => {
                    if let Some(d) = parts.get_mut(&partition) {
                        d.apply_trim(floor);
                    }
                }
            }
        }

        let mut store = DurableStore {
            root,
            seg_dir,
            ephemeral,
            compaction: CompactionConfig::with_min_segments(params.compact_min_segments),
            wal,
            order: order.clone(),
            parts,
            cache: RefCell::new(ColdCache::new(params.cold_cache_segments)),
            stats: RefCell::new(stats),
        };

        // Anchor the fresh WAL file with a post-replay snapshot, then
        // settle the tiers (flush replayed seals, prune, compact).
        let snapshot = totals_records(&store.order, &store.parts);
        for rec in &snapshot {
            store.wal.append(rec, Vec::new)?;
        }
        for p in order {
            store.flush_tail(p)?;
            store.maintain(p)?;
        }
        Ok(store)
    }

    fn part(&self, p: PartitionId) -> &DurablePartition {
        self.parts.get(&p).unwrap_or_else(|| panic!("partition {p} not hosted"))
    }

    /// Flush every sealed tail segment of `p` to a cold file (one file
    /// per seal — the flush unit that trim parity is built on).
    fn flush_tail(&mut self, p: PartitionId) -> io::Result<()> {
        loop {
            let (base, bytes, chunks) = {
                let d = self.parts.get_mut(&p).expect("validated");
                match d.tail.front_sealed() {
                    // Rc-payload clones: the flush shares, never copies.
                    Some((base, bytes, chunks)) => (base, bytes, chunks.to_vec()),
                    None => return Ok(()),
                }
            };
            let meta = segment::write_segment(&self.seg_dir, p, base, &chunks)?;
            let end = base + chunks.len() as u64;
            let d = self.parts.get_mut(&p).expect("validated");
            d.files.push(meta);
            d.units.push_back(TrimUnit { base, end, bytes });
            d.tail.trim_below(end);
            self.stats.borrow_mut().segments_flushed += 1;
        }
    }

    /// Post-flush/post-trim housekeeping: prune WAL files the cold tier
    /// covers, drop fully-trimmed cold files, merge old runs.
    fn maintain(&mut self, p: PartitionId) -> io::Result<()> {
        let flushed: HashMap<PartitionId, ChunkOffset> =
            self.parts.iter().map(|(&q, d)| (q, d.cold_end())).collect();
        self.wal.prune(&flushed)?;

        let d = self.parts.get_mut(&p).expect("validated");
        let before = d.files.len();
        compaction::compact_partition(
            &self.seg_dir,
            &mut d.files,
            d.start,
            &self.compaction,
            &mut self.stats.borrow_mut(),
        )?;
        if d.files.len() != before {
            self.cache.borrow_mut().purge(p);
        }
        Ok(())
    }

    /// One cold chunk by offset: bloom-checked file lookup through the
    /// decoded-segment cache. Panics on corruption (a bloom negative for
    /// an in-range offset, or a missing file) — the WAL/scan layers are
    /// supposed to have quarantined those.
    fn cold_chunk(&self, d: &DurablePartition, at: ChunkOffset) -> Chunk {
        let p = d.tail.id;
        let idx = d.files.partition_point(|m| m.end <= at);
        let meta = d
            .files
            .get(idx)
            .filter(|m| m.holds(at))
            .unwrap_or_else(|| panic!("no cold segment of {p} holds offset {at}"));
        {
            let mut stats = self.stats.borrow_mut();
            stats.bloom_checks += 1;
            if !meta.bloom.might_contain(at) {
                stats.bloom_negatives += 1;
                panic!(
                    "bloom denies offset {at} inside segment {} — corrupt index",
                    meta.path.display()
                );
            }
        }
        let key = (p, meta.base);
        let cached = self.cache.borrow().get(key);
        let chunks = match cached {
            Some(chunks) => {
                self.stats.borrow_mut().cold_cache_hits += 1;
                chunks
            }
            None => {
                let loaded = Rc::new(segment::load_chunks(meta).unwrap_or_else(|e| {
                    panic!("cold segment load failed ({}): {e}", meta.path.display())
                }));
                self.stats.borrow_mut().cold_loads += 1;
                self.cache.borrow_mut().insert(key, Rc::clone(&loaded));
                loaded
            }
        };
        chunks[(at - meta.base) as usize].clone()
    }

    /// The unified budget walk: cold range then tail, replicating
    /// `PartitionLog::walk_from`'s rules exactly (always take the first
    /// available chunk; stop when the next would bust the budget).
    fn walk(
        &self,
        p: PartitionId,
        offset: ChunkOffset,
        max_bytes: u64,
        mut f: impl FnMut(ChunkOffset, &Chunk),
    ) -> (u64, u64) {
        let d = self.part(p);
        let cold_end = d.cold_end();
        let head = d.tail.head();
        if offset >= head {
            return (0, 0);
        }
        let mut at = offset;
        let mut taken = 0u64;
        let mut bytes = 0u64;
        let mut budget = max_bytes;
        while at < cold_end {
            let chunk = self.cold_chunk(d, at);
            let b = chunk.bytes();
            if taken > 0 && b > budget {
                return (taken, bytes);
            }
            f(at, &chunk);
            taken += 1;
            bytes += b;
            budget = budget.saturating_sub(b);
            at += 1;
            if budget == 0 {
                return (taken, bytes);
            }
        }
        if at < head {
            // Crossing into the tail: the at-least-one rule only applies
            // if nothing was taken yet; otherwise the boundary chunk must
            // fit like any mid-walk chunk would.
            if taken > 0 {
                let (_, first) = d.tail.peek_from(at, 1);
                if first > budget {
                    return (taken, bytes);
                }
            }
            let (t, b) = d.tail.walk_from(at, budget, &mut f);
            taken += t;
            bytes += b;
        }
        (taken, bytes)
    }
}

impl LogStore for DurableStore {
    fn mode(&self) -> StoreMode {
        StoreMode::Durable
    }

    fn partitions(&self) -> Vec<PartitionId> {
        self.order.clone()
    }

    fn contains(&self, p: PartitionId) -> bool {
        self.parts.contains_key(&p)
    }

    fn append(&mut self, p: PartitionId, chunk: Chunk) -> ChunkOffset {
        let offset = self.part(p).tail.head();
        let rec = WalRecord::Append { partition: p, offset, chunk: chunk.clone() };
        // The rotation snapshot excludes the pending record (the WAL
        // layer writes it after the snapshot in the fresh file).
        let order = &self.order;
        let parts = &self.parts;
        self.wal
            .append(&rec, || totals_records(order, parts))
            .unwrap_or_else(|e| panic!("wal append failed for {p}: {e}"));

        let d = self.parts.get_mut(&p).expect("validated");
        d.total_bytes += chunk.bytes();
        d.total_records += chunk.records as u64;
        let assigned = d.tail.append(chunk);
        debug_assert_eq!(assigned, offset);

        self.flush_tail(p).unwrap_or_else(|e| panic!("segment flush failed for {p}: {e}"));
        self.maintain(p).unwrap_or_else(|e| panic!("store maintenance failed for {p}: {e}"));
        offset
    }

    fn head(&self, p: PartitionId) -> ChunkOffset {
        self.part(p).tail.head()
    }

    fn start(&self, p: PartitionId) -> ChunkOffset {
        self.part(p).start
    }

    fn available_from(&self, p: PartitionId, offset: ChunkOffset) -> u64 {
        let d = self.part(p);
        d.tail.head().saturating_sub(offset.max(d.start))
    }

    fn read_into(
        &self,
        p: PartitionId,
        offset: ChunkOffset,
        max_bytes: u64,
        out: &mut Vec<StampedChunk>,
    ) -> Result<u64, TrimmedError> {
        let start = self.part(p).start;
        if offset < start {
            return Err(TrimmedError { requested: offset, start });
        }
        let (chunks, _) = self.walk(p, offset, max_bytes, |_, _| {});
        out.reserve(chunks as usize);
        let (taken, _) = self.walk(p, offset, max_bytes, |at, chunk| {
            out.push(StampedChunk { partition: p, offset: at, chunk: chunk.clone() });
        });
        debug_assert_eq!(taken, chunks);
        Ok(taken)
    }

    fn peek_from(&self, p: PartitionId, offset: ChunkOffset, max_bytes: u64) -> (u64, u64) {
        if offset < self.part(p).start {
            return (0, 0);
        }
        self.walk(p, offset, max_bytes, |_, _| {})
    }

    fn trim_below(&mut self, p: PartitionId, watermark: ChunkOffset) -> u64 {
        let d = self.parts.get_mut(&p).expect("validated");
        let before = d.start;
        let reclaimed = d.apply_trim(watermark);
        let floor = d.start;
        if floor > before {
            let rec = WalRecord::Trim { partition: p, floor };
            let order = &self.order;
            let parts = &self.parts;
            self.wal
                .append(&rec, || totals_records(order, parts))
                .unwrap_or_else(|e| panic!("wal trim failed for {p}: {e}"));
            self.maintain(p)
                .unwrap_or_else(|e| panic!("store maintenance failed for {p}: {e}"));
        }
        reclaimed
    }

    fn resident_bytes(&self) -> u64 {
        self.parts.values().map(|d| d.tail.resident_bytes()).sum()
    }

    fn total_appended_bytes(&self, p: PartitionId) -> u64 {
        self.part(p).total_bytes
    }

    fn total_appended_records(&self, p: PartitionId) -> u64 {
        self.part(p).total_records
    }

    fn stats(&self) -> StoreStats {
        let mut stats = self.stats.borrow().clone();
        stats.wal = self.wal.stats();
        stats.cold_segments = self.parts.values().map(|d| d.files.len() as u64).sum();
        stats.cold_bytes =
            self.parts.values().flat_map(|d| d.files.iter().map(|m| m.data_bytes)).sum();
        stats
    }
}

impl Drop for DurableStore {
    fn drop(&mut self) {
        if self.ephemeral {
            let _ = fs::remove_dir_all(&self.root);
        }
    }
}

/// Where this store keeps its files (tests point crash-recovery runs at
/// the same directory).
impl DurableStore {
    pub fn root(&self) -> &Path {
        &self.root
    }
}
