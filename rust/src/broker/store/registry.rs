//! Pluggable store construction, keyed by [`StoreMode`] — the storage
//! twin of `SourceRegistry`/`WriterRegistry`: `cluster::launch` resolves
//! the configured mode and never names a concrete backend type.

use std::io;

use crate::config::StoreMode;
use crate::proto::PartitionId;

use super::durable::DurableStore;
use super::memory::MemoryStore;
use super::{LogStore, StoreParams};

/// Builds one [`LogStore`] backend for its mode.
pub trait StoreFactory {
    /// The mode this factory serves.
    fn mode(&self) -> StoreMode;

    /// Open the backend hosting `partitions`. Only the durable backend
    /// can actually fail (directory I/O); memory is infallible.
    fn open(
        &self,
        params: &StoreParams,
        partitions: &[PartitionId],
    ) -> io::Result<Box<dyn LogStore>>;
}

struct MemoryStoreFactory;

impl StoreFactory for MemoryStoreFactory {
    fn mode(&self) -> StoreMode {
        StoreMode::Memory
    }

    fn open(
        &self,
        params: &StoreParams,
        partitions: &[PartitionId],
    ) -> io::Result<Box<dyn LogStore>> {
        Ok(Box::new(MemoryStore::new(params.segment_bytes, partitions)))
    }
}

struct DurableStoreFactory;

impl StoreFactory for DurableStoreFactory {
    fn mode(&self) -> StoreMode {
        StoreMode::Durable
    }

    fn open(
        &self,
        params: &StoreParams,
        partitions: &[PartitionId],
    ) -> io::Result<Box<dyn LogStore>> {
        Ok(Box::new(DurableStore::open(params, partitions)?))
    }
}

/// The pluggable factory registry, keyed by [`StoreMode`].
pub struct StoreRegistry {
    factories: Vec<Box<dyn StoreFactory>>,
}

impl StoreRegistry {
    /// An empty registry (plug in your own factories).
    pub fn empty() -> Self {
        Self { factories: Vec::new() }
    }

    /// The two built-in backends: memory, durable.
    pub fn builtin() -> Self {
        let mut r = Self::empty();
        r.register(Box::new(MemoryStoreFactory));
        r.register(Box::new(DurableStoreFactory));
        r
    }

    /// Register a factory; replaces any previous factory for the same mode.
    pub fn register(&mut self, factory: Box<dyn StoreFactory>) {
        if let Some(slot) = self.factories.iter_mut().find(|f| f.mode() == factory.mode()) {
            *slot = factory;
        } else {
            self.factories.push(factory);
        }
    }

    pub fn get(&self, mode: StoreMode) -> Option<&dyn StoreFactory> {
        self.factories.iter().find(|f| f.mode() == mode).map(|b| b.as_ref())
    }

    /// Resolve a mode or die loudly — an unregistered mode is a config
    /// error, not a silently storeless broker.
    pub fn expect(&self, mode: StoreMode) -> &dyn StoreFactory {
        self.get(mode)
            .unwrap_or_else(|| panic!("no store factory registered for mode `{}`", mode.name()))
    }

    /// The modes currently registered (in registration order).
    pub fn modes(&self) -> Vec<StoreMode> {
        self.factories.iter().map(|f| f.mode()).collect()
    }
}

impl Default for StoreRegistry {
    fn default() -> Self {
        Self::builtin()
    }
}
