//! The in-memory backend: today's `PartitionLog` per partition, behind
//! the [`LogStore`] trait. This is the sim default — no I/O, no extra
//! state, byte-for-byte the pre-store-subsystem broker behavior.

use std::collections::HashMap;

use crate::config::StoreMode;
use crate::proto::{Chunk, ChunkOffset, PartitionId, StampedChunk};

use super::super::log::{PartitionLog, TrimmedError};
use super::{LogStore, StoreStats};

/// Pure in-memory partition logs (creation-ordered for determinism).
#[derive(Debug)]
pub struct MemoryStore {
    order: Vec<PartitionId>,
    logs: HashMap<PartitionId, PartitionLog>,
}

impl MemoryStore {
    pub fn new(segment_bytes: u64, partitions: &[PartitionId]) -> Self {
        let mut order = Vec::with_capacity(partitions.len());
        let mut logs = HashMap::with_capacity(partitions.len());
        for &p in partitions {
            order.push(p);
            logs.insert(p, PartitionLog::new(p, segment_bytes));
        }
        MemoryStore { order, logs }
    }

    fn log(&self, p: PartitionId) -> &PartitionLog {
        self.logs.get(&p).unwrap_or_else(|| panic!("partition {p} not hosted"))
    }

    fn log_mut(&mut self, p: PartitionId) -> &mut PartitionLog {
        self.logs.get_mut(&p).unwrap_or_else(|| panic!("partition {p} not hosted"))
    }
}

impl LogStore for MemoryStore {
    fn mode(&self) -> StoreMode {
        StoreMode::Memory
    }

    fn partitions(&self) -> Vec<PartitionId> {
        self.order.clone()
    }

    fn contains(&self, p: PartitionId) -> bool {
        self.logs.contains_key(&p)
    }

    fn append(&mut self, p: PartitionId, chunk: Chunk) -> ChunkOffset {
        self.log_mut(p).append(chunk)
    }

    fn head(&self, p: PartitionId) -> ChunkOffset {
        self.log(p).head()
    }

    fn start(&self, p: PartitionId) -> ChunkOffset {
        self.log(p).start()
    }

    fn available_from(&self, p: PartitionId, offset: ChunkOffset) -> u64 {
        self.log(p).available_from(offset)
    }

    fn read_into(
        &self,
        p: PartitionId,
        offset: ChunkOffset,
        max_bytes: u64,
        out: &mut Vec<StampedChunk>,
    ) -> Result<u64, TrimmedError> {
        self.log(p).read_into(offset, max_bytes, out)
    }

    fn peek_from(&self, p: PartitionId, offset: ChunkOffset, max_bytes: u64) -> (u64, u64) {
        self.log(p).peek_from(offset, max_bytes)
    }

    fn trim_below(&mut self, p: PartitionId, watermark: ChunkOffset) -> u64 {
        self.log_mut(p).trim_below(watermark)
    }

    fn resident_bytes(&self) -> u64 {
        self.logs.values().map(PartitionLog::resident_bytes).sum()
    }

    fn total_appended_bytes(&self, p: PartitionId) -> u64 {
        self.log(p).total_appended_bytes()
    }

    fn total_appended_records(&self, p: PartitionId) -> u64 {
        self.log(p).total_appended_records()
    }

    fn stats(&self) -> StoreStats {
        StoreStats::default()
    }
}
