//! Pluggable broker log storage: in-memory segments or a durable tier.
//!
//! The broker stores partition logs behind the [`LogStore`] trait and
//! opens a backend through the [`StoreRegistry`] (the same pluggability
//! pattern as `SourceRegistry`/`WriterRegistry`), selected by the
//! `store_mode` config knob:
//!
//! * **`memory`** ([`MemoryStore`]) — today's pure in-memory
//!   `PartitionLog` per partition, unchanged. The sim default: zero
//!   behavior change, zero I/O, retention is the only footprint bound.
//! * **`durable`** ([`DurableStore`]) — a tiered log under the same
//!   semantics, built from three layers:
//!
//!   1. **WAL ring** ([`wal`]) — every append is framed into the active
//!      write-ahead file *before* it lands in the in-memory tail, so a
//!      broker crash loses nothing past the last intact frame. The ring
//!      rotates at `store_wal_bytes` and prunes sealed files once the
//!      cold tier holds their chunks.
//!   2. **Sorted segments** ([`segment`]) — when the in-memory tail seals
//!      a segment, its chunk run is flushed to an immutable, checksummed,
//!      bloom-indexed cold file and dropped from memory. Laggard readers
//!      (the hybrid source's pull-fallback, restarting consumers) serve
//!      from these files through a small shared-chunk cache, so the
//!      zero-copy discipline survives the disk hop: one materialisation
//!      per chunk per segment load, `Rc`-shared to every reader after.
//!   3. **Compaction** ([`compaction`]) — cold files wholly below the
//!      retention floor are deleted, and once a partition accumulates
//!      `store_compact_min_segments` files the oldest run is merged into
//!      one (fresh bloom, one checksum), keeping file counts and lookup
//!      fan-out bounded on long runs. Compaction is background
//!      maintenance: it charges no simulated time, mirroring a broker
//!      that compacts off the hot path.
//!
//! ## The retention-floor contract with checkpoints
//!
//! Trimming is driven by the broker exactly as before: the consumer
//! progress watermark, clamped by active push-subscription cursors and —
//! when a checkpoint coordinator is running — by the **committed-epoch
//! floor** (`RpcKind::CommitCheckpoint` cursors). The store never trims
//! or compacts past what the broker hands to [`LogStore::trim_below`],
//! so committed epochs double as the compaction floor: a durable broker
//! can always replay from the last committed checkpoint, and everything
//! below it is reclaimable on *both* tiers (memory tail and cold files)
//! plus the WAL ring.
//!
//! ## Trim-gap parity
//!
//! Both backends advance the retained `start` at the same points: the
//! durable store tracks the *logical* segment boundaries the memory
//! backend would have sealed (its flush units) and trims whole units,
//! independent of how compaction has merged the physical files
//! underneath. `TrimmedError` and the pull path's trim-gap recovery are
//! therefore byte-identical across `store_mode` settings — the golden
//! parity suite asserts exactly this.

use std::path::PathBuf;

use crate::config::{ExperimentConfig, StoreMode};
use crate::proto::{Chunk, ChunkOffset, PartitionId, StampedChunk};

use super::log::TrimmedError;

pub mod bloom;
mod codec;
pub mod compaction;
mod durable;
mod memory;
mod registry;
mod segment;
mod wal;
#[cfg(test)]
mod tests;

pub use bloom::Bloom;
pub use compaction::CompactionConfig;
pub use durable::DurableStore;
pub use memory::MemoryStore;
pub use registry::{StoreFactory, StoreRegistry};
pub use segment::SegmentMeta;
pub use wal::WalStats;

/// How a store backend is opened: which partitions it hosts and every
/// knob the config exposes. `Debug + Clone` like every params struct in
/// the crate (`BrokerParams`, writer/source params).
#[derive(Debug, Clone)]
pub struct StoreParams {
    pub mode: StoreMode,
    /// Durable root directory. `None` = an ephemeral per-process temp
    /// directory, created on open and removed when the store drops —
    /// what sweeps and tests want. Explicit paths persist across runs
    /// (that is what crash-recovery opens).
    pub dir: Option<PathBuf>,
    /// In-memory tail segment capacity; also the cold flush unit.
    pub segment_bytes: u64,
    /// WAL ring rotation size.
    pub wal_file_bytes: u64,
    /// Cold files per partition that trigger a merge.
    pub compact_min_segments: usize,
    /// Cold segments kept decoded for readers (shared-chunk cache).
    pub cold_cache_segments: usize,
}

impl StoreParams {
    /// Pure in-memory backend (the default everywhere a config is not in
    /// play: backup brokers, unit rigs).
    pub fn memory(segment_bytes: u64) -> Self {
        StoreParams {
            mode: StoreMode::Memory,
            dir: None,
            segment_bytes,
            wal_file_bytes: 64 << 20,
            compact_min_segments: 4,
            cold_cache_segments: 4,
        }
    }

    /// The experiment config's `store_*` knobs, verbatim.
    pub fn from_config(config: &ExperimentConfig) -> Self {
        StoreParams {
            mode: config.store_mode,
            dir: if config.store_dir.is_empty() {
                None
            } else {
                Some(PathBuf::from(&config.store_dir))
            },
            segment_bytes: config.store_segment_bytes,
            wal_file_bytes: config.store_wal_bytes,
            compact_min_segments: config.store_compact_min_segments,
            cold_cache_segments: config.store_cold_cache_segments,
        }
    }
}

/// Store-level counters, all zero for the memory backend. Exported as
/// `broker.store_*` gauges after a run and printed by `bench store`.
#[derive(Debug, Clone, Default)]
pub struct StoreStats {
    /// WAL ring counters (durable only).
    pub wal: WalStats,
    /// Sealed tail segments flushed to cold files.
    pub segments_flushed: u64,
    /// Cold files merged away by compaction.
    pub segments_compacted: u64,
    /// Merge passes run.
    pub compactions: u64,
    /// Corrupt cold files dropped at open (torn flushes; WAL re-covers).
    pub torn_segments: u64,
    /// Cold files currently on disk.
    pub cold_segments: u64,
    /// Payload bytes currently in cold files.
    pub cold_bytes: u64,
    /// Segment loads that hit the decoded-chunk cache.
    pub cold_cache_hits: u64,
    /// Segment loads that went to disk.
    pub cold_loads: u64,
    /// Bloom filter consultations on the cold read path.
    pub bloom_checks: u64,
    /// Bloom negatives (in-range offset the file denies — corruption
    /// tripwire; see [`bloom`]).
    pub bloom_negatives: u64,
}

/// A partition-log storage backend.
///
/// Semantics are pinned to [`super::PartitionLog`]'s — offsets are dense
/// chunk indices per partition, reads walk consecutive chunks under a
/// byte budget and always yield at least one available chunk, reads
/// below the retained `start` fail with [`TrimmedError`], and trimming
/// advances in whole-segment units. The golden parity harness runs both
/// backends over identical schedules and demands identical totals.
///
/// Read methods take `&self`: backends use interior mutability for
/// caches and counters so the broker can consult the store while holding
/// other borrows (cost model peeks, push-path gathers).
///
/// Partition-scoped methods panic on an unhosted partition — the broker
/// validates with [`LogStore::contains`] at its RPC boundaries first,
/// exactly as it did against the `HashMap` of logs.
pub trait LogStore {
    /// Which backend this is (registry echo, gauges).
    fn mode(&self) -> StoreMode;

    /// Hosted partitions, in deterministic (creation) order.
    fn partitions(&self) -> Vec<PartitionId>;

    /// Does this store host `p`?
    fn contains(&self, p: PartitionId) -> bool;

    /// Append one sealed chunk; returns its offset.
    fn append(&mut self, p: PartitionId, chunk: Chunk) -> ChunkOffset;

    /// Next offset to be written.
    fn head(&self, p: PartitionId) -> ChunkOffset;

    /// Oldest retained offset.
    fn start(&self, p: PartitionId) -> ChunkOffset;

    /// Chunks available at or past `offset`.
    fn available_from(&self, p: PartitionId, offset: ChunkOffset) -> u64;

    /// Read consecutive chunks from `offset` under `max_bytes` into
    /// `out`; returns chunks taken. See `PartitionLog::read_into`.
    fn read_into(
        &self,
        p: PartitionId,
        offset: ChunkOffset,
        max_bytes: u64,
        out: &mut Vec<StampedChunk>,
    ) -> Result<u64, TrimmedError>;

    /// Cost-model peek: `(chunks, bytes)` a read would return.
    fn peek_from(&self, p: PartitionId, offset: ChunkOffset, max_bytes: u64) -> (u64, u64);

    /// Advance retention; returns bytes reclaimed (both tiers).
    fn trim_below(&mut self, p: PartitionId, watermark: ChunkOffset) -> u64;

    /// Bytes resident **in memory** across partitions (the footprint the
    /// paper's retention bound is about; cold files are not counted).
    fn resident_bytes(&self) -> u64;

    /// Lifetime appended bytes (survives trimming and restarts).
    fn total_appended_bytes(&self, p: PartitionId) -> u64;

    /// Lifetime appended records (survives trimming and restarts).
    fn total_appended_records(&self, p: PartitionId) -> u64;

    /// Backend counters snapshot.
    fn stats(&self) -> StoreStats;

    /// [`LogStore::read_into`] into a fresh vector.
    fn read_from(
        &self,
        p: PartitionId,
        offset: ChunkOffset,
        max_bytes: u64,
    ) -> Result<Vec<StampedChunk>, TrimmedError> {
        let mut out = Vec::new();
        self.read_into(p, offset, max_bytes, &mut out)?;
        Ok(out)
    }
}

/// A read-only view of one partition inside a [`LogStore`] — what
/// `Broker::partition` hands to tests and examples, preserving the old
/// `Option<&PartitionLog>` call shapes over the trait object.
#[derive(Clone, Copy)]
pub struct LogView<'a> {
    store: &'a dyn LogStore,
    p: PartitionId,
}

impl<'a> LogView<'a> {
    pub(crate) fn new(store: &'a dyn LogStore, p: PartitionId) -> Self {
        LogView { store, p }
    }

    pub fn head(&self) -> ChunkOffset {
        self.store.head(self.p)
    }

    pub fn start(&self) -> ChunkOffset {
        self.store.start(self.p)
    }

    pub fn available_from(&self, offset: ChunkOffset) -> u64 {
        self.store.available_from(self.p, offset)
    }

    pub fn read_from(
        &self,
        offset: ChunkOffset,
        max_bytes: u64,
    ) -> Result<Vec<StampedChunk>, TrimmedError> {
        self.store.read_from(self.p, offset, max_bytes)
    }

    pub fn peek_from(&self, offset: ChunkOffset, max_bytes: u64) -> (u64, u64) {
        self.store.peek_from(self.p, offset, max_bytes)
    }

    pub fn total_appended_bytes(&self) -> u64 {
        self.store.total_appended_bytes(self.p)
    }

    pub fn total_appended_records(&self) -> u64 {
        self.store.total_appended_records(self.p)
    }
}
