//! Byte-level encode/decode helpers shared by the WAL and segment file
//! formats. Everything is little-endian and hand-rolled — the offline
//! vendor set has no serde, and the two formats are small enough that an
//! explicit codec is clearer than a derive anyway.

/// 64-bit FNV-1a over a byte slice — the integrity checksum both file
/// formats append to their payloads (torn-write detection, not crypto).
pub(crate) fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

pub(crate) fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

pub(crate) fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// A bounds-checked read cursor over a decoded buffer. Every getter
/// returns `None` past the end instead of panicking — a truncated file
/// tail must decode as "torn", not crash the replay.
pub(crate) struct Cursor<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Cursor<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, at: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.at
    }

    pub fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        if self.remaining() < n {
            return None;
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Some(s)
    }

    pub fn u8(&mut self) -> Option<u8> {
        self.take(1).map(|s| s[0])
    }

    pub fn u32(&mut self) -> Option<u32> {
        self.take(4).map(|s| u32::from_le_bytes(s.try_into().expect("4 bytes")))
    }

    pub fn u64(&mut self) -> Option<u64> {
        self.take(8).map(|s| u64::from_le_bytes(s.try_into().expect("8 bytes")))
    }
}
