//! Store subsystem unit tests: bloom filter, WAL ring, segment files,
//! compaction, registry, and memory-vs-durable semantic parity at the
//! `LogStore` level (the cluster-level golden parity lives in
//! `tests/durable_store.rs`).

use std::collections::HashMap;
use std::fs;
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::broker::TrimmedError;
use crate::config::StoreMode;
use crate::proto::{Chunk, PartitionId};

use super::bloom::Bloom;
use super::wal::{WalRecord, WalRing};
use super::{
    compaction, segment, CompactionConfig, DurableStore, LogStore, MemoryStore, StoreFactory,
    StoreParams, StoreRegistry, StoreStats,
};

static TEST_DIR_SEQ: AtomicU64 = AtomicU64::new(0);

/// A fresh directory under the system temp dir; the test removes it.
fn test_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "zettastream-store-test-{tag}-{}-{}",
        std::process::id(),
        TEST_DIR_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = fs::remove_dir_all(&dir);
    dir
}

fn sim(bytes: u32) -> Chunk {
    Chunk::sim(1, bytes)
}

/// A real chunk whose every payload byte is `fill`.
fn real(fill: u8, records: u32, record_size: u32) -> Chunk {
    Chunk::real(records, record_size, Rc::new(vec![fill; (records * record_size) as usize]))
}

fn durable_params(dir: &PathBuf, segment_bytes: u64) -> StoreParams {
    StoreParams {
        mode: StoreMode::Durable,
        dir: Some(dir.clone()),
        segment_bytes,
        wal_file_bytes: 64 << 20,
        compact_min_segments: 4,
        cold_cache_segments: 4,
    }
}

// -------------------------------------------------------------------------
// Bloom filter
// -------------------------------------------------------------------------

#[test]
fn bloom_has_no_false_negatives() {
    let mut b = Bloom::with_capacity(1000);
    for k in 0..1000u64 {
        b.insert(k);
    }
    for k in 0..1000u64 {
        assert!(b.might_contain(k), "inserted key {k} denied");
    }
}

#[test]
fn bloom_false_positive_rate_is_low() {
    let mut b = Bloom::with_capacity(1000);
    for k in 0..1000u64 {
        b.insert(k);
    }
    // ~1% expected at 10 bits/key with 7 hashes; 5% is a loose ceiling.
    let fp = (10_000u64..20_000).filter(|&k| b.might_contain(k)).count();
    assert!(fp < 500, "{fp} false positives in 10k absent-key probes");
}

#[test]
fn bloom_parts_roundtrip() {
    let mut b = Bloom::with_capacity(64);
    for k in 0..64u64 {
        b.insert(k * 3);
    }
    let (bits, hashes, words) = b.parts();
    let again = Bloom::from_parts(bits, hashes, words.to_vec()).expect("valid parts");
    assert_eq!(again, b);
}

#[test]
fn bloom_rejects_inconsistent_parts() {
    // bits demand more words than provided.
    assert!(Bloom::from_parts(1024, 7, vec![0; 2]).is_none());
}

// -------------------------------------------------------------------------
// WAL ring
// -------------------------------------------------------------------------

#[test]
fn wal_replays_records_in_write_order() {
    let dir = test_dir("wal-replay");
    {
        let (mut wal, replay) = WalRing::open(&dir, 1 << 20).unwrap();
        assert!(replay.is_empty(), "fresh dir replays nothing");
        for i in 0..10u64 {
            let rec = WalRecord::Append {
                partition: PartitionId(0),
                offset: i,
                chunk: real(i as u8, 2, 16),
            };
            wal.append(&rec, Vec::new).unwrap();
        }
        wal.append(&WalRecord::Trim { partition: PartitionId(0), floor: 3 }, Vec::new)
            .unwrap();
        assert_eq!(wal.stats().records, 10);
        assert_eq!(wal.stats().trims, 1);
    }
    let (wal, replay) = WalRing::open(&dir, 1 << 20).unwrap();
    assert_eq!(replay.len(), 11);
    for (i, rec) in replay[..10].iter().enumerate() {
        let WalRecord::Append { partition, offset, chunk } = rec else {
            panic!("expected append at {i}");
        };
        assert_eq!(*partition, PartitionId(0));
        assert_eq!(*offset, i as u64);
        let data = chunk.payload.buffer().expect("real payload survives replay");
        assert!(data.iter().all(|&b| b == i as u8));
    }
    assert!(matches!(replay[10], WalRecord::Trim { floor: 3, .. }));
    assert_eq!(wal.stats().replayed_records, 10);
    drop(wal);
    fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn wal_rotates_and_writes_snapshots() {
    let dir = test_dir("wal-rotate");
    let chunk = real(7, 1, 64);
    let rotate = 2 * WalRing::frame_bytes(&chunk);
    {
        let (mut wal, _) = WalRing::open(&dir, rotate).unwrap();
        for i in 0..6u64 {
            let rec = WalRecord::Append {
                partition: PartitionId(0),
                offset: i,
                chunk: chunk.clone(),
            };
            wal.append(&rec, || {
                vec![WalRecord::Totals { partition: PartitionId(0), bytes: i * 64, records: i }]
            })
            .unwrap();
        }
        assert!(wal.stats().files_created >= 3, "rotation never happened");
    }
    let (_, replay) = WalRing::open(&dir, rotate).unwrap();
    let appends: Vec<u64> = replay
        .iter()
        .filter_map(|r| match r {
            WalRecord::Append { offset, .. } => Some(*offset),
            _ => None,
        })
        .collect();
    assert_eq!(appends, (0..6).collect::<Vec<_>>(), "every append survives rotation");
    assert!(
        replay.iter().any(|r| matches!(r, WalRecord::Totals { .. })),
        "rotated files start with a totals snapshot"
    );
    fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn wal_prunes_files_the_cold_tier_covers() {
    let dir = test_dir("wal-prune");
    let chunk = real(1, 1, 32);
    // Rotate on every append past the first: one offset per sealed file.
    let (mut wal, _) = WalRing::open(&dir, WalRing::frame_bytes(&chunk)).unwrap();
    for i in 0..4u64 {
        let rec =
            WalRecord::Append { partition: PartitionId(0), offset: i, chunk: chunk.clone() };
        wal.append(&rec, Vec::new).unwrap();
    }
    let retained = wal.files_retained();
    assert!(retained >= 4);

    let mut flushed = HashMap::new();
    flushed.insert(PartitionId(0), 0u64);
    assert_eq!(wal.prune(&flushed).unwrap(), 0, "nothing flushed, nothing pruned");

    flushed.insert(PartitionId(0), 2);
    assert_eq!(wal.prune(&flushed).unwrap(), 2, "files holding offsets 0 and 1 go");
    assert_eq!(wal.files_retained(), retained - 2);
    assert_eq!(wal.stats().files_pruned, 2);
    drop(wal);
    fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn wal_torn_tail_ends_replay_cleanly() {
    let dir = test_dir("wal-torn");
    {
        let (mut wal, _) = WalRing::open(&dir, 1 << 20).unwrap();
        for i in 0..5u64 {
            let rec = WalRecord::Append {
                partition: PartitionId(0),
                offset: i,
                chunk: real(i as u8, 1, 32),
            };
            wal.append(&rec, Vec::new).unwrap();
        }
    }
    // Tear the last frame mid-payload, as a crash mid-write would.
    let path = dir.join("wal-00000000.log");
    let bytes = fs::read(&path).unwrap();
    fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();

    let (wal, replay) = WalRing::open(&dir, 1 << 20).unwrap();
    assert_eq!(replay.len(), 4, "intact prefix replays, torn record does not");
    assert_eq!(wal.stats().torn_tails, 1);
    drop(wal);
    fs::remove_dir_all(&dir).unwrap();
}

// -------------------------------------------------------------------------
// Segment files
// -------------------------------------------------------------------------

#[test]
fn segment_roundtrips_chunks_and_bloom() {
    let dir = test_dir("seg-roundtrip");
    fs::create_dir_all(&dir).unwrap();
    let chunks: Vec<Chunk> = (0..8).map(|i| real(i as u8, 4, 32)).collect();
    let meta = segment::write_segment(&dir, PartitionId(3), 100, &chunks).unwrap();
    assert_eq!((meta.base, meta.end), (100, 108));
    assert_eq!(meta.chunks(), 8);
    assert_eq!(meta.data_bytes, 8 * 4 * 32);
    for off in 100..108 {
        assert!(meta.bloom.might_contain(off), "bloom denies resident offset {off}");
    }

    let (scanned, dropped) = segment::scan_dir(&dir).unwrap();
    assert_eq!(dropped, 0);
    assert_eq!(scanned.len(), 1);
    assert_eq!(scanned[0].partition, PartitionId(3));

    let loaded = segment::load_chunks(&meta).unwrap();
    assert_eq!(loaded.len(), 8);
    for (i, c) in loaded.iter().enumerate() {
        assert_eq!((c.records, c.record_size), (4, 32));
        let data = c.payload.buffer().expect("real payload");
        assert!(data.iter().all(|&b| b == i as u8));
    }
    fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn segment_scan_quarantines_corrupt_files() {
    let dir = test_dir("seg-scan");
    fs::create_dir_all(&dir).unwrap();
    let keep = segment::write_segment(&dir, PartitionId(0), 0, &[sim(100)]).unwrap();
    let torn = segment::write_segment(&dir, PartitionId(0), 1, &[sim(100)]).unwrap();
    let bytes = fs::read(&torn.path).unwrap();
    fs::write(&torn.path, &bytes[..bytes.len() - 1]).unwrap();

    let (metas, dropped) = segment::scan_dir(&dir).unwrap();
    assert_eq!(dropped, 1);
    assert_eq!(metas.len(), 1);
    assert_eq!(metas[0].base, keep.base);
    assert!(!torn.path.exists(), "corrupt file deleted, WAL still covers it");
    fs::remove_dir_all(&dir).unwrap();
}

// -------------------------------------------------------------------------
// Compaction
// -------------------------------------------------------------------------

#[test]
fn compaction_merges_oldest_run_and_drops_trimmed_prefix() {
    let dir = test_dir("compact");
    fs::create_dir_all(&dir).unwrap();
    let mut files = Vec::new();
    for i in 0..4u64 {
        files
            .push(segment::write_segment(&dir, PartitionId(0), i * 2, &[sim(50), sim(50)]).unwrap());
    }
    let mut stats = StoreStats::default();
    let cfg = CompactionConfig { min_segments: 4, max_merge: 2 };
    compaction::compact_partition(&dir, &mut files, 0, &cfg, &mut stats).unwrap();
    assert_eq!(stats.compactions, 1);
    assert_eq!(stats.segments_compacted, 2);
    assert_eq!(files.len(), 3);
    assert_eq!((files[0].base, files[0].end), (0, 4), "oldest run merged");
    let merged = segment::load_chunks(&files[0]).unwrap();
    assert_eq!(merged.len(), 4);

    // Retention passed the merged file entirely: the prefix drop takes it.
    compaction::compact_partition(&dir, &mut files, 4, &cfg, &mut stats).unwrap();
    assert_eq!(files.len(), 2);
    assert_eq!(files[0].base, 4);
    fs::remove_dir_all(&dir).unwrap();
}

// -------------------------------------------------------------------------
// Registry
// -------------------------------------------------------------------------

#[test]
fn registry_builtin_serves_both_modes() {
    let r = StoreRegistry::builtin();
    assert_eq!(r.modes(), vec![StoreMode::Memory, StoreMode::Durable]);
    let store = r
        .expect(StoreMode::Memory)
        .open(&StoreParams::memory(1024), &[PartitionId(0)])
        .unwrap();
    assert_eq!(store.mode(), StoreMode::Memory);
    assert!(store.contains(PartitionId(0)));
}

struct TinyFactory;

impl StoreFactory for TinyFactory {
    fn mode(&self) -> StoreMode {
        StoreMode::Memory
    }

    fn open(
        &self,
        _params: &StoreParams,
        _partitions: &[PartitionId],
    ) -> std::io::Result<Box<dyn LogStore>> {
        Ok(Box::new(MemoryStore::new(1024, &[PartitionId(9)])))
    }
}

#[test]
fn registry_register_replaces_same_mode() {
    let mut r = StoreRegistry::builtin();
    r.register(Box::new(TinyFactory));
    assert_eq!(r.modes().len(), 2, "replacement, not addition");
    let store =
        r.expect(StoreMode::Memory).open(&StoreParams::memory(1024), &[]).unwrap();
    assert!(store.contains(PartitionId(9)), "replacement factory answered");
}

#[test]
#[should_panic(expected = "no store factory registered")]
fn registry_expect_panics_on_missing_mode() {
    StoreRegistry::empty().expect(StoreMode::Durable);
}

// -------------------------------------------------------------------------
// Durable store
// -------------------------------------------------------------------------

/// Identical op-for-op behavior across backends, under trims and budget
/// reads, with sizes that force frequent seals and compactions.
#[test]
fn durable_matches_memory_over_a_scripted_run() {
    let p = PartitionId(0);
    let mut mem = MemoryStore::new(256, &[p]);
    let params = StoreParams {
        mode: StoreMode::Durable,
        dir: None,
        segment_bytes: 256,
        wal_file_bytes: 4096,
        compact_min_segments: 3,
        cold_cache_segments: 2,
    };
    let mut dur = DurableStore::open(&params, &[p]).unwrap();

    let mut x = 0x2545_F491_4F6C_DD1Du64; // xorshift: deterministic sizes
    for step in 0..200u64 {
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        let size = 16 + (x % 5) as u32 * 24;
        let chunk = real((step & 0xFF) as u8, 1, size);
        assert_eq!(mem.append(p, chunk.clone()), dur.append(p, chunk));

        if step % 7 == 3 {
            let watermark = mem.head(p).saturating_sub(4);
            assert_eq!(
                mem.trim_below(p, watermark),
                dur.trim_below(p, watermark),
                "reclaimed bytes split at step {step}"
            );
        }

        let head = mem.head(p);
        let start = mem.start(p);
        for probe in [start, (start + head) / 2, head.saturating_sub(1), head + 5] {
            let a = mem.read_from(p, probe, 200);
            let b = dur.read_from(p, probe, 200);
            match (a, b) {
                (Ok(av), Ok(bv)) => {
                    assert_eq!(av.len(), bv.len(), "chunk count split at {step}/{probe}");
                    for (ac, bc) in av.iter().zip(&bv) {
                        assert_eq!(ac.offset, bc.offset);
                        assert_eq!(ac.chunk.bytes(), bc.chunk.bytes());
                    }
                }
                (Err(ae), Err(be)) => assert_eq!(ae, be),
                (a, b) => panic!("parity split at {step}/{probe}: {a:?} vs {b:?}"),
            }
        }
        assert_eq!(mem.peek_from(p, start, 512), dur.peek_from(p, start, 512));
        assert_eq!(mem.head(p), dur.head(p));
        assert_eq!(mem.start(p), dur.start(p));
        assert_eq!(mem.available_from(p, start), dur.available_from(p, start));
        assert_eq!(mem.total_appended_bytes(p), dur.total_appended_bytes(p));
        assert_eq!(mem.total_appended_records(p), dur.total_appended_records(p));
    }
    let stats = dur.stats();
    assert!(stats.segments_flushed > 0, "the run never reached the cold tier");
    assert!(stats.compactions > 0, "the run never compacted");
}

#[test]
fn durable_laggard_reads_span_cold_files_and_tail() {
    let p = PartitionId(0);
    let params = StoreParams {
        mode: StoreMode::Durable,
        dir: None,
        segment_bytes: 128,
        wal_file_bytes: 1 << 20,
        compact_min_segments: 3,
        cold_cache_segments: 2,
    };
    let mut store = DurableStore::open(&params, &[p]).unwrap();
    for i in 0..50u64 {
        store.append(p, real(i as u8, 1, 64));
    }
    let before = store.stats();
    assert!(before.segments_flushed > 0);

    // One unbounded read walks the whole cold range and into the tail.
    let all = store.read_from(p, 0, u64::MAX).unwrap();
    assert_eq!(all.len(), 50);
    for (i, sc) in all.iter().enumerate() {
        assert_eq!(sc.offset, i as u64);
        let data = sc.chunk.payload.buffer().expect("real payload");
        assert!(data.iter().all(|&b| b == i as u8), "payload bytes survived the disk hop");
    }
    let after = store.stats();
    assert!(after.cold_loads > before.cold_loads, "cold files were actually read");
    assert!(after.bloom_checks > 0);
    assert_eq!(after.bloom_negatives, 0);

    // A second laggard pass leans on the decoded-segment cache.
    store.read_from(p, 0, u64::MAX).unwrap();
    assert!(store.stats().cold_cache_hits > after.cold_cache_hits);
}

#[test]
fn durable_trim_reports_the_gap_like_memory() {
    let p = PartitionId(0);
    let params = StoreParams {
        mode: StoreMode::Durable,
        dir: None,
        segment_bytes: 128,
        wal_file_bytes: 1 << 20,
        compact_min_segments: 4,
        cold_cache_segments: 2,
    };
    let mut store = DurableStore::open(&params, &[p]).unwrap();
    for i in 0..10u64 {
        store.append(p, real(i as u8, 1, 64));
    }
    store.trim_below(p, 6);
    assert_eq!(store.start(p), 6);
    let err = store.read_from(p, 2, 1024).unwrap_err();
    assert_eq!(err, TrimmedError { requested: 2, start: 6 });
    assert_eq!(store.peek_from(p, 2, 1024), (0, 0));
}

#[test]
fn durable_reopen_recovers_tail_and_totals() {
    let dir = test_dir("durable-reopen");
    let p = PartitionId(0);
    let params = durable_params(&dir, 256);
    let (head, bytes, records, read_before) = {
        let mut store = DurableStore::open(&params, &[p]).unwrap();
        for i in 0..40u64 {
            store.append(p, real(i as u8, 1, 64));
        }
        (
            store.head(p),
            store.total_appended_bytes(p),
            store.total_appended_records(p),
            store.read_from(p, 0, u64::MAX).unwrap(),
        )
        // Dropping with an explicit dir persists everything — the crash
        // model is "process died after the last append's WAL write".
    };

    let mut store = DurableStore::open(&params, &[p]).unwrap();
    assert_eq!(store.head(p), head);
    assert_eq!(store.start(p), 0);
    assert_eq!(store.total_appended_bytes(p), bytes);
    assert_eq!(store.total_appended_records(p), records);
    let read_after = store.read_from(p, 0, u64::MAX).unwrap();
    assert_eq!(read_before.len(), read_after.len());
    for (a, b) in read_before.iter().zip(&read_after) {
        assert_eq!(a.offset, b.offset);
        let da = a.chunk.payload.buffer().expect("real");
        let db = b.chunk.payload.buffer().expect("real");
        assert_eq!(da, db, "byte-identical recovery at offset {}", a.offset);
    }

    // The recovered store keeps working: appends resume at the old head.
    assert_eq!(store.append(p, real(99, 1, 64)), head);
    drop(store);
    fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn durable_reopen_with_pruned_wal_keeps_exact_totals() {
    let dir = test_dir("durable-pruned");
    let p = PartitionId(0);
    let mut params = durable_params(&dir, 256);
    // Tiny ring: constant rotation + pruning, so recovery must combine
    // TOTALS snapshots with the surviving suffix of appends.
    params.wal_file_bytes = 2 * WalRing::frame_bytes(&real(0, 1, 64));
    let (head, bytes, records) = {
        let mut store = DurableStore::open(&params, &[p]).unwrap();
        for i in 0..64u64 {
            store.append(p, real(i as u8, 1, 64));
        }
        assert!(store.stats().wal.files_pruned > 0, "ring never pruned");
        (store.head(p), store.total_appended_bytes(p), store.total_appended_records(p))
    };
    let store = DurableStore::open(&params, &[p]).unwrap();
    assert_eq!(store.head(p), head);
    assert_eq!(store.total_appended_bytes(p), bytes);
    assert_eq!(store.total_appended_records(p), records);
    drop(store);
    fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn durable_open_resolves_interrupted_compaction() {
    let dir = test_dir("durable-dedup");
    let p = PartitionId(0);
    let params = durable_params(&dir, 128);
    {
        let mut store = DurableStore::open(&params, &[p]).unwrap();
        for i in 0..12u64 {
            store.append(p, real(i as u8, 1, 64));
        }
    }
    // Fake a crash mid-compaction: the merged file landed, the sources
    // were not yet deleted.
    let seg_dir = dir.join("segments");
    let (metas, _) = segment::scan_dir(&seg_dir).unwrap();
    assert!(metas.len() >= 2);
    let mut chunks = Vec::new();
    for m in &metas[..2] {
        chunks.extend(segment::load_chunks(m).unwrap());
    }
    segment::write_segment(&seg_dir, p, metas[0].base, &chunks).unwrap();

    let store = DurableStore::open(&params, &[p]).unwrap();
    assert!(store.stats().segments_compacted >= 2, "contained sources dropped at open");
    let all = store.read_from(p, 0, u64::MAX).unwrap();
    assert_eq!(all.len(), 12);
    for (i, sc) in all.iter().enumerate() {
        assert_eq!(sc.offset, i as u64);
        let data = sc.chunk.payload.buffer().expect("real");
        assert!(data.iter().all(|&b| b == i as u8));
    }
    drop(store);
    fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn ephemeral_store_removes_its_directory_on_drop() {
    let p = PartitionId(0);
    let params = StoreParams {
        mode: StoreMode::Durable,
        dir: None,
        segment_bytes: 256,
        wal_file_bytes: 1 << 20,
        compact_min_segments: 4,
        cold_cache_segments: 2,
    };
    let mut store = DurableStore::open(&params, &[p]).unwrap();
    store.append(p, sim(100));
    let root = store.root().to_path_buf();
    assert!(root.exists());
    drop(store);
    assert!(!root.exists(), "ephemeral root survived drop");
}

#[test]
fn durable_handles_sim_payloads() {
    // The figure sweeps run the sim data plane; the durable tier must
    // round-trip accounting-only chunks (no payload bytes on disk).
    let dir = test_dir("durable-sim");
    let p = PartitionId(0);
    let params = durable_params(&dir, 128);
    {
        let mut store = DurableStore::open(&params, &[p]).unwrap();
        for _ in 0..20u64 {
            store.append(p, sim(64));
        }
    }
    let store = DurableStore::open(&params, &[p]).unwrap();
    assert_eq!(store.head(p), 20);
    let all = store.read_from(p, 0, u64::MAX).unwrap();
    assert_eq!(all.len(), 20);
    assert!(all.iter().all(|sc| !sc.chunk.payload.is_real()));
    assert_eq!(store.total_appended_bytes(p), 20 * 64);
    drop(store);
    fs::remove_dir_all(&dir).unwrap();
}
