//! Background compaction for the cold tier: drop fully-trimmed files,
//! merge old runs so file counts stay bounded on long runs.
//!
//! Compaction operates purely on the *physical* files. The durable
//! store's logical trim units (the flush-unit boundaries that make trim
//! semantics identical to the memory backend) are untouched — merging
//! four files into one never changes when `start` advances, only how
//! many files a cold read might touch.
//!
//! Crash safety: a merge writes the replacement file **before** deleting
//! its sources, so a crash can leave both on disk. The open-time scan
//! resolves this by dropping any file whose range is contained in
//! another's — the merged file subsumes its sources exactly.
//!
//! Like a real broker's compaction thread, this work happens off the hot
//! path: it charges no simulated time (the DES models request service,
//! not background maintenance).

use std::fs;
use std::io;
use std::path::Path;

use crate::proto::ChunkOffset;

use super::segment::{self, SegmentMeta};
use super::StoreStats;

/// Compaction policy knobs (per partition).
#[derive(Debug, Clone)]
pub struct CompactionConfig {
    /// Cold files that trigger a merge pass.
    pub min_segments: usize,
    /// Most files merged in one pass (bounds a pass's reload volume).
    pub max_merge: usize,
}

impl Default for CompactionConfig {
    fn default() -> Self {
        CompactionConfig { min_segments: 4, max_merge: 8 }
    }
}

impl CompactionConfig {
    pub fn with_min_segments(min_segments: usize) -> Self {
        CompactionConfig { min_segments: min_segments.max(2), ..Default::default() }
    }
}

/// One maintenance pass over a partition's cold files (sorted by base):
/// delete files wholly below the logical `start`, then — if at least
/// `min_segments` remain — merge the oldest run into a single file.
pub(crate) fn compact_partition(
    dir: &Path,
    files: &mut Vec<SegmentMeta>,
    start: ChunkOffset,
    cfg: &CompactionConfig,
    stats: &mut StoreStats,
) -> io::Result<()> {
    // Trimmed-prefix drop: retention already passed these files entirely.
    while files.first().is_some_and(|f| f.end <= start) {
        let gone = files.remove(0);
        fs::remove_file(&gone.path)?;
    }

    if files.len() < cfg.min_segments.max(2) {
        return Ok(());
    }

    // Merge the oldest contiguous run. Runs are contiguous by
    // construction (dense offsets, in-order flushes); stop early if a
    // rescan ever surfaced a gap rather than merging across it.
    let mut k = 1;
    while k < files.len().min(cfg.max_merge) && files[k - 1].end == files[k].base {
        k += 1;
    }
    if k < 2 {
        return Ok(());
    }

    let partition = files[0].partition;
    let base = files[0].base;
    let mut chunks = Vec::new();
    for meta in &files[..k] {
        chunks.extend(segment::load_chunks(meta)?);
    }
    let merged = segment::write_segment(dir, partition, base, &chunks)?;
    for meta in files.drain(..k) {
        // The merged image is durable; sources go last (crash here leaves
        // subsumed files the open-time scan cleans up).
        fs::remove_file(&meta.path)?;
    }
    files.insert(0, merged);

    stats.compactions += 1;
    stats.segments_compacted += k as u64;
    Ok(())
}
