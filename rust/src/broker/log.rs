//! Partition log: segments of record-framed chunks (KerA-style storage).
//!
//! A partition is an append-only sequence of chunks grouped into fixed-size
//! *segments* (the paper fixes the segment size to 8 MiB, §V-A). Offsets
//! are chunk indices. Reads return consecutive chunks from an offset up to
//! a byte budget — the pull path's per-partition `CS` and the push path's
//! object capacity both map to that budget. Retention trims whole segments
//! strictly below the consumers' progress watermark, bounding memory in
//! real-data-plane runs.

use std::collections::VecDeque;

use crate::proto::{Chunk, ChunkOffset, PartitionId, StampedChunk};

/// Default segment capacity — the paper's fixed 8 MiB.
pub const DEFAULT_SEGMENT_BYTES: u64 = 8 * 1024 * 1024;

#[derive(Debug)]
struct Segment {
    /// Offset of the first chunk in this segment.
    base: ChunkOffset,
    chunks: Vec<Chunk>,
    bytes: u64,
    capacity: u64,
}

impl Segment {
    fn new(base: ChunkOffset, capacity: u64) -> Self {
        Segment { base, chunks: Vec::new(), bytes: 0, capacity }
    }

    fn end(&self) -> ChunkOffset {
        self.base + self.chunks.len() as u64
    }

    fn has_room(&self, bytes: u64) -> bool {
        self.chunks.is_empty() || self.bytes + bytes <= self.capacity
    }
}

/// One partition's log.
#[derive(Debug)]
pub struct PartitionLog {
    pub id: PartitionId,
    segments: VecDeque<Segment>,
    segment_bytes: u64,
    /// First retained offset (everything below was trimmed).
    start: ChunkOffset,
    /// Next offset to be assigned.
    head: ChunkOffset,
    total_appended_bytes: u64,
    total_appended_records: u64,
    sealed_segments: u64,
}

impl PartitionLog {
    pub fn new(id: PartitionId, segment_bytes: u64) -> Self {
        Self::with_base(id, segment_bytes, 0)
    }

    /// A log whose first chunk will take offset `base` — how the durable
    /// store rebuilds its hot tail above an existing cold tier on reopen.
    pub(crate) fn with_base(id: PartitionId, segment_bytes: u64, base: ChunkOffset) -> Self {
        assert!(segment_bytes > 0);
        Self {
            id,
            segments: VecDeque::new(),
            segment_bytes,
            start: base,
            head: base,
            total_appended_bytes: 0,
            total_appended_records: 0,
            sealed_segments: 0,
        }
    }

    /// Append one sealed chunk; returns its offset.
    pub fn append(&mut self, chunk: Chunk) -> ChunkOffset {
        let bytes = chunk.bytes();
        let records = chunk.records as u64;
        let needs_new = match self.segments.back() {
            Some(seg) => !seg.has_room(bytes),
            None => true,
        };
        if needs_new {
            if self.segments.back().is_some() {
                self.sealed_segments += 1;
            }
            self.segments.push_back(Segment::new(self.head, self.segment_bytes));
        }
        let seg = self.segments.back_mut().expect("just ensured");
        seg.chunks.push(chunk);
        seg.bytes += bytes;
        let offset = self.head;
        self.head += 1;
        self.total_appended_bytes += bytes;
        self.total_appended_records += records;
        offset
    }

    /// Next offset to be written (== number of chunks ever appended).
    pub fn head(&self) -> ChunkOffset {
        self.head
    }

    /// Oldest retained offset.
    pub fn start(&self) -> ChunkOffset {
        self.start
    }

    /// Chunks available at or past `offset`.
    pub fn available_from(&self, offset: ChunkOffset) -> u64 {
        self.head.saturating_sub(offset.max(self.start))
    }

    /// Index of the segment containing `offset` (one binary search; the
    /// walk helpers then advance linearly — segments are contiguous).
    fn segment_of(&self, offset: ChunkOffset) -> usize {
        self.segments
            .partition_point(|seg| seg.end() <= offset)
            .min(self.segments.len().saturating_sub(1))
    }

    /// Walk consecutive resident chunks from `offset` under the byte
    /// budget, calling `f(offset, chunk)` for each. One binary search, then
    /// a single linear pass across segments — never a per-chunk search.
    /// Always yields at least one chunk if any is available (the paper's
    /// consumers always make progress). `offset` must be `>= self.start`.
    pub(crate) fn walk_from(
        &self,
        offset: ChunkOffset,
        max_bytes: u64,
        mut f: impl FnMut(ChunkOffset, &Chunk),
    ) -> (u64, u64) {
        debug_assert!(offset >= self.start);
        if offset >= self.head {
            return (0, 0);
        }
        let mut seg_idx = self.segment_of(offset);
        let mut at = offset;
        let mut taken = 0u64;
        let mut bytes = 0u64;
        let mut budget = max_bytes;
        while at < self.head {
            let seg = &self.segments[seg_idx];
            if at >= seg.end() {
                seg_idx += 1;
                continue;
            }
            let chunk = &seg.chunks[(at - seg.base) as usize];
            let b = chunk.bytes();
            if taken > 0 && b > budget {
                break;
            }
            f(at, chunk);
            taken += 1;
            bytes += b;
            budget = budget.saturating_sub(b);
            at += 1;
            if budget == 0 {
                break;
            }
        }
        (taken, bytes)
    }

    /// Read consecutive chunks from `offset`, stopping when the cumulative
    /// payload would exceed `max_bytes` (always returns at least one chunk
    /// if any is available).
    ///
    /// Returns an error if `offset` was already trimmed (a slow consumer
    /// fell behind retention — surfaced, not papered over).
    pub fn read_from(
        &self,
        offset: ChunkOffset,
        max_bytes: u64,
    ) -> Result<Vec<StampedChunk>, TrimmedError> {
        let mut out = Vec::new();
        self.read_into(offset, max_bytes, &mut out)?;
        Ok(out)
    }

    /// [`PartitionLog::read_from`] appending into a caller-owned vector —
    /// the pull path's reply buffer. Two linear passes, each one segment
    /// walk (never a per-chunk search): a clone-free peek that sizes the
    /// reservation exactly (one `reserve` per partition read), then the
    /// fill walk. Chunks are shared into the output (`Rc` payload bump),
    /// the segment-resident bytes are never touched.
    pub fn read_into(
        &self,
        offset: ChunkOffset,
        max_bytes: u64,
        out: &mut Vec<StampedChunk>,
    ) -> Result<u64, TrimmedError> {
        if offset < self.start {
            return Err(TrimmedError { requested: offset, start: self.start });
        }
        let (chunks, _) = self.peek_from(offset, max_bytes);
        out.reserve(chunks as usize);
        let id = self.id;
        let (taken, _) = self.walk_from(offset, max_bytes, |at, chunk| {
            out.push(StampedChunk { partition: id, offset: at, chunk: chunk.clone() });
        });
        debug_assert_eq!(taken, chunks);
        Ok(taken)
    }

    /// Cost-model peek: `(chunks, bytes)` a `read_from(offset, max_bytes)`
    /// would return, without cloning anything. Keeps the broker's
    /// service-time estimation off the allocator (hot on the pull path).
    pub fn peek_from(&self, offset: ChunkOffset, max_bytes: u64) -> (u64, u64) {
        if offset < self.start {
            return (0, 0);
        }
        self.walk_from(offset, max_bytes, |_, _| {})
    }

    /// Drop whole segments strictly below `watermark` (all consumers have
    /// passed them). Returns bytes reclaimed.
    pub fn trim_below(&mut self, watermark: ChunkOffset) -> u64 {
        let mut reclaimed = 0;
        while let Some(front) = self.segments.front() {
            // Only fully-consumed, fully-sealed (non-tail) segments go.
            if front.end() <= watermark && self.segments.len() > 1 {
                let seg = self.segments.pop_front().expect("peeked");
                reclaimed += seg.bytes;
                self.start = seg.end();
            } else {
                break;
            }
        }
        reclaimed
    }

    /// The front segment when it is sealed (a younger segment exists
    /// behind it): `(base, payload bytes, chunks)`. This is the durable
    /// store's flush unit — it writes the run to a cold file, then trims
    /// the tail below the unit's end.
    pub(crate) fn front_sealed(&self) -> Option<(ChunkOffset, u64, &[Chunk])> {
        if self.segments.len() > 1 {
            let seg = self.segments.front().expect("len checked");
            Some((seg.base, seg.bytes, &seg.chunks))
        } else {
            None
        }
    }

    /// Bytes currently resident.
    pub fn resident_bytes(&self) -> u64 {
        self.segments.iter().map(|s| s.bytes).sum()
    }

    /// Segments currently resident.
    pub fn resident_segments(&self) -> usize {
        self.segments.len()
    }

    pub fn total_appended_bytes(&self) -> u64 {
        self.total_appended_bytes
    }

    pub fn total_appended_records(&self) -> u64 {
        self.total_appended_records
    }
}

/// Read below retention: the consumer lost data to trimming.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TrimmedError {
    pub requested: ChunkOffset,
    pub start: ChunkOffset,
}

impl std::fmt::Display for TrimmedError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "offset {} below retained start {} (trimmed)",
            self.requested, self.start
        )
    }
}

impl std::error::Error for TrimmedError {}
