//! The KerA-like streaming storage broker.
//!
//! §IV-A: "a broker is configured with one dispatcher thread (one CPU core)
//! polling the network and responsible for serving RPC requests and
//! multiple working threads that do the actual writes and reads to data
//! stream partitions." Exactly that, as a DES actor:
//!
//! * every incoming RPC first occupies the **dispatcher** ([`CorePool`] of
//!   one) for `dispatch_ns` — the single-core frontend the paper (via
//!   RAMCloud/Arachne) identifies as the low-latency bottleneck;
//! * the handler then occupies a **worker core** (pool of `NBc`, or
//!   `NBc - push_threads` when a push thread is dedicated) for the
//!   byte-proportional append/read service time — here producer and pull
//!   RPCs *compete*, which is the paper's central interference effect;
//! * with `Replication = 2` an append is acked only after a nested
//!   replicate RPC to the backup broker round-trips (§V-C Fig. 3);
//! * the **push path** (§IV-B) runs on dedicated push threads: one
//!   subscription RPC registers sources, then each free push thread picks
//!   a runnable subscription round-robin, fills a free shared object with
//!   the next chunks of one partition, seals it and notifies the source.
//!   Backpressure is object exhaustion (plasma), not RPC pacing;
//! * the **shared-memory write path** (`WriteMode::SharedMem`) mirrors
//!   that for ingestion: a `WriteSubscribe` RPC registers a colocated
//!   producer's object pool, and each `SealObject` notification makes a
//!   worker core append the object's chunks to the logs — the payload
//!   reaches the broker through plasma, never the wire.

mod log;
pub mod store;
#[cfg(test)]
mod tests;

pub use log::{PartitionLog, TrimmedError, DEFAULT_SEGMENT_BYTES};
pub use store::{
    DurableStore, LogStore, LogView, MemoryStore, StoreFactory, StoreParams, StoreRegistry,
    StoreStats, WalStats,
};

use std::collections::{BTreeMap, HashMap};

use crate::config::{CostModel, FaultKind, StoreMode};
use crate::metrics::{Class, SharedMetrics};
use crate::net::{NodeId, SharedNetwork};
use crate::plasma::SharedStore;
use crate::shard::{BrokerShard, ShardTable};
use crate::proto::{
    Chunk, ChunkOffset, Msg, ObjectId, PartitionId, RpcEnvelope, RpcId, RpcKind, RpcReply,
    RpcRequest, StampedChunk, SubId,
};
use crate::sim::{Actor, ActorId, CorePool, Ctx, Job, Time};

/// Job-tag phases (tag = ctx_id * 8 + phase).
const PH_DISPATCH: u64 = 0;
const PH_WORK: u64 = 1;
const PH_PUSH: u64 = 2;

/// Idempotence-table entries retained per writer. Writers have at most a
/// handful of appends in flight, so 64 covers every live rpc id with a wide
/// margin while keeping the table O(writers), not O(run length).
const APPLIED_PER_CLIENT: usize = 64;

/// Static broker wiring.
#[derive(Debug, Clone)]
pub struct BrokerParams {
    /// Node this broker lives on.
    pub node: NodeId,
    /// `NBc` minus any dedicated push threads.
    pub worker_cores: usize,
    /// Dedicated push threads (0 in pull-only deployments; the paper uses 1).
    pub push_threads: usize,
    /// Log storage backend and its knobs (segment size, durable tier).
    pub store: StoreParams,
    /// Partitions this broker hosts.
    pub partitions: Vec<PartitionId>,
    /// Backup broker's actor id (replication target), if replication = 2.
    pub backup: Option<(ActorId, NodeId)>,
    /// True for the backup broker itself (serves only Replicate RPCs).
    pub is_backup: bool,
    pub cost: CostModel,
}

/// In-flight RPC context.
#[derive(Debug)]
struct RpcCtx {
    req: RpcRequest,
    /// Result staged by the work phase, sent after the handler completes.
    staged: Option<RpcReply>,
    /// Bytes the reply carries on the wire (pull data).
    reply_bytes: u64,
}

/// In-flight push fill: content gathered at job start, sealed at job end.
#[derive(Debug)]
struct FillCtx {
    object: ObjectId,
    content: Vec<StampedChunk>,
}

/// An ingest held for shard-quorum acks (generalises the backup pair's
/// single held ack to `replication_factor - 1` peers, majority commit).
#[derive(Debug)]
struct QuorumCtx {
    /// Peer acks still needed before the producer ack goes out.
    need: usize,
    /// Shared object a held seal releases once the quorum commits.
    held_object: Option<ObjectId>,
}

/// The broker actor.
pub struct Broker {
    params: BrokerParams,
    dispatcher: CorePool,
    workers: CorePool,
    push_pool: CorePool,
    /// Partition logs behind the pluggable storage backend.
    logs: Box<dyn LogStore>,
    /// Consumer progress per partition (for retention trimming).
    watermarks: HashMap<PartitionId, ChunkOffset>,
    /// Last committed checkpoint cursors (`CommitCheckpoint`): once any
    /// commit landed, retention may never trim past these — the log below
    /// the floor is the recovery replay data.
    committed: HashMap<PartitionId, ChunkOffset>,
    ctxs: HashMap<u64, RpcCtx>,
    fills: HashMap<u64, FillCtx>,
    next_ctx: u64,
    /// Appends waiting for a backup ack: replicate-rpc-id -> (append ctx
    /// id, shared object to release once durable — `Some` for held seals).
    awaiting_backup: HashMap<RpcId, (u64, Option<ObjectId>)>,
    next_client_rpc: RpcId,
    /// Sharded-topology state, installed by the launcher post-build when
    /// `broker_count > 1`. `None` = classic single-broker topology,
    /// bit-identical to the pre-shard behaviour. See [`crate::shard`] for
    /// the assignment-epoch contract this broker enforces.
    shard: Option<BrokerShard>,
    /// Sharded ingests held for quorum: append ctx id -> quorum state.
    quorum: HashMap<u64, QuorumCtx>,
    /// Outstanding `ShardReplicate` rpcs -> (append ctx id, peer broker
    /// index). Empty means every accepted write is fully replicated — the
    /// freeze drain gate. The peer index lets a fail-over purge exactly
    /// the acks a dead peer will never send.
    replicate_rids: HashMap<RpcId, (u64, usize)>,
    /// Exactly-once dedup across fail-over: writer-origin (actor, rpc id)
    /// -> the (records, bytes) totals its append landed with. Recorded at
    /// the primary when the append lands AND at every replica when the
    /// `ShardReplicate` applies (the origin rides on the fan-out), so a
    /// promoted replica re-acks a retransmitted append instead of
    /// appending it twice. Pruned to [`APPLIED_PER_CLIENT`] per writer.
    applied: HashMap<ActorId, BTreeMap<RpcId, (u64, u64)>>,
    /// A `ShardFreeze` whose ack waits for `replicate_rids` to drain.
    pending_freeze: Option<(RpcCtx, u64)>,
    /// Replica-side reorder buffers: replicated chunks that arrived ahead
    /// of the log head, keyed by their primary-assigned offset. Applying
    /// in offset order keeps every replica log byte-identical to the
    /// primary's regardless of worker-completion order.
    reorder: HashMap<PartitionId, BTreeMap<ChunkOffset, Chunk>>,
    /// Subscriptions in round-robin order for push scheduling.
    push_ring: Vec<SubId>,
    push_rr: usize,
    net: SharedNetwork,
    store: SharedStore,
    metrics: SharedMetrics,
    /// Entity id for metrics gauges (broker index).
    entity: usize,
    trimmed_bytes: u64,
    /// Retention scans are throttled: consumer progress advances every
    /// read, but segments (8 MiB) only complete every many chunks, so
    /// scanning on each read is pure overhead (perf pass, EXPERIMENTS.md
    /// §Perf).
    trim_tick: u32,
    /// Killed by the fault injector (`fault_kind=broker`): a dead broker
    /// silently drops every subsequent event — requests, replicate acks,
    /// heartbeats, its own job completions. Nothing escapes a corpse.
    dead: bool,
}

impl Broker {
    pub fn new(
        params: BrokerParams,
        net: SharedNetwork,
        store: SharedStore,
        metrics: SharedMetrics,
        entity: usize,
    ) -> Self {
        let logs = StoreRegistry::builtin()
            .expect(params.store.mode)
            .open(&params.store, &params.partitions)
            .unwrap_or_else(|e| {
                panic!("opening `{}` store failed: {e}", params.store.mode.name())
            });
        Self::with_store(params, logs, net, store, metrics, entity)
    }

    /// A broker over a pre-opened storage backend — what `launch_full`
    /// uses with a caller-supplied [`StoreRegistry`], and what tests use
    /// to hand in a rigged store.
    pub fn with_store(
        params: BrokerParams,
        logs: Box<dyn LogStore>,
        net: SharedNetwork,
        store: SharedStore,
        metrics: SharedMetrics,
        entity: usize,
    ) -> Self {
        assert!(params.worker_cores > 0, "broker needs at least one worker core");
        Self {
            dispatcher: CorePool::new(1),
            workers: CorePool::new(params.worker_cores),
            push_pool: CorePool::new(params.push_threads.max(1)),
            // a pool must have >= 1 core; gate use on params.push_threads
            logs,
            watermarks: HashMap::new(),
            committed: HashMap::new(),
            ctxs: HashMap::new(),
            fills: HashMap::new(),
            next_ctx: 0,
            awaiting_backup: HashMap::new(),
            next_client_rpc: 0,
            shard: None,
            quorum: HashMap::new(),
            replicate_rids: HashMap::new(),
            applied: HashMap::new(),
            pending_freeze: None,
            reorder: HashMap::new(),
            push_ring: Vec::new(),
            push_rr: 0,
            net,
            store,
            metrics,
            entity,
            trimmed_bytes: 0,
            trim_tick: 0,
            dead: false,
            params,
        }
    }

    // ---------------------------------------------------------------------
    // Frontend: dispatcher -> worker phases
    // ---------------------------------------------------------------------

    fn on_rpc(&mut self, req: RpcRequest, ctx: &mut Ctx<'_, Msg>) {
        let id = self.next_ctx;
        self.next_ctx += 1;
        self.ctxs.insert(id, RpcCtx { req, staged: None, reply_bytes: 0 });
        let job = Job { cost: self.params.cost.dispatch_ns, tag: id * 8 + PH_DISPATCH };
        if let Some(started) = self.dispatcher.submit(ctx.now(), job) {
            ctx.send_self_in(started.cost, Msg::JobDone(started.tag));
        }
    }

    fn work_cost(&self, kind: &RpcKind) -> Time {
        let c = &self.params.cost;
        match kind {
            RpcKind::Append { chunks, .. } => {
                let bytes: u64 = chunks.iter().map(|(_, ch)| ch.bytes()).sum();
                c.rpc_base_ns + chunks.len() as Time * c.append_chunk_ns
                    + (bytes as f64 / c.append_bw_bps * 1e9) as Time
            }
            RpcKind::Pull { assignments, max_bytes } => {
                // Service time is proportional to what the read will return;
                // peek the logs without cloning (state reads are free, the
                // time is charged here; the clone happens once, in do_pull).
                let mut bytes = 0u64;
                let mut chunks = 0u64;
                for &(p, off) in assignments {
                    if self.logs.contains(p) {
                        let (ch, by) = self.logs.peek_from(p, off, *max_bytes);
                        chunks += ch;
                        bytes += by;
                    }
                }
                c.rpc_base_ns + c.read_cost(bytes, chunks)
            }
            RpcKind::PushSubscribe { sources } => {
                c.rpc_base_ns + sources.len() as Time * c.rpc_base_ns
            }
            RpcKind::PushUnsubscribe { .. } => c.rpc_base_ns,
            RpcKind::CommitCheckpoint { .. } => c.rpc_base_ns,
            RpcKind::SealObject { id, .. } => {
                // Appending a sealed object is charged like the equivalent
                // Append RPC: the payload still has to reach the log — what
                // the shared-memory path saves is the wire transfer and the
                // per-request producer round-trip, not the append work. A
                // bad/stale object id costs the base handler time; the
                // handler will reject it with an Error reply.
                match self.store.borrow().sealed_info(*id) {
                    Some((_, bytes, chunks)) => {
                        c.rpc_base_ns + chunks as Time * c.append_chunk_ns
                            + (bytes as f64 / c.append_bw_bps * 1e9) as Time
                    }
                    None => c.rpc_base_ns,
                }
            }
            RpcKind::WriteSubscribe { .. } => 2 * c.rpc_base_ns,
            RpcKind::Replicate { bytes, chunks } => {
                c.rpc_base_ns + *chunks as Time * c.append_chunk_ns
                    + (*bytes as f64 / c.append_bw_bps * 1e9) as Time
            }
            // A shard replica pays the same append work the primary did —
            // the quorum write really lands on every peer's log.
            RpcKind::ShardReplicate { chunks, .. } => {
                let bytes: u64 = chunks.iter().map(|s| s.chunk.bytes()).sum();
                c.rpc_base_ns + chunks.len() as Time * c.append_chunk_ns
                    + (bytes as f64 / c.append_bw_bps * 1e9) as Time
            }
            RpcKind::ShardFreeze { .. }
            | RpcKind::ShardPromote { .. }
            | RpcKind::ShardFailover { .. }
            | RpcKind::Heartbeat => c.rpc_base_ns,
        }
    }

    fn on_dispatched(&mut self, id: u64, ctx: &mut Ctx<'_, Msg>) {
        let cost = {
            let rpc_ctx = self.ctxs.get(&id).expect("ctx alive through dispatch");
            self.work_cost(&rpc_ctx.req.kind)
        };
        let job = Job { cost, tag: id * 8 + PH_WORK };
        if let Some(started) = self.workers.submit(ctx.now(), job) {
            ctx.send_self_in(started.cost, Msg::JobDone(started.tag));
        }
    }

    /// Worker phase complete: hand off to the per-kind handler. One method
    /// per RPC kind keeps the frontend dispatch flat as kinds accumulate
    /// (the write path added two).
    fn on_worked(&mut self, id: u64, ctx: &mut Ctx<'_, Msg>) {
        let mut rpc_ctx = self.ctxs.remove(&id).expect("ctx alive through work");
        // Take the kind by value — an Append's chunk vector must not be
        // cloned per dispatch. The cheap placeholder left behind is never
        // read again (held contexts track their object id separately).
        let kind = std::mem::replace(
            &mut rpc_ctx.req.kind,
            RpcKind::Replicate { bytes: 0, chunks: 0 },
        );
        match kind {
            RpcKind::Append { chunks, produced_at } => {
                self.finish_append(id, rpc_ctx, chunks, produced_at, ctx)
            }
            RpcKind::Pull { assignments, max_bytes } => {
                self.finish_pull(rpc_ctx, &assignments, max_bytes, ctx)
            }
            RpcKind::PushSubscribe { sources } => {
                self.finish_push_subscribe(rpc_ctx, &sources, ctx)
            }
            RpcKind::PushUnsubscribe { sub } => self.finish_push_unsubscribe(rpc_ctx, sub, ctx),
            RpcKind::CommitCheckpoint { epoch, cursors } => {
                self.finish_commit(rpc_ctx, epoch, &cursors, ctx)
            }
            RpcKind::WriteSubscribe { producer } => {
                self.finish_write_subscribe(rpc_ctx, &producer, ctx)
            }
            RpcKind::SealObject { id: object, produced_at } => {
                self.finish_seal(id, rpc_ctx, object, produced_at, ctx)
            }
            RpcKind::Replicate { .. } => self.finish_replicate(rpc_ctx, ctx),
            RpcKind::ShardReplicate { chunks, origin } => {
                self.finish_shard_replicate(rpc_ctx, chunks, origin, ctx)
            }
            RpcKind::ShardFreeze { epoch, partitions } => {
                self.finish_shard_freeze(rpc_ctx, epoch, &partitions, ctx)
            }
            RpcKind::ShardPromote { epoch, partitions } => {
                self.finish_shard_promote(rpc_ctx, epoch, &partitions, ctx)
            }
            RpcKind::ShardFailover { epoch, dead, table, gained } => {
                self.finish_shard_failover(rpc_ctx, epoch, dead, table, &gained, ctx)
            }
            RpcKind::Heartbeat => self.finish_heartbeat(rpc_ctx, ctx),
        }
    }

    // ---------------------------------------------------------------------
    // Sharded topology: routing authority, quorum replication, hand-off
    // ---------------------------------------------------------------------

    /// Install the sharded-topology state (launcher, post-build).
    pub fn set_shard(&mut self, shard: BrokerShard) {
        self.shard = Some(shard);
    }

    pub fn shard(&self) -> Option<&BrokerShard> {
        self.shard.as_ref()
    }

    /// Is this broker the routing authority (current primary) for `p`?
    /// Without shard state every hosted partition qualifies.
    fn serves(&self, p: PartitionId) -> bool {
        match &self.shard {
            Some(s) => s.is_primary(p),
            None => true,
        }
    }

    /// The refusal a stale-routed request gets instead of service. The
    /// epoch lets the client tell "broker ahead of my table" from a
    /// repeat of what it already knows.
    fn wrong_shard(&self) -> RpcReply {
        RpcReply::WrongShard { epoch: self.shard.as_ref().map_or(0, |s| s.epoch) }
    }

    /// Whole-batch routing check, before anything is appended: a refused
    /// batch must land nowhere (the retry at the new primary is the only
    /// copy — zero duplication).
    fn shard_refusal(&self, mut parts: impl Iterator<Item = PartitionId>) -> Option<RpcReply> {
        if self.shard.is_none() {
            return None;
        }
        parts.any(|p| !self.serves(p)).then(|| self.wrong_shard())
    }

    /// Does the ingest tail fan out to quorum peers?
    fn shard_replicates(&self) -> bool {
        self.shard.as_ref().is_some_and(|s| s.table.replication() >= 2)
    }

    /// Validate-then-append like `append_chunks`, additionally returning
    /// the appended chunks stamped with their assigned offsets — the
    /// replication fan-out payload (`Rc` clones of resident payloads, no
    /// byte copies).
    fn append_chunks_stamped(
        &mut self,
        chunks: Vec<(PartitionId, Chunk)>,
        produced_at: Option<Time>,
        now: Time,
    ) -> Result<(u64, u64, Vec<StampedChunk>), PartitionId> {
        if let Some(bad) = chunks.iter().find(|(p, _)| !self.logs.contains(*p)) {
            return Err(bad.0);
        }
        let mut records = 0u64;
        let mut bytes = 0u64;
        let mut stamped = Vec::with_capacity(chunks.len());
        for (p, chunk) in chunks {
            records += chunk.records as u64;
            bytes += chunk.bytes();
            let offset = self.logs.append(p, chunk.clone());
            if let Some(produced) = produced_at {
                self.metrics.borrow_mut().tracer.on_append(p.0, offset, produced, now);
            }
            stamped.push(StampedChunk { partition: p, offset, chunk });
        }
        Ok((records, bytes, stamped))
    }

    /// The sharded ingest tail shared by Append and SealObject: append at
    /// primary-assigned offsets, fan the stamped chunks out to every
    /// standing replica, and hold the producer ack until a majority of
    /// the replica set (this append included) holds the data.
    #[allow(clippy::too_many_arguments)]
    fn finish_ingest_sharded(
        &mut self,
        id: u64,
        mut rpc_ctx: RpcCtx,
        chunks: Vec<(PartitionId, Chunk)>,
        produced_at: Option<Time>,
        held_object: Option<ObjectId>,
        is_seal: bool,
        ctx: &mut Ctx<'_, Msg>,
    ) {
        match self.append_chunks_stamped(chunks, produced_at, ctx.now()) {
            Err(p) => {
                rpc_ctx.staged =
                    Some(RpcReply::Error { reason: format!("unknown partition {p}") });
                self.reply(rpc_ctx, ctx);
            }
            Ok((records, bytes, stamped)) => {
                self.metrics
                    .borrow_mut()
                    .record(Class::ProducerBytes, self.entity, ctx.now(), bytes);
                // Record the landed totals under the writer's (actor, rpc)
                // origin: a retransmit of this exact request — at this
                // broker or at the replica a fail-over promotes — re-acks
                // instead of appending twice.
                let origin = (rpc_ctx.req.reply_to, rpc_ctx.req.id);
                self.record_applied(origin.0, origin.1, records, bytes);
                rpc_ctx.staged = Some(if is_seal {
                    RpcReply::SealAck { records, bytes }
                } else {
                    RpcReply::AppendAck { records, bytes }
                });
                // Group the fan-out by replica peer. Batches stay within
                // one primary's range, so in practice every chunk shares
                // one peer set; the grouping keeps mixed batches correct.
                // After a fail-over rows are ragged, so the quorum need is
                // the strictest (largest) of the batch's partitions.
                let shard = self.shard.as_ref().expect("sharded ingest tail");
                let need = stamped
                    .iter()
                    .map(|sc| shard.needed_peer_acks(sc.partition))
                    .max()
                    .unwrap_or(0);
                let mut by_peer: Vec<((usize, (ActorId, NodeId)), Vec<StampedChunk>)> =
                    Vec::new();
                for sc in stamped {
                    for peer in shard.replica_peers(sc.partition) {
                        match by_peer.iter_mut().find(|(to, _)| *to == peer) {
                            Some((_, list)) => list.push(sc.clone()),
                            None => by_peer.push((peer, vec![sc.clone()])),
                        }
                    }
                }
                if need == 0 {
                    // One-survivor replica set: the primary alone is the
                    // whole quorum — ack right away (still replicated as
                    // well as the shrunk set allows).
                    debug_assert!(by_peer.is_empty(), "no quorum need but standing peers");
                    if let Some(object) = held_object {
                        self.store.borrow_mut().release(object);
                    }
                    self.reply(rpc_ctx, ctx);
                    self.schedule_push(ctx);
                    return;
                }
                self.quorum.insert(id, QuorumCtx { need, held_object });
                self.ctxs.insert(id, rpc_ctx);
                for ((peer_idx, (peer, peer_node)), list) in by_peer {
                    let peer_bytes: u64 = list.iter().map(|s| s.chunk.bytes()).sum();
                    let rid = self.next_client_rpc;
                    self.next_client_rpc += 1;
                    self.replicate_rids.insert(rid, (id, peer_idx));
                    let deliver = self.net.borrow_mut().send(
                        ctx.now(),
                        self.params.node,
                        peer_node,
                        peer_bytes,
                    );
                    ctx.send_at(
                        deliver,
                        peer,
                        Msg::rpc(RpcRequest {
                            id: rid,
                            reply_to: ctx.self_id(),
                            from_node: self.params.node,
                            kind: RpcKind::ShardReplicate {
                                chunks: list,
                                origin: Some(origin),
                            },
                        }),
                    );
                }
                self.schedule_push(ctx);
            }
        }
    }

    /// Look up a writer-origin (actor, rpc) in the idempotence table.
    fn applied_lookup(&self, actor: ActorId, rid: RpcId) -> Option<(u64, u64)> {
        self.applied.get(&actor).and_then(|per| per.get(&rid)).copied()
    }

    /// Record an applied append's totals under its writer origin, pruning
    /// the oldest entries past the per-client cap (rpc ids are issued in
    /// order, so `pop_first` evicts the longest-settled requests — far
    /// behind anything a writer could still retransmit).
    fn record_applied(&mut self, actor: ActorId, rid: RpcId, records: u64, bytes: u64) {
        let per = self.applied.entry(actor).or_default();
        per.insert(rid, (records, bytes));
        while per.len() > APPLIED_PER_CLIENT {
            per.pop_first();
        }
    }

    /// Replica side of the quorum: apply primary-stamped chunks in offset
    /// order (the reorder buffer absorbs out-of-order arrivals), then ack.
    /// The writer origin riding along is recorded in the idempotence table
    /// — sound to do here, before quorum commit, because the primary's
    /// fan-out is atomic with its own append and the fabric never drops:
    /// whatever this replica applies, the primary acked or would ack with
    /// exactly these totals.
    fn finish_shard_replicate(
        &mut self,
        mut rpc_ctx: RpcCtx,
        chunks: Vec<StampedChunk>,
        origin: Option<(ActorId, RpcId)>,
        ctx: &mut Ctx<'_, Msg>,
    ) {
        if let Some((actor, rid)) = origin {
            let records: u64 = chunks.iter().map(|s| s.chunk.records as u64).sum();
            let bytes: u64 = chunks.iter().map(|s| s.chunk.bytes()).sum();
            self.record_applied(actor, rid, records, bytes);
        }
        for sc in chunks {
            debug_assert!(self.logs.contains(sc.partition), "replicas host every partition");
            let head = self.logs.head(sc.partition);
            if sc.offset < head {
                continue; // duplicate delivery; the log already has it
            }
            if sc.offset > head {
                self.reorder.entry(sc.partition).or_default().insert(sc.offset, sc.chunk);
                continue;
            }
            let p = sc.partition;
            self.logs.append(p, sc.chunk);
            let mut next = sc.offset + 1;
            if let Some(buf) = self.reorder.get_mut(&p) {
                while let Some(chunk) = buf.remove(&next) {
                    self.logs.append(p, chunk);
                    next += 1;
                }
            }
        }
        rpc_ctx.staged = Some(RpcReply::ReplicateAck);
        self.reply(rpc_ctx, ctx);
    }

    /// Hand-off step 1 (drain): stop serving the named partitions — stale
    /// routes now bounce with `WrongShard` — and ack once every in-flight
    /// quorum replication has drained, so the gaining replica holds every
    /// byte this primary ever acked.
    fn finish_shard_freeze(
        &mut self,
        mut rpc_ctx: RpcCtx,
        epoch: u64,
        partitions: &[PartitionId],
        ctx: &mut Ctx<'_, Msg>,
    ) {
        let Some(shard) = self.shard.as_mut() else {
            rpc_ctx.staged =
                Some(RpcReply::Error { reason: "freeze on an unsharded broker".into() });
            self.reply(rpc_ctx, ctx);
            return;
        };
        for p in partitions {
            shard.primaries.remove(p);
        }
        shard.epoch = shard.epoch.max(epoch);
        if self.replicate_rids.is_empty() {
            rpc_ctx.staged = Some(RpcReply::FreezeAck { epoch });
            self.reply(rpc_ctx, ctx);
        } else {
            assert!(self.pending_freeze.is_none(), "one hand-off at a time");
            self.pending_freeze = Some((rpc_ctx, epoch));
        }
    }

    /// Hand-off step 2 (resume): start serving the named partitions. The
    /// coordinator only promotes after every losing primary's drain, so
    /// this broker's log head equals the old primary's — cursors carry
    /// over unchanged.
    fn finish_shard_promote(
        &mut self,
        mut rpc_ctx: RpcCtx,
        epoch: u64,
        partitions: &[PartitionId],
        ctx: &mut Ctx<'_, Msg>,
    ) {
        for p in partitions {
            debug_assert!(
                self.reorder.get(p).map_or(true, |b| b.is_empty()),
                "promotion with undrained replication for {p}"
            );
        }
        let Some(shard) = self.shard.as_mut() else {
            rpc_ctx.staged =
                Some(RpcReply::Error { reason: "promote on an unsharded broker".into() });
            self.reply(rpc_ctx, ctx);
            return;
        };
        for &p in partitions {
            shard.primaries.insert(p);
        }
        shard.epoch = shard.epoch.max(epoch);
        rpc_ctx.staged = Some(RpcReply::PromoteAck { epoch });
        self.reply(rpc_ctx, ctx);
        self.schedule_push(ctx);
    }

    /// Failure-detector probe: a live broker acks with its epoch; a dead
    /// one never gets here (the `dead` gate drops the event), and that
    /// silence is the detection signal.
    fn finish_heartbeat(&mut self, mut rpc_ctx: RpcCtx, ctx: &mut Ctx<'_, Msg>) {
        let epoch = self.shard.as_ref().map_or(0, |s| s.epoch);
        rpc_ctx.staged = Some(RpcReply::HeartbeatAck { epoch });
        self.reply(rpc_ctx, ctx);
    }

    /// The emergency epoch, survivor side: the coordinator declared `dead`
    /// dead and rebuilt the table. Unlike the planned hand-off there is no
    /// freeze/drain phase — by declaration time (a full lease after the
    /// death, orders of magnitude above any delivery delay) everything the
    /// corpse ever fanned out has long been applied here. Three steps:
    /// purge replication held on the corpse (its acks will never come, and
    /// the shrunk replica sets no longer count it toward quorum), install
    /// the rebuilt table wholesale, and start serving the gained
    /// partitions after draining any contiguous reordered replication.
    fn finish_shard_failover(
        &mut self,
        mut rpc_ctx: RpcCtx,
        epoch: u64,
        dead: usize,
        table: ShardTable,
        gained: &[PartitionId],
        ctx: &mut Ctx<'_, Msg>,
    ) {
        if self.shard.is_none() {
            rpc_ctx.staged =
                Some(RpcReply::Error { reason: "fail-over on an unsharded broker".into() });
            self.reply(rpc_ctx, ctx);
            return;
        }
        // 1. Purge: every replicate rid held on the dead peer releases
        // exactly like an ack — the new quorum arithmetic excludes it.
        let dead_rids: Vec<RpcId> = self
            .replicate_rids
            .iter()
            .filter(|&(_, &(_, peer))| peer == dead)
            .map(|(&rid, _)| rid)
            .collect();
        for rid in dead_rids {
            let (ctx_id, _) = self.replicate_rids.remove(&rid).expect("just listed");
            self.on_shard_replicate_ack(ctx_id, ctx);
        }
        // 2. Install the rebuilt assignment; primaries derive from it.
        let shard = self.shard.as_mut().expect("checked above");
        shard.table = table;
        shard.epoch = shard.epoch.max(epoch);
        shard.primaries = shard.table.primaries_of(shard.index).into_iter().collect();
        // 3. Promote the gained partitions on the spot: drain contiguous
        // reordered replication, then nothing may remain buffered — a gap
        // would mean the lease was shorter than a delivery delay.
        for &p in gained {
            if let Some(buf) = self.reorder.get_mut(&p) {
                let mut next = self.logs.head(p);
                while let Some(chunk) = buf.remove(&next) {
                    self.logs.append(p, chunk);
                    next += 1;
                }
                assert!(
                    buf.is_empty(),
                    "promoted {p} with a gap in replicated data (lease too short?)"
                );
            }
        }
        rpc_ctx.staged = Some(RpcReply::FailoverAck { epoch });
        self.reply(rpc_ctx, ctx);
        // Gained primaries may unblock push subscriptions re-homing here.
        self.schedule_push(ctx);
    }

    /// A freeze acks only once every outstanding `ShardReplicate` has
    /// drained — checked at freeze time and after each peer ack.
    fn maybe_finish_freeze(&mut self, ctx: &mut Ctx<'_, Msg>) {
        if !self.replicate_rids.is_empty() {
            return;
        }
        if let Some((mut rpc_ctx, epoch)) = self.pending_freeze.take() {
            rpc_ctx.staged = Some(RpcReply::FreezeAck { epoch });
            self.reply(rpc_ctx, ctx);
        }
    }

    /// A quorum peer acked a `ShardReplicate`: one less vote needed. The
    /// producer ack (and any held seal object) releases at majority; the
    /// remaining acks only retire their rpc ids (the freeze drain gate).
    fn on_shard_replicate_ack(&mut self, ctx_id: u64, ctx: &mut Ctx<'_, Msg>) {
        if let Some(q) = self.quorum.get_mut(&ctx_id) {
            q.need -= 1;
            if q.need == 0 {
                let q = self.quorum.remove(&ctx_id).expect("just seen");
                let rpc_ctx = self.ctxs.remove(&ctx_id).expect("held sharded ingest ctx");
                if let Some(object) = q.held_object {
                    self.store.borrow_mut().release(object);
                }
                self.reply(rpc_ctx, ctx);
            }
        }
        self.maybe_finish_freeze(ctx);
    }

    fn finish_pull(
        &mut self,
        mut rpc_ctx: RpcCtx,
        assignments: &[(PartitionId, ChunkOffset)],
        max_bytes: u64,
        ctx: &mut Ctx<'_, Msg>,
    ) {
        let reply = self.do_pull(assignments, max_bytes);
        if let RpcReply::PullData { chunks, .. } = &reply {
            rpc_ctx.reply_bytes = chunks.iter().map(|s| s.chunk.bytes()).sum();
            self.metrics.borrow_mut().record(
                Class::ConsumerBytes,
                self.entity,
                ctx.now(),
                rpc_ctx.reply_bytes,
            );
        }
        rpc_ctx.staged = Some(reply);
        self.reply(rpc_ctx, ctx);
    }

    fn finish_push_subscribe(
        &mut self,
        mut rpc_ctx: RpcCtx,
        sources: &[crate::proto::PushSourceSpec],
        ctx: &mut Ctx<'_, Msg>,
    ) {
        let reply = self.do_subscribe(sources);
        rpc_ctx.staged = Some(reply);
        self.reply(rpc_ctx, ctx);
        self.schedule_push(ctx);
    }

    fn finish_push_unsubscribe(&mut self, mut rpc_ctx: RpcCtx, sub: SubId, ctx: &mut Ctx<'_, Msg>) {
        let reply = self.do_unsubscribe(sub);
        rpc_ctx.staged = Some(reply);
        self.reply(rpc_ctx, ctx);
    }

    fn finish_replicate(&mut self, mut rpc_ctx: RpcCtx, ctx: &mut Ctx<'_, Msg>) {
        rpc_ctx.staged = Some(RpcReply::ReplicateAck);
        self.reply(rpc_ctx, ctx);
    }

    /// Record a completed checkpoint's cursors as the new retention floor.
    /// Floors are monotone per partition (epochs commit in order, but the
    /// network may not deliver them so). The whole batch is validated
    /// before any floor moves — a refused commit must not raise a partial
    /// prefix (same hardening rule as Append/seal batches).
    fn finish_commit(
        &mut self,
        mut rpc_ctx: RpcCtx,
        epoch: u64,
        cursors: &[(PartitionId, ChunkOffset)],
        ctx: &mut Ctx<'_, Msg>,
    ) {
        if let Some((p, _)) = cursors.iter().find(|(p, _)| !self.logs.contains(*p)) {
            rpc_ctx.staged = Some(RpcReply::Error { reason: format!("unknown partition {p}") });
            self.reply(rpc_ctx, ctx);
            return;
        }
        for &(p, off) in cursors {
            let e = self.committed.entry(p).or_insert(0);
            *e = (*e).max(off);
        }
        rpc_ctx.staged = Some(RpcReply::CommitAck { epoch });
        self.reply(rpc_ctx, ctx);
    }

    /// Register a colocated producer's write-side object pool. Write
    /// subscriptions carry no read cursors: they never enter the push
    /// rotation and never pin retention.
    fn finish_write_subscribe(
        &mut self,
        mut rpc_ctx: RpcCtx,
        spec: &crate::proto::WriteProducerSpec,
        ctx: &mut Ctx<'_, Msg>,
    ) {
        for &p in &spec.partitions {
            if !self.logs.contains(p) {
                rpc_ctx.staged = Some(RpcReply::Error { reason: format!("unknown partition {p}") });
                self.reply(rpc_ctx, ctx);
                return;
            }
        }
        if let Some(reply) = self.shard_refusal(spec.partitions.iter().copied()) {
            rpc_ctx.staged = Some(reply);
            self.reply(rpc_ctx, ctx);
            return;
        }
        let sub = self.store.borrow_mut().create_subscription(
            spec.producer_actor,
            Vec::new(),
            spec.objects,
            spec.object_bytes,
        );
        rpc_ctx.staged = Some(RpcReply::WriteSubscribeAck { sub });
        self.reply(rpc_ctx, ctx);
    }

    /// Validate-then-append one batch; returns `(records, bytes, chunks)`
    /// or the first unknown partition, in which case NOTHING was appended —
    /// the client's bounded retry must not duplicate a landed prefix.
    fn append_chunks(
        &mut self,
        chunks: Vec<(PartitionId, Chunk)>,
        produced_at: Option<Time>,
        now: Time,
    ) -> Result<(u64, u64, u32), PartitionId> {
        if let Some(bad) = chunks.iter().find(|(p, _)| !self.logs.contains(*p)) {
            return Err(bad.0);
        }
        let mut records = 0u64;
        let mut bytes = 0u64;
        let nchunks = chunks.len() as u32;
        for (p, chunk) in chunks {
            records += chunk.records as u64;
            bytes += chunk.bytes();
            let off = self.logs.append(p, chunk);
            // `produced_at` is only ever Some when the tracer sampled this
            // request — the hot untraced path takes no borrow here.
            if let Some(produced) = produced_at {
                self.metrics.borrow_mut().tracer.on_append(p.0, off, produced, now);
            }
        }
        Ok((records, bytes, nchunks))
    }

    /// The shared tail of every ingesting handler: with a backup, forward
    /// the payload as a nested Replicate RPC and hold the staged ack until
    /// it round-trips; without one, ack immediately. `held_object` is the
    /// shared object a held seal releases once durable. Returns true when
    /// the ack was held.
    fn ack_after_replication(
        &mut self,
        id: u64,
        rpc_ctx: RpcCtx,
        bytes: u64,
        nchunks: u32,
        held_object: Option<ObjectId>,
        ctx: &mut Ctx<'_, Msg>,
    ) -> bool {
        let Some((backup_actor, backup_node)) = self.params.backup else {
            self.reply(rpc_ctx, ctx);
            return false;
        };
        let rid = self.next_client_rpc;
        self.next_client_rpc += 1;
        self.awaiting_backup.insert(rid, (id, held_object));
        self.ctxs.insert(id, rpc_ctx);
        let deliver = self.net.borrow_mut().send(ctx.now(), self.params.node, backup_node, bytes);
        ctx.send_at(
            deliver,
            backup_actor,
            Msg::rpc(RpcRequest {
                id: rid,
                reply_to: ctx.self_id(),
                from_node: self.params.node,
                kind: RpcKind::Replicate { bytes, chunks: nchunks },
            }),
        );
        true
    }

    /// A colocated producer sealed a shared object: append its chunks to
    /// the partition logs (the worker-core service time was already
    /// charged), replicate if configured, then release the buffer and ack.
    fn finish_seal(
        &mut self,
        id: u64,
        mut rpc_ctx: RpcCtx,
        object: ObjectId,
        produced_at: Option<Time>,
        ctx: &mut Ctx<'_, Msg>,
    ) {
        // A duplicate or stale notification (object unknown, already
        // released) is a client error, not a broker panic.
        if self.store.borrow().sealed_info(object).is_none() {
            rpc_ctx.staged =
                Some(RpcReply::Error { reason: format!("object {object:?} is not sealed") });
            self.reply(rpc_ctx, ctx);
            return;
        }
        let chunks: Vec<(PartitionId, Chunk)> = self
            .store
            .borrow()
            .read(object)
            .iter()
            .map(|sc| (sc.partition, sc.chunk.clone()))
            .collect();
        // Routing check first: on WrongShard the object stays sealed and
        // the producer re-notifies the new primary (the plasma store is
        // node-global, so the buffer itself needs no hand-off).
        if let Some(reply) = self.shard_refusal(chunks.iter().map(|(p, _)| *p)) {
            rpc_ctx.staged = Some(reply);
            self.reply(rpc_ctx, ctx);
            return;
        }
        if self.shard_replicates() {
            // Fail-over retransmit dedup: if this exact seal already landed
            // (here, or at the dead primary whose replication reached us),
            // re-ack the recorded totals and free the buffer — appending
            // again would double the records.
            if let Some((records, bytes)) =
                self.applied_lookup(rpc_ctx.req.reply_to, rpc_ctx.req.id)
            {
                self.store.borrow_mut().release(object);
                rpc_ctx.staged = Some(RpcReply::SealAck { records, bytes });
                self.reply(rpc_ctx, ctx);
                self.schedule_push(ctx);
                return;
            }
            return self
                .finish_ingest_sharded(id, rpc_ctx, chunks, produced_at, Some(object), true, ctx);
        }
        match self.append_chunks(chunks, produced_at, ctx.now()) {
            Err(p) => {
                // The object stays sealed: the producer owns the retry (or
                // reclaims the buffer after bounded retries).
                rpc_ctx.staged =
                    Some(RpcReply::Error { reason: format!("unknown partition {p}") });
                self.reply(rpc_ctx, ctx);
            }
            Ok((records, bytes, nchunks)) => {
                self.metrics
                    .borrow_mut()
                    .record(Class::ProducerBytes, self.entity, ctx.now(), bytes);
                rpc_ctx.staged = Some(RpcReply::SealAck { records, bytes });
                if !self.ack_after_replication(id, rpc_ctx, bytes, nchunks, Some(object), ctx) {
                    // No backup: the buffer is reusable right away. (With
                    // one, on_backup_ack releases it — the ack doubles as
                    // the durable-reuse signal.)
                    self.store.borrow_mut().release(object);
                }
                // New data may unblock push subscriptions.
                self.schedule_push(ctx);
            }
        }
    }

    /// Append chunks to partition logs; ack immediately (replication = 1)
    /// or hold for the backup round-trip (replication = 2).
    fn finish_append(
        &mut self,
        id: u64,
        mut rpc_ctx: RpcCtx,
        chunks: Vec<(PartitionId, Chunk)>,
        produced_at: Option<Time>,
        ctx: &mut Ctx<'_, Msg>,
    ) {
        // Routing check before anything lands: a batch refused with
        // WrongShard must append nothing — the retry at the new primary
        // is the only copy.
        if let Some(reply) = self.shard_refusal(chunks.iter().map(|(p, _)| *p)) {
            rpc_ctx.staged = Some(reply);
            self.reply(rpc_ctx, ctx);
            return;
        }
        if self.shard_replicates() {
            // Fail-over retransmit dedup: an append that already landed
            // (at this broker, or at the dead primary whose replication
            // fan-out reached us before it died) re-acks its recorded
            // totals instead of landing twice.
            if let Some((records, bytes)) =
                self.applied_lookup(rpc_ctx.req.reply_to, rpc_ctx.req.id)
            {
                rpc_ctx.staged = Some(RpcReply::AppendAck { records, bytes });
                self.reply(rpc_ctx, ctx);
                return;
            }
            return self.finish_ingest_sharded(id, rpc_ctx, chunks, produced_at, None, false, ctx);
        }
        match self.append_chunks(chunks, produced_at, ctx.now()) {
            Err(p) => {
                rpc_ctx.staged =
                    Some(RpcReply::Error { reason: format!("unknown partition {p}") });
                self.reply(rpc_ctx, ctx);
            }
            Ok((records, bytes, nchunks)) => {
                self.metrics
                    .borrow_mut()
                    .record(Class::ProducerBytes, self.entity, ctx.now(), bytes);
                rpc_ctx.staged = Some(RpcReply::AppendAck { records, bytes });
                self.ack_after_replication(id, rpc_ctx, bytes, nchunks, None, ctx);
                // New data may unblock push subscriptions.
                self.schedule_push(ctx);
            }
        }
    }

    fn do_pull(&mut self, assignments: &[(PartitionId, ChunkOffset)], max_bytes: u64) -> RpcReply {
        let mut out = Vec::new();
        let mut trims = Vec::new();
        for &(p, off) in assignments {
            if !self.logs.contains(p) {
                return RpcReply::Error { reason: format!("unknown partition {p}") };
            }
            if !self.serves(p) {
                // Reads only ever come off the current primary — serving
                // them from a frozen log would race the hand-off.
                return self.wrong_shard();
            }
            let start = self.logs.start(p);
            if off < start {
                // The consumer fell behind retention (a torn-down push
                // subscription's cursors no longer pin it). Surface the
                // trim floor so the client can skip forward and count the
                // gap instead of wedging the partition.
                trims.push((p, start));
                continue;
            }
            // One exactly-sized append per partition, straight into the
            // reply vector: the log peeks (clone-free), reserves, then
            // fills in a single linear walk, sharing the resident chunks
            // (`Rc` payload bump, no byte work).
            match self.logs.read_into(p, off, max_bytes, &mut out) {
                Ok(_) => {}
                Err(e) => return RpcReply::Error { reason: e.to_string() },
            }
            // Progress watermark feeds retention trimming.
            let w = self.watermarks.entry(p).or_insert(0);
            *w = (*w).max(off);
        }
        self.trim();
        RpcReply::PullData { chunks: out, trims }
    }

    fn do_subscribe(&mut self, sources: &[crate::proto::PushSourceSpec]) -> RpcReply {
        let mut first = None;
        for spec in sources {
            for &(p, _) in &spec.assignments {
                if !self.logs.contains(p) {
                    return RpcReply::Error { reason: format!("unknown partition {p}") };
                }
                if !self.serves(p) {
                    return self.wrong_shard();
                }
            }
            let sub = self.store.borrow_mut().create_subscription(
                spec.source_actor,
                spec.assignments.clone(),
                spec.objects,
                spec.object_bytes,
            );
            self.push_ring.push(sub);
            first.get_or_insert(sub);
        }
        RpcReply::SubscribeAck { sub: first.unwrap_or(SubId(0)) }
    }

    /// Remove `sub` from the push rotation. Any fill already gathered keeps
    /// going (its chunks are reflected in the returned cursors, so the
    /// client consumes it, then resumes pulling from the cursors — neither
    /// loss nor duplication).
    fn do_unsubscribe(&mut self, sub: SubId) -> RpcReply {
        let Some(pos) = self.push_ring.iter().position(|&s| s == sub) else {
            return RpcReply::Error { reason: format!("unknown subscription {sub:?}") };
        };
        self.push_ring.remove(pos);
        if self.push_rr > pos {
            self.push_rr -= 1;
        }
        if !self.push_ring.is_empty() {
            self.push_rr %= self.push_ring.len();
        } else {
            self.push_rr = 0;
        }
        let cursors = self.store.borrow_mut().deactivate(sub);
        RpcReply::UnsubscribeAck { sub, cursors }
    }

    /// Send the staged reply back over the network.
    fn reply(&mut self, rpc_ctx: RpcCtx, ctx: &mut Ctx<'_, Msg>) {
        let reply = rpc_ctx.staged.expect("reply staged before send");
        let to_node = rpc_ctx.req.from_node;
        let deliver = if rpc_ctx.reply_bytes > 0 {
            self.net
                .borrow_mut()
                .send(ctx.now(), self.params.node, to_node, rpc_ctx.reply_bytes)
        } else {
            self.net
                .borrow_mut()
                .send_control(ctx.now(), self.params.node, to_node)
        };
        ctx.send_at(
            deliver,
            rpc_ctx.req.reply_to,
            Msg::reply(RpcEnvelope { id: rpc_ctx.req.id, reply }),
        );
    }

    /// Backup acked a replicate: release the held producer append. A held
    /// seal additionally returns its shared object to the free pool now —
    /// reuse before replication would hand the producer a buffer whose
    /// data is not durable yet.
    fn on_backup_ack(&mut self, rid: RpcId, ctx: &mut Ctx<'_, Msg>) {
        let (id, held_object) = self
            .awaiting_backup
            .remove(&rid)
            .expect("replicate ack matches a held append");
        let rpc_ctx = self.ctxs.remove(&id).expect("held append ctx");
        if let Some(object) = held_object {
            self.store.borrow_mut().release(object);
        }
        self.reply(rpc_ctx, ctx);
    }

    // ---------------------------------------------------------------------
    // Push path (dedicated threads)
    // ---------------------------------------------------------------------

    /// Try to start fills on idle push threads. A subscription is runnable
    /// if it has a free object AND unconsumed chunks on some partition.
    fn schedule_push(&mut self, ctx: &mut Ctx<'_, Msg>) {
        if self.params.push_threads == 0 || self.push_ring.is_empty() {
            return;
        }
        loop {
            if self.push_pool.busy() >= self.params.push_threads {
                return; // all dedicated threads occupied
            }
            let Some(fill) = self.gather_next_fill() else {
                return; // nothing runnable anywhere
            };
            let bytes: u64 = fill.content.iter().map(|s| s.chunk.bytes()).sum();
            let records: u64 = fill.content.iter().map(|s| s.chunk.records as u64).sum();
            let cost = self.params.cost.push_fill_cost(bytes, records);
            let id = self.next_ctx;
            self.next_ctx += 1;
            self.fills.insert(id, fill);
            let job = Job { cost, tag: id * 8 + PH_PUSH };
            if let Some(started) = self.push_pool.submit(ctx.now(), job) {
                ctx.send_self_in(started.cost, Msg::JobDone(started.tag));
            }
        }
    }

    /// Round-robin over subscriptions, then over a subscription's
    /// partitions; acquire an object and stage the chunks it will carry.
    fn gather_next_fill(&mut self) -> Option<FillCtx> {
        let mut store = self.store.borrow_mut();
        for i in 0..self.push_ring.len() {
            let ring_idx = (self.push_rr + i) % self.push_ring.len();
            let sub = self.push_ring[ring_idx];
            if !store.has_free(sub) {
                continue;
            }
            // Find a partition of this sub with data at its cursor.
            let (nparts, rr0) = {
                let s = store.subscription(sub);
                (s.cursors.len(), s.rr_next)
            };
            let mut chosen: Option<(usize, PartitionId, ChunkOffset)> = None;
            for j in 0..nparts {
                let k = (rr0 + j) % nparts;
                let (p, off) = store.subscription(sub).cursors[k];
                // A frozen partition stops filling mid-hand-off; its
                // subscription resumes at the new primary.
                let avail = if self.logs.contains(p) && self.serves(p) {
                    self.logs.available_from(p, off)
                } else {
                    0
                };
                if avail > 0 {
                    chosen = Some((k, p, off));
                    break;
                }
            }
            let Some((k, p, off)) = chosen else { continue };
            let object = store.acquire(sub).expect("has_free checked");
            let capacity = store.capacity(object);
            let content = self
                .logs
                .read_from(p, off, capacity)
                .expect("cursor is broker-managed, never below retention");
            debug_assert!(!content.is_empty());
            // Advance the broker-managed cursor & rr pointers now: the next
            // fill (possibly concurrent on another push thread) must not
            // re-send these chunks.
            {
                let s = store.subscription_mut(sub);
                s.cursors[k].1 = off + content.len() as u64;
                s.rr_next = (k + 1) % nparts;
            }
            let w = self.watermarks.entry(p).or_insert(0);
            *w = (*w).max(off);
            self.push_rr = (ring_idx + 1) % self.push_ring.len();
            drop(store);
            self.trim();
            return Some(FillCtx { object, content });
        }
        None
    }

    /// A push thread finished copying: seal, notify the source, refill.
    fn on_fill_done(&mut self, id: u64, ctx: &mut Ctx<'_, Msg>) {
        let fill = self.fills.remove(&id).expect("fill ctx alive");
        let bytes: u64 = fill.content.iter().map(|s| s.chunk.bytes()).sum();
        let source = {
            let mut store = self.store.borrow_mut();
            store.seal(fill.object, fill.content);
            store.subscription(fill.object.sub).source_actor
        };
        {
            let mut m = self.metrics.borrow_mut();
            m.record(Class::ObjectsFilled, self.entity, ctx.now(), 1);
            m.record(Class::ConsumerBytes, self.entity, ctx.now(), bytes);
        }
        // Step 3: notify the colocated source through the store.
        ctx.send_in(self.params.cost.notify_ns, source, Msg::ObjectReady { id: fill.object });
    }

    /// Retention: trim below the slowest consumer's progress. Throttled —
    /// a full scan every 64 reads is far more often than segments seal.
    fn trim(&mut self) {
        self.trim_tick = self.trim_tick.wrapping_add(1);
        if self.trim_tick % 64 != 0 {
            return;
        }
        // Push cursors also hold back retention.
        for p in self.logs.partitions() {
            if !self.serves(p) {
                // A standing replica's consumers read at the primary, so
                // its own watermarks say nothing; only the committed
                // checkpoint floor may trim it (the replica log must stay
                // byte-identical and promotable).
                if !self.committed.is_empty() {
                    let floor = self.committed.get(&p).copied().unwrap_or(0);
                    self.trimmed_bytes += self.logs.trim_below(p, floor);
                }
                continue;
            }
            let mut watermark = *self.watermarks.get(&p).unwrap_or(&0);
            {
                let store = self.store.borrow();
                for sub in store.subscriptions() {
                    if !sub.active {
                        continue; // unsubscribed cursors no longer pin retention
                    }
                    for &(sp, off) in &sub.cursors {
                        if sp == p {
                            watermark = watermark.min(off);
                        }
                    }
                }
            }
            if !self.committed.is_empty() {
                // Checkpointing active: retention never passes the last
                // restorable point (the committed checkpoint's cursor).
                watermark = watermark.min(self.committed.get(&p).copied().unwrap_or(0));
            }
            self.trimmed_bytes += self.logs.trim_below(p, watermark);
        }
    }

    // ---------------------------------------------------------------------
    // Introspection for the launcher / tests
    // ---------------------------------------------------------------------

    /// A read-only view of one hosted partition's log (any backend).
    pub fn partition(&self, p: PartitionId) -> Option<LogView<'_>> {
        self.logs.contains(p).then(|| LogView::new(self.logs.as_ref(), p))
    }

    /// The storage backend's counters.
    pub fn store_stats(&self) -> StoreStats {
        self.logs.stats()
    }

    pub fn resident_bytes(&self) -> u64 {
        self.logs.resident_bytes()
    }

    pub fn trimmed_bytes(&self) -> u64 {
        self.trimmed_bytes
    }

    /// End-of-run utilisation gauges (plus storage-tier gauges when the
    /// durable backend is active).
    pub fn export_gauges(&mut self, now: Time, prefix: &str) {
        let d = self.dispatcher.utilization(now);
        let w = self.workers.utilization(now);
        let p = self.push_pool.utilization(now);
        let stats = self.logs.stats();
        let durable = self.logs.mode() == StoreMode::Durable;
        let mut m = self.metrics.borrow_mut();
        m.set_gauge(format!("{prefix}.dispatcher_util"), d);
        m.set_gauge(format!("{prefix}.worker_util"), w);
        if self.params.push_threads > 0 {
            m.set_gauge(format!("{prefix}.push_util"), p);
        }
        m.set_gauge(format!("{prefix}.worker_queue_peak"), self.workers.queue_peak() as f64);
        if durable {
            m.set_gauge(format!("{prefix}.store_wal_records"), stats.wal.records as f64);
            m.set_gauge(format!("{prefix}.store_wal_bytes"), stats.wal.bytes as f64);
            m.set_gauge(
                format!("{prefix}.store_wal_files"),
                stats.wal.files_created as f64,
            );
            m.set_gauge(format!("{prefix}.store_wal_pruned"), stats.wal.files_pruned as f64);
            m.set_gauge(
                format!("{prefix}.store_segments_flushed"),
                stats.segments_flushed as f64,
            );
            m.set_gauge(format!("{prefix}.store_compactions"), stats.compactions as f64);
            m.set_gauge(format!("{prefix}.store_cold_segments"), stats.cold_segments as f64);
            m.set_gauge(format!("{prefix}.store_cold_bytes"), stats.cold_bytes as f64);
            m.set_gauge(format!("{prefix}.store_cold_loads"), stats.cold_loads as f64);
            m.set_gauge(
                format!("{prefix}.store_cold_cache_hits"),
                stats.cold_cache_hits as f64,
            );
        }
    }
}

impl Actor<Msg> for Broker {
    fn on_event(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        if self.dead {
            // A killed broker is a black hole: requests, nested-rpc acks
            // and its own queued job completions all vanish. Clients see
            // silence (their deadline path), the coordinator sees missed
            // heartbeats (its lease path).
            return;
        }
        if let Msg::Fault { kind } = msg {
            assert_eq!(kind, FaultKind::Broker, "brokers only die of broker faults");
            self.dead = true;
            return;
        }
        match msg {
            Msg::Rpc(req) => self.on_rpc(*req, ctx),
            Msg::JobDone(tag) => {
                let (id, phase) = (tag / 8, tag % 8);
                match phase {
                    PH_DISPATCH => {
                        self.on_dispatched(id, ctx);
                        if let Some(next) = self.dispatcher.on_complete(ctx.now()) {
                            ctx.send_self_in(next.cost, Msg::JobDone(next.tag));
                        }
                    }
                    PH_WORK => {
                        self.on_worked(id, ctx);
                        if let Some(next) = self.workers.on_complete(ctx.now()) {
                            ctx.send_self_in(next.cost, Msg::JobDone(next.tag));
                        }
                    }
                    PH_PUSH => {
                        self.on_fill_done(id, ctx);
                        if let Some(next) = self.push_pool.on_complete(ctx.now()) {
                            ctx.send_self_in(next.cost, Msg::JobDone(next.tag));
                        }
                        self.schedule_push(ctx);
                    }
                    _ => unreachable!("unknown phase {phase}"),
                }
            }
            Msg::Reply(env) => {
                // Two nested-rpc ack streams share this seam: quorum
                // ShardReplicate acks and the legacy backup pair's.
                if let Some((ctx_id, _peer)) = self.replicate_rids.remove(&env.id) {
                    match env.reply {
                        RpcReply::ReplicateAck => {}
                        other => panic!(
                            "broker {}: shard replicate refused: {other:?}",
                            self.entity
                        ),
                    }
                    self.on_shard_replicate_ack(ctx_id, ctx);
                } else if self.shard.is_some() {
                    // A replicate ack whose rid a fail-over already purged
                    // (the peer was declared dead with the ack still in
                    // flight): the quorum it voted in has been settled by
                    // the purge — drop it.
                } else {
                    self.on_backup_ack(env.id, ctx);
                }
            }
            // Step 4: a source released an object — its buffer is free again.
            Msg::ObjectFreed { id } => {
                self.store.borrow_mut().release(id);
                self.schedule_push(ctx);
            }
            Msg::DataAvailable => self.schedule_push(ctx),
            other => panic!("broker {}: unexpected {:?}", self.entity, other),
        }
    }

    fn label(&self) -> String {
        if self.params.is_backup {
            format!("backup-broker#{}", self.entity)
        } else {
            format!("broker#{}", self.entity)
        }
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}
