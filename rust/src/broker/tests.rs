//! Broker unit tests: log mechanics + RPC frontend behaviour via a scripted
//! client actor.

use std::cell::RefCell;
use std::rc::Rc;

use super::*;
use crate::config::NetworkProfile;
use crate::metrics::MetricsHub;
use crate::net::Network;
use crate::plasma::ObjectStore;
use crate::proto::*;
use crate::sim::{Actor, ActorId, Ctx, Engine, MICROS, SECOND};

mod log_tests {
    use super::*;
    use crate::broker::log::PartitionLog;

    fn log_with(chunks: usize, records: u32, rec_size: u32, seg_bytes: u64) -> PartitionLog {
        let mut log = PartitionLog::new(PartitionId(0), seg_bytes);
        for _ in 0..chunks {
            log.append(Chunk::sim(records, rec_size));
        }
        log
    }

    #[test]
    fn append_assigns_sequential_offsets() {
        let mut log = PartitionLog::new(PartitionId(0), 1024);
        assert_eq!(log.append(Chunk::sim(1, 10)), 0);
        assert_eq!(log.append(Chunk::sim(1, 10)), 1);
        assert_eq!(log.head(), 2);
        assert_eq!(log.total_appended_records(), 2);
        assert_eq!(log.total_appended_bytes(), 20);
    }

    #[test]
    fn segments_roll_at_capacity() {
        // 100-byte chunks into 256-byte segments: 2 per segment
        let log = log_with(5, 1, 100, 256);
        assert_eq!(log.resident_segments(), 3);
    }

    #[test]
    fn oversized_chunk_gets_own_segment() {
        let mut log = PartitionLog::new(PartitionId(0), 64);
        log.append(Chunk::sim(1, 100)); // bigger than a segment: allowed alone
        log.append(Chunk::sim(1, 100));
        assert_eq!(log.resident_segments(), 2);
    }

    #[test]
    fn read_respects_byte_budget() {
        let log = log_with(10, 10, 10, 1 << 20); // 100-byte chunks
        let got = log.read_from(0, 250).unwrap();
        assert_eq!(got.len(), 2, "two whole chunks fit 250 bytes, third does not");
        assert_eq!(got[0].offset, 0);
        assert_eq!(got[1].offset, 1);
    }

    #[test]
    fn read_returns_at_least_one_chunk() {
        let log = log_with(3, 10, 10, 1 << 20);
        let got = log.read_from(1, 1).unwrap(); // budget smaller than a chunk
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].offset, 1);
    }

    #[test]
    fn read_at_head_is_empty() {
        let log = log_with(3, 1, 10, 1 << 20);
        assert!(log.read_from(3, 1024).unwrap().is_empty());
        assert_eq!(log.available_from(3), 0);
        assert_eq!(log.available_from(1), 2);
    }

    #[test]
    fn trim_drops_whole_consumed_segments() {
        let mut log = log_with(6, 1, 100, 200); // 2 chunks per segment
        let reclaimed = log.trim_below(3); // chunks 0,1 in segment 0: below 3
        assert_eq!(reclaimed, 200);
        assert_eq!(log.start(), 2);
        assert!(log.read_from(1, 100).is_err(), "trimmed offsets error");
        let ok = log.read_from(2, 1000).unwrap();
        assert_eq!(ok.first().unwrap().offset, 2);
    }

    #[test]
    fn trim_never_drops_the_tail_segment() {
        let mut log = log_with(2, 1, 100, 200); // both chunks in one segment
        assert_eq!(log.trim_below(100), 0);
        assert_eq!(log.resident_segments(), 1);
    }

    #[test]
    fn trimmed_error_is_descriptive() {
        let mut log = log_with(6, 1, 100, 200);
        log.trim_below(4);
        let err = log.read_from(0, 100).unwrap_err();
        assert_eq!(err.start, 4);
        assert!(err.to_string().contains("trimmed"));
    }

    #[test]
    fn trim_crosses_multiple_segment_boundaries() {
        // 10 chunks of 100 B into 200 B segments: 5 segments of 2 chunks.
        let mut log = log_with(10, 1, 100, 200);
        assert_eq!(log.resident_segments(), 5);
        // Watermark 7 clears segments [0,1], [2,3], [4,5] — three whole
        // segments — but not [6,7], which the watermark splits.
        let reclaimed = log.trim_below(7);
        assert_eq!(reclaimed, 600);
        assert_eq!(log.start(), 6);
        assert_eq!(log.resident_segments(), 2);
        // Reads straddling the trim point: behind errors, at/after works.
        assert_eq!(log.read_from(5, 1000).unwrap_err().start, 6);
        let ok = log.read_from(6, 1000).unwrap();
        assert_eq!(ok.first().unwrap().offset, 6);
        assert_eq!(ok.len(), 4);
        // A later, higher watermark keeps trimming incrementally.
        assert_eq!(log.trim_below(9), 200);
        assert_eq!(log.start(), 8);
    }

    #[test]
    fn trim_is_idempotent_and_monotone() {
        let mut log = log_with(8, 1, 100, 200);
        assert_eq!(log.trim_below(4), 400);
        assert_eq!(log.trim_below(4), 0, "re-trimming the same watermark is free");
        assert_eq!(log.trim_below(2), 0, "a regressing watermark never un-trims");
        assert_eq!(log.start(), 4);
        assert_eq!(log.available_from(0), 4, "only retained chunks count");
    }
}

// ---------------------------------------------------------------------------
// Actor-level tests with a scripted client
// ---------------------------------------------------------------------------

type Inbox = Rc<RefCell<Vec<(u64, Msg)>>>;

/// Test client: forwards scripted requests, logs every delivery (time, msg).
struct Probe {
    inbox: Inbox,
}

impl Actor<Msg> for Probe {
    fn on_event(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        self.inbox.borrow_mut().push((ctx.now(), msg));
    }
}

struct Rig {
    engine: Engine<Msg>,
    broker: ActorId,
    probe: ActorId,
    inbox: Inbox,
    store: crate::plasma::SharedStore,
    metrics: crate::metrics::SharedMetrics,
}

fn rig(params_fn: impl FnOnce(&mut BrokerParams)) -> Rig {
    let mut engine = Engine::new(7);
    let net = Network::shared(NetworkProfile::INFINIBAND, NetworkProfile::LOOPBACK);
    let store = ObjectStore::shared();
    let metrics = MetricsHub::shared();
    let mut params = BrokerParams {
        node: 0,
        worker_cores: 4,
        push_threads: 1,
        store: StoreParams::memory(8 * 1024 * 1024),
        partitions: (0..4).map(PartitionId).collect(),
        backup: None,
        is_backup: false,
        cost: Default::default(),
    };
    params_fn(&mut params);
    let broker = engine.add_actor(Box::new(Broker::new(
        params,
        net,
        store.clone(),
        metrics.clone(),
        0,
    )));
    let inbox: Inbox = Rc::new(RefCell::new(Vec::new()));
    let probe = engine.add_actor(Box::new(Probe { inbox: inbox.clone() }));
    Rig { engine, broker, probe, inbox, store, metrics }
}

fn append_req(rig: &Rig, id: RpcId, parts: &[usize], records: u32, rec_size: u32) -> Msg {
    Msg::rpc(RpcRequest {
        id,
        reply_to: rig.probe,
        from_node: 1,
        kind: RpcKind::Append {
            chunks: parts
                .iter()
                .map(|&p| (PartitionId(p), Chunk::sim(records, rec_size)))
                .collect(),
            produced_at: None,
        },
    })
}

fn replies(inbox: &Inbox) -> Vec<(u64, RpcEnvelope)> {
    inbox
        .borrow()
        .iter()
        .filter_map(|(t, m)| match m {
            Msg::Reply(env) => Some((*t, (**env).clone())),
            _ => None,
        })
        .collect()
}

#[test]
fn append_then_pull_round_trip() {
    let mut r = rig(|_| {});
    r.engine.schedule(0, r.broker, append_req(&r, 1, &[0, 1], 100, 100));
    r.engine.run_until(SECOND);
    let reps = replies(&r.inbox);
    assert_eq!(reps.len(), 1);
    match &reps[0].1.reply {
        RpcReply::AppendAck { records, bytes } => {
            assert_eq!(*records, 200);
            assert_eq!(*bytes, 20_000);
        }
        other => panic!("want AppendAck, got {other:?}"),
    }
    // ack latency: dispatch + base + 2 appends + 20 kB memcpy + net
    let t = reps[0].0;
    assert!(t > 2 * MICROS && t < 100 * MICROS, "append ack at {t} ns");

    // now pull it back
    r.engine.schedule(
        r.engine.now(),
        r.broker,
        Msg::rpc(RpcRequest {
            id: 2,
            reply_to: r.probe,
            from_node: 1,
            kind: RpcKind::Pull {
                assignments: vec![(PartitionId(0), 0), (PartitionId(1), 0)],
                max_bytes: 1 << 20,
            },
        }),
    );
    r.engine.run_until(2 * SECOND);
    let reps = replies(&r.inbox);
    assert_eq!(reps.len(), 2);
    match &reps[1].1.reply {
        RpcReply::PullData { chunks, trims } => {
            assert_eq!(chunks.len(), 2);
            assert_eq!(chunks[0].chunk.records, 100);
            assert!(trims.is_empty(), "nothing trimmed yet");
        }
        other => panic!("want PullData, got {other:?}"),
    }
}

#[test]
fn pull_of_unknown_partition_errors() {
    let mut r = rig(|_| {});
    r.engine.schedule(
        0,
        r.broker,
        Msg::rpc(RpcRequest {
            id: 9,
            reply_to: r.probe,
            from_node: 1,
            kind: RpcKind::Pull { assignments: vec![(PartitionId(99), 0)], max_bytes: 1024 },
        }),
    );
    r.engine.run_until(SECOND);
    let reps = replies(&r.inbox);
    assert!(matches!(reps[0].1.reply, RpcReply::Error { .. }));
}

#[test]
fn single_worker_core_serialises_rpcs() {
    // Two appends to a 1-core broker: second ack ~ one service time later.
    let mut r = rig(|p| {
        p.worker_cores = 1;
        p.push_threads = 0;
    });
    r.engine.schedule(0, r.broker, append_req(&r, 1, &[0], 1000, 100));
    r.engine.schedule(0, r.broker, append_req(&r, 2, &[1], 1000, 100));
    r.engine.run_until(SECOND);
    let reps = replies(&r.inbox);
    assert_eq!(reps.len(), 2);
    let gap = reps[1].0 - reps[0].0;
    // 100 kB at 10 GB/s = 10 us service; the gap must be about that
    assert!(gap > 8 * MICROS, "serialised appends must queue: gap {gap}");

    // same pair with 2 cores: acks nearly simultaneous
    let mut r2 = rig(|p| {
        p.worker_cores = 2;
        p.push_threads = 0;
    });
    r2.engine.schedule(0, r2.broker, append_req(&r2, 1, &[0], 1000, 100));
    r2.engine.schedule(0, r2.broker, append_req(&r2, 2, &[1], 1000, 100));
    r2.engine.run_until(SECOND);
    let reps2 = replies(&r2.inbox);
    let gap2 = reps2[1].0 - reps2[0].0;
    assert!(gap2 < gap / 2, "parallel cores must overlap: {gap2} vs {gap}");
}

#[test]
fn dispatcher_is_a_single_serial_core() {
    // Many zero-byte pulls: their acks space out by at least dispatch_ns.
    let mut r = rig(|p| {
        p.worker_cores = 16;
        p.push_threads = 0;
    });
    for i in 0..50 {
        r.engine.schedule(
            0,
            r.broker,
            Msg::rpc(RpcRequest {
                id: i,
                reply_to: r.probe,
                from_node: 1,
                kind: RpcKind::Pull { assignments: vec![(PartitionId(0), 0)], max_bytes: 1024 },
            }),
        );
    }
    r.engine.run_until(SECOND);
    let reps = replies(&r.inbox);
    assert_eq!(reps.len(), 50);
    let span = reps.last().unwrap().0 - reps[0].0;
    let dispatch = CostModel::default().dispatch_ns;
    assert!(
        span >= 49 * dispatch,
        "dispatcher must serialise 50 RPCs: span {span} < {}",
        49 * dispatch
    );
}

use crate::config::CostModel;

#[test]
fn replicated_append_waits_for_backup() {
    // Broker with a backup: ack arrives only after the nested round-trip.
    let mut engine = Engine::new(7);
    let net = Network::shared(NetworkProfile::INFINIBAND, NetworkProfile::LOOPBACK);
    let store = ObjectStore::shared();
    let metrics = MetricsHub::shared();
    let backup_params = BrokerParams {
        node: 2,
        worker_cores: 4,
        push_threads: 0,
        store: StoreParams::memory(8 << 20),
        partitions: vec![],
        backup: None,
        is_backup: true,
        cost: Default::default(),
    };
    let backup = engine.add_actor(Box::new(Broker::new(
        backup_params,
        net.clone(),
        store.clone(),
        metrics.clone(),
        1,
    )));
    let primary_params = BrokerParams {
        node: 0,
        worker_cores: 4,
        push_threads: 0,
        store: StoreParams::memory(8 << 20),
        partitions: vec![PartitionId(0)],
        backup: Some((backup, 2)),
        is_backup: false,
        cost: Default::default(),
    };
    let primary = engine.add_actor(Box::new(Broker::new(
        primary_params,
        net,
        store,
        metrics,
        0,
    )));
    let inbox: Inbox = Rc::new(RefCell::new(Vec::new()));
    let probe = engine.add_actor(Box::new(Probe { inbox: inbox.clone() }));

    engine.schedule(
        0,
        primary,
        Msg::rpc(RpcRequest {
            id: 1,
            reply_to: probe,
            from_node: 1,
            kind: RpcKind::Append {
                chunks: vec![(PartitionId(0), Chunk::sim(1000, 100))],
                produced_at: None,
            },
        }),
    );
    engine.run_until(SECOND);
    let reps = replies(&inbox);
    assert_eq!(reps.len(), 1);
    assert!(matches!(reps[0].1.reply, RpcReply::AppendAck { .. }));
    let t_replicated = reps[0].0;

    // Reference: same append without replication is much faster.
    let mut r = rig(|p| p.push_threads = 0);
    r.engine.schedule(0, r.broker, append_req(&r, 1, &[0], 1000, 100));
    r.engine.run_until(SECOND);
    let t_plain = replies(&r.inbox)[0].0;
    assert!(
        t_replicated > t_plain + 2 * MICROS,
        "replication must add a round-trip: {t_replicated} vs {t_plain}"
    );
}

#[test]
fn push_subscription_fills_and_notifies() {
    let mut r = rig(|p| p.push_threads = 1);
    // Subscribe one source for partitions 0 and 1, two objects of 64 KiB.
    r.engine.schedule(
        0,
        r.broker,
        Msg::rpc(RpcRequest {
            id: 1,
            reply_to: r.probe,
            from_node: 0,
            kind: RpcKind::PushSubscribe {
                sources: vec![PushSourceSpec {
                    source_actor: r.probe,
                    assignments: vec![(PartitionId(0), 0), (PartitionId(1), 0)],
                    objects: 2,
                    object_bytes: 64 * 1024,
                }],
            },
        }),
    );
    // Produce data afterwards.
    r.engine.schedule(10 * MICROS, r.broker, append_req(&r, 2, &[0, 1], 100, 100));
    r.engine.run_until(SECOND);

    let inbox = r.inbox.borrow();
    let ready: Vec<_> = inbox
        .iter()
        .filter_map(|(t, m)| match m {
            Msg::ObjectReady { id } => Some((*t, *id)),
            _ => None,
        })
        .collect();
    assert_eq!(ready.len(), 2, "one object per partition's chunk: {inbox:?}");
    // Verify sealed content is readable through the store.
    let store = r.store.borrow();
    let (records, bytes) = store.sealed_counts(ready[0].1);
    assert_eq!(records, 100);
    assert_eq!(bytes, 10_000);
    drop(store);
    assert_eq!(r.metrics.borrow().total(crate::metrics::Class::ObjectsFilled), 2);
}

#[test]
fn push_respects_object_backpressure() {
    let mut r = rig(|p| p.push_threads = 1);
    // One object only: after it fills, the second chunk must wait for a free.
    r.engine.schedule(
        0,
        r.broker,
        Msg::rpc(RpcRequest {
            id: 1,
            reply_to: r.probe,
            from_node: 0,
            kind: RpcKind::PushSubscribe {
                sources: vec![PushSourceSpec {
                    source_actor: r.probe,
                    assignments: vec![(PartitionId(0), 0)],
                    objects: 1,
                    object_bytes: 16 * 1024,
                }],
            },
        }),
    );
    r.engine.schedule(10 * MICROS, r.broker, append_req(&r, 2, &[0], 100, 100));
    r.engine.schedule(20 * MICROS, r.broker, append_req(&r, 3, &[0], 100, 100));
    r.engine.run_until(SECOND);
    let ready_count = r
        .inbox
        .borrow()
        .iter()
        .filter(|(_, m)| matches!(m, Msg::ObjectReady { .. }))
        .count();
    assert_eq!(ready_count, 1, "second fill must stall on the single object");

    // Source frees the object -> the parked chunk is pushed.
    let id = {
        let inbox = r.inbox.borrow();
        inbox
            .iter()
            .find_map(|(_, m)| match m {
                Msg::ObjectReady { id } => Some(*id),
                _ => None,
            })
            .unwrap()
    };
    let now = r.engine.now();
    r.engine.schedule(now, r.broker, Msg::ObjectFreed { id });
    r.engine.run_until(2 * SECOND);
    let ready_count = r
        .inbox
        .borrow()
        .iter()
        .filter(|(_, m)| matches!(m, Msg::ObjectReady { .. }))
        .count();
    assert_eq!(ready_count, 2, "freed object must be reused for the parked chunk");
}

#[test]
fn push_unsubscribe_returns_cursors_and_stops_fills() {
    let mut r = rig(|p| p.push_threads = 1);
    r.engine.schedule(
        0,
        r.broker,
        Msg::rpc(RpcRequest {
            id: 1,
            reply_to: r.probe,
            from_node: 0,
            kind: RpcKind::PushSubscribe {
                sources: vec![PushSourceSpec {
                    source_actor: r.probe,
                    assignments: vec![(PartitionId(0), 0)],
                    objects: 2,
                    object_bytes: 64 * 1024,
                }],
            },
        }),
    );
    r.engine.schedule(10 * MICROS, r.broker, append_req(&r, 2, &[0], 100, 100));
    r.engine.run_until(SECOND);
    let sub = {
        let inbox = r.inbox.borrow();
        inbox
            .iter()
            .find_map(|(_, m)| match m {
                Msg::Reply(env) => match &env.reply {
                    RpcReply::SubscribeAck { sub } => Some(*sub),
                    _ => None,
                },
                _ => None,
            })
            .expect("subscribed")
    };
    // Tear the subscription down; the ack must carry the advanced cursor.
    let now = r.engine.now();
    r.engine.schedule(
        now,
        r.broker,
        Msg::rpc(RpcRequest {
            id: 3,
            reply_to: r.probe,
            from_node: 0,
            kind: RpcKind::PushUnsubscribe { sub },
        }),
    );
    r.engine.run_until(2 * SECOND);
    let cursors = {
        let inbox = r.inbox.borrow();
        inbox
            .iter()
            .find_map(|(_, m)| match m {
                Msg::Reply(env) => match &env.reply {
                    RpcReply::UnsubscribeAck { cursors, .. } => Some(cursors.clone()),
                    _ => None,
                },
                _ => None,
            })
            .expect("unsubscribe acked")
    };
    assert_eq!(cursors, vec![(PartitionId(0), 1)], "cursor advanced past the gathered fill");
    // Appends after the unsubscribe must not fill further objects.
    let filled_before = r.metrics.borrow().total(crate::metrics::Class::ObjectsFilled);
    let now = r.engine.now();
    r.engine.schedule(now, r.broker, append_req(&r, 4, &[0], 100, 100));
    r.engine.run_until(3 * SECOND);
    let filled_after = r.metrics.borrow().total(crate::metrics::Class::ObjectsFilled);
    assert_eq!(filled_before, filled_after, "inactive subscription gets no fills");
    // Unknown subscriptions error instead of panicking.
    let now = r.engine.now();
    r.engine.schedule(
        now,
        r.broker,
        Msg::rpc(RpcRequest {
            id: 5,
            reply_to: r.probe,
            from_node: 0,
            kind: RpcKind::PushUnsubscribe { sub },
        }),
    );
    r.engine.run_until(4 * SECOND);
    let errors = r
        .inbox
        .borrow()
        .iter()
        .filter(|(_, m)| match m {
            Msg::Reply(env) => matches!(env.reply, RpcReply::Error { .. }),
            _ => false,
        })
        .count();
    assert_eq!(errors, 1, "double unsubscribe is a client error");
}

#[test]
fn push_object_batches_small_chunks() {
    // Many small chunks, one big object: a single fill carries them all.
    let mut r = rig(|p| p.push_threads = 1);
    r.engine.schedule(0, r.broker, append_req(&r, 1, &[0], 10, 100)); // 1 kB
    r.engine.schedule(0, r.broker, append_req(&r, 2, &[0], 10, 100));
    r.engine.schedule(0, r.broker, append_req(&r, 3, &[0], 10, 100));
    r.engine.schedule(
        50 * MICROS, // subscribe after data landed
        r.broker,
        Msg::rpc(RpcRequest {
            id: 4,
            reply_to: r.probe,
            from_node: 0,
            kind: RpcKind::PushSubscribe {
                sources: vec![PushSourceSpec {
                    source_actor: r.probe,
                    assignments: vec![(PartitionId(0), 0)],
                    objects: 2,
                    object_bytes: 64 * 1024,
                }],
            },
        }),
    );
    r.engine.run_until(SECOND);
    let ready: Vec<_> = r
        .inbox
        .borrow()
        .iter()
        .filter_map(|(_, m)| match m {
            Msg::ObjectReady { id } => Some(*id),
            _ => None,
        })
        .collect();
    assert_eq!(ready.len(), 1, "all three small chunks fit one object fill");
    assert_eq!(r.store.borrow().read(ready[0]).len(), 3);
}

#[test]
fn producer_bytes_metric_recorded() {
    let mut r = rig(|_| {});
    r.engine.schedule(0, r.broker, append_req(&r, 1, &[0, 1, 2, 3], 100, 100));
    r.engine.run_until(SECOND);
    assert_eq!(
        r.metrics.borrow().total(crate::metrics::Class::ProducerBytes),
        4 * 100 * 100
    );
}

// ---------------------------------------------------------------------------
// Shared-memory write path: WriteSubscribe + SealObject
// ---------------------------------------------------------------------------

fn write_subscribe_req(r: &Rig, id: RpcId, parts: &[usize], objects: usize) -> Msg {
    Msg::rpc(RpcRequest {
        id,
        reply_to: r.probe,
        from_node: 0,
        kind: RpcKind::WriteSubscribe {
            producer: WriteProducerSpec {
                producer_actor: r.probe,
                partitions: parts.iter().map(|&p| PartitionId(p)).collect(),
                objects,
                object_bytes: 64 * 1024,
            },
        },
    })
}

/// Run the subscription handshake and return the granted SubId.
fn write_sub(r: &mut Rig, parts: &[usize], objects: usize) -> SubId {
    r.engine.schedule(0, r.broker, write_subscribe_req(r, 1, parts, objects));
    r.engine.run_until(10 * MICROS);
    let reps = replies(&r.inbox);
    match reps.last().expect("subscribe acked").1.reply {
        RpcReply::WriteSubscribeAck { sub } => sub,
        ref other => panic!("expected WriteSubscribeAck, got {other:?}"),
    }
}

#[test]
fn write_subscribe_allocates_a_pool() {
    let mut r = rig(|_| {});
    let sub = write_sub(&mut r, &[0, 1], 3);
    let store = r.store.borrow();
    assert!(store.has_free(sub), "objects start free");
    assert_eq!(store.reserved_bytes(), 3 * 64 * 1024);
    assert!(
        store.subscription(sub).cursors.is_empty(),
        "write pools carry no read cursors (never pin retention)"
    );
}

#[test]
fn write_subscribe_of_unknown_partition_errors() {
    let mut r = rig(|_| {});
    r.engine.schedule(0, r.broker, write_subscribe_req(&r, 1, &[0, 9], 2));
    r.engine.run_until(10 * MICROS);
    let reps = replies(&r.inbox);
    assert!(
        matches!(&reps[0].1.reply, RpcReply::Error { reason } if reason.contains("unknown")),
        "{reps:?}"
    );
    assert_eq!(r.store.borrow().reserved_bytes(), 0, "no pool for a rejected spec");
}

fn seal_req(r: &Rig, id: RpcId, object: crate::proto::ObjectId) -> Msg {
    Msg::rpc(RpcRequest {
        id,
        reply_to: r.probe,
        from_node: 0,
        kind: RpcKind::SealObject { id: object, produced_at: None },
    })
}

/// Acquire + fill + seal one object the way the colocated producer does.
fn fill_object(r: &Rig, sub: SubId, parts: &[usize], records: u32) -> crate::proto::ObjectId {
    let mut store = r.store.borrow_mut();
    let object = store.acquire(sub).expect("a free object");
    let content = parts
        .iter()
        .map(|&p| StampedChunk {
            partition: PartitionId(p),
            offset: 0, // placeholder: the broker assigns log offsets
            chunk: Chunk::sim(records, 100),
        })
        .collect();
    store.seal(object, content);
    object
}

#[test]
fn seal_object_appends_releases_and_acks() {
    let mut r = rig(|_| {});
    let sub = write_sub(&mut r, &[0, 1], 1);
    let object = fill_object(&r, sub, &[0, 1], 100);
    assert!(!r.store.borrow().has_free(sub), "the only object is sealed");
    r.engine.schedule(20 * MICROS, r.broker, seal_req(&r, 2, object));
    r.engine.run_until(SECOND);
    let reps = replies(&r.inbox);
    let seal_ack = &reps.last().unwrap().1;
    match seal_ack.reply {
        RpcReply::SealAck { records, bytes } => {
            assert_eq!(records, 200);
            assert_eq!(bytes, 20_000);
        }
        ref other => panic!("expected SealAck, got {other:?}"),
    }
    // The chunks are in the logs at broker-assigned offsets...
    let b = r.engine.actor_as::<Broker>(r.broker).unwrap();
    assert_eq!(b.partition(PartitionId(0)).unwrap().total_appended_records(), 100);
    assert_eq!(b.partition(PartitionId(1)).unwrap().total_appended_records(), 100);
    // ...and the buffer is reusable.
    assert!(r.store.borrow().has_free(sub), "released for reuse");
    assert_eq!(
        r.metrics.borrow().total(crate::metrics::Class::ProducerBytes),
        20_000,
        "seal appends count as producer ingest"
    );
}

#[test]
fn seal_of_unknown_partition_errors_and_keeps_the_object() {
    let mut r = rig(|_| {});
    let sub = write_sub(&mut r, &[0], 1);
    // A mixed object: valid p0 plus unknown p9. Nothing may be appended —
    // the producer retries the whole object, so a landed prefix would be
    // duplicated.
    let object = fill_object(&r, sub, &[0, 9], 10);
    r.engine.schedule(20 * MICROS, r.broker, seal_req(&r, 2, object));
    r.engine.run_until(SECOND);
    let reps = replies(&r.inbox);
    assert!(
        matches!(&reps.last().unwrap().1.reply, RpcReply::Error { reason }
            if reason.contains("unknown partition")),
        "{reps:?}"
    );
    {
        let b = r.engine.actor_as::<Broker>(r.broker).unwrap();
        assert_eq!(
            b.partition(PartitionId(0)).unwrap().total_appended_records(),
            0,
            "no valid-prefix append on a rejected object"
        );
    }
    // The producer owns the retry: the object must still be sealed.
    assert!(!r.store.borrow().has_free(sub));
    assert_eq!(r.store.borrow().sealed_chunks(object), 2, "content intact for the retry");
}

#[test]
fn stale_seal_notification_is_an_error_not_a_panic() {
    let mut r = rig(|_| {});
    let sub = write_sub(&mut r, &[0], 1);
    let object = fill_object(&r, sub, &[0], 10);
    r.engine.schedule(20 * MICROS, r.broker, seal_req(&r, 2, object));
    // A duplicate notification for the same object, arriving after the
    // broker appended and released it...
    r.engine.schedule(SECOND / 2, r.broker, seal_req(&r, 3, object));
    // ...and one for an object that never existed.
    let bogus = ObjectId { sub: SubId(99), slot: 7 };
    r.engine.schedule(SECOND / 2 + MICROS, r.broker, seal_req(&r, 4, bogus));
    r.engine.run_until(SECOND);
    let reps = replies(&r.inbox);
    let ack = reps.iter().find(|(_, e)| e.id == 2).expect("first seal served");
    assert!(matches!(ack.1.reply, RpcReply::SealAck { .. }), "{ack:?}");
    for id in [3u64, 4] {
        let rep = reps.iter().find(|(_, e)| e.id == id).expect("stale seal answered");
        assert!(
            matches!(&rep.1.reply, RpcReply::Error { reason } if reason.contains("not sealed")),
            "stale/bogus seal must be a protocol error, not a broker panic: {rep:?}"
        );
    }
}

#[test]
fn append_with_any_unknown_partition_appends_nothing() {
    let mut r = rig(|_| {});
    r.engine.schedule(0, r.broker, append_req(&r, 1, &[0, 9], 100, 100));
    r.engine.run_until(SECOND);
    let reps = replies(&r.inbox);
    assert!(matches!(&reps[0].1.reply, RpcReply::Error { .. }), "{reps:?}");
    let b = r.engine.actor_as::<Broker>(r.broker).unwrap();
    assert_eq!(
        b.partition(PartitionId(0)).unwrap().total_appended_records(),
        0,
        "the valid prefix must not land (a client retry would duplicate it)"
    );
}

#[test]
fn replicated_seal_releases_only_after_backup_ack() {
    let mut engine = Engine::new(7);
    let net = Network::shared(NetworkProfile::INFINIBAND, NetworkProfile::LOOPBACK);
    let store = ObjectStore::shared();
    let metrics = MetricsHub::shared();
    let backup = engine.add_actor(Box::new(Broker::new(
        BrokerParams {
            node: 2,
            worker_cores: 4,
            push_threads: 0,
            store: StoreParams::memory(8 << 20),
            partitions: vec![],
            backup: None,
            is_backup: true,
            cost: Default::default(),
        },
        net.clone(),
        store.clone(),
        metrics.clone(),
        1,
    )));
    let primary = engine.add_actor(Box::new(Broker::new(
        BrokerParams {
            node: 0,
            worker_cores: 4,
            push_threads: 0,
            store: StoreParams::memory(8 << 20),
            partitions: vec![PartitionId(0)],
            backup: Some((backup, 2)),
            is_backup: false,
            cost: Default::default(),
        },
        net,
        store.clone(),
        metrics,
        0,
    )));
    let inbox: Inbox = Rc::new(RefCell::new(Vec::new()));
    let probe = engine.add_actor(Box::new(Probe { inbox: inbox.clone() }));
    engine.schedule(
        0,
        primary,
        Msg::rpc(RpcRequest {
            id: 1,
            reply_to: probe,
            from_node: 0,
            kind: RpcKind::WriteSubscribe {
                producer: WriteProducerSpec {
                    producer_actor: probe,
                    partitions: vec![PartitionId(0)],
                    objects: 1,
                    object_bytes: 64 * 1024,
                },
            },
        }),
    );
    engine.run_until(10 * MICROS);
    let sub = match replies(&inbox).last().expect("subscribed").1.reply {
        RpcReply::WriteSubscribeAck { sub } => sub,
        ref other => panic!("expected WriteSubscribeAck, got {other:?}"),
    };
    let object = {
        let mut s = store.borrow_mut();
        let object = s.acquire(sub).expect("free object");
        s.seal(
            object,
            vec![StampedChunk {
                partition: PartitionId(0),
                offset: 0,
                chunk: Chunk::sim(1000, 100),
            }],
        );
        object
    };
    engine.schedule(
        20 * MICROS,
        primary,
        Msg::rpc(RpcRequest {
            id: 2,
            reply_to: probe,
            from_node: 0,
            kind: RpcKind::SealObject { id: object, produced_at: None },
        }),
    );
    engine.run_until(SECOND);
    let reps = replies(&inbox);
    let (t_ack, env) = reps.last().unwrap();
    assert!(matches!(env.reply, RpcReply::SealAck { records: 1000, .. }), "{env:?}");
    assert!(store.borrow().has_free(sub), "released after the backup round-trip");
    // The ack must carry the backup's extra round-trip (node 0 <-> node 2).
    assert!(*t_ack > 20 * MICROS + 10 * MICROS, "replicated seal ack at {t_ack}");
}

// ---------------------------------------------------------------------------
// Watermark-driven retention at the broker (satellite)
// ---------------------------------------------------------------------------

#[test]
fn watermark_trim_leaves_laggards_behind() {
    // Tiny segments so retention actually rolls: 100-byte chunks into
    // 1000-byte segments. A fast consumer advances the watermark past many
    // sealed segments; the throttled trim (every 64 reads) then drops
    // them, and a pull from offset 0 afterwards reports the trim instead
    // of silently rereading.
    let mut r = rig(|p| p.store.segment_bytes = 1000);
    // 200 chunks on partition 0, appended in 4 RPCs of 50 chunks each.
    for i in 0..4u64 {
        r.engine.schedule(
            i * 10 * MICROS,
            r.broker,
            Msg::rpc(RpcRequest {
                id: i,
                reply_to: r.probe,
                from_node: 1,
                kind: RpcKind::Append {
                    chunks: (0..50).map(|_| (PartitionId(0), Chunk::sim(1, 100))).collect(),
                    produced_at: None,
                },
            }),
        );
    }
    // 70 fast-consumer pulls at offset 150: enough reads to pass the
    // 64-read trim throttle with the watermark parked at 150.
    for i in 0..70u64 {
        r.engine.schedule(
            (100 + i * 20) * MICROS,
            r.broker,
            Msg::rpc(RpcRequest {
                id: 100 + i,
                reply_to: r.probe,
                from_node: 1,
                kind: RpcKind::Pull { assignments: vec![(PartitionId(0), 150)], max_bytes: 100 },
            }),
        );
    }
    // The laggard wakes up at offset 0 after retention has moved on.
    r.engine.schedule(
        SECOND / 100,
        r.broker,
        Msg::rpc(RpcRequest {
            id: 999,
            reply_to: r.probe,
            from_node: 1,
            kind: RpcKind::Pull { assignments: vec![(PartitionId(0), 0)], max_bytes: 100 },
        }),
    );
    r.engine.run_until(SECOND);
    {
        let b = r.engine.actor_as::<Broker>(r.broker).unwrap();
        assert!(b.trimmed_bytes() > 0, "segments were reclaimed");
        let log = b.partition(PartitionId(0)).unwrap();
        assert_eq!(log.start(), 150, "whole segments strictly below the watermark went");
        assert_eq!(log.head(), 200);
    }
    let reps = replies(&r.inbox);
    let laggard = reps.iter().find(|(_, env)| env.id == 999).expect("laggard answered");
    // A read behind the trim point surfaces the trim — structured, so the
    // client can skip to the floor with a counted gap instead of wedging.
    match &laggard.1.reply {
        RpcReply::PullData { chunks, trims } => {
            assert!(chunks.is_empty(), "nothing below the floor is served");
            assert_eq!(trims, &vec![(PartitionId(0), 150)], "the floor is reported");
        }
        other => panic!("want PullData with trims, got {other:?}"),
    }
}

#[test]
fn committed_checkpoint_floors_retention() {
    // Same layout as the laggard test, but a checkpoint commit at offset
    // 100 pins retention below the fast consumer's watermark (150): the
    // replay data in [100, 150) must survive trimming.
    let mut r = rig(|p| p.store.segment_bytes = 1000);
    r.engine.schedule(
        0,
        r.broker,
        Msg::rpc(RpcRequest {
            id: 1000,
            reply_to: r.probe,
            from_node: 0,
            kind: RpcKind::CommitCheckpoint { epoch: 1, cursors: vec![(PartitionId(0), 100)] },
        }),
    );
    for i in 0..4u64 {
        r.engine.schedule(
            (1 + i * 10) * MICROS,
            r.broker,
            Msg::rpc(RpcRequest {
                id: i,
                reply_to: r.probe,
                from_node: 1,
                kind: RpcKind::Append {
                    chunks: (0..50).map(|_| (PartitionId(0), Chunk::sim(1, 100))).collect(),
                    produced_at: None,
                },
            }),
        );
    }
    for i in 0..70u64 {
        r.engine.schedule(
            (100 + i * 20) * MICROS,
            r.broker,
            Msg::rpc(RpcRequest {
                id: 100 + i,
                reply_to: r.probe,
                from_node: 1,
                kind: RpcKind::Pull { assignments: vec![(PartitionId(0), 150)], max_bytes: 100 },
            }),
        );
    }
    r.engine.run_until(SECOND);
    {
        let reps = replies(&r.inbox);
        let ack = reps.iter().find(|(_, env)| env.id == 1000).expect("commit answered");
        assert!(
            matches!(ack.1.reply, RpcReply::CommitAck { epoch: 1 }),
            "commit acked: {:?}",
            ack.1
        );
    }
    let b = r.engine.actor_as::<Broker>(r.broker).unwrap();
    let log = b.partition(PartitionId(0)).unwrap();
    assert!(
        log.start() <= 100,
        "retention must not pass the committed floor: start {}",
        log.start()
    );
    assert!(b.trimmed_bytes() > 0, "segments below the floor still trim");
    // A recovery replay from the committed cursor succeeds.
    assert!(log.read_from(100, 1000).is_ok());
}

#[test]
fn commit_for_an_unknown_partition_errors() {
    let mut r = rig(|_| {});
    r.engine.schedule(
        0,
        r.broker,
        Msg::rpc(RpcRequest {
            id: 7,
            reply_to: r.probe,
            from_node: 0,
            kind: RpcKind::CommitCheckpoint { epoch: 1, cursors: vec![(PartitionId(99), 0)] },
        }),
    );
    r.engine.run_until(SECOND);
    let reps = replies(&r.inbox);
    assert!(matches!(&reps[0].1.reply, RpcReply::Error { .. }), "{reps:?}");
}
